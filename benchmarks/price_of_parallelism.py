"""Paper §2.2: the price of parallelism -- round counts of the sequential
vs the parallel algorithm over a heterogeneous instance set, plus the
cascade worst case (m-fold inflation)."""
from __future__ import annotations


from repro.core import propagate, propagate_sequential
from repro.data import make_cascade_chain
from repro.data.instances import instances_for_set

from .common import geomean


def run():
    ratios = []
    n_equal = 0
    total = 0
    for set_name in ("Set-1", "Set-2", "Set-3"):
        for spec, p in instances_for_set(set_name, per_family=2):
            rs = propagate_sequential(p)
            rp = propagate(p, driver="device_loop")
            if rs.infeasible or bool(rp.infeasible):
                continue
            if not (rs.converged and bool(rp.converged)):
                continue
            total += 1
            ratios.append(int(rp.rounds) / max(1, rs.rounds))
            n_equal += 1
    cascade = make_cascade_chain(length=64)
    rs = propagate_sequential(cascade)
    rp = propagate(cascade)
    rows = [
        ("price_of_parallelism_geomean_ratio", 0.0,
         f"geomean_rounds_ratio={geomean(ratios):.2f} (paper: 1.4)"),
        ("price_of_parallelism_max_ratio", 0.0,
         f"max_rounds_ratio={max(ratios):.1f} over {total} instances (paper max: 22)"),
        ("price_of_parallelism_cascade", 0.0,
         f"seq_rounds={rs.rounds} par_rounds={int(rp.rounds)} (worst case ~m)"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
