"""Paper Table 1 / Figure 1: speedup of the parallel algorithm over cpu_seq
by instance-size set (geomean + percentiles).

Hardware-honest proxy (DESIGN.md §7): the "GPU" side is the JAX-parallel
algorithm (XLA:CPU, device_loop driver) on this container; cpu_seq is the
faithful numpy Algorithm 1.  Timing excludes one-time init (paper §4.3):
CSC build for cpu_seq, device transfer + jit compile for the parallel side.
On-TPU projections come from §Roofline, not from this benchmark.
"""
from __future__ import annotations

import numpy as np

from repro.core import DeviceProblem, propagate_sequential
from repro.core.propagator import _round_fn
from repro.core.types import DEFAULT_CONFIG
import jax
import jax.numpy as jnp

from .common import geomean, time_fn
from repro.data.instances import instances_for_set


def _timed_parallel(p, cfg=DEFAULT_CONFIG):
    """device_loop propagation with compile excluded from timing."""
    dp = DeviceProblem(p)
    round_fn = _round_fn(dp, cfg)

    @jax.jit
    def run(lb0, ub0):
        def body(s):
            lb, ub, _, r = s
            lb, ub, ch = round_fn(lb=lb, ub=ub)
            return lb, ub, ch, r + 1

        def cond(s):
            return s[2] & (s[3] < cfg.max_rounds)

        lb, ub, ch, r = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        return lb, ub, r

    run(dp.lb0, dp.ub0)[0].block_until_ready()  # compile (excluded)

    def call():
        run(dp.lb0, dp.ub0)[0].block_until_ready()

    return time_fn(call, repeats=3)


def _timed_seq(p):
    return time_fn(lambda: propagate_sequential(p), repeats=1, warmup=0)


def run(max_set: int = 6, per_family: int = 1):
    rows = []
    all_speedups = []
    for k in range(1, max_set + 1):
        set_name = f"Set-{k}"
        speedups = []
        for spec, p in instances_for_set(set_name, per_family=per_family):
            t_seq = _timed_seq(p)
            t_par = _timed_parallel(p)
            speedups.append(t_seq / t_par)
        all_speedups += speedups
        rows.append(
            (f"speedup_{set_name}", 0.0,
             f"geomean_speedup={geomean(speedups):.2f} n={len(speedups)}")
        )
    s = np.sort(all_speedups)
    rows.append(("speedup_all", 0.0, f"geomean={geomean(all_speedups):.2f}"))
    rows.append(
        ("speedup_percentiles", 0.0,
         f"p5={s[int(0.05*len(s))]:.2f} p50={np.median(s):.2f} "
         f"p95={s[min(len(s)-1, int(0.95*len(s))) ]:.2f}")
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
