"""Benchmark harness: one module per paper table/figure (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV per row.  Propagation runs in fp64
(the paper's default); the precision module covers fp32.
"""
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)


def main() -> None:
    from . import (
        baseline_validation,
        bench_prop,
        block_ell_engine,
        loop_variants,
        ordering,
        precision,
        price_of_parallelism,
        prop_roofline,
        speedup_sets,
    )

    modules = [
        ("§2.2 price of parallelism", price_of_parallelism),
        ("Table 1 speedups by size set", speedup_sets),
        ("Fig 2 precision (fp32 vs fp64)", precision),
        ("Fig 3 baseline validation", baseline_validation),
        ("App B ordering", ordering),
        ("App C loop variants", loop_variants),
        ("§4.4 propagation roofline", prop_roofline),
        ("beyond-paper: block-ELL engine", block_ell_engine),
        ("perf trajectory: BENCH_prop.json", bench_prop),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for title, mod in modules:
        if only and only not in mod.__name__:
            continue
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001 -- report and continue
            print(f"{mod.__name__},0,ERROR: {type(e).__name__}: {e}")
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"# [{title}] done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
