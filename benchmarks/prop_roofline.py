"""Paper §4.4 roofline analysis of the propagation kernel itself.

Static analysis (no TPU in this container): per-round arithmetic intensity
from instance structure, the three v5e roofline terms for a production-scale
sharded propagation (single round, per device), and the measured XLA:CPU
round throughput as a ground reference.

Paper numbers for comparison: AI ~= 2.96 (fp64), machine balance 8.53 on
V100 (memory-bound), 23.64% of attainable performance on average.
"""
from __future__ import annotations


from repro.core import DeviceProblem
from repro.data.instances import instances_for_set
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

from .common import geomean, time_fn


def round_flops_bytes(p, dtype_bytes=8):
    """Analytic per-round FLOPs and HBM bytes of the parallel algorithm.

    FLOPs: ~8 ops/nnz for activities (select, mul, add x2 sides) +
    ~10 ops/nnz for residual+candidates + ~2 ops/col for updates.
    Bytes: CSR arrays read once (val f8 + col i4 + row_id i4), bounds
    gathered once per nnz side, candidate scatter, bounds rewrite.
    """
    nnz, m, n = p.csr.nnz, p.m, p.n
    flops = 18 * nnz + 6 * m + 4 * n
    bytes_ = nnz * (dtype_bytes + 4 + 4 + 2 * dtype_bytes) + (
        4 * m + 6 * n
    ) * dtype_bytes
    return flops, bytes_


def run():
    rows = []
    ai_all = []
    for spec, p in instances_for_set("Set-4", per_family=2):
        f, b = round_flops_bytes(p)
        ai_all.append(f / b)
    rows.append(
        ("prop_arithmetic_intensity", 0.0,
         f"geomean_AI={geomean(ai_all):.3f} flop/byte "
         f"(paper: 2.96 measured; v5e balance={PEAK_FLOPS_BF16/HBM_BW:.1f})")
    )

    # Production-scale sharded round, per device (16M nnz / 256 chips).
    nnz, m, n = 16_000_000, 1_000_000, 500_000
    chips = 256
    f = (18 * nnz) / chips
    b = (nnz * (4 + 4 + 4 + 8)) / chips  # fp32 vals/bounds + int32 indices
    coll = (4 * m * 4 + 2 * n * 4 + 2 * n * 4)  # psum acts + pmax/pmin bounds
    t_c, t_m, t_i = f / PEAK_FLOPS_BF16, b / HBM_BW, coll / ICI_BW
    rows.append(
        ("prop_sharded_roofline_per_round", 0.0,
         f"t_compute={t_c:.2e}s t_memory={t_m:.2e}s t_collective={t_i:.2e}s "
         f"bottleneck={'collective' if t_i == max(t_c, t_m, t_i) else 'memory' if t_m == max(t_c, t_m, t_i) else 'compute'}")
    )

    # Measured XLA:CPU single-round throughput (ground reference).
    import jax
    from repro.core.propagator import _round_fn
    from repro.core.types import DEFAULT_CONFIG

    spec, p = instances_for_set("Set-6", per_family=1)[0]
    dp = DeviceProblem(p)
    rf = jax.jit(_round_fn(dp, DEFAULT_CONFIG))
    rf(lb=dp.lb0, ub=dp.ub0)[0].block_until_ready()
    t = time_fn(lambda: rf(lb=dp.lb0, ub=dp.ub0)[0].block_until_ready())
    f1, b1 = round_flops_bytes(p)
    rows.append(
        ("prop_round_measured_cpu", t * 1e6,
         f"nnz={p.csr.nnz} GB/s={b1/t/1e9:.2f} GFLOP/s={f1/t/1e9:.2f}")
    )

    # Measured bytes accessed per round (cost analysis, not the model above):
    # the fused in-VMEM gather+scatter round vs the seed candidates+segment
    # dataflow, on Set-2 (the acceptance set for the fused engine).  Shares
    # bench_prop's measurement so both tables report the same population.
    from .bench_prop import bytes_per_round

    fused_b = bytes_per_round("fused")
    legacy_b = bytes_per_round("legacy")
    reduction = geomean([l / f for l, f in zip(legacy_b, fused_b)])
    rows.append(
        ("prop_bytes_per_round_set2", 0.0,
         f"geomean_fused={geomean(fused_b):.0f}B geomean_legacy={geomean(legacy_b):.0f}B "
         f"reduction={reduction:.2f}x")
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
