"""Perf trajectory for the propagation engines.

Times one round per engine (the jnp-oracle arithmetic of each dataflow --
on CPU that is the honest number; interpret-mode Pallas timings measure the
emulator) and measures bytes accessed per round via
``repro.kernels.round_cost_analysis``, then writes ``BENCH_prop.json`` so
future PRs have a comparable perf baseline.
"""
from __future__ import annotations

import json

import jax

from repro.data.instances import instances_for_set
from repro.kernels import (
    legacy_round_fn_for,
    prepare_block_ell,
    round_cost_analysis,
    round_fn_for,
)

from .common import geomean, time_fn

SET = "Set-2"
PER_FAMILY = 2
ENGINES = ("fused", "segment", "legacy")
OUT_PATH = "BENCH_prop.json"


def bytes_per_round(engine: str, per_family: int = PER_FAMILY):
    """Measured bytes/round of one engine over the benchmark set (shared by
    this module and the roofline table so they report the same population)."""
    return [
        round_cost_analysis(p, engine)["bytes_accessed"]
        for _, p in instances_for_set(SET, per_family=per_family)
    ]


def run(out_path: str = OUT_PATH):
    insts = instances_for_set(SET, per_family=PER_FAMILY)
    acc = {e: {"round_us": [], "bytes": []} for e in ENGINES}
    for spec, p in insts:
        prep = prepare_block_ell(p)
        for engine in ENGINES:
            if engine == "legacy":
                fn = jax.jit(legacy_round_fn_for(prep, use_pallas=False))
                lb, ub = prep.d.lb0, prep.d.ub0
            else:
                fn = jax.jit(round_fn_for(prep, use_pallas=False, scatter=engine))
                lb, ub = prep.lb0, prep.ub0
            fn(lb, ub)[0].block_until_ready()  # compile outside the timer
            t = time_fn(lambda: fn(lb, ub)[0].block_until_ready())
            acc[engine]["round_us"].append(t * 1e6)
            acc[engine]["bytes"].append(
                round_cost_analysis(p, engine)["bytes_accessed"]
            )

    report = {
        "set": SET,
        "instances": len(insts),
        "engines": {
            e: {
                "geomean_round_us": geomean(v["round_us"]),
                "geomean_bytes_per_round": geomean(v["bytes"]),
            }
            for e, v in acc.items()
        },
    }
    report["bytes_reduction_fused_vs_legacy"] = geomean(
        [l / f for l, f in zip(acc["legacy"]["bytes"], acc["fused"]["bytes"])]
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        (
            f"bench_prop_{e}",
            report["engines"][e]["geomean_round_us"],
            f"geomean_bytes_per_round={report['engines'][e]['geomean_bytes_per_round']:.0f}",
        )
        for e in ENGINES
    ]
    rows.append(
        ("bench_prop_json", 0.0,
         f"written={out_path} "
         f"bytes_reduction_fused_vs_legacy={report['bytes_reduction_fused_vs_legacy']:.2f}x")
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
