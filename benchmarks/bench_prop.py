"""Perf trajectory for the propagation engines.

Times one round per engine (the jnp-oracle arithmetic of each dataflow --
on CPU that is the honest number; interpret-mode Pallas timings measure the
emulator) and measures bytes accessed per round via
``repro.kernels.round_cost_analysis``; additionally times full batched
propagation (one dispatch per bucket, ``propagate_batch``) against
sequential per-instance dispatches, and warm-start NODE batches (B nodes of
one instance over a shared resident matrix, ``propagate_nodes``) against
repacking each node as a fresh instance, reporting instances/sec and
nodes/sec throughput.

A ``partitioned`` engine row records the column-slab engine on
VMEM-exceeding banded large-n instances (``n_pad > SCATTER_MAX_NPAD``),
with the segment engine measured on the same instances for comparison.

Results are MERGED into ``BENCH_prop.json`` (engine rows are updated or
added, unknown keys from earlier PRs are preserved) so the perf trajectory
stays comparable across PRs.  See docs/BENCHMARKS.md for the JSON schema,
the paired-trials methodology, and the recipe for adding an engine row.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nodes import branch_children, propagate_nodes
from repro.core.propagator import fresh_instance_runner, owned_copy, propagate
from repro.data.instances import instances_for_set, make_banded, make_pseudo_boolean
from repro.kernels import (
    SCATTER_MAX_NPAD,
    batched_device_runner,
    legacy_round_fn_for,
    packed_problems,
    prepare_block_ell,
    prepare_problem_batch,
    round_cost_analysis,
    round_fn_for,
)

from .common import geomean, time_fn

SET = "Set-2"
PER_FAMILY = 2
ENGINES = ("fused", "segment", "legacy")
OUT_PATH = "BENCH_prop.json"

# Large-n population for the partitioned engine row: banded instances whose
# n_pad exceeds the VMEM accumulator budget (the regime the fused engine
# used to abandon to the segment fallback).  Banded columns keep the slab
# copy duplication near 1; nnz >> n so the nnz-proportional byte model, not
# the O(n_pad) resident vectors, dominates the comparison.
LARGE_N = SCATTER_MAX_NPAD + 4000
LARGE_SPECS = (
    dict(m=12_000, row_nnz=32, band=1024, seed=0),
    dict(m=15_000, row_nnz=32, band=1024, seed=1),
)
LARGE_TILE = dict(tile_rows=8, tile_width=32)

# Batched-throughput population: >= 8 Set-2 instances of the quick-verdict
# serving shape (set-cover presolves converge in one round, so the batch has
# no stragglers and the comparison isolates dispatch amortization -- the
# thing batching is for; straggler behaviour is covered by the per-instance
# convergence-mask tests instead).
BATCH_FAMILIES = ("set_cover",)
BATCH_PER_FAMILY = 12


def bytes_per_round(engine: str, per_family: int = PER_FAMILY):
    """Measured bytes/round of one engine over the benchmark set (shared by
    this module and the roofline table so they report the same population)."""
    return [
        round_cost_analysis(p, engine)["bytes_accessed"]
        for _, p in instances_for_set(SET, per_family=per_family)
    ]


def _single_dispatch_runner(prep, max_rounds: int = 100):
    """Per-instance jitted device-loop fixed point (the strongest sequential
    baseline: compile paid once outside the timer, one dispatch per call)."""
    round_fn = round_fn_for(prep, use_pallas=False)
    n = prep.n

    @jax.jit
    def run(lb0, ub0):
        def body(s):
            lb, ub, _, r = s
            lb, ub, ch = round_fn(lb, ub)
            return lb, ub, ch, r + 1

        def cond(s):
            return s[2] & (s[3] < max_rounds)

        lb, ub, ch, r = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        return lb[:n], ub[:n], r

    return run


def batched_throughput():
    """Instances/sec: one batched dispatch per bucket vs sequential
    per-instance dispatches, over >= 8 Set-2 instances (both sides use
    precompiled runners and identical tile layouts; compile excluded)."""
    problems = [
        p
        for _, p in instances_for_set(
            SET, per_family=BATCH_PER_FAMILY, families=BATCH_FAMILIES
        )
    ]

    seq_runners = [
        (_single_dispatch_runner(prep), prep)
        for prep in (prepare_block_ell(p) for p in problems)
    ]

    def run_sequential():
        for run, prep in seq_runners:
            lb, _, _ = run(owned_copy(prep.lb0), owned_copy(prep.ub0))
        lb.block_until_ready()

    batches = packed_problems(problems)
    batch_runners = [
        (batched_device_runner(prep, use_pallas=False), prep)
        for prep in (prepare_problem_batch(b) for b in batches)
    ]

    def run_batched():
        for run, prep in batch_runners:
            lb, *_ = run(owned_copy(prep.d.lb0), owned_copy(prep.d.ub0))
        lb.block_until_ready()

    # Paired trials (sequential and batched alternate within each trial) with
    # a median-of-trials speedup: robust against the container's background
    # load drifting between the two measurements.
    trials = []
    for _ in range(7):
        t_seq = time_fn(run_sequential, repeats=3, warmup=1)
        t_bat = time_fn(run_batched, repeats=3, warmup=1)
        trials.append((t_seq, t_bat))
    speedup = float(np.median([ts / tb for ts, tb in trials]))
    t_seq = float(np.median([ts for ts, _ in trials]))
    t_bat = float(np.median([tb for _, tb in trials]))
    n_inst = len(problems)
    return {
        "instances": n_inst,
        "buckets": len(batches),
        "bucket_shapes": [list(b.ell.val.shape) for b in batches],
        "sequential_instances_per_sec": n_inst / t_seq,
        "batched_instances_per_sec": n_inst / t_bat,
        "batched_speedup": speedup,
    }


# Node-batch population: one Set-2-sized pseudo-boolean instance (the paper's
# §1 target workload; rows carry <= 8 nonzeros so tile_width=8 keeps the
# block-ELL padding proportional to nnz) x NODE_BATCH warm-started nodes,
# each differing from the propagated root by a couple of branching fixings.
NODE_BATCH = 32
NODE_TILE = dict(tile_rows=8, tile_width=8)


def _node_population():
    root = make_pseudo_boolean(n=150, m=160, seed=1)  # seed 1: feasible root
    r0 = propagate(root)
    assert not bool(r0.infeasible)
    lb0, ub0 = np.asarray(r0.lb), np.asarray(r0.ub)
    rng = np.random.default_rng(0)
    lb_nodes = np.repeat(lb0[None, :], NODE_BATCH, axis=0)
    ub_nodes = np.repeat(ub0[None, :], NODE_BATCH, axis=0)
    for i in range(NODE_BATCH):
        lb, ub = lb_nodes[i], ub_nodes[i]
        for _ in range(2):
            free = np.flatnonzero(root.is_int & (lb < ub))
            var = int(rng.choice(free))
            (dlb, dub), (ulb, uub) = branch_children(lb, ub, var, lb[var])
            lb, ub = (dlb, dub) if rng.random() < 0.5 else (ulb, uub)
        lb_nodes[i], ub_nodes[i] = lb, ub
    return root, lb_nodes, ub_nodes


def node_throughput():
    """Nodes/sec: one warm-start node-batch dispatch over the shared
    resident matrix vs repacking-and-dispatching each node as a fresh
    instance (``core.fresh_instance_runner``: per-node host repack + full
    re-upload, compile excluded; paired median-of-trials as above)."""
    root, lb_nodes, ub_nodes = _node_population()

    def run_shared():
        res = propagate_nodes(
            root, lb_nodes, ub_nodes, use_pallas=False, **NODE_TILE
        )
        res.lb.block_until_ready()

    propagate_fresh = fresh_instance_runner(root)

    def run_repack():
        for i in range(NODE_BATCH):
            lb, *_ = propagate_fresh(lb_nodes[i], ub_nodes[i])
        lb.block_until_ready()

    propagate_fresh(lb_nodes[0], ub_nodes[0])[0].block_until_ready()  # compile
    trials = []
    for _ in range(7):
        t_rep = time_fn(run_repack, repeats=3, warmup=1)
        t_sha = time_fn(run_shared, repeats=3, warmup=1)
        trials.append((t_rep, t_sha))
    speedup = float(np.median([tr / ts for tr, ts in trials]))
    t_rep = float(np.median([tr for tr, _ in trials]))
    t_sha = float(np.median([ts for _, ts in trials]))
    return {
        "instance": {"family": "pseudo_boolean", "m": root.m, "n": root.n,
                     "nnz": root.nnz},
        "nodes": NODE_BATCH,
        "repack_nodes_per_sec": NODE_BATCH / t_rep,
        "shared_nodes_per_sec": NODE_BATCH / t_sha,
        "shared_matrix_speedup": speedup,
    }


def partitioned_large_row():
    """The ``partitioned`` engine row: round time + measured bytes/round of
    the column-slab engine on VMEM-exceeding banded instances, with the
    segment engine measured on the SAME instances for the comparison the
    partitioned engine exists to win (jnp-oracle arithmetic timings, like
    the other engine rows; bytes from ``round_cost_analysis``)."""
    acc = {
        "partitioned": {"round_us": [], "bytes": []},
        "segment": {"round_us": [], "bytes": []},
    }
    for spec in LARGE_SPECS:
        p = make_banded(n=LARGE_N, **spec)
        prep = prepare_block_ell(p, **LARGE_TILE)
        assert prep.n_pad > SCATTER_MAX_NPAD
        for engine in ("partitioned", "segment"):
            fn = jax.jit(round_fn_for(prep, use_pallas=False, scatter=engine))
            lb, ub = prep.lb0, prep.ub0
            fn(lb, ub)[0].block_until_ready()  # compile outside the timer
            t = time_fn(lambda: fn(lb, ub)[0].block_until_ready())
            acc[engine]["round_us"].append(t * 1e6)
            acc[engine]["bytes"].append(
                round_cost_analysis(p, engine, **LARGE_TILE)["bytes_accessed"]
            )
    return {
        "set": f"banded n={LARGE_N}",
        "instances": len(LARGE_SPECS),
        "n_pad_over_budget": True,
        "geomean_round_us": geomean(acc["partitioned"]["round_us"]),
        "geomean_bytes_per_round": geomean(acc["partitioned"]["bytes"]),
        "segment_geomean_round_us": geomean(acc["segment"]["round_us"]),
        "segment_geomean_bytes_per_round": geomean(acc["segment"]["bytes"]),
        "bytes_vs_segment": geomean(
            [pb / sb for pb, sb in zip(acc["partitioned"]["bytes"], acc["segment"]["bytes"])]
        ),
    }


def _merge_report(report: dict, out_path: str) -> dict:
    """Merge new engine rows into an existing BENCH_prop.json: engine rows
    are updated/added, any other keys from earlier PRs are preserved."""
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                old = json.load(f)
        except (OSError, json.JSONDecodeError):
            old = {}
        engines = dict(old.get("engines", {}))
        engines.update(report.get("engines", {}))
        merged = {**old, **report}
        merged["engines"] = engines
        return merged
    return report


def run(out_path: str = OUT_PATH):
    insts = instances_for_set(SET, per_family=PER_FAMILY)
    acc = {e: {"round_us": [], "bytes": []} for e in ENGINES}
    for spec, p in insts:
        prep = prepare_block_ell(p)
        for engine in ENGINES:
            if engine == "legacy":
                fn = jax.jit(legacy_round_fn_for(prep, use_pallas=False))
                lb, ub = prep.d.lb0, prep.d.ub0
            else:
                fn = jax.jit(round_fn_for(prep, use_pallas=False, scatter=engine))
                lb, ub = prep.lb0, prep.ub0
            fn(lb, ub)[0].block_until_ready()  # compile outside the timer
            t = time_fn(lambda: fn(lb, ub)[0].block_until_ready())
            acc[engine]["round_us"].append(t * 1e6)
            acc[engine]["bytes"].append(
                round_cost_analysis(p, engine)["bytes_accessed"]
            )

    thru = batched_throughput()
    nodes = node_throughput()
    large = partitioned_large_row()
    report = {
        "set": SET,
        "instances": len(insts),
        # The engine-row population (PR 3 added pseudo_boolean to the
        # default families, growing it 6 -> 8 instances): recorded so the
        # cross-PR trajectory is read against its workload, not assumed
        # constant.
        "families": sorted({spec.family for spec, _ in insts}),
        "engines": {
            e: {
                "geomean_round_us": geomean(v["round_us"]),
                "geomean_bytes_per_round": geomean(v["bytes"]),
            }
            for e, v in acc.items()
        },
    }
    report["engines"]["batched"] = {
        "instances_per_sec": thru["batched_instances_per_sec"],
        "speedup_vs_sequential_dispatch": thru["batched_speedup"],
    }
    report["engines"]["nodes"] = {
        "nodes_per_sec": nodes["shared_nodes_per_sec"],
        "speedup_vs_repack_dispatch": nodes["shared_matrix_speedup"],
    }
    report["engines"]["partitioned"] = large
    report["bytes_reduction_fused_vs_legacy"] = geomean(
        [l / f for l, f in zip(acc["legacy"]["bytes"], acc["fused"]["bytes"])]
    )
    report["batched_throughput"] = thru
    report["node_throughput"] = nodes
    report = _merge_report(report, out_path)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        (
            f"bench_prop_{e}",
            report["engines"][e]["geomean_round_us"],
            f"geomean_bytes_per_round={report['engines'][e]['geomean_bytes_per_round']:.0f}",
        )
        for e in ENGINES
    ]
    rows.append(
        ("bench_prop_batched",
         1e6 / thru["batched_instances_per_sec"],
         f"instances_per_sec={thru['batched_instances_per_sec']:.1f} "
         f"speedup_vs_sequential={thru['batched_speedup']:.2f}x "
         f"buckets={thru['buckets']} instances={thru['instances']}")
    )
    rows.append(
        ("bench_prop_nodes",
         1e6 / nodes["shared_nodes_per_sec"],
         f"nodes_per_sec={nodes['shared_nodes_per_sec']:.1f} "
         f"speedup_vs_repack={nodes['shared_matrix_speedup']:.2f}x "
         f"nodes={nodes['nodes']}")
    )
    rows.append(
        ("bench_prop_partitioned",
         large["geomean_round_us"],
         f"large_set={large['set']} "
         f"bytes_per_round={large['geomean_bytes_per_round']:.0f} "
         f"segment_bytes={large['segment_geomean_bytes_per_round']:.0f} "
         f"bytes_vs_segment={large['bytes_vs_segment']:.2f}x")
    )
    rows.append(
        ("bench_prop_json", 0.0,
         f"written={out_path} "
         f"bytes_reduction_fused_vs_legacy={report['bytes_reduction_fused_vs_legacy']:.2f}x")
    )
    return rows


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)  # match benchmarks.run
    for r in run():
        print(",".join(map(str, r)))
