"""Perf trajectory for the propagation engines.

Times one round per engine (the jnp-oracle arithmetic of each dataflow --
on CPU that is the honest number; interpret-mode Pallas timings measure the
emulator) and measures bytes accessed per round via
``repro.kernels.round_cost_analysis``; additionally times full batched
propagation (one dispatch per bucket, ``propagate_batch``) against
sequential per-instance dispatches, and warm-start NODE batches (B nodes of
one instance over a shared resident matrix, ``propagate_nodes``) against
repacking each node as a fresh instance, reporting instances/sec and
nodes/sec throughput.

A ``service`` row measures the continuous-batching propagation service
(``repro.core.service``) at saturation: instances pre-packed to slot shape
outside the timer, a closed submit->pump->retire loop over resident
super-tiles, reporting instances/sec, admit->retire latency percentiles,
mean slot occupancy, and a zero-recompile assertion over the serve loop.

A ``solver`` row measures the device-resident branch-and-bound driver
(``repro.core.solver.solve``) against the level-by-level Python driver of
``examples/bnb_dive.py`` on deep SOS1-style dives, reporting nodes/sec for
both drivers, the speedup (asserted >= 3x in the full run), and host syncs
per node on each side.

A ``partitioned`` engine row records the column-slab engine on
VMEM-exceeding banded large-n instances (``n_pad > SCATTER_MAX_NPAD``),
with the segment engine measured on the same instances for comparison.
The row sweeps candidate SLAB_NPAD widths (``sweep_slab_widths``), reports
the tuned width's round time plus a fenced per-phase breakdown
(copy/reduce/combine/merge), and nests its population facts under
``population`` so the row's top level holds measurements only.  ``--smoke``
runs a scaled-down row through the same builder and asserts its schema
merges cleanly (the CI bench-smoke job).

Results are MERGED into ``BENCH_prop.json`` (engine rows are updated or
added, unknown keys from earlier PRs are preserved) so the perf trajectory
stays comparable across PRs.  See docs/BENCHMARKS.md for the JSON schema,
the paired-trials methodology, and the recipe for adding an engine row.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds as bnd
from repro.core.nodes import branch_children, pick_most_fractional, propagate_nodes
from repro.core.propagator import fresh_instance_runner, owned_copy, propagate
from repro.core.service import BucketSpec, PropagationService
from repro.core.solver import solve
from repro.core.sparse import Problem, batch_stats, csr_from_dense
from repro.core.types import DEFAULT_CONFIG, INF
from repro.data.instances import instances_for_set, make_banded, make_pseudo_boolean
from repro.kernels import (
    SCATTER_MAX_NPAD,
    SLAB_NPAD,
    batched_device_runner,
    legacy_round_fn_for,
    packed_problems,
    prepare_block_ell,
    prepare_problem_batch,
    propagate_block_ell,
    round_cost_analysis,
    round_fn_for,
)
from repro.kernels import ref as kref
from repro.kernels.ops import default_slab_width
from repro.obs.metrics import run_metadata
from repro.obs.timing import (
    median_of,
    median_ratio,
    paired_trials,
    time_fenced,
    time_phases,
)
from repro.obs.trace import SPAN_KEYS, Tracer

from .common import geomean

SET = "Set-2"
PER_FAMILY = 2
ENGINES = ("fused", "segment", "legacy")
OUT_PATH = "BENCH_prop.json"

# Large-n population for the partitioned engine row: banded instances whose
# n_pad exceeds the VMEM accumulator budget (the regime the fused engine
# used to abandon to the segment fallback).  Banded columns keep the slab
# copy duplication near 1; nnz >> n so the nnz-proportional byte model, not
# the O(n_pad) resident vectors, dominates the comparison.
LARGE_N = SCATTER_MAX_NPAD + 4000
LARGE_SPECS = (
    dict(m=12_000, row_nnz=32, band=1024, seed=0),
    dict(m=15_000, row_nnz=32, band=1024, seed=1),
)
LARGE_TILE = dict(tile_rows=8, tile_width=32)

# Batched-throughput population: >= 8 Set-2 instances of the quick-verdict
# serving shape (set-cover presolves converge in one round, so the batch has
# no stragglers and the comparison isolates dispatch amortization -- the
# thing batching is for; straggler behaviour is covered by the per-instance
# convergence-mask tests instead).
BATCH_FAMILIES = ("set_cover",)
BATCH_PER_FAMILY = 12
# Both sides use the SAME fill-tuned tile layout: set-cover rows carry ~10
# nonzeros, so the default tile_width=128 pads each chunk >90% empty and the
# batched row would mostly measure padding arithmetic, under-reporting real
# throughput.  tile_width=16 keeps every bucket's super-tile at least half
# full (asserted below, recorded as ``bucket_fill``).
BATCH_TILE = dict(tile_rows=8, tile_width=16)
BATCH_MIN_FILL = 0.5


def bytes_per_round(engine: str, per_family: int = PER_FAMILY):
    """Measured bytes/round of one engine over the benchmark set (shared by
    this module and the roofline table so they report the same population)."""
    return [
        round_cost_analysis(p, engine)["bytes_accessed"]
        for _, p in instances_for_set(SET, per_family=per_family)
    ]


def _single_dispatch_runner(prep, max_rounds: int = 100):
    """Per-instance jitted device-loop fixed point (the strongest sequential
    baseline: compile paid once outside the timer, one dispatch per call)."""
    round_fn = round_fn_for(prep, use_pallas=False)
    n = prep.n

    @jax.jit
    def run(lb0, ub0):
        def body(s):
            lb, ub, _, r = s
            lb, ub, ch = round_fn(lb, ub)
            return lb, ub, ch, r + 1

        def cond(s):
            return s[2] & (s[3] < max_rounds)

        lb, ub, ch, r = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        return lb[:n], ub[:n], r

    return run


def batched_throughput():
    """Instances/sec: one batched dispatch per bucket vs sequential
    per-instance dispatches, over >= 8 Set-2 instances (both sides use
    precompiled runners and the identical fill-tuned ``BATCH_TILE`` layout;
    compile excluded; per-bucket super-tile occupancy recorded and required
    to stay >= ``BATCH_MIN_FILL``)."""
    problems = [
        p
        for _, p in instances_for_set(
            SET, per_family=BATCH_PER_FAMILY, families=BATCH_FAMILIES
        )
    ]

    seq_runners = [
        (_single_dispatch_runner(prep), prep)
        for prep in (prepare_block_ell(p, **BATCH_TILE) for p in problems)
    ]

    def run_sequential():
        for run, prep in seq_runners:
            lb, _, _ = run(owned_copy(prep.lb0), owned_copy(prep.ub0))
        lb.block_until_ready()

    batches = packed_problems(problems, **BATCH_TILE)
    fills = [b["fill"] for b in batch_stats(batches)["per_bucket"]]
    assert min(fills) >= BATCH_MIN_FILL, (
        f"batched-row population under-fills its super-tiles: {fills} "
        f"(grow BATCH_PER_FAMILY or retune BATCH_TILE)"
    )
    batch_runners = [
        (batched_device_runner(prep, use_pallas=False), prep)
        for prep in (prepare_problem_batch(b) for b in batches)
    ]

    def run_batched():
        for run, prep in batch_runners:
            lb, *_ = run(owned_copy(prep.d.lb0), owned_copy(prep.d.ub0))
        lb.block_until_ready()

    # Paired trials (sequential and batched alternate within each trial) with
    # a median-of-trials speedup: robust against the container's background
    # load drifting between the two measurements.
    trials = paired_trials([run_sequential, run_batched], trials=7, repeats=3)
    speedup = median_ratio(trials, num=0, den=1)
    t_seq = median_of(trials, 0)
    t_bat = median_of(trials, 1)
    n_inst = len(problems)
    return {
        "instances": n_inst,
        "buckets": len(batches),
        "bucket_shapes": [list(b.ell.val.shape) for b in batches],
        "bucket_fill": [float(f) for f in fills],
        "tile_width": BATCH_TILE["tile_width"],
        "sequential_instances_per_sec": n_inst / t_seq,
        "batched_instances_per_sec": n_inst / t_bat,
        "batched_speedup": speedup,
    }


# Node-batch population: one Set-2-sized pseudo-boolean instance (the paper's
# §1 target workload; rows carry <= 8 nonzeros so tile_width=8 keeps the
# block-ELL padding proportional to nnz) x NODE_BATCH warm-started nodes,
# each differing from the propagated root by a couple of branching fixings.
NODE_BATCH = 32
NODE_TILE = dict(tile_rows=8, tile_width=8)


def _node_population():
    root = make_pseudo_boolean(n=150, m=160, seed=1)  # seed 1: feasible root
    r0 = propagate(root)
    assert not bool(r0.infeasible)
    lb0, ub0 = np.asarray(r0.lb), np.asarray(r0.ub)
    rng = np.random.default_rng(0)
    lb_nodes = np.repeat(lb0[None, :], NODE_BATCH, axis=0)
    ub_nodes = np.repeat(ub0[None, :], NODE_BATCH, axis=0)
    for i in range(NODE_BATCH):
        lb, ub = lb_nodes[i], ub_nodes[i]
        for _ in range(2):
            free = np.flatnonzero(root.is_int & (lb < ub))
            var = int(rng.choice(free))
            (dlb, dub), (ulb, uub) = branch_children(lb, ub, var, lb[var])
            lb, ub = (dlb, dub) if rng.random() < 0.5 else (ulb, uub)
        lb_nodes[i], ub_nodes[i] = lb, ub
    return root, lb_nodes, ub_nodes


def node_throughput():
    """Nodes/sec: one warm-start node-batch dispatch over the shared
    resident matrix vs repacking-and-dispatching each node as a fresh
    instance (``core.fresh_instance_runner``: per-node host repack + full
    re-upload, compile excluded; paired median-of-trials as above)."""
    root, lb_nodes, ub_nodes = _node_population()

    def run_shared():
        res = propagate_nodes(
            root, lb_nodes, ub_nodes, use_pallas=False, **NODE_TILE
        )
        res.lb.block_until_ready()

    propagate_fresh = fresh_instance_runner(root)

    def run_repack():
        for i in range(NODE_BATCH):
            lb, *_ = propagate_fresh(lb_nodes[i], ub_nodes[i])
        lb.block_until_ready()

    propagate_fresh(lb_nodes[0], ub_nodes[0])[0].block_until_ready()  # compile
    trials = paired_trials([run_repack, run_shared], trials=7, repeats=3)
    speedup = median_ratio(trials, num=0, den=1)
    t_rep = median_of(trials, 0)
    t_sha = median_of(trials, 1)
    return {
        "instance": {"family": "pseudo_boolean", "m": root.m, "n": root.n,
                     "nnz": root.nnz},
        "nodes": NODE_BATCH,
        "repack_nodes_per_sec": NODE_BATCH / t_rep,
        "shared_nodes_per_sec": NODE_BATCH / t_sha,
        "shared_matrix_speedup": speedup,
    }


# Solver-row population: SOS1-style instances -- one ``sum x <= 1`` packing
# row over all n binaries plus a few redundant two-variable rows.  Branching
# a variable UP (to 1) propagates every other variable to 0 in one round, so
# the search is a width-2 dive of depth ~n: the host-overhead-dominated
# regime a device-resident search loop exists for.  Both drivers use the
# same rule, branch point and pruning test, so they visit the IDENTICAL
# (2n-3)-node tree and nodes/sec compares equal work -- on wide trees the
# two drivers saturate at parity on CPU (the pool sweep costs what the
# frontier stack costs), so this row isolates the loop-hosting cost the
# solver removes, not propagation arithmetic.
SOLVER_NS = (48, 64)
SOLVER_EXTRA_ROWS = 4
# Tuned on this population: the dive's frontier never exceeds 2 open nodes,
# so a tiny pool keeps the per-level sweep cheap, and sync_every=16
# amortizes the host readback over 16 levels per dispatch.
SOLVER_KW = dict(node_cap=8, max_levels=256, sync_every=16, use_pallas=False)

# Every key the ``solver`` row must carry (the smoke job and
# docs/BENCHMARKS.md read this set; population facts are NESTED under
# ``population`` like the partitioned/service rows).
SOLVER_ROW_KEYS = frozenset({
    "population",
    "device_nodes_per_sec",
    "python_nodes_per_sec",
    "speedup_vs_python_driver",
    "target_met",
    "host_syncs",
    "python_host_syncs",
    "host_syncs_per_node",
    "python_host_syncs_per_node",
    "nodes",
    "levels",
    "objective_match",
    "statuses",
})


def _sos1_problem(n: int, extra_rows: int = SOLVER_EXTRA_ROWS) -> Problem:
    """One ``sum x <= 1`` row over n binaries plus ``extra_rows`` redundant
    pair rows (``x_i + x_j <= 1``) so the matrix is not a single row."""
    dense = np.zeros((1 + extra_rows, n))
    dense[0] = 1.0
    for k in range(extra_rows):
        dense[1 + k, k % n] = 1.0
        dense[1 + k, (k * 7 + 3) % n] = 1.0
    m = 1 + extra_rows
    return Problem(
        csr=csr_from_dense(dense),
        lhs=np.full(m, -INF),
        rhs=np.ones(m),
        lb=np.zeros(n, np.float64),
        ub=np.ones(n, np.float64),
        is_int=np.ones(n, bool),
    )


def _sos1_objective(n: int) -> np.ndarray:
    """Integral mixed-sign costs (every third negative) -- same shape the
    differential tests use, so pruning does real work on the dive."""
    sign = np.where(np.arange(n) % 3 == 0, -1.0, 1.0)
    return np.arange(1, n + 1, dtype=np.float64) * sign


def _python_bnb(p, c, node_cap: int, max_levels: int):
    """The level-by-level Python driver of ``examples/bnb_dive.py``: one
    ``propagate_nodes`` dispatch per frontier level, ALL search bookkeeping
    (objective, branching, incumbent, pruning) in host numpy, one readback
    per level.  Returns ``(incumbent, created, levels, syncs)``."""
    frontier = [(np.asarray(p.lb, np.float64), np.asarray(p.ub, np.float64))]
    inc = INF
    created, levels, syncs = 1, 0, 0
    while frontier and levels < max_levels:
        levels += 1
        lbs = np.stack([nd[0] for nd in frontier])
        ubs = np.stack([nd[1] for nd in frontier])
        out = propagate_nodes(
            p, lbs, ubs, use_pallas=False, tile_rows=8, tile_width=8
        )
        lbs, ubs = np.asarray(out.lb), np.asarray(out.ub)
        infeas = np.asarray(out.infeasible)
        syncs += 1  # readback before ANY host-side search decision
        nxt = []
        for i in range(lbs.shape[0]):
            if infeas[i]:
                continue
            lb, ub = lbs[i], ubs[i]
            obj = float(np.sum(np.where(c > 0, c * lb, c * ub)))
            if obj >= inc:
                continue
            var = pick_most_fractional(lb, ub, p.is_int)
            if var is None:
                inc = obj
                continue
            bv = np.clip(
                np.floor(0.5 * (lb[var] + ub[var])), lb[var], ub[var] - 1.0
            )
            down, up = branch_children(lb, ub, var, float(bv))
            nxt += [down, up]
            created += 2
        frontier = nxt[:node_cap]
    return inc, created, levels, syncs


def solver_row(
    ns=SOLVER_NS,
    kw: dict | None = None,
    trials: int = 5,
    repeats: int = 3,
    assert_target: bool = True,
):
    """Device-resident ``solve()`` vs the hosted level-by-level driver.

    Per instance the row first proves the comparison is apples-to-apples --
    same optimum AND same node count (identical trees) -- then times both
    drivers with :func:`paired_trials` (warm-up outside the timer, paired
    median-of-trials; docs/BENCHMARKS.md).  Reported nodes/sec divides each
    search's created-node count by its median wall time; ``target_met``
    records the tentpole criterion -- geomean speedup >= 3x on the CPU
    backend -- and the full run asserts it (``assert_target=False`` in the
    single-repeat smoke, where the schema is the contract)."""
    kw = dict(SOLVER_KW, **(kw or {}))
    dev_rate, py_rate, ratios = [], [], []
    nodes_l, levels_l, syncs_l, py_syncs_l, statuses = [], [], [], [], []
    objective_match = True
    for n in ns:
        p = _sos1_problem(n)
        c = _sos1_objective(n)
        res = solve(p, c, **kw)  # warm-up: prepare tiles + compile runner
        py_inc, py_created, py_levels, py_syncs = _python_bnb(
            p, c, kw["node_cap"], kw["max_levels"]
        )
        objective_match &= py_inc == res.objective
        assert py_inc == res.objective, (n, py_inc, res.objective)
        assert py_created == res.nodes_created, (
            n, py_created, res.nodes_created,
        )
        trials_ = paired_trials(
            [
                lambda: _python_bnb(p, c, kw["node_cap"], kw["max_levels"]),
                lambda: solve(p, c, **kw),
            ],
            trials=trials,
            repeats=repeats,
        )
        t_py, t_dev = median_of(trials_, 0), median_of(trials_, 1)
        ratios.append(median_ratio(trials_, num=0, den=1))
        dev_rate.append(res.nodes_created / t_dev)
        py_rate.append(py_created / t_py)
        nodes_l.append(int(res.nodes_created))
        levels_l.append(int(res.levels))
        syncs_l.append(int(res.host_syncs))
        py_syncs_l.append(int(py_syncs))
        statuses.append(res.status)
    speedup = geomean(ratios)
    if assert_target:
        assert speedup >= 3.0, (speedup, ratios)
    return {
        "population": {
            "family": "sos1",
            "ns": list(ns),
            "extra_rows": SOLVER_EXTRA_ROWS,
            "node_cap": kw["node_cap"],
            "sync_every": kw["sync_every"],
        },
        "device_nodes_per_sec": geomean(dev_rate),
        "python_nodes_per_sec": geomean(py_rate),
        "speedup_vs_python_driver": speedup,
        "target_met": bool(speedup >= 3.0),
        "host_syncs": syncs_l,
        "python_host_syncs": py_syncs_l,
        "host_syncs_per_node": float(sum(syncs_l)) / sum(nodes_l),
        "python_host_syncs_per_node": float(sum(py_syncs_l)) / sum(nodes_l),
        "nodes": nodes_l,
        "levels": levels_l,
        "objective_match": bool(objective_match),
        "statuses": statuses,
    }


# Service-row population: the FULL Set-2 family mix (the same four families
# as the engine rows), sized to keep the slot pool saturated.  A mixed
# stream is the serving scenario the slot machinery exists for: instances
# converge at different round counts, so quick ones retire and backfill
# while stragglers keep their slots -- a single-family population would
# degenerate to lockstep waves and measure none of that.
SERVICE_PER_FAMILY = 6
SERVICE_SLOTS = 4
SERVICE_SIZE_CLASSES = 2

# Every key the ``service`` row must carry (the smoke job and
# docs/BENCHMARKS.md read this set; population facts are NESTED under
# ``population`` like the partitioned row).
SERVICE_ROW_KEYS = frozenset({
    "population",
    "instances_per_sec",
    "sequential_instances_per_sec",
    "tuned_sequential_instances_per_sec",
    "speedup_vs_sequential_dispatch",
    "speedup_vs_tuned_sequential",
    "latency_ms_p50",
    "latency_ms_p95",
    "latency_ms_p99",
    "queue_latency_ms_p50",
    "queue_latency_ms_p95",
    "queue_latency_ms_p99",
    "service_latency_ms_p50",
    "service_latency_ms_p95",
    "service_latency_ms_p99",
    "mean_slot_occupancy",
    "bucket_fill",
    "compiles_during_serve",
})


def service_row(
    per_family: int = SERVICE_PER_FAMILY,
    slots: int = SERVICE_SLOTS,
    size_classes: int = SERVICE_SIZE_CLASSES,
    rounds_per_step: int = 8,
    tile_width: int | None = None,
    trials: int = 5,
    repeats: int = 3,
):
    """Continuous-batching service throughput at saturation.

    Closed loop: every instance is pre-packed to its slot shape OUTSIDE the
    timer (the measured loop is submit -> pump -> retire, device-bound, not
    host packing), all submitted at once so the slot pool stays saturated,
    then drained.  Two sequential baselines, both per-instance jitted
    single-dispatch runners with compile excluded: the DEFAULT-layout one
    is the baseline of record (the same definition the batched row has
    carried since its 1.05x days, so the headline speedup is comparable
    across PRs), and the fill-tuned one (the service's own tile sizing
    applied per instance) is recorded alongside so the layout contribution
    to the headline is explicit rather than hidden.  Latency percentiles
    are per ticket from the last timed trial, split three ways:
    submit->retire (``latency_ms_*``), submit->admit queueing
    (``queue_latency_ms_*``) and admit->retire resident service time
    (``service_latency_ms_*``);
    ``compiles_during_serve`` asserts the AOT warmup covered every engine
    the loop dispatched (slot backfill never recompiles).

    ``tile_width`` pins the bucket tile width (``None`` = fill-tuned per
    bucket, the default the service ships with); :func:`service_sweep_row`
    sweeps it alongside ``slots``/``rounds_per_step``."""
    problems = [p for _, p in instances_for_set(SET, per_family=per_family)]
    n_inst = len(problems)

    seq_runners = [
        (_single_dispatch_runner(prep), prep)
        for prep in (prepare_block_ell(p) for p in problems)
    ]

    def run_sequential():
        for run, prep in seq_runners:
            lb, _, _ = run(owned_copy(prep.lb0), owned_copy(prep.ub0))
        lb.block_until_ready()

    specs = BucketSpec.for_problems(
        problems, slots=slots, tile_width=tile_width,
        size_classes=size_classes,
    )
    tuned_runners = [
        (_single_dispatch_runner(prep), prep)
        for prep in (
            prepare_block_ell(
                p,
                tile_width=next(
                    s for s in specs if s.fits_problem(p)
                ).tile_width,
            )
            for p in problems
        )
    ]

    def run_tuned_sequential():
        for run, prep in tuned_runners:
            lb, _, _ = run(owned_copy(prep.lb0), owned_copy(prep.ub0))
        lb.block_until_ready()

    svc = PropagationService(
        specs, rounds_per_step=rounds_per_step, use_pallas=False
    )
    payloads = []
    for p in problems:
        spec = next(s for s in specs if s.fits_problem(p))
        payloads.append(spec.pack(p, dtype=np.float64))
    fill_by_spec = {
        s: [pl.fill() for pl in payloads if s.admits(pl)] for s in specs
    }

    last_tickets: list = []

    def run_service():
        last_tickets[:] = [svc.submit(payload=pl) for pl in payloads]
        svc.drain()

    run_service()  # settle allocator/caches outside the timer (compile
    # already happened at service construction -- AOT warmup)
    counts_before = svc.compile_counts()

    trials_ = paired_trials(
        [run_sequential, run_tuned_sequential, run_service],
        trials=trials, repeats=repeats,
    )
    counts_after = svc.compile_counts()
    compiles = sum(
        (a["step"] or 0) - (b["step"] or 0)
        + sum((a["admit"][k] or 0) - (b["admit"][k] or 0) for k in a["admit"])
        for a, b in zip(counts_after.values(), counts_before.values())
    )
    assert compiles == 0, f"serve loop recompiled: {counts_after}"

    speedup = median_ratio(trials_, num=0, den=2)
    speedup_tuned = median_ratio(trials_, num=1, den=2)
    t_seq = median_of(trials_, 0)
    t_tun = median_of(trials_, 1)
    t_svc = median_of(trials_, 2)
    lat_ms = np.asarray([tk.latency() for tk in last_tickets]) * 1e3
    queue_ms = np.asarray([tk.queue_latency() for tk in last_tickets]) * 1e3
    svc_ms = np.asarray([tk.service_latency() for tk in last_tickets]) * 1e3
    st = svc.stats()
    # Already a fraction of the slot pool: the bucket accumulates
    # occupied/slots per pump.
    occ = float(np.mean([b["mean_occupancy"] for b in st["buckets"]]))
    return {
        "population": {
            "set": SET,
            "families": sorted({s.family for s, _ in
                                instances_for_set(SET, per_family=1)}),
            "instances": n_inst,
            "buckets": len(specs),
            "slots": slots,
            "size_classes": size_classes,
            "rounds_per_step": rounds_per_step,
            "tile_widths": sorted({s.tile_width for s in specs}),
            "payloads_prebuilt": True,
        },
        "instances_per_sec": n_inst / t_svc,
        "sequential_instances_per_sec": n_inst / t_seq,
        "tuned_sequential_instances_per_sec": n_inst / t_tun,
        "speedup_vs_sequential_dispatch": speedup,
        "speedup_vs_tuned_sequential": speedup_tuned,
        "latency_ms_p50": float(np.percentile(lat_ms, 50)),
        "latency_ms_p95": float(np.percentile(lat_ms, 95)),
        "latency_ms_p99": float(np.percentile(lat_ms, 99)),
        "queue_latency_ms_p50": float(np.percentile(queue_ms, 50)),
        "queue_latency_ms_p95": float(np.percentile(queue_ms, 95)),
        "queue_latency_ms_p99": float(np.percentile(queue_ms, 99)),
        "service_latency_ms_p50": float(np.percentile(svc_ms, 50)),
        "service_latency_ms_p95": float(np.percentile(svc_ms, 95)),
        "service_latency_ms_p99": float(np.percentile(svc_ms, 99)),
        "mean_slot_occupancy": occ,
        "bucket_fill": [
            float(np.mean(fill_by_spec[s])) for s in specs if fill_by_spec[s]
        ],
        "compiles_during_serve": int(compiles),
    }


# The service tuning sweep: the row of record's config first, then
# one-factor moves around it (more slots, one size class, shorter/longer
# pump quanta, a pinned narrow tile).  A full factorial would mostly time
# jit compilation of baselines; one-factor probes around the shipped point
# answer the question the row exists for -- is the 0.5x headline a tuning
# artifact or structural?
SERVICE_SWEEP_GRID = (
    dict(slots=4, size_classes=2, rounds_per_step=8, tile_width=None),
    dict(slots=8, size_classes=2, rounds_per_step=8, tile_width=None),
    dict(slots=4, size_classes=1, rounds_per_step=8, tile_width=None),
    dict(slots=4, size_classes=2, rounds_per_step=4, tile_width=None),
    dict(slots=4, size_classes=2, rounds_per_step=16, tile_width=None),
    dict(slots=4, size_classes=2, rounds_per_step=8, tile_width=32),
)

_SWEEP_CFG_KEYS = ("slots", "size_classes", "rounds_per_step", "tile_width")

# Every key the ``service_sweep`` row must carry (the smoke job and
# docs/BENCHMARKS.md read this set).
SERVICE_SWEEP_ROW_KEYS = frozenset({
    "grid",
    "tuned",
    "target_met",
})


def service_sweep_row(
    grid=SERVICE_SWEEP_GRID,
    per_family: int = SERVICE_PER_FAMILY,
    trials: int = 3,
    repeats: int = 2,
    final_trials: int = 5,
    final_repeats: int = 3,
):
    """Sweep the service's tuning knobs and re-measure the best point.

    Each grid point runs :func:`service_row` at reduced fidelity (the sweep
    ranks configs; it does not need publication-grade medians), the config
    maximizing ``speedup_vs_tuned_sequential`` is re-run at full fidelity,
    and ``target_met`` records whether the tuned point clears 1.0x against
    the fill-tuned sequential baseline.  When it does not, the grid is the
    evidence that the gap is structural (pump-quantum overshoot on a
    fast-converging population) rather than a mistuned default -- see
    docs/BENCHMARKS.md."""
    points = []
    for cfg in grid:
        row = service_row(
            per_family=per_family, trials=trials, repeats=repeats, **cfg
        )
        points.append({
            **{k: cfg[k] for k in _SWEEP_CFG_KEYS},
            "speedup_vs_tuned_sequential": row["speedup_vs_tuned_sequential"],
            "speedup_vs_sequential_dispatch":
                row["speedup_vs_sequential_dispatch"],
            "instances_per_sec": row["instances_per_sec"],
        })
    best = max(points, key=lambda r: r["speedup_vs_tuned_sequential"])
    best_cfg = {k: best[k] for k in _SWEEP_CFG_KEYS}
    tuned = service_row(
        per_family=per_family, trials=final_trials, repeats=final_repeats,
        **best_cfg,
    )
    return {
        "grid": points,
        "tuned": {"config": best_cfg, **tuned},
        "target_met": bool(tuned["speedup_vs_tuned_sequential"] >= 1.0),
    }


# Every key the ``partitioned`` engine row must carry (the smoke job and
# docs/BENCHMARKS.md read this set; population facts are NESTED so the row's
# top level holds only measurements, like every other engine row).
PARTITIONED_ROW_KEYS = frozenset({
    "population",
    "geomean_round_us",
    "geomean_bytes_per_round",
    "segment_geomean_round_us",
    "segment_geomean_bytes_per_round",
    "round_us_vs_segment",
    "bytes_vs_segment",
    "tuned_slab_npad",
    "slab_sweep_us",
    "phases_us",
})

PHASE_NAMES = ("copy", "reduce", "combine", "merge")


def _partitioned_phase_fns(prep, part):
    """The partitioned round's four phases as separately jitted closures
    (jnp-oracle arithmetic, matching the engine-row timings):

      * ``copy``    -- pad the bound plane to the slab grid and gather every
        main-stream and straddle-stream copy's slab-local bound windows;
      * ``reduce``  -- per-copy activity partials over both streams;
      * ``combine`` -- straddle-table segment sum, per-row aggregate
        selection, and the candidate arithmetic;
      * ``merge``   -- the column reduction (rectangle gather when
        scheduled) and the bound merge.

    Each returns concrete arrays so ``jax.block_until_ready`` fences the
    phase boundary; feeding phase N the MATERIALIZED outputs of phase N-1
    is exactly what the fused kernel avoids, so the per-phase sum runs
    above the fused round time -- the breakdown is for attribution, not a
    faster total."""
    cfg = DEFAULT_CONFIG
    dt = prep.d.val.dtype
    eps = cfg.eps_for(dt)
    int_eps, inf = cfg.int_eps, cfg.inf
    n_pad = prep.n_pad
    extra = part.n_pad_part - n_pad
    has_straddle = part.has_straddle

    @jax.jit
    def copy_phase(lb, ub):
        z = jnp.zeros((extra,), lb.dtype)
        lbf = jnp.concatenate([lb, z])
        ubf = jnp.concatenate([ub, z])
        lb_g, ub_g, col_g = kref._partitioned_gathered_bounds(
            part, lbf, ubf, part.val, part.col_s, part.tile_inst, part.tile_slab
        )
        if has_straddle:
            a_lb, a_ub, _ = kref._partitioned_gathered_bounds(
                part, lbf, ubf, part.a_val, part.a_col_s,
                part.a_tile_inst, part.a_tile_slab,
            )
        else:
            a_lb = a_ub = jnp.zeros((0,) + part.val.shape[1:], dt)
        return lb_g, ub_g, a_lb, a_ub, col_g

    @jax.jit
    def reduce_phase(lb_g, ub_g, a_lb, a_ub):
        main = kref.activities_tiles_ref(part.val, lb_g, ub_g, inf)
        if has_straddle:
            sub = kref.activities_tiles_ref(part.a_val, a_lb, a_ub, inf)
        else:
            sub = main
        return main, sub

    @jax.jit
    def combine_phase(main, sub, lb_g, ub_g):
        mf, mc, xf, xc = main
        if has_straddle:
            slot = part.a_slot.reshape(-1)
            nseg = part.n_straddle + 1
            tab = lambda x: jax.ops.segment_sum(
                x.reshape(-1), slot, num_segments=nseg
            )
            done = part.row_done != 0
            sel = lambda local, t: jnp.where(done, local, tab(t)[part.agg_slot])
            amf, amc, axf, axc = sub
            rmf, rmc, rxf, rxc = sel(mf, amf), sel(mc, amc), sel(xf, axf), sel(xc, axc)
        else:
            rmf, rmc, rxf, rxc = mf, mc, xf, xc
        return kref.candidates_tiles_ref(
            part.val, lb_g, ub_g, part.ii_g != 0, rmf, rmc, rxf, rxc,
            part.lhs_g, part.rhs_g, int_eps, inf,
        )

    @jax.jit
    def merge_phase(lcand, ucand, col_g, lb, ub):
        if part.col_slots is not None:
            fl = jnp.concatenate([lcand.reshape(-1), jnp.full((1,), -inf, dt)])
            fu = jnp.concatenate([ucand.reshape(-1), jnp.full((1,), inf, dt)])
            best_l = jnp.maximum(fl[part.col_slots].max(axis=1), -inf)
            best_u = jnp.minimum(fu[part.col_slots].min(axis=1), inf)
        else:
            best_l, best_u = kref.batched_scatter_round_ref(
                lcand, ucand, col_g, 1, part.n_pad_part, inf
            )
            best_l, best_u = best_l.reshape(-1), best_u.reshape(-1)
        return bnd.apply_updates(lb, ub, best_l[:n_pad], best_u[:n_pad], eps, inf)

    return copy_phase, reduce_phase, combine_phase, merge_phase


def _partitioned_phase_times(prep, part, repeats: int = 3, tracer=None) -> dict:
    """Per-phase wall times (us) of one partitioned round via
    ``obs.timing.time_phases``: each phase is fed the previous phase's
    ready outputs and fenced at its boundary; a ``tracer`` additionally
    emits one ``phase:<name>`` span per phase onto the shared trace."""
    copy_f, reduce_f, combine_f, merge_f = _partitioned_phase_fns(prep, part)
    g = jax.block_until_ready
    lb, ub = prep.lb0, prep.ub0
    gathered = g(copy_f(lb, ub))
    partials = g(reduce_f(*gathered[:4]))
    cands = g(combine_f(*partials, gathered[0], gathered[1]))
    g(merge_f(*cands, gathered[4], lb, ub))
    return time_phases(
        {
            "copy": lambda: copy_f(lb, ub),
            "reduce": lambda: reduce_f(*gathered[:4]),
            "combine": lambda: combine_f(*partials, gathered[0], gathered[1]),
            "merge": lambda: merge_f(*cands, gathered[4], lb, ub),
        },
        repeats=repeats,
        tracer=tracer,
    )


def sweep_slab_widths(n_pad: int) -> "list[int]":
    """The SLAB_NPAD autotune candidates for a padded domain: the balanced
    width at the VMEM cap (the fewest slabs) plus the balanced widths at
    one and two extra slabs -- narrower windows trade accumulator residency
    for more straddling copies, and which side wins is an empirical
    property of the instance family, hence the sweep."""
    base = max(1, -(-n_pad // SLAB_NPAD))
    widths = []
    for ns in (base, base + 1, base + 2):
        w = default_slab_width(n_pad, cap=-(-n_pad // ns))
        if w not in widths:
            widths.append(w)
    return widths


def partitioned_large_row(
    specs=LARGE_SPECS,
    n: int = LARGE_N,
    tile: dict = LARGE_TILE,
    widths=None,
    repeats: int = 3,
):
    """The ``partitioned`` engine row: SLAB_NPAD-swept round time, per-phase
    breakdown and measured bytes/round of the column-slab engine on banded
    instances, with the segment engine measured on the SAME instances for
    the comparison the partitioned engine exists to win (jnp-oracle
    arithmetic timings, like the other engine rows; bytes from
    ``round_cost_analysis``).  Population facts live under the nested
    ``population`` key so the row's top level is measurements only
    (see ``PARTITIONED_ROW_KEYS`` and docs/BENCHMARKS.md)."""
    pairs = []
    for spec in specs:
        p = make_banded(n=n, **spec)
        pairs.append((p, prepare_block_ell(p, **tile)))
    n_pad = pairs[0][1].n_pad
    if widths is None:
        widths = sweep_slab_widths(n_pad)

    sweep_raw = {}
    for w in widths:
        us = []
        for _, prep in pairs:
            fn = jax.jit(
                round_fn_for(prep, use_pallas=False, scatter="partitioned", slab=w)
            )
            lb, ub = prep.lb0, prep.ub0
            t = time_fenced(lambda: fn(lb, ub), repeats=repeats)
            us.append(t * 1e6)
        sweep_raw[w] = us
    tuned = min(sweep_raw, key=lambda w: geomean(sweep_raw[w]))

    seg_us, seg_b, part_b = [], [], []
    phase_acc = {k: [] for k in PHASE_NAMES}
    for p, prep in pairs:
        fn = jax.jit(round_fn_for(prep, use_pallas=False, scatter="segment"))
        lb, ub = prep.lb0, prep.ub0
        t = time_fenced(lambda: fn(lb, ub), repeats=repeats)
        seg_us.append(t * 1e6)
        seg_b.append(round_cost_analysis(p, "segment", **tile)["bytes_accessed"])
        part_b.append(
            round_cost_analysis(p, "partitioned", **tile)["bytes_accessed"]
        )
        times = _partitioned_phase_times(
            prep, prep.slab_partition(tuned), repeats=repeats
        )
        for k in PHASE_NAMES:
            phase_acc[k].append(times[k])

    return {
        "population": {
            "set": f"banded n={n}",
            "instances": len(pairs),
            "n_pad_over_budget": bool(n_pad > SCATTER_MAX_NPAD),
        },
        "geomean_round_us": geomean(sweep_raw[tuned]),
        "geomean_bytes_per_round": geomean(part_b),
        "segment_geomean_round_us": geomean(seg_us),
        "segment_geomean_bytes_per_round": geomean(seg_b),
        "round_us_vs_segment": geomean(
            [t / s for t, s in zip(sweep_raw[tuned], seg_us)]
        ),
        "bytes_vs_segment": geomean(
            [pb / sb for pb, sb in zip(part_b, seg_b)]
        ),
        "tuned_slab_npad": int(tuned),
        "slab_sweep_us": {str(w): geomean(us) for w, us in sweep_raw.items()},
        "phases_us": {k: geomean(v) for k, v in phase_acc.items()},
    }


# Every key the ``obs`` observability row must carry (the smoke job and
# docs/OBSERVABILITY.md read this set).
OBS_ROW_KEYS = frozenset({
    "population",
    "telemetry_capacity",
    "overhead_ratio",
    "overhead_bound",
    "bitwise_identical",
    "rounds_recorded",
    "ring_wrapped",
    "span_count",
    "span_schema_ok",
    "metrics_sources",
})

# Acceptance bars for the telemetry-on/off wall-clock ratio.  The full-row
# population amortizes the per-round record ops into the round arithmetic;
# the smoke population is tiny (fixed dispatch costs loom large), so its
# bar is looser.  Both are pinned: a regression that makes telemetry
# expensive fails the bench, not just a dashboard.
OBS_OVERHEAD_BOUND = 1.25
OBS_SMOKE_OVERHEAD_BOUND = 1.5


def obs_row(
    per_family: int = PER_FAMILY,
    capacity: int = 64,
    trials: int = 7,
    repeats: int = 3,
    overhead_bound: float = OBS_OVERHEAD_BOUND,
):
    """The ``obs`` row: what does device telemetry cost, and does the rest
    of the observability plane hold its contracts?

    Three measurements in one row: (1) paired-trials wall-clock ratio of
    full fixed points with the telemetry plane on vs off over the Set-2
    population plus one contraction chain (the 100-round worst case, where
    per-round recording has the most rounds to slow down), asserted under
    the pinned ``overhead_bound`` and required bitwise-identical; (2) a
    traced+telemetered service run whose exported spans are schema-checked
    against ``SPAN_KEYS``; (3) the service's metrics-registry source list,
    so a silently dropped gauge shows up as a row diff."""
    from .precision import _contraction_chain  # lazy: precision imports us

    problems = [p for _, p in instances_for_set(SET, per_family=per_family)]
    problems.append(_contraction_chain(48, rho=0.9))

    def run_off():
        return [propagate_block_ell(p, use_pallas=False) for p in problems]

    def run_on():
        return [
            propagate_block_ell(p, use_pallas=False, telemetry=capacity)
            for p in problems
        ]

    off, on = run_off(), run_on()
    bitwise = all(
        np.array_equal(np.asarray(a.lb), np.asarray(b.lb))
        and np.array_equal(np.asarray(a.ub), np.asarray(b.ub))
        and int(a.rounds) == int(b.rounds)
        for a, b in zip(off, on)
    )
    assert bitwise, "telemetry-on bounds diverged from telemetry-off"
    rounds_recorded = sum(r.telemetry.rounds_recorded for r in on)
    ring_wrapped = sum(r.telemetry.rounds_recorded > capacity for r in on)

    trials_ = paired_trials([run_off, run_on], trials=trials, repeats=repeats)
    ratio = median_ratio(trials_, num=1, den=0)
    assert ratio <= overhead_bound, (
        f"telemetry overhead {ratio:.3f}x exceeds the {overhead_bound}x bar"
    )

    svc_probs = [p for _, p in instances_for_set(SET, per_family=1)]
    specs = BucketSpec.for_problems(svc_probs, slots=2)
    tracer = Tracer()
    svc = PropagationService(
        specs, use_pallas=False, telemetry=capacity, tracer=tracer
    )
    svc_res = svc.serve(svc_probs)
    assert all(r.telemetry is not None for r in svc_res)
    lines = [json.loads(ln) for ln in tracer.export().strip().splitlines()]
    span_schema_ok = bool(lines) and all(set(d) == SPAN_KEYS for d in lines)
    assert span_schema_ok, "exported spans violate the pinned SPAN_KEYS schema"
    assert {"pump", "step", "ticket"} <= {d["name"] for d in lines}

    return {
        "population": {
            "set": SET,
            "instances": len(problems),
            "contraction_chains": 1,
        },
        "telemetry_capacity": capacity,
        "overhead_ratio": float(ratio),
        "overhead_bound": float(overhead_bound),
        "bitwise_identical": bool(bitwise),
        "rounds_recorded": int(rounds_recorded),
        "ring_wrapped": int(ring_wrapped),
        "span_count": len(lines),
        "span_schema_ok": bool(span_schema_ok),
        "metrics_sources": sorted(svc.stats()["metrics"]["sources"]),
    }


def obs_smoke(out_path: str = OUT_PATH):
    """CI schema smoke for ``--smoke --telemetry``: a scaled-down ``obs``
    row from the SAME builder, schema-asserted against ``OBS_ROW_KEYS``
    (with the smoke overhead bar) and merged into a THROWAWAY copy of
    ``BENCH_prop.json``, run-metadata stamp included."""
    row = obs_row(
        per_family=1, capacity=8, trials=2, repeats=1,
        overhead_bound=OBS_SMOKE_OVERHEAD_BOUND,
    )
    missing = OBS_ROW_KEYS - set(row)
    extra = set(row) - OBS_ROW_KEYS
    assert not missing and not extra, (sorted(missing), sorted(extra))
    assert row["bitwise_identical"] is True
    assert row["overhead_ratio"] <= row["overhead_bound"]
    assert row["span_schema_ok"] and row["span_count"] > 0
    # The contraction chain runs to the round cap, so a capacity-8 ring
    # must have wrapped -- truncation semantics exercised, not just spare
    # capacity.
    assert row["ring_wrapped"] >= 1
    assert {"compile_counts", "engine_cache", "kernel_caches", "service"} <= set(
        row["metrics_sources"]
    )
    merged = _merge_report({"obs": row}, out_path)
    assert merged["obs"] == row
    assert set(merged["run_meta"]) == {
        "git_commit", "timestamp", "jax_version", "x64", "backend",
    }
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(merged, f, indent=2)
        tmp = f.name
    try:
        with open(tmp) as f:
            assert json.load(f)["obs"] == row
    finally:
        os.unlink(tmp)
    return [
        ("obs_smoke", 0.0,
         f"schema_ok overhead_ratio={row['overhead_ratio']:.3f} "
         f"(bar<={row['overhead_bound']}) spans={row['span_count']} "
         f"ring_wrapped={row['ring_wrapped']}")
    ]


def obs_run(out_path: str = OUT_PATH):
    """Record the full-fidelity ``obs`` row into ``BENCH_prop.json``."""
    row = obs_row()
    merged = _merge_report({"obs": row}, out_path)
    with open(out_path, "w") as f:
        json.dump(merged, f, indent=2)
    return [
        ("obs_telemetry", 0.0,
         f"overhead_ratio={row['overhead_ratio']:.3f} "
         f"(bar<={row['overhead_bound']}) "
         f"rounds_recorded={row['rounds_recorded']} "
         f"ring_wrapped={row['ring_wrapped']} spans={row['span_count']}")
    ]


def smoke(out_path: str = OUT_PATH):
    """CI schema smoke (``--smoke``): scaled-down partitioned AND service
    rows from the SAME row builders as the full run (small instances, single
    repeat), schema-asserted against ``PARTITIONED_ROW_KEYS`` /
    ``SERVICE_ROW_KEYS`` and merged into a THROWAWAY copy of
    ``BENCH_prop.json`` -- proving the rows the next full run writes merge
    cleanly without touching the committed trajectory."""
    row = partitioned_large_row(
        specs=(dict(m=400, row_nnz=8, band=256, seed=0),),
        n=1500,
        tile=dict(tile_rows=8, tile_width=8),
        widths=[128, 256],
        repeats=1,
    )
    missing = PARTITIONED_ROW_KEYS - set(row)
    extra = set(row) - PARTITIONED_ROW_KEYS
    assert not missing and not extra, (sorted(missing), sorted(extra))
    assert set(row["phases_us"]) == set(PHASE_NAMES)
    assert set(row["population"]) == {"set", "instances", "n_pad_over_budget"}
    assert str(row["tuned_slab_npad"]) in row["slab_sweep_us"]

    svc = service_row(
        per_family=2, slots=2, size_classes=1, trials=1, repeats=1
    )
    missing = SERVICE_ROW_KEYS - set(svc)
    extra = set(svc) - SERVICE_ROW_KEYS
    assert not missing and not extra, (sorted(missing), sorted(extra))
    assert svc["compiles_during_serve"] == 0
    assert svc["latency_ms_p50"] <= svc["latency_ms_p99"]
    assert 0.0 < svc["mean_slot_occupancy"] <= 1.0

    sol = solver_row(ns=(24,), trials=1, repeats=1, assert_target=False)
    missing = SOLVER_ROW_KEYS - set(sol)
    extra = set(sol) - SOLVER_ROW_KEYS
    assert not missing and not extra, (sorted(missing), sorted(extra))
    assert sol["objective_match"]
    assert all(s == "optimal" for s in sol["statuses"])
    assert sol["host_syncs_per_node"] <= sol["python_host_syncs_per_node"]

    sweep = service_sweep_row(
        grid=(
            dict(slots=2, size_classes=1, rounds_per_step=8, tile_width=None),
            dict(slots=2, size_classes=1, rounds_per_step=4, tile_width=None),
        ),
        per_family=2, trials=1, repeats=1, final_trials=1, final_repeats=1,
    )
    missing = SERVICE_SWEEP_ROW_KEYS - set(sweep)
    extra = set(sweep) - SERVICE_SWEEP_ROW_KEYS
    assert not missing and not extra, (sorted(missing), sorted(extra))
    assert len(sweep["grid"]) == 2
    assert set(sweep["tuned"]) == SERVICE_ROW_KEYS | {"config"}
    assert sweep["tuned"]["config"] in [
        {k: pt[k] for k in _SWEEP_CFG_KEYS} for pt in sweep["grid"]
    ]
    assert sweep["target_met"] == (
        sweep["tuned"]["speedup_vs_tuned_sequential"] >= 1.0
    )

    merged = _merge_report(
        {"engines": {
            "partitioned": row, "service": svc, "service_sweep": sweep,
            "solver": sol,
        }}, out_path
    )
    assert merged["engines"]["partitioned"] == row
    assert merged["engines"]["service"] == svc
    assert merged["engines"]["service_sweep"] == sweep
    assert merged["engines"]["solver"] == sol
    if os.path.exists(out_path):
        with open(out_path) as f:
            old = json.load(f)
        lost_engines = set(old.get("engines", {})) - set(merged["engines"])
        lost_keys = set(old) - set(merged)
        assert not lost_engines and not lost_keys, (lost_engines, lost_keys)
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(merged, f, indent=2)
        tmp = f.name
    try:
        with open(tmp) as f:
            back = json.load(f)
        assert back["engines"]["partitioned"] == row
        assert back["engines"]["service"] == svc
    finally:
        os.unlink(tmp)
    return [
        ("bench_prop_smoke", row["geomean_round_us"],
         f"schema_ok tuned_slab_npad={row['tuned_slab_npad']} "
         f"phases={','.join(PHASE_NAMES)} "
         f"service_ips={svc['instances_per_sec']:.1f} "
         f"solver_nps={sol['device_nodes_per_sec']:.0f}")
    ]


def _merge_report(report: dict, out_path: str) -> dict:
    """Merge new engine rows into an existing BENCH_prop.json: engine rows
    are updated/added, any other keys from earlier PRs are preserved.
    Every merge re-stamps ``run_meta`` (git commit, timestamp, jax
    version, x64, backend -- ``obs.metrics.run_metadata``) so the
    trajectory file always attributes its newest rows."""
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                old = json.load(f)
        except (OSError, json.JSONDecodeError):
            old = {}
        engines = dict(old.get("engines", {}))
        engines.update(report.get("engines", {}))
        merged = {**old, **report}
        merged["engines"] = engines
    else:
        merged = dict(report)
    merged["run_meta"] = run_metadata()
    return merged


def run(out_path: str = OUT_PATH):
    insts = instances_for_set(SET, per_family=PER_FAMILY)
    acc = {e: {"round_us": [], "bytes": []} for e in ENGINES}
    for spec, p in insts:
        prep = prepare_block_ell(p)
        for engine in ENGINES:
            if engine == "legacy":
                fn = jax.jit(legacy_round_fn_for(prep, use_pallas=False))
                lb, ub = prep.d.lb0, prep.d.ub0
            else:
                fn = jax.jit(round_fn_for(prep, use_pallas=False, scatter=engine))
                lb, ub = prep.lb0, prep.ub0
            t = time_fenced(lambda: fn(lb, ub))  # warmup compiles off-timer
            acc[engine]["round_us"].append(t * 1e6)
            acc[engine]["bytes"].append(
                round_cost_analysis(p, engine)["bytes_accessed"]
            )

    thru = batched_throughput()
    nodes = node_throughput()
    large = partitioned_large_row()
    svc = service_row()
    sweep = service_sweep_row()
    solver = solver_row()
    report = {
        "set": SET,
        "instances": len(insts),
        # The engine-row population (PR 3 added pseudo_boolean to the
        # default families, growing it 6 -> 8 instances): recorded so the
        # cross-PR trajectory is read against its workload, not assumed
        # constant.
        "families": sorted({spec.family for spec, _ in insts}),
        "engines": {
            e: {
                "geomean_round_us": geomean(v["round_us"]),
                "geomean_bytes_per_round": geomean(v["bytes"]),
            }
            for e, v in acc.items()
        },
    }
    report["engines"]["batched"] = {
        "instances_per_sec": thru["batched_instances_per_sec"],
        "speedup_vs_sequential_dispatch": thru["batched_speedup"],
        "bucket_fill": thru["bucket_fill"],
    }
    report["engines"]["service"] = svc
    report["engines"]["service_sweep"] = sweep
    report["engines"]["nodes"] = {
        "nodes_per_sec": nodes["shared_nodes_per_sec"],
        "speedup_vs_repack_dispatch": nodes["shared_matrix_speedup"],
    }
    report["engines"]["partitioned"] = large
    report["engines"]["solver"] = solver
    report["bytes_reduction_fused_vs_legacy"] = geomean(
        [l / f for l, f in zip(acc["legacy"]["bytes"], acc["fused"]["bytes"])]
    )
    report["batched_throughput"] = thru
    report["node_throughput"] = nodes
    report = _merge_report(report, out_path)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        (
            f"bench_prop_{e}",
            report["engines"][e]["geomean_round_us"],
            f"geomean_bytes_per_round={report['engines'][e]['geomean_bytes_per_round']:.0f}",
        )
        for e in ENGINES
    ]
    rows.append(
        ("bench_prop_batched",
         1e6 / thru["batched_instances_per_sec"],
         f"instances_per_sec={thru['batched_instances_per_sec']:.1f} "
         f"speedup_vs_sequential={thru['batched_speedup']:.2f}x "
         f"buckets={thru['buckets']} instances={thru['instances']} "
         f"bucket_fill={','.join(f'{f:.2f}' for f in thru['bucket_fill'])}")
    )
    rows.append(
        ("bench_prop_service",
         1e6 / svc["instances_per_sec"],
         f"instances_per_sec={svc['instances_per_sec']:.1f} "
         f"speedup_vs_sequential={svc['speedup_vs_sequential_dispatch']:.2f}x "
         f"p50={svc['latency_ms_p50']:.1f}ms p95={svc['latency_ms_p95']:.1f}ms "
         f"p99={svc['latency_ms_p99']:.1f}ms "
         f"occupancy={svc['mean_slot_occupancy']:.2f} "
         f"compiles_during_serve={svc['compiles_during_serve']}")
    )
    tuned_cfg = sweep["tuned"]["config"]
    rows.append(
        ("bench_prop_service_sweep",
         1e6 / sweep["tuned"]["instances_per_sec"],
         f"tuned[slots={tuned_cfg['slots']} "
         f"size_classes={tuned_cfg['size_classes']} "
         f"rounds_per_step={tuned_cfg['rounds_per_step']} "
         f"tile_width={tuned_cfg['tile_width']}] "
         f"speedup_vs_tuned_sequential="
         f"{sweep['tuned']['speedup_vs_tuned_sequential']:.2f}x "
         f"grid_points={len(sweep['grid'])} "
         f"target_met={sweep['target_met']}")
    )
    rows.append(
        ("bench_prop_nodes",
         1e6 / nodes["shared_nodes_per_sec"],
         f"nodes_per_sec={nodes['shared_nodes_per_sec']:.1f} "
         f"speedup_vs_repack={nodes['shared_matrix_speedup']:.2f}x "
         f"nodes={nodes['nodes']}")
    )
    phases = " ".join(
        f"{k}={large['phases_us'][k]:.0f}us" for k in PHASE_NAMES
    )
    rows.append(
        ("bench_prop_partitioned",
         large["geomean_round_us"],
         f"large_set={large['population']['set']} "
         f"tuned_slab_npad={large['tuned_slab_npad']} "
         f"round_us_vs_segment={large['round_us_vs_segment']:.2f}x "
         f"bytes_per_round={large['geomean_bytes_per_round']:.0f} "
         f"segment_bytes={large['segment_geomean_bytes_per_round']:.0f} "
         f"bytes_vs_segment={large['bytes_vs_segment']:.2f}x "
         f"phases[{phases}]")
    )
    rows.append(
        ("bench_prop_solver",
         1e6 / solver["device_nodes_per_sec"],
         f"device_nodes_per_sec={solver['device_nodes_per_sec']:.0f} "
         f"python_nodes_per_sec={solver['python_nodes_per_sec']:.0f} "
         f"speedup_vs_python_driver="
         f"{solver['speedup_vs_python_driver']:.2f}x "
         f"host_syncs_per_node={solver['host_syncs_per_node']:.3f} "
         f"python_syncs_per_node={solver['python_host_syncs_per_node']:.3f} "
         f"target_met={solver['target_met']}")
    )
    rows.append(
        ("bench_prop_json", 0.0,
         f"written={out_path} "
         f"bytes_reduction_fused_vs_legacy={report['bytes_reduction_fused_vs_legacy']:.2f}x")
    )
    return rows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument(
        "--smoke",
        action="store_true",
        help="quick CI schema check: scaled-down partitioned row, merged "
        "into a throwaway copy of BENCH_prop.json (nothing written)",
    )
    parser.add_argument(
        "--telemetry",
        action="store_true",
        help="additionally build the obs row: telemetry on/off overhead "
        "ratio (asserted under its pinned bound), span schema check and "
        "metrics-registry sources (merged like the engine rows; with "
        "--smoke, asserted against a throwaway copy instead)",
    )
    ns = parser.parse_args()
    jax.config.update("jax_enable_x64", True)  # match benchmarks.run
    rows = list(smoke() if ns.smoke else run())
    if ns.telemetry:
        rows += obs_smoke() if ns.smoke else obs_run()
    for r in rows:
        print(",".join(map(str, r)))
