"""Paper Appendix B: effect of constraint/variable ordering on performance
and results -- random row/col permutations vs the original ordering."""
from __future__ import annotations

import numpy as np

from repro.core import bounds_equal, permute_problem, propagate
from repro.data.instances import instances_for_set

from .common import geomean
from .speedup_sets import _timed_parallel


def run(n_seeds: int = 3, max_set: int = 3):
    deltas = []
    limit_same = 0
    total = 0
    for k in range(2, max_set + 1):
        for spec, p in instances_for_set(f"Set-{k}", per_family=1):
            t0 = _timed_parallel(p)
            r0 = propagate(p)
            for seed in range(1, n_seeds + 1):
                rng = np.random.default_rng(seed)
                rp = rng.permutation(p.m)
                cp = rng.permutation(p.n)
                p2 = permute_problem(p, rp, cp)
                t1 = _timed_parallel(p2)
                r1 = propagate(p2)
                total += 1
                limit_same += bounds_equal(
                    np.asarray(r0.lb)[cp], np.asarray(r0.ub)[cp], r1.lb, r1.ub
                )
                deltas.append(t1 / t0)
    return [
        ("ordering_permuted_time_ratio", 0.0,
         f"geomean={geomean(deltas):.3f} max={max(deltas):.2f} "
         "(paper App B: <= ~4.3% effect)"),
        ("ordering_limit_point_invariance", 0.0, f"same={limit_same}/{total}"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
