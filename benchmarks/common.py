"""Shared benchmark utilities: timing, instance sets, geometric means.

Paper methodology (§4.3): one-time init (CSC build, block-ELL conversion,
jit compile == the paper's excluded memory transfer/setup) is NOT timed;
timing covers first propagation round to results available.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np


def geomean(xs) -> float:
    xs = np.asarray([max(x, 1e-12) for x in xs], dtype=np.float64)
    return float(np.exp(np.log(xs).mean()))


def time_fn(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of wall time in seconds (after warmup calls)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_sets(per_family: int = 1, max_set: int = 8):
    """Instances grouped by the paper's size sets (scaled, DESIGN.md §7)."""
    from repro.data.instances import SIZE_SETS, instances_for_set

    out = {}
    for name, _, _ in SIZE_SETS[:max_set]:
        out[name] = instances_for_set(name, per_family=per_family)
    return out


def fmt_rows(rows: List[Tuple[str, float, str]]) -> str:
    return "\n".join(f"{n},{us:.1f},{d}" for n, us, d in rows)
