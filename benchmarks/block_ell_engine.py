"""Beyond-paper table: the Pallas block-ELL engine vs the segment-op engine
(jnp oracle path) on CPU, plus block-ELL padding overhead by tile shape --
the static cost of the CSR-adaptive-style regularization (DESIGN.md §2)."""
from __future__ import annotations

import numpy as np

from repro.core import bounds_equal, csr_to_block_ell, propagate
from repro.data.instances import instances_for_set
from repro.kernels import propagate_block_ell

from .common import geomean, time_fn


def run():
    rows = []
    pad_fracs = {}
    for tr, tw in ((8, 32), (8, 128), (4, 64)):
        fracs = []
        for spec, p in instances_for_set("Set-3", per_family=1):
            b = csr_to_block_ell(p.csr, tile_rows=tr, tile_width=tw)
            fracs.append(b.padding_fraction())
        pad_fracs[(tr, tw)] = float(np.mean(fracs))
        rows.append(
            (f"block_ell_padding_r{tr}_w{tw}", 0.0,
             f"mean_padding_fraction={np.mean(fracs):.3f}")
        )

    agree = 0
    ratios = []
    for spec, p in instances_for_set("Set-2", per_family=1):
        r_seg = propagate(p, driver="device_loop")
        t_seg = time_fn(lambda: propagate(p, driver="device_loop"), repeats=2)
        r_bel = propagate_block_ell(p, tile_rows=8, tile_width=32,
                                    use_pallas=False, driver="device_loop")
        t_bel = time_fn(
            lambda: propagate_block_ell(p, tile_rows=8, tile_width=32,
                                        use_pallas=False, driver="device_loop"),
            repeats=2,
        )
        agree += bounds_equal(r_seg.lb, r_seg.ub, r_bel.lb, r_bel.ub)
        ratios.append(t_seg / t_bel)
    rows.append(
        ("block_ell_vs_segment_engine", 0.0,
         f"agree={agree} geomean_t_seg/t_bel={geomean(ratios):.2f}")
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
