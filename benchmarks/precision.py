"""Paper Figure 2 / §4.5 grown into the two-tier precision row.

Three questions, one schema-pinned ``precision`` row in BENCH_prop.json:

  * **what does the fp32 tier buy** -- fused-round bytes/round and wall
    clock at fp32 vs fp64 on the same instances (value planes halve, the
    compact int16/int8 index streams shrink the rest; the acceptance bar
    is <= 0.6x bytes/round, asserted);
  * **what does it cost** -- the paper's §4.5 correctness accounting of
    fp32-ONLY fixed points against the fp64 limit point
    (same / elsewhere / round-cap, paper: 842/987 same, 118 capped), plus
    the two-tier scheme's accounting (it must land on the fp64 fixed
    point -- that is its contract, see ``tests/test_precision.py``);
  * **what does the progress measure save** -- rounds dropped by the
    device-resident early stop at ``STOP_PROGRESS``, with the worst-case
    relative drift of the early bounds from the exact fixed point.

``run()`` merges the row into ``BENCH_prop.json`` next to the engine rows
(``bench_prop._merge_report`` preserves everything else); ``--smoke`` is
the CI leg: a scaled-down row from the same builder, schema-asserted and
merged into a THROWAWAY copy.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax
import numpy as np

from repro.core import (
    INF,
    Problem,
    TierPolicy,
    bounds_equal,
    csr_from_dense,
    propagate,
)
from repro.data.instances import instances_for_set
from repro.kernels import prepare_block_ell, round_cost_analysis, round_fn_for
from repro.obs.timing import median_ratio, paired_trials

from .bench_prop import OUT_PATH, SET, _merge_report
from .common import geomean

PER_FAMILY = 2
STOP_PROGRESS = 1e-3   # early-stop threshold the row is recorded at
PATIENCE = 2
BYTES_RATIO_MAX = 0.6  # acceptance bar: fp32 fused bytes/round vs fp64

PRECISION_ROW_KEYS = frozenset({
    "population",                    # {"set", "instances", "families"}
    "fp32_geomean_bytes_per_round",  # fused engine, fp32 tier
    "fp64_geomean_bytes_per_round",
    "fp32_bytes_per_round_ratio",    # geomean per-instance ratio (<= 0.6)
    "fp32_round_us_ratio",           # paired fused-round wall clock ratio
    "same_limit_point",              # fp32-ONLY vs fp64 (paper §4.5)
    "two_tier",                      # tiered runs vs fp64-only
    "early_stop",                    # progress-based early stop accounting
})


def _contraction_chain(n: int = 32, rho: float = 0.9) -> Problem:
    """Cyclic contraction ``x_j <= rho * x_{j+1}``, ``x in [0, 1]``: every
    round shrinks every upper bound by ``rho`` toward the limit point 0,
    an epsilon tail that grinds to the round cap at ever-smaller progress.
    This is the workload the progress-based early stop exists for
    (Sofranac et al., arXiv:2106.07573) -- the crisp synthetic families
    converge in <= 5 rounds with O(1) per-round progress, leaving the
    early stop nothing to save."""
    dense = np.zeros((n, n))
    for j in range(n):
        dense[j, j] = 1.0
        dense[j, (j + 1) % n] = -rho
    return Problem(
        csr=csr_from_dense(dense),
        lhs=np.full(n, -INF),
        rhs=np.zeros(n),
        lb=np.zeros(n),
        ub=np.ones(n),
        is_int=np.zeros(n, dtype=bool),
    )


def _max_rel_drift(lb_a, ub_a, lb_b, ub_b) -> float:
    """Worst relative deviation between two bound sets, infinities
    (either sentinel representation) counted as agreeing."""
    out = 0.0
    for a, b in ((lb_a, lb_b), (ub_a, ub_b)):
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        fin = (np.abs(a) < INF / 2) & (np.abs(b) < INF / 2)
        if np.any(fin):
            d = np.abs(a[fin] - b[fin]) / (1.0 + np.abs(b[fin]))
            out = max(out, float(np.max(d)))
    return out


def precision_row(
    set_name: str = SET,
    per_family: int = PER_FAMILY,
    trials: int = 5,
    repeats: int = 3,
) -> dict:
    """Build the ``precision`` row (see PRECISION_ROW_KEYS)."""
    insts = instances_for_set(set_name, per_family=per_family)

    bytes32, bytes64, us_ratios = [], [], []
    same = diff = capped = infeas_agree = 0
    tt_feasible = tt_same = 0
    tier_shares = []
    rounds_full = rounds_stopped = stopped_early = 0
    drift = 0.0

    for spec, p in insts:
        b32 = round_cost_analysis(p, "fused", dtype=np.float32)["bytes_accessed"]
        b64 = round_cost_analysis(p, "fused", dtype=np.float64)["bytes_accessed"]
        bytes32.append(b32)
        bytes64.append(b64)

        # Paired fused-round timing at both dtypes
        # (``obs.timing.paired_trials``: fp32/fp64 interleave within each
        # trial, the median per-trial ratio is robust to background-load
        # drift; the shared warmup fences the compiles off-timer).
        variants = []
        for dt in (np.float32, np.float64):
            prep = prepare_block_ell(p, dtype=dt)
            fn = jax.jit(round_fn_for(prep, use_pallas=False, scatter="fused"))
            variants.append(
                lambda fn=fn, lb0=prep.lb0, ub0=prep.ub0: fn(lb0, ub0)
            )
        pair = paired_trials(variants, trials=trials, repeats=repeats)
        us_ratios.append(median_ratio(pair, num=0, den=1))

        # Paper §4.5: where does the fp32-ONLY fixed point land relative
        # to the fp64 one?
        r64 = propagate(p)
        r32 = propagate(p, dtype=np.float32)
        if bool(r64.infeasible):
            if bool(r32.infeasible):
                infeas_agree += 1
            else:
                diff += 1
        elif not bool(r32.converged):
            capped += 1
        elif bool(bounds_equal(r32.lb, r32.ub, r64.lb, r64.ub)):
            same += 1
        else:
            diff += 1

        # The two-tier scheme's accounting (its contract is SAME limit
        # point -- tests/test_precision.py asserts the tight bands; the
        # row records the paper-criterion rate and the fp32 share).
        rt = propagate(p, policy=TierPolicy())
        if not bool(r64.infeasible) and not bool(rt.infeasible):
            tt_feasible += 1
            if bool(bounds_equal(rt.lb, rt.ub, r64.lb, r64.ub)):
                tt_same += 1
            tier_shares.append(
                max(int(rt.tier_rounds), 1) / max(int(rt.rounds), 1)
            )

    # Progress-based early stop.  The Set families converge crisply
    # (<= 5 rounds, O(1) per-round progress until the zero-change round),
    # leaving the early stop nothing to save -- so the accounting
    # population adds two contraction chains with geometric epsilon tails
    # (the workload the measure exists for; see _contraction_chain).
    es_pop = [p for _, p in insts] + [
        _contraction_chain(32, rho=0.8),
        _contraction_chain(48, rho=0.85),
    ]
    for p in es_pop:
        r = propagate(p)
        if bool(r.infeasible):
            continue
        rs = propagate(
            p,
            policy=TierPolicy(
                two_tier=False, stop_progress=STOP_PROGRESS,
                patience=PATIENCE,
            ),
        )
        rounds_full += int(r.rounds)
        rounds_stopped += int(rs.rounds)
        if int(rs.rounds) < int(r.rounds):
            stopped_early += 1
        drift = max(drift, _max_rel_drift(rs.lb, rs.ub, r.lb, r.ub))

    ratio = geomean([a / b for a, b in zip(bytes32, bytes64)])
    assert ratio <= BYTES_RATIO_MAX, (
        f"fp32 fused bytes/round ratio {ratio:.3f} exceeds the "
        f"{BYTES_RATIO_MAX} acceptance bar (compact index streams missing?)"
    )
    return {
        "population": {
            "set": set_name,
            "instances": len(insts),
            "families": sorted({spec.family for spec, _ in insts}),
        },
        "fp32_geomean_bytes_per_round": geomean(bytes32),
        "fp64_geomean_bytes_per_round": geomean(bytes64),
        "fp32_bytes_per_round_ratio": ratio,
        "fp32_round_us_ratio": geomean(us_ratios),
        "same_limit_point": {
            "same": same,
            "diff": diff,
            "round_cap": capped,
            "infeasible_agree": infeas_agree,
            "paper": "842/987 same; 118 capped (fp32-only, Fig. 2)",
        },
        "two_tier": {
            "feasible": tt_feasible,
            "same_limit_point": tt_same,
            "fp32_round_share_geomean": geomean(tier_shares)
            if tier_shares else 0.0,
        },
        "early_stop": {
            "stop_progress": STOP_PROGRESS,
            "patience": PATIENCE,
            "instances": len(es_pop),
            "contraction_chains": 2,
            "rounds_full": rounds_full,
            "rounds_stopped": rounds_stopped,
            "rounds_saved_frac": (rounds_full - rounds_stopped)
            / max(rounds_full, 1),
            "instances_stopped_early": stopped_early,
            "max_rel_drift": drift,
        },
    }


def smoke(out_path: str = OUT_PATH):
    """CI schema smoke (``--smoke``): a scaled-down row from the SAME
    builder, schema-asserted against ``PRECISION_ROW_KEYS`` and merged
    into a THROWAWAY copy of ``BENCH_prop.json``."""
    row = precision_row(set_name="Set-1", per_family=1, trials=1, repeats=1)
    missing = PRECISION_ROW_KEYS - set(row)
    extra = set(row) - PRECISION_ROW_KEYS
    assert not missing and not extra, (sorted(missing), sorted(extra))
    assert row["fp32_bytes_per_round_ratio"] <= BYTES_RATIO_MAX
    acc = row["same_limit_point"]
    assert (
        acc["same"] + acc["diff"] + acc["round_cap"] + acc["infeasible_agree"]
        == row["population"]["instances"]
    )
    # The two-tier contract at the paper criterion: every feasible tiered
    # run lands on the fp64 limit point.
    assert row["two_tier"]["same_limit_point"] == row["two_tier"]["feasible"]
    # The contraction chains guarantee the early stop has a tail to cut.
    assert 0.0 < row["early_stop"]["rounds_saved_frac"] <= 1.0
    assert row["early_stop"]["instances_stopped_early"] >= 1

    merged = _merge_report({"precision": row}, out_path)
    assert merged["precision"] == row
    if os.path.exists(out_path):
        with open(out_path) as f:
            old = json.load(f)
        lost = set(old) - set(merged)
        assert not lost, lost
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(merged, f, indent=2)
        tmp = f.name
    try:
        with open(tmp) as f:
            assert json.load(f)["precision"] == row
    finally:
        os.unlink(tmp)
    return [
        ("precision_smoke", 0.0,
         f"schema_ok bytes_ratio={row['fp32_bytes_per_round_ratio']:.3f} "
         f"two_tier_same={row['two_tier']['same_limit_point']}"
         f"/{row['two_tier']['feasible']}")
    ]


def run(out_path: str = OUT_PATH):
    row = precision_row()
    merged = _merge_report({"precision": row}, out_path)
    with open(out_path, "w") as f:
        json.dump(merged, f, indent=2)
    acc = row["same_limit_point"]
    es = row["early_stop"]
    return [
        ("precision_fp32_bytes_per_round", 0.0,
         f"ratio={row['fp32_bytes_per_round_ratio']:.3f} "
         f"(bar<={BYTES_RATIO_MAX}) round_us_ratio="
         f"{row['fp32_round_us_ratio']:.2f}"),
        ("precision_fp32_same_limit", 0.0,
         f"same={acc['same']} diff={acc['diff']} round_cap={acc['round_cap']} "
         f"infeas_agree={acc['infeasible_agree']} "
         f"(paper: 842/987 same; 118 capped)"),
        ("precision_two_tier", 0.0,
         f"same_limit={row['two_tier']['same_limit_point']}"
         f"/{row['two_tier']['feasible']} fp32_share="
         f"{row['two_tier']['fp32_round_share_geomean']:.2f}"),
        ("precision_early_stop", 0.0,
         f"rounds {es['rounds_full']}->{es['rounds_stopped']} "
         f"saved_frac={es['rounds_saved_frac']:.2f} "
         f"stopped={es['instances_stopped_early']} "
         f"max_rel_drift={es['max_rel_drift']:.1e}"),
    ]


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)  # match benchmarks.run
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for r in smoke() if args.smoke else run():
        print(",".join(map(str, r)))
