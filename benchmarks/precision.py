"""Paper Figure 2 / §4.5: single- vs double-precision executions --
speed delta and correctness accounting (converged-to-same-limit-point /
converged-elsewhere / hit-round-cap), fp32 vs fp64."""
from __future__ import annotations

import numpy as np

from repro.core import bounds_equal, propagate, propagate_sequential
from repro.data.instances import instances_for_set

from .common import geomean
from .speedup_sets import _timed_parallel


def run(max_set: int = 4):
    same, diff, capped = 0, 0, 0
    speed_ratio = []
    for k in range(1, max_set + 1):
        for spec, p in instances_for_set(f"Set-{k}", per_family=1):
            ref = propagate_sequential(p)  # fp64 reference
            r32 = propagate(p, dtype=np.float32)
            if not bool(r32.converged):
                capped += 1
            elif bounds_equal(ref.lb, ref.ub, r32.lb, r32.ub):
                same += 1
            else:
                diff += 1
            t64 = _timed_parallel(p)
            dp32 = p.astype(np.float32)
            t32 = _timed_parallel(dp32)
            speed_ratio.append(t64 / t32)
    n = same + diff + capped
    return [
        ("precision_fp32_same_limit", 0.0,
         f"same={same}/{n} diff={diff} round_cap={capped} "
         f"(paper: 842/987 same; 118 capped)"),
        ("precision_fp32_speedup_vs_fp64", 0.0,
         f"geomean_t64/t32={geomean(speed_ratio):.2f} "
         f"(paper V100: ~1.0; sparse-int-heavy)"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
