"""Paper Figure 3 / §4.6: baseline validation.

The paper validates its cpu_seq/cpu_omp baselines against PaPILO.  PaPILO is
unavailable offline, so the counterpart here validates our cpu_seq (marking)
against an INDEPENDENT sequential implementation (marking disabled -- a
different traversal discipline exercising the same math) and against the JAX
single-device engine, on both results (bound equality) and runtime order of
magnitude."""
from __future__ import annotations


from repro.core import bounds_equal, propagate, propagate_sequential
from repro.data.instances import instances_for_set

from .common import geomean, time_fn


def run(max_set: int = 4):
    agree_marking = 0
    agree_jax = 0
    total = 0
    speed_marking = []
    for k in range(1, max_set + 1):
        for spec, p in instances_for_set(f"Set-{k}", per_family=1):
            a = propagate_sequential(p, use_marking=True)
            b = propagate_sequential(p, use_marking=False)
            c = propagate(p)
            total += 1
            agree_marking += bounds_equal(a.lb, a.ub, b.lb, b.ub)
            agree_jax += bounds_equal(a.lb, a.ub, c.lb, c.ub)
            t_mark = time_fn(lambda: propagate_sequential(p, use_marking=True),
                             repeats=1, warmup=0)
            t_nomark = time_fn(lambda: propagate_sequential(p, use_marking=False),
                               repeats=1, warmup=0)
            speed_marking.append(t_nomark / t_mark)
    return [
        ("baseline_marking_vs_nomarking_agreement", 0.0,
         f"agree={agree_marking}/{total}"),
        ("baseline_seq_vs_jax_agreement", 0.0, f"agree={agree_jax}/{total}"),
        ("baseline_marking_speedup", 0.0,
         f"geomean_t_nomark/t_mark={geomean(speed_marking):.2f} "
         "(marking mechanism pays off sequentially, paper §2.1)"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
