"""Paper Appendix C / §3.7: loop-driver variants.

  host_loop   == paper cpu_loop    (host checks the converged flag per round)
  device_loop == paper gpu_loop    (whole fixed point one device dispatch)
  unrolled(4) == megakernel-esque  (4 fused rounds per convergence check)

Paper finding: cpu_loop fastest overall, gpu_loop converging to it with
instance size (Amdahl), megakernel worst.  On XLA:CPU the host/device sync
cost differs from CUDA, so the ordering itself is environment-specific; the
benchmark reports the measured ratios.
"""
from __future__ import annotations

from repro.data.instances import instances_for_set

from .common import geomean, time_fn


def _timed(p, driver, unroll=1):
    import jax

    from repro.core.propagator import DeviceProblem, _round_fn, _device_fixed_point
    from repro.core.types import DEFAULT_CONFIG as cfg
    
    dp = DeviceProblem(p)
    round_fn = _round_fn(dp, cfg)
    if driver == "host_loop":
        jit_round = jax.jit(lambda lb, ub: round_fn(lb=lb, ub=ub))
        jit_round(dp.lb0, dp.ub0)[0].block_until_ready()

        def call():
            lb, ub = dp.lb0, dp.ub0
            changed, rounds = True, 0
            while changed and rounds < cfg.max_rounds:
                lb, ub, ch = jit_round(lb, ub)
                changed = bool(ch)  # per-round host sync
                rounds += 1

        return time_fn(call, repeats=3)

    @jax.jit
    def run(lb0, ub0):
        lb, ub, ch, r, _prog = _device_fixed_point(
            round_fn, lb0, ub0, cfg.max_rounds, unroll
        )
        return lb, ub, r

    run(dp.lb0, dp.ub0)[0].block_until_ready()
    return time_fn(lambda: run(dp.lb0, dp.ub0)[0].block_until_ready(), repeats=3)


def run(max_set: int = 5):
    rows = []
    for k in (1, 3, max_set):
        ratios_g, ratios_m = [], []
        for spec, p in instances_for_set(f"Set-{k}", per_family=1):
            t_host = _timed(p, "host_loop")
            t_dev = _timed(p, "device_loop")
            t_unr = _timed(p, "device_loop", unroll=4)
            ratios_g.append(t_dev / t_host)
            ratios_m.append(t_unr / t_host)
        rows.append(
            (f"loop_variants_Set-{k}", 0.0,
             f"device/host={geomean(ratios_g):.2f} unrolled4/host={geomean(ratios_m):.2f}")
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
