"""Serving example: prefill + batched greedy decoding with a sharded-layout
KV cache (rolling-window for the hybrid arch), across three cache families.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.train.serve_step import generate

for arch in ("qwen2-0.5b", "recurrentgemma-9b", "mamba2-780m"):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s_prompt, steps, s_max = 4, 16, 24, 64
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, s_prompt), 0,
                                cfg.vocab_size)
    fe = None
    if cfg.frontend != "none":
        fe = jnp.zeros((b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    t0 = time.perf_counter()
    out = generate(params, cfg, prompt, steps=steps, s_max=s_max,
                   frontend_embeds=fe)
    dt = time.perf_counter() - t0
    print(f"{arch:20s} ({cfg.family:6s}): generated {out.shape} tokens in "
          f"{dt:.2f}s -- sample: {np.asarray(out[0, :10]).tolist()}")
