"""Quickstart: propagate a small MIP with the paper's parallel algorithm.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import INF, Problem, csr_from_dense, propagate, propagate_sequential

# A tiny MIP:  min c^T x  s.t.
#   2x + 3y        <= 6
#    x +  y +  z   >= 1
#        4y -  z   == 2      (as ranged row 2 <= . <= 2)
# x,y integer in [0,10], z continuous in [0,8].
A = np.array(
    [
        [2.0, 3.0, 0.0],
        [1.0, 1.0, 1.0],
        [0.0, 4.0, -1.0],
    ]
)
problem = Problem(
    csr=csr_from_dense(A),
    lhs=np.array([-INF, 1.0, 2.0]),
    rhs=np.array([6.0, INF, 2.0]),
    lb=np.zeros(3),
    ub=np.array([10.0, 10.0, 8.0]),
    is_int=np.array([True, True, False]),
)

print("initial domains:")
for j, (l, u) in enumerate(zip(problem.lb, problem.ub)):
    print(f"  x{j} in [{l:g}, {u:g}]")

# GPU-parallel algorithm (Alg. 2), whole fixed point in ONE device dispatch.
result = propagate(problem, driver="device_loop")
print(f"\nparallel propagation: {int(result.rounds)} rounds, "
      f"converged={bool(result.converged)}, infeasible={bool(result.infeasible)}")
for j, (l, u) in enumerate(zip(np.asarray(result.lb), np.asarray(result.ub))):
    print(f"  x{j} in [{l:g}, {u:g}]")

# Sequential reference (Alg. 1, with constraint marking).
seq = propagate_sequential(problem)
print(f"\nsequential reference: {seq.rounds} rounds -- bounds match: "
      f"{np.allclose(seq.lb, np.asarray(result.lb)) and np.allclose(seq.ub, np.asarray(result.ub))}")

# --- Warm start: the tree-search pattern -------------------------------------
# A branch-and-bound node differs from its parent by ONE branching bound.
# Bounds are RUNTIME arguments of every driver, so a node propagates through
# the SAME resident engine -- nothing is repacked or recompiled.  Here: the
# kernel-backed engine, first the root, then a child with y fixed to 0.
from repro.kernels import cache_info, propagate_block_ell

root = propagate_block_ell(problem)          # prepares + compiles once
child_lb = np.asarray(root.lb).copy()
child_ub = np.asarray(root.ub).copy()
child_ub[1] = 1.0                            # branch down: y <= 1
child = propagate_block_ell(problem, lb0=child_lb, ub0=child_ub)
print(f"\nwarm-started child (y <= 1): infeasible={bool(child.infeasible)}, "
      f"rounds={int(child.rounds)}")
for j, (l, u) in enumerate(zip(np.asarray(child.lb), np.asarray(child.ub))):
    print(f"  x{j} in [{l:g}, {u:g}]")

# The engine caches did the heavy lifting exactly once: the second call hits
# both the prepared-instance LRU and the compiled-runner LRU.
info = cache_info()
print("\ncache_info():")
for name in ("prepare_block_ell", "block_ell_runner"):
    c = info[name]
    print(f"  {name}: hits={c['hits']} misses={c['misses']} size={c['size']}")
