"""Batched diving example: tree-search propagation over a SHARED matrix.

A branch-and-bound dive repeatedly branches an integer variable, propagates
the child's domain, and prunes infeasible children.  The node engine serves
this shape directly: the instance's block-ELL tiles and the compiled fixed
point are prepared ONCE (keyed on matrix structure), every frontier level
is one ``propagate_nodes`` dispatch over ``(B, n)`` bound planes, and the
per-node ``infeasible`` flags drive on-device pruning.

The same frontier is then re-propagated the repack way -- each node treated
as a brand-new instance (fresh packing + device transfer + dispatch) -- to
show what warm-start bounds threading saves.

  PYTHONPATH=src python examples/bnb_dive.py
"""
import time

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import NodeBatch, branch_children, propagate, propagate_node_batch
from repro.data import make_pseudo_boolean

MAX_WIDTH = 64   # frontier cap per level
DEPTH = 16       # dive levels (deep enough that some branches conflict)
# Pallas kernels on TPU; the jnp engine elsewhere (interpret mode measures
# the emulator, not the algorithm -- same policy as benchmarks/bench_prop).
USE_PALLAS = jax.default_backend() == "tpu"
# Pseudo-boolean rows carry <= 8 nonzeros; tiling at the row width keeps the
# block-ELL padding (and with it every per-round sweep) proportional to nnz.
TILE = dict(tile_rows=8, tile_width=8)

# Clause-heavy and over-constrained (no helper unit clauses): deep dives
# accumulate enough fixings that some children become infeasible.
root = make_pseudo_boolean(n=60, m=120, seed=0, unit_frac=0.0)
print(f"root: m={root.m} n={root.n} nnz={root.nnz} (pseudo-boolean, all binary)")

r0 = propagate(root)
assert not bool(r0.infeasible)
print(f"root propagation: {int(r0.rounds)} rounds\n")


def pick_branch_var(lb, ub, is_int, rng):
    """A random unfixed integer variable (diving heuristics go here)."""
    free = np.flatnonzero(is_int & (lb < ub))
    return int(rng.choice(free)) if free.size else None


def dive(problem, lb0, ub0):
    """Run the dive; returns (nodes propagated, pruned count, wall seconds).

    Level k: branch every frontier node (down + up child), propagate the
    whole child batch in one dispatch, keep the feasible children."""
    rng = np.random.default_rng(0)
    frontier = NodeBatch(problem, lb0[None, :], ub0[None, :])
    total, pruned = 0, 0
    t0 = time.perf_counter()
    for level in range(DEPTH):
        children = []
        for i in range(frontier.size):
            lb, ub = frontier.lb[i], frontier.ub[i]
            var = pick_branch_var(lb, ub, problem.is_int, rng)
            if var is None:
                continue
            down, up = branch_children(lb, ub, var, lb[var])
            children += [down, up]
        if not children:
            break
        batch = NodeBatch.from_nodes(problem, children[:MAX_WIDTH])
        res = propagate_node_batch(batch, use_pallas=USE_PALLAS, **TILE)
        keep = ~np.asarray(res.infeasible)
        total += batch.size
        pruned += int((~keep).sum())
        frontier = NodeBatch(problem, np.asarray(res.lb)[keep], np.asarray(res.ub)[keep])
        print(
            f"  level {level}: {batch.size:3d} nodes, "
            f"{int((~keep).sum())} pruned, frontier {frontier.size}"
        )
        if frontier.size == 0:
            break
    return total, pruned, time.perf_counter() - t0


# Warm-up: prepare the matrix + compile one fixed point per frontier width
# (the one-time cost a search pays at its first dive, excluded like the
# paper's init phase).
dive(root, np.asarray(r0.lb), np.asarray(r0.ub))

print("shared-matrix dive (warm):")
total, pruned, dt = dive(root, np.asarray(r0.lb), np.asarray(r0.ub))
print(
    f"  {total} nodes in {dt * 1e3:.1f} ms -> {total / dt:.0f} nodes/sec "
    f"({pruned} pruned on-device)\n"
)

# The repack baseline: every node is treated as a brand-new instance -- the
# host re-expands the CSR structure and re-uploads the whole matrix before
# its one per-node dispatch (``core.fresh_instance_runner``; shapes are
# stable, so XLA compiles once and the comparison isolates the per-node
# repack + transfer + dispatch cost the shared-matrix engine avoids).
from repro.core import fresh_instance_runner  # noqa: E402

rng = np.random.default_rng(0)
sample = []
lb, ub = np.asarray(r0.lb), np.asarray(r0.ub)
for _ in range(16):
    var = pick_branch_var(lb, ub, root.is_int, rng)
    (dlb, dub), _ = branch_children(lb, ub, var, lb[var])
    sample.append((dlb, dub))

propagate_fresh = fresh_instance_runner(root)
propagate_fresh(*sample[0])[0].block_until_ready()  # compile (excluded)
t0 = time.perf_counter()
for dlb, dub in sample:
    out = propagate_fresh(dlb, dub)
out[0].block_until_ready()
dt_repack = time.perf_counter() - t0

batch = NodeBatch.from_nodes(root, sample)
propagate_node_batch(batch, use_pallas=USE_PALLAS, **TILE)  # warm the runner
t0 = time.perf_counter()
res = propagate_node_batch(batch, use_pallas=USE_PALLAS, **TILE)
np.asarray(res.lb)
dt_shared = time.perf_counter() - t0

print("repack-per-node baseline (same 16 nodes):")
print(f"  repack: {len(sample) / dt_repack:8.0f} nodes/sec")
print(f"  shared: {len(sample) / dt_shared:8.0f} nodes/sec "
      f"({dt_repack / dt_shared:.1f}x)")
