"""Device-resident branch-and-bound vs a level-by-level Python driver.

``core.solver.solve`` keeps the WHOLE search on device: node pool,
branching-variable selection, incumbent and pruning all live in a
``lax.while_loop`` carry, and the host is consulted only every
``sync_every`` levels.  This example solves one pseudo-boolean instance
twice:

  1. with ``solve()`` -- one compiled search, ``ceil(levels/sync_every)``
     host syncs, per-level telemetry read back at the end;
  2. with the pre-solver shape this example used to demonstrate -- a Python
     loop that propagates each frontier level in one ``propagate_nodes``
     dispatch but does ALL search bookkeeping (branching, incumbent,
     pruning) in host numpy, syncing every level.

Branching is deterministic in both drivers (``pick_most_fractional``, ties
to the lowest column index -- the RNG pick the old example used made runs
non-reproducible), so both searches find the same optimum and the
comparison isolates the cost of hosting the search loop.

  PYTHONPATH=src python examples/bnb_dive.py
"""
import time

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import (
    INF,
    branch_children,
    pick_most_fractional,
    propagate_nodes,
    solve,
)
from repro.data import make_pseudo_boolean

NODE_CAP = 512
MAX_LEVELS = 64
SYNC_EVERY = 8
# Pallas kernels on TPU; the jnp engine elsewhere (interpret mode measures
# the emulator, not the algorithm -- same policy as benchmarks/bench_prop).
USE_PALLAS = jax.default_backend() == "tpu"
# Pseudo-boolean rows carry <= 8 nonzeros; tiling at the row width keeps the
# block-ELL padding (and with it every per-round sweep) proportional to nnz.
TILE = dict(tile_rows=8, tile_width=8)

# Default clause mix: unit clauses give propagation traction, so leaves
# seed the incumbent early and bound pruning keeps the pool small.  (For
# clause-heavy instances with no traction, pass ``expand_width`` to solve()
# -- the deepest-first DFS beam -- instead of a larger ``node_cap``.)
root = make_pseudo_boolean(n=48, m=96, seed=0)
sign = np.where(np.arange(root.n) % 3 == 0, -1.0, 1.0)
c = np.arange(1, root.n + 1, dtype=np.float64) * sign
print(f"root: m={root.m} n={root.n} nnz={root.nnz} (pseudo-boolean, all binary)")


# --- 1. device-resident search ---------------------------------------------

kw = dict(
    node_cap=NODE_CAP, max_levels=MAX_LEVELS, sync_every=SYNC_EVERY,
    use_pallas=USE_PALLAS, telemetry=MAX_LEVELS, **TILE,
)
solve(root, c, **kw)  # warm-up: prepare tiles + compile the search runner
t0 = time.perf_counter()
res = solve(root, c, **kw)
dt_dev = time.perf_counter() - t0

tel = res.telemetry.summary()
print("\ndevice-resident solve():")
print(f"  status={res.status} objective={res.objective}")
print(
    f"  {res.nodes_expanded} expanded / {res.nodes_created} created "
    f"({res.leaves} leaves, {res.pruned_bound} bound-pruned, "
    f"{res.pruned_infeasible} infeasible)"
)
print(
    f"  {res.levels} levels, {res.host_syncs} host syncs "
    f"(sync_every={SYNC_EVERY}), incumbent trajectory "
    f"{res.incumbent_trajectory}"
)
print(
    f"  telemetry: first incumbent at level {tel['stop_round']}, "
    f"first fathom at level {tel['infeasible_round']}"
)
print(
    f"  {res.nodes_created} nodes in {dt_dev * 1e3:.1f} ms -> "
    f"{res.nodes_created / dt_dev:.0f} nodes/sec"
)


# --- 2. level-by-level Python driver ----------------------------------------

def python_bnb(p, c):
    """The hosted search: device propagation per level, numpy bookkeeping.

    Same branching rule, branch point and pruning test as ``solve()``, so
    it visits an equivalent tree -- but the frontier, incumbent and slot
    logic live on the host, one sync (plus numpy passes) per level."""
    frontier = [(np.asarray(p.lb, np.float64), np.asarray(p.ub, np.float64))]
    inc, inc_x = INF, None
    created, levels, syncs = 1, 0, 0
    while frontier and levels < MAX_LEVELS:
        levels += 1
        lbs = np.stack([n[0] for n in frontier])
        ubs = np.stack([n[1] for n in frontier])
        out = propagate_nodes(p, lbs, ubs, use_pallas=USE_PALLAS, **TILE)
        lbs, ubs = np.asarray(out.lb), np.asarray(out.ub)
        infeas = np.asarray(out.infeasible)
        syncs += 1  # readback before ANY host-side search decision
        nxt = []
        for i in range(lbs.shape[0]):
            if infeas[i]:
                continue
            lb, ub = lbs[i], ubs[i]
            obj = float(np.sum(np.where(c > 0, c * lb, c * ub)))
            if obj >= inc:
                continue
            var = pick_most_fractional(lb, ub, p.is_int)
            if var is None:
                inc, inc_x = obj, lb.copy()
                continue
            bv = np.clip(np.floor(0.5 * (lb[var] + ub[var])), lb[var],
                         ub[var] - 1.0)
            down, up = branch_children(lb, ub, var, float(bv))
            nxt += [down, up]
            created += 2
        frontier = nxt[:NODE_CAP]
    return inc, inc_x, created, levels, syncs


python_bnb(root, c)  # warm-up: same compile exclusion as solve()
t0 = time.perf_counter()
inc, inc_x, created, levels, syncs = python_bnb(root, c)
dt_py = time.perf_counter() - t0

print("\nlevel-by-level Python driver (same rule, same branch points):")
print(f"  objective={inc} ({created} nodes, {levels} levels, {syncs} syncs)")
print(
    f"  {created} nodes in {dt_py * 1e3:.1f} ms -> "
    f"{created / dt_py:.0f} nodes/sec"
)

assert inc == res.objective, (inc, res.objective)
ratio = (res.nodes_created / dt_dev) / (created / dt_py)
print(
    f"\nsame optimum, {res.host_syncs} vs {syncs} host syncs -> "
    f"device-resident search is {ratio:.1f}x on nodes/sec here"
)
print(
    "(wide trees saturate both drivers on CPU propagation arithmetic; on "
    "deep narrow dives, where per-level host overhead dominates, the "
    "`solver` row of BENCH_prop.json measures the payoff of hosting the "
    "loop on device)"
)
