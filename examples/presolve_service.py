"""Presolve-service example: batched domain propagation of many MIP
instances with redundancy/infeasibility verdicts -- the "serving" shape of
the paper's technique (a presolver processes streams of subproblems).

  PYTHONPATH=src python examples/presolve_service.py
"""
import time

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import propagate, analyze_constraints
from repro.core.propagator import DeviceProblem
from repro.data import make_bin_packing, make_knapsack, make_mixed, make_set_cover

REQUESTS = [
    ("knapsack", make_knapsack(n=60, m=12, seed=1)),
    ("set_cover", make_set_cover(n=80, m=25, seed=2)),
    ("bin_packing", make_bin_packing(items=20, bins=6, seed=3)),
    ("mixed_1", make_mixed(m=300, n=220, seed=4)),
    ("mixed_2", make_mixed(m=500, n=400, seed=5)),
]

print(f"{'instance':12s} {'m':>6s} {'n':>6s} {'nnz':>8s} {'rounds':>6s} "
      f"{'tightened':>9s} {'redundant':>9s} {'infeas':>6s} {'ms':>8s}")
for name, p in REQUESTS:
    t0 = time.perf_counter()
    r = propagate(p, driver="device_loop")
    dt = (time.perf_counter() - t0) * 1e3

    tightened = int(
        np.sum(np.asarray(r.lb) > p.lb + 1e-9) + np.sum(np.asarray(r.ub) < p.ub - 1e-9)
    )
    dp = DeviceProblem(p)
    verdict = analyze_constraints(
        dp.row_id, dp.val, dp.col, dp.lhs, dp.rhs, r.lb, r.ub, p.m
    )
    print(
        f"{name:12s} {p.m:6d} {p.n:6d} {p.nnz:8d} {int(r.rounds):6d} "
        f"{tightened:9d} {int(np.sum(np.asarray(verdict.redundant))):9d} "
        f"{str(bool(r.infeasible)):>6s} {dt:8.1f}"
    )
