"""Presolve-service example: a CONTINUOUS-BATCHING propagation service
(``repro.core.PropagationService``) serving a Poisson request stream -- the
"serving" shape of the paper's technique (a presolver processes streams of
subproblems arriving at unpredictable times).

Instances stream through per-bucket device-resident super-tiles: each
request is admitted into a free slot via a device-side scatter, converged
instances retire (async readback) while co-resident instances keep
iterating, and freed slots are backfilled from the queue without a single
recompile (all engines are AOT-warmed at construction).  Contrast with the
fixed-batch shape (``propagate_batch``), which must collect the whole batch
before dispatching and holds every result until the slowest instance
converges.

  PYTHONPATH=src python examples/presolve_service.py
"""
import time

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import PropagationService, analyze_constraints
from repro.core.propagator import DeviceProblem
from repro.data import make_bin_packing, make_knapsack, make_mixed, make_set_cover

REQUESTS = [
    ("knapsack_1", make_knapsack(n=60, m=12, seed=1)),
    ("knapsack_2", make_knapsack(n=70, m=14, seed=11)),
    ("set_cover_1", make_set_cover(n=80, m=25, seed=2)),
    ("set_cover_2", make_set_cover(n=90, m=30, seed=12)),
    ("bin_packing", make_bin_packing(items=20, bins=6, seed=3)),
    ("mixed_1", make_mixed(m=300, n=220, seed=4)),
    ("mixed_2", make_mixed(m=500, n=400, seed=5)),
    ("mixed_3", make_mixed(m=300, n=220, seed=6)),
    ("mixed_4", make_mixed(m=500, n=400, seed=7)),
    ("mixed_5", make_mixed(m=320, n=240, seed=8)),
]

MEAN_ARRIVAL_S = 0.003  # Poisson request stream: ~330 requests/sec offered

names = [nm for nm, _ in REQUESTS]
problems = [p for _, p in REQUESTS]

# Size the slot pool from the sample population: one bucket per padded
# column class, split into tile-count quantiles so small instances get
# tight slots.  Construction AOT-compiles every step/admit engine -- the
# serving loop below never compiles.
t0 = time.perf_counter()
svc = PropagationService.from_problems(
    problems, slots=2, size_classes=2, use_pallas=False
)
print(
    f"service up in {time.perf_counter() - t0:.1f}s: "
    + ", ".join(
        f"bucket[n_pad={b['n_pad']} tiles={b['slot_tiles']}x{b['slots']}slots]"
        for b in svc.stats()["buckets"]
    )
)

# Background device loop: pumps admissions/steps/retirements continuously;
# the client thread only submits and waits on tickets.
with svc:
    rng = np.random.default_rng(0)
    tickets = []
    t0 = time.perf_counter()
    for name, p in REQUESTS:
        time.sleep(rng.exponential(MEAN_ARRIVAL_S))
        tickets.append(svc.submit(p))
    results = [tk.result(timeout=60.0) for tk in tickets]
    wall = time.perf_counter() - t0

lat = np.asarray([tk.latency() for tk in tickets]) * 1e3
print(
    f"served {len(tickets)} requests in {wall * 1e3:.1f} ms wall "
    f"({len(tickets) / wall:.0f} instances/sec with Poisson arrivals)\n"
    f"latency p50={np.percentile(lat, 50):.1f}ms "
    f"p95={np.percentile(lat, 95):.1f}ms max={lat.max():.1f}ms"
)
st = svc.stats()
print(
    f"retired={st['retired']} pending={st['pending']} "
    f"mean occupancy={np.mean([b['mean_occupancy'] for b in st['buckets']]):.2f} "
    f"engine cache: {st['engine_cache']}\n"
)

print(f"{'instance':12s} {'m':>6s} {'n':>6s} {'nnz':>8s} {'rounds':>6s} "
      f"{'tightened':>9s} {'redundant':>9s} {'infeas':>6s}")
for name, p, r in zip(names, problems, results):
    tightened = int(
        np.sum(np.asarray(r.lb) > p.lb + 1e-9) + np.sum(np.asarray(r.ub) < p.ub - 1e-9)
    )
    dp = DeviceProblem(p)
    verdict = analyze_constraints(
        dp.row_id, dp.val, dp.col, dp.lhs, dp.rhs, r.lb, r.ub, p.m
    )
    print(
        f"{name:12s} {p.m:6d} {p.n:6d} {p.nnz:8d} {int(r.rounds):6d} "
        f"{tightened:9d} {int(np.sum(np.asarray(verdict.redundant))):9d} "
        f"{str(bool(r.infeasible)):>6s}"
    )
