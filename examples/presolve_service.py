"""Presolve-service example: BATCHED domain propagation of many MIP
instances in a handful of device dispatches -- the "serving" shape of the
paper's technique (a presolver processes streams of subproblems).

The request stream is packed with ``pack_problems`` (instances bucketed by
padded shape, one super-tile per bucket), each bucket's fixed point runs as
ONE dispatch with a per-instance convergence mask, and redundancy /
infeasibility verdicts are layered on top per instance.

  PYTHONPATH=src python examples/presolve_service.py
"""
import time

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import analyze_constraints, batch_stats, pack_problems, propagate_batch
from repro.core.propagator import DeviceProblem
from repro.data import make_bin_packing, make_knapsack, make_mixed, make_set_cover

REQUESTS = [
    ("knapsack_1", make_knapsack(n=60, m=12, seed=1)),
    ("knapsack_2", make_knapsack(n=70, m=14, seed=11)),
    ("set_cover_1", make_set_cover(n=80, m=25, seed=2)),
    ("set_cover_2", make_set_cover(n=90, m=30, seed=12)),
    ("bin_packing", make_bin_packing(items=20, bins=6, seed=3)),
    ("mixed_1", make_mixed(m=300, n=220, seed=4)),
    ("mixed_2", make_mixed(m=500, n=400, seed=5)),
    ("mixed_3", make_mixed(m=300, n=220, seed=6)),
    ("mixed_4", make_mixed(m=500, n=400, seed=7)),
    ("mixed_5", make_mixed(m=320, n=240, seed=8)),
]

names = [nm for nm, _ in REQUESTS]
problems = [p for _, p in REQUESTS]

stats = batch_stats(pack_problems(problems))
print(
    f"packed {stats['instances']} instances into {stats['buckets']} buckets "
    f"{stats['bucket_shapes']} (padding {stats['padding_fraction']:.1%})"
)

# Warm-up: compile one batched fixed point per bucket (excluded from serving
# time, like the paper's init phase).
propagate_batch(problems, driver="device_loop")

t0 = time.perf_counter()
results = propagate_batch(problems, driver="device_loop")
dt = time.perf_counter() - t0
print(
    f"batched propagation: {len(problems)} instances in {dt * 1e3:.1f} ms "
    f"({len(problems) / dt:.0f} instances/sec)\n"
)

print(f"{'instance':12s} {'m':>6s} {'n':>6s} {'nnz':>8s} {'rounds':>6s} "
      f"{'tightened':>9s} {'redundant':>9s} {'infeas':>6s}")
for name, p, r in zip(names, problems, results):
    tightened = int(
        np.sum(np.asarray(r.lb) > p.lb + 1e-9) + np.sum(np.asarray(r.ub) < p.ub - 1e-9)
    )
    dp = DeviceProblem(p)
    verdict = analyze_constraints(
        dp.row_id, dp.val, dp.col, dp.lhs, dp.rhs, r.lb, r.ub, p.m
    )
    print(
        f"{name:12s} {p.m:6d} {p.n:6d} {p.nnz:8d} {int(r.rounds):6d} "
        f"{tightened:9d} {int(np.sum(np.asarray(verdict.redundant))):9d} "
        f"{str(bool(r.infeasible)):>6s}"
    )
