"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on the synthetic token pipeline, with checkpointing.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]

(This drives the same launcher as production: repro.launch.train.)
"""
import sys

sys.argv = [sys.argv[0]]  # launcher parses its own args below

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokens import DataConfig, make_batch
from repro.models.config import ModelConfig
from repro.models.transformer import init_params, param_count
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step

# ~100M-param config of the qwen2 family (structure from the assigned arch).
CFG = ModelConfig(
    name="qwen2-100m",
    family="dense",
    num_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=2,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    dtype="float32",
    attn_chunk=128,
)

STEPS = 200
BATCH, SEQ = 8, 256


def main():
    print(f"model: {CFG.name}, params = {param_count(CFG)/1e6:.1f}M")
    opt_cfg = OptimizerConfig(lr_peak=1e-3, warmup_steps=20, total_steps=STEPS)
    data_cfg = DataConfig(vocab_size=CFG.vocab_size, seq_len=SEQ, global_batch=BATCH)

    params = init_params(CFG, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(CFG, opt_cfg))

    losses = []
    for step in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in make_batch(data_cfg, step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % 20 == 0:
            print(f"step {step+1:4d} loss {np.mean(losses[-20:]):.4f} "
                  f"lr {float(metrics['lr']):.2e}")
    print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"({'improved' if np.mean(losses[-10:]) < losses[0] else 'FAILED'})")


if __name__ == "__main__":
    main()
