"""Distributed propagation demo: row-partitioned fixed point under shard_map
on a multi-device mesh (8 forced host devices), matching the single-device
result bit-for-bit in the bounds.

  PYTHONPATH=src python examples/distributed_propagation.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import bounds_equal, propagate, propagate_sharded
from repro.data import make_mixed

mesh = jax.make_mesh((2, 4), ("data", "model"))
print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
      f"({len(jax.devices())} devices)")

p = make_mixed(m=2000, n=1500, seed=42)
print(f"instance: m={p.m} n={p.n} nnz={p.nnz}")

r1 = propagate(p, driver="device_loop")
r2 = propagate_sharded(p, mesh)

print(f"single-device : rounds={int(r1.rounds)} converged={bool(r1.converged)}")
print(f"sharded (2x4) : rounds={int(r2.rounds)} converged={bool(r2.converged)}")
print("limit points equal:",
      bounds_equal(np.asarray(r1.lb), np.asarray(r1.ub),
                   np.asarray(r2.lb), np.asarray(r2.ub)))
tight = int(np.sum(np.asarray(r2.lb) > p.lb + 1e-9)
            + np.sum(np.asarray(r2.ub) < p.ub - 1e-9))
print(f"bounds tightened: {tight}")
