"""Property-based tests (hypothesis) for system invariants:

  * parallel == sequential limit point (paper's central correctness claim);
  * monotonicity: propagation only tightens domains;
  * idempotence: the fixed point is stable under one more round;
  * row-scaling invariance: scaling a row and its sides by 2^k (exact in fp)
    leaves the limit point unchanged;
  * ordering invariance: row/col permutations permute the limit point
    (App. B semantic counterpart).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    INF,
    Problem,
    bounds_equal,
    csr_from_coo,
    permute_problem,
    propagate,
    propagate_sequential,
)
from repro.data.instances import make_mixed

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def problems(draw):
    m = draw(st.integers(2, 18))
    n = draw(st.integers(2, 14))
    density = draw(st.floats(0.2, 0.7))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    nnz_mask = rng.random((m, n)) < density
    # Ensure at least one nonzero per row.
    for i in range(m):
        if not nnz_mask[i].any():
            nnz_mask[i, rng.integers(0, n)] = True
    rows, cols = np.nonzero(nnz_mask)
    vals = rng.choice([-3.0, -2.0, -1.0, 1.0, 2.0, 3.0], size=rows.size)
    csr = csr_from_coo(rows.astype(np.int32), cols.astype(np.int32), vals, m, n)
    ub = rng.integers(1, 8, size=n).astype(np.float64)
    lb = -rng.integers(0, 3, size=n).astype(np.float64)
    lb[rng.random(n) < 0.15] = -INF
    ub[rng.random(n) < 0.15] = INF
    is_int = rng.random(n) < 0.5
    row_abs = np.zeros(m)
    np.add.at(row_abs, rows, np.abs(vals) * 2.0)
    lhs = np.where(rng.random(m) < 0.4, -INF, -row_abs * rng.uniform(0.1, 0.5, m))
    rhs = np.where(rng.random(m) < 0.2, INF, row_abs * rng.uniform(0.1, 0.5, m))
    swap = lhs > rhs
    lhs[swap], rhs[swap] = rhs[swap], lhs[swap]
    return Problem(csr=csr, lhs=lhs, rhs=rhs, lb=lb, ub=ub, is_int=is_int)


@given(problems())
@settings(**SETTINGS)
def test_parallel_equals_sequential_limit_point(p):
    a = propagate_sequential(p)
    b = propagate(p, driver="device_loop")
    if a.infeasible or bool(b.infeasible):
        return  # infeasibility verdicts may be reached at different rounds
    if not (a.converged and bool(b.converged)):
        return  # round-cap hit: paper excludes these from comparison (§4.1)
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub), (
        np.max(np.abs(a.lb - np.asarray(b.lb))),
        np.max(np.abs(a.ub - np.asarray(b.ub))),
    )


@given(problems())
@settings(**SETTINGS)
def test_monotonicity(p):
    r = propagate(p)
    assert np.all(np.asarray(r.lb) >= p.lb - 1e-12)
    assert np.all(np.asarray(r.ub) <= p.ub + 1e-12)


@given(problems())
@settings(**SETTINGS)
def test_fixed_point_idempotent(p):
    r = propagate(p)
    if bool(r.infeasible) or not bool(r.converged):
        return
    p2 = p._replace(lb=np.asarray(r.lb), ub=np.asarray(r.ub))
    r2 = propagate(p2)
    assert int(r2.rounds) <= 1  # the confirming round finds nothing
    assert bounds_equal(r.lb, r.ub, r2.lb, r2.ub)


@given(problems(), st.integers(-2, 4))
@settings(**SETTINGS)
def test_row_scaling_invariance(p, k):
    scale = float(2.0**k)
    csr2 = p.csr._replace(val=p.csr.val * scale)
    lhs2 = np.where(np.abs(p.lhs) >= INF, p.lhs, p.lhs * scale)
    rhs2 = np.where(np.abs(p.rhs) >= INF, p.rhs, p.rhs * scale)
    p2 = p._replace(csr=csr2, lhs=lhs2, rhs=rhs2)
    a = propagate(p)
    b = propagate(p2)
    if bool(a.infeasible) or bool(b.infeasible):
        return
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_permutation_invariance(seed):
    p = make_mixed(m=40, n=30, seed=seed % 100)
    rng = np.random.default_rng(seed)
    rp = rng.permutation(p.m)
    cp = rng.permutation(p.n)
    p2 = permute_problem(p, rp, cp)
    a = propagate(p)
    b = propagate(p2)
    if bool(a.infeasible) or bool(b.infeasible):
        return
    if not (bool(a.converged) and bool(b.converged)):
        return
    # b's bounds are a's bounds under the column permutation.
    assert bounds_equal(
        np.asarray(a.lb)[cp], np.asarray(a.ub)[cp], b.lb, b.ub
    )
