"""Observability plane: device telemetry, span tracing, metrics registry.

The load-bearing contract is non-interference -- telemetry-on must return
BITWISE-identical bounds with identical compile counts across every engine
(fused, partitioned, batched, nodes, service), because the plane rides the
while_loop carry without touching the bound dataflow.  The rest pins ring
truncation semantics, host/device telemetry agreement, the span schema,
the registry snapshot envelope, and the shared timing utilities.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import INF, Problem, TierPolicy, csr_from_dense, propagate
from repro.core.nodes import propagate_nodes
from repro.core.propagator import propagate_batch
from repro.core.service import BucketSpec, PropagationService
from repro.data import make_knapsack, make_set_cover
from repro.kernels import propagate_block_ell
from repro.obs import (
    SNAPSHOT_KEYS,
    SPAN_KEYS,
    MetricsRegistry,
    NullTracer,
    TelemetryPlane,
    Tracer,
    device_plane,
    host_snapshot,
    median_of,
    median_ratio,
    paired_trials,
    record_round,
    reset_rows,
    run_metadata,
    time_fenced,
    time_phases,
)

CAP = 16


def contraction_chain(n: int = 32, rho: float = 0.9) -> Problem:
    """Cyclic contraction with a geometric epsilon tail: rounds >> CAP, the
    ring-truncation workload (same construction as benchmarks.precision)."""
    dense = np.zeros((n, n))
    for j in range(n):
        dense[j, j] = 1.0
        dense[j, (j + 1) % n] = -rho
    return Problem(
        csr=csr_from_dense(dense),
        lhs=np.full(n, -INF),
        rhs=np.zeros(n),
        lb=np.zeros(n),
        ub=np.ones(n),
        is_int=np.zeros(n, dtype=bool),
    )


def assert_same_bounds(a, b):
    assert np.array_equal(np.asarray(a.lb), np.asarray(b.lb))
    assert np.array_equal(np.asarray(a.ub), np.asarray(b.ub))
    assert int(a.rounds) == int(b.rounds)


# -- bitwise non-interference, engine by engine ---------------------------


def test_fused_bitwise_and_snapshot():
    p = make_set_cover(60, 30, seed=0)
    off = propagate_block_ell(p, use_pallas=False)
    on = propagate_block_ell(p, use_pallas=False, telemetry=CAP)
    assert_same_bounds(off, on)
    assert off.telemetry is None
    t = on.telemetry
    assert t.capacity == CAP
    assert t.rounds_recorded == int(on.rounds)
    hist = t.progress_history()
    assert hist.shape == (min(CAP, t.rounds_recorded),)
    assert not np.any(np.isnan(hist))
    assert t.infeasible_round == -1 and t.stop_round == -1


def test_partitioned_bitwise():
    p = make_knapsack(300, 40, seed=1)
    kw = dict(use_pallas=False, scatter="partitioned", slab=128)
    off = propagate_block_ell(p, **kw)
    on = propagate_block_ell(p, telemetry=CAP, **kw)
    assert_same_bounds(off, on)
    assert on.telemetry.rounds_recorded == int(on.rounds)


def test_batched_bitwise_per_instance_snapshots():
    probs = [make_set_cover(40, 20, seed=s) for s in range(3)] + [
        make_knapsack(40, 10, seed=s) for s in range(3)
    ]
    off = propagate_batch(probs, use_pallas=False)
    on = propagate_batch(probs, use_pallas=False, telemetry=CAP)
    for a, b in zip(off, on):
        assert_same_bounds(a, b)
        # Instances of one bucket share a batched plane; each snapshot
        # views its own row.
        assert b.telemetry.rounds_recorded == int(b.rounds)
        assert len(b.telemetry.progress_history()) == min(CAP, int(b.rounds))


def test_batched_host_loop_bitwise():
    probs = [make_set_cover(40, 20, seed=s) for s in range(3)]
    off = propagate_batch(probs, use_pallas=False, driver="host_loop")
    on = propagate_batch(
        probs, use_pallas=False, driver="host_loop", telemetry=CAP
    )
    for a, b in zip(off, on):
        assert_same_bounds(a, b)
        assert b.telemetry.rounds_recorded == int(b.rounds)


def test_nodes_bitwise():
    p = make_set_cover(40, 20, seed=0)
    lb = np.repeat(np.asarray(p.lb, np.float64)[None, :], 4, axis=0)
    ub = np.repeat(np.asarray(p.ub, np.float64)[None, :], 4, axis=0)
    off = propagate_nodes(p, lb, ub, use_pallas=False)
    on = propagate_nodes(p, lb, ub, use_pallas=False, telemetry=CAP)
    assert np.array_equal(np.asarray(off.lb), np.asarray(on.lb))
    assert np.array_equal(np.asarray(off.ub), np.asarray(on.ub))
    assert off.node_telemetry(0) is None
    for i in range(4):
        snap = on.node_telemetry(i)
        assert snap.rounds_recorded == int(np.asarray(on.rounds)[i])


def test_two_tier_snapshot_chain():
    p = make_knapsack(80, 20, seed=2)
    pol = TierPolicy()
    off = propagate(p, policy=pol)
    on = propagate(p, policy=pol, telemetry=CAP)
    assert_same_bounds(off, on)
    t = on.telemetry
    if int(on.tier_rounds) > 0:  # promotion happened: fp32 tier recorded
        assert t.tier_switch_round == int(on.tier_rounds)
        assert t.fp32 is not None
        assert t.fp32.rounds_recorded == int(on.tier_rounds)


# -- ring truncation + host/device agreement ------------------------------


def test_ring_truncation_keeps_tail():
    p = contraction_chain()
    r = propagate(p, telemetry=8)
    t = r.telemetry
    assert t.rounds_recorded == int(r.rounds) > 8
    hist = t.progress_history()
    assert hist.shape == (8,)
    # The tail of a contraction is monotone decreasing progress.
    assert np.all(np.diff(hist) <= 1e-12)
    # host_loop reproduces the device ring layout exactly.
    rh = propagate(p, driver="host_loop", telemetry=8)
    np.testing.assert_allclose(
        rh.telemetry.progress_history(), hist, rtol=1e-12
    )
    assert rh.telemetry.rounds_recorded == t.rounds_recorded


def test_infeasible_round_latches_first():
    plane = device_plane(4)
    plane = record_round(plane, 0.5, 1, jnp.asarray(False))
    plane = record_round(plane, 0.4, 2, jnp.asarray(True))
    plane = record_round(plane, 0.3, 3, jnp.asarray(True))
    assert int(plane.infeas_round) == 2  # first firing round, never moves
    assert int(plane.ticks) == 3


def test_batched_record_respects_active_mask():
    plane = device_plane(4, batch=2)
    active = jnp.asarray([True, False])
    plane = record_round(
        plane, jnp.asarray([0.5, 0.7]), jnp.asarray([1, 1]),
        jnp.asarray([False, False]), active=active,
    )
    assert plane.ticks.tolist() == [1, 0]
    assert np.isnan(np.asarray(plane.ring)[1]).all()
    plane = reset_rows(plane, jnp.asarray([0]))
    assert plane.ticks.tolist() == [0, 0]
    assert np.isnan(np.asarray(plane.ring)).all()


def test_host_snapshot_matches_device_wrap():
    history = [2.0 ** -i for i in range(11)]
    snap = host_snapshot(history, capacity=4)
    assert snap.rounds_recorded == 11
    np.testing.assert_allclose(snap.progress_history(), history[-4:])


# -- service: bitwise, snapshots, zero extra compiles ---------------------


def test_service_bitwise_compiles_and_snapshots():
    probs = [make_set_cover(40, 20, seed=s) for s in range(4)] + [
        make_knapsack(40, 10, seed=s) for s in range(2)
    ]
    specs = BucketSpec.for_problems(probs, slots=2)
    svc_off = PropagationService(specs, use_pallas=False)
    svc_on = PropagationService(specs, use_pallas=False, telemetry=CAP)
    res_off = svc_off.serve(probs)
    res_on = svc_on.serve(probs)
    for a, b in zip(res_off, res_on):
        assert_same_bounds(a, b)
        assert a.telemetry is None
        # Retired snapshots are host copies: they survive slot recycling.
        assert b.telemetry.rounds_recorded == int(b.rounds)
        assert len(b.telemetry.progress_history()) == min(CAP, int(b.rounds))
    # Telemetry adds NO compiled traces: same engine structure, and a
    # second serve (retire + backfill churn) compiles nothing new.
    counts = svc_on.compile_counts()
    assert counts == svc_off.compile_counts()
    svc_on.serve(probs)
    assert svc_on.compile_counts() == counts


def test_service_latency_split_and_metrics():
    probs = [make_set_cover(40, 20, seed=s) for s in range(3)]
    specs = BucketSpec.for_problems(probs, slots=2)
    svc = PropagationService(specs, use_pallas=False, telemetry=CAP)
    tickets = [svc.submit(p) for p in probs]
    svc.drain()
    for tk in tickets:
        assert tk.queue_latency() >= 0.0
        assert tk.service_latency() >= 0.0
        assert tk.latency() == pytest.approx(
            tk.queue_latency() + tk.service_latency()
        )
    st = svc.stats()
    snap = st["metrics"]
    assert set(snap) == SNAPSHOT_KEYS
    assert snap["errors"] == {}
    assert {"compile_counts", "engine_cache", "kernel_caches", "service"} <= set(
        snap["sources"]
    )
    assert snap["sources"]["service"]["retired"] == len(probs)


def test_service_tracer_spans():
    probs = [make_set_cover(40, 20, seed=s) for s in range(3)]
    specs = BucketSpec.for_problems(probs, slots=2)
    tr = Tracer()
    svc = PropagationService(
        specs, use_pallas=False, telemetry=CAP, tracer=tr
    )
    svc.serve(probs)
    names = {s.name for s in tr.spans()}
    assert {"pump", "admit", "step", "readback", "ticket"} <= names
    tickets = [s for s in tr.spans() if s.name == "ticket"]
    assert len(tickets) == len(probs)
    for s in tickets:
        assert s.attrs["queue_ms"] >= 0.0 and s.attrs["service_ms"] >= 0.0
    # admit/step/readback nest under a pump span.
    pump_ids = {s.span_id for s in tr.spans() if s.name == "pump"}
    for s in tr.spans():
        if s.name in ("admit", "step", "readback"):
            assert s.parent_id in pump_ids


# -- tracer / registry / timing: pure host, dtype-agnostic ----------------


@pytest.mark.f32native
def test_tracer_schema_nesting_export(tmp_path):
    tr = Tracer()
    with tr.span("outer", kind="test"):
        with tr.span("inner"):
            pass
    tr.record("external", 1.0, 2.0, answer=42)
    spans = {s.name: s for s in tr.spans()}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["external"].attrs == {"answer": 42}
    path = tmp_path / "trace.jsonl"
    text = tr.export(path)
    lines = [json.loads(ln) for ln in text.strip().splitlines()]
    assert len(lines) == 3
    for d in lines:
        assert set(d) == SPAN_KEYS
        assert d["dur_ms"] >= 0.0
    assert path.read_text() == text
    tr.clear()
    assert tr.spans() == []


@pytest.mark.f32native
def test_null_tracer_is_noop():
    tr = NullTracer()
    with tr.span("anything"):
        tr.record("x", 0.0, 1.0)
    assert tr.spans() == []
    assert tr.export() == ""


@pytest.mark.f32native
def test_registry_schema_and_error_isolation():
    reg = MetricsRegistry()
    reg.register("good", lambda: {"v": 1})
    reg.register("bad", lambda: 1 / 0)
    with pytest.raises(ValueError):
        reg.register("good", lambda: 2)
    snap = reg.snapshot()
    assert set(snap) == SNAPSHOT_KEYS
    assert snap["sources"] == {"good": {"v": 1}}
    assert "bad" in snap["errors"] and "ZeroDivisionError" in snap["errors"]["bad"]
    reg.register("good", lambda: 2, replace=True)
    assert reg.snapshot()["sources"]["good"] == 2
    reg.unregister("bad")
    assert reg.source_names() == ("good",)


@pytest.mark.f32native
def test_run_metadata_shape():
    meta = run_metadata()
    assert set(meta) == {
        "git_commit", "timestamp", "jax_version", "x64", "backend",
    }
    assert meta["git_commit"] != ""
    assert isinstance(meta["x64"], bool)


@pytest.mark.f32native
def test_timing_utilities():
    xs = jnp.arange(1024.0)
    t = time_fenced(lambda: xs * 2.0, repeats=2)
    assert 0.0 < t < 10.0
    trials = paired_trials(
        [lambda: xs + 1.0, lambda: xs + 2.0], trials=3, repeats=2
    )
    assert len(trials) == 3 and all(len(row) == 2 for row in trials)
    assert median_ratio(trials) > 0.0
    assert median_of(trials, 0) > 0.0
    tr = Tracer()
    phases = time_phases(
        {"a": lambda: xs * 3.0, "b": lambda: xs * 4.0},
        repeats=1, tracer=tr,
    )
    assert set(phases) == {"a", "b"} and all(v > 0.0 for v in phases.values())
    assert {s.name for s in tr.spans()} == {"phase:a", "phase:b"}
