"""Property tests for the sparse-layout conversions that back the engines:

  * ``csr_to_csc`` represents the SAME dense matrix (round-trip through
    both layouts), across seeded random sparsity patterns, empty rows/cols,
    and duplicate-free COO inputs;
  * ``permute_problem`` commutes with propagation: propagating a
    row/col-permuted problem yields the permuted bounds of the original's
    fixed point (paper App. B's semantic counterpart, here as an
    always-running seeded sweep -- the hypothesis variant in
    test_properties.py is skipped when hypothesis is absent).
"""
import numpy as np
import pytest

from repro.core import (
    INF,
    Problem,
    bounds_equal,
    csr_from_coo,
    csr_from_dense,
    csr_to_csc,
    permute_problem,
    propagate,
)
from repro.data.instances import make_mixed, make_pseudo_boolean


def _random_problem(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(3, 25))
    n = int(rng.integers(3, 20))
    density = float(rng.uniform(0.15, 0.6))
    mask = rng.random((m, n)) < density
    for i in range(m):  # at least one nonzero per row
        if not mask[i].any():
            mask[i, rng.integers(0, n)] = True
    a = np.where(mask, rng.choice([-3.0, -2.0, -1.0, 1.0, 2.0], size=(m, n)), 0.0)
    csr = csr_from_dense(a)
    ub = rng.integers(1, 6, size=n).astype(np.float64)
    lb = -rng.integers(0, 3, size=n).astype(np.float64)
    lb[rng.random(n) < 0.15] = -INF
    ub[rng.random(n) < 0.15] = INF
    row_abs = np.abs(a).sum(axis=1)
    lhs = np.where(rng.random(m) < 0.4, -INF, -row_abs * rng.uniform(0.1, 0.5, m))
    rhs = np.where(rng.random(m) < 0.2, INF, row_abs * rng.uniform(0.1, 0.5, m))
    swap = lhs > rhs
    lhs[swap], rhs[swap] = rhs[swap], lhs[swap]
    return Problem(
        csr=csr, lhs=lhs, rhs=rhs, lb=lb, ub=ub, is_int=rng.random(n) < 0.5
    )


def _csc_to_dense(csc) -> np.ndarray:
    m, n = int(csc.n_rows), int(csc.col_ptr.shape[0]) - 1
    a = np.zeros((m, n), dtype=csc.val.dtype)
    for j in range(n):
        s, e = int(csc.col_ptr[j]), int(csc.col_ptr[j + 1])
        a[csc.row[s:e], j] = csc.val[s:e]
    return a


# ---------------------------------------------------------------------------
# CSC round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(12))
def test_csr_to_csc_same_dense_matrix(seed):
    p = _random_problem(seed)
    dense = p.csr.to_dense()
    np.testing.assert_array_equal(_csc_to_dense(csr_to_csc(p.csr)), dense)


def test_csr_to_csc_handles_empty_rows_and_cols():
    # Row 1 and column 2 carry no nonzeros at all.
    a = np.array([[1.0, 0.0, 0.0, -2.0],
                  [0.0, 0.0, 0.0, 0.0],
                  [0.0, 3.0, 0.0, 0.5]])
    csr = csr_from_dense(a)
    csc = csr_to_csc(csr)
    np.testing.assert_array_equal(_csc_to_dense(csc), a)
    assert int(csc.col_ptr[2]) == int(csc.col_ptr[3])  # empty column window


def test_csr_to_csc_column_major_invariants():
    p = make_mixed(m=60, n=45, seed=9)
    csc = csr_to_csc(p.csr)
    assert csc.val.shape == p.csr.val.shape
    cols_of = np.repeat(np.arange(p.n), np.diff(csc.col_ptr))
    assert (np.diff(cols_of) >= 0).all()  # columns nondecreasing
    for j in range(p.n):  # rows sorted within each column
        s, e = int(csc.col_ptr[j]), int(csc.col_ptr[j + 1])
        assert (np.diff(csc.row[s:e]) > 0).all()


def test_coo_csr_csc_round_trip():
    rng = np.random.default_rng(42)
    m, n, nnz = 15, 12, 40
    cells = rng.choice(m * n, size=nnz, replace=False)
    rows, cols = (cells // n).astype(np.int32), (cells % n).astype(np.int32)
    vals = rng.uniform(-4, 4, size=nnz)
    csr = csr_from_coo(rows, cols, vals, m, n)
    dense = np.zeros((m, n))
    dense[rows, cols] = vals
    np.testing.assert_array_equal(csr.to_dense(), dense)
    np.testing.assert_array_equal(_csc_to_dense(csr_to_csc(csr)), dense)


# ---------------------------------------------------------------------------
# Permutation commutes with propagation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_permuted_problem_propagates_to_permuted_bounds(seed):
    p = _random_problem(100 + seed)
    rng = np.random.default_rng(seed)
    row_perm = rng.permutation(p.m)
    col_perm = rng.permutation(p.n)
    q = permute_problem(p, row_perm, col_perm)
    # Structural check: the permuted dense matrix is the original reindexed.
    np.testing.assert_array_equal(
        q.csr.to_dense(), p.csr.to_dense()[np.ix_(row_perm, col_perm)]
    )
    rp = propagate(p)
    rq = propagate(q)
    assert bool(rq.infeasible) == bool(rp.infeasible)
    if not bool(rp.infeasible):
        assert bounds_equal(
            np.asarray(rq.lb), np.asarray(rq.ub),
            np.asarray(rp.lb)[col_perm], np.asarray(rp.ub)[col_perm],
        )


def test_permutation_identity_is_noop():
    p = make_pseudo_boolean(n=40, m=30, seed=5)
    q = permute_problem(p, np.arange(p.m), np.arange(p.n))
    np.testing.assert_array_equal(q.csr.to_dense(), p.csr.to_dense())
    np.testing.assert_array_equal(q.lb, p.lb)
    np.testing.assert_array_equal(q.lhs, p.lhs)
