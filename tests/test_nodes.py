"""Warm-start bounds threading + the shared-matrix node engine.

Four layers:
  * warm-start identity: propagating from explicit ``lb0/ub0`` equal to the
    root bounds is BITWISE identical to the default path for every driver
    (host_loop, device_loop, unrolled, fused Pallas block-ELL, batched);
  * structure-keyed caches: a bounds-only Problem variant reuses the
    prepared tiles and the compiled fixed point of its root;
  * node batches: B warm-started nodes over ONE shared matrix match B
    independent single-instance warm-started runs node-by-node, including
    per-node rounds/converged/infeasible, on both the vmapped jnp path and
    the node kernel (Pallas interpret);
  * pruning: an infeasible node is flagged without poisoning its batch.
"""
import numpy as np
import jax
import pytest

from repro.core import (
    NodeBatch,
    branch_children,
    bounds_equal,
    propagate,
    propagate_batch,
    propagate_node_batch,
    propagate_nodes,
)
from repro.core.sharded import propagate_sharded
from repro.data import make_cascade_chain, make_knapsack, make_mixed, make_pseudo_boolean
from repro.kernels import cache_info, prepare_block_ell, propagate_block_ell


def _assert_same_result(a, b):
    np.testing.assert_array_equal(np.asarray(a.lb), np.asarray(b.lb))
    np.testing.assert_array_equal(np.asarray(a.ub), np.asarray(b.ub))
    assert int(a.rounds) == int(b.rounds)
    assert bool(a.converged) == bool(b.converged)
    assert bool(a.infeasible) == bool(b.infeasible)


def _branched_nodes(p, count, fixings=3, seed=0):
    """``count`` node bound plans, each a few random branchings off root."""
    rng = np.random.default_rng(seed)
    nodes = []
    for _ in range(count):
        lb, ub = p.lb.copy(), p.ub.copy()
        for var in rng.choice(p.n, size=fixings, replace=False):
            if not p.is_int[var] or lb[var] >= ub[var]:
                continue
            down, up = branch_children(lb, ub, int(var), lb[var])
            lb, ub = down if rng.random() < 0.5 else up
        nodes.append((lb, ub))
    return nodes


# ---------------------------------------------------------------------------
# Warm-start identity, every driver
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("driver", ["host_loop", "device_loop", "unrolled"])
def test_core_driver_warm_start_identity(driver):
    p = make_mixed(m=90, n=70, seed=3)
    base = propagate(p, driver=driver)
    warm = propagate(p, driver=driver, lb0=p.lb, ub0=p.ub)
    _assert_same_result(base, warm)


@pytest.mark.parametrize(
    "kwargs",
    [
        dict(use_pallas=False),
        dict(use_pallas=True, interpret=True),
        dict(use_pallas=False, driver="host_loop"),
        dict(use_pallas=False, scatter="segment"),
    ],
)
def test_block_ell_warm_start_identity(kwargs):
    p = make_mixed(m=90, n=70, seed=4)
    base = propagate_block_ell(p, **kwargs)
    warm = propagate_block_ell(p, lb0=p.lb, ub0=p.ub, **kwargs)
    _assert_same_result(base, warm)


def test_batched_warm_start_identity():
    probs = [make_mixed(m=80, n=60, seed=s) for s in range(4)]
    base = propagate_batch(probs, use_pallas=False)
    warm = propagate_batch(
        probs, use_pallas=False, bounds=[(p.lb, p.ub) for p in probs]
    )
    for a, b in zip(base, warm):
        _assert_same_result(a, b)


def test_batched_partial_bounds_override():
    """``None`` entries keep their own bounds; overridden instances match
    a repacked problem carrying those bounds."""
    probs = [make_knapsack(n=50, m=15, seed=s) for s in range(3)]
    lb1 = probs[1].lb.copy()
    lb1[:5] = 1.0
    warm = propagate_batch(
        probs, use_pallas=False, bounds=[None, (lb1, probs[1].ub), None]
    )
    base = propagate_batch(probs, use_pallas=False)
    repacked = propagate_batch(
        [probs[0], probs[1]._replace(lb=lb1), probs[2]], use_pallas=False
    )
    _assert_same_result(warm[0], base[0])
    _assert_same_result(warm[2], base[2])
    _assert_same_result(warm[1], repacked[1])


def test_sharded_warm_start_identity():
    mesh = jax.make_mesh((1,), ("x",))
    p = make_mixed(m=60, n=50, seed=5)
    base = propagate_sharded(p, mesh)
    warm = propagate_sharded(p, mesh, lb0=p.lb, ub0=p.ub)
    _assert_same_result(base, warm)


def test_warm_start_equals_repacked_problem():
    """Explicit per-call bounds == baking the same bounds into a fresh
    Problem, bitwise, on the fused engine."""
    p = make_knapsack(n=40, m=12, seed=2)
    lb2, ub2 = p.lb.copy(), p.ub.copy()
    lb2[3] = 1.0
    ub2[7] = 0.0
    warm = propagate_block_ell(p, lb0=lb2, ub0=ub2, use_pallas=False)
    packed = propagate_block_ell(p._replace(lb=lb2, ub=ub2), use_pallas=False)
    _assert_same_result(warm, packed)


# ---------------------------------------------------------------------------
# Structure-keyed caches
# ---------------------------------------------------------------------------


def test_prepare_cache_keys_on_structure():
    p = make_mixed(m=50, n=40, seed=6)
    prep = prepare_block_ell(p)
    node_lb = np.maximum(p.lb, 0.0)
    node = p._replace(lb=node_lb, ub=p.ub.copy())
    prep_node = prepare_block_ell(node)
    # Same structure object graph -> shared device tiles + hoisted gathers.
    assert prep_node.d.val is prep.d.val
    assert prep_node.d.col is prep.d.col
    assert prep_node.ii_g is prep.ii_g
    # ... but BOTH bound carriers of the view reflect the node's bounds.
    np.testing.assert_array_equal(np.asarray(prep_node.d.lb0), node_lb)
    np.testing.assert_array_equal(np.asarray(prep_node.lb0)[: p.n], node_lb)


def test_cache_info_counts_hits_and_misses():
    p = make_mixed(m=50, n=40, seed=7)
    before = cache_info()["prepare_block_ell"]
    prepare_block_ell(p)
    prepare_block_ell(p)
    after = cache_info()["prepare_block_ell"]
    assert after["hits"] >= before["hits"] + 1
    assert after["misses"] >= before["misses"] + 1
    assert after["maxsize"] == 32
    assert set(cache_info()) >= {
        "prepare_block_ell", "block_ell_runner", "packed_problems",
        "prepare_problem_batch", "batch_runner", "node_runner",
    }


# ---------------------------------------------------------------------------
# Node batches vs independent single-instance runs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kwargs", [dict(use_pallas=False), dict(use_pallas=True, interpret=True)]
)
def test_node_batch_matches_single_runs_bitwise(kwargs):
    p = make_knapsack(n=40, m=12, seed=1)
    nodes = _branched_nodes(p, 5)
    res = propagate_nodes(
        p, np.stack([a for a, _ in nodes]), np.stack([b for _, b in nodes]),
        **kwargs,
    )
    assert res.size == 5
    for i, (lb, ub) in enumerate(nodes):
        single = propagate_block_ell(p, lb0=lb, ub0=ub, **kwargs)
        _assert_same_result(res.result(i), single)


def test_node_batch_mixed_round_counts():
    """A root-bounds node and a tightened node of the §2.2 cascade converge
    to their own fixed points with their own round counts."""
    c = make_cascade_chain(16)
    ub_tight = c.ub.copy()
    ub_tight[0] = 0.25
    res = propagate_nodes(
        c, np.stack([c.lb, c.lb]), np.stack([c.ub, ub_tight]), use_pallas=False
    )
    for i, (lb, ub) in enumerate([(c.lb, c.ub), (c.lb, ub_tight)]):
        single = propagate_block_ell(c, lb0=lb, ub0=ub, use_pallas=False)
        _assert_same_result(res.result(i), single)
    assert int(res.rounds[0]) != int(res.rounds[1])


def test_node_batch_multichunk_path():
    """tile_width below the longest row forces the vmapped multichunk
    round; node results still match single runs."""
    p = make_knapsack(n=40, m=10, seed=2)
    assert any(np.diff(p.csr.row_ptr) > 8)
    nodes = _branched_nodes(p, 3, seed=4)
    res = propagate_nodes(
        p, np.stack([a for a, _ in nodes]), np.stack([b for _, b in nodes]),
        tile_rows=2, tile_width=8, use_pallas=False,
    )
    for i, (lb, ub) in enumerate(nodes):
        single = propagate_block_ell(
            p, lb0=lb, ub0=ub, tile_rows=2, tile_width=8, use_pallas=False
        )
        _assert_same_result(res.result(i), single)


def test_infeasible_node_is_pruned_not_poisoning():
    p = make_knapsack(n=30, m=10, seed=3)
    ok_lb, ok_ub = p.lb.copy(), p.ub.copy()
    bad_lb = p.lb.copy()
    bad_lb[:] = 1.0  # select every item: violates the knapsack capacities
    res = propagate_nodes(
        p, np.stack([ok_lb, bad_lb]), np.stack([ok_ub, p.ub]), use_pallas=False
    )
    assert not bool(res.infeasible[0])
    assert bool(res.infeasible[1])
    single = propagate_block_ell(p, use_pallas=False)
    _assert_same_result(res.result(0), single)


def test_node_batch_api_and_branching_helpers():
    p = make_pseudo_boolean(n=40, m=30, seed=2)
    nb = NodeBatch.from_root(p, copies=3)
    assert nb.size == 3 and nb.lb.shape == (3, p.n)
    (dlb, dub), (ulb, uub) = branch_children(p.lb, p.ub, 5, 0.0)
    assert dub[5] == 0.0 and ulb[5] == 1.0
    nb2 = NodeBatch.from_nodes(p, [(dlb, dub), (ulb, uub)])
    res = propagate_node_batch(nb2, use_pallas=False)
    survivors = nb2.select(~np.asarray(res.infeasible))
    assert survivors.size == int((~np.asarray(res.infeasible)).sum())
    for r, full in zip(res.results(), [res.result(0), res.result(1)]):
        np.testing.assert_array_equal(np.asarray(r.lb), np.asarray(full.lb))


def test_repeated_node_propagation_is_stable():
    """Structure/runner caches + donation must not corrupt state across
    repeated node propagations of the same instance."""
    p = make_mixed(m=60, n=45, seed=8)
    nodes = _branched_nodes(p, 4, seed=5)
    lb = np.stack([a for a, _ in nodes])
    ub = np.stack([b for _, b in nodes])
    r1 = propagate_nodes(p, lb, ub, use_pallas=False)
    r2 = propagate_nodes(p, lb, ub, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(r1.lb), np.asarray(r2.lb))
    np.testing.assert_array_equal(np.asarray(r1.ub), np.asarray(r2.ub))
    np.testing.assert_array_equal(np.asarray(r1.rounds), np.asarray(r2.rounds))


def test_warm_start_agrees_with_sequential_limit():
    """A warm-started node's limit point agrees with propagating the node
    as its own problem through the sequential reference."""
    from repro.core import propagate_sequential

    p = make_pseudo_boolean(n=50, m=40, seed=3)
    (lb, ub), = _branched_nodes(p, 1, fixings=2, seed=6)
    warm = propagate_block_ell(p, lb0=lb, ub0=ub, use_pallas=False)
    seq = propagate_sequential(p._replace(lb=lb, ub=ub))
    if not bool(warm.infeasible):
        assert bounds_equal(warm.lb, warm.ub, seq.lb, seq.ub)
