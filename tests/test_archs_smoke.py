"""Per-architecture smoke tests (task deliverable f): every assigned arch
instantiates a REDUCED config and runs a forward + one train step on CPU,
asserting output shapes and finiteness.  Serving-path equivalence
(prefill+decode == full forward) is checked for one representative of each
attention family."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    SHAPES,
    cell_supported,
    decode_step,
    forward_train,
    init_cache,
    init_params,
    input_specs,
    prefill,
)
from repro.models.transformer import padded_vocab
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.serve_step import prefill_to_decode_cache
from repro.train.train_step import make_train_step


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = (
            jax.random.normal(jax.random.PRNGKey(1), (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.1
        ).astype(jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = forward_train(
        params, cfg, batch["tokens"], frontend_embeds=batch.get("frontend_embeds")
    )
    b, s = batch["tokens"].shape
    s_total = s + (cfg.n_frontend_tokens if cfg.frontend != "none" else 0)
    assert logits.shape == (b, s_total, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    opt_state = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _batch(cfg)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # Params actually moved.
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved
    assert int(opt_state2.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_runs(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, batch=2, s_max=64)
    logits, cache2 = decode_step(
        params, cfg, jnp.zeros((2, 1), jnp.int32), cache, jnp.int32(0)
    )
    assert logits.shape == (2, 1, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# Serving-path equivalence: one representative per attention family.
@pytest.mark.parametrize(
    "arch", ["granite-3-2b", "deepseek-v2-236b", "mamba2-780m", "recurrentgemma-9b"]
)
def test_prefill_decode_matches_full_forward(arch):
    import dataclasses

    # f64 isolates cache-LAYOUT bugs from chunked-vs-stepwise recurrence
    # drift (which is tested at module level with appropriate tolerances).
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float64")
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s_pre, s_max = 2, 24, 48
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s_pre + 4), 0, cfg.vocab_size)

    # Reference: full forward over s_pre + 4 tokens.
    full_logits, _ = forward_train(params, cfg, toks)

    # Prefill on the first s_pre, then 4 decode steps.  Tolerances absorb
    # chunked-vs-stepwise recurrence drift (SSD / online-softmax) amplified
    # by the unembed projection; cache-layout bugs give O(1..10) diffs.
    tol = dict(rtol=5e-2, atol=5e-2)
    lg, caches = prefill(params, cfg, toks[:, :s_pre])
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(full_logits[:, s_pre - 1], np.float32),
        **tol,
    )
    cache = prefill_to_decode_cache(cfg, caches, s_pre, s_max)
    for i in range(4):
        lg, cache = decode_step(
            params, cfg, toks[:, s_pre + i : s_pre + i + 1], cache,
            jnp.int32(s_pre + i),
        )
        got = np.asarray(lg[:, 0], np.float32)
        want = np.asarray(full_logits[:, s_pre + i], np.float32)
        np.testing.assert_allclose(got, want, **tol)
        # Greedy decisions must agree.
        assert (got.argmax(-1) == want.argmax(-1)).mean() >= 0.99


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_all_shapes(arch):
    """input_specs must build for every supported (arch x shape) cell."""
    cfg = get_config(arch)
    for shape in SHAPES.values():
        ok, why = cell_supported(cfg, shape)
        if not ok:
            assert shape.name == "long_500k" and why
            continue
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        leaves = jax.tree.leaves(specs)
        assert all(hasattr(l, "shape") for l in leaves)


def test_long_500k_skip_set_documented():
    """Exactly the sub-quadratic archs run long_500k."""
    runnable = {
        a for a in ARCH_IDS
        if cell_supported(get_config(a), SHAPES["long_500k"])[0]
    }
    assert runnable == {"mamba2-780m", "recurrentgemma-9b"}
