import jax
import pytest

# Propagation limit-point agreement needs f64 (paper runs double precision by
# default); LM smoke configs pin their own float32 dtypes explicitly.
# NOTE: do NOT set xla_force_host_platform_device_count here -- smoke tests
# and benches must see 1 device (multi-device tests use subprocesses).
jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    import numpy as np

    return np.random.default_rng(0)
