import os

import jax
import pytest

# Propagation limit-point agreement needs f64 (paper runs double precision by
# default); LM smoke configs pin their own float32 dtypes explicitly.
# NOTE: do NOT set xla_force_host_platform_device_count here -- smoke tests
# and benches must see 1 device (multi-device tests use subprocesses).
#
# REPRO_TEST_X64=0 opts OUT of the force-enable so a leg can run with x64
# genuinely off (CI's fp32 leg).  In that mode float64 silently degrades to
# float32 inside JAX, so every test that compares against an in-process f64
# oracle is vacuous -- collection keeps only tests marked ``f32native``
# (their oracle is host numpy, which ignores the JAX x64 switch).
if os.environ.get("REPRO_TEST_X64", "1") != "0":
    jax.config.update("jax_enable_x64", True)


def pytest_collection_modifyitems(config, items):
    if jax.config.jax_enable_x64:
        return
    skip = pytest.mark.skip(reason="needs jax_enable_x64 (f64 degrades to f32)")
    for item in items:
        if "f32native" not in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def rng():
    import numpy as np

    return np.random.default_rng(0)
