"""Pallas kernel validation: interpret-mode kernels vs the pure-jnp oracle
(ref.py), swept over tile shapes and dtypes, plus the full block-ELL engine
against the sequential reference."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import INF, bounds_equal, csr_to_block_ell, propagate_sequential
from repro.data import make_cascade_chain, make_knapsack, make_mixed, make_set_cover
from repro.kernels import (
    activities_tiles,
    candidates_tiles,
    fused_round_tiles,
    propagate_block_ell,
)
from repro.kernels import ref as kref


def _tiles(rng, t, r, k, dtype, inf_frac=0.1):
    val = rng.choice([-2.0, -1.0, 0.0, 1.0, 3.0], size=(t, r, k)).astype(dtype)
    lb = rng.uniform(-5, 0, size=(t, r, k)).astype(dtype)
    ub = rng.uniform(0, 5, size=(t, r, k)).astype(dtype)
    lb[rng.random((t, r, k)) < inf_frac] = -INF
    ub[rng.random((t, r, k)) < inf_frac] = INF
    return jnp.asarray(val), jnp.asarray(lb), jnp.asarray(ub)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("t,r,k", [(1, 2, 4), (3, 4, 8), (2, 8, 16), (5, 1, 32)])
def test_activities_kernel_matches_ref(dtype, t, r, k, rng):
    val, lb, ub = _tiles(rng, t, r, k, dtype)
    got = activities_tiles(val, lb, ub, interpret=True)
    want = kref.activities_tiles_ref(val, lb, ub)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("t,r,k", [(2, 2, 4), (3, 4, 8)])
def test_candidates_kernel_matches_ref(dtype, t, r, k, rng):
    val, lb, ub = _tiles(rng, t, r, k, dtype)
    ii = jnp.asarray(rng.random((t, r, k)) < 0.5)
    mf, mc, xf, xc = kref.activities_tiles_ref(val, lb, ub)
    lhs = jnp.asarray(rng.uniform(-10, 0, size=(t, r)).astype(dtype))
    rhs = jnp.asarray(rng.uniform(0, 10, size=(t, r)).astype(dtype))
    got = candidates_tiles(
        val, lb, ub, ii, mf, mc, xf, xc, lhs, rhs, int_eps=1e-6, interpret=True
    )
    want = kref.candidates_tiles_ref(
        val, lb, ub, ii, mf, mc, xf, xc, lhs, rhs, int_eps=1e-6
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


@pytest.mark.parametrize("t,r,k", [(2, 2, 8), (4, 4, 4)])
def test_fused_kernel_matches_ref(t, r, k, rng):
    val, lb, ub = _tiles(rng, t, r, k, np.float32)
    ii = jnp.asarray(rng.random((t, r, k)) < 0.5)
    lhs = jnp.asarray(rng.uniform(-10, 0, size=(t, r)).astype(np.float32))
    rhs = jnp.asarray(rng.uniform(0, 10, size=(t, r)).astype(np.float32))
    got = fused_round_tiles(val, lb, ub, ii, lhs, rhs, int_eps=1e-6, interpret=True)
    want = kref.fused_round_tiles_ref(val, lb, ub, ii, lhs, rhs, int_eps=1e-6)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


class TestBlockEllConversion:
    def test_covers_all_nonzeros(self):
        p = make_mixed(m=40, n=30, seed=1)
        b = csr_to_block_ell(p.csr, tile_rows=4, tile_width=8)
        assert int((b.val != 0).sum()) == p.csr.nnz
        # Row sums through chunks reproduce dense row sums.
        dense = p.csr.to_dense()
        chunk_sums = np.asarray(b.val).sum(axis=2).reshape(-1)
        rows = np.asarray(b.chunk_row).reshape(-1)
        got = np.zeros(p.m + 1)
        np.add.at(got, rows, chunk_sums)
        np.testing.assert_allclose(got[: p.m], dense.sum(axis=1), rtol=1e-12)

    def test_long_rows_split(self):
        p = make_knapsack(n=50, m=4, seed=0)
        b = csr_to_block_ell(p.csr, tile_rows=2, tile_width=4)
        rows = np.asarray(b.chunk_row).reshape(-1)
        # Some row must span multiple chunks.
        vals, counts = np.unique(rows[rows < p.m], return_counts=True)
        assert counts.max() > 1

    def test_empty_rows_ok(self):
        from repro.core import Problem, csr_from_dense

        A = np.array([[1.0, 2.0], [0.0, 0.0], [3.0, 0.0]])
        csr = csr_from_dense(A)
        b = csr_to_block_ell(csr, tile_rows=2, tile_width=2)
        assert int((b.val != 0).sum()) == 3


@pytest.mark.parametrize("fused", ["auto", "yes"])
@pytest.mark.parametrize("gen,kwargs", [
    (make_knapsack, dict(n=30, m=10, seed=3)),
    (make_set_cover, dict(n=40, m=12, seed=4)),
])
def test_block_ell_engine_short_rows(gen, kwargs, fused):
    p = gen(**kwargs)
    a = propagate_sequential(p)
    b = propagate_block_ell(p, tile_rows=4, tile_width=64, fused=fused,
                            driver="device_loop")
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


@pytest.mark.parametrize("tile_width", [4, 16])
def test_block_ell_engine_row_splitting(tile_width):
    """tile_width smaller than rows forces the multi-chunk (CSR-vector) path."""
    p = make_mixed(m=50, n=35, seed=7)
    a = propagate_sequential(p)
    b = propagate_block_ell(p, tile_rows=4, tile_width=tile_width,
                            fused="no", driver="host_loop")
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


def test_block_ell_cascade():
    p = make_cascade_chain(20)
    a = propagate_sequential(p)
    b = propagate_block_ell(p, tile_rows=2, tile_width=4)
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


def test_pallas_vs_jnp_engine_identical():
    """use_pallas=True/False must be bit-compatible (same arithmetic)."""
    p = make_mixed(m=30, n=25, seed=9)
    a = propagate_block_ell(p, tile_rows=4, tile_width=8, use_pallas=True,
                            driver="host_loop")
    b = propagate_block_ell(p, tile_rows=4, tile_width=8, use_pallas=False,
                            driver="host_loop")
    np.testing.assert_array_equal(np.asarray(a.lb), np.asarray(b.lb))
    np.testing.assert_array_equal(np.asarray(a.ub), np.asarray(b.ub))
