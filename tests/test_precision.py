"""Two-tier adaptive precision: the safety contract and its plumbing.

The fp32 tier is only admissible because three invariants hold (see
``core.bounds.widen_outward`` / ``core.types.int_round_slack`` and the
two-tier front ends in ``core.propagator`` / ``kernels.ops`` /
``core.nodes``):

  * **never tighter** -- outward-rounded fp32 bounds stay outside the f64
    fixed point up to an fp32-representation band (observed <= 6.4e-8
    relative on cancellation-heavy rows; asserted here at 1e-6, well under
    the paper's 1e-5 limit-point criterion), and integer bounds are never
    overtightened at all (the rounding slack absorbs the discontinuity);
  * **no false infeasibility** -- an fp32 infeasible verdict is never
    trusted: the two-tier front ends rerun the endgame from the ORIGINAL
    bounds in the final dtype, so the reported verdict is always f64's;
  * **same limit point** -- promotion is an exact cast of outward bounds
    (with re-canonicalized infinity sentinels), so the tiered run lands on
    the f64-only fixed point: bitwise for integer variables, within the
    same fp32 band for continuous ones (the endgame's monotone merge keeps
    a band-tighter fp32 bound rather than weakening it).

Tests marked ``f32native`` compare JAX fp32 engines against the HOST
numpy-f64 sequential oracle (``core.seq_ref``), so they stay meaningful
with ``jax_enable_x64`` off -- CI's fp32 leg runs exactly these (see
``conftest.pytest_collection_modifyitems``).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    DEFAULT_CONFIG,
    INF,
    PropagationService,
    TierPolicy,
    bounds_equal,
    branch_children,
    int_round_slack,
    progress_measure,
    propagate,
    propagate_batch,
    propagate_nodes,
    propagate_sequential,
    widen_outward,
)
from repro.data import (
    make_banded,
    make_knapsack,
    make_mixed,
    make_pseudo_boolean,
    make_set_cover,
)
from repro.kernels import prepare_block_ell, propagate_block_ell, round_cost_analysis

# fp32-representation / cancellation band of the outward-rounded tier:
# observed worst case 6.4e-8 relative ("mixed" family, cancellation-heavy
# rows), asserted with ~15x headroom -- still 10x tighter than the paper's
# bounds_equal criterion (t_rel=1e-5).
F32_BAND = 1e-6


def _population():
    """Small instances of every family (fast under interpret mode)."""
    return [
        ("knapsack", make_knapsack(n=50, m=10, seed=0)),
        ("knapsack1", make_knapsack(n=50, m=10, seed=1)),
        ("set_cover", make_set_cover(n=60, m=20, seed=0)),
        ("mixed", make_mixed(m=80, n=60, seed=0)),
        ("mixed1", make_mixed(m=80, n=60, seed=3)),
        ("banded", make_banded(n=384, m=64, row_nnz=8, band=48, seed=0)),
        ("pb", make_pseudo_boolean(n=60, m=40, seed=0)),
    ]


def _run_f32(engine, p):
    """One fp32-only fixed point on the given engine family."""
    if engine == "jnp":
        return propagate(p, dtype=np.float32)
    return propagate_block_ell(p, dtype=np.float32, scatter=engine)


def _assert_never_tighter(name, lb_t, ub_t, lb_o, ub_o, is_int, band):
    """Tier bounds must stay outside the oracle's, up to ``band`` relative
    for continuous variables and EXACTLY for integer ones."""
    lb_t = np.asarray(lb_t, np.float64)
    ub_t = np.asarray(ub_t, np.float64)
    # An oracle-infinite bound the tier made finite is an unbounded
    # overtightening -- never allowed.
    assert not np.any((lb_o <= -INF / 2) & (lb_t > -INF / 2)), name
    assert not np.any((ub_o >= INF / 2) & (ub_t < INF / 2)), name
    fin_l = lb_o > -INF / 2
    fin_u = ub_o < INF / 2
    tol = np.where(is_int, 0.0, band * (1.0 + np.abs(lb_o)))
    assert np.all(lb_t[fin_l] <= (lb_o + tol)[fin_l]), (
        f"{name}: lb overtightened by "
        f"{np.max((lb_t - lb_o - tol)[fin_l]):.3e}"
    )
    tol = np.where(is_int, 0.0, band * (1.0 + np.abs(ub_o)))
    assert np.all(ub_t[fin_u] >= (ub_o - tol)[fin_u]), (
        f"{name}: ub overtightened by "
        f"{np.max((ub_o - tol - ub_t)[fin_u]):.3e}"
    )


# ---------------------------------------------------------------------------
# fp32 tier vs the host numpy f64 oracle (runs on the x64-off CI leg)
# ---------------------------------------------------------------------------


@pytest.mark.f32native
@pytest.mark.parametrize("engine", ["jnp", "fused", "segment", "batch"])
def test_fp32_tier_never_tighter_than_f64_oracle(engine):
    """Outward-rounded fp32 fixed points stay outside the sequential f64
    oracle's on every family and engine, and an fp32 infeasible verdict
    implies the oracle agrees (no false positives on these families)."""
    pop = _population()
    if engine == "batch":
        batch = propagate_batch([p for _, p in pop], dtype=np.float32)
    for idx, (name, p) in enumerate(pop):
        seq = propagate_sequential(p)
        r = batch[idx] if engine == "batch" else _run_f32(engine, p)
        if bool(r.infeasible):
            assert seq.infeasible, f"{name}/{engine}: false fp32 infeasibility"
            continue
        if seq.infeasible:
            continue  # engine missed a detection the verdict test covers
        _assert_never_tighter(
            f"{name}/{engine}", r.lb, r.ub,
            np.asarray(seq.lb), np.asarray(seq.ub),
            np.asarray(p.is_int, bool), F32_BAND,
        )
        # NOTE: limit-point agreement at the paper's tolerance is a
        # STATISTIC (the fp32-only fixed point may stop epsilon-weaker --
        # the paper reports 842/987, and benchmarks/precision.py accounts
        # the rate); the invariant tested here is only never-tighter.


@pytest.mark.f32native
def test_fp32_infeasibility_detected_on_infeasible_family():
    """The pb family's infeasible seeds ARE detected by the fp32 tier
    (outward rounding weakens bounds but not past a real conflict)."""
    p = make_pseudo_boolean(n=80, m=80, seed=0)
    seq = propagate_sequential(p)
    assert seq.infeasible  # seed pinned to an infeasible instance
    assert bool(propagate(p, dtype=np.float32).infeasible)


# ---------------------------------------------------------------------------
# Safety primitives (pure, dtype-explicit)
# ---------------------------------------------------------------------------


@pytest.mark.f32native
def test_widen_outward_semantics():
    l = jnp.asarray([-2.0, 0.5, 1000.0], jnp.float32)
    u = jnp.asarray([3.0, 0.75, -1000.0], jnp.float32)
    wl, wu = widen_outward(l, u, 0.0)
    assert np.array_equal(np.asarray(wl), np.asarray(l))  # exact identity
    assert np.array_equal(np.asarray(wu), np.asarray(u))
    out = 2.0**-17
    wl, wu = widen_outward(l, u, out)
    dl = np.asarray(l, np.float64) - np.asarray(wl, np.float64)
    du = np.asarray(wu, np.float64) - np.asarray(u, np.float64)
    scale_l = np.maximum(1.0, np.abs(np.asarray(l, np.float64)))
    scale_u = np.maximum(1.0, np.abs(np.asarray(u, np.float64)))
    assert np.all(dl > 0) and np.all(du > 0)            # strictly outward
    assert np.all(dl >= 0.9 * out * scale_l)            # scale-aware width
    assert np.all(du >= 0.9 * out * scale_u)


@pytest.mark.f32native
def test_int_round_slack_per_dtype():
    assert int_round_slack(jnp.float32) == 2.0**-17
    assert int_round_slack(jnp.bfloat16) == 2.0**-6
    assert int_round_slack(jnp.float64) == 0.0  # f64 rounding stays bitwise


@pytest.mark.f32native
def test_progress_measure_semantics():
    lb = jnp.asarray([-INF, 0.0, 2.0], jnp.float32)
    ub = jnp.asarray([INF, 10.0, 4.0], jnp.float32)
    # No movement -> exactly zero.
    assert float(progress_measure(lb, ub, lb, ub)) == 0.0
    # An infinite->finite jump contributes ~1 (sentinel dominates the
    # denominator); a finite tighten contributes ~|delta|/scale.
    lb2 = jnp.asarray([0.0, 0.0, 2.0], jnp.float32)
    ub2 = jnp.asarray([INF, 5.0, 4.0], jnp.float32)
    m = float(progress_measure(lb, ub, lb2, ub2))
    assert m == pytest.approx(1.0 + 5.0 / 11.0, rel=1e-3)
    # Batched planes reduce per instance (trailing axis).
    mb = progress_measure(
        jnp.stack([lb, lb]), jnp.stack([ub, ub]),
        jnp.stack([lb2, lb]), jnp.stack([ub2, ub]),
    )
    assert mb.shape == (2,) and float(mb[1]) == 0.0


def test_compact_index_streams_per_dtype():
    """Low-precision prep narrows the index streams (int16 cols, int8
    integrality marks) -- the other half of the fp32 byte saving; f64 prep
    keeps the original int32 streams bitwise."""
    p = make_set_cover(n=60, m=20, seed=0)
    prep32 = prepare_block_ell(p, dtype=np.float32)
    assert prep32.d.col.dtype == np.dtype(np.int16)
    assert prep32.ii_g.dtype == np.dtype(np.int8)
    prep64 = prepare_block_ell(p, dtype=np.float64)
    assert prep64.d.col.dtype == np.dtype(np.int32)
    assert prep64.ii_g.dtype == np.dtype(np.int32)


def test_fp32_fused_bytes_per_round_ratio():
    """The acceptance bar of the tier: fused-engine fp32 rounds move
    <= 0.6x the bytes of fp64 rounds (value planes halve, index streams
    quarter/halve via the compact dtypes)."""
    for name, p in [
        ("mixed", make_mixed(m=80, n=60, seed=0)),
        ("set_cover", make_set_cover(n=60, m=20, seed=0)),
    ]:
        b32 = round_cost_analysis(p, "fused", dtype=np.float32)["bytes_accessed"]
        b64 = round_cost_analysis(p, "fused", dtype=np.float64)["bytes_accessed"]
        assert b32 / b64 <= 0.6, f"{name}: {b32 / b64:.3f}"


# ---------------------------------------------------------------------------
# Two-tier runs land on the f64 fixed point
# ---------------------------------------------------------------------------


def _assert_same_fixed_point(name, lb_t, ub_t, r64, is_int):
    """Two-tier vs f64-only: bitwise for integer variables; continuous
    ones agree within the fp32 band (a cancellation-heavy row can carry
    an fp32-tier bound up to ~6.6e-8 relative INSIDE the f64 fixed point,
    and the monotone endgame keeps the tighter value -- the same band the
    never-tighter contract allows), plus the paper's limit-point
    criterion, which is 10x looser."""
    lb_t, ub_t = np.asarray(lb_t), np.asarray(ub_t)
    lb_r, ub_r = np.asarray(r64.lb), np.asarray(r64.ub)
    assert np.array_equal(lb_t[is_int], lb_r[is_int]), name
    assert np.array_equal(ub_t[is_int], ub_r[is_int]), name
    tol = F32_BAND * (1.0 + np.abs(lb_r))
    assert np.all(np.abs(lb_t - lb_r) <= tol), name
    tol = F32_BAND * (1.0 + np.abs(ub_r))
    assert np.all(np.abs(ub_t - ub_r) <= tol), name
    assert bool(bounds_equal(lb_t, ub_t, r64.lb, r64.ub)), name


@pytest.mark.parametrize("engine", ["jnp", "fused"])
def test_two_tier_lands_on_f64_fixed_point(engine):
    run = (
        (lambda p, **kw: propagate(p, **kw)) if engine == "jnp"
        else (lambda p, **kw: propagate_block_ell(p, scatter="fused", **kw))
    )
    for name, p in _population():
        r64 = run(p)
        rt = run(p, policy=TierPolicy())
        assert bool(rt.infeasible) == bool(r64.infeasible), f"{name}/{engine}"
        if bool(r64.infeasible):
            continue
        _assert_same_fixed_point(
            f"{name}/{engine}", rt.lb, rt.ub, r64, np.asarray(p.is_int, bool)
        )
        # The tier actually ran (feasible instances promote, not restart).
        assert int(rt.tier_rounds) >= 1


def test_two_tier_batch_lands_on_f64_fixed_point():
    pop = _population()
    base = propagate_batch([p for _, p in pop])
    tier = propagate_batch([p for _, p in pop], policy=TierPolicy())
    for (name, p), r64, rt in zip(pop, base, tier):
        assert bool(rt.infeasible) == bool(r64.infeasible), name
        if bool(r64.infeasible):
            continue
        _assert_same_fixed_point(
            f"{name}/batch", rt.lb, rt.ub, r64, np.asarray(p.is_int, bool)
        )


def test_two_tier_nodes_lands_on_f64_fixed_point():
    p = make_set_cover(n=60, m=20, seed=0)
    var = int(np.where(np.asarray(p.is_int, bool))[0][0])
    (dl, du), (ul, uu) = branch_children(p.lb, p.ub, var, 0.0)
    lb_nodes = np.stack([np.asarray(p.lb, np.float64), dl, ul])
    ub_nodes = np.stack([np.asarray(p.ub, np.float64), du, uu])
    base = propagate_nodes(p, lb_nodes, ub_nodes)
    tier = propagate_nodes(p, lb_nodes, ub_nodes, policy=TierPolicy())
    is_int = np.asarray(p.is_int, bool)
    for i in range(3):
        assert bool(tier.infeasible[i]) == bool(base.infeasible[i])
        if bool(base.infeasible[i]):
            continue
        _assert_same_fixed_point(
            f"node{i}", tier.lb[i], tier.ub[i], base.result(i), is_int
        )


def test_two_tier_guard_ignores_fp32_infeasible(monkeypatch):
    """An fp32 infeasible verdict is NEVER the result: force the tier to
    claim infeasibility on a feasible instance and check the endgame
    restarts from the original bounds, landing bitwise on the f64-only
    run with the correct (feasible) verdict."""
    import repro.core.propagator as prop_mod

    p = make_set_cover(n=60, m=20, seed=0)
    r_base = propagate(p)
    assert not bool(r_base.infeasible)

    real = prop_mod._propagate_single

    def lying_fp32(p_, cfg_, driver_, dtype_, lb0_, ub0_, **kw):
        r = real(p_, cfg_, driver_, dtype_, lb0_, ub0_, **kw)
        if dtype_ is not None and np.dtype(dtype_) == np.float32:
            return r._replace(infeasible=jnp.asarray(True))
        return r

    monkeypatch.setattr(prop_mod, "_propagate_single", lying_fp32)
    rt = propagate(p, policy=TierPolicy())
    assert not bool(rt.infeasible)
    assert int(rt.tier_rounds) >= 1  # the (discarded) tier is accounted
    assert np.array_equal(np.asarray(rt.lb), np.asarray(r_base.lb))
    assert np.array_equal(np.asarray(rt.ub), np.asarray(r_base.ub))
    assert int(rt.rounds) == int(r_base.rounds)


# ---------------------------------------------------------------------------
# Progress-based early stop
# ---------------------------------------------------------------------------


@pytest.mark.f32native
def test_early_stop_is_a_trajectory_prefix():
    """Stopping on flatlined progress can only truncate the monotone
    trajectory: stopped bounds sit between the root bounds and the full
    fixed point, rounds never increase, and a truncated run reports
    converged=False."""
    saved = 0
    for name, p in _population():
        full = propagate(p, dtype=np.float32)
        if bool(full.infeasible):
            continue
        stop = propagate(
            p, dtype=np.float32,
            policy=TierPolicy(two_tier=False, stop_progress=0.05, patience=1),
        )
        assert int(stop.rounds) <= int(full.rounds), name
        saved += int(full.rounds) - int(stop.rounds)
        # fp32's sentinel is 1.00000002e20; clamp before comparing against
        # the f64 root bounds (semantically both are "infinite").
        lb_s = np.maximum(np.asarray(stop.lb, np.float64), -INF)
        ub_s = np.minimum(np.asarray(stop.ub, np.float64), INF)
        lb_f = np.maximum(np.asarray(full.lb, np.float64), -INF)
        ub_f = np.minimum(np.asarray(full.ub, np.float64), INF)
        assert np.all(lb_s >= np.asarray(p.lb, np.float64)), name
        assert np.all(ub_s <= np.asarray(p.ub, np.float64)), name
        assert np.all(lb_s <= lb_f) and np.all(ub_s >= ub_f), name
        if int(stop.rounds) < int(full.rounds):
            assert not bool(stop.converged), name
            assert float(stop.progress) < 0.05, name
    assert saved > 0  # the threshold actually fires somewhere in the set


def test_service_early_retire_frees_slots():
    """A service armed with ``stop_progress`` retires flatlined slots
    early: the stats counter matches the per-result evidence, and every
    early result is a valid prefix of the corresponding exact-service
    trajectory (same slot geometry -> bitwise comparable)."""
    pop = [make_set_cover(n=60, m=20, seed=s) for s in range(3)] + [
        make_mixed(m=80, n=60, seed=s) for s in range(3)
    ]
    exact = PropagationService.from_problems(
        pop, slots=2, tile_width=8, use_pallas=False
    )
    ref = exact.serve(pop)
    assert exact.stats()["early_stopped"] == 0
    eager = PropagationService.from_problems(
        pop, slots=2, tile_width=8, use_pallas=False,
        stop_progress=1e6, patience=1,  # everything flatlines immediately
    )
    got = eager.serve(pop)
    n_early = sum(
        1 for r in got
        if not bool(r.converged) and int(r.rounds) < DEFAULT_CONFIG.max_rounds
    )
    assert eager.stats()["early_stopped"] == n_early
    assert n_early >= 1
    for r, rr in zip(got, ref):
        if bool(rr.infeasible):
            continue
        assert np.all(np.asarray(r.lb) <= np.asarray(rr.lb))
        assert np.all(np.asarray(r.ub) >= np.asarray(rr.ub))
        assert np.isfinite(float(r.progress)) or bool(r.converged)
