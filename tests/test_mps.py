"""MPS reader/writer: hand-written fixture + roundtrip through the
propagator (the limit point must survive serialization)."""
import io

import numpy as np
import pytest

from repro.core import INF, Problem, bounds_equal, propagate
from repro.data.instances import make_mixed
from repro.data.mps import read_mps, write_mps

FIXTURE = """\
NAME          TEST
ROWS
 N  COST
 L  CAP
 G  COVER
 E  BAL
COLUMNS
    MARKER    'MARKER'  'INTORG'
    X  CAP  2.0  COVER  1.0
    X  COST  1.0
    Y  CAP  3.0  BAL  4.0
    MARKER    'MARKER'  'INTEND'
    Z  COVER  1.0  BAL  -1.0
RHS
    RHS  CAP  6.0  COVER  1.0
    RHS  BAL  2.0
BOUNDS
 UP BND  X  10.0
 UP BND  Y  10.0
 UP BND  Z  8.0
ENDATA
"""


def test_read_fixture():
    p = read_mps(io.StringIO(FIXTURE))
    assert p.m == 3 and p.n == 3
    assert p.is_int.tolist() == [True, True, False]
    # CAP: <= 6; COVER: >= 1; BAL: == 2
    np.testing.assert_allclose(p.rhs[0], 6.0)
    assert p.lhs[0] <= -INF
    np.testing.assert_allclose(p.lhs[1], 1.0)
    assert p.rhs[1] >= INF
    np.testing.assert_allclose([p.lhs[2], p.rhs[2]], [2.0, 2.0])
    np.testing.assert_allclose(p.ub, [10.0, 10.0, 8.0])
    # Same instance as examples/quickstart.py => same tightenings.
    r = propagate(p)
    np.testing.assert_allclose(np.asarray(r.ub), [1.0, 2.0, 6.0])
    np.testing.assert_allclose(np.asarray(r.lb), [0.0, 1.0, 2.0])


def test_roundtrip_preserves_limit_point():
    p = make_mixed(m=40, n=30, seed=3)
    buf = io.StringIO()
    write_mps(p, buf)
    buf.seek(0)
    p2 = read_mps(buf)
    assert p2.m == p.m and p2.n == p.n and p2.nnz == p.nnz
    a = propagate(p)
    b = propagate(p2)
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


def test_ranges_section():
    mps = """\
NAME T
ROWS
 N OBJ
 L R1
COLUMNS
    X  R1  1.0
RHS
    RHS  R1  5.0
RANGES
    RNG  R1  3.0
BOUNDS
 UP BND  X  100.0
ENDATA
"""
    p = read_mps(io.StringIO(mps))
    # L row with range 3: 2 <= x <= 5
    np.testing.assert_allclose([p.lhs[0], p.rhs[0]], [2.0, 5.0])
    r = propagate(p)
    np.testing.assert_allclose(np.asarray(r.ub), [5.0])
    np.testing.assert_allclose(np.asarray(r.lb), [2.0])


# ---------------------------------------------------------------------------
# Round-trip property: write_mps -> read_mps reproduces the Problem
# ---------------------------------------------------------------------------


def _random_roundtrip_problem(seed):
    """Random Problem exercising every writer construct: L/G/E/ranged/free
    rows, BV/MI/UI-equivalent bound types, FX, infinite bounds, integrality
    markers.  Every row and column has at least one entry (the writer drops
    empty columns by construction)."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(4, 20))
    n = int(rng.integers(4, 16))
    mask = rng.random((m, n)) < 0.35
    mask[np.arange(m), rng.integers(0, n, size=m)] = True   # rows nonempty
    mask[rng.integers(0, m, size=n), np.arange(n)] = True   # cols nonempty
    rows, cols = np.nonzero(mask)
    vals = rng.standard_normal(rows.size) * 10.0            # arbitrary floats
    vals[vals == 0] = 1.0
    from repro.core import csr_from_coo

    csr = csr_from_coo(rows.astype(np.int32), cols.astype(np.int32), vals, m, n)

    kind = rng.integers(0, 5, size=m)  # 0=L 1=G 2=E 3=ranged 4=free
    lo = rng.standard_normal(m) * 5.0
    hi = lo + np.abs(rng.standard_normal(m)) * 5.0 + 1e-3
    lhs = np.where(kind == 0, -INF, lo)
    rhs = np.where(kind == 1, INF, np.where(kind == 2, lo, hi))
    lhs = np.where(kind == 4, -INF, lhs)
    rhs = np.where(kind == 4, INF, rhs)

    is_int = rng.random(n) < 0.5
    btype = rng.integers(0, 5, size=n)  # 0=[0,U] 1=MI 2=free 3=FX 4=[L,U]
    lb = np.zeros(n)
    ub = np.abs(rng.standard_normal(n)) * 9.0 + 0.5
    lb[btype == 1] = -INF
    lb[btype == 2] = -INF
    ub[btype == 2] = INF
    fx = btype == 3
    lb[fx] = ub[fx] = rng.standard_normal(fx.sum()) * 3.0
    lb[btype == 4] = -np.abs(rng.standard_normal((btype == 4).sum())) * 3.0
    binary = (rng.random(n) < 0.3) & ~fx
    lb[binary], ub[binary], is_int[binary] = 0.0, 1.0, True  # BV-equivalent
    return Problem(csr=csr, lhs=lhs, rhs=rhs, lb=lb, ub=ub, is_int=is_int)


@pytest.mark.parametrize("seed", range(10))
def test_roundtrip_reproduces_problem(seed):
    """write_mps -> read_mps reproduces the Problem: identical sparsity
    and values (17-digit exact), identical bounds/integrality, and sides
    equal up to one rounding in the RANGES reconstruction."""
    p = _random_roundtrip_problem(seed)
    buf = io.StringIO()
    write_mps(p, buf)
    buf.seek(0)
    p2 = read_mps(buf)
    assert (p2.m, p2.n, p2.nnz) == (p.m, p.n, p.nnz)
    np.testing.assert_array_equal(p2.csr.to_dense(), p.csr.to_dense())
    np.testing.assert_array_equal(np.asarray(p2.is_int), np.asarray(p.is_int))
    np.testing.assert_array_equal(p2.lb, p.lb)
    np.testing.assert_array_equal(p2.ub, p.ub)
    # Ranged rows reconstruct lhs as rhs - |range|: exact values everywhere,
    # one float rounding allowed in that reconstruction.
    np.testing.assert_allclose(p2.rhs, p.rhs, rtol=0, atol=0)
    np.testing.assert_allclose(p2.lhs, p.lhs, rtol=1e-15, atol=1e-12)


def test_reader_bound_types_bv_mi_ui():
    """BV / MI / UI / LI bound cards: integrality + bound semantics."""
    mps = """\
NAME T
ROWS
 N OBJ
 L R1
COLUMNS
    A  R1  1.0
    B  R1  1.0
    C  R1  1.0
    D  R1  1.0
RHS
    RHS  R1  10.0
BOUNDS
 BV BND  A
 MI BND  B
 UI BND  C  7
 LI BND  D  -2
ENDATA
"""
    p = read_mps(io.StringIO(mps))
    # BV: binary [0, 1] integer.
    assert p.is_int[0] and p.lb[0] == 0.0 and p.ub[0] == 1.0
    # MI: lower bound -inf, continuous.
    assert not p.is_int[1] and p.lb[1] <= -INF and p.ub[1] >= INF
    # UI: integer upper bound.
    assert p.is_int[2] and p.lb[2] == 0.0 and p.ub[2] == 7.0
    # LI: integer lower bound.
    assert p.is_int[3] and p.lb[3] == -2.0 and p.ub[3] >= INF
