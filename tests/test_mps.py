"""MPS reader/writer: hand-written fixture + roundtrip through the
propagator (the limit point must survive serialization)."""
import io

import numpy as np

from repro.core import INF, bounds_equal, propagate
from repro.data.instances import make_mixed
from repro.data.mps import read_mps, write_mps

FIXTURE = """\
NAME          TEST
ROWS
 N  COST
 L  CAP
 G  COVER
 E  BAL
COLUMNS
    MARKER    'MARKER'  'INTORG'
    X  CAP  2.0  COVER  1.0
    X  COST  1.0
    Y  CAP  3.0  BAL  4.0
    MARKER    'MARKER'  'INTEND'
    Z  COVER  1.0  BAL  -1.0
RHS
    RHS  CAP  6.0  COVER  1.0
    RHS  BAL  2.0
BOUNDS
 UP BND  X  10.0
 UP BND  Y  10.0
 UP BND  Z  8.0
ENDATA
"""


def test_read_fixture():
    p = read_mps(io.StringIO(FIXTURE))
    assert p.m == 3 and p.n == 3
    assert p.is_int.tolist() == [True, True, False]
    # CAP: <= 6; COVER: >= 1; BAL: == 2
    np.testing.assert_allclose(p.rhs[0], 6.0)
    assert p.lhs[0] <= -INF
    np.testing.assert_allclose(p.lhs[1], 1.0)
    assert p.rhs[1] >= INF
    np.testing.assert_allclose([p.lhs[2], p.rhs[2]], [2.0, 2.0])
    np.testing.assert_allclose(p.ub, [10.0, 10.0, 8.0])
    # Same instance as examples/quickstart.py => same tightenings.
    r = propagate(p)
    np.testing.assert_allclose(np.asarray(r.ub), [1.0, 2.0, 6.0])
    np.testing.assert_allclose(np.asarray(r.lb), [0.0, 1.0, 2.0])


def test_roundtrip_preserves_limit_point():
    p = make_mixed(m=40, n=30, seed=3)
    buf = io.StringIO()
    write_mps(p, buf)
    buf.seek(0)
    p2 = read_mps(buf)
    assert p2.m == p.m and p2.n == p.n and p2.nnz == p.nnz
    a = propagate(p)
    b = propagate(p2)
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


def test_ranges_section():
    mps = """\
NAME T
ROWS
 N OBJ
 L R1
COLUMNS
    X  R1  1.0
RHS
    RHS  R1  5.0
RANGES
    RNG  R1  3.0
BOUNDS
 UP BND  X  100.0
ENDATA
"""
    p = read_mps(io.StringIO(mps))
    # L row with range 3: 2 <= x <= 5
    np.testing.assert_allclose([p.lhs[0], p.rhs[0]], [2.0, 5.0])
    r = propagate(p)
    np.testing.assert_allclose(np.asarray(r.ub), [5.0])
    np.testing.assert_allclose(np.asarray(r.lb), [2.0])
