"""Column-slab partitioned engine validation (the VMEM size cliff).

Four layers:
  * partition builder: slab masking/duplication/coverage invariants of
    ``build_slab_partition``;
  * kernel vs slab oracle: the partitioned Pallas round (A''' -> combine ->
    E''' -> slab merge) is bitwise-equal to ``ref.partitioned_round_ref``
    over the same partition arrays (interpret mode, eager);
  * engine vs engine: partitioned fixed points agree with the segment
    oracle engine on random instances -- single-instance, batched, and
    node paths, including rows spanning chunks;
  * the size cliff itself: ``scatter="auto"`` picks ``fused`` below
    ``SCATTER_MAX_NPAD`` and ``partitioned`` above it (a real
    ``n_pad > 2^16`` instance rides the partitioned path end to end), and
    the partitioned round measures fewer HBM bytes than the segment round
    on banded large-n instances.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bounds_equal, propagate_batch
from repro.core.nodes import propagate_nodes
from repro.data import make_banded, make_knapsack, make_mixed, make_set_cover
from repro.kernels import (
    SCATTER_MAX_NPAD,
    prepare_block_ell,
    propagate_block_ell,
    round_cost_analysis,
    round_fn_for,
)
from repro.kernels import ops as kops
from repro.kernels import prop_round as kern
from repro.kernels import ref as kref


def _assert_engines_equal(a, b, exact=True):
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)
    assert int(a.rounds) == int(b.rounds)
    assert bool(a.infeasible) == bool(b.infeasible)
    if exact:
        np.testing.assert_allclose(
            np.asarray(a.lb), np.asarray(b.lb), rtol=1e-12, atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(a.ub), np.asarray(b.ub), rtol=1e-12, atol=1e-12
        )


# ---------------------------------------------------------------------------
# Partition builder invariants
# ---------------------------------------------------------------------------


def test_partition_masks_and_covers():
    p = make_mixed(m=40, n=300, seed=11)
    prep = prepare_block_ell(p, 4, 32)
    part = prep.slab_partition(128)
    assert part.slab == 128
    assert part.n_slabs == -(-prep.n_pad // 128)
    assert part.n_pad_part == part.n_slabs * 128

    val = np.asarray(part.val)
    col = np.asarray(part.col_s)
    # Masking preserves every nonzero exactly once across copies.
    assert int((val != 0).sum()) == p.nnz
    # Slab-local columns stay inside their window.
    assert col.min() >= 0 and col[val != 0].max() < part.slab
    # Copies are (instance, slab, tile)-sorted; every slab window covered.
    slabs = np.asarray(part.tile_slab)
    assert (np.diff(slabs) >= 0).all()
    assert set(np.unique(slabs)) == set(range(part.n_slabs))
    # Straddling tiles were duplicated (mixed instances have wide rows).
    assert part.duplication >= 1.0
    assert part.num_copies >= part.source_tiles


def test_partition_is_cached_per_slab_width():
    p = make_mixed(m=20, n=200, seed=3)
    prep = prepare_block_ell(p, 4, 32)
    a = prep.slab_partition(128)
    assert prep.slab_partition(128) is a
    b = prep.slab_partition(256)
    assert b is not a and b.n_slabs != a.n_slabs
    # Bounds-swapped prepare() views share the structure-derived partition.
    view = prepare_block_ell(
        p._replace(lb=p.lb - 1.0, ub=p.ub + 1.0), 4, 32
    )
    assert view.slab_partition(128) is a


# ---------------------------------------------------------------------------
# Kernels vs the slab oracle (bitwise, eager interpret mode)
# ---------------------------------------------------------------------------


def _oracle_round_single(part, lb, ub, n_pad, eps=1e-9, int_eps=1e-6):
    """One ``(n_pad,)`` plane through the jnp slab oracle + shared merge."""
    from repro.core import bounds as bnd

    best_l, best_u = kref.partitioned_round_ref(
        part, lb[None, :], ub[None, :], int_eps
    )
    return bnd.apply_updates(lb, ub, best_l[0, :n_pad], best_u[0, :n_pad], eps)


# 128/256 exercise 3- and 2-slab grids with straddling copies; 512 covers
# the whole padded domain (n_pad = 384), forcing the single-slab degenerate
# partition through the same 2D (run, tile) kernels.
@pytest.mark.parametrize("slab_w", [128, 256, 512])
@pytest.mark.parametrize("seed,tile", [(0, (4, 16)), (7, (2, 8)), (11, (8, 32))])
def test_partitioned_round_matches_slab_oracle(seed, tile, slab_w):
    p = make_mixed(m=30, n=280, seed=seed)
    prep = prepare_block_ell(p, *tile)
    part = prep.slab_partition(slab_w)
    assert part.n_slabs == -(-prep.n_pad // slab_w)
    if slab_w >= prep.n_pad:
        assert part.n_slabs == 1

    got_l, got_u, ch = kops._partitioned_pallas_round(
        part, prep.lb0[None, :], prep.ub0[None, :], jnp.ones((1,), jnp.int32),
        node=False, eps=1e-9, int_eps=1e-6, inf=kref.INF, interpret=True,
    )
    want_lb, want_ub, want_ch = _oracle_round_single(
        part, prep.lb0, prep.ub0, prep.n_pad
    )
    np.testing.assert_array_equal(np.asarray(got_l[0]), np.asarray(want_lb))
    np.testing.assert_array_equal(np.asarray(got_u[0]), np.asarray(want_ub))
    assert bool(ch[0]) == bool(want_ch)


def test_partitioned_round_straddling_every_boundary_matches_oracle():
    """Dense knapsack rows cross EVERY slab boundary: all rows ride the
    straddle sub-stream and the out-of-band aggregate table, and the fused
    round still lands bitwise on the oracle."""
    p = make_knapsack(n=280, m=8, seed=5)
    prep = prepare_block_ell(p, 2, 8)
    part = prep.slab_partition(128)
    assert part.n_slabs >= 3 and part.has_straddle
    # Straddle copies appear in every slab window (every boundary crossed).
    assert set(np.unique(np.asarray(part.a_tile_slab))) == set(range(part.n_slabs))

    got_l, got_u, ch = kops._partitioned_pallas_round(
        part, prep.lb0[None, :], prep.ub0[None, :], jnp.ones((1,), jnp.int32),
        node=False, eps=1e-9, int_eps=1e-6, inf=kref.INF, interpret=True,
    )
    want_lb, want_ub, want_ch = _oracle_round_single(
        part, prep.lb0, prep.ub0, prep.n_pad
    )
    np.testing.assert_array_equal(np.asarray(got_l[0]), np.asarray(want_lb))
    np.testing.assert_array_equal(np.asarray(got_u[0]), np.asarray(want_ub))
    assert bool(ch[0]) == bool(want_ch)


@pytest.mark.parametrize("slab_w", [128, 256])
def test_batched_partitioned_round_matches_slab_oracle(slab_w):
    """Multi-instance copies route through run_inst to per-instance plane
    rows; converged (inactive) instances freeze in-kernel."""
    from repro.core import bounds as bnd

    problems = [make_mixed(m=25, n=260, seed=s) for s in range(3)]
    batches = kops.packed_problems(problems, 4, 32)
    assert len(batches) == 1
    prep = kops.prepare_problem_batch(batches[0])
    part = prep.slab_partition(slab_w)
    assert part.batch == 3

    active = jnp.asarray([1, 0, 1], jnp.int32)
    lb, ub = prep.d.lb0, prep.d.ub0
    got_l, got_u, ch = kops._partitioned_pallas_round(
        part, lb, ub, active,
        node=False, eps=1e-9, int_eps=1e-6, inf=kref.INF, interpret=True,
    )
    best_l, best_u = kref.partitioned_round_ref(part, lb, ub, 1e-6)
    for i in range(3):
        if not int(active[i]):
            np.testing.assert_array_equal(np.asarray(got_l[i]), np.asarray(lb[i]))
            np.testing.assert_array_equal(np.asarray(got_u[i]), np.asarray(ub[i]))
            assert not bool(ch[i])
            continue
        want_lb, want_ub, want_ch = bnd.apply_updates(
            lb[i], ub[i], best_l[i, : prep.n_pad], best_u[i, : prep.n_pad], 1e-9
        )
        np.testing.assert_array_equal(np.asarray(got_l[i]), np.asarray(want_lb))
        np.testing.assert_array_equal(np.asarray(got_u[i]), np.asarray(want_ub))
        assert bool(ch[i]) == bool(want_ch)


@pytest.mark.parametrize("slab_w", [128, 512])
def test_node_partitioned_round_matches_node_oracle(slab_w):
    """ONE instance's partition against (B, n_pad) per-node planes on the
    (node, run, tile) grid: active nodes land bitwise on the vmapped
    oracle, inactive nodes freeze."""
    from repro.core import bounds as bnd

    root = make_mixed(m=30, n=280, seed=3)
    prep = prepare_block_ell(root, 4, 16)
    part = prep.slab_partition(slab_w)
    lb0, ub0 = np.asarray(prep.lb0), np.asarray(prep.ub0)
    lb = np.repeat(lb0[None], 3, axis=0)
    ub = np.repeat(ub0[None], 3, axis=0)
    free = np.flatnonzero(
        np.asarray(root.is_int) & (lb0[: root.n] < ub0[: root.n])
    )
    ub[1][free[0]] = lb0[free[0]]  # node 1: branch x[free[0]] down
    lb, ub = jnp.asarray(lb), jnp.asarray(ub)

    active = jnp.asarray([1, 1, 0], jnp.int32)
    got_l, got_u, ch = kops._partitioned_pallas_round(
        part, lb, ub, active,
        node=True, eps=1e-9, int_eps=1e-6, inf=kref.INF, interpret=True,
    )
    best_l, best_u = kref.node_partitioned_round_ref(part, lb, ub, 1e-6)
    for i in range(2):
        want_lb, want_ub, want_ch = bnd.apply_updates(
            lb[i], ub[i], best_l[i, : prep.n_pad], best_u[i, : prep.n_pad], 1e-9
        )
        np.testing.assert_array_equal(np.asarray(got_l[i]), np.asarray(want_lb))
        np.testing.assert_array_equal(np.asarray(got_u[i]), np.asarray(want_ub))
        assert bool(ch[i]) == bool(want_ch)
    np.testing.assert_array_equal(np.asarray(got_l[2]), np.asarray(lb[2]))
    np.testing.assert_array_equal(np.asarray(got_u[2]), np.asarray(ub[2]))
    assert not bool(ch[2])


def test_apply_updates_slab_matches_shared_semantics(rng):
    from repro.core import bounds as bnd

    n_pad_part = 512
    lb = jnp.asarray(rng.uniform(-5, 0, (2, n_pad_part)))
    ub = jnp.asarray(rng.uniform(0, 5, (2, n_pad_part)))
    best_l = jnp.asarray(rng.uniform(-6, 2, (2, n_pad_part)))
    best_u = jnp.asarray(rng.uniform(-2, 6, (2, n_pad_part)))
    active = jnp.asarray([True, False])
    got = kern.apply_updates_slab_tiles(
        lb, ub, best_l, best_u, active, slab=128, eps=1e-9, interpret=True
    )
    want_lb, want_ub, _ = bnd.apply_updates(lb[0], ub[0], best_l[0], best_u[0], 1e-9)
    np.testing.assert_array_equal(np.asarray(got[0][0]), np.asarray(want_lb))
    np.testing.assert_array_equal(np.asarray(got[0][1]), np.asarray(lb[1]))  # frozen
    np.testing.assert_array_equal(np.asarray(got[1][1]), np.asarray(ub[1]))
    assert bool(got[2][0]) and not bool(got[2][1])


# ---------------------------------------------------------------------------
# Engine vs engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_partitioned_engine_matches_segment(seed):
    p = make_mixed(m=35, n=300, seed=seed)
    a = propagate_block_ell(
        p, tile_rows=4, tile_width=32, scatter="partitioned", slab=128,
        driver="host_loop",
    )
    b = propagate_block_ell(
        p, tile_rows=4, tile_width=32, scatter="segment", driver="host_loop"
    )
    _assert_engines_equal(a, b)


def test_partitioned_rows_span_chunks():
    """tile_width far below the longest row: slab copies AND chunk splits
    both complete through the same (T', R) combine."""
    p = make_knapsack(n=280, m=8, seed=5)
    assert int(np.diff(p.csr.row_ptr).max()) > 8
    a = propagate_block_ell(
        p, tile_rows=2, tile_width=8, scatter="partitioned", slab=128,
        driver="host_loop",
    )
    b = propagate_block_ell(
        p, tile_rows=2, tile_width=8, scatter="segment", driver="host_loop"
    )
    _assert_engines_equal(a, b)


def test_partitioned_device_loop_and_jnp_paths_agree():
    p = make_set_cover(n=270, m=25, seed=6)
    kw = dict(tile_rows=4, tile_width=32, scatter="partitioned", slab=128)
    a = propagate_block_ell(p, driver="device_loop", **kw)
    b = propagate_block_ell(p, driver="host_loop", use_pallas=False, **kw)
    _assert_engines_equal(a, b)


# ---------------------------------------------------------------------------
# The size cliff: scatter="auto" selection on both sides
# ---------------------------------------------------------------------------


def test_auto_selects_engine_on_both_sides_of_the_cliff():
    small = prepare_block_ell(make_mixed(m=10, n=50, seed=0), 4, 16)
    assert small.n_pad <= SCATTER_MAX_NPAD
    assert kops._resolve_scatter("auto", small) == "fused"

    big = make_banded(n=SCATTER_MAX_NPAD + 200, m=48, row_nnz=6, band=512, seed=0)
    prep = prepare_block_ell(big, 8, 8)
    assert prep.n_pad > SCATTER_MAX_NPAD
    assert kops._resolve_scatter("auto", prep) == "partitioned"
    with pytest.raises(ValueError):
        kops._resolve_scatter("bogus", prep)


def test_auto_large_scatter_env_override(monkeypatch):
    """REPRO_AUTO_LARGE_SCATTER reroutes only the large-instance leg of
    scatter='auto' (escape hatch for re-validating on new hardware)."""
    big = make_banded(n=SCATTER_MAX_NPAD + 200, m=48, row_nnz=6, band=512, seed=0)
    prep = prepare_block_ell(big, 8, 8)
    assert kops._resolve_scatter("auto", prep) == "partitioned"
    monkeypatch.setenv(kops.AUTO_LARGE_SCATTER_ENV, "segment")
    assert kops._resolve_scatter("auto", prep) == "segment"
    small = prepare_block_ell(make_mixed(m=10, n=50, seed=0), 4, 16)
    assert kops._resolve_scatter("auto", small) == "fused"  # unaffected
    monkeypatch.setenv(kops.AUTO_LARGE_SCATTER_ENV, "bogus")
    with pytest.raises(ValueError):
        kops._resolve_scatter("auto", prep)


def test_default_slab_width_is_balanced():
    from repro.kernels.ops import default_slab_width

    # One slab while the domain fits the cap; balanced lane-multiple slabs
    # beyond it, overhanging n_pad by less than one lane row per slab.
    assert default_slab_width(SCATTER_MAX_NPAD) == SCATTER_MAX_NPAD
    n_pad = SCATTER_MAX_NPAD + 4096
    w = default_slab_width(n_pad)
    assert w % 128 == 0 and w <= SCATTER_MAX_NPAD
    n_slabs = -(-n_pad // w)
    assert n_slabs == 2
    assert n_slabs * w - n_pad < 128 * n_slabs


def test_fits_one_chunk_on_both_sides():
    p = make_set_cover(n=60, m=12, seed=1)
    wide = prepare_block_ell(p, 4, 128)
    narrow = prepare_block_ell(p, 4, 4)
    assert wide.fits_one_chunk and not narrow.fits_one_chunk
    a = propagate_block_ell(p, tile_rows=4, tile_width=128, driver="host_loop")
    b = propagate_block_ell(p, tile_rows=4, tile_width=4, driver="host_loop")
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


def test_vmem_exceeding_instance_rides_partitioned_auto():
    """The acceptance path: a real n_pad > SCATTER_MAX_NPAD instance
    propagates under scatter='auto' (resolved to the partitioned kernels)
    and matches the segment oracle engine exactly (integer-valued data)."""
    p = make_banded(n=SCATTER_MAX_NPAD + 4000, m=56, row_nnz=6, band=512, seed=2)
    prep = prepare_block_ell(p, 8, 8)
    assert prep.n_pad > SCATTER_MAX_NPAD
    part = prep.slab_partition()
    assert part.n_slabs >= 2
    auto = propagate_block_ell(
        p, tile_rows=8, tile_width=8, scatter="auto", driver="host_loop"
    )
    seg = propagate_block_ell(
        p, tile_rows=8, tile_width=8, scatter="segment", driver="host_loop"
    )
    _assert_engines_equal(auto, seg)
    np.testing.assert_array_equal(np.asarray(auto.lb), np.asarray(seg.lb))
    np.testing.assert_array_equal(np.asarray(auto.ub), np.asarray(seg.ub))


# ---------------------------------------------------------------------------
# Batched and node paths across the cliff (shrunken budget keeps tests fast)
# ---------------------------------------------------------------------------


@pytest.fixture
def tiny_budget(monkeypatch):
    """Shrink the VMEM budget so ordinary test instances cross the cliff
    and ride the REAL partitioned kernels in every engine."""
    kops.clear_prepare_cache()
    kops.clear_batch_caches()
    monkeypatch.setattr(kops, "SCATTER_MAX_NPAD", 128)
    monkeypatch.setattr(kops, "SLAB_NPAD", 128)
    yield
    kops.clear_prepare_cache()
    kops.clear_batch_caches()


def test_batched_partitioned_matches_single_instance(tiny_budget):
    problems = [make_mixed(m=25, n=260, seed=s) for s in range(3)]
    assert all(prepare_block_ell(p).n_pad > kops.SCATTER_MAX_NPAD for p in problems)
    batched = propagate_batch(problems)
    for p, got in zip(problems, batched):
        want = propagate_block_ell(p, scatter="partitioned", driver="device_loop")
        _assert_engines_equal(got, want)


def test_node_partitioned_matches_warm_started_singles(tiny_budget):
    root = make_mixed(m=25, n=260, seed=4)
    prep = prepare_block_ell(root)
    assert prep.n_pad > kops.SCATTER_MAX_NPAD
    lb0, ub0 = np.asarray(root.lb), np.asarray(root.ub)
    nodes_lb = np.stack([lb0, lb0.copy(), lb0.copy()])
    nodes_ub = np.stack([ub0, ub0.copy(), ub0.copy()])
    free = np.flatnonzero(root.is_int & (lb0 < ub0))
    nodes_lb[1][free[0]] = max(lb0[free[0]], 1.0)
    nodes_ub[2][free[1]] = min(ub0[free[1]], 0.0)
    res = propagate_nodes(root, nodes_lb, nodes_ub)
    for i in range(3):
        want = propagate_block_ell(
            root, scatter="partitioned", driver="device_loop",
            lb0=nodes_lb[i], ub0=nodes_ub[i],
        )
        got = res.result(i)
        assert bounds_equal(got.lb, got.ub, want.lb, want.ub)
        assert int(got.rounds) == int(want.rounds)
        assert bool(got.infeasible) == bool(want.infeasible)


# ---------------------------------------------------------------------------
# Bytes: the partitioned round keeps the fused byte model at scale
# ---------------------------------------------------------------------------


def test_partitioned_bytes_well_under_segment_on_large_instances():
    """On a VMEM-exceeding banded instance with nnz >> n the partitioned
    round measures well under the segment round at the HBM boundary (the
    O(n_pad) resident-vector terms amortize away as nnz grows; the bench
    records the trajectory in BENCH_prop.json)."""
    p = make_banded(n=SCATTER_MAX_NPAD + 4000, m=15_000, row_nnz=32, band=1024, seed=3)
    kw = dict(tile_rows=8, tile_width=32)
    part_b = round_cost_analysis(p, "partitioned", **kw)["bytes_accessed"]
    seg_b = round_cost_analysis(p, "segment", **kw)["bytes_accessed"]
    assert part_b < 0.5 * seg_b, (part_b, seg_b)


def test_round_fn_for_accepts_partitioned():
    p = make_mixed(m=20, n=200, seed=9)
    prep = prepare_block_ell(p, 4, 32)
    fn = round_fn_for(prep, scatter="partitioned", slab=128)
    lb, ub, changed = jax.jit(fn)(prep.lb0, prep.ub0)
    assert lb.shape == (prep.n_pad,) and ub.shape == (prep.n_pad,)
    assert bool(changed) in (True, False)
