"""Differential + property tests for the device-resident B&B solver.

Four layers:

  * differential: ``solve()`` vs the exhaustive oracle
    (``brute_force_solve``) on >= 20 seeded pure-integer instances across
    two families (pseudo-boolean, random MIP) and both branching rules --
    BITWISE objective agreement and matching feasibility verdicts (the
    integral-data exactness contract of ``core.solver``), plus
    infeasible-at-root and optimal-at-root edge cases;
  * search properties (hypothesis): ``branch_children`` partitions the
    parent domain; ``_plan_expansion`` never double-allocates or leaks
    pool slots; pruning never changes the optimum, only the node count;
  * sync contract: the host is consulted at most ``ceil(levels /
    sync_every)`` times, counted through the ``on_sync`` hook, and the
    pool accounting balances at every sync;
  * determinism: two identical ``solve()`` calls produce identical
    incumbent trajectories, node counts and solutions, for both rules.
"""
import math

import numpy as np
import pytest

try:  # property tests run under hypothesis when present, seeded draws if not
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - container without hypothesis
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import (
    INF,
    BranchRule,
    Problem,
    branch_children,
    brute_force_solve,
    csr_from_dense,
    solve,
)
from repro.core.solver import FREE, OPEN, READY, _plan_expansion
from repro.data import make_pseudo_boolean, make_random_mip
from repro.kernels import node_objective_tiles
from repro.kernels.ref import node_objective_ref


def _objective(p):
    """Deterministic integral objective with mixed signs (exact in f64)."""
    n = p.lb.shape[0]
    sign = np.where(np.arange(n) % 3 == 0, -1.0, 1.0)
    return np.arange(1, n + 1, dtype=np.float64) * sign


def _assert_solution_feasible(p, x, tol=1e-8):
    m, n = p.csr.m, p.csr.n
    dense = np.zeros((m, n))
    dense[np.asarray(p.csr.row_ids()), np.asarray(p.csr.col)] = np.asarray(
        p.csr.val
    )
    ax = dense @ x
    lhs, rhs = np.asarray(p.lhs), np.asarray(p.rhs)
    assert np.all((lhs <= -INF) | (ax >= lhs - tol))
    assert np.all((rhs >= INF) | (ax <= rhs + tol))
    assert np.all(x >= np.asarray(p.lb) - tol)
    assert np.all(x <= np.asarray(p.ub) + tol)
    assert np.all(np.abs(x - np.round(x)) <= 1e-6)


def _check_accounting(res):
    assert res.nodes_created == 1 + 2 * res.nodes_expanded
    if res.status in ("optimal", "infeasible"):
        assert res.nodes_created == (
            res.leaves
            + res.pruned_bound
            + res.pruned_infeasible
            + res.nodes_expanded
        )


# ---------------------------------------------------------------------------
# Differential suite: 20 seeded instances, both families, both rules.
# ---------------------------------------------------------------------------

PB_SEEDS = list(range(12))
MIP_SEEDS = list(range(8))


@pytest.mark.parametrize("seed", PB_SEEDS)
def test_differential_pseudo_boolean(seed):
    p = make_pseudo_boolean(n=12, m=16, seed=seed)
    c = _objective(p)
    bf = brute_force_solve(p, c)
    rule = BranchRule.PSEUDO_COST if seed % 2 else BranchRule.MOST_FRACTIONAL
    res = solve(
        p, c, rule=rule, node_cap=64, max_levels=32, sync_every=8,
        use_pallas=False,
    )
    assert res.feasible == bf.feasible
    assert res.objective == bf.objective  # bitwise, per the module contract
    _check_accounting(res)
    if res.feasible:
        assert res.status == "optimal"
        assert float(c @ res.x) == bf.objective
        _assert_solution_feasible(p, res.x)
    else:
        assert res.status == "infeasible"
        assert res.x is None


@pytest.mark.parametrize("seed", MIP_SEEDS)
def test_differential_random_mip(seed):
    p = make_random_mip(n=9, m=12, seed=seed)
    c = _objective(p)
    bf = brute_force_solve(p, c)
    res = solve(
        p, c, node_cap=128, max_levels=48, sync_every=8, use_pallas=False,
    )
    assert res.feasible == bf.feasible
    if bf.feasible:
        assert res.objective == bf.objective
        assert float(c @ res.x) == bf.objective
        _assert_solution_feasible(p, res.x)
    _check_accounting(res)


def test_infeasible_at_root():
    # x0 >= 1 and x0 <= 0: root propagation crosses the bounds immediately.
    dense = np.array([[1.0, 0.0], [1.0, 0.0], [0.0, 1.0]])
    p = Problem(
        csr=csr_from_dense(dense),
        lhs=np.array([1.0, -INF, -INF]),
        rhs=np.array([INF, 0.0, 1.0]),
        lb=np.zeros(2),
        ub=np.ones(2),
        is_int=np.ones(2, bool),
    )
    c = np.array([1.0, 1.0])
    bf = brute_force_solve(p, c)
    assert not bf.feasible
    res = solve(p, c, node_cap=8, max_levels=8, use_pallas=False)
    assert res.status == "infeasible"
    assert not res.feasible
    assert res.x is None
    assert res.nodes_expanded == 0
    assert res.pruned_infeasible == 1
    assert res.levels == 1
    assert res.host_syncs == 1


def test_optimal_at_root():
    # Equality rows fix every variable at the root fixed point: the search
    # finds the incumbent at level 1 without expanding a single node.
    dense = np.array([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]])
    sides = np.array([1.0, 2.0, 0.0])
    p = Problem(
        csr=csr_from_dense(dense),
        lhs=sides,
        rhs=sides,
        lb=np.zeros(3),
        ub=np.full(3, 3.0),
        is_int=np.ones(3, bool),
    )
    c = np.array([1.0, -1.0, 2.0])
    bf = brute_force_solve(p, c)
    res = solve(p, c, node_cap=8, max_levels=8, use_pallas=False)
    assert res.status == "optimal"
    assert res.objective == bf.objective == -1.0
    np.testing.assert_array_equal(res.x, sides)
    assert res.nodes_expanded == 0
    assert res.leaves == 1
    assert res.levels == 1
    assert res.host_syncs == 1


# ---------------------------------------------------------------------------
# Host-sync contract + pool accounting at every sync.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sync_every", [2, 8])
def test_host_sync_contract(sync_every):
    p = make_pseudo_boolean(n=12, m=16, seed=0)
    c = _objective(p)
    calls = []
    res = solve(
        p, c, node_cap=64, max_levels=32, sync_every=sync_every,
        use_pallas=False, on_sync=calls.append,
    )
    # Every host consultation goes through on_sync: the dispatch count IS
    # the sync count, bounded by ceil(levels / sync_every).
    assert len(calls) == res.host_syncs
    assert res.host_syncs <= max(1, math.ceil(res.levels / sync_every))
    assert len(res.incumbent_trajectory) == res.host_syncs
    assert calls[-1]["done"]
    for snap in calls:
        # Statuses tile the pool: no slot leaks or double-allocations.
        assert snap["open"] + snap["ready"] + snap["free"] == 64
    # Fate partition at the final sync: every created node is alive or has
    # exactly one recorded fate.
    last = calls[-1]
    alive = last["open"] + last["ready"]
    assert alive == (
        res.nodes_created
        - res.nodes_expanded
        - res.leaves
        - res.pruned_bound
        - res.pruned_infeasible
    )


def test_sync_every_one_syncs_every_level():
    p = make_pseudo_boolean(n=12, m=16, seed=1)
    c = _objective(p)
    calls = []
    res = solve(
        p, c, node_cap=64, max_levels=32, sync_every=1, use_pallas=False,
        on_sync=calls.append,
    )
    assert res.host_syncs == res.levels == len(calls)
    levels = [snap["levels"] for snap in calls]
    assert levels == list(range(1, res.levels + 1))


# ---------------------------------------------------------------------------
# Determinism: bit-identical reruns, both rules.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "rule", [BranchRule.MOST_FRACTIONAL, BranchRule.PSEUDO_COST]
)
def test_determinism(rule):
    p = make_pseudo_boolean(n=12, m=16, seed=3)
    c = _objective(p)
    kw = dict(
        rule=rule, node_cap=64, max_levels=32, sync_every=2,
        use_pallas=False, telemetry=8,
    )
    r1 = solve(p, c, **kw)
    r2 = solve(p, c, **kw)
    assert r1.incumbent_trajectory == r2.incumbent_trajectory
    assert r1.objective == r2.objective
    assert (
        r1.nodes_expanded, r1.nodes_created, r1.leaves,
        r1.pruned_bound, r1.pruned_infeasible, r1.levels, r1.host_syncs,
    ) == (
        r2.nodes_expanded, r2.nodes_created, r2.leaves,
        r2.pruned_bound, r2.pruned_infeasible, r2.levels, r2.host_syncs,
    )
    if r1.feasible:
        np.testing.assert_array_equal(r1.x, r2.x)
    np.testing.assert_array_equal(
        r1.telemetry.progress_history(), r2.telemetry.progress_history()
    )


# ---------------------------------------------------------------------------
# Search properties: hypothesis when available, seeded draws always.
# ---------------------------------------------------------------------------

def _check_branch_children_partition(seed, n, value):
    """Down/up children tile the parent's integer domain on the branching
    variable exactly: disjoint, and their union is the parent domain."""
    rng = np.random.default_rng(seed)
    lb = rng.integers(-3, 1, n).astype(np.float64)
    ub = lb + rng.integers(1, 5, n)
    var = int(rng.integers(0, n))
    value = float(np.clip(lb[var] + value % (ub[var] - lb[var]), lb[var],
                          ub[var] - 1.0))
    (dlb, dub), (ulb, uub) = branch_children(lb, ub, var, value)
    f = math.floor(value)
    # Unbranched variables untouched.
    mask = np.arange(n) != var
    np.testing.assert_array_equal(dlb[mask], lb[mask])
    np.testing.assert_array_equal(uub[mask], ub[mask])
    np.testing.assert_array_equal(dub[mask], ub[mask])
    np.testing.assert_array_equal(ulb[mask], lb[mask])
    parent = set(range(int(lb[var]), int(ub[var]) + 1))
    down = set(range(int(dlb[var]), int(dub[var]) + 1))
    up = set(range(int(ulb[var]), int(uub[var]) + 1))
    assert down == {v for v in parent if v <= f}
    assert up == {v for v in parent if v >= f + 1}
    assert down | up == parent
    assert not (down & up)


def _check_plan_expansion(seed, cap):
    """Slot planning pairs distinct READY parents with distinct FREE
    children, exactly min(#READY, #FREE) of each, sentinel ``cap``
    beyond -- so ``mode='drop'`` scatters can neither leak a slot nor
    write one twice."""
    rng = np.random.default_rng(seed)
    status = rng.choice([FREE, OPEN, READY], size=cap).astype(np.int32)
    depth = rng.integers(0, 6, cap).astype(np.int32)
    nbound = rng.integers(-9, 9, cap).astype(np.float64)
    parent, child, k, n_ready, n_free = (
        np.asarray(a)
        for a in _plan_expansion(
            jnp.asarray(status), jnp.asarray(depth), jnp.asarray(nbound)
        )
    )
    k = int(k)
    assert int(n_ready) == int((status == READY).sum())
    assert int(n_free) == int((status == FREE).sum())
    assert k == min(int(n_ready), int(n_free))
    pk, ck = parent[:k], child[:k]
    assert len(set(pk.tolist())) == k  # no parent expanded twice
    assert len(set(ck.tolist())) == k  # no slot allocated twice
    assert all(status[i] == READY for i in pk)
    assert all(status[i] == FREE for i in ck)
    assert (parent[k:] == cap).all()  # unused ranks carry the drop sentinel
    assert (child[k:] == cap).all()
    # Deterministic priority: deepest-first, then best bound, then slot id.
    keys = [(-int(depth[i]), float(nbound[i]), int(i)) for i in pk]
    assert keys == sorted(keys)
    assert ck.tolist() == sorted(np.nonzero(status == FREE)[0][:k].tolist())


@pytest.mark.parametrize("seed", range(10))
def test_branch_children_partition_domain(seed):
    rng = np.random.default_rng(1000 + seed)
    _check_branch_children_partition(
        seed, int(rng.integers(2, 13)), float(rng.uniform(-3.0, 3.0))
    )


@pytest.mark.parametrize("seed", range(10))
def test_plan_expansion_never_leaks_or_double_allocates(seed):
    rng = np.random.default_rng(2000 + seed)
    _check_plan_expansion(seed, int(rng.integers(4, 33)))


def test_expand_width_clamps_plan():
    """``width`` caps the wave at the TOP of the deepest-first priority:
    the clamped plan is exactly the unlimited plan's prefix."""
    rng = np.random.default_rng(42)
    cap = 24
    status = jnp.asarray(
        rng.choice([FREE, OPEN, READY], size=cap).astype(np.int32)
    )
    depth = jnp.asarray(rng.integers(0, 6, cap).astype(np.int32))
    nbound = jnp.asarray(rng.integers(-9, 9, cap).astype(np.float64))
    full = [np.asarray(a) for a in _plan_expansion(status, depth, nbound)]
    for width in (1, 2, 3):
        parent, child, k, n_ready, n_free = (
            np.asarray(a)
            for a in _plan_expansion(status, depth, nbound, width=width)
        )
        k = int(k)
        assert k == min(int(n_ready), int(n_free), width)
        assert int(n_ready) == int(full[3]) and int(n_free) == int(full[4])
        np.testing.assert_array_equal(parent[:k], full[0][:k])
        np.testing.assert_array_equal(child[:k], full[1][:k])
        assert (parent[k:] == cap).all() and (child[k:] == cap).all()


@pytest.mark.parametrize("seed", [0, 3])
def test_expand_width_beam_is_exact(seed):
    """A narrow DFS beam (un-expanded READY nodes wait, nothing is
    dropped) reaches the SAME proven optimum as the unlimited frontier --
    the property the Python driver's frontier truncation does not have."""
    p = make_pseudo_boolean(n=12, m=16, seed=seed)
    c = _objective(p)
    wide = solve(p, c, node_cap=64, max_levels=256, sync_every=8,
                 use_pallas=False)
    beam = solve(p, c, node_cap=16, max_levels=256, sync_every=8,
                 expand_width=2, use_pallas=False)
    assert wide.status == "optimal"
    assert beam.status == "optimal"
    assert beam.objective == wide.objective
    _check_accounting(beam)
    if beam.feasible:
        _assert_solution_feasible(p, beam.x)


if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=20, deadline=None)

    @given(
        st.integers(0, 2**31 - 1), st.integers(2, 12), st.floats(-3.0, 3.0)
    )
    @settings(**SETTINGS)
    def test_branch_children_partition_domain_hyp(seed, n, value):
        _check_branch_children_partition(seed, n, value)

    @given(st.integers(0, 2**31 - 1), st.integers(4, 32))
    @settings(**SETTINGS)
    def test_plan_expansion_hyp(seed, cap):
        _check_plan_expansion(seed, cap)


@pytest.mark.parametrize("seed", [0, 2, 5])
def test_pruning_only_removes_suboptimal_subtrees(seed):
    """prune_gap=-INF disables bound pruning: the search expands at least
    as many nodes, finds the SAME optimum, and never bound-prunes --
    i.e. pruned subtrees provably contained no better incumbent."""
    p = make_pseudo_boolean(n=8, m=12, seed=seed)
    c = _objective(p)
    on = solve(
        p, c, node_cap=512, max_levels=32, use_pallas=False, prune_gap=0.0
    )
    off = solve(
        p, c, node_cap=512, max_levels=32, use_pallas=False, prune_gap=-INF
    )
    assert on.status == off.status == "optimal"
    assert on.objective == off.objective
    assert off.pruned_bound == 0
    assert on.nodes_expanded <= off.nodes_expanded
    assert on.nodes_created <= off.nodes_created
    _check_accounting(on)
    _check_accounting(off)


# ---------------------------------------------------------------------------
# Kernel vs reference: the node-objective oracle, bitwise.
# ---------------------------------------------------------------------------

def test_node_objective_kernel_matches_ref(rng):
    bsz, n_pad, n = 16, 24, 19
    lb = rng.integers(-4, 2, (bsz, n_pad)).astype(np.float64)
    ub = lb + rng.integers(0, 4, (bsz, n_pad))
    # A few unbounded and a few crossed rows exercise every predicate.
    lb[3, 2] = -INF
    ub[4, 5] = INF
    lb[6, 7] = ub[6, 7] + 1.0
    c = rng.integers(-5, 6, n_pad).astype(np.float64)
    valid = np.zeros(n_pad, bool)
    valid[:n] = True
    is_int = valid.copy()
    args = (
        jnp.asarray(lb), jnp.asarray(ub), jnp.asarray(c),
        jnp.asarray(is_int), jnp.asarray(valid), 1e-8,
    )
    obj_r, fix_r, cr_r = node_objective_ref(*args)
    obj_k, fix_k, cr_k = node_objective_tiles(*args, interpret=True)
    np.testing.assert_array_equal(np.asarray(obj_k), np.asarray(obj_r))
    np.testing.assert_array_equal(np.asarray(fix_k), np.asarray(fix_r))
    np.testing.assert_array_equal(np.asarray(cr_k), np.asarray(cr_r))
    assert bool(np.asarray(cr_r)[6])


# ---------------------------------------------------------------------------
# Deeper search: invariants at scale (marked `solver`).
# ---------------------------------------------------------------------------

@pytest.mark.solver
def test_deep_search_invariants():
    p = make_pseudo_boolean(n=40, m=56, seed=7)
    c = _objective(p)
    calls = []
    res = solve(
        p, c, node_cap=512, max_levels=64, sync_every=8,
        use_pallas=False, telemetry=64, on_sync=calls.append,
    )
    assert res.status in ("optimal", "infeasible", "pool_exhausted",
                          "level_limit")
    _check_accounting(res)
    assert res.host_syncs <= max(1, math.ceil(res.levels / 8))
    assert res.telemetry.rounds_recorded == res.levels
    if res.feasible:
        _assert_solution_feasible(p, res.x)
        assert float(c @ res.x) == res.objective
