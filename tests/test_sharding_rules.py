"""Mesh-independent sharding-rule checks: every parameter / cache / batch
dimension that a rule shards must divide the production mesh axis sizes.
These catch config regressions without compiling anything (no devices)."""
import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.sharding import (
    _path_str,
    cache_spec,
    spec_for_param,
)
from repro.models import SHAPES, cell_supported, input_specs
from repro.models.transformer import init_params

AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}
AXIS_SIZES_MULTI = {"pod": 2, "data": 32, "model": 16}  # data widened by pod


def _check_divisible(spec, shape, ctx):
    for dim, ax in zip(shape, tuple(spec)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axes:
            total *= AXIS_SIZES[a]
        assert dim % total == 0, f"{ctx}: dim {dim} not divisible by {axes}={total}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    n_sharded = 0
    for path, leaf in flat:
        ps = _path_str(path)
        spec = spec_for_param(ps, len(leaf.shape))
        _check_divisible(spec, leaf.shape, f"{arch}:{ps}")
        if any(ax is not None for ax in tuple(spec)):
            n_sharded += 1
    # The bulk of parameters must actually be sharded.
    assert n_sharded >= len(flat) // 3, f"{arch}: too few sharded params"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_no_replicated_giants(arch):
    """No parameter >64MB may be fully replicated on the production mesh."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        ps = _path_str(path)
        spec = spec_for_param(ps, len(leaf.shape))
        import math

        bytes_ = math.prod(leaf.shape) * 4
        if bytes_ > 64 * 2**20:
            assert any(ax is not None for ax in tuple(spec)), (
                f"{arch}:{ps} ({bytes_/2**20:.0f} MiB) replicated"
            )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if shape.kind != "decode" or not cell_supported(cfg, shape)[0]:
            continue
        specs = input_specs(cfg, shape)
        flat = jax.tree_util.tree_flatten_with_path(specs["cache"])[0]
        for path, leaf in flat:
            ps = "cache/" + _path_str(path)
            spec = cache_spec(cfg, ps, leaf.shape, ("data",))
            _check_divisible(spec, leaf.shape, f"{arch}:{shape.name}:{ps}")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_dims_divisible(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if not cell_supported(cfg, shape)[0]:
            continue
        gb = shape.global_batch
        if gb > 1:
            assert gb % 16 == 0 and gb % 32 == 0 or gb % 16 == 0, (
                f"{shape.name}: batch {gb}"
            )


def test_vocab_padding_divisible():
    from repro.models.transformer import padded_vocab

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert padded_vocab(cfg) % 256 == 0
        assert padded_vocab(cfg) >= cfg.vocab_size
