"""Continuous-batching service: slot lifecycle, bitwise retire/backfill,
AOT-warmed engines, occupancy-masked device loop, stats endpoint.

The load-bearing guarantees:
  * admit -> converge -> retire -> backfill leaves every instance's bounds
    BITWISE identical to a fresh one-shot ``propagate_batch`` of the same
    instance with the same tile parameters (exact-arithmetic families; the
    general-float family is pinned to reassociation-ulp agreement plus
    exact round counts -- see the ``core.service`` module docstring);
  * backfill never compiles (compile-trace counts frozen after warmup,
    engine LRU hits on same-shape reconstruction);
  * retirement/backfill happen while a slow co-resident instance is still
    iterating -- the device loop is never stopped for slot turnover;
  * ``batched_step_rounds`` chunked by any budget reproduces the one-call
    fixed point bit-for-bit (the service step primitive);
  * the stats endpoint surfaces the same per-bucket occupancy/padding
    histogram shape as ``batch_stats``.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    INF,
    BucketSpec,
    PropagationService,
    batched_fixed_point,
    batched_step_rounds,
    evict_slot,
    pack_into_slot,
    propagate_batch,
)
from repro.data import make_cascade_chain, make_knapsack, make_mixed, make_set_cover


def _one_shot(p, tile_rows=8, tile_width=8):
    """The fixed-batch reference path the service must reproduce."""
    return propagate_batch(
        [p], tile_rows=tile_rows, tile_width=tile_width, use_pallas=False
    )[0]


def _assert_bitwise(r, one):
    np.testing.assert_array_equal(r.lb, np.asarray(one.lb))
    np.testing.assert_array_equal(r.ub, np.asarray(one.ub))
    assert r.rounds == int(one.rounds)
    assert r.converged == bool(one.converged)
    assert r.infeasible == bool(one.infeasible)


SET_COVERS = [make_set_cover(n=60, m=20, seed=s) for s in range(6)]


@pytest.fixture(scope="module")
def sc_service():
    """Two-slot multichunk bucket (tile_width 8 < longest set-cover row):
    six instances through two slots forces retire->backfill recycling."""
    return PropagationService.from_problems(SET_COVERS, slots=2, tile_width=8)


# ---------------------------------------------------------------------------
# Slot-granular packing
# ---------------------------------------------------------------------------


def test_pack_into_slot_invariants():
    p = make_set_cover(n=60, m=20, seed=0)
    pay = pack_into_slot(p, slot_tiles=12, slot_rows=30, n_pad=128, tile_width=8)
    assert pay.val.shape == (12, 8, 8) and pay.n_pad == 128
    assert 0 < pay.tiles_used <= 12
    # Unused trailing tiles are all padding parked on the instance's dummy row.
    tail = slice(pay.tiles_used, None)
    assert (pay.val[tail] == 0).all() and (pay.chunk_row[tail] == p.m).all()
    assert (pay.ii[pay.val == 0] == 0).all()
    assert 0 < pay.fill() <= 1.0
    # Bounds plane zero-padded past n.
    assert (pay.lb[p.n:] == 0).all() and (pay.ub[p.n:] == 0).all()
    with pytest.raises(ValueError):
        pack_into_slot(p, slot_tiles=1, slot_rows=30, n_pad=128, tile_width=8)
    with pytest.raises(ValueError):
        pack_into_slot(p, slot_tiles=12, slot_rows=5, n_pad=128, tile_width=8)


def test_evict_slot_is_all_padding():
    pay = evict_slot(slot_tiles=3, slot_rows=10, n_pad=128, tile_width=8)
    assert (pay.val == 0).all() and pay.nnz == 0 and pay.tiles_used == 0
    assert (pay.chunk_row == 10).all()  # the slot's own dummy row
    assert pay.fill() == 0.0


def test_bucket_spec_routing():
    spec = BucketSpec(
        n_pad=128, slots=2, slot_tiles=8, slot_rows=25,
        tile_width=8, fits_one_chunk=False,
    )
    assert spec.fits_problem(make_set_cover(n=60, m=20, seed=0))
    assert not spec.fits_problem(make_mixed(m=120, n=100, seed=0))  # m too big
    assert not spec.fits_problem(make_mixed(m=20, n=200, seed=0))   # n too big
    pay = spec.pack(make_set_cover(n=60, m=20, seed=0))
    assert spec.admits(pay)
    other = pack_into_slot(
        make_set_cover(n=60, m=20, seed=0),
        slot_tiles=9, slot_rows=25, n_pad=128, tile_width=8,
    )
    assert not spec.admits(other)  # wrong slot shape
    svc = PropagationService([spec], use_pallas=False)
    with pytest.raises(ValueError):
        svc.submit(make_mixed(m=120, n=100, seed=0))


def test_for_problems_size_classes():
    """Quantile sub-buckets: small instances route to tight slots instead
    of inheriting the population max, every sampled instance still fits
    some spec, and serving through the size-classed pool stays bitwise."""
    small = [make_set_cover(n=60, m=20, seed=s) for s in range(3)]
    big = [make_cascade_chain(length=100 + s) for s in range(3)]
    pop = small + big
    flat = BucketSpec.for_problems(pop, slots=2, tile_width=8)
    split = BucketSpec.for_problems(
        pop, slots=2, tile_width=8, size_classes=2
    )
    assert len(split) > len(flat)
    for npad in {s.n_pad for s in split}:
        group = [s for s in split if s.n_pad == npad]
        tiles = [s.slot_tiles for s in group]
        assert tiles == sorted(tiles)  # tightest-first routing order
        rows = [s.slot_rows for s in group]
        assert rows == sorted(rows, reverse=True)  # suffix-max row caps
    for p in pop:
        assert any(s.fits_problem(p) for s in split)
    # A small instance lands in a strictly tighter slot than the flat pool
    # (whose capacity is the population max).
    tight = next(s for s in split if s.fits_problem(small[0]))
    wide = next(s for s in flat if s.fits_problem(small[0]))
    assert tight.slot_tiles < wide.slot_tiles
    svc = PropagationService(split, use_pallas=False)
    for p, r in zip(pop, svc.serve(pop)):
        _assert_bitwise(r, _one_shot(p))


# ---------------------------------------------------------------------------
# The service step primitive
# ---------------------------------------------------------------------------


def test_batched_step_rounds_matches_fixed_point_bitwise():
    """Chunking the fixed point by ANY budget cannot change the carried
    trajectory: resumed steps land bit-for-bit on the one-call result."""
    lb0 = jnp.zeros((3, 4))
    ub0 = jnp.asarray(np.array([[5.3] * 4, [1.1] * 4, [0.0] * 4]))

    def round_fn(lb, ub, active):
        new_ub = jnp.maximum(lb, ub - 0.7)
        new_ub = jnp.where(active[:, None], new_ub, ub)
        return lb, new_ub, jnp.any(new_ub != ub, axis=-1)

    lb_f, ub_f, rounds_f, conv_f = batched_fixed_point(round_fn, lb0, ub0, 100)
    for budget in (1, 3, 7):
        active = jnp.ones(3, bool)
        state = (lb0, ub0, active, active, jnp.zeros(3, jnp.int32))
        while bool(jnp.any(state[2])):
            state = batched_step_rounds(round_fn, *state, 100, budget=budget)
        np.testing.assert_array_equal(np.asarray(state[0]), np.asarray(lb_f))
        np.testing.assert_array_equal(np.asarray(state[1]), np.asarray(ub_f))
        np.testing.assert_array_equal(np.asarray(state[4]), np.asarray(rounds_f))
        np.testing.assert_array_equal(~np.asarray(state[3]), np.asarray(conv_f))


# ---------------------------------------------------------------------------
# Lifecycle: admit -> converge -> retire -> backfill, bitwise
# ---------------------------------------------------------------------------


def test_lifecycle_backfill_bitwise_multichunk(sc_service):
    """Six instances through two slots (multichunk jnp round): every result
    -- including the backfilled ones -- bitwise vs one-shot propagate_batch."""
    before = sc_service.stats()["retired"]
    results = sc_service.serve(SET_COVERS)
    for p, r in zip(SET_COVERS, results):
        _assert_bitwise(r, _one_shot(p, tile_width=8))
    assert sc_service.stats()["retired"] == before + len(SET_COVERS)


def test_lifecycle_backfill_bitwise_fused():
    """Same contract through the fused (chunk-complete) engine path."""
    probs = [make_knapsack(n=60, m=20, seed=s) for s in range(5)]
    svc = PropagationService.from_problems(probs, slots=2, tile_width=128)
    assert svc._buckets[0].spec.fits_one_chunk
    for p, r in zip(probs, svc.serve(probs)):
        _assert_bitwise(r, _one_shot(p, tile_width=128))


def test_lifecycle_general_floats_reassociation_ulps():
    """General-coefficient family: the service's runtime-argument graphs may
    reassociate reductions differently from the one-shot jit-constant
    graphs, so agreement is pinned to ulps -- but round trajectories and
    verdicts must match exactly."""
    probs = [make_mixed(m=60, n=50, seed=s) for s in range(4)]
    svc = PropagationService.from_problems(probs, slots=2, tile_width=8)
    for p, r in zip(probs, svc.serve(probs)):
        one = _one_shot(p, tile_width=8)
        np.testing.assert_allclose(r.lb, np.asarray(one.lb), rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(r.ub, np.asarray(one.ub), rtol=1e-12, atol=1e-12)
        assert r.rounds == int(one.rounds)
        assert r.converged == bool(one.converged)
        assert r.infeasible == bool(one.infeasible)


def test_backfill_never_compiles(sc_service):
    """Steady state is compile-free: the compiled-trace counts of the step
    and every admission engine are frozen after construction-time warmup,
    across a full serve with slot recycling -- and a same-shape service
    reconstruction is an engine-cache HIT (no rebuild either)."""
    cc0 = sc_service.compile_counts()
    for counts in cc0.values():
        assert counts["step"] == 1
        assert all(c == 1 for c in counts["admit"].values())
    sc_service.serve(SET_COVERS)
    assert sc_service.compile_counts() == cc0
    hits0 = sc_service.stats()["engine_cache"]["hits"]
    PropagationService.from_problems(SET_COVERS, slots=2, tile_width=8)
    assert sc_service.stats()["engine_cache"]["hits"] > hits0


def test_retire_backfill_while_slow_instance_resident():
    """One slow cascade + four 1-round instances through two slots: the
    fast slots turn over (retire + backfill) while the cascade is STILL
    resident and iterating -- slot turnover never stops the device loop."""
    slow = make_cascade_chain(24)
    free = [
        p._replace(lhs=np.full(p.m, -INF), rhs=np.full(p.m, INF))
        for p in (make_set_cover(n=60, m=20, seed=s) for s in range(4))
    ]
    svc = PropagationService.from_problems(
        [slow] + free, slots=2, tile_width=8, rounds_per_step=4
    )
    slow_t = svc.submit(slow)
    fast_ts = [svc.submit(p) for p in free]
    while not slow_t.done():
        svc.pump()
    svc.drain()
    # The cascade ran many budgeted steps; the fast instances all finished
    # first, and the last of them was ADMITTED after the first RETIRED
    # (true backfill) while the cascade had not yet retired.
    assert slow_t.result().rounds > 20
    assert all(t.done_t < slow_t.done_t for t in fast_ts)
    assert fast_ts[-1].admit_t > fast_ts[0].done_t
    assert fast_ts[-1].admit_t < slow_t.done_t
    for p, t in zip([slow] + free, [slow_t] + fast_ts):
        _assert_bitwise(t.result(), _one_shot(p, tile_width=8))


# ---------------------------------------------------------------------------
# Stats endpoint + tickets
# ---------------------------------------------------------------------------


def test_stats_endpoint_histogram(sc_service):
    """Mid-flight stats surface the batch_stats-shaped occupancy/padding
    histogram over the RESIDENT instances."""
    tickets = [sc_service.submit(p) for p in SET_COVERS[:3]]
    sc_service.pump()  # admissions land; nothing may retire mid-flight check
    st = sc_service.stats()
    bk = st["buckets"][0]
    hist = bk["histogram"]
    assert set(hist) == {
        "n_pad", "instances", "tiles", "tile_rows", "tile_width",
        "nnz", "padded_slots", "fill", "padding_fraction",
    }
    if bk["occupied"]:  # the 1-round instances may all have retired already
        assert hist["instances"] == bk["occupied"]
        assert 0.0 < hist["fill"] <= 1.0
        assert hist["fill"] + hist["padding_fraction"] == pytest.approx(1.0)
    assert 0.0 < bk["mean_occupancy"] <= 1.0
    assert {"hits", "misses", "size", "maxsize"} <= set(st["engine_cache"])
    assert "batch_runner" in st["kernel_caches"]
    sc_service.drain()
    st = sc_service.stats()
    assert st["occupied"] == 0 and st["pending"] == 0
    assert all(t.done() for t in tickets)


def test_ticket_timeout_and_latency(sc_service):
    t = sc_service.submit(SET_COVERS[0])
    with pytest.raises(TimeoutError):
        t.result(timeout=0.01)
    assert t.latency() is None
    sc_service.drain()
    assert t.done() and t.latency() >= 0.0
    assert t.admit_t >= t.submit_t and t.done_t >= t.admit_t


# ---------------------------------------------------------------------------
# Concurrency: background loop + thread-safe caches
# ---------------------------------------------------------------------------


def test_background_loop_with_concurrent_submitters():
    """Background device-loop thread + several client threads submitting
    concurrently: every ticket resolves, results bitwise vs one-shot."""
    probs = [make_set_cover(n=60, m=20, seed=100 + s) for s in range(9)]
    svc = PropagationService.from_problems(probs, slots=2, tile_width=8)
    tickets = {}
    lock = threading.Lock()

    def client(chunk):
        for i, p in chunk:
            t = svc.submit(p)
            with lock:
                tickets[i] = t

    chunks = [list(enumerate(probs))[i::3] for i in range(3)]
    with svc:  # starts/stops the background pump thread
        workers = [threading.Thread(target=client, args=(c,)) for c in chunks]
        for w in workers:
            w.start()
        for w in workers:
            w.join()
        results = {i: t.result(timeout=300) for i, t in tickets.items()}
    assert len(results) == len(probs)
    for i, p in enumerate(probs):
        _assert_bitwise(results[i], _one_shot(p, tile_width=8))


def test_lru_cache_thread_safety_hammer():
    """The engine LRU caches are shared between the admission worker and
    the device loop: hammer one from many threads and check the counters
    stayed consistent (satellite: thread-safe LRU)."""
    from repro.kernels.ops import LRU

    lru = LRU(maxsize=8)
    gets = 400
    threads = 8

    def worker(tid):
        for i in range(gets):
            key = ("k", (tid * i) % 16)
            if lru.get(key, ()) is None:
                lru.put(key, (), tid * 1000 + i)
            lru.info()
            len(lru)

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    info = lru.info()
    assert info["hits"] + info["misses"] == threads * gets
    assert info["size"] <= 8
