"""Unit tests for the core domain-propagation engine (paper §1.1, §3.4)."""
import numpy as np

from repro.core import (
    INF,
    Problem,
    PropagatorConfig,
    analyze_constraints,
    bounds_equal,
    csr_from_dense,
    propagate,
    propagate_sequential,
)
from repro.core.propagator import DeviceProblem
from repro.data import make_cascade_chain, make_knapsack


def _prob(A, lhs, rhs, lb, ub, is_int=None):
    A = np.asarray(A, dtype=np.float64)
    n = A.shape[1]
    return Problem(
        csr=csr_from_dense(A),
        lhs=np.asarray(lhs, dtype=np.float64),
        rhs=np.asarray(rhs, dtype=np.float64),
        lb=np.asarray(lb, dtype=np.float64),
        ub=np.asarray(ub, dtype=np.float64),
        is_int=(np.zeros(n, dtype=bool) if is_int is None else np.asarray(is_int)),
    )


class TestHandComputed:
    def test_knapsack_row(self):
        # 2x + 3y <= 6, x,y in [0,10] integer  =>  x <= 3, y <= 2
        p = _prob([[2.0, 3.0]], [-INF], [6.0], [0, 0], [10, 10], [True, True])
        for driver in ("host_loop", "device_loop", "unrolled"):
            r = propagate(p, driver=driver)
            np.testing.assert_allclose(np.asarray(r.ub), [3.0, 2.0])
            np.testing.assert_allclose(np.asarray(r.lb), [0.0, 0.0])
            assert bool(r.converged) and not bool(r.infeasible)

    def test_lower_side(self):
        # x + y >= 8 with x <= 3  =>  y >= 5
        p = _prob([[1.0, 1.0]], [8.0], [INF], [0, 0], [3, 10])
        r = propagate(p)
        np.testing.assert_allclose(np.asarray(r.lb), [0.0, 5.0])

    def test_negative_coefficient(self):
        # x - y <= 0, x in [2,10], y in [0,5]  =>  x <= 5, y >= 2
        p = _prob([[1.0, -1.0]], [-INF], [0.0], [2, 0], [10, 5])
        r = propagate(p)
        np.testing.assert_allclose(np.asarray(r.ub), [5.0, 5.0])
        np.testing.assert_allclose(np.asarray(r.lb), [2.0, 2.0])

    def test_integer_rounding(self):
        # 2x <= 5, x integer => x <= 2 (floor of 2.5)
        p = _prob([[2.0]], [-INF], [5.0], [0], [10], [True])
        r = propagate(p)
        np.testing.assert_allclose(np.asarray(r.ub), [2.0])

    def test_infeasible_detection(self):
        # x + y <= 1 with x,y >= 1 => infeasible after propagation
        p = _prob([[1.0, 1.0]], [-INF], [1.0], [1, 1], [10, 10])
        r = propagate(p)
        assert bool(r.infeasible)
        rs = propagate_sequential(p)
        assert rs.infeasible

    def test_equality_row_fixing(self):
        # x + y == 4, x in [0,1] => y in [3,4]
        p = _prob([[1.0, 1.0]], [4.0], [4.0], [0, 0], [1, 10])
        r = propagate(p)
        np.testing.assert_allclose(np.asarray(r.lb), [0.0, 3.0])
        np.testing.assert_allclose(np.asarray(r.ub), [1.0, 4.0])


class TestInfinityHandling:
    """Paper §3.4: residual activities with infinite contributions."""

    def test_single_infinite_bound_still_propagates(self):
        # x + y <= 5, y unbounded above: residual for y is finite => y <= 5-lx
        p = _prob([[1.0, 1.0]], [-INF], [5.0], [1, 0], [2, INF])
        r = propagate(p)
        # y's candidate uses residual min-activity of x = 1 => y <= 4
        np.testing.assert_allclose(np.asarray(r.ub), [2.0, 4.0])

    def test_two_infinite_bounds_no_tightening(self):
        # x + y <= 5 with both unbounded above: no upper bound deducible for
        # either (residuals infinite), lower bounds unaffected.
        p = _prob([[1.0, 1.0]], [-INF], [5.0], [0, 0], [INF, INF])
        r = propagate(p)
        # each var: residual min activity = other's lb = 0 -> cand 5
        np.testing.assert_allclose(np.asarray(r.ub), [5.0, 5.0])

    def test_all_infinite(self):
        p = _prob([[1.0, 1.0]], [-INF], [5.0], [-INF, -INF], [INF, INF])
        r = propagate(p)
        # residuals are -inf (other var unbounded below) -> no tightening
        assert np.all(np.asarray(r.ub) >= INF)

    def test_seq_matches_parallel_on_inf(self):
        p = _prob(
            [[1.0, 2.0, -1.0], [1.0, 0.0, 3.0]],
            [-INF, 1.0],
            [4.0, INF],
            [0, -INF, 0],
            [INF, 5, INF],
        )
        a = propagate_sequential(p)
        b = propagate(p)
        assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


class TestPresolveVerdicts:
    def test_redundant_and_infeasible(self):
        p = _prob(
            [[1.0, 1.0], [1.0, 1.0]],
            [-INF, -INF],
            [100.0, -50.0],
            [0, 0],
            [10, 10],
        )
        dp = DeviceProblem(p)
        v = analyze_constraints(
            dp.row_id, dp.val, dp.col, dp.lhs, dp.rhs, dp.lb0, dp.ub0, p.m
        )
        assert bool(v.redundant[0])      # max activity 20 <= 100
        assert bool(v.infeasible[1])     # min activity 0 > -50
        assert bool(v.any_infeasible)


class TestDrivers:
    def test_all_drivers_same_result(self):
        p = make_knapsack(n=30, m=8, seed=5)
        results = [propagate(p, driver=d) for d in ("host_loop", "device_loop", "unrolled")]
        for r in results[1:]:
            assert bounds_equal(results[0].lb, results[0].ub, r.lb, r.ub)

    def test_cascade_round_inflation(self):
        """§2.2: cascade chain needs ~m parallel rounds but few sequential."""
        p = make_cascade_chain(length=24)
        rs = propagate_sequential(p)
        rp = propagate(p, driver="device_loop")
        assert rs.rounds <= 3
        assert int(rp.rounds) >= 24
        assert bounds_equal(rs.lb, rs.ub, rp.lb, rp.ub)

    def test_round_cap_respected(self):
        p = make_cascade_chain(length=64)
        cfg = PropagatorConfig(max_rounds=10)
        r = propagate(p, cfg=cfg)
        assert int(r.rounds) <= 10 + 1
        assert not bool(r.converged)

    def test_no_marking_seq_same_limit(self):
        p = make_knapsack(n=25, m=6, seed=2)
        a = propagate_sequential(p, use_marking=True)
        b = propagate_sequential(p, use_marking=False)
        assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


class TestBoundsEqual:
    def test_tolerance(self):
        assert bounds_equal([1.0], [2.0], [1.0 + 1e-9], [2.0 - 1e-9])
        assert not bounds_equal([1.0], [2.0], [1.1], [2.0])

    def test_infinities_equal(self):
        assert bounds_equal([-INF], [INF], [-INF * 1.0], [INF])
