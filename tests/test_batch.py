"""Batched multi-instance propagation: packing, kernels, drivers.

Four layers:
  * packing: flat super-tile structure (per-instance row/col offsets,
    contiguous tile streams, index round-trip);
  * acceptance: ``propagate_batch`` over a bucket of >= 8 Set-2 instances is
    BITWISE identical to per-instance ``scatter='fused'`` runs;
  * convergence mask: a batch mixing a 1-round instance with a many-round
    instance converges each to its own fixed point (own round count, no
    cross-instance bound leakage), finished instances are no-ops;
  * kernels: the batched fused-scatter kernel (scalar-prefetch instance
    routing + active gating) and the batched merge kernel vs their oracles.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    INF,
    Problem,
    batch_stats,
    bounds_equal,
    csr_from_coo,
    pack_problems,
    propagate_batch,
)
from repro.core import bounds as bnd
from repro.data import make_cascade_chain, make_knapsack, make_mixed, make_set_cover
from repro.kernels import (
    apply_updates_batch_tiles,
    batched_fused_scatter_round_tiles,
    col_pad,
    propagate_block_ell,
)
from repro.kernels import ref as kref


def _set2_bucket(count=8, m=120, n=100):
    """Set-2-sized instances (size in [100, 200)) that share one bucket."""
    return [make_mixed(m=m, n=n, seed=s) for s in range(count)]


def _free_problem(m=20, n=60, seed=0):
    """Converges in one (no-change) round: every side is infinite."""
    p = make_knapsack(n=n, m=m, seed=seed)
    return p._replace(lhs=np.full(p.m, -INF), rhs=np.full(p.m, INF))


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------


def test_pack_problems_flat_structure():
    probs = _set2_bucket(3) + [make_knapsack(n=60, m=20, seed=7)]
    batches = pack_problems(probs)
    assert len(batches) == 1  # all pad to n_pad == 128
    b = batches[0]
    ell = b.ell
    assert sorted(b.indices) == [0, 1, 2, 3]
    # Tile streams are contiguous per instance and ordered.
    assert (np.diff(ell.tile_inst) >= 0).all()
    # Global rows: instance i's chunks stay inside its row window.
    for i, p in enumerate(b.problems):
        rows = ell.chunk_row[ell.tile_inst == i]
        assert rows.min() >= ell.row_offset[i]
        assert rows.max() <= ell.row_offset[i] + p.m  # dummy row included
    # Side stacking: dummy rows are zero, real rows match.
    for i, p in enumerate(b.problems):
        off = ell.row_offset[i]
        np.testing.assert_array_equal(b.lhs1[off : off + p.m], p.lhs)
        assert b.lhs1[off + p.m] == 0.0
    stats = batch_stats(batches)
    assert stats["instances"] == 4 and stats["buckets"] == 1


def test_batch_stats_per_bucket_histogram():
    """Per-bucket occupancy/padding histogram: same shape the service's
    stats endpoint surfaces for its resident buckets."""
    probs = [
        make_mixed(m=120, n=100, seed=0),
        make_mixed(m=120, n=200, seed=1),
        make_set_cover(n=90, m=30, seed=2),
    ]
    batches = pack_problems(probs)
    stats = batch_stats(batches)
    per = stats["per_bucket"]
    assert len(per) == len(batches) == 2
    keys = {
        "n_pad", "instances", "tiles", "tile_rows", "tile_width",
        "nnz", "padded_slots", "fill", "padding_fraction",
    }
    for h in per:
        assert keys <= set(h)
        assert 0.0 < h["fill"] <= 1.0
        assert h["fill"] + h["padding_fraction"] == pytest.approx(1.0)
        assert 0 < h["nnz"] <= h["padded_slots"]
    assert sum(h["instances"] for h in per) == stats["instances"]
    assert sum(h["nnz"] for h in per) == stats["nnz"]
    assert sum(h["padded_slots"] for h in per) == stats["padded_slots"]


def test_pack_problems_buckets_by_col_pad():
    probs = [make_mixed(m=120, n=100, seed=0), make_mixed(m=120, n=200, seed=1)]
    batches = pack_problems(probs)
    assert len(batches) == 2  # n_pad 128 vs 256
    assert {b.n_pad for b in batches} == {128, 256}
    # Forcing a common width packs them together (the batch-sharded path).
    (single,) = pack_problems(probs, n_pad=256)
    assert single.size == 2 and single.n_pad == 256


# ---------------------------------------------------------------------------
# Acceptance: batched == per-instance fused, bitwise
# ---------------------------------------------------------------------------


def test_batched_matches_single_instance_fused_bitwise():
    probs = _set2_bucket(8)
    assert len(pack_problems(probs)) == 1  # one bucket of >= 8 Set-2 instances
    results = propagate_batch(probs, use_pallas=False)
    for p, r in zip(probs, results):
        single = propagate_block_ell(
            p, scatter="fused", use_pallas=False, driver="device_loop"
        )
        np.testing.assert_array_equal(np.asarray(r.lb), np.asarray(single.lb))
        np.testing.assert_array_equal(np.asarray(r.ub), np.asarray(single.ub))
        assert int(r.rounds) == int(single.rounds)
        assert bool(r.converged) == bool(single.converged)
        assert bool(r.infeasible) == bool(single.infeasible)


def test_batched_matches_single_instance_multichunk_bitwise():
    """tile_width below the longest row forces the multi-chunk batched path."""
    probs = [make_knapsack(n=40, m=10, seed=s) for s in range(3)]
    assert any(int(np.diff(p.csr.row_ptr).max()) > 8 for p in probs)
    results = propagate_batch(probs, tile_rows=2, tile_width=8, use_pallas=False)
    for p, r in zip(probs, results):
        single = propagate_block_ell(
            p, tile_rows=2, tile_width=8, scatter="fused",
            use_pallas=False, driver="device_loop",
        )
        np.testing.assert_array_equal(np.asarray(r.lb), np.asarray(single.lb))
        np.testing.assert_array_equal(np.asarray(r.ub), np.asarray(single.ub))
        assert int(r.rounds) == int(single.rounds)


def test_batched_pallas_interpret_matches_jnp_engine():
    probs = [make_knapsack(n=60, m=20, seed=s) for s in range(2)] + [
        make_set_cover(n=60, m=22, seed=9),
        make_cascade_chain(16),
    ]
    assert len(pack_problems(probs)) == 1
    rp = propagate_batch(probs, use_pallas=True, interpret=True)
    rj = propagate_batch(probs, use_pallas=False)
    for a, b in zip(rp, rj):
        np.testing.assert_allclose(
            np.asarray(a.lb), np.asarray(b.lb), rtol=1e-12, atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(a.ub), np.asarray(b.ub), rtol=1e-12, atol=1e-12
        )
        assert int(a.rounds) == int(b.rounds)


def test_batched_host_loop_matches_device_loop():
    probs = _set2_bucket(3)
    rh = propagate_batch(probs, use_pallas=False, driver="host_loop")
    rd = propagate_batch(probs, use_pallas=False, driver="device_loop")
    for a, b in zip(rh, rd):
        np.testing.assert_array_equal(np.asarray(a.lb), np.asarray(b.lb))
        np.testing.assert_array_equal(np.asarray(a.ub), np.asarray(b.ub))
        assert int(a.rounds) == int(b.rounds)


# ---------------------------------------------------------------------------
# Per-instance convergence mask
# ---------------------------------------------------------------------------


def test_convergence_mask_mixed_rounds_no_leakage():
    """One-round instance + many-round cascade in ONE bucket: each converges
    to its own fixed point with its own round count, and the bounds are
    bitwise what each instance gets when propagated alone."""
    fast = _free_problem(m=20, n=60)
    slow = make_cascade_chain(16)  # needs ~18 rounds
    probs = [fast, slow]
    assert len(pack_problems(probs)) == 1
    res = propagate_batch(probs, use_pallas=False)
    assert int(res[0].rounds) == 1
    assert int(res[1].rounds) > 10
    for p, r in zip(probs, res):
        single = propagate_block_ell(
            p, scatter="fused", use_pallas=False, driver="device_loop"
        )
        assert int(r.rounds) == int(single.rounds)
        np.testing.assert_array_equal(np.asarray(r.lb), np.asarray(single.lb))
        np.testing.assert_array_equal(np.asarray(r.ub), np.asarray(single.ub))
    assert bool(res[0].converged) and bool(res[1].converged)


def test_per_instance_infeasibility_is_isolated():
    """An infeasible instance reports infeasible without poisoning its
    bucket mates."""
    ok = make_set_cover(n=30, m=10, seed=1)
    bad = Problem(
        csr=csr_from_coo(
            np.array([0]), np.array([0]), np.array([1.0]), 1, 30
        ),
        lhs=np.full(1, 5.0),  # x0 >= 5 with ub = 1: empty domain
        rhs=np.full(1, INF),
        lb=np.zeros(30),
        ub=np.ones(30),
        is_int=np.zeros(30, dtype=bool),
    )
    res = propagate_batch([ok, bad], use_pallas=False)
    assert not bool(res[0].infeasible)
    assert bool(res[1].infeasible)


# ---------------------------------------------------------------------------
# Batched kernels vs oracles
# ---------------------------------------------------------------------------


def _flat_batch(rng, sizes, r, k, n, dtype=np.float64):
    """Random flat tile stream: ``sizes[i]`` tiles for instance i."""
    t = sum(sizes)
    bsz = len(sizes)
    n_pad = col_pad(n)
    val = rng.choice([-2.0, -1.0, 0.0, 1.0, 3.0], size=(t, r, k)).astype(dtype)
    col = rng.integers(0, n, size=(t, r, k)).astype(np.int32)
    col[val == 0] = 0
    tile_inst = np.repeat(np.arange(bsz, dtype=np.int32), sizes)
    lb = rng.uniform(-5, 0, size=(bsz, n_pad)).astype(dtype)
    ub = rng.uniform(0, 5, size=(bsz, n_pad)).astype(dtype)
    lb[rng.random((bsz, n_pad)) < 0.15] = -INF
    ub[rng.random((bsz, n_pad)) < 0.15] = INF
    ii = rng.random((t, r, k)) < 0.5
    lhs = rng.uniform(-10, 0, size=(t, r)).astype(dtype)
    rhs = rng.uniform(0, 10, size=(t, r)).astype(dtype)
    j = jnp.asarray
    return (j(val), j(col), j(ii), j(lhs), j(rhs), j(lb), j(ub),
            j(tile_inst), n_pad)


@pytest.mark.parametrize("sizes,n", [((2, 3), 20), ((1, 4, 2), 150)])
def test_batched_fused_scatter_kernel_matches_ref(sizes, n, rng):
    val, col, ii, lhs, rhs, lb, ub, tile_inst, n_pad = _flat_batch(
        rng, sizes, 4, 8, n
    )
    active = jnp.ones(len(sizes), dtype=bool)
    got = batched_fused_scatter_round_tiles(
        val, col, ii, lhs, rhs, lb, ub, tile_inst, active, n_pad,
        int_eps=1e-6, interpret=True,
    )
    col_g = col + tile_inst[:, None, None] * n_pad
    want = kref.batched_fused_scatter_round_ref(
        val, col_g, ii, lhs, rhs, lb, ub, n_pad, int_eps=1e-6
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12, atol=1e-12)


def test_batched_kernel_inactive_instances_emit_identity(rng):
    val, col, ii, lhs, rhs, lb, ub, tile_inst, n_pad = _flat_batch(
        rng, (2, 2, 3), 4, 8, 30
    )
    active = jnp.asarray([True, False, True])
    bl, bu = batched_fused_scatter_round_tiles(
        val, col, ii, lhs, rhs, lb, ub, tile_inst, active, n_pad,
        int_eps=1e-6, interpret=True,
    )
    assert (np.asarray(bl)[1] == -INF).all()
    assert (np.asarray(bu)[1] == INF).all()
    # Active rows match the all-active oracle.
    col_g = col + tile_inst[:, None, None] * n_pad
    wl, wu = kref.batched_fused_scatter_round_ref(
        val, col_g, ii, lhs, rhs, lb, ub, n_pad, int_eps=1e-6
    )
    np.testing.assert_allclose(np.asarray(bl)[[0, 2]], np.asarray(wl)[[0, 2]])
    np.testing.assert_allclose(np.asarray(bu)[[0, 2]], np.asarray(wu)[[0, 2]])


def test_apply_updates_batch_tiles_matches_shared_semantics(rng):
    bsz, n_pad = 3, 128
    lb = jnp.asarray(rng.uniform(-5, 0, (bsz, n_pad)))
    ub = jnp.asarray(rng.uniform(0, 5, (bsz, n_pad)))
    best_l = jnp.asarray(rng.uniform(-6, 2, (bsz, n_pad)))
    best_u = jnp.asarray(rng.uniform(-2, 6, (bsz, n_pad)))
    active = jnp.asarray([True, False, True])
    got = apply_updates_batch_tiles(
        lb, ub, best_l, best_u, active, eps=1e-9, interpret=True
    )
    want = bnd.apply_updates_batch(lb, ub, best_l, best_u, eps=1e-9)
    for i in range(bsz):
        if bool(active[i]):
            np.testing.assert_array_equal(np.asarray(got[0])[i], np.asarray(want[0])[i])
            np.testing.assert_array_equal(np.asarray(got[1])[i], np.asarray(want[1])[i])
            assert bool(got[2][i]) == bool(want[2][i])
        else:  # inactive: bounds pass through, unchanged
            np.testing.assert_array_equal(np.asarray(got[0])[i], np.asarray(lb)[i])
            np.testing.assert_array_equal(np.asarray(got[1])[i], np.asarray(ub)[i])
            assert not bool(got[2][i])


def test_batched_results_have_unpadded_shapes():
    probs = [make_mixed(m=30, n=25, seed=1), make_mixed(m=40, n=31, seed=2)]
    res = propagate_batch(probs, use_pallas=False)
    assert res[0].lb.shape == (25,) and res[1].lb.shape == (31,)


def test_repeated_propagate_batch_is_stable():
    """Runner/prepare/pack caches + donation must not corrupt state across
    repeated propagations of the same problem list."""
    probs = _set2_bucket(3)
    r1 = propagate_batch(probs, use_pallas=False)
    r2 = propagate_batch(probs, use_pallas=False)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(np.asarray(a.lb), np.asarray(b.lb))
        np.testing.assert_array_equal(np.asarray(a.ub), np.asarray(b.ub))


# ---------------------------------------------------------------------------
# Batch-axis sharding (subprocess with forced host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_batch_sharded_matches_batched():
    import os
    import subprocess
    import sys
    import textwrap

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = src
    code = textwrap.dedent("""
        import jax, numpy as np
        jax.config.update("jax_enable_x64", True)
        from repro.core import propagate_batch, propagate_batch_sharded, bounds_equal
        from repro.data import make_mixed, make_knapsack, make_cascade_chain
        probs = ([make_mixed(m=80, n=60, seed=s) for s in range(5)]
                 + [make_knapsack(n=60, m=20, seed=3), make_cascade_chain(12)])
        mesh = jax.make_mesh((4,), ("b",))
        rs = propagate_batch_sharded(probs, mesh)
        rb = propagate_batch(probs, use_pallas=False)
        for p, a, b in zip(probs, rs, rb):
            assert bounds_equal(np.asarray(a.lb), np.asarray(a.ub),
                                np.asarray(b.lb), np.asarray(b.ub)), p.m
            assert int(a.rounds) == int(b.rounds)
            assert bool(a.converged) == bool(b.converged)
        print("BATCH_SHARDED_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=420,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "BATCH_SHARDED_OK" in out.stdout
