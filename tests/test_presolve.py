"""Direct unit tests for ``core.presolve.analyze_constraints`` (paper §1.1
Steps 1 and 2): redundancy / infeasibility verdicts from row activities,
including rows with infinite activity contributions."""
import numpy as np
import jax.numpy as jnp

from repro.core import INF, analyze_constraints
from repro.core.propagator import DeviceProblem
from repro.data import make_mixed


def _analyze(rows, cols, vals, lhs, rhs, lb, ub, m):
    return analyze_constraints(
        jnp.asarray(np.asarray(rows, dtype=np.int32)),
        jnp.asarray(np.asarray(vals, dtype=np.float64)),
        jnp.asarray(np.asarray(cols, dtype=np.int32)),
        jnp.asarray(np.asarray(lhs, dtype=np.float64)),
        jnp.asarray(np.asarray(rhs, dtype=np.float64)),
        jnp.asarray(np.asarray(lb, dtype=np.float64)),
        jnp.asarray(np.asarray(ub, dtype=np.float64)),
        m,
    )


def test_redundant_row():
    # x0 + x1 <= 5 with x in [0, 1]^2: amax = 2 <= 5, lhs = -inf  ->  Step 1.
    v = _analyze([0, 0], [0, 1], [1.0, 1.0], [-INF], [5.0], [0, 0], [1, 1], 1)
    assert bool(v.redundant[0])
    assert not bool(v.infeasible[0])
    assert not bool(v.any_infeasible)


def test_infeasible_row_lhs_unreachable():
    # x0 >= 5 with x0 in [0, 1]: amax = 1 < lhs  ->  Step 2.
    v = _analyze([0], [0], [1.0], [5.0], [INF], [0], [1], 1)
    assert bool(v.infeasible[0])
    assert not bool(v.redundant[0])
    assert bool(v.any_infeasible)


def test_infeasible_row_rhs_unreachable():
    # -2 x0 <= -10 i.e. amin = -2 > rhs with x0 in [0, 1].
    v = _analyze([0], [0], [-2.0], [-INF], [-10.0], [0], [1], 1)
    assert bool(v.infeasible[0])
    assert bool(v.any_infeasible)


def test_mixed_verdicts():
    # Row 0 redundant, row 1 infeasible, row 2 neither.
    rows = [0, 1, 2]
    cols = [0, 1, 2]
    vals = [1.0, 1.0, 1.0]
    lhs = [-INF, 5.0, 0.5]
    rhs = [10.0, INF, INF]
    lb = [0.0, 0.0, 0.0]
    ub = [1.0, 1.0, 1.0]
    v = _analyze(rows, cols, vals, lhs, rhs, lb, ub, 3)
    assert np.asarray(v.redundant).tolist() == [True, False, False]
    assert np.asarray(v.infeasible).tolist() == [False, True, False]
    assert bool(v.any_infeasible)


def test_infinite_activity_rows():
    # x0 has ub = +inf: amax = +inf, so a finite-rhs row is neither
    # redundant (amax > rhs) nor infeasible (amin = 0 <= rhs).
    v = _analyze([0], [0], [1.0], [-INF], [3.0], [0.0], [INF], 1)
    assert not bool(v.redundant[0])
    assert not bool(v.infeasible[0])
    # Both bounds infinite: amin = -inf, amax = +inf -- never a verdict
    # unless the sides are infinite too.
    v = _analyze([0], [0], [1.0], [-2.0], [3.0], [-INF], [INF], 1)
    assert not bool(v.redundant[0])
    assert not bool(v.infeasible[0])
    # Free row (both sides infinite) IS redundant whatever the activity.
    v = _analyze([0], [0], [1.0], [-INF], [INF], [-INF], [INF], 1)
    assert bool(v.redundant[0])


def test_single_infinity_does_not_mask_other_contributions():
    # Row: x0 + x1 >= 1 with x0 in [0, inf), x1 in [0, 1].
    # amin = 0 (finite), amax = +inf -> not redundant (rhs fine: +inf),
    # not infeasible (amax >= lhs).
    v = _analyze([0, 0], [0, 1], [1.0, 1.0], [1.0], [INF],
                 [0.0, 0.0], [INF, 1.0], 1)
    assert not bool(v.redundant[0])
    assert not bool(v.infeasible[0])


def test_feas_eps_tolerance():
    # amin exceeds rhs by less than feas_eps: not flagged infeasible.
    v = _analyze([0], [0], [1.0], [-INF], [1.0 - 1e-12], [1.0], [1.0], 1)
    assert not bool(v.infeasible[0])
    # ... but a clear violation is.
    v = _analyze([0], [0], [1.0], [-INF], [0.5], [1.0], [1.0], 1)
    assert bool(v.infeasible[0])


def test_matches_bruteforce_on_random_instance():
    p = make_mixed(m=60, n=45, seed=7)
    dp = DeviceProblem(p)
    v = analyze_constraints(
        dp.row_id, dp.val, dp.col, dp.lhs, dp.rhs, dp.lb0, dp.ub0, p.m
    )
    # Dense brute force with sentinel-infinity semantics.
    a = p.csr.to_dense()
    lb = np.where(np.abs(p.lb) >= INF, np.sign(p.lb) * np.inf, p.lb)
    ub = np.where(np.abs(p.ub) >= INF, np.sign(p.ub) * np.inf, p.ub)
    with np.errstate(invalid="ignore"):
        cmin = np.where(a > 0, a * lb, a * ub)
        cmax = np.where(a > 0, a * ub, a * lb)
    amin = np.where(a == 0, 0.0, cmin).sum(axis=1)  # mask 0 * inf = NaN
    amax = np.where(a == 0, 0.0, cmax).sum(axis=1)
    lhs = np.where(p.lhs <= -INF, -np.inf, p.lhs)  # sentinel sides -> IEEE inf
    rhs = np.where(p.rhs >= INF, np.inf, p.rhs)
    redundant = (lhs <= amin) & (amax <= rhs)
    infeasible = (amin > rhs + 1e-8) | (lhs > amax + 1e-8)
    np.testing.assert_array_equal(np.asarray(v.redundant), redundant)
    np.testing.assert_array_equal(np.asarray(v.infeasible), infeasible)
