"""Optimizer, data pipeline, checkpointing, grad compression."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.tokens import DataConfig, make_batch
from repro.train.checkpoint import (
    AsyncCheckpointer,
    latest_step_dir,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    clip_by_global_norm,
    compress_grads,
    cosine_lr,
    global_norm,
    init_opt_state,
)


class TestOptimizer:
    def test_adamw_minimizes_quadratic(self):
        cfg = OptimizerConfig(lr_peak=0.1, lr_min=0.01, warmup_steps=5,
                              total_steps=200, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0, 2.0])}
        state = init_opt_state(params, cfg)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(params, g, state, cfg)
        assert float(loss(params)) < 1e-3

    def test_weight_decay_shrinks(self):
        cfg = OptimizerConfig(lr_peak=0.1, warmup_steps=0, total_steps=10,
                              weight_decay=0.5)
        params = {"w": jnp.array([10.0])}
        state = init_opt_state(params, cfg)
        g = {"w": jnp.array([0.0])}
        params, state, _ = adamw_update(params, g, state, cfg)
        assert float(params["w"][0]) < 10.0

    def test_clip_by_global_norm(self):
        g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(norm) - 5.0) < 1e-6
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5

    def test_cosine_schedule_endpoints(self):
        cfg = OptimizerConfig(lr_peak=1.0, lr_min=0.1, warmup_steps=10,
                              total_steps=100)
        assert float(cosine_lr(cfg, jnp.int32(0))) == 0.0
        assert abs(float(cosine_lr(cfg, jnp.int32(10))) - 1.0) < 1e-6
        assert abs(float(cosine_lr(cfg, jnp.int32(100))) - 0.1) < 1e-6

    def test_bf16_state_dtype(self):
        cfg = OptimizerConfig(state_dtype="bfloat16")
        params = {"w": jnp.ones((4,), jnp.float32)}
        state = init_opt_state(params, cfg)
        assert state.m["w"].dtype == jnp.bfloat16
        g = {"w": jnp.full((4,), 0.1, jnp.bfloat16)}
        params2, state2, _ = adamw_update(params, g, state, cfg)
        assert state2.m["w"].dtype == jnp.bfloat16
        assert params2["w"].dtype == jnp.float32

    def test_grad_compression_error_feedback(self):
        g = {"w": jnp.array([0.1, -0.25, 0.7])}
        ef = {"w": jnp.zeros(3)}
        gq, ef2 = compress_grads(g, ef)
        # Quantized + residual reconstructs the original exactly.
        np.testing.assert_allclose(
            np.asarray(gq["w"] + ef2["w"]), np.asarray(g["w"]), rtol=1e-6
        )


class TestData:
    def test_determinism_and_restart_alignment(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
        a = make_batch(cfg, step=3)
        b = make_batch(cfg, step=3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_shards_partition_batch(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=1)
        s0 = make_batch(cfg, 0, shard=0, num_shards=2)
        s1 = make_batch(cfg, 0, shard=1, num_shards=2)
        assert s0["tokens"].shape == (4, 8)
        assert not np.array_equal(s0["tokens"], s1["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=50, seq_len=12, global_batch=2, seed=2)
        b = make_batch(cfg, 0)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 50
        assert b["tokens"].shape == b["labels"].shape


class TestCheckpoint:
    def _state(self, v=1.0):
        return {
            "params": {"w": jnp.full((3, 2), v), "b": jnp.zeros((2,))},
            "step_info": jnp.int32(v),
        }

    def test_roundtrip(self, tmp_path):
        d = str(tmp_path / "ckpt")
        state = self._state(2.5)
        save_checkpoint(d, 7, state)
        restored, step = restore_checkpoint(d, self._state(0.0))
        assert step == 7
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
        )

    def test_keep_n_retention(self, tmp_path):
        d = str(tmp_path / "ckpt")
        for s in range(5):
            save_checkpoint(d, s, self._state(s), keep_n=2)
        dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert dirs == ["step_00000003", "step_00000004"]

    def test_latest_pointer_and_fallback(self, tmp_path):
        d = str(tmp_path / "ckpt")
        save_checkpoint(d, 1, self._state())
        save_checkpoint(d, 2, self._state())
        assert latest_step_dir(d).endswith("step_00000002")
        os.remove(os.path.join(d, "LATEST"))  # crash before pointer update
        assert latest_step_dir(d).endswith("step_00000002")

    def test_restore_empty_dir_returns_init(self, tmp_path):
        like = self._state(9.0)
        restored, step = restore_checkpoint(str(tmp_path / "none"), like)
        assert step == 0
        assert restored is like

    def test_async_checkpointer(self, tmp_path):
        d = str(tmp_path / "ckpt")
        ck = AsyncCheckpointer(d, keep_n=2)
        ck.save(5, self._state(5.0))
        ck.wait()
        _, step = restore_checkpoint(d, self._state())
        assert step == 5

    def test_elastic_reshard_restore(self, tmp_path):
        """Restore with explicit (single-device) shardings = device_put path."""
        d = str(tmp_path / "ckpt")
        save_checkpoint(d, 3, self._state(1.5))
        dev = jax.devices()[0]
        sharding = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(dev), self._state()
        )
        restored, step = restore_checkpoint(d, self._state(), shardings=sharding)
        assert step == 3
        assert restored["params"]["w"].devices() == {dev}
