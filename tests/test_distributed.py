"""Multi-device tests (subprocess with forced host devices, so the main
pytest process keeps seeing exactly 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_propagation_matches_single_device():
    out = _run("""
        import jax, numpy as np
        jax.config.update("jax_enable_x64", True)
        from repro.core import propagate, propagate_sharded, bounds_equal
        from repro.data import make_mixed, make_cascade_chain
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for seed in range(3):
            p = make_mixed(m=60, n=45, seed=seed)
            a = propagate(p, driver="device_loop")
            b = propagate_sharded(p, mesh)
            assert bounds_equal(np.asarray(a.lb), np.asarray(a.ub),
                                np.asarray(b.lb), np.asarray(b.ub)), seed
            assert int(a.rounds) == int(b.rounds), (int(a.rounds), int(b.rounds))
        p = make_cascade_chain(16)
        a = propagate(p); b = propagate_sharded(p, mesh)
        assert bounds_equal(np.asarray(a.lb), np.asarray(a.ub),
                            np.asarray(b.lb), np.asarray(b.ub))
        print("SHARDED_OK")
    """)
    assert "SHARDED_OK" in out


@pytest.mark.slow
def test_row_partitioned_propagation_matches():
    """Beyond-paper §Perf variant: row partition == nnz partition == single."""
    out = _run("""
        import jax, numpy as np
        jax.config.update("jax_enable_x64", True)
        from repro.core import propagate, propagate_sharded_rows, bounds_equal
        from repro.data import make_mixed, make_knapsack
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for seed in range(3):
            p = make_mixed(m=70, n=50, seed=seed + 20)
            a = propagate(p)
            b = propagate_sharded_rows(p, mesh)
            assert bounds_equal(np.asarray(a.lb), np.asarray(a.ub),
                                np.asarray(b.lb), np.asarray(b.ub)), seed
            assert int(a.rounds) == int(b.rounds)
        print("ROWS_OK")
    """)
    assert "ROWS_OK" in out


@pytest.mark.slow
def test_sharded_propagation_multipod_axes():
    out = _run("""
        import jax, numpy as np
        jax.config.update("jax_enable_x64", True)
        from repro.core import propagate, propagate_sharded, bounds_equal
        from repro.data import make_mixed
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        p = make_mixed(m=50, n=40, seed=11)
        a = propagate(p)
        b = propagate_sharded(p, mesh)
        assert bounds_equal(np.asarray(a.lb), np.asarray(a.ub),
                            np.asarray(b.lb), np.asarray(b.ub))
        print("MULTIPOD_OK")
    """)
    assert "MULTIPOD_OK" in out


@pytest.mark.slow
def test_sharded_train_step_matches_unsharded():
    """One train step on a (2,2) mesh == the same step on 1 logical device."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.transformer import init_params
        from repro.train.optimizer import OptimizerConfig, init_opt_state
        from repro.train.train_step import make_train_step
        from repro.launch.sharding import param_shardings, opt_state_shardings, batch_shardings
        from repro.models.config import InputShape, input_specs

        cfg = get_config("granite-3-2b", smoke=True)
        opt_cfg = OptimizerConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params, opt_cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

        ref_step = jax.jit(make_train_step(cfg, opt_cfg))
        p1, o1, m1 = ref_step(params, opt, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = param_shardings(cfg, mesh)
        o_sh = opt_state_shardings(cfg, mesh, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg, mesh),
                       in_shardings=(p_sh, o_sh, None),
                       out_shardings=(p_sh, o_sh, None))
        p2, o2, m2 = step(jax.device_put(params, p_sh),
                          jax.device_put(opt, o_sh), batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (float(m1["loss"]), float(m2["loss"]))
        d = max(float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 2e-2, d
        print("TRAIN_SHARDED_OK", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "TRAIN_SHARDED_OK" in out


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """The dry-run path itself (lower+compile+memory+probe) on a tiny mesh."""
    out = _run("""
        import jax
        from repro.configs import get_config
        from repro.models.config import SHAPES
        from repro.launch.dryrun import lower_cell
        import dataclasses
        cfg = get_config("granite-3-2b", smoke=True)
        cfg = dataclasses.replace(cfg, dtype="bfloat16")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
        lowered, compiled = lower_cell(cfg, shape, mesh, microbatches=2)
        ma = compiled.memory_analysis()
        assert ma.argument_size_in_bytes > 0
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax wraps the dict
            ca = ca[0]
        assert ca.get("flops", 0) > 0
        print("DRYRUN_OK")
    """)
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard_across_meshes():
    """Save sharded on (4,) devices, restore onto a (2,2) mesh layout."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import save_checkpoint, restore_checkpoint
        state = {"w": jnp.arange(64.0).reshape(8, 8)}
        mesh1 = jax.make_mesh((4,), ("data",))
        s1 = {"w": NamedSharding(mesh1, P("data", None))}
        state1 = jax.device_put(state, s1)
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, state1)
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        s2 = {"w": NamedSharding(mesh2, P("data", "model"))}
        restored, step = restore_checkpoint(d, state, shardings=s2)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
        assert len(restored["w"].devices()) == 4
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out
