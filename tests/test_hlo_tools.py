"""Roofline tooling: collective parser + dot-FLOPs parser on both synthetic
HLO snippets and a real compiled module."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import RooflineTerms, collective_bytes, extrapolate
from repro.roofline.hlo_flops import dot_flops_by_op, hbm_traffic_estimate

SYNTHETIC = """
  %all-reduce.1 = f32[16,4096]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8]
  %all-gather.2 = bf16[1024,512]{1,0} all-gather(%y), replica_groups=[4,2]<=[8], dimensions={0}
  %reduce-scatter.3 = f32[128]{0} reduce-scatter(%z), replica_groups=[1,8]<=[8], dimensions={0}
  %all-to-all.4 = f32[64,64]{1,0} all-to-all(%w), replica_groups={{0,1,2,3},{4,5,6,7}}
  %collective-permute.5 = bf16[256]{0} collective-permute(%v), source_target_pairs={{0,1}}
"""


def test_collective_parser_synthetic():
    got = collective_bytes(SYNTHETIC)
    assert got["all-reduce"] == 16 * 4096 * 4
    assert got["all-gather"] == 1024 * 512 * 2 / 2   # result / group_size(2)
    assert got["reduce-scatter"] == 128 * 4 * 8      # result * group_size(8)
    assert got["all-to-all"] == 64 * 64 * 4
    assert got["collective-permute"] == 256 * 2
    assert got["total"] == sum(
        got[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")
    )


def test_dot_flops_on_compiled_module():
    def f(a, b, c):
        return (a @ b) @ c

    sds = jax.ShapeDtypeStruct
    m, k, n, p = 8, 16, 32, 4
    compiled = (
        jax.jit(f)
        .lower(sds((m, k), jnp.float32), sds((k, n), jnp.float32), sds((n, p), jnp.float32))
        .compile()
    )
    total, by_op = dot_flops_by_op(compiled.as_text())
    want = 2 * m * k * n + 2 * m * n * p
    assert abs(total - want) / want < 1e-6, (total, want)


def test_hbm_traffic_estimate_counts_dots():
    def f(a, b):
        return a @ b

    sds = jax.ShapeDtypeStruct
    compiled = (
        jax.jit(f)
        .lower(sds((64, 128), jnp.float32), sds((128, 32), jnp.float32))
        .compile()
    )
    traffic = hbm_traffic_estimate(compiled.as_text())
    want = (64 * 128 + 128 * 32 + 64 * 32) * 4
    assert traffic >= want


def test_extrapolation_linear():
    # cost(n) = 7 + 3n measured at n=1,2 -> n=10
    assert extrapolate(10.0, 13.0, 1, 2, 10) == 7 + 3 * 10


def test_roofline_terms_bottleneck():
    t = RooflineTerms(flops=197e12, bytes_hbm=819e9 * 2, bytes_coll=50e9).finalize()
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 2.0) < 1e-9
    assert abs(t.t_collective - 1.0) < 1e-9
    assert t.bottleneck == "memory"
    assert t.t_bound == t.t_memory
