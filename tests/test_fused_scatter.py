"""Fused-scatter engine validation.

Three layers:
  * kernel vs oracle: the in-VMEM gather/scatter kernels (D, E, A', F)
    against their jnp oracles in ``ref.py``, interpret mode;
  * engine vs engine: ``scatter='fused'`` is ``bounds_equal`` to the
    segment-op engine and to ``seq_ref`` on random instances, including
    empty columns, all-infinite bounds, and rows spanning multiple chunks;
  * prepare(): instance caching and donation-safety of the cached bounds.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    INF,
    Problem,
    bounds_equal,
    csr_from_coo,
    propagate_sequential,
)
from repro.core import bounds as bnd
from repro.data import make_cascade_chain, make_knapsack, make_mixed, make_set_cover
from repro.kernels import (
    activities_tiles,
    apply_updates_tiles,
    candidates_scatter_tiles,
    col_pad,
    fused_scatter_round_tiles,
    prepare_block_ell,
    propagate_block_ell,
)
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


def _tiles(rng, t, r, k, n, dtype=np.float64, inf_frac=0.15):
    """Random candidate-kernel inputs with block-ELL conventions
    (val == 0 and col == 0 mark padding)."""
    val = rng.choice([-2.0, -1.0, 0.0, 1.0, 3.0], size=(t, r, k)).astype(dtype)
    col = rng.integers(0, n, size=(t, r, k)).astype(np.int32)
    col[val == 0] = 0
    n_pad = col_pad(n)
    lb = rng.uniform(-5, 0, size=n_pad).astype(dtype)
    ub = rng.uniform(0, 5, size=n_pad).astype(dtype)
    lb[rng.random(n_pad) < inf_frac] = -INF
    ub[rng.random(n_pad) < inf_frac] = INF
    ii = rng.random((t, r, k)) < 0.5
    lhs = rng.uniform(-10, 0, size=(t, r)).astype(dtype)
    rhs = rng.uniform(0, 10, size=(t, r)).astype(dtype)
    j = jnp.asarray
    return j(val), j(col), j(lb), j(ub), j(ii), j(lhs), j(rhs), n_pad


@pytest.mark.parametrize("t,r,k,n", [(1, 2, 4, 3), (3, 4, 8, 20), (2, 8, 16, 150)])
def test_fused_scatter_kernel_matches_ref(t, r, k, n, rng):
    val, col, lb, ub, ii, lhs, rhs, n_pad = _tiles(rng, t, r, k, n)
    got = fused_scatter_round_tiles(
        val, col, ii, lhs, rhs, lb, ub, n_pad, int_eps=1e-6, interpret=True
    )
    want = kref.fused_scatter_round_tiles_ref(
        val, col, ii, lhs, rhs, lb, ub, n_pad, int_eps=1e-6
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("t,r,k,n", [(2, 2, 4, 10), (3, 4, 8, 140)])
def test_candidates_scatter_kernel_matches_ref(t, r, k, n, rng):
    val, col, lb, ub, ii, lhs, rhs, n_pad = _tiles(rng, t, r, k, n)
    mf, mc, xf, xc = kref.activities_tiles_ref(val, lb[col], ub[col])
    got = candidates_scatter_tiles(
        val, col, ii, mf, mc, xf, xc, lhs, rhs, lb, ub, n_pad,
        int_eps=1e-6, interpret=True,
    )
    want = kref.candidates_scatter_tiles_ref(
        val, col, ii, mf, mc, xf, xc, lhs, rhs, lb, ub, n_pad, int_eps=1e-6
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("t,r,k,n", [(2, 2, 4, 10), (2, 4, 8, 130)])
def test_activities_gather_kernel_matches_ref(t, r, k, n, rng):
    from repro.kernels import activities_gather_tiles

    val, col, lb, ub, _, _, _, n_pad = _tiles(rng, t, r, k, n)
    got = activities_gather_tiles(val, col, lb, ub, n_pad, interpret=True)
    want = kref.activities_gather_tiles_ref(val, col, lb, ub, n_pad)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12, atol=1e-12)


def test_in_kernel_gather_matches_xla_gather(rng):
    """The one-hot in-kernel gather is exact (single-term sums), so the
    gathered activities must be bitwise equal to XLA-gathered ones."""
    from repro.kernels import activities_gather_tiles

    val, col, lb, ub, _, _, _, n_pad = _tiles(rng, 3, 4, 8, 60)
    got = activities_gather_tiles(val, col, lb, ub, n_pad, interpret=True)
    want = activities_tiles(val, lb[col], ub[col], interpret=True)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12, atol=1e-12)


def test_apply_updates_kernel_matches_shared_semantics(rng):
    n_pad = 128
    lb = jnp.asarray(rng.uniform(-5, 0, n_pad))
    ub = jnp.asarray(rng.uniform(0, 5, n_pad))
    best_l = jnp.asarray(rng.uniform(-6, 2, n_pad))
    best_u = jnp.asarray(rng.uniform(-2, 6, n_pad))
    got = apply_updates_tiles(lb, ub, best_l, best_u, eps=1e-9, interpret=True)
    want = bnd.apply_updates(lb, ub, best_l, best_u, eps=1e-9)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    assert bool(got[2]) == bool(want[2])


# ---------------------------------------------------------------------------
# Engine vs engine (property sweep over random instances)
# ---------------------------------------------------------------------------


def _random_problem(seed, m=None, n=None, empty_col_frac=0.0, all_inf_bounds=False):
    rng = np.random.default_rng(seed)
    m = m or int(rng.integers(3, 25))
    n = n or int(rng.integers(3, 20))
    mask = rng.random((m, n)) < rng.uniform(0.2, 0.6)
    for i in range(m):
        if not mask[i].any():
            mask[i, rng.integers(0, n)] = True
    if empty_col_frac:
        dead = rng.random(n) < empty_col_frac
        mask[:, dead] = False
        for i in range(m):  # keep rows nonempty among live columns
            if not mask[i].any():
                live = np.nonzero(~dead)[0]
                mask[i, rng.choice(live)] = True
    rows, cols = np.nonzero(mask)
    vals = rng.choice([-3.0, -2.0, -1.0, 1.0, 2.0, 3.0], size=rows.size)
    csr = csr_from_coo(rows.astype(np.int32), cols.astype(np.int32), vals, m, n)
    if all_inf_bounds:
        lb = np.full(n, -INF)
        ub = np.full(n, INF)
    else:
        lb = -rng.integers(0, 3, size=n).astype(np.float64)
        ub = rng.integers(1, 8, size=n).astype(np.float64)
        lb[rng.random(n) < 0.15] = -INF
        ub[rng.random(n) < 0.15] = INF
    is_int = rng.random(n) < 0.5
    row_abs = np.zeros(m)
    np.add.at(row_abs, rows, np.abs(vals) * 2.0)
    lhs = np.where(rng.random(m) < 0.4, -INF, -row_abs * rng.uniform(0.1, 0.5, m))
    rhs = np.where(rng.random(m) < 0.2, INF, row_abs * rng.uniform(0.1, 0.5, m))
    swap = lhs > rhs
    lhs[swap], rhs[swap] = rhs[swap], lhs[swap]
    return Problem(csr=csr, lhs=lhs, rhs=rhs, lb=lb, ub=ub, is_int=is_int)


def _check_engines_agree(p, tile_rows=4, tile_width=16):
    a = propagate_sequential(p)
    fused = propagate_block_ell(
        p, tile_rows=tile_rows, tile_width=tile_width, scatter="fused",
        driver="host_loop",
    )
    seg = propagate_block_ell(
        p, tile_rows=tile_rows, tile_width=tile_width, scatter="segment",
        driver="host_loop",
    )
    if bool(a.infeasible) or bool(fused.infeasible):
        return  # infeasibility verdicts may be reached at different rounds
    assert bounds_equal(fused.lb, fused.ub, seg.lb, seg.ub)
    if not (a.converged and bool(fused.converged)):
        return
    assert bounds_equal(a.lb, a.ub, fused.lb, fused.ub)


@pytest.mark.parametrize("seed", range(8))
def test_fused_engine_random_instances(seed):
    _check_engines_agree(_random_problem(seed))


@pytest.mark.parametrize("seed", [100, 101, 102])
def test_fused_engine_empty_columns(seed):
    p = _random_problem(seed, m=15, n=18, empty_col_frac=0.3)
    # Some column really is empty.
    assert (np.bincount(p.csr.col, minlength=p.n) == 0).any()
    _check_engines_agree(p)


@pytest.mark.parametrize("seed", [200, 201])
def test_fused_engine_all_infinite_bounds(seed):
    p = _random_problem(seed, m=12, n=10, all_inf_bounds=True)
    _check_engines_agree(p)


def test_fused_engine_rows_span_chunks():
    """tile_width far below the longest row forces the multi-chunk
    (activities-gather + candidates-scatter) path."""
    p = make_knapsack(n=40, m=6, seed=5)
    assert int(np.diff(p.csr.row_ptr).max()) > 8
    _check_engines_agree(p, tile_rows=2, tile_width=8)


@pytest.mark.parametrize("gen,kwargs", [
    (make_mixed, dict(m=40, n=30, seed=11)),
    (make_set_cover, dict(n=40, m=12, seed=6)),
])
def test_fused_engine_generators(gen, kwargs):
    _check_engines_agree(gen(**kwargs), tile_rows=4, tile_width=32)


def test_fused_engine_cascade_device_loop():
    p = make_cascade_chain(16)
    a = propagate_sequential(p)
    b = propagate_block_ell(p, tile_rows=2, tile_width=4, scatter="fused",
                            driver="device_loop")
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


def test_fused_pallas_vs_jnp_close():
    """Pallas and jnp engines share all candidate formulas; lowering-level
    reduction-order/FMA differences may cost a couple of ulps at most."""
    p = make_mixed(m=30, n=25, seed=13)
    a = propagate_block_ell(p, tile_rows=4, tile_width=8, scatter="fused",
                            use_pallas=True, driver="host_loop")
    b = propagate_block_ell(p, tile_rows=4, tile_width=8, scatter="fused",
                            use_pallas=False, driver="host_loop")
    np.testing.assert_allclose(np.asarray(a.lb), np.asarray(b.lb), rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(a.ub), np.asarray(b.ub), rtol=1e-12, atol=1e-12)
    assert bounds_equal(a.lb, a.ub, b.lb, b.ub)


# ---------------------------------------------------------------------------
# prepare(): caching and donation safety
# ---------------------------------------------------------------------------


def test_prepare_cache_reuses_instance():
    p = make_mixed(m=20, n=15, seed=3)
    a = prepare_block_ell(p, 4, 16)
    b = prepare_block_ell(p, 4, 16)
    assert a is b
    c = prepare_block_ell(p, 4, 32)  # different layout -> different entry
    assert c is not a


def test_repeated_propagation_with_donation_is_stable():
    """Donated drivers must never invalidate the cached initial bounds:
    propagating the same instance twice gives identical results."""
    p = make_set_cover(n=30, m=10, seed=8)
    kw = dict(tile_rows=4, tile_width=32, scatter="fused", donate=True,
              driver="host_loop")
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # CPU warns that donation is a no-op
        r1 = propagate_block_ell(p, **kw)
        r2 = propagate_block_ell(p, **kw)
    np.testing.assert_array_equal(np.asarray(r1.lb), np.asarray(r2.lb))
    np.testing.assert_array_equal(np.asarray(r1.ub), np.asarray(r2.ub))


def test_result_has_unpadded_shape():
    p = _random_problem(42, m=9, n=7)
    r = propagate_block_ell(p, tile_rows=2, tile_width=8, scatter="fused")
    assert r.lb.shape == (7,) and r.ub.shape == (7,)
