"""Minimal MPS reader/writer (free-format subset).

Covers the constructs needed to load MIPLIB-style instances into a
propagation ``Problem``: ROWS (N/L/G/E), COLUMNS (with INTORG/INTEND
integrality markers), RHS, RANGES, BOUNDS (UP/LO/FX/BV/MI/PL/UI/LI).
The objective row is parsed and ignored (propagation is constraint-only).
"""
from __future__ import annotations

from typing import Dict, List, TextIO

import numpy as np

from ..core.sparse import Problem, csr_from_coo
from ..core.types import INF


def read_mps(f: TextIO) -> Problem:
    section = None
    row_kind: Dict[str, str] = {}
    row_order: List[str] = []
    obj_row = None
    col_ids: Dict[str, int] = {}
    is_int_flags: List[bool] = []
    entries: List[tuple] = []   # (row_name, col_idx, value)
    rhs: Dict[str, float] = {}
    ranges: Dict[str, float] = {}
    bounds: List[tuple] = []    # (kind, col, value)
    integer_mode = False

    for raw in f:
        line = raw.rstrip("\n")
        if not line.strip() or line.lstrip().startswith("*"):
            continue
        if not line[0].isspace():  # section header
            section = line.split()[0].upper()
            continue
        tok = line.split()
        if section == "ROWS":
            kind, name = tok[0].upper(), tok[1]
            if kind == "N":
                if obj_row is None:
                    obj_row = name
                continue
            row_kind[name] = kind
            row_order.append(name)
        elif section == "COLUMNS":
            if len(tok) >= 3 and tok[1].upper() == "'MARKER'":
                marker = tok[2].strip("'").upper()
                integer_mode = marker == "INTORG"
                continue
            col = tok[0]
            if col not in col_ids:
                col_ids[col] = len(col_ids)
                is_int_flags.append(integer_mode)
            j = col_ids[col]
            for r, v in zip(tok[1::2], tok[2::2]):
                if r == obj_row:
                    continue
                entries.append((r, j, float(v)))
        elif section == "RHS":
            for r, v in zip(tok[1::2], tok[2::2]):
                if r != obj_row:
                    rhs[r] = float(v)
        elif section == "RANGES":
            for r, v in zip(tok[1::2], tok[2::2]):
                ranges[r] = float(v)
        elif section == "BOUNDS":
            kind, col = tok[0].upper(), tok[2]
            val = float(tok[3]) if len(tok) > 3 else 0.0
            bounds.append((kind, col, val))

    n = len(col_ids)
    m = len(row_order)
    row_ids = {r: i for i, r in enumerate(row_order)}
    rows = np.array([row_ids[r] for r, _, _ in entries], dtype=np.int32)
    cols = np.array([j for _, j, _ in entries], dtype=np.int32)
    vals = np.array([v for _, _, v in entries], dtype=np.float64)
    csr = csr_from_coo(rows, cols, vals, m, n)

    lhs = np.full(m, -INF)
    rhs_arr = np.full(m, INF)
    for r, i in row_ids.items():
        b = rhs.get(r, 0.0)
        kind = row_kind[r]
        if kind == "L":
            rhs_arr[i] = b
        elif kind == "G":
            lhs[i] = b
        elif kind == "E":
            lhs[i] = rhs_arr[i] = b
        if r in ranges:  # MPS RANGES semantics
            rg = ranges[r]
            if kind == "L":
                lhs[i] = b - abs(rg)
            elif kind == "G":
                rhs_arr[i] = b + abs(rg)
            elif kind == "E":
                if rg >= 0:
                    rhs_arr[i] = b + rg
                else:
                    lhs[i] = b + rg

    lb = np.zeros(n)
    ub = np.full(n, INF)
    is_int = np.array(is_int_flags, dtype=bool)
    ub[is_int] = INF  # integers default [0, inf) unless bounded; BV below
    for kind, col, val in bounds:
        if col not in col_ids:
            continue
        j = col_ids[col]
        if kind == "UP":
            ub[j] = val
            if val < 0 and lb[j] == 0:
                lb[j] = -INF  # MPS quirk
        elif kind == "LO":
            lb[j] = val
        elif kind == "FX":
            lb[j] = ub[j] = val
        elif kind == "BV":
            lb[j], ub[j] = 0.0, 1.0
            is_int[j] = True
        elif kind == "MI":
            lb[j] = -INF
        elif kind == "PL":
            ub[j] = INF
        elif kind == "UI":
            ub[j] = val
            is_int[j] = True
        elif kind == "LI":
            lb[j] = val
            is_int[j] = True

    return Problem(csr=csr, lhs=lhs, rhs=rhs_arr, lb=lb, ub=ub, is_int=is_int)


def write_mps(p: Problem, f: TextIO, name: str = "REPRO"):
    """Write a Problem as free-format MPS (ranged rows via RANGES).

    Values are printed with 17 significant digits, so every finite float64
    survives the write -> read round trip bit-exactly.
    """
    f.write(f"NAME          {name}\n")
    f.write("ROWS\n N  COST\n")
    kinds = []
    for i in range(p.m):
        has_l = p.lhs[i] > -INF
        has_r = p.rhs[i] < INF
        if has_l and has_r:
            kinds.append("E" if p.lhs[i] == p.rhs[i] else "R")
            f.write(f" {'E' if p.lhs[i] == p.rhs[i] else 'L'}  R{i}\n")
        elif has_l:
            kinds.append("G")
            f.write(f" G  R{i}\n")
        else:
            kinds.append("L")
            f.write(f" L  R{i}\n")
    f.write("COLUMNS\n")
    csc_order = {}
    rid = p.csr.row_ids()
    for idx in range(p.csr.nnz):
        csc_order.setdefault(int(p.csr.col[idx]), []).append(
            (int(rid[idx]), float(p.csr.val[idx]))
        )
    int_open = False
    for j in range(p.n):
        if p.is_int[j] and not int_open:
            f.write("    MARKER    'MARKER'  'INTORG'\n")
            int_open = True
        if not p.is_int[j] and int_open:
            f.write("    MARKER    'MARKER'  'INTEND'\n")
            int_open = False
        for i, v in csc_order.get(j, []):
            f.write(f"    C{j}  R{i}  {v:.17g}\n")
    if int_open:
        f.write("    MARKER    'MARKER'  'INTEND'\n")
    f.write("RHS\n")
    for i, kind in enumerate(kinds):
        if kind in ("L", "R"):
            f.write(f"    RHS  R{i}  {p.rhs[i]:.17g}\n")
        elif kind == "G":
            f.write(f"    RHS  R{i}  {p.lhs[i]:.17g}\n")
        elif kind == "E":
            f.write(f"    RHS  R{i}  {p.rhs[i]:.17g}\n")
    f.write("RANGES\n")
    for i, kind in enumerate(kinds):
        if kind == "R":
            f.write(f"    RNG  R{i}  {p.rhs[i] - p.lhs[i]:.17g}\n")
    f.write("BOUNDS\n")
    for j in range(p.n):
        if p.lb[j] <= -INF:
            f.write(f" MI BND  C{j}\n")
        elif p.lb[j] != 0.0:
            f.write(f" LO BND  C{j}  {p.lb[j]:.17g}\n")
        if p.ub[j] < INF:
            f.write(f" UP BND  C{j}  {p.ub[j]:.17g}\n")
    f.write("ENDATA\n")
