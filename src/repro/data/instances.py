"""Synthetic MIP instance generator (MIPLIB-2017-like structural mixes).

The container is offline, so the paper's MIPLIB 2017 test bed is replaced by
a seeded generator reproducing the structural features the paper calls out:

  * highly sparse matrices with power-law row lengths (§1, §3);
  * a few very dense *connecting constraints* (§3: the CSR-vector case);
  * integrality mixes (§1.1 Step 3 rounding);
  * finite and infinite bounds / one-sided constraints (§3.4);
  * cascade chains -- the §2.2 price-of-parallelism worst case;
  * classic families (knapsack, set cover, bin packing, assignment) whose
    propagation behavior is well understood.

Sizes are scaled so the Set-1..Set-8 sweep (paper §4.1) completes on one CPU;
the set boundaries keep the paper's *relative* 2x spacing.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, List, Tuple

import numpy as np

from ..core.sparse import CSR, Problem, csr_from_coo
from ..core.types import INF


@dataclasses.dataclass(frozen=True)
class InstanceSpec:
    family: str
    m: int
    n: int
    seed: int


# ---------------------------------------------------------------------------
# Families
# ---------------------------------------------------------------------------


def make_knapsack(n: int = 50, m: int = 10, seed: int = 0) -> Problem:
    """m knapsack rows over n binary items: a^T x <= cap, a > 0."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    rhs = np.empty(m)
    for i in range(m):
        k = int(rng.integers(max(2, n // 4), max(3, n // 2)))
        js = rng.choice(n, size=k, replace=False)
        a = rng.integers(1, 20, size=k).astype(np.float64)
        rows += [i] * k
        cols += list(js)
        vals += list(a)
        rhs[i] = float(a.sum()) * rng.uniform(0.2, 0.5)
    csr = csr_from_coo(
        np.array(rows), np.array(cols), np.array(vals, dtype=np.float64), m, n
    )
    return Problem(
        csr=csr,
        lhs=np.full(m, -INF),
        rhs=rhs,
        lb=np.zeros(n),
        ub=np.ones(n),
        is_int=np.ones(n, dtype=bool),
    )


def make_set_cover(n: int = 60, m: int = 20, seed: int = 0) -> Problem:
    """sum_j x_j >= 1 over random supports; binary x."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    for i in range(m):
        k = int(rng.integers(2, max(3, n // 5)))
        js = rng.choice(n, size=k, replace=False)
        rows += [i] * k
        cols += list(js)
        vals += [1.0] * k
    csr = csr_from_coo(
        np.array(rows), np.array(cols), np.array(vals, dtype=np.float64), m, n
    )
    return Problem(
        csr=csr,
        lhs=np.ones(m),
        rhs=np.full(m, INF),
        lb=np.zeros(n),
        ub=np.ones(n),
        is_int=np.ones(n, dtype=bool),
    )


def make_bin_packing(items: int = 30, bins: int = 10, seed: int = 0) -> Problem:
    """x[i,b] binary assignment; capacity rows + assignment equalities."""
    rng = np.random.default_rng(seed)
    n = items * bins
    sizes = rng.integers(2, 12, size=items).astype(np.float64)
    cap = float(sizes.sum() / bins * 1.4)
    rows, cols, vals = [], [], []
    lhs, rhs = [], []
    r = 0
    for b in range(bins):  # capacity rows
        for i in range(items):
            rows.append(r)
            cols.append(i * bins + b)
            vals.append(sizes[i])
        lhs.append(-INF)
        rhs.append(cap)
        r += 1
    for i in range(items):  # assignment equalities: sum_b x[i,b] == 1
        for b in range(bins):
            rows.append(r)
            cols.append(i * bins + b)
            vals.append(1.0)
        lhs.append(1.0)
        rhs.append(1.0)
        r += 1
    csr = csr_from_coo(
        np.array(rows), np.array(cols), np.array(vals, dtype=np.float64), r, n
    )
    return Problem(
        csr=csr,
        lhs=np.array(lhs),
        rhs=np.array(rhs),
        lb=np.zeros(n),
        ub=np.ones(n),
        is_int=np.ones(n, dtype=bool),
    )


def make_assignment(size: int = 12, seed: int = 0) -> Problem:
    """Assignment polytope rows; LP-relaxed bounds on continuous x."""
    n = size * size
    rows, cols, vals = [], [], []
    lhs, rhs = [], []
    r = 0
    for i in range(size):
        for j in range(size):
            rows.append(r)
            cols.append(i * size + j)
            vals.append(1.0)
        lhs.append(1.0)
        rhs.append(1.0)
        r += 1
    for j in range(size):
        for i in range(size):
            rows.append(r)
            cols.append(i * size + j)
            vals.append(1.0)
        lhs.append(1.0)
        rhs.append(1.0)
        r += 1
    csr = csr_from_coo(
        np.array(rows), np.array(cols), np.array(vals, dtype=np.float64), r, n
    )
    return Problem(
        csr=csr,
        lhs=np.array(lhs),
        rhs=np.array(rhs),
        lb=np.zeros(n),
        ub=np.ones(n),
        is_int=np.zeros(n, dtype=bool),
    )


def make_cascade_chain(length: int = 64, seed: int = 0) -> Problem:
    """§2.2 worst case: x_{k+1} <= x_k chain seeded by x_0 <= 0.5.

    Sequential propagation resolves the chain in one round (forward order);
    the parallel algorithm needs ~``length`` rounds.
    """
    del seed
    n = length + 1
    m = length + 1
    rows, cols, vals = [], [], []
    lhs, rhs = [], []
    # Row 0: x_0 <= 0.5
    rows += [0]
    cols += [0]
    vals += [1.0]
    lhs.append(-INF)
    rhs.append(0.5)
    # Row k: x_k - x_{k-1} <= 0  =>  x_k <= x_{k-1}
    for k in range(1, length + 1):
        rows += [k, k]
        cols += [k, k - 1]
        vals += [1.0, -1.0]
        lhs.append(-INF)
        rhs.append(0.0)
    csr = csr_from_coo(
        np.array(rows), np.array(cols), np.array(vals, dtype=np.float64), m, n
    )
    return Problem(
        csr=csr,
        lhs=np.array(lhs),
        rhs=np.array(rhs),
        lb=np.zeros(n),
        ub=np.ones(n),
        is_int=np.zeros(n, dtype=bool),
    )


def make_pseudo_boolean(
    n: int = 80,
    m: int = 60,
    seed: int = 0,
    clause_frac: float = 0.65,
    unit_frac: float = 0.1,
) -> Problem:
    """Pseudo-boolean optimization instance (paper §1's explicit target
    workload): 0/1 variables, ±1 coefficients only.

    Rows mix three shapes:
      * clause-like rows (fraction ``clause_frac``) encoding
        ``x_{j1} v ... v ¬x_{jk}``: positive literals contribute ``+x_j``,
        negated ones ``-x_j``, and the side is ``sum >= 1 - #negated``
        (the standard linearization of a clause);
      * unit clauses (fraction ``unit_frac``) fixing a single literal --
        the seeds that make root propagation cascade through the clauses
        (a PB instance mid-search always carries branching units);
      * cardinality rows ``sum_j x_j <= k/2`` over a random support.
    """
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    lhs = np.empty(m)
    rhs = np.empty(m)
    for i in range(m):
        shape = rng.random()
        if shape < unit_frac:
            j = int(rng.integers(0, n))
            sign = 1.0 if rng.random() < 0.5 else -1.0
            rows.append(i)
            cols.append(j)
            vals.append(sign)
            lhs[i] = 1.0 if sign > 0 else 0.0  # x_j >= 1  /  -x_j >= 0
            rhs[i] = INF
            continue
        k = int(rng.integers(2, max(3, min(9, n))))
        js = rng.choice(n, size=k, replace=False)
        if shape < unit_frac + clause_frac:
            sign = rng.choice([-1.0, 1.0], size=k)
            if not (sign > 0).any():
                sign[rng.integers(0, k)] = 1.0  # keep at least one positive literal
            a = sign
            lhs[i] = 1.0 - float((sign < 0).sum())
            rhs[i] = INF
        else:
            a = np.ones(k)
            lhs[i] = -INF
            rhs[i] = float(max(1, k // 2))
        rows += [i] * k
        cols += list(js)
        vals += list(a)
    csr = csr_from_coo(
        np.array(rows), np.array(cols), np.array(vals, dtype=np.float64), m, n
    )
    return Problem(
        csr=csr,
        lhs=lhs,
        rhs=rhs,
        lb=np.zeros(n),
        ub=np.ones(n),
        is_int=np.ones(n, dtype=bool),
    )


def make_random_mip(
    n: int = 10,
    m: int = 14,
    seed: int = 0,
    ub_max: int = 3,
    tight: float = 0.35,
) -> Problem:
    """Small bounded pure-integer instances for exact solver cross-checks.

    Every variable is integer on ``[0, u_j]`` with ``u_j <= ub_max`` and
    all data is INTEGRAL -- coefficients in ``±[1, 4]``, sides rounded to
    integers -- so every activity and objective sum is an exact f64
    integer and the brute-force oracle comparison
    (``core.seq_ref.brute_force_solve`` vs ``core.solver.solve``) can be
    bitwise.  Rows mix ``<=``, ``>=`` and ranged shapes with integral
    sides drawn strictly inside each row's activity range (``tight``
    controls how deep they cut), so rows actually propagate; some seeds'
    ranged rows conflict and the instance is infeasible -- kept on
    purpose, the differential suite asserts the verdict matches the
    oracle either way.  Enumeration size is ``prod(u_j + 1)``: keep
    ``n * log2(ub_max + 1)`` near 20 for oracle-speed instances."""
    rng = np.random.default_rng(seed)
    lb = np.zeros(n)
    ub = rng.integers(1, ub_max + 1, size=n).astype(np.float64)
    rows, cols, vals = [], [], []
    lhs = np.empty(m)
    rhs = np.empty(m)
    for i in range(m):
        k = int(rng.integers(2, max(3, n // 2 + 1)))
        js = rng.choice(n, size=k, replace=False)
        a = rng.integers(1, 5, size=k).astype(np.float64)
        a *= rng.choice([-1.0, 1.0], size=k)
        amin = float(np.where(a > 0, a * lb[js], a * ub[js]).sum())
        amax = float(np.where(a > 0, a * ub[js], a * lb[js]).sum())
        kind = rng.random()
        q = rng.uniform(tight, 0.9)
        if kind < 0.45:
            lhs[i], rhs[i] = -INF, float(np.floor(amin + q * (amax - amin)))
        elif kind < 0.9:
            lhs[i], rhs[i] = float(np.ceil(amax - q * (amax - amin))), INF
        else:
            lo = float(np.ceil(amin + 0.25 * (amax - amin)))
            hi = float(np.floor(amax - 0.25 * (amax - amin)))
            lhs[i], rhs[i] = min(lo, hi), max(lo, hi)
        rows += [i] * k
        cols += list(js)
        vals += list(a)
    csr = csr_from_coo(
        np.array(rows), np.array(cols), np.array(vals, dtype=np.float64), m, n
    )
    return Problem(
        csr=csr,
        lhs=lhs,
        rhs=rhs,
        lb=lb,
        ub=ub,
        is_int=np.ones(n, dtype=bool),
    )


def make_banded(
    n: int = 100_000,
    m: int = 2_000,
    row_nnz: int = 24,
    band: int = 2_048,
    seed: int = 0,
    int_frac: float = 0.4,
) -> Problem:
    """Wide instance with column-banded rows (the favorably-large regime).

    Each row draws ``row_nnz`` integer-valued coefficients from a random
    ``band``-wide column window, modeling the column locality real models
    exhibit after ordering (paper App. B) -- the regime where the paper's
    speedups grow with size and where the column-slab partitioned engine
    keeps tile duplication near 1 (a row's band rarely straddles a slab
    boundary).  Data is integer-valued so engine cross-checks can assert
    exact agreement."""
    rng = np.random.default_rng(seed)
    row_nnz = min(row_nnz, band, n)
    rows = np.repeat(np.arange(m, dtype=np.int32), row_nnz)
    starts = rng.integers(0, max(1, n - band + 1), size=m)
    cols = np.empty(m * row_nnz, dtype=np.int64)
    for i in range(m):
        cols[i * row_nnz : (i + 1) * row_nnz] = starts[i] + rng.choice(
            min(band, n - starts[i]), size=row_nnz, replace=False
        )
    vals = rng.choice([-3.0, -2.0, -1.0, 1.0, 2.0, 3.0], size=m * row_nnz)
    csr = csr_from_coo(rows, cols.astype(np.int32), vals, m, n)
    lb = -rng.integers(0, 3, size=n).astype(np.float64)
    ub = rng.integers(1, 8, size=n).astype(np.float64)
    lb[rng.random(n) < 0.1] = -INF
    ub[rng.random(n) < 0.1] = INF
    is_int = rng.random(n) < int_frac
    absrow = np.zeros(m)
    np.add.at(absrow, rows, np.abs(vals) * 2.0)
    kind = rng.random(m)
    # lhs <= 0 <= rhs by construction (absrow >= 0), so no side swap needed.
    lhs = np.where(kind < 0.4, -INF, -absrow * 0.3)
    rhs = np.where(kind > 0.8, INF, absrow * 0.3)
    return Problem(csr=csr, lhs=lhs, rhs=rhs, lb=lb, ub=ub, is_int=is_int)


def make_mixed(
    m: int = 200,
    n: int = 150,
    seed: int = 0,
    density: float = 0.03,
    dense_rows: int = 2,
    int_frac: float = 0.6,
    inf_bound_frac: float = 0.15,
) -> Problem:
    """MIPLIB-like heterogeneous instance (power-law rows + dense connecting rows)."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    # Power-law-ish row lengths.
    base = max(2, int(density * n))
    raw = rng.pareto(2.0, size=m) + 1.0
    lengths = np.clip((raw * base).astype(int), 2, max(3, n // 2))
    # A few dense connecting rows (paper §3).
    dense_idx = rng.choice(m, size=min(dense_rows, m), replace=False)
    lengths[dense_idx] = max(3, int(n * 0.8))
    for i in range(m):
        k = int(lengths[i])
        js = rng.choice(n, size=k, replace=False)
        a = rng.choice([-1.0, 1.0], size=k) * rng.integers(1, 10, size=k)
        rows += [i] * k
        cols += list(js)
        vals += list(a.astype(np.float64))
    csr = csr_from_coo(
        np.array(rows), np.array(cols), np.array(vals, dtype=np.float64), m, n
    )
    # Bounds: mostly [0, U]; some infinite; integrality mix.
    ub = rng.integers(1, 10, size=n).astype(np.float64)
    lb = np.zeros(n)
    inf_mask = rng.random(n) < inf_bound_frac
    ub[inf_mask] = INF
    lb[rng.random(n) < inf_bound_frac * 0.5] = -INF
    is_int = rng.random(n) < int_frac
    # Sides: mix of <=, >=, ranged rows; scaled to row content for tightness.
    rid = csr.row_ids()
    absrow = np.zeros(m)
    np.add.at(absrow, rid, np.abs(csr.val) * 3.0)
    kind = rng.random(m)
    lhs = np.where(kind < 0.35, -INF, -absrow * rng.uniform(0.1, 0.4, m))
    rhs = np.where(kind > 0.85, INF, absrow * rng.uniform(0.1, 0.4, m))
    bad = lhs > rhs
    lhs[bad], rhs[bad] = rhs[bad], lhs[bad]
    return Problem(
        csr=csr, lhs=lhs, rhs=rhs, lb=lb, ub=ub, is_int=is_int
    )


FAMILIES: Dict[str, Callable[..., Problem]] = {
    "knapsack": make_knapsack,
    "set_cover": make_set_cover,
    "bin_packing": make_bin_packing,
    "assignment": make_assignment,
    "cascade": make_cascade_chain,
    "mixed": make_mixed,
    "pseudo_boolean": make_pseudo_boolean,
    "banded": make_banded,
    "random_mip": make_random_mip,
}


def make_instance(spec: InstanceSpec) -> Problem:
    if spec.family == "knapsack":
        return make_knapsack(n=spec.n, m=spec.m, seed=spec.seed)
    if spec.family == "set_cover":
        return make_set_cover(n=spec.n, m=spec.m, seed=spec.seed)
    if spec.family == "bin_packing":
        items = max(4, spec.n // 10)
        return make_bin_packing(items=items, bins=10, seed=spec.seed)
    if spec.family == "assignment":
        return make_assignment(size=max(3, int(np.sqrt(spec.n))), seed=spec.seed)
    if spec.family == "cascade":
        return make_cascade_chain(length=spec.m - 1, seed=spec.seed)
    if spec.family == "mixed":
        return make_mixed(m=spec.m, n=spec.n, seed=spec.seed)
    if spec.family == "pseudo_boolean":
        return make_pseudo_boolean(n=spec.n, m=spec.m, seed=spec.seed)
    if spec.family == "random_mip":
        # Solver-oracle family: n is clamped so the brute-force enumeration
        # (prod of domain widths) stays tractable whatever the spec asks.
        return make_random_mip(n=min(spec.n, 12), m=spec.m, seed=spec.seed)
    if spec.family == "banded":
        return make_banded(
            n=spec.n, m=spec.m, band=max(128, spec.n // 8), seed=spec.seed
        )
    raise ValueError(spec.family)


# Paper §4.1 size partition [s, t): scaled 100x down so the sweep runs on one
# CPU container while keeping the 2x set spacing.  "size" = max(m, n).
SIZE_SETS: List[Tuple[str, int, int]] = [
    ("Set-1", 10, 100),
    ("Set-2", 100, 200),
    ("Set-3", 200, 400),
    ("Set-4", 400, 800),
    ("Set-5", 800, 1600),
    ("Set-6", 1600, 3200),
    ("Set-7", 3200, 6400),
    ("Set-8", 6400, 12800),
]


def instances_for_set(
    set_name: str,
    per_family: int = 2,
    # pseudo_boolean appended LAST on purpose: the per-family rng draws are
    # sequential, so earlier families keep their exact pre-existing sizes.
    families: Tuple[str, ...] = ("mixed", "knapsack", "set_cover", "pseudo_boolean"),
) -> List[Tuple[InstanceSpec, Problem]]:
    lo, hi = next((a, b) for nm, a, b in SIZE_SETS if nm == set_name)
    out = []
    # NOT hash(): str hashes are salted per process (PYTHONHASHSEED), which
    # silently made every benchmark run on a different instance draw.
    rng = np.random.default_rng(zlib.crc32(set_name.encode("utf-8")))
    for fam in families:
        for k in range(per_family):
            size = int(rng.integers(lo, hi))
            m = size
            n = max(10, int(size * rng.uniform(0.6, 1.2)))
            spec = InstanceSpec(family=fam, m=m, n=n, seed=1000 + k + lo)
            out.append((spec, make_instance(spec)))
    return out
