"""Deterministic synthetic LM token pipeline.

Every (step, shard) batch is a pure function of (seed, step, shard_id):
after a preemption or an elastic resize, any host can regenerate exactly its
slice of the global batch with zero coordination -- the data-side half of
the fault-tolerance story (DESIGN.md §8).

The stream is Zipf-distributed token ids with short-range repetition
structure so cross-entropy decreases measurably during the example training
runs (pure uniform noise would pin the loss at log V).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    repeat_p: float = 0.3  # P(copy a recent token) -> learnable structure


def _batch_rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard])
    )


def make_batch(cfg: DataConfig, step: int, shard: int = 0, num_shards: int = 1):
    """Return {'tokens','labels'} for this shard of the global batch."""
    assert cfg.global_batch % num_shards == 0
    b = cfg.global_batch // num_shards
    rng = _batch_rng(cfg, step, shard)
    raw = rng.zipf(cfg.zipf_a, size=(b, cfg.seq_len + 1))
    toks = (raw - 1) % cfg.vocab_size
    # Inject copy structure: with prob repeat_p, token t = token t-k (k<=8).
    mask = rng.random((b, cfg.seq_len + 1)) < cfg.repeat_p
    lags = rng.integers(1, 9, size=(b, cfg.seq_len + 1))
    idx = np.maximum(np.arange(cfg.seq_len + 1)[None, :] - lags, 0)
    toks = np.where(mask, np.take_along_axis(toks, idx, axis=1), toks)
    toks = toks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenStream:
    """Stateless iterator facade used by the training driver."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1,
                 start_step: int = 0):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.step = start_step

    def __next__(self):
        batch = make_batch(self.cfg, self.step, self.shard, self.num_shards)
        self.step += 1
        return batch

    def __iter__(self):
        return self
