"""Data substrate: synthetic MIP instance generation (MIPLIB-like structural
mixes), a minimal MPS reader, and the deterministic LM token pipeline."""
from .instances import (
    InstanceSpec,
    make_instance,
    make_knapsack,
    make_set_cover,
    make_bin_packing,
    make_assignment,
    make_banded,
    make_cascade_chain,
    make_mixed,
    make_pseudo_boolean,
    make_random_mip,
    SIZE_SETS,
    instances_for_set,
)
from .mps import read_mps, write_mps
