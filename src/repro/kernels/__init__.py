"""Pallas TPU kernels for the paper's compute hot spot -- the fused
propagation round (Alg. 3) -- plus jnp oracles (ref.py) and the jit'd
block-ELL propagation engine (ops.py) with its fully fused scatter round."""
from .ops import (
    DeviceBlockEll,
    DeviceProblemBatch,
    PreparedBlockEll,
    PreparedBatch,
    device_block_ell,
    prepare_block_ell,
    clear_prepare_cache,
    block_ell_round,
    round_fn_for,
    legacy_round_fn_for,
    round_cost_analysis,
    propagate_block_ell,
    prepare_problem_batch,
    batched_round_fn_for,
    batched_reference_round,
    propagate_batch_prepared,
    propagate_batch_block_ell,
    batched_device_runner,
    packed_problems,
    clear_batch_caches,
    rows_fit_one_chunk,
    SCATTER_MAX_NPAD,
)
from .prop_round import (
    activities_tiles,
    activities_gather_tiles,
    candidates_tiles,
    fused_round_tiles,
    fused_scatter_round_tiles,
    candidates_scatter_tiles,
    apply_updates_tiles,
    batched_fused_scatter_round_tiles,
    apply_updates_batch_tiles,
    col_pad,
)
from . import ref
