"""Pallas TPU kernels for the paper's compute hot spot -- the fused
propagation round (Alg. 3) -- plus jnp oracles (ref.py) and the jit'd
block-ELL propagation engine (ops.py)."""
from .ops import (
    DeviceBlockEll,
    device_block_ell,
    block_ell_round,
    propagate_block_ell,
    rows_fit_one_chunk,
)
from .prop_round import activities_tiles, candidates_tiles, fused_round_tiles
from . import ref
