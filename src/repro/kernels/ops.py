"""Jit'd public wrappers around the Pallas kernels: a complete block-ELL
propagation engine (gathers + kernels + segment reductions + bound update).

This is the kernel-backed sibling of ``core.propagator``; both share the
bound-update logic so they converge to identical fixed points.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bounds as bnd
from ..core.sparse import BlockEll, Problem, csr_to_block_ell
from ..core.types import DEFAULT_CONFIG, INF, PropagationResult, PropagatorConfig
from . import prop_round as kern
from . import ref as kref


class DeviceBlockEll(NamedTuple):
    """Device-resident block-ELL instance (pytree)."""

    val: jnp.ndarray        # (T, R, K)
    col: jnp.ndarray        # (T, R, K) int32
    chunk_row: jnp.ndarray  # (T, R) int32 in [0, m]; m == padding
    lhs1: jnp.ndarray       # (m+1,) sides padded with one dummy slot at index m
    rhs1: jnp.ndarray       # (m+1,)
    is_int: jnp.ndarray     # (n,) bool
    lb0: jnp.ndarray        # (n,)
    ub0: jnp.ndarray        # (n,)


def device_block_ell(p: Problem, tile_rows: int = 8, tile_width: int = 128, dtype=None) -> DeviceBlockEll:
    dtype = dtype or p.csr.val.dtype
    b = csr_to_block_ell(p.csr, tile_rows=tile_rows, tile_width=tile_width)
    pad1 = lambda x: np.concatenate([x, np.zeros(1, dtype=x.dtype)])
    return DeviceBlockEll(
        val=jnp.asarray(b.val, dtype=dtype),
        col=jnp.asarray(b.col),
        chunk_row=jnp.asarray(b.chunk_row),
        lhs1=jnp.asarray(pad1(p.lhs), dtype=dtype),
        rhs1=jnp.asarray(pad1(p.rhs), dtype=dtype),
        is_int=jnp.asarray(p.is_int),
        lb0=jnp.asarray(p.lb, dtype=dtype),
        ub0=jnp.asarray(p.ub, dtype=dtype),
    )


def rows_fit_one_chunk(p: Problem, tile_width: int) -> bool:
    return int(np.diff(p.csr.row_ptr).max(initial=0)) <= tile_width


# ---------------------------------------------------------------------------
# One block-ELL round
# ---------------------------------------------------------------------------


def block_ell_round(
    d: DeviceBlockEll,
    lb,
    ub,
    m: int,
    n: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
    use_pallas: bool = True,
    fused: bool = False,
    interpret: bool | None = None,
):
    """One propagation round over block-ELL tiles. Returns (lb, ub, changed)."""
    lb_g = lb[d.col]
    ub_g = ub[d.col]
    ii_g = d.is_int[d.col]
    lhs_g = d.lhs1[d.chunk_row]
    rhs_g = d.rhs1[d.chunk_row]

    if fused:
        # Alg.-3-faithful: activities live in VMEM, reused for candidates.
        if use_pallas:
            lcand, ucand = kern.fused_round_tiles(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf, interpret
            )
        else:
            lcand, ucand = kref.fused_round_tiles_ref(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf
            )
    else:
        if use_pallas:
            mf, mc, xf, xc = kern.activities_tiles(d.val, lb_g, ub_g, inf, interpret)
        else:
            mf, mc, xf, xc = kref.activities_tiles_ref(d.val, lb_g, ub_g, inf)
        # Combine chunk partials into completed row aggregates (long rows).
        crow = d.chunk_row.reshape(-1)
        seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=m + 1)
        row_mf, row_mc = seg(mf), seg(mc)
        row_xf, row_xc = seg(xf), seg(xc)
        # Gather completed aggregates back per chunk.
        g = lambda x: x[d.chunk_row]
        if use_pallas:
            lcand, ucand = kern.candidates_tiles(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.candidates_tiles_ref(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf,
            )

    flat_col = d.col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=n)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=n)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


# ---------------------------------------------------------------------------
# Full propagation drivers over block-ELL
# ---------------------------------------------------------------------------


def propagate_block_ell(
    p: Problem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    fused: str = "auto",
    driver: str = "device_loop",
    interpret: bool | None = None,
) -> PropagationResult:
    """Kernel-backed propagation.  ``fused='auto'`` picks the Alg.-3 fusion
    whenever every row fits in one chunk (the paper's common case)."""
    d = device_block_ell(p, tile_rows, tile_width, dtype)
    m, n = p.m, p.n
    do_fuse = (
        rows_fit_one_chunk(p, tile_width) if fused == "auto" else bool(fused == "yes" or fused is True)
    )
    eps = cfg.eps_for(d.val.dtype)
    round_fn = functools.partial(
        block_ell_round,
        d,
        m=m,
        n=n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=do_fuse,
        interpret=interpret,
    )

    if driver == "host_loop":
        jit_round = jax.jit(round_fn)
        lb, ub = d.lb0, d.ub0
        rounds, changed = 0, True
        while changed and rounds < cfg.max_rounds:
            lb, ub, cdev = jit_round(lb, ub)
            changed = bool(cdev)
            rounds += 1
        infeas = bool(jnp.any(lb > ub + cfg.feas_eps))
        return PropagationResult(
            lb, ub, jnp.int32(rounds), jnp.asarray(not changed), jnp.asarray(infeas)
        )

    @jax.jit
    def run(lb0, ub0):
        def body(state):
            lb, ub, _, r = state
            lb, ub, ch = round_fn(lb, ub)
            return lb, ub, ch, r + 1

        def cond(state):
            _, _, ch, r = state
            return ch & (r < cfg.max_rounds)

        lb, ub, ch, r = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        return lb, ub, r, ~ch, jnp.any(lb > ub + cfg.feas_eps)

    lb, ub, rounds, converged, infeasible = run(d.lb0, d.ub0)
    return PropagationResult(lb, ub, rounds, converged, infeasible)
