"""Jit'd public wrappers around the Pallas kernels: a complete block-ELL
propagation engine (kernels + column reduction + bound update).

This is the kernel-backed sibling of ``core.propagator``; both share the
bound-update logic so they converge to identical fixed points.

Engine anatomy (see README "fused-scatter dataflow"):

  * ``prepare_block_ell`` -- one-time, cached per instance: block-ELL
    conversion, device transfer, and the *round-constant* gathers
    (``is_int[col]``, ``lhs1[chunk_row]``, ``rhs1[chunk_row]``) that the seed
    engine recomputed every round.
  * ``scatter="fused"`` -- the fully fused round: one Pallas kernel gathers
    the bounds in-kernel from the VMEM-resident (n_pad,) vectors, computes
    activities and candidates, AND does the column-wise best-bound
    reduction into ``(2, n_pad)`` accumulators that stay in VMEM across all
    grid steps; a small merge kernel then folds them into (lb, ub) in place
    (``input_output_aliases``).  NO nnz-shaped tensor -- neither gathered
    bounds nor candidates -- is produced in HBM during a round.
  * ``scatter="partitioned"`` -- the column-slab engine for instances whose
    ``n_pad`` exceeds the VMEM accumulator budget: the padded column space
    is split into balanced slabs (``default_slab_width``, capped at
    ``SLAB_NPAD``), the tile stream into per-slab
    masked copies (``build_slab_partition``, cached on the prep), and the
    round runs two-phase -- per-copy activity partials with in-window
    gather, a tiny ``(T', R)`` XLA segment combine, candidates + per-slab
    scatter -- so only ``(1, S)`` bound/accumulator windows are ever
    VMEM-resident and the fused byte model holds at any instance size.
    ``scatter="auto"`` selects it beyond ``SCATTER_MAX_NPAD``.
  * ``scatter="segment"`` -- the materializing oracle: XLA bound gathers,
    candidates written to HBM, column reduction via XLA segment ops (the
    seed dataflow, kept for cross-validation).
  * Zero-copy fixed point: every jitted driver donates the (lb, ub) buffers
    (``donate_argnums``) so XLA updates bounds in place round over round.
    Donation is requested only on backends that implement it (TPU/GPU); the
    drivers hand the loop *private copies* of the cached initial bounds so
    donation can never invalidate the prepare() cache.

Per-round HBM-traffic model (8-byte fp, 4-byte ints, nnz_pad = T*R*K):

  segment (seed): gather writes+reads 2x lb/ub + is_int (~40 B/nnz), tile
    reads val+col (~12 B/nnz), candidate writes (~16 B/nnz), segment-op
    candidate+col reads (~24 B/nnz)   => ~92 B/nnz + O(m + n)
  fused:          tile reads val+col+is_int (~16 B/nnz) + O(m + n_pad)
    for the resident bound/accumulator vectors and row aggregates

``round_cost_analysis`` measures this at the HBM boundary of the actual
lowered round instead of asserting it.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bounds as bnd
from ..core.propagator import (
    batched_fixed_point,
    donate_kwargs,
    donate_supported,
    owned_copy,
)
from ..core.sparse import (
    BlockEll,
    Problem,
    ProblemBatch,
    csr_to_block_ell,
    pack_problems,
)
from ..core.types import DEFAULT_CONFIG, INF, PropagationResult, PropagatorConfig
from . import prop_round as kern
from . import ref as kref


class DeviceBlockEll(NamedTuple):
    """Device-resident block-ELL instance (pytree)."""

    val: jnp.ndarray        # (T, R, K)
    col: jnp.ndarray        # (T, R, K) int32
    chunk_row: jnp.ndarray  # (T, R) int32 in [0, m]; m == padding
    lhs1: jnp.ndarray       # (m+1,) sides padded with one dummy slot at index m
    rhs1: jnp.ndarray       # (m+1,)
    is_int: jnp.ndarray     # (n,) bool
    lb0: jnp.ndarray        # (n,)
    ub0: jnp.ndarray        # (n,)


def device_block_ell(p: Problem, tile_rows: int = 8, tile_width: int = 128, dtype=None) -> DeviceBlockEll:
    """Convert + upload one instance: block-ELL tiles of shape
    ``(tile_rows, tile_width)``, sides padded with a dummy slot for the
    padding row, bounds and integrality marks as ``(n,)`` device arrays.
    Prefer :func:`prepare_block_ell`, which caches this and hoists the
    round-constant gathers."""
    dtype = dtype or p.csr.val.dtype
    b = csr_to_block_ell(p.csr, tile_rows=tile_rows, tile_width=tile_width)
    pad1 = lambda x: np.concatenate([x, np.zeros(1, dtype=x.dtype)])
    return DeviceBlockEll(
        val=jnp.asarray(b.val, dtype=dtype),
        col=jnp.asarray(b.col),
        chunk_row=jnp.asarray(b.chunk_row),
        lhs1=jnp.asarray(pad1(p.lhs), dtype=dtype),
        rhs1=jnp.asarray(pad1(p.rhs), dtype=dtype),
        is_int=jnp.asarray(p.is_int),
        lb0=jnp.asarray(p.lb, dtype=dtype),
        ub0=jnp.asarray(p.ub, dtype=dtype),
    )


def rows_fit_one_chunk(p: Problem, tile_width: int) -> bool:
    """True iff every row's nonzeros fit one ``tile_width``-wide chunk --
    the condition for the single-kernel fused round (no cross-chunk
    activity combine needed)."""
    return int(np.diff(p.csr.row_ptr).max(initial=0)) <= tile_width


# ---------------------------------------------------------------------------
# Column-slab partitioning: the tile stream re-bucketed per VMEM-sized slab
# ---------------------------------------------------------------------------


class SlabPartition(NamedTuple):
    """A block-ELL tile stream re-bucketed by column slabs (device-ready).

    The padded column space is split into ``n_slabs`` windows of ``slab``
    columns; each source tile becomes one COPY per slab it touches, keeping
    only the nonzeros whose columns fall in that slab (``val == 0``
    elsewhere, exactly the block-ELL padding convention).  Copies are
    sorted by ``(instance, slab, source tile)`` so each ``(instance,
    slab)`` window's bound/accumulator blocks stay VMEM-resident across
    its contiguous copies in the partitioned kernels; every window is
    covered (synthetic all-padding copies fill empty ones) so accumulators
    are always initialized.  Built once per prepared instance/bucket by
    :func:`build_slab_partition` and cached (see
    ``PreparedBlockEll.slab_partition``)."""

    val: jnp.ndarray        # (T', R, K) slab-masked copies; 0 == padding
    col_s: jnp.ndarray      # (T', R, K) int32 slab-LOCAL columns
    chunk_row: jnp.ndarray  # (T', R) int32 rows (global ids in batched use)
    tile_inst: jnp.ndarray  # (T',) int32 instance of each copy (0 if single)
    tile_slab: jnp.ndarray  # (T',) int32 slab of each copy
    ii_g: jnp.ndarray       # (T', R, K) int32 is_int at each kept nonzero
    lhs_g: jnp.ndarray      # (T', R) sides gathered per chunk
    rhs_g: jnp.ndarray      # (T', R)
    slab: int               # S: columns per slab (multiple of LANE)
    n_slabs: int            # windows per instance
    n_pad_part: int         # n_slabs * slab >= n_pad
    source_tiles: int       # T of the unpartitioned stream

    @property
    def num_copies(self) -> int:
        return int(self.val.shape[0])

    @property
    def duplication(self) -> float:
        """Copy blowup vs the source stream (1.0 == no tile straddles)."""
        return self.num_copies / max(1, self.source_tiles)


def build_slab_partition(
    val: np.ndarray,
    col: np.ndarray,
    chunk_row: np.ndarray,
    tile_inst: np.ndarray,
    lhs1: np.ndarray,
    rhs1: np.ndarray,
    is_int_rows: np.ndarray,
    n_pad: int,
    slab: int,
    dummy_rows: np.ndarray,
) -> SlabPartition:
    """Host-side slab bucketing of a (possibly batched) block-ELL stream.

    ``val``/``col`` are ``(T, R, K)`` tiles with instance-local columns;
    ``chunk_row`` carries the row ids the activity combine segments over
    (global across instances in batched use); ``lhs1``/``rhs1`` are the
    side vectors those ids index; ``is_int_rows`` is the ``(B, n_pad)``
    integrality plane and ``dummy_rows`` each instance's padding row.

    Tiles whose nonzero columns span several slabs are duplicated once per
    touched slab with the out-of-slab nonzeros masked to padding -- rare
    when columns are locally clustered, and bounded by ``n_slabs`` copies
    in the worst case (``SlabPartition.duplication`` reports the measured
    blowup).  Synthetic all-padding copies cover ``(instance, slab)``
    windows that no tile touches, so every accumulator window is visited
    and initialized."""
    val = np.asarray(val)
    col = np.asarray(col)
    chunk_row = np.asarray(chunk_row)
    tile_inst = np.asarray(tile_inst, dtype=np.int64)
    is_int_rows = np.asarray(is_int_rows)
    dummy_rows = np.asarray(dummy_rows, dtype=np.int32)
    t, r, k = val.shape
    dt = val.dtype
    if slab % kern.LANE:
        raise ValueError(f"slab={slab} must be a multiple of LANE={kern.LANE}")
    n_slabs = -(-n_pad // slab)
    n_pad_part = n_slabs * slab
    bsz = int(dummy_rows.shape[0])

    nz = val != 0
    slab_of = np.where(nz, col // slab, 0)
    touched = np.zeros((t, n_slabs), dtype=bool)
    t_idx = np.broadcast_to(np.arange(t)[:, None, None], val.shape)
    touched[t_idx[nz], slab_of[nz]] = True
    # All-padding source tiles ride slab 0 so T' >= T and no tile vanishes.
    touched[~touched.any(axis=1), 0] = True

    t_ids, s_ids = np.nonzero(touched)  # tile-major copy list
    inst_ids = tile_inst[t_ids]

    pv = val[t_ids]
    pc = col[t_ids]
    keep = (pv != 0) & ((pc // slab) == s_ids[:, None, None])
    pval = np.where(keep, pv, 0).astype(dt)
    pcol = np.where(keep, pc - s_ids[:, None, None] * slab, 0).astype(np.int32)
    pii = np.where(keep, is_int_rows[inst_ids[:, None, None], pc], False)
    pchunk = chunk_row[t_ids].astype(np.int32)

    # Synthetic all-padding copies for uncovered (instance, slab) windows:
    # their chunks target the instance's dummy row, their candidates are
    # sentinels, so they only initialize the window's accumulators.
    cover = np.zeros((bsz, n_slabs), dtype=bool)
    cover[inst_ids, s_ids] = True
    miss_i, miss_s = np.nonzero(~cover)
    if miss_i.size:
        c = miss_i.size
        pval = np.concatenate([pval, np.zeros((c, r, k), dt)])
        pcol = np.concatenate([pcol, np.zeros((c, r, k), np.int32)])
        pii = np.concatenate([pii, np.zeros((c, r, k), bool)])
        pchunk = np.concatenate(
            [pchunk, np.broadcast_to(dummy_rows[miss_i][:, None], (c, r)).astype(np.int32)]
        )
        inst_ids = np.concatenate([inst_ids, miss_i])
        s_ids = np.concatenate([s_ids, miss_s])
        t_ids = np.concatenate([t_ids, np.full(c, t, dtype=t_ids.dtype)])

    # (instance, slab, source-tile) order: each (instance, slab) window is
    # one contiguous run, tiles in stream order within it.
    order = np.lexsort((t_ids, s_ids, inst_ids))
    pval, pcol, pii = pval[order], pcol[order], pii[order]
    pchunk = pchunk[order]
    inst_ids, s_ids = inst_ids[order], s_ids[order]

    lhs1 = np.asarray(lhs1, dtype=dt)
    rhs1 = np.asarray(rhs1, dtype=dt)
    # The partition may be built lazily inside a jit trace (the first round
    # closure that needs it); materialize concrete device constants there
    # instead of leaking trace-scoped tracers into the prep cache.
    with jax.ensure_compile_time_eval():
        return SlabPartition(
            val=jnp.asarray(pval),
            col_s=jnp.asarray(pcol),
            chunk_row=jnp.asarray(pchunk),
            tile_inst=jnp.asarray(inst_ids.astype(np.int32)),
            tile_slab=jnp.asarray(s_ids.astype(np.int32)),
            ii_g=jnp.asarray(pii.astype(np.int32)),
            lhs_g=jnp.asarray(lhs1[pchunk]),
            rhs_g=jnp.asarray(rhs1[pchunk]),
            slab=int(slab),
            n_slabs=int(n_slabs),
            n_pad_part=int(n_pad_part),
            source_tiles=t,
        )


# ---------------------------------------------------------------------------
# Prepared instances: one-time setup, hoisted round constants, LRU-cached
# ---------------------------------------------------------------------------

# Largest column-padded width the fused scatter keeps resident in VMEM
# (2 accumulators x n_pad x 8 B = 1 MiB at the cap; ~6% of a v5e core's VMEM).
SCATTER_MAX_NPAD = 1 << 16

# Cap on the partitioned engine's column-slab width: one slab's resident
# state is at most what the fused engine keeps at its cap, so any instance
# the fused engine could hold is one slab of the partitioned one.  The
# default width is BALANCED below the cap (``default_slab_width``) so the
# slab grid overhangs the padded domain by less than one lane row per slab
# instead of up to a whole slab.
SLAB_NPAD = SCATTER_MAX_NPAD


def default_slab_width(n_pad: int, cap: int | None = None) -> int:
    """Balanced column-slab width for a padded domain: the fewest slabs
    whose width stays within the VMEM cap (:data:`SLAB_NPAD`), each width a
    LANE multiple, so ``n_pad_part - n_pad < LANE * n_slabs`` -- the
    per-round pad/slice of the partitioned dataflow stays negligible."""
    cap = SLAB_NPAD if cap is None else int(cap)
    n_slabs = max(1, -(-n_pad // cap))
    return -(-n_pad // (n_slabs * kern.LANE)) * kern.LANE


class LRU:
    """Bounded LRU keyed by tuples that embed ``id()`` of host objects.

    Every entry pins its ``anchors`` (the objects whose ids appear in the
    key) so an id cannot be recycled while the entry is live, and a hit is
    honoured only if every anchor is still the identical object.  Counts
    hits/misses for ``cache_info()``; ``on_evict`` lets dependent caches
    (compiled runners pinning a prep's device tiles) be purged with it.
    """

    def __init__(self, maxsize: int, on_evict=None):
        self.maxsize = maxsize
        self._d: "OrderedDict[tuple, tuple[tuple, object]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self._on_evict = on_evict

    def get(self, key, anchors: tuple):
        hit = self._d.get(key)
        if hit is not None and all(a is b for a, b in zip(hit[0], anchors)):
            self._d.move_to_end(key)
            self.hits += 1
            return hit[1]
        self.misses += 1
        return None

    def put(self, key, anchors: tuple, value) -> None:
        self._d[key] = (anchors, value)
        while len(self._d) > self.maxsize:
            _, (anchors_e, value_e) = self._d.popitem(last=False)
            if self._on_evict is not None:
                self._on_evict(anchors_e, value_e)

    def drop_where(self, pred) -> None:
        """Remove every entry whose ``(anchors, value)`` satisfies ``pred``."""
        for key in [k for k, v in self._d.items() if pred(*v)]:
            del self._d[key]

    def clear(self) -> None:
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)

    def info(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "size": len(self._d),
            "maxsize": self.maxsize,
        }


@dataclasses.dataclass(frozen=True)
class PreparedBlockEll:
    """Device tiles + everything about a round that does not change across
    rounds: the constant gathers the seed engine recomputed per round, the
    column-padded initial bounds, and static layout facts.

    Not a pytree on purpose -- drivers close over it, so its arrays become
    jit constants and its ints/bools stay static.  The round closures read
    only MATRIX STRUCTURE from it (``d``, the hoisted gathers, the layout
    ints); ``lb0``/``ub0`` are per-problem defaults that every driver
    accepts as runtime overrides, so one prepared engine serves any bounds
    (the warm-start / tree-search contract).
    """

    d: DeviceBlockEll
    ii_g: jnp.ndarray    # (T, R, K) int32: is_int[col], hoisted
    lhs_g: jnp.ndarray   # (T, R): lhs1[chunk_row], hoisted
    rhs_g: jnp.ndarray   # (T, R): rhs1[chunk_row], hoisted
    lb0: jnp.ndarray     # (n_pad,) default initial bounds (column-padded)
    ub0: jnp.ndarray     # (n_pad,)
    m: int
    n: int
    n_pad: int
    fits_one_chunk: bool
    # Slab partitions derived from the (immutable) tiles, built lazily and
    # keyed by slab width; shared by bounds-swapped views of this prep.
    _slabs: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False
    )

    def slab_partition(self, slab: int | None = None) -> SlabPartition:
        """This instance's tile stream re-bucketed into ``slab``-wide column
        windows (default: :func:`default_slab_width`, balanced below the
        :data:`SLAB_NPAD` cap), for the ``partitioned`` engine.

        Built once per slab width from the resident tiles (a host-side
        pass over the block-ELL arrays) and cached on the prep, so rounds
        and recompilations never pay it again."""
        s = default_slab_width(self.n_pad) if slab is None else int(slab)
        part = self._slabs.get(s)
        if part is None:
            d = self.d
            is_int_rows = np.zeros((1, self.n_pad), dtype=bool)
            is_int_rows[0, : self.n] = np.asarray(d.is_int)
            part = build_slab_partition(
                np.asarray(d.val),
                np.asarray(d.col),
                np.asarray(d.chunk_row),
                np.zeros(d.val.shape[0], dtype=np.int32),
                np.asarray(d.lhs1),
                np.asarray(d.rhs1),
                is_int_rows,
                self.n_pad,
                s,
                np.array([self.m], dtype=np.int32),
            )
            self._slabs[s] = part
        return part

    def pad_bound(self, arr):
        """One caller bound vector -> the column-padded ``(n_pad,)`` domain
        (padded columns sit at 0, the same trivially-converged fill prepare
        uses)."""
        dt = self.d.val.dtype
        a = jnp.asarray(arr, dt)
        if a.shape != (self.n,):
            raise ValueError(f"bounds have shape {a.shape}, expected {(self.n,)}")
        if self.n_pad > self.n:
            a = jnp.concatenate([a, jnp.zeros((self.n_pad - self.n,), dt)])
        return a

    def pad_bounds(self, lb, ub):
        return self.pad_bound(lb), self.pad_bound(ub)


# Structure anchors: a prepared engine depends on the matrix, the sides and
# the integrality marks -- NOT on the bounds.  Keying prepare on these means
# a B&B node built as ``root._replace(lb=..., ub=...)`` (same csr/lhs/rhs/
# is_int objects) hits the cache and reuses the resident tiles.
def _structure_anchors(p: Problem) -> tuple:
    return (p.csr, p.lhs, p.rhs, p.is_int)


def _drop_runners_for(anchors, value) -> None:
    """Prep-cache eviction hook: compiled runners close over the evicted
    prep's device tiles, so dropping them alongside keeps device memory
    bounded by the prepare LRU, not by the (larger) runner LRUs."""
    _, prep = value
    tiles = prep.d.val
    dead = lambda runner_anchors, _runner: runner_anchors[0] is tiles
    _runner_cache.drop_where(dead)
    _node_runner_cache.drop_where(dead)


_prep_cache = LRU(maxsize=32, on_evict=_drop_runners_for)


def prepare_block_ell(
    p: Problem, tile_rows: int = 8, tile_width: int = 128, dtype=None
) -> PreparedBlockEll:
    """One-time setup for kernel-backed propagation, LRU-cached per matrix
    STRUCTURE (``csr``/``lhs``/``rhs``/``is_int`` identity -- maxsize 32,
    see ``cache_info()``).

    Repeated propagations of the same ``Problem`` -- or of a bounds-only
    variant like a tree-search node (``p._replace(lb=..., ub=...)``) --
    reuse the block-ELL tiles, device buffers and hoisted gathers instead
    of rebuilding and re-transferring them.  The cache pins the keyed
    structure arrays so ``id()`` keys cannot be recycled while an entry is
    live; a hit from a problem whose bounds differ from the cached defaults
    returns a cheap bounds-swapped view sharing every device tile.
    """
    dt = np.dtype(dtype) if dtype is not None else np.dtype(p.csr.val.dtype)
    anchors = _structure_anchors(p)
    key = tuple(id(a) for a in anchors) + (tile_rows, tile_width, dt.str)
    hit = _prep_cache.get(key, anchors)
    if hit is not None:
        creator, prep = hit
        if creator.lb is p.lb and creator.ub is p.ub:
            return prep
        # Bounds-swapped view: every heavy array (tiles, hoisted gathers) is
        # shared with the cached prep, and BOTH bound carriers -- the padded
        # prep.lb0/ub0 and the unpadded d.lb0/ub0 -- reflect p's bounds, so
        # legacy readers of d.lb0 cannot silently see the creator's domain.
        # Runner caches key on id(d.val) (stable across _replace), so the
        # view reuses the creator's compiled fixed points.
        lb0, ub0 = prep.pad_bounds(p.lb, p.ub)
        d = prep.d._replace(
            lb0=jnp.asarray(p.lb, dt), ub0=jnp.asarray(p.ub, dt)
        )
        return dataclasses.replace(prep, d=d, lb0=lb0, ub0=ub0)

    d = device_block_ell(p, tile_rows, tile_width, dt)
    n_pad = kern.col_pad(p.n)
    padn = lambda x: jnp.concatenate([x, jnp.zeros((n_pad - p.n,), x.dtype)])
    prep = PreparedBlockEll(
        d=d,
        ii_g=d.is_int[d.col].astype(jnp.int32),
        lhs_g=d.lhs1[d.chunk_row],
        rhs_g=d.rhs1[d.chunk_row],
        lb0=padn(d.lb0) if n_pad > p.n else d.lb0,
        ub0=padn(d.ub0) if n_pad > p.n else d.ub0,
        m=p.m,
        n=p.n,
        n_pad=n_pad,
        fits_one_chunk=rows_fit_one_chunk(p, tile_width),
    )
    _prep_cache.put(key, anchors, (p, prep))
    return prep


def clear_prepare_cache() -> None:
    """Drop all cached prepared instances and their compiled single-instance
    / node-batch runners (frees device buffers)."""
    _prep_cache.clear()
    _runner_cache.clear()
    _node_runner_cache.clear()


# ---------------------------------------------------------------------------
# One block-ELL round
# ---------------------------------------------------------------------------


def block_ell_round(
    d: DeviceBlockEll,
    lb,
    ub,
    m: int,
    n: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
    use_pallas: bool = True,
    fused: bool = False,
    interpret: bool | None = None,
):
    """One propagation round over block-ELL tiles (seed dataflow, kept as the
    legacy baseline: per-round constant gathers, candidates materialized in
    HBM, XLA segment reduction).  Returns (lb, ub, changed)."""
    lb_g = lb[d.col]
    ub_g = ub[d.col]
    ii_g = d.is_int[d.col]
    lhs_g = d.lhs1[d.chunk_row]
    rhs_g = d.rhs1[d.chunk_row]

    if fused:
        # Alg.-3-faithful: activities live in VMEM, reused for candidates.
        if use_pallas:
            lcand, ucand = kern.fused_round_tiles(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf, interpret
            )
        else:
            lcand, ucand = kref.fused_round_tiles_ref(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf
            )
    else:
        if use_pallas:
            mf, mc, xf, xc = kern.activities_tiles(d.val, lb_g, ub_g, inf, interpret)
        else:
            mf, mc, xf, xc = kref.activities_tiles_ref(d.val, lb_g, ub_g, inf)
        # Combine chunk partials into completed row aggregates (long rows).
        crow = d.chunk_row.reshape(-1)
        seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=m + 1)
        row_mf, row_mc = seg(mf), seg(mc)
        row_xf, row_xc = seg(xf), seg(xc)
        # Gather completed aggregates back per chunk.
        g = lambda x: x[d.chunk_row]
        if use_pallas:
            lcand, ucand = kern.candidates_tiles(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.candidates_tiles_ref(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf,
            )

    flat_col = d.col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=n)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=n)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


def _combine_chunk_partials(prep: PreparedBlockEll, mf, mc, xf, xc):
    """Chunk partials -> completed per-chunk row aggregates (long rows)."""
    d = prep.d
    crow = d.chunk_row.reshape(-1)
    seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=prep.m + 1)
    g = lambda x: seg(x)[d.chunk_row]
    return g(mf), g(mc), g(xf), g(xc)


def _combine_copy_partials(part: SlabPartition, num_rows: int, mf, mc, xf, xc):
    """Per-copy activity partials -> completed aggregates gathered back per
    copy.  Rows whose nonzeros are split across slab copies (or chunks)
    complete here; the combine is a tiny ``(T', R)``-sized XLA segment sum,
    the only inter-slab dataflow of a partitioned round."""
    crow = part.chunk_row.reshape(-1)
    seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=num_rows)
    g = lambda x: seg(x)[part.chunk_row]
    return g(mf), g(mc), g(xf), g(xc)


def _partitioned_pallas_round(
    part: SlabPartition, lb, ub, active, num_rows: int,
    *, node: bool, eps: float, int_eps: float, inf: float,
    interpret: bool | None,
):
    """The one slab-round dataflow every partitioned engine shares, over
    ``(B, n_pad)`` bound planes: pad to the slab grid -> per-copy activity
    partials -> ``(T', R)`` segment combine -> candidates + per-slab
    scatter -> slab-gridded merge -> slice back.

    ``node=True`` sweeps ONE instance's copies per node on the ``(B, T')``
    grid (per-node bound windows, per-node partials combined under vmap);
    otherwise copies route by their own instance id on the flat ``(T',)``
    grid (single-instance callers pass ``B == 1``).  Returns the updated
    ``(B, n_pad)`` planes and the ``(B,)`` changed flags."""
    bsz, n_pad = lb.shape
    extra = part.n_pad_part - n_pad
    if extra:
        z = jnp.zeros((bsz, extra), lb.dtype)
        lbp = jnp.concatenate([lb, z], axis=1)
        ubp = jnp.concatenate([ub, z], axis=1)
    else:
        lbp, ubp = lb, ub
    if node:
        mf, mc, xf, xc = kern.node_activities_slab_tiles(
            part.val, part.col_s, part.tile_slab, active, lbp, ubp,
            part.slab, inf, interpret,
        )
        crow = part.chunk_row.reshape(-1)
        seg1 = lambda x: jax.ops.segment_sum(x, crow, num_segments=num_rows)
        g = lambda x: jax.vmap(seg1)(x.reshape(bsz, -1))[:, part.chunk_row]
        rmf, rmc, rxf, rxc = g(mf), g(mc), g(xf), g(xc)
        best_l, best_u = kern.node_candidates_scatter_slab_tiles(
            part.val, part.col_s, part.ii_g, rmf, rmc, rxf, rxc,
            part.lhs_g, part.rhs_g, part.tile_slab, active, lbp, ubp,
            part.slab, int_eps, inf, interpret,
        )
    else:
        mf, mc, xf, xc = kern.batched_activities_slab_tiles(
            part.val, part.col_s, part.tile_inst, part.tile_slab, active,
            lbp, ubp, part.slab, inf, interpret,
        )
        rmf, rmc, rxf, rxc = _combine_copy_partials(part, num_rows, mf, mc, xf, xc)
        best_l, best_u = kern.batched_candidates_scatter_slab_tiles(
            part.val, part.col_s, part.ii_g, rmf, rmc, rxf, rxc,
            part.lhs_g, part.rhs_g, part.tile_inst, part.tile_slab, active,
            lbp, ubp, part.slab, int_eps, inf, interpret,
        )
    new_lb, new_ub, ch = kern.apply_updates_slab_tiles(
        lbp, ubp, best_l, best_u, active, part.slab, eps, inf, interpret
    )
    if extra:
        new_lb, new_ub = new_lb[:, :n_pad], new_ub[:, :n_pad]
    return new_lb, new_ub, ch


def _prepared_round(
    prep: PreparedBlockEll,
    lb,
    ub,
    *,
    eps: float,
    int_eps: float,
    inf: float,
    use_pallas: bool,
    fused: bool,
    scatter: str,
    interpret: bool | None,
    slab: int | None = None,
):
    """One round over hoisted constants.  (lb, ub) live in the column-padded
    ``(n_pad,)`` domain end to end; only the bound gathers run in XLA."""
    d = prep.d

    if scatter == "partitioned":
        # Column-slab partitioned round (VMEM-exceeding n_pad): per-slab
        # masked tile copies, two-phase (partials -> tiny XLA combine ->
        # candidates + per-slab scatter), slab-gridded merge.  Only (1, S)
        # windows are ever VMEM-resident; no nnz-shaped tensor touches HBM.
        part = prep.slab_partition(slab)
        if use_pallas:
            new_lb, new_ub, ch = _partitioned_pallas_round(
                part, lb[None, :], ub[None, :], jnp.ones((1,), jnp.int32),
                prep.m + 1, node=False, eps=eps, int_eps=int_eps, inf=inf,
                interpret=interpret,
            )
            return new_lb[0], new_ub[0], ch[0]
        dt = d.val.dtype
        extra = part.n_pad_part - prep.n_pad
        lbp = jnp.concatenate([lb, jnp.zeros((extra,), dt)]) if extra else lb
        ubp = jnp.concatenate([ub, jnp.zeros((extra,), dt)]) if extra else ub
        best_l, best_u = kref.partitioned_round_ref(
            part.val, part.col_s, part.tile_slab, part.chunk_row,
            part.ii_g != 0, part.lhs_g, part.rhs_g, lbp, ubp,
            prep.m + 1, part.slab, part.n_pad_part, int_eps, inf,
        )
        return bnd.apply_updates(
            lb, ub, best_l[: prep.n_pad], best_u[: prep.n_pad], eps, inf
        )

    if scatter == "fused":
        if fused:
            # Fully fused: even the bound gather happens in the kernel, so
            # no nnz-shaped tensor is produced in HBM at all this round.
            if use_pallas:
                best_l, best_u = kern.fused_scatter_round_tiles(
                    d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g,
                    lb, ub, prep.n_pad, int_eps, inf, interpret,
                )
            else:
                best_l, best_u = kref.fused_scatter_round_tiles_ref(
                    d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g,
                    lb, ub, prep.n_pad, int_eps, inf,
                )
        else:
            # Long rows: chunk partials (in-kernel gather) -> XLA segment
            # combine of the tiny (T, R) aggregates -> fused scatter round.
            if use_pallas:
                mf, mc, xf, xc = kern.activities_gather_tiles(
                    d.val, d.col, lb, ub, prep.n_pad, inf, interpret
                )
            else:
                mf, mc, xf, xc = kref.activities_gather_tiles_ref(
                    d.val, d.col, lb, ub, prep.n_pad, inf
                )
            rmf, rmc, rxf, rxc = _combine_chunk_partials(prep, mf, mc, xf, xc)
            if use_pallas:
                best_l, best_u = kern.candidates_scatter_tiles(
                    d.val, d.col, prep.ii_g, rmf, rmc, rxf, rxc,
                    prep.lhs_g, prep.rhs_g, lb, ub, prep.n_pad, int_eps, inf,
                    interpret,
                )
            else:
                best_l, best_u = kref.candidates_scatter_tiles_ref(
                    d.val, d.col, prep.ii_g, rmf, rmc, rxf, rxc,
                    prep.lhs_g, prep.rhs_g, lb, ub, prep.n_pad, int_eps, inf,
                )
        if use_pallas:
            return kern.apply_updates_tiles(lb, ub, best_l, best_u, eps, inf, interpret)
        return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)

    # scatter == "segment": the materializing oracle path (hoisted gathers).
    lb_g = lb[d.col]
    ub_g = ub[d.col]
    if fused:
        if use_pallas:
            lcand, ucand = kern.fused_round_tiles(
                d.val, lb_g, ub_g, prep.ii_g, prep.lhs_g, prep.rhs_g,
                int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.fused_round_tiles_ref(
                d.val, lb_g, ub_g, prep.ii_g, prep.lhs_g, prep.rhs_g, int_eps, inf
            )
    else:
        if use_pallas:
            mf, mc, xf, xc = kern.activities_tiles(d.val, lb_g, ub_g, inf, interpret)
        else:
            mf, mc, xf, xc = kref.activities_tiles_ref(d.val, lb_g, ub_g, inf)
        rmf, rmc, rxf, rxc = _combine_chunk_partials(prep, mf, mc, xf, xc)
        if use_pallas:
            lcand, ucand = kern.candidates_tiles(
                d.val, lb_g, ub_g, prep.ii_g, rmf, rmc, rxf, rxc,
                prep.lhs_g, prep.rhs_g, int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.candidates_tiles_ref(
                d.val, lb_g, ub_g, prep.ii_g, rmf, rmc, rxf, rxc,
                prep.lhs_g, prep.rhs_g, int_eps, inf,
            )
    flat_col = d.col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=prep.n_pad)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=prep.n_pad)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


def legacy_round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """The seed round (``block_ell_round``) as a jit-able ``(lb, ub) ->
    (lb, ub, changed)`` closure over a prepared instance -- bounds in the
    unpadded ``(n,)`` domain.  Kept as the measured baseline."""
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        block_ell_round,
        prep.d,
        m=prep.m,
        n=prep.n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=prep.fits_one_chunk,
        interpret=interpret,
    )


def round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    scatter: str = "fused",
    fused: bool | None = None,
    interpret: bool | None = None,
    slab: int | None = None,
):
    """A jit-able ``(lb, ub) -> (lb, ub, changed)`` round closure over a
    prepared instance (bounds in the ``(n_pad,)`` domain).  ``slab``
    overrides the partitioned engine's column-slab width (default
    :data:`SLAB_NPAD`; ignored by the other scatter modes)."""
    scatter = _resolve_scatter(scatter, prep)
    do_fuse = prep.fits_one_chunk if fused is None else bool(fused)
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        _prepared_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=do_fuse,
        scatter=scatter,
        interpret=interpret,
        slab=slab,
    )


# ---------------------------------------------------------------------------
# Full propagation drivers over block-ELL
# ---------------------------------------------------------------------------


def _resolve_scatter(scatter: str, prep: PreparedBlockEll) -> str:
    """The engine decision (see docs/ARCHITECTURE.md): ``auto`` keeps the
    fully fused round while the ``(2, n_pad)`` accumulators fit the VMEM
    budget and moves to the column-slab partitioned round beyond it, so
    the fused ~16 B/nnz dataflow holds at every instance size; ``segment``
    (the materializing oracle) is only ever explicit."""
    if scatter == "auto":
        return "fused" if prep.n_pad <= SCATTER_MAX_NPAD else "partitioned"
    if scatter not in ("fused", "segment", "partitioned"):
        raise ValueError(f"unknown scatter mode: {scatter!r}")
    return scatter


# Jitted single-instance fixed points, cached per matrix structure + config:
# the tree-search pattern re-propagates the same prepared engine with fresh
# bounds thousands of times, and rebuilding the jit closure per call would
# recompile every time.  Keyed on id(prep.d.val) -- the tile array shared by
# every bounds-swapped prepare() view of one structure -- so ONE compiled
# engine serves any bounds (the round closures read only structure from the
# prep they were built over, never its bound defaults).
_runner_cache = LRU(maxsize=64)


def _initial_padded_bounds(prep: PreparedBlockEll, lb0, ub0):
    """Per-call bound overrides -> private, donated-safe (n_pad,) buffers."""
    lb = owned_copy(prep.lb0 if lb0 is None else prep.pad_bound(lb0))
    ub = owned_copy(prep.ub0 if ub0 is None else prep.pad_bound(ub0))
    return lb, ub


def propagate_block_ell(
    p: Problem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    fused: str = "auto",
    driver: str = "device_loop",
    interpret: bool | None = None,
    scatter: str = "auto",
    donate: bool | None = None,
    lb0=None,
    ub0=None,
    slab: int | None = None,
) -> PropagationResult:
    """Kernel-backed propagation.

    ``fused='auto'`` picks the Alg.-3 fusion whenever every row fits in one
    chunk (the paper's common case).  ``scatter='auto'`` picks the fully
    fused in-VMEM column reduction while the padded column count fits the
    accumulator budget and the column-slab ``partitioned`` engine beyond it
    (``slab`` overrides its window width); ``scatter='segment'`` forces the
    materializing oracle.  ``donate=None`` donates the bound buffers
    wherever the backend implements donation (zero-copy fixed point).

    ``lb0``/``ub0`` warm-start the fixed point from caller-supplied bounds:
    the prepared tiles, hoisted gathers AND the compiled fixed point are all
    cached per matrix structure, so propagating a B&B node costs one
    dispatch with two (n,) uploads -- no repacking, no recompilation."""
    if driver not in ("host_loop", "device_loop"):
        raise ValueError(f"unknown driver: {driver!r}")
    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    do_fuse = (
        prep.fits_one_chunk if fused == "auto" else bool(fused == "yes" or fused is True)
    )
    scatter = _resolve_scatter(scatter, prep)
    do_donate = donate_supported() if donate is None else bool(donate)
    n = prep.n

    key = (
        id(prep.d.val), cfg, use_pallas, do_fuse, scatter, interpret, do_donate,
        driver, slab,
    )
    anchors = (prep.d.val,)

    def build():
        donate_kw = {"donate_argnums": (0, 1)} if do_donate else {}
        round_fn = functools.partial(
            _prepared_round,
            prep,
            eps=cfg.eps_for(prep.d.val.dtype),
            int_eps=cfg.int_eps,
            inf=cfg.inf,
            use_pallas=use_pallas,
            fused=do_fuse,
            scatter=scatter,
            interpret=interpret,
            slab=slab,
        )
        if driver == "host_loop":
            return jax.jit(round_fn, **donate_kw)

        @functools.partial(jax.jit, **donate_kw)
        def run(lb0, ub0):
            def body(state):
                lb, ub, _, r = state
                lb, ub, ch = round_fn(lb, ub)
                return lb, ub, ch, r + 1

            def cond(state):
                _, _, ch, r = state
                return ch & (r < cfg.max_rounds)

            lb, ub, ch, r = jax.lax.while_loop(
                cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
            )
            lb, ub = lb[:n], ub[:n]
            return lb, ub, r, ~ch, jnp.any(lb > ub + cfg.feas_eps)

        return run

    runner = _runner_cache.get(key, anchors)
    if runner is None:
        runner = build()
        _runner_cache.put(key, anchors, runner)

    lb, ub = _initial_padded_bounds(prep, lb0, ub0)

    if driver == "host_loop":
        rounds, changed = 0, True
        while changed and rounds < cfg.max_rounds:
            # Donated in, fresh buffers out: the loop owns its bounds, so XLA
            # reuses the same two (n_pad,) buffers round over round.
            lb, ub, cdev = runner(lb, ub)
            changed = bool(cdev)
            rounds += 1
        infeas = bool(jnp.any(lb[:n] > ub[:n] + cfg.feas_eps))
        return PropagationResult(
            lb[:n], ub[:n], jnp.int32(rounds), jnp.asarray(not changed), jnp.asarray(infeas)
        )

    lb, ub, rounds, converged, infeasible = runner(lb, ub)
    return PropagationResult(lb, ub, rounds, converged, infeasible)


# ---------------------------------------------------------------------------
# Batched engine: a whole ProblemBatch per dispatch
# ---------------------------------------------------------------------------


class DeviceProblemBatch(NamedTuple):
    """Device-resident packed batch (pytree): the flat tile stream, hoisted
    round-constant gathers/offsets, initial bounds and the real-column
    mask.  ``col`` keeps instance-local columns (the kernel routes blocks
    by ``tile_inst``); ``col_g`` carries the precomputed global ids
    ``col + tile_inst * n_pad`` for the flat XLA dataflow."""

    val: jnp.ndarray        # (T, R, K)
    col: jnp.ndarray        # (T, R, K) int32 instance-local
    col_g: jnp.ndarray      # (T, R, K) int32 global (bound-plane) columns
    chunk_row: jnp.ndarray  # (T, R) int32 global row ids
    tile_inst: jnp.ndarray  # (T,) int32 instance of each tile
    ii_g: jnp.ndarray       # (T, R, K) int32: is_int[col], hoisted
    lhs_g: jnp.ndarray      # (T, R): lhs1[chunk_row], hoisted
    rhs_g: jnp.ndarray      # (T, R)
    lb0: jnp.ndarray        # (B, n_pad)
    ub0: jnp.ndarray        # (B, n_pad)
    col_valid: jnp.ndarray  # (B, n_pad) bool: j < n_i (real columns)


@dataclasses.dataclass(frozen=True)
class PreparedBatch:
    """One bucket, device-ready.  Like :class:`PreparedBlockEll`, not a
    pytree: drivers close over it so arrays become jit constants."""

    batch: ProblemBatch
    d: DeviceProblemBatch
    size: int
    m_total: int
    n_pad: int
    fits_one_chunk: bool
    # Lazy slab partitions of the packed stream, keyed by slab width.
    _slabs: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False
    )

    def slab_partition(self, slab: int | None = None) -> SlabPartition:
        """The bucket's flat super-tile stream re-bucketed into per-instance
        ``slab``-wide column windows (default :func:`default_slab_width`), copies
        sorted ``(instance, slab, tile)``; built once per slab width from
        the host-side packed arrays and cached on the prep."""
        s = default_slab_width(self.n_pad) if slab is None else int(slab)
        part = self._slabs.get(s)
        if part is None:
            ell = self.batch.ell
            dt = np.dtype(self.d.val.dtype)
            # Instance i's padding chunks target its dummy row, the last of
            # its row range.
            dummy_rows = (ell.row_offset[1:] - 1).astype(np.int32)
            part = build_slab_partition(
                np.asarray(ell.val, dtype=dt),
                ell.col,
                ell.chunk_row,
                ell.tile_inst,
                self.batch.lhs1,
                self.batch.rhs1,
                self.batch.is_int,
                self.n_pad,
                s,
                dummy_rows,
            )
            self._slabs[s] = part
        return part


_batch_prep_cache = LRU(maxsize=16)


def prepare_problem_batch(batch: ProblemBatch, dtype=None) -> PreparedBatch:
    """Device transfer + hoisted constant gathers for one packed bucket,
    LRU-cached per ``ProblemBatch`` (maxsize 16, see ``cache_info()``; the
    serving pattern re-propagates the same packed batch with fresh
    bounds -- ``propagate_batch_prepared`` takes them as per-call
    arguments)."""
    ell = batch.ell
    dt = np.dtype(dtype) if dtype is not None else np.dtype(ell.val.dtype)
    key = (id(batch), dt.str)
    hit = _batch_prep_cache.get(key, (batch,))
    if hit is not None:
        return hit

    n_pad = batch.n_pad
    col_g = ell.col + ell.tile_inst[:, None, None] * np.int32(n_pad)
    ii_g = batch.is_int.reshape(-1)[col_g]
    lhs_g = batch.lhs1[ell.chunk_row]
    rhs_g = batch.rhs1[ell.chunk_row]
    col_valid = np.arange(n_pad)[None, :] < ell.n[:, None]
    d = DeviceProblemBatch(
        val=jnp.asarray(ell.val, dtype=dt),
        col=jnp.asarray(ell.col),
        col_g=jnp.asarray(col_g),
        chunk_row=jnp.asarray(ell.chunk_row),
        tile_inst=jnp.asarray(ell.tile_inst),
        ii_g=jnp.asarray(ii_g.astype(np.int32)),
        lhs_g=jnp.asarray(lhs_g.astype(dt)),
        rhs_g=jnp.asarray(rhs_g.astype(dt)),
        lb0=jnp.asarray(batch.lb, dtype=dt),
        ub0=jnp.asarray(batch.ub, dtype=dt),
        col_valid=jnp.asarray(col_valid),
    )
    prep = PreparedBatch(
        batch=batch,
        d=d,
        size=batch.size,
        m_total=batch.m_total,
        n_pad=n_pad,
        fits_one_chunk=all(
            rows_fit_one_chunk(p, ell.tile_width) for p in batch.problems
        ),
    )
    _batch_prep_cache.put(key, (batch,), prep)
    return prep


def batched_reference_round(
    val, col_g, ii_g, chunk_row, lhs_g, rhs_g, lb, ub, active,
    *, m_total: int, n_pad: int, fits_one_chunk: bool,
    eps: float, int_eps: float, inf: float,
):
    """One batched round at the data level (jnp oracle arithmetic), usable
    under ``shard_map``/``jit`` with the batch axis as a plain leading dim
    of the bound plane.  The whole batch is ONE flat dataflow -- one
    gather, one candidate sweep, one column segment reduction -- so the
    per-op dispatch overhead is paid once per round, not once per instance.
    Inactive instances' candidates are forced to the reduction identity, so
    their bounds pass through unchanged and report no change."""
    if fits_one_chunk:
        best_l, best_u = kref.batched_fused_scatter_round_ref(
            val, col_g, ii_g, lhs_g, rhs_g, lb, ub, n_pad, int_eps, inf
        )
    else:
        best_l, best_u = kref.batched_candidates_scatter_round_ref(
            val, col_g, ii_g, chunk_row, lhs_g, rhs_g, lb, ub,
            m_total, n_pad, int_eps, inf,
        )
    best_l = jnp.where(active[:, None], best_l, -inf)
    best_u = jnp.where(active[:, None], best_u, inf)
    return bnd.apply_updates_batch(lb, ub, best_l, best_u, eps, inf)


def _batched_prepared_round(
    prep: PreparedBatch, lb, ub, active,
    *, eps: float, int_eps: float, inf: float,
    use_pallas: bool, interpret: bool | None,
):
    """One round over a prepared bucket: ``(B, n_pad)`` bounds + ``(B,)``
    active mask -> updated bounds + per-instance changed flags.

    The Pallas path (chunk-complete rows, the paper's common case) runs the
    batched kernel D -- the grid walks the flat tile stream, the
    scalar-prefetched instance map routes each tile to its bound-plane and
    accumulator rows, converged instances are gated off in-kernel -- then
    the batched merge kernel.  Buckets whose ``n_pad`` exceeds the VMEM
    accumulator budget run the slab-partitioned kernels instead (copies
    routed by ``(instance, slab)``, same gating); only buckets with rows
    spanning chunks at small ``n_pad`` use the batched jnp dataflow."""
    d = prep.d
    if use_pallas and prep.fits_one_chunk and prep.n_pad <= SCATTER_MAX_NPAD:
        best_l, best_u = kern.batched_fused_scatter_round_tiles(
            d.val, d.col, d.ii_g, d.lhs_g, d.rhs_g, lb, ub,
            d.tile_inst, active, prep.n_pad, int_eps, inf, interpret,
        )
        return kern.apply_updates_batch_tiles(
            lb, ub, best_l, best_u, active, eps, inf, interpret
        )
    if use_pallas and prep.n_pad > SCATTER_MAX_NPAD:
        return _partitioned_pallas_round(
            prep.slab_partition(), lb, ub, active, prep.m_total + 1,
            node=False, eps=eps, int_eps=int_eps, inf=inf, interpret=interpret,
        )
    return batched_reference_round(
        d.val, d.col_g, d.ii_g, d.chunk_row, d.lhs_g, d.rhs_g, lb, ub, active,
        m_total=prep.m_total, n_pad=prep.n_pad,
        fits_one_chunk=prep.fits_one_chunk,
        eps=eps, int_eps=int_eps, inf=inf,
    )


def batched_round_fn_for(
    prep: PreparedBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """A jit-able ``(lb, ub, active) -> (lb, ub, changed)`` batched round
    closure over a prepared bucket."""
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        _batched_prepared_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        interpret=interpret,
    )


def _unpack_batch_results(prep, lb, ub, rounds, converged, infeasible):
    out = []
    for i, p in enumerate(prep.batch.problems):
        out.append(
            PropagationResult(
                lb[i, : p.n], ub[i, : p.n], rounds[i], converged[i], infeasible[i]
            )
        )
    return out


# Jitted fixed-point runners, cached per prepared bucket + config (maxsize
# 64, see ``cache_info()``): the serving loop re-propagates the same packed
# batches, and rebuilding the jit closure per request would recompile every
# time.  Bounds are runtime arguments of every runner, so one compiled
# fixed point serves any warm-start bound plane.
_batch_runner_cache = LRU(maxsize=64)


def _cached_batch_runner(prep, key, build):
    runner = _batch_runner_cache.get(key, (prep,))
    if runner is None:
        runner = build()
        _batch_runner_cache.put(key, (prep,), runner)
    return runner


def batched_device_runner(
    prep: PreparedBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
    donate: bool | None = None,
):
    """The bucket's whole fixed point as ONE jitted dispatch, cached:
    ``run(lb0, ub0) -> (lb, ub, rounds, converged, infeasible)`` (all
    per-instance; ``lb0``/``ub0`` donated where supported)."""
    key = (id(prep), cfg, use_pallas, interpret, donate, "device")

    def build():
        round_fn = batched_round_fn_for(prep, cfg, use_pallas, interpret)
        if donate is None:
            donate_kw = donate_kwargs(argnums=(0, 1))
        else:
            donate_kw = {"donate_argnums": (0, 1)} if donate else {}
        col_valid = prep.d.col_valid

        @functools.partial(jax.jit, **donate_kw)
        def run(lb0, ub0):
            lb, ub, rounds, converged = batched_fixed_point(
                round_fn, lb0, ub0, cfg.max_rounds
            )
            infeasible = jnp.any((lb > ub + cfg.feas_eps) & col_valid, axis=-1)
            return lb, ub, rounds, converged, infeasible

        return run

    return _cached_batch_runner(prep, key, build)


def _batch_initial_bounds(prep: PreparedBatch, lb0, ub0):
    """Per-call bound planes -> private, donated-safe (B, n_pad) buffers."""
    d = prep.d
    out = []
    for override, default in ((lb0, d.lb0), (ub0, d.ub0)):
        if override is None:
            out.append(owned_copy(default))
            continue
        arr = jnp.asarray(override, d.val.dtype)
        if arr.shape != default.shape:
            raise ValueError(
                f"bound plane has shape {arr.shape}, expected {default.shape}"
            )
        out.append(owned_copy(arr))
    return tuple(out)


def propagate_batch_prepared(
    prep: PreparedBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    driver: str = "device_loop",
    interpret: bool | None = None,
    donate: bool | None = None,
    lb0=None,
    ub0=None,
):
    """Run one prepared bucket to its per-instance fixed points.

    ``device_loop``: the entire batched fixed point is ONE dispatch
    (``batched_fixed_point`` under jit, bounds donated).  ``host_loop``:
    host syncs the per-instance changed flags each round and retires
    converged instances from the active mask.  ``lb0``/``ub0`` warm-start
    the bucket from a caller-supplied ``(B, n_pad)`` bound plane (default:
    the packed instances' root bounds) -- the prepared tiles and the cached
    runner serve any plane.  Returns one ``PropagationResult`` per
    instance, bucket order."""
    d = prep.d
    bsz = prep.size

    if driver == "host_loop":
        key = (id(prep), cfg, use_pallas, interpret, donate, "host")

        def build():
            round_fn = batched_round_fn_for(prep, cfg, use_pallas, interpret)
            if donate is None:
                donate_kw = donate_kwargs(argnums=(0, 1))
            else:
                donate_kw = {"donate_argnums": (0, 1)} if donate else {}
            return jax.jit(round_fn, **donate_kw)

        jit_round = _cached_batch_runner(prep, key, build)
        lb, ub = _batch_initial_bounds(prep, lb0, ub0)
        active = np.ones(bsz, dtype=bool)
        last_changed = np.ones(bsz, dtype=bool)
        rounds = np.zeros(bsz, dtype=np.int32)
        while active.any():
            lb, ub, ch = jit_round(lb, ub, jnp.asarray(active))
            ch = np.asarray(ch)  # the per-round host<->device sync point
            rounds += active
            last_changed = np.where(active, ch, last_changed)
            active = active & ch & (rounds < cfg.max_rounds)
        infeasible = np.asarray(
            jnp.any((lb > ub + cfg.feas_eps) & d.col_valid, axis=-1)
        )
        return _unpack_batch_results(
            prep, lb, ub, rounds, ~last_changed, infeasible
        )

    if driver != "device_loop":
        raise ValueError(f"unknown driver: {driver!r}")

    run = batched_device_runner(prep, cfg, use_pallas, interpret, donate)
    lb_init, ub_init = _batch_initial_bounds(prep, lb0, ub0)
    lb, ub, rounds, converged, infeasible = run(lb_init, ub_init)
    return _unpack_batch_results(prep, lb, ub, rounds, converged, infeasible)


# Packed-batch cache (maxsize 8, see ``cache_info()``): serving
# re-propagates the same request list, and repacking would defeat both the
# prepare() and the runner caches (both key on object identity).
_pack_cache = LRU(maxsize=8)


def packed_problems(problems, tile_rows: int = 8, tile_width: int = 128):
    """LRU-cached ``pack_problems``: the same problem list (by identity)
    packs once and reuses its ``ProblemBatch`` objects across calls."""
    problems = list(problems)
    anchors = tuple(problems)
    key = (tuple(id(p) for p in problems), tile_rows, tile_width)
    hit = _pack_cache.get(key, anchors)
    if hit is not None:
        return hit
    batches = pack_problems(problems, tile_rows=tile_rows, tile_width=tile_width)
    _pack_cache.put(key, anchors, batches)
    return batches


def clear_batch_caches() -> None:
    """Drop packed batches, prepared buckets and jitted runners."""
    _pack_cache.clear()
    _batch_prep_cache.clear()
    _batch_runner_cache.clear()


def cache_info() -> dict:
    """Hit/miss/size/maxsize counters of every engine-level LRU cache
    (prepared instances, compiled single-instance runners, packed batches,
    prepared buckets, batched runners, node-batch runners).  Complements
    the ``clear_*`` helpers; sizes are entry counts, not bytes."""
    return {
        "prepare_block_ell": _prep_cache.info(),
        "block_ell_runner": _runner_cache.info(),
        "packed_problems": _pack_cache.info(),
        "prepare_problem_batch": _batch_prep_cache.info(),
        "batch_runner": _batch_runner_cache.info(),
        "node_runner": _node_runner_cache.info(),
    }


def _bound_planes_for_batch(batch: ProblemBatch, bounds):
    """Per-problem ``(lb, ub)`` overrides -> this bucket's (B, n_pad) planes.

    ``bounds[i]`` (input order) is either ``None`` (use problem ``i``'s own
    bounds) or a ``(lb, ub)`` pair of ``(n_i,)`` arrays."""
    lb_plane = np.array(batch.lb, copy=True)
    ub_plane = np.array(batch.ub, copy=True)
    touched = False
    for row, (idx, p) in enumerate(zip(batch.indices, batch.problems)):
        pair = bounds[idx]
        if pair is None:
            continue
        lb_i, ub_i = pair
        lb_i = np.asarray(lb_i, lb_plane.dtype)
        ub_i = np.asarray(ub_i, ub_plane.dtype)
        if lb_i.shape != (p.n,) or ub_i.shape != (p.n,):
            raise ValueError(
                f"bounds for instance {idx} have shapes {lb_i.shape}/{ub_i.shape}, "
                f"expected {(p.n,)}"
            )
        lb_plane[row, : p.n] = lb_i
        ub_plane[row, : p.n] = ub_i
        touched = True
    if not touched:
        return None, None
    return lb_plane, ub_plane


def propagate_batch_block_ell(
    problems,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    driver: str = "device_loop",
    interpret: bool | None = None,
    donate: bool | None = None,
    bounds=None,
):
    """Batched kernel-backed propagation: pack -> per-bucket dispatch ->
    per-instance results in input order.  Packing, device transfer and the
    jitted fixed-point runners are all LRU-cached, so a serving loop that
    re-propagates the same instances pays them once.  ``bounds`` (one
    ``(lb, ub)`` pair or ``None`` per problem, input order) warm-starts
    instances from caller bounds through the SAME packed tiles and compiled
    runners -- nothing is repacked or recompiled.  The public front end is
    ``repro.core.propagate_batch``."""
    problems = list(problems)
    if bounds is not None:
        bounds = list(bounds)
        if len(bounds) != len(problems):
            raise ValueError(
                f"bounds has {len(bounds)} entries for {len(problems)} problems"
            )
    batches = packed_problems(problems, tile_rows=tile_rows, tile_width=tile_width)
    out = [None] * len(problems)
    for batch in batches:
        prep = prepare_problem_batch(batch, dtype)
        lb0 = ub0 = None
        if bounds is not None:
            lb0, ub0 = _bound_planes_for_batch(batch, bounds)
        results = propagate_batch_prepared(
            prep, cfg, use_pallas=use_pallas, driver=driver,
            interpret=interpret, donate=donate, lb0=lb0, ub0=ub0,
        )
        for idx, res in zip(batch.indices, results):
            out[idx] = res
    return out


# ---------------------------------------------------------------------------
# Node-batch engine: one shared matrix, many bound planes (tree search)
# ---------------------------------------------------------------------------


def _node_round(
    prep: PreparedBlockEll, lb, ub, active,
    *, eps: float, int_eps: float, inf: float,
    use_pallas: bool, interpret: bool | None,
):
    """One round over a node batch: ``(B, n_pad)`` per-node bounds +
    ``(B,)`` active mask -> updated bounds + per-node changed flags, with
    the instance's matrix tiles shared by every node.

    The Pallas path (chunk-complete rows, accumulator budget respected)
    runs the node kernel -- the grid walks ``(B, T)`` with the tile axis
    minor, converged nodes gated off in-kernel -- then the batched merge
    kernel.  Nodes of a VMEM-exceeding instance (``n_pad`` beyond the
    accumulator budget) run the slab-partitioned node kernels on a
    ``(B, T')`` grid over the per-slab copies, same gating.  Otherwise the
    single-instance jnp round is vmapped over the node axis (multichunk
    rows at small ``n_pad``, or ``use_pallas=False``), with inactive
    nodes' bounds frozen outside."""
    if use_pallas and prep.fits_one_chunk and prep.n_pad <= SCATTER_MAX_NPAD:
        d = prep.d
        best_l, best_u = kern.node_fused_scatter_round_tiles(
            d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g, lb, ub,
            active, prep.n_pad, int_eps, inf, interpret,
        )
        return kern.apply_updates_batch_tiles(
            lb, ub, best_l, best_u, active, eps, inf, interpret
        )
    if use_pallas and prep.n_pad > SCATTER_MAX_NPAD:
        return _partitioned_pallas_round(
            prep.slab_partition(), lb, ub, active, prep.m + 1,
            node=True, eps=eps, int_eps=int_eps, inf=inf, interpret=interpret,
        )
    single = functools.partial(
        _prepared_round,
        prep,
        eps=eps,
        int_eps=int_eps,
        inf=inf,
        use_pallas=False,
        fused=prep.fits_one_chunk,
        scatter=_resolve_scatter("auto", prep),
        interpret=interpret,
    )
    new_lb, new_ub, changed = jax.vmap(single)(lb, ub)
    lb = jnp.where(active[:, None], new_lb, lb)
    ub = jnp.where(active[:, None], new_ub, ub)
    return lb, ub, changed & active


def node_round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """A jit-able ``(lb, ub, active) -> (lb, ub, changed)`` node-batch
    round closure over a prepared instance (bounds ``(B, n_pad)``)."""
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        _node_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        interpret=interpret,
    )


# Node-batch fixed-point runners, cached per matrix structure + node count +
# config (maxsize 32, see ``cache_info()``): a tree search re-propagates the
# same instance with fresh node bounds every dive, and the bounds are
# runtime arguments, so each (structure, B) pair compiles exactly once.
_node_runner_cache = LRU(maxsize=32)


def node_batch_runner(
    prep: PreparedBlockEll,
    batch_size: int,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
    donate: bool | None = None,
):
    """The node batch's whole fixed point as ONE jitted dispatch, cached:
    ``run(lb0, ub0) -> (lb, ub, rounds, converged, infeasible)`` with the
    node axis leading everywhere (``lb0``/``ub0`` donated where
    supported)."""
    do_donate = donate_supported() if donate is None else bool(donate)
    key = (id(prep.d.val), batch_size, cfg, use_pallas, interpret, do_donate)
    anchors = (prep.d.val,)
    runner = _node_runner_cache.get(key, anchors)
    if runner is not None:
        return runner

    round_fn = node_round_fn_for(prep, cfg, use_pallas, interpret)
    donate_kw = {"donate_argnums": (0, 1)} if do_donate else {}
    col_valid = jnp.arange(prep.n_pad) < prep.n

    @functools.partial(jax.jit, **donate_kw)
    def run(lb0, ub0):
        lb, ub, rounds, converged = batched_fixed_point(
            round_fn, lb0, ub0, cfg.max_rounds
        )
        infeasible = jnp.any((lb > ub + cfg.feas_eps) & col_valid[None, :], axis=-1)
        return lb, ub, rounds, converged, infeasible

    _node_runner_cache.put(key, anchors, run)
    return run


def propagate_nodes_prepared(
    prep: PreparedBlockEll,
    lb_nodes,
    ub_nodes,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
    donate: bool | None = None,
):
    """Run B warm-started nodes of one prepared instance to their fixed
    points in ONE dispatch.

    ``lb_nodes``/``ub_nodes`` are ``(B, n)`` per-node bound planes (the
    only per-node state -- the matrix tiles are resident once).  Returns
    ``(lb, ub, rounds, converged, infeasible)`` with the node axis leading;
    ``infeasible`` marks nodes whose domain emptied (prune them).  Each
    node's result is exactly what its own single-instance warm-started
    ``propagate_block_ell`` run would produce, including round counts."""
    lb_nodes = np.asarray(lb_nodes)
    ub_nodes = np.asarray(ub_nodes)
    if lb_nodes.ndim != 2 or lb_nodes.shape != ub_nodes.shape:
        raise ValueError(
            f"node bound planes must share a (B, n) shape, got "
            f"{lb_nodes.shape} / {ub_nodes.shape}"
        )
    bsz, n = lb_nodes.shape
    if n != prep.n:
        raise ValueError(f"node bounds have n={n}, instance has n={prep.n}")
    dt = prep.d.val.dtype
    pad = prep.n_pad - prep.n
    planes = []
    for plane in (lb_nodes, ub_nodes):
        plane = np.asarray(plane, dt)
        if pad:
            plane = np.concatenate([plane, np.zeros((bsz, pad), dt)], axis=1)
        planes.append(jnp.asarray(plane))
    run = node_batch_runner(prep, bsz, cfg, use_pallas, interpret, donate)
    lb, ub, rounds, converged, infeasible = run(*planes)
    return lb[:, : prep.n], ub[:, : prep.n], rounds, converged, infeasible


# ---------------------------------------------------------------------------
# Measured bytes-per-round (XLA cost analysis, not assertions)
# ---------------------------------------------------------------------------


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    size = 1
    for s in shape:
        size *= int(s)
    return size * np.dtype(aval.dtype).itemsize


# Structural primitives whose own operands are pass-through loop/call state:
# recurse into their bodies (counted once, as HloCostAnalysis does for while
# bodies) instead of counting the carried tuple.
_RECURSE_PRIMS = frozenset(
    {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call", "while", "cond", "scan"}
)
_INNER_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr", "branches")


def _inner_jaxprs(eqn):
    out = []
    for name in _INNER_JAXPR_PARAMS:
        v = eqn.params.get(name)
        if v is None:
            continue
        for j in v if isinstance(v, (list, tuple)) else [v]:
            out.append(j.jaxpr if hasattr(j, "jaxpr") else j)
    return out


def hbm_bytes_of(fn, *args) -> float:
    """HBM-boundary bytes-accessed of ``fn``, measured from its traced jaxpr.

    Every XLA op counts operand + result bytes -- the same per-instruction
    definition XLA's ``HloCostAnalysis`` uses.  A ``pallas_call`` counts its
    operands + results only: that is exactly the traffic the kernel DMAs
    between HBM and VMEM, while kernel-internal values are VMEM/register
    resident by construction (the interpret-mode emulation would otherwise
    misattribute them as memory traffic).
    """
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr) -> float:
        total = 0.0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in _RECURSE_PRIMS:
                for inner in _inner_jaxprs(eqn):
                    total += walk(inner)
                continue
            total += sum(
                _aval_bytes(v.aval)
                for v in list(eqn.invars) + list(eqn.outvars)
                if hasattr(v, "aval")
            )
        return total

    return walk(closed.jaxpr)


def round_cost_analysis(
    p: Problem,
    scatter: str = "fused",
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    interpret: bool | None = None,
    include_compiled: bool = False,
) -> dict:
    """Measure ONE propagation round's memory traffic.

    ``scatter`` selects the dataflow being measured:
      * ``"fused"``       -- the fully fused in-VMEM gather+round+reduction;
      * ``"partitioned"`` -- the column-slab engine (per-slab tile copies,
        two-phase, slab-windowed scatter) that replaces ``fused`` beyond
        the VMEM accumulator budget;
      * ``"segment"``     -- candidates materialized + XLA segment
        reduction, with hoisted constant gathers;
      * ``"legacy"``      -- the seed round verbatim (``block_ell_round``):
        per-round constant gathers + materialized candidates.

    Returns a dict with
      * ``bytes_accessed``: HBM-boundary bytes (see ``hbm_bytes_of``) -- the
        number the fused engine is designed to shrink;
      * with ``include_compiled=True``, also ``bytes_accessed_compiled`` /
        ``flops``: the raw aggregate from ``Compiled.cost_analysis()`` on
        this backend's lowering, reported for transparency (on CPU it
        includes interpret-mode emulation buffers that a TPU kernel keeps in
        VMEM; computing it pays a full XLA compile, hence opt-in).
    """
    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    val_dtype = prep.d.val.dtype
    if scatter == "legacy":
        fn = legacy_round_fn_for(prep, cfg, use_pallas=True, interpret=interpret)
        shape = (prep.n,)
    else:
        fn = round_fn_for(prep, cfg, use_pallas=True, scatter=scatter, interpret=interpret)
        shape = (prep.n_pad,)
    sds = jax.ShapeDtypeStruct(shape, val_dtype)
    out = {"bytes_accessed": hbm_bytes_of(fn, sds, sds)}
    if include_compiled:
        compiled = jax.jit(fn).lower(sds, sds).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["bytes_accessed_compiled"] = float(ca.get("bytes accessed", 0.0))
        out["flops"] = float(ca.get("flops", 0.0))
    return out
