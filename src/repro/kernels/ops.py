"""Jit'd public wrappers around the Pallas kernels: a complete block-ELL
propagation engine (kernels + column reduction + bound update).

This is the kernel-backed sibling of ``core.propagator``; both share the
bound-update logic so they converge to identical fixed points.

Engine anatomy (see README "fused-scatter dataflow"):

  * ``prepare_block_ell`` -- one-time, cached per instance: block-ELL
    conversion, device transfer, and the *round-constant* gathers
    (``is_int[col]``, ``lhs1[chunk_row]``, ``rhs1[chunk_row]``) that the seed
    engine recomputed every round.
  * ``scatter="fused"`` -- the fully fused round: one Pallas kernel gathers
    the bounds in-kernel from the VMEM-resident (n_pad,) vectors, computes
    activities and candidates, AND does the column-wise best-bound
    reduction into ``(2, n_pad)`` accumulators that stay in VMEM across all
    grid steps; a small merge kernel then folds them into (lb, ub) in place
    (``input_output_aliases``).  NO nnz-shaped tensor -- neither gathered
    bounds nor candidates -- is produced in HBM during a round.
  * ``scatter="segment"`` -- the materializing oracle: XLA bound gathers,
    candidates written to HBM, column reduction via XLA segment ops (the
    seed dataflow, kept for cross-validation and as the fallback when
    ``n_pad`` exceeds the VMEM accumulator budget).
  * Zero-copy fixed point: every jitted driver donates the (lb, ub) buffers
    (``donate_argnums``) so XLA updates bounds in place round over round.
    Donation is requested only on backends that implement it (TPU/GPU); the
    drivers hand the loop *private copies* of the cached initial bounds so
    donation can never invalidate the prepare() cache.

Per-round HBM-traffic model (8-byte fp, 4-byte ints, nnz_pad = T*R*K):

  segment (seed): gather writes+reads 2x lb/ub + is_int (~40 B/nnz), tile
    reads val+col (~12 B/nnz), candidate writes (~16 B/nnz), segment-op
    candidate+col reads (~24 B/nnz)   => ~92 B/nnz + O(m + n)
  fused:          tile reads val+col+is_int (~16 B/nnz) + O(m + n_pad)
    for the resident bound/accumulator vectors and row aggregates

``round_cost_analysis`` measures this at the HBM boundary of the actual
lowered round instead of asserting it.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bounds as bnd
from ..core.propagator import batched_fixed_point, donate_kwargs, owned_copy
from ..core.sparse import (
    BlockEll,
    Problem,
    ProblemBatch,
    csr_to_block_ell,
    pack_problems,
)
from ..core.types import DEFAULT_CONFIG, INF, PropagationResult, PropagatorConfig
from . import prop_round as kern
from . import ref as kref


class DeviceBlockEll(NamedTuple):
    """Device-resident block-ELL instance (pytree)."""

    val: jnp.ndarray        # (T, R, K)
    col: jnp.ndarray        # (T, R, K) int32
    chunk_row: jnp.ndarray  # (T, R) int32 in [0, m]; m == padding
    lhs1: jnp.ndarray       # (m+1,) sides padded with one dummy slot at index m
    rhs1: jnp.ndarray       # (m+1,)
    is_int: jnp.ndarray     # (n,) bool
    lb0: jnp.ndarray        # (n,)
    ub0: jnp.ndarray        # (n,)


def device_block_ell(p: Problem, tile_rows: int = 8, tile_width: int = 128, dtype=None) -> DeviceBlockEll:
    dtype = dtype or p.csr.val.dtype
    b = csr_to_block_ell(p.csr, tile_rows=tile_rows, tile_width=tile_width)
    pad1 = lambda x: np.concatenate([x, np.zeros(1, dtype=x.dtype)])
    return DeviceBlockEll(
        val=jnp.asarray(b.val, dtype=dtype),
        col=jnp.asarray(b.col),
        chunk_row=jnp.asarray(b.chunk_row),
        lhs1=jnp.asarray(pad1(p.lhs), dtype=dtype),
        rhs1=jnp.asarray(pad1(p.rhs), dtype=dtype),
        is_int=jnp.asarray(p.is_int),
        lb0=jnp.asarray(p.lb, dtype=dtype),
        ub0=jnp.asarray(p.ub, dtype=dtype),
    )


def rows_fit_one_chunk(p: Problem, tile_width: int) -> bool:
    return int(np.diff(p.csr.row_ptr).max(initial=0)) <= tile_width


# ---------------------------------------------------------------------------
# Prepared instances: one-time setup, hoisted round constants, LRU-cached
# ---------------------------------------------------------------------------

# Largest column-padded width the fused scatter keeps resident in VMEM
# (2 accumulators x n_pad x 8 B = 1 MiB at the cap; ~6% of a v5e core's VMEM).
SCATTER_MAX_NPAD = 1 << 16


@dataclasses.dataclass(frozen=True)
class PreparedBlockEll:
    """Device tiles + everything about a round that does not change across
    rounds: the constant gathers the seed engine recomputed per round, the
    column-padded initial bounds, and static layout facts.

    Not a pytree on purpose -- drivers close over it, so its arrays become
    jit constants and its ints/bools stay static.
    """

    d: DeviceBlockEll
    ii_g: jnp.ndarray    # (T, R, K) int32: is_int[col], hoisted
    lhs_g: jnp.ndarray   # (T, R): lhs1[chunk_row], hoisted
    rhs_g: jnp.ndarray   # (T, R): rhs1[chunk_row], hoisted
    lb0: jnp.ndarray     # (n_pad,) initial bounds in the column-padded domain
    ub0: jnp.ndarray     # (n_pad,)
    m: int
    n: int
    n_pad: int
    fits_one_chunk: bool


_prep_cache: "OrderedDict[tuple, tuple[Problem, PreparedBlockEll]]" = OrderedDict()
_PREP_CACHE_CAPACITY = 32


def prepare_block_ell(
    p: Problem, tile_rows: int = 8, tile_width: int = 128, dtype=None
) -> PreparedBlockEll:
    """One-time setup for kernel-backed propagation, LRU-cached per instance.

    Repeated propagations of the same ``Problem`` (the benchmark pattern)
    reuse the block-ELL tiles, device buffers and hoisted gathers instead of
    rebuilding and re-transferring them.  The cache keeps a strong reference
    to the keyed ``Problem`` so ``id()`` keys cannot be recycled while an
    entry is live.
    """
    dt = np.dtype(dtype) if dtype is not None else np.dtype(p.csr.val.dtype)
    key = (id(p), tile_rows, tile_width, dt.str)
    hit = _prep_cache.get(key)
    if hit is not None and hit[0] is p:
        _prep_cache.move_to_end(key)
        return hit[1]

    d = device_block_ell(p, tile_rows, tile_width, dt)
    n_pad = kern.col_pad(p.n)
    padn = lambda x: jnp.concatenate([x, jnp.zeros((n_pad - p.n,), x.dtype)])
    prep = PreparedBlockEll(
        d=d,
        ii_g=d.is_int[d.col].astype(jnp.int32),
        lhs_g=d.lhs1[d.chunk_row],
        rhs_g=d.rhs1[d.chunk_row],
        lb0=padn(d.lb0) if n_pad > p.n else d.lb0,
        ub0=padn(d.ub0) if n_pad > p.n else d.ub0,
        m=p.m,
        n=p.n,
        n_pad=n_pad,
        fits_one_chunk=rows_fit_one_chunk(p, tile_width),
    )
    _prep_cache[key] = (p, prep)
    while len(_prep_cache) > _PREP_CACHE_CAPACITY:
        _prep_cache.popitem(last=False)
    return prep


def clear_prepare_cache() -> None:
    """Drop all cached prepared instances (frees device buffers)."""
    _prep_cache.clear()


# ---------------------------------------------------------------------------
# One block-ELL round
# ---------------------------------------------------------------------------


def block_ell_round(
    d: DeviceBlockEll,
    lb,
    ub,
    m: int,
    n: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
    use_pallas: bool = True,
    fused: bool = False,
    interpret: bool | None = None,
):
    """One propagation round over block-ELL tiles (seed dataflow, kept as the
    legacy baseline: per-round constant gathers, candidates materialized in
    HBM, XLA segment reduction).  Returns (lb, ub, changed)."""
    lb_g = lb[d.col]
    ub_g = ub[d.col]
    ii_g = d.is_int[d.col]
    lhs_g = d.lhs1[d.chunk_row]
    rhs_g = d.rhs1[d.chunk_row]

    if fused:
        # Alg.-3-faithful: activities live in VMEM, reused for candidates.
        if use_pallas:
            lcand, ucand = kern.fused_round_tiles(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf, interpret
            )
        else:
            lcand, ucand = kref.fused_round_tiles_ref(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf
            )
    else:
        if use_pallas:
            mf, mc, xf, xc = kern.activities_tiles(d.val, lb_g, ub_g, inf, interpret)
        else:
            mf, mc, xf, xc = kref.activities_tiles_ref(d.val, lb_g, ub_g, inf)
        # Combine chunk partials into completed row aggregates (long rows).
        crow = d.chunk_row.reshape(-1)
        seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=m + 1)
        row_mf, row_mc = seg(mf), seg(mc)
        row_xf, row_xc = seg(xf), seg(xc)
        # Gather completed aggregates back per chunk.
        g = lambda x: x[d.chunk_row]
        if use_pallas:
            lcand, ucand = kern.candidates_tiles(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.candidates_tiles_ref(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf,
            )

    flat_col = d.col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=n)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=n)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


def _combine_chunk_partials(prep: PreparedBlockEll, mf, mc, xf, xc):
    """Chunk partials -> completed per-chunk row aggregates (long rows)."""
    d = prep.d
    crow = d.chunk_row.reshape(-1)
    seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=prep.m + 1)
    g = lambda x: seg(x)[d.chunk_row]
    return g(mf), g(mc), g(xf), g(xc)


def _prepared_round(
    prep: PreparedBlockEll,
    lb,
    ub,
    *,
    eps: float,
    int_eps: float,
    inf: float,
    use_pallas: bool,
    fused: bool,
    scatter: str,
    interpret: bool | None,
):
    """One round over hoisted constants.  (lb, ub) live in the column-padded
    ``(n_pad,)`` domain end to end; only the bound gathers run in XLA."""
    d = prep.d

    if scatter == "fused":
        if fused:
            # Fully fused: even the bound gather happens in the kernel, so
            # no nnz-shaped tensor is produced in HBM at all this round.
            if use_pallas:
                best_l, best_u = kern.fused_scatter_round_tiles(
                    d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g,
                    lb, ub, prep.n_pad, int_eps, inf, interpret,
                )
            else:
                best_l, best_u = kref.fused_scatter_round_tiles_ref(
                    d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g,
                    lb, ub, prep.n_pad, int_eps, inf,
                )
        else:
            # Long rows: chunk partials (in-kernel gather) -> XLA segment
            # combine of the tiny (T, R) aggregates -> fused scatter round.
            if use_pallas:
                mf, mc, xf, xc = kern.activities_gather_tiles(
                    d.val, d.col, lb, ub, prep.n_pad, inf, interpret
                )
            else:
                mf, mc, xf, xc = kref.activities_gather_tiles_ref(
                    d.val, d.col, lb, ub, prep.n_pad, inf
                )
            rmf, rmc, rxf, rxc = _combine_chunk_partials(prep, mf, mc, xf, xc)
            if use_pallas:
                best_l, best_u = kern.candidates_scatter_tiles(
                    d.val, d.col, prep.ii_g, rmf, rmc, rxf, rxc,
                    prep.lhs_g, prep.rhs_g, lb, ub, prep.n_pad, int_eps, inf,
                    interpret,
                )
            else:
                best_l, best_u = kref.candidates_scatter_tiles_ref(
                    d.val, d.col, prep.ii_g, rmf, rmc, rxf, rxc,
                    prep.lhs_g, prep.rhs_g, lb, ub, prep.n_pad, int_eps, inf,
                )
        if use_pallas:
            return kern.apply_updates_tiles(lb, ub, best_l, best_u, eps, inf, interpret)
        return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)

    # scatter == "segment": the materializing oracle path (hoisted gathers).
    lb_g = lb[d.col]
    ub_g = ub[d.col]
    if fused:
        if use_pallas:
            lcand, ucand = kern.fused_round_tiles(
                d.val, lb_g, ub_g, prep.ii_g, prep.lhs_g, prep.rhs_g,
                int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.fused_round_tiles_ref(
                d.val, lb_g, ub_g, prep.ii_g, prep.lhs_g, prep.rhs_g, int_eps, inf
            )
    else:
        if use_pallas:
            mf, mc, xf, xc = kern.activities_tiles(d.val, lb_g, ub_g, inf, interpret)
        else:
            mf, mc, xf, xc = kref.activities_tiles_ref(d.val, lb_g, ub_g, inf)
        rmf, rmc, rxf, rxc = _combine_chunk_partials(prep, mf, mc, xf, xc)
        if use_pallas:
            lcand, ucand = kern.candidates_tiles(
                d.val, lb_g, ub_g, prep.ii_g, rmf, rmc, rxf, rxc,
                prep.lhs_g, prep.rhs_g, int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.candidates_tiles_ref(
                d.val, lb_g, ub_g, prep.ii_g, rmf, rmc, rxf, rxc,
                prep.lhs_g, prep.rhs_g, int_eps, inf,
            )
    flat_col = d.col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=prep.n_pad)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=prep.n_pad)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


def legacy_round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """The seed round (``block_ell_round``) as a jit-able ``(lb, ub) ->
    (lb, ub, changed)`` closure over a prepared instance -- bounds in the
    unpadded ``(n,)`` domain.  Kept as the measured baseline."""
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        block_ell_round,
        prep.d,
        m=prep.m,
        n=prep.n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=prep.fits_one_chunk,
        interpret=interpret,
    )


def round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    scatter: str = "fused",
    fused: bool | None = None,
    interpret: bool | None = None,
):
    """A jit-able ``(lb, ub) -> (lb, ub, changed)`` round closure over a
    prepared instance (bounds in the ``(n_pad,)`` domain)."""
    scatter = _resolve_scatter(scatter, prep)
    do_fuse = prep.fits_one_chunk if fused is None else bool(fused)
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        _prepared_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=do_fuse,
        scatter=scatter,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Full propagation drivers over block-ELL
# ---------------------------------------------------------------------------


def _resolve_scatter(scatter: str, prep: PreparedBlockEll) -> str:
    if scatter == "auto":
        return "fused" if prep.n_pad <= SCATTER_MAX_NPAD else "segment"
    if scatter not in ("fused", "segment"):
        raise ValueError(f"unknown scatter mode: {scatter!r}")
    return scatter


def propagate_block_ell(
    p: Problem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    fused: str = "auto",
    driver: str = "device_loop",
    interpret: bool | None = None,
    scatter: str = "auto",
    donate: bool | None = None,
) -> PropagationResult:
    """Kernel-backed propagation.

    ``fused='auto'`` picks the Alg.-3 fusion whenever every row fits in one
    chunk (the paper's common case).  ``scatter='auto'`` picks the fully
    fused in-VMEM column reduction unless the padded column count exceeds
    the accumulator budget; ``scatter='segment'`` forces the materializing
    oracle.  ``donate=None`` donates the bound buffers wherever the backend
    implements donation (zero-copy fixed point)."""
    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    do_fuse = (
        prep.fits_one_chunk if fused == "auto" else bool(fused == "yes" or fused is True)
    )
    scatter = _resolve_scatter(scatter, prep)
    if donate is None:
        donate_kw = donate_kwargs(argnums=(0, 1))
    else:
        donate_kw = {"donate_argnums": (0, 1)} if donate else {}
    eps = cfg.eps_for(prep.d.val.dtype)
    round_fn = functools.partial(
        _prepared_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=do_fuse,
        scatter=scatter,
        interpret=interpret,
    )
    n = prep.n

    if driver == "host_loop":
        jit_round = jax.jit(round_fn, **donate_kw)
        lb, ub = owned_copy(prep.lb0), owned_copy(prep.ub0)
        rounds, changed = 0, True
        while changed and rounds < cfg.max_rounds:
            # Donated in, fresh buffers out: the loop owns its bounds, so XLA
            # reuses the same two (n_pad,) buffers round over round.
            lb, ub, cdev = jit_round(lb, ub)
            changed = bool(cdev)
            rounds += 1
        infeas = bool(jnp.any(lb[:n] > ub[:n] + cfg.feas_eps))
        return PropagationResult(
            lb[:n], ub[:n], jnp.int32(rounds), jnp.asarray(not changed), jnp.asarray(infeas)
        )

    if driver != "device_loop":
        raise ValueError(f"unknown driver: {driver!r}")

    @functools.partial(jax.jit, **donate_kw)
    def run(lb0, ub0):
        def body(state):
            lb, ub, _, r = state
            lb, ub, ch = round_fn(lb, ub)
            return lb, ub, ch, r + 1

        def cond(state):
            _, _, ch, r = state
            return ch & (r < cfg.max_rounds)

        lb, ub, ch, r = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        lb, ub = lb[:n], ub[:n]
        return lb, ub, r, ~ch, jnp.any(lb > ub + cfg.feas_eps)

    lb, ub, rounds, converged, infeasible = run(owned_copy(prep.lb0), owned_copy(prep.ub0))
    return PropagationResult(lb, ub, rounds, converged, infeasible)


# ---------------------------------------------------------------------------
# Batched engine: a whole ProblemBatch per dispatch
# ---------------------------------------------------------------------------


class DeviceProblemBatch(NamedTuple):
    """Device-resident packed batch (pytree): the flat tile stream, hoisted
    round-constant gathers/offsets, initial bounds and the real-column
    mask.  ``col`` keeps instance-local columns (the kernel routes blocks
    by ``tile_inst``); ``col_g`` carries the precomputed global ids
    ``col + tile_inst * n_pad`` for the flat XLA dataflow."""

    val: jnp.ndarray        # (T, R, K)
    col: jnp.ndarray        # (T, R, K) int32 instance-local
    col_g: jnp.ndarray      # (T, R, K) int32 global (bound-plane) columns
    chunk_row: jnp.ndarray  # (T, R) int32 global row ids
    tile_inst: jnp.ndarray  # (T,) int32 instance of each tile
    ii_g: jnp.ndarray       # (T, R, K) int32: is_int[col], hoisted
    lhs_g: jnp.ndarray      # (T, R): lhs1[chunk_row], hoisted
    rhs_g: jnp.ndarray      # (T, R)
    lb0: jnp.ndarray        # (B, n_pad)
    ub0: jnp.ndarray        # (B, n_pad)
    col_valid: jnp.ndarray  # (B, n_pad) bool: j < n_i (real columns)


@dataclasses.dataclass(frozen=True)
class PreparedBatch:
    """One bucket, device-ready.  Like :class:`PreparedBlockEll`, not a
    pytree: drivers close over it so arrays become jit constants."""

    batch: ProblemBatch
    d: DeviceProblemBatch
    size: int
    m_total: int
    n_pad: int
    fits_one_chunk: bool


_batch_prep_cache: "OrderedDict[tuple, tuple[ProblemBatch, PreparedBatch]]" = OrderedDict()
_BATCH_PREP_CACHE_CAPACITY = 16


def prepare_problem_batch(batch: ProblemBatch, dtype=None) -> PreparedBatch:
    """Device transfer + hoisted constant gathers for one packed bucket,
    LRU-cached per ``ProblemBatch`` (the serving pattern re-propagates the
    same packed batch with fresh bounds)."""
    ell = batch.ell
    dt = np.dtype(dtype) if dtype is not None else np.dtype(ell.val.dtype)
    key = (id(batch), dt.str)
    hit = _batch_prep_cache.get(key)
    if hit is not None and hit[0] is batch:
        _batch_prep_cache.move_to_end(key)
        return hit[1]

    n_pad = batch.n_pad
    col_g = ell.col + ell.tile_inst[:, None, None] * np.int32(n_pad)
    ii_g = batch.is_int.reshape(-1)[col_g]
    lhs_g = batch.lhs1[ell.chunk_row]
    rhs_g = batch.rhs1[ell.chunk_row]
    col_valid = np.arange(n_pad)[None, :] < ell.n[:, None]
    d = DeviceProblemBatch(
        val=jnp.asarray(ell.val, dtype=dt),
        col=jnp.asarray(ell.col),
        col_g=jnp.asarray(col_g),
        chunk_row=jnp.asarray(ell.chunk_row),
        tile_inst=jnp.asarray(ell.tile_inst),
        ii_g=jnp.asarray(ii_g.astype(np.int32)),
        lhs_g=jnp.asarray(lhs_g.astype(dt)),
        rhs_g=jnp.asarray(rhs_g.astype(dt)),
        lb0=jnp.asarray(batch.lb, dtype=dt),
        ub0=jnp.asarray(batch.ub, dtype=dt),
        col_valid=jnp.asarray(col_valid),
    )
    prep = PreparedBatch(
        batch=batch,
        d=d,
        size=batch.size,
        m_total=batch.m_total,
        n_pad=n_pad,
        fits_one_chunk=all(
            rows_fit_one_chunk(p, ell.tile_width) for p in batch.problems
        ),
    )
    _batch_prep_cache[key] = (batch, prep)
    while len(_batch_prep_cache) > _BATCH_PREP_CACHE_CAPACITY:
        _batch_prep_cache.popitem(last=False)
    return prep


def batched_reference_round(
    val, col_g, ii_g, chunk_row, lhs_g, rhs_g, lb, ub, active,
    *, m_total: int, n_pad: int, fits_one_chunk: bool,
    eps: float, int_eps: float, inf: float,
):
    """One batched round at the data level (jnp oracle arithmetic), usable
    under ``shard_map``/``jit`` with the batch axis as a plain leading dim
    of the bound plane.  The whole batch is ONE flat dataflow -- one
    gather, one candidate sweep, one column segment reduction -- so the
    per-op dispatch overhead is paid once per round, not once per instance.
    Inactive instances' candidates are forced to the reduction identity, so
    their bounds pass through unchanged and report no change."""
    if fits_one_chunk:
        best_l, best_u = kref.batched_fused_scatter_round_ref(
            val, col_g, ii_g, lhs_g, rhs_g, lb, ub, n_pad, int_eps, inf
        )
    else:
        best_l, best_u = kref.batched_candidates_scatter_round_ref(
            val, col_g, ii_g, chunk_row, lhs_g, rhs_g, lb, ub,
            m_total, n_pad, int_eps, inf,
        )
    best_l = jnp.where(active[:, None], best_l, -inf)
    best_u = jnp.where(active[:, None], best_u, inf)
    return bnd.apply_updates_batch(lb, ub, best_l, best_u, eps, inf)


def _batched_prepared_round(
    prep: PreparedBatch, lb, ub, active,
    *, eps: float, int_eps: float, inf: float,
    use_pallas: bool, interpret: bool | None,
):
    """One round over a prepared bucket: ``(B, n_pad)`` bounds + ``(B,)``
    active mask -> updated bounds + per-instance changed flags.

    The Pallas path (chunk-complete rows, the paper's common case) runs the
    batched kernel D -- the grid walks the flat tile stream, the
    scalar-prefetched instance map routes each tile to its bound-plane and
    accumulator rows, converged instances are gated off in-kernel -- then
    the batched merge kernel.  Buckets with rows spanning chunks use the
    batched jnp dataflow (the multichunk kernels stay single-instance, as
    does the ``SCATTER_MAX_NPAD`` fallback)."""
    d = prep.d
    if use_pallas and prep.fits_one_chunk and prep.n_pad <= SCATTER_MAX_NPAD:
        best_l, best_u = kern.batched_fused_scatter_round_tiles(
            d.val, d.col, d.ii_g, d.lhs_g, d.rhs_g, lb, ub,
            d.tile_inst, active, prep.n_pad, int_eps, inf, interpret,
        )
        return kern.apply_updates_batch_tiles(
            lb, ub, best_l, best_u, active, eps, inf, interpret
        )
    return batched_reference_round(
        d.val, d.col_g, d.ii_g, d.chunk_row, d.lhs_g, d.rhs_g, lb, ub, active,
        m_total=prep.m_total, n_pad=prep.n_pad,
        fits_one_chunk=prep.fits_one_chunk,
        eps=eps, int_eps=int_eps, inf=inf,
    )


def batched_round_fn_for(
    prep: PreparedBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """A jit-able ``(lb, ub, active) -> (lb, ub, changed)`` batched round
    closure over a prepared bucket."""
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        _batched_prepared_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        interpret=interpret,
    )


def _unpack_batch_results(prep, lb, ub, rounds, converged, infeasible):
    out = []
    for i, p in enumerate(prep.batch.problems):
        out.append(
            PropagationResult(
                lb[i, : p.n], ub[i, : p.n], rounds[i], converged[i], infeasible[i]
            )
        )
    return out


# Jitted fixed-point runners, cached per prepared bucket + config: the
# serving loop re-propagates the same packed batches, and rebuilding the jit
# closure per request would recompile every time.
_batch_runner_cache: "OrderedDict[tuple, tuple[PreparedBatch, object]]" = OrderedDict()
_BATCH_RUNNER_CACHE_CAPACITY = 64


def _cached_batch_runner(prep, key, build):
    hit = _batch_runner_cache.get(key)
    if hit is not None and hit[0] is prep:
        _batch_runner_cache.move_to_end(key)
        return hit[1]
    runner = build()
    _batch_runner_cache[key] = (prep, runner)
    while len(_batch_runner_cache) > _BATCH_RUNNER_CACHE_CAPACITY:
        _batch_runner_cache.popitem(last=False)
    return runner


def batched_device_runner(
    prep: PreparedBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
    donate: bool | None = None,
):
    """The bucket's whole fixed point as ONE jitted dispatch, cached:
    ``run(lb0, ub0) -> (lb, ub, rounds, converged, infeasible)`` (all
    per-instance; ``lb0``/``ub0`` donated where supported)."""
    key = (id(prep), cfg, use_pallas, interpret, donate, "device")

    def build():
        round_fn = batched_round_fn_for(prep, cfg, use_pallas, interpret)
        if donate is None:
            donate_kw = donate_kwargs(argnums=(0, 1))
        else:
            donate_kw = {"donate_argnums": (0, 1)} if donate else {}
        col_valid = prep.d.col_valid

        @functools.partial(jax.jit, **donate_kw)
        def run(lb0, ub0):
            lb, ub, rounds, converged = batched_fixed_point(
                round_fn, lb0, ub0, cfg.max_rounds
            )
            infeasible = jnp.any((lb > ub + cfg.feas_eps) & col_valid, axis=-1)
            return lb, ub, rounds, converged, infeasible

        return run

    return _cached_batch_runner(prep, key, build)


def propagate_batch_prepared(
    prep: PreparedBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    driver: str = "device_loop",
    interpret: bool | None = None,
    donate: bool | None = None,
):
    """Run one prepared bucket to its per-instance fixed points.

    ``device_loop``: the entire batched fixed point is ONE dispatch
    (``batched_fixed_point`` under jit, bounds donated).  ``host_loop``:
    host syncs the per-instance changed flags each round and retires
    converged instances from the active mask.  Returns one
    ``PropagationResult`` per instance, bucket order."""
    d = prep.d
    bsz = prep.size

    if driver == "host_loop":
        key = (id(prep), cfg, use_pallas, interpret, donate, "host")

        def build():
            round_fn = batched_round_fn_for(prep, cfg, use_pallas, interpret)
            if donate is None:
                donate_kw = donate_kwargs(argnums=(0, 1))
            else:
                donate_kw = {"donate_argnums": (0, 1)} if donate else {}
            return jax.jit(round_fn, **donate_kw)

        jit_round = _cached_batch_runner(prep, key, build)
        lb, ub = owned_copy(d.lb0), owned_copy(d.ub0)
        active = np.ones(bsz, dtype=bool)
        last_changed = np.ones(bsz, dtype=bool)
        rounds = np.zeros(bsz, dtype=np.int32)
        while active.any():
            lb, ub, ch = jit_round(lb, ub, jnp.asarray(active))
            ch = np.asarray(ch)  # the per-round host<->device sync point
            rounds += active
            last_changed = np.where(active, ch, last_changed)
            active = active & ch & (rounds < cfg.max_rounds)
        infeasible = np.asarray(
            jnp.any((lb > ub + cfg.feas_eps) & d.col_valid, axis=-1)
        )
        return _unpack_batch_results(
            prep, lb, ub, rounds, ~last_changed, infeasible
        )

    if driver != "device_loop":
        raise ValueError(f"unknown driver: {driver!r}")

    run = batched_device_runner(prep, cfg, use_pallas, interpret, donate)
    lb, ub, rounds, converged, infeasible = run(owned_copy(d.lb0), owned_copy(d.ub0))
    return _unpack_batch_results(prep, lb, ub, rounds, converged, infeasible)


# Packed-batch cache: serving re-propagates the same request list, and
# repacking would defeat both the prepare() and the runner caches (both key
# on object identity).
_pack_cache: "OrderedDict[tuple, tuple[tuple, list]]" = OrderedDict()
_PACK_CACHE_CAPACITY = 8


def packed_problems(problems, tile_rows: int = 8, tile_width: int = 128):
    """LRU-cached ``pack_problems``: the same problem list (by identity)
    packs once and reuses its ``ProblemBatch`` objects across calls."""
    problems = list(problems)
    key = (tuple(id(p) for p in problems), tile_rows, tile_width)
    hit = _pack_cache.get(key)
    if hit is not None and all(a is b for a, b in zip(hit[0], problems)):
        _pack_cache.move_to_end(key)
        return hit[1]
    batches = pack_problems(problems, tile_rows=tile_rows, tile_width=tile_width)
    _pack_cache[key] = (tuple(problems), batches)
    while len(_pack_cache) > _PACK_CACHE_CAPACITY:
        _pack_cache.popitem(last=False)
    return batches


def clear_batch_caches() -> None:
    """Drop packed batches, prepared buckets and jitted runners."""
    _pack_cache.clear()
    _batch_prep_cache.clear()
    _batch_runner_cache.clear()


def propagate_batch_block_ell(
    problems,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    driver: str = "device_loop",
    interpret: bool | None = None,
    donate: bool | None = None,
):
    """Batched kernel-backed propagation: pack -> per-bucket dispatch ->
    per-instance results in input order.  Packing, device transfer and the
    jitted fixed-point runners are all LRU-cached, so a serving loop that
    re-propagates the same instances pays them once.  The public front end
    is ``repro.core.propagate_batch``."""
    problems = list(problems)
    batches = packed_problems(problems, tile_rows=tile_rows, tile_width=tile_width)
    out = [None] * len(problems)
    for batch in batches:
        prep = prepare_problem_batch(batch, dtype)
        results = propagate_batch_prepared(
            prep, cfg, use_pallas=use_pallas, driver=driver,
            interpret=interpret, donate=donate,
        )
        for idx, res in zip(batch.indices, results):
            out[idx] = res
    return out


# ---------------------------------------------------------------------------
# Measured bytes-per-round (XLA cost analysis, not assertions)
# ---------------------------------------------------------------------------


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    size = 1
    for s in shape:
        size *= int(s)
    return size * np.dtype(aval.dtype).itemsize


# Structural primitives whose own operands are pass-through loop/call state:
# recurse into their bodies (counted once, as HloCostAnalysis does for while
# bodies) instead of counting the carried tuple.
_RECURSE_PRIMS = frozenset(
    {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call", "while", "cond", "scan"}
)
_INNER_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr", "branches")


def _inner_jaxprs(eqn):
    out = []
    for name in _INNER_JAXPR_PARAMS:
        v = eqn.params.get(name)
        if v is None:
            continue
        for j in v if isinstance(v, (list, tuple)) else [v]:
            out.append(j.jaxpr if hasattr(j, "jaxpr") else j)
    return out


def hbm_bytes_of(fn, *args) -> float:
    """HBM-boundary bytes-accessed of ``fn``, measured from its traced jaxpr.

    Every XLA op counts operand + result bytes -- the same per-instruction
    definition XLA's ``HloCostAnalysis`` uses.  A ``pallas_call`` counts its
    operands + results only: that is exactly the traffic the kernel DMAs
    between HBM and VMEM, while kernel-internal values are VMEM/register
    resident by construction (the interpret-mode emulation would otherwise
    misattribute them as memory traffic).
    """
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr) -> float:
        total = 0.0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in _RECURSE_PRIMS:
                for inner in _inner_jaxprs(eqn):
                    total += walk(inner)
                continue
            total += sum(
                _aval_bytes(v.aval)
                for v in list(eqn.invars) + list(eqn.outvars)
                if hasattr(v, "aval")
            )
        return total

    return walk(closed.jaxpr)


def round_cost_analysis(
    p: Problem,
    scatter: str = "fused",
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    interpret: bool | None = None,
    include_compiled: bool = False,
) -> dict:
    """Measure ONE propagation round's memory traffic.

    ``scatter`` selects the dataflow being measured:
      * ``"fused"``   -- the fully fused in-VMEM gather+round+reduction;
      * ``"segment"`` -- candidates materialized + XLA segment reduction,
        with hoisted constant gathers;
      * ``"legacy"``  -- the seed round verbatim (``block_ell_round``):
        per-round constant gathers + materialized candidates.

    Returns a dict with
      * ``bytes_accessed``: HBM-boundary bytes (see ``hbm_bytes_of``) -- the
        number the fused engine is designed to shrink;
      * with ``include_compiled=True``, also ``bytes_accessed_compiled`` /
        ``flops``: the raw aggregate from ``Compiled.cost_analysis()`` on
        this backend's lowering, reported for transparency (on CPU it
        includes interpret-mode emulation buffers that a TPU kernel keeps in
        VMEM; computing it pays a full XLA compile, hence opt-in).
    """
    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    val_dtype = prep.d.val.dtype
    if scatter == "legacy":
        fn = legacy_round_fn_for(prep, cfg, use_pallas=True, interpret=interpret)
        shape = (prep.n,)
    else:
        fn = round_fn_for(prep, cfg, use_pallas=True, scatter=scatter, interpret=interpret)
        shape = (prep.n_pad,)
    sds = jax.ShapeDtypeStruct(shape, val_dtype)
    out = {"bytes_accessed": hbm_bytes_of(fn, sds, sds)}
    if include_compiled:
        compiled = jax.jit(fn).lower(sds, sds).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["bytes_accessed_compiled"] = float(ca.get("bytes accessed", 0.0))
        out["flops"] = float(ca.get("flops", 0.0))
    return out
