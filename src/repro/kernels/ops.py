"""Jit'd public wrappers around the Pallas kernels: a complete block-ELL
propagation engine (kernels + column reduction + bound update).

This is the kernel-backed sibling of ``core.propagator``; both share the
bound-update logic so they converge to identical fixed points.

Engine anatomy (see README "fused-scatter dataflow"):

  * ``prepare_block_ell`` -- one-time, cached per instance: block-ELL
    conversion, device transfer, and the *round-constant* gathers
    (``is_int[col]``, ``lhs1[chunk_row]``, ``rhs1[chunk_row]``) that the seed
    engine recomputed every round.
  * ``scatter="fused"`` -- the fully fused round: one Pallas kernel gathers
    the bounds in-kernel from the VMEM-resident (n_pad,) vectors, computes
    activities and candidates, AND does the column-wise best-bound
    reduction into ``(2, n_pad)`` accumulators that stay in VMEM across all
    grid steps; a small merge kernel then folds them into (lb, ub) in place
    (``input_output_aliases``).  NO nnz-shaped tensor -- neither gathered
    bounds nor candidates -- is produced in HBM during a round.
  * ``scatter="segment"`` -- the materializing oracle: XLA bound gathers,
    candidates written to HBM, column reduction via XLA segment ops (the
    seed dataflow, kept for cross-validation and as the fallback when
    ``n_pad`` exceeds the VMEM accumulator budget).
  * Zero-copy fixed point: every jitted driver donates the (lb, ub) buffers
    (``donate_argnums``) so XLA updates bounds in place round over round.
    Donation is requested only on backends that implement it (TPU/GPU); the
    drivers hand the loop *private copies* of the cached initial bounds so
    donation can never invalidate the prepare() cache.

Per-round HBM-traffic model (8-byte fp, 4-byte ints, nnz_pad = T*R*K):

  segment (seed): gather writes+reads 2x lb/ub + is_int (~40 B/nnz), tile
    reads val+col (~12 B/nnz), candidate writes (~16 B/nnz), segment-op
    candidate+col reads (~24 B/nnz)   => ~92 B/nnz + O(m + n)
  fused:          tile reads val+col+is_int (~16 B/nnz) + O(m + n_pad)
    for the resident bound/accumulator vectors and row aggregates

``round_cost_analysis`` measures this at the HBM boundary of the actual
lowered round instead of asserting it.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bounds as bnd
from ..core.propagator import donate_kwargs, owned_copy
from ..core.sparse import BlockEll, Problem, csr_to_block_ell
from ..core.types import DEFAULT_CONFIG, INF, PropagationResult, PropagatorConfig
from . import prop_round as kern
from . import ref as kref


class DeviceBlockEll(NamedTuple):
    """Device-resident block-ELL instance (pytree)."""

    val: jnp.ndarray        # (T, R, K)
    col: jnp.ndarray        # (T, R, K) int32
    chunk_row: jnp.ndarray  # (T, R) int32 in [0, m]; m == padding
    lhs1: jnp.ndarray       # (m+1,) sides padded with one dummy slot at index m
    rhs1: jnp.ndarray       # (m+1,)
    is_int: jnp.ndarray     # (n,) bool
    lb0: jnp.ndarray        # (n,)
    ub0: jnp.ndarray        # (n,)


def device_block_ell(p: Problem, tile_rows: int = 8, tile_width: int = 128, dtype=None) -> DeviceBlockEll:
    dtype = dtype or p.csr.val.dtype
    b = csr_to_block_ell(p.csr, tile_rows=tile_rows, tile_width=tile_width)
    pad1 = lambda x: np.concatenate([x, np.zeros(1, dtype=x.dtype)])
    return DeviceBlockEll(
        val=jnp.asarray(b.val, dtype=dtype),
        col=jnp.asarray(b.col),
        chunk_row=jnp.asarray(b.chunk_row),
        lhs1=jnp.asarray(pad1(p.lhs), dtype=dtype),
        rhs1=jnp.asarray(pad1(p.rhs), dtype=dtype),
        is_int=jnp.asarray(p.is_int),
        lb0=jnp.asarray(p.lb, dtype=dtype),
        ub0=jnp.asarray(p.ub, dtype=dtype),
    )


def rows_fit_one_chunk(p: Problem, tile_width: int) -> bool:
    return int(np.diff(p.csr.row_ptr).max(initial=0)) <= tile_width


# ---------------------------------------------------------------------------
# Prepared instances: one-time setup, hoisted round constants, LRU-cached
# ---------------------------------------------------------------------------

# Largest column-padded width the fused scatter keeps resident in VMEM
# (2 accumulators x n_pad x 8 B = 1 MiB at the cap; ~6% of a v5e core's VMEM).
SCATTER_MAX_NPAD = 1 << 16


@dataclasses.dataclass(frozen=True)
class PreparedBlockEll:
    """Device tiles + everything about a round that does not change across
    rounds: the constant gathers the seed engine recomputed per round, the
    column-padded initial bounds, and static layout facts.

    Not a pytree on purpose -- drivers close over it, so its arrays become
    jit constants and its ints/bools stay static.
    """

    d: DeviceBlockEll
    ii_g: jnp.ndarray    # (T, R, K) int32: is_int[col], hoisted
    lhs_g: jnp.ndarray   # (T, R): lhs1[chunk_row], hoisted
    rhs_g: jnp.ndarray   # (T, R): rhs1[chunk_row], hoisted
    lb0: jnp.ndarray     # (n_pad,) initial bounds in the column-padded domain
    ub0: jnp.ndarray     # (n_pad,)
    m: int
    n: int
    n_pad: int
    fits_one_chunk: bool


_prep_cache: "OrderedDict[tuple, tuple[Problem, PreparedBlockEll]]" = OrderedDict()
_PREP_CACHE_CAPACITY = 32


def prepare_block_ell(
    p: Problem, tile_rows: int = 8, tile_width: int = 128, dtype=None
) -> PreparedBlockEll:
    """One-time setup for kernel-backed propagation, LRU-cached per instance.

    Repeated propagations of the same ``Problem`` (the benchmark pattern)
    reuse the block-ELL tiles, device buffers and hoisted gathers instead of
    rebuilding and re-transferring them.  The cache keeps a strong reference
    to the keyed ``Problem`` so ``id()`` keys cannot be recycled while an
    entry is live.
    """
    dt = np.dtype(dtype) if dtype is not None else np.dtype(p.csr.val.dtype)
    key = (id(p), tile_rows, tile_width, dt.str)
    hit = _prep_cache.get(key)
    if hit is not None and hit[0] is p:
        _prep_cache.move_to_end(key)
        return hit[1]

    d = device_block_ell(p, tile_rows, tile_width, dt)
    n_pad = kern.col_pad(p.n)
    padn = lambda x: jnp.concatenate([x, jnp.zeros((n_pad - p.n,), x.dtype)])
    prep = PreparedBlockEll(
        d=d,
        ii_g=d.is_int[d.col].astype(jnp.int32),
        lhs_g=d.lhs1[d.chunk_row],
        rhs_g=d.rhs1[d.chunk_row],
        lb0=padn(d.lb0) if n_pad > p.n else d.lb0,
        ub0=padn(d.ub0) if n_pad > p.n else d.ub0,
        m=p.m,
        n=p.n,
        n_pad=n_pad,
        fits_one_chunk=rows_fit_one_chunk(p, tile_width),
    )
    _prep_cache[key] = (p, prep)
    while len(_prep_cache) > _PREP_CACHE_CAPACITY:
        _prep_cache.popitem(last=False)
    return prep


def clear_prepare_cache() -> None:
    """Drop all cached prepared instances (frees device buffers)."""
    _prep_cache.clear()


# ---------------------------------------------------------------------------
# One block-ELL round
# ---------------------------------------------------------------------------


def block_ell_round(
    d: DeviceBlockEll,
    lb,
    ub,
    m: int,
    n: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
    use_pallas: bool = True,
    fused: bool = False,
    interpret: bool | None = None,
):
    """One propagation round over block-ELL tiles (seed dataflow, kept as the
    legacy baseline: per-round constant gathers, candidates materialized in
    HBM, XLA segment reduction).  Returns (lb, ub, changed)."""
    lb_g = lb[d.col]
    ub_g = ub[d.col]
    ii_g = d.is_int[d.col]
    lhs_g = d.lhs1[d.chunk_row]
    rhs_g = d.rhs1[d.chunk_row]

    if fused:
        # Alg.-3-faithful: activities live in VMEM, reused for candidates.
        if use_pallas:
            lcand, ucand = kern.fused_round_tiles(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf, interpret
            )
        else:
            lcand, ucand = kref.fused_round_tiles_ref(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf
            )
    else:
        if use_pallas:
            mf, mc, xf, xc = kern.activities_tiles(d.val, lb_g, ub_g, inf, interpret)
        else:
            mf, mc, xf, xc = kref.activities_tiles_ref(d.val, lb_g, ub_g, inf)
        # Combine chunk partials into completed row aggregates (long rows).
        crow = d.chunk_row.reshape(-1)
        seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=m + 1)
        row_mf, row_mc = seg(mf), seg(mc)
        row_xf, row_xc = seg(xf), seg(xc)
        # Gather completed aggregates back per chunk.
        g = lambda x: x[d.chunk_row]
        if use_pallas:
            lcand, ucand = kern.candidates_tiles(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.candidates_tiles_ref(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf,
            )

    flat_col = d.col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=n)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=n)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


def _combine_chunk_partials(prep: PreparedBlockEll, mf, mc, xf, xc):
    """Chunk partials -> completed per-chunk row aggregates (long rows)."""
    d = prep.d
    crow = d.chunk_row.reshape(-1)
    seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=prep.m + 1)
    g = lambda x: seg(x)[d.chunk_row]
    return g(mf), g(mc), g(xf), g(xc)


def _prepared_round(
    prep: PreparedBlockEll,
    lb,
    ub,
    *,
    eps: float,
    int_eps: float,
    inf: float,
    use_pallas: bool,
    fused: bool,
    scatter: str,
    interpret: bool | None,
):
    """One round over hoisted constants.  (lb, ub) live in the column-padded
    ``(n_pad,)`` domain end to end; only the bound gathers run in XLA."""
    d = prep.d

    if scatter == "fused":
        if fused:
            # Fully fused: even the bound gather happens in the kernel, so
            # no nnz-shaped tensor is produced in HBM at all this round.
            if use_pallas:
                best_l, best_u = kern.fused_scatter_round_tiles(
                    d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g,
                    lb, ub, prep.n_pad, int_eps, inf, interpret,
                )
            else:
                best_l, best_u = kref.fused_scatter_round_tiles_ref(
                    d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g,
                    lb, ub, prep.n_pad, int_eps, inf,
                )
        else:
            # Long rows: chunk partials (in-kernel gather) -> XLA segment
            # combine of the tiny (T, R) aggregates -> fused scatter round.
            if use_pallas:
                mf, mc, xf, xc = kern.activities_gather_tiles(
                    d.val, d.col, lb, ub, prep.n_pad, inf, interpret
                )
            else:
                mf, mc, xf, xc = kref.activities_gather_tiles_ref(
                    d.val, d.col, lb, ub, prep.n_pad, inf
                )
            rmf, rmc, rxf, rxc = _combine_chunk_partials(prep, mf, mc, xf, xc)
            if use_pallas:
                best_l, best_u = kern.candidates_scatter_tiles(
                    d.val, d.col, prep.ii_g, rmf, rmc, rxf, rxc,
                    prep.lhs_g, prep.rhs_g, lb, ub, prep.n_pad, int_eps, inf,
                    interpret,
                )
            else:
                best_l, best_u = kref.candidates_scatter_tiles_ref(
                    d.val, d.col, prep.ii_g, rmf, rmc, rxf, rxc,
                    prep.lhs_g, prep.rhs_g, lb, ub, prep.n_pad, int_eps, inf,
                )
        if use_pallas:
            return kern.apply_updates_tiles(lb, ub, best_l, best_u, eps, inf, interpret)
        return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)

    # scatter == "segment": the materializing oracle path (hoisted gathers).
    lb_g = lb[d.col]
    ub_g = ub[d.col]
    if fused:
        if use_pallas:
            lcand, ucand = kern.fused_round_tiles(
                d.val, lb_g, ub_g, prep.ii_g, prep.lhs_g, prep.rhs_g,
                int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.fused_round_tiles_ref(
                d.val, lb_g, ub_g, prep.ii_g, prep.lhs_g, prep.rhs_g, int_eps, inf
            )
    else:
        if use_pallas:
            mf, mc, xf, xc = kern.activities_tiles(d.val, lb_g, ub_g, inf, interpret)
        else:
            mf, mc, xf, xc = kref.activities_tiles_ref(d.val, lb_g, ub_g, inf)
        rmf, rmc, rxf, rxc = _combine_chunk_partials(prep, mf, mc, xf, xc)
        if use_pallas:
            lcand, ucand = kern.candidates_tiles(
                d.val, lb_g, ub_g, prep.ii_g, rmf, rmc, rxf, rxc,
                prep.lhs_g, prep.rhs_g, int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.candidates_tiles_ref(
                d.val, lb_g, ub_g, prep.ii_g, rmf, rmc, rxf, rxc,
                prep.lhs_g, prep.rhs_g, int_eps, inf,
            )
    flat_col = d.col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=prep.n_pad)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=prep.n_pad)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


def legacy_round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """The seed round (``block_ell_round``) as a jit-able ``(lb, ub) ->
    (lb, ub, changed)`` closure over a prepared instance -- bounds in the
    unpadded ``(n,)`` domain.  Kept as the measured baseline."""
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        block_ell_round,
        prep.d,
        m=prep.m,
        n=prep.n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=prep.fits_one_chunk,
        interpret=interpret,
    )


def round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    scatter: str = "fused",
    fused: bool | None = None,
    interpret: bool | None = None,
):
    """A jit-able ``(lb, ub) -> (lb, ub, changed)`` round closure over a
    prepared instance (bounds in the ``(n_pad,)`` domain)."""
    scatter = _resolve_scatter(scatter, prep)
    do_fuse = prep.fits_one_chunk if fused is None else bool(fused)
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        _prepared_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=do_fuse,
        scatter=scatter,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Full propagation drivers over block-ELL
# ---------------------------------------------------------------------------


def _resolve_scatter(scatter: str, prep: PreparedBlockEll) -> str:
    if scatter == "auto":
        return "fused" if prep.n_pad <= SCATTER_MAX_NPAD else "segment"
    if scatter not in ("fused", "segment"):
        raise ValueError(f"unknown scatter mode: {scatter!r}")
    return scatter


def propagate_block_ell(
    p: Problem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    fused: str = "auto",
    driver: str = "device_loop",
    interpret: bool | None = None,
    scatter: str = "auto",
    donate: bool | None = None,
) -> PropagationResult:
    """Kernel-backed propagation.

    ``fused='auto'`` picks the Alg.-3 fusion whenever every row fits in one
    chunk (the paper's common case).  ``scatter='auto'`` picks the fully
    fused in-VMEM column reduction unless the padded column count exceeds
    the accumulator budget; ``scatter='segment'`` forces the materializing
    oracle.  ``donate=None`` donates the bound buffers wherever the backend
    implements donation (zero-copy fixed point)."""
    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    do_fuse = (
        prep.fits_one_chunk if fused == "auto" else bool(fused == "yes" or fused is True)
    )
    scatter = _resolve_scatter(scatter, prep)
    if donate is None:
        donate_kw = donate_kwargs(argnums=(0, 1))
    else:
        donate_kw = {"donate_argnums": (0, 1)} if donate else {}
    eps = cfg.eps_for(prep.d.val.dtype)
    round_fn = functools.partial(
        _prepared_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=do_fuse,
        scatter=scatter,
        interpret=interpret,
    )
    n = prep.n

    if driver == "host_loop":
        jit_round = jax.jit(round_fn, **donate_kw)
        lb, ub = owned_copy(prep.lb0), owned_copy(prep.ub0)
        rounds, changed = 0, True
        while changed and rounds < cfg.max_rounds:
            # Donated in, fresh buffers out: the loop owns its bounds, so XLA
            # reuses the same two (n_pad,) buffers round over round.
            lb, ub, cdev = jit_round(lb, ub)
            changed = bool(cdev)
            rounds += 1
        infeas = bool(jnp.any(lb[:n] > ub[:n] + cfg.feas_eps))
        return PropagationResult(
            lb[:n], ub[:n], jnp.int32(rounds), jnp.asarray(not changed), jnp.asarray(infeas)
        )

    if driver != "device_loop":
        raise ValueError(f"unknown driver: {driver!r}")

    @functools.partial(jax.jit, **donate_kw)
    def run(lb0, ub0):
        def body(state):
            lb, ub, _, r = state
            lb, ub, ch = round_fn(lb, ub)
            return lb, ub, ch, r + 1

        def cond(state):
            _, _, ch, r = state
            return ch & (r < cfg.max_rounds)

        lb, ub, ch, r = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        lb, ub = lb[:n], ub[:n]
        return lb, ub, r, ~ch, jnp.any(lb > ub + cfg.feas_eps)

    lb, ub, rounds, converged, infeasible = run(owned_copy(prep.lb0), owned_copy(prep.ub0))
    return PropagationResult(lb, ub, rounds, converged, infeasible)


# ---------------------------------------------------------------------------
# Measured bytes-per-round (XLA cost analysis, not assertions)
# ---------------------------------------------------------------------------


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    size = 1
    for s in shape:
        size *= int(s)
    return size * np.dtype(aval.dtype).itemsize


# Structural primitives whose own operands are pass-through loop/call state:
# recurse into their bodies (counted once, as HloCostAnalysis does for while
# bodies) instead of counting the carried tuple.
_RECURSE_PRIMS = frozenset(
    {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call", "while", "cond", "scan"}
)
_INNER_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr", "branches")


def _inner_jaxprs(eqn):
    out = []
    for name in _INNER_JAXPR_PARAMS:
        v = eqn.params.get(name)
        if v is None:
            continue
        for j in v if isinstance(v, (list, tuple)) else [v]:
            out.append(j.jaxpr if hasattr(j, "jaxpr") else j)
    return out


def hbm_bytes_of(fn, *args) -> float:
    """HBM-boundary bytes-accessed of ``fn``, measured from its traced jaxpr.

    Every XLA op counts operand + result bytes -- the same per-instruction
    definition XLA's ``HloCostAnalysis`` uses.  A ``pallas_call`` counts its
    operands + results only: that is exactly the traffic the kernel DMAs
    between HBM and VMEM, while kernel-internal values are VMEM/register
    resident by construction (the interpret-mode emulation would otherwise
    misattribute them as memory traffic).
    """
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr) -> float:
        total = 0.0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in _RECURSE_PRIMS:
                for inner in _inner_jaxprs(eqn):
                    total += walk(inner)
                continue
            total += sum(
                _aval_bytes(v.aval)
                for v in list(eqn.invars) + list(eqn.outvars)
                if hasattr(v, "aval")
            )
        return total

    return walk(closed.jaxpr)


def round_cost_analysis(
    p: Problem,
    scatter: str = "fused",
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    interpret: bool | None = None,
    include_compiled: bool = False,
) -> dict:
    """Measure ONE propagation round's memory traffic.

    ``scatter`` selects the dataflow being measured:
      * ``"fused"``   -- the fully fused in-VMEM gather+round+reduction;
      * ``"segment"`` -- candidates materialized + XLA segment reduction,
        with hoisted constant gathers;
      * ``"legacy"``  -- the seed round verbatim (``block_ell_round``):
        per-round constant gathers + materialized candidates.

    Returns a dict with
      * ``bytes_accessed``: HBM-boundary bytes (see ``hbm_bytes_of``) -- the
        number the fused engine is designed to shrink;
      * with ``include_compiled=True``, also ``bytes_accessed_compiled`` /
        ``flops``: the raw aggregate from ``Compiled.cost_analysis()`` on
        this backend's lowering, reported for transparency (on CPU it
        includes interpret-mode emulation buffers that a TPU kernel keeps in
        VMEM; computing it pays a full XLA compile, hence opt-in).
    """
    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    val_dtype = prep.d.val.dtype
    if scatter == "legacy":
        fn = legacy_round_fn_for(prep, cfg, use_pallas=True, interpret=interpret)
        shape = (prep.n,)
    else:
        fn = round_fn_for(prep, cfg, use_pallas=True, scatter=scatter, interpret=interpret)
        shape = (prep.n_pad,)
    sds = jax.ShapeDtypeStruct(shape, val_dtype)
    out = {"bytes_accessed": hbm_bytes_of(fn, sds, sds)}
    if include_compiled:
        compiled = jax.jit(fn).lower(sds, sds).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["bytes_accessed_compiled"] = float(ca.get("bytes accessed", 0.0))
        out["flops"] = float(ca.get("flops", 0.0))
    return out
