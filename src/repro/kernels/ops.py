"""Jit'd public wrappers around the Pallas kernels: a complete block-ELL
propagation engine (kernels + column reduction + bound update).

This is the kernel-backed sibling of ``core.propagator``; both share the
bound-update logic so they converge to identical fixed points.

Engine anatomy (see README "fused-scatter dataflow"):

  * ``prepare_block_ell`` -- one-time, cached per instance: block-ELL
    conversion, device transfer, and the *round-constant* gathers
    (``is_int[col]``, ``lhs1[chunk_row]``, ``rhs1[chunk_row]``) that the seed
    engine recomputed every round.
  * ``scatter="fused"`` -- the fully fused round: one Pallas kernel gathers
    the bounds in-kernel from the VMEM-resident (n_pad,) vectors, computes
    activities and candidates, AND does the column-wise best-bound
    reduction into ``(2, n_pad)`` accumulators that stay in VMEM across all
    grid steps; a small merge kernel then folds them into (lb, ub) in place
    (``input_output_aliases``).  NO nnz-shaped tensor -- neither gathered
    bounds nor candidates -- is produced in HBM during a round.
  * ``scatter="partitioned"`` -- the column-slab engine for instances whose
    ``n_pad`` exceeds the VMEM accumulator budget: the padded column space
    is split into balanced slabs (``default_slab_width``, capped at
    ``SLAB_NPAD``, overridable per call via ``slab=``), the CHUNK stream
    into per-slab masked copies grouped by ``(instance, slab)`` window
    (``build_slab_partition``, cached on the prep per width), and the round
    is ONE fused slab-parallel kernel on a 2D ``(run, tile)`` grid --
    gather, activities, candidates, per-slab scatter into VMEM scratch
    accumulators AND the bound merge, with the window (slab) axis parallel.
    Only rows whose nonzeros straddle copies detour through a tiny
    out-of-band partials kernel + XLA segment combine first.  Only
    ``(1, S)`` bound/accumulator windows are ever VMEM-resident, no partial
    bound plane round-trips through HBM, and the fused byte model holds at
    any instance size.  ``scatter="auto"`` selects it beyond
    ``SCATTER_MAX_NPAD`` (override: ``REPRO_AUTO_LARGE_SCATTER=segment``).
  * ``scatter="segment"`` -- the materializing oracle: XLA bound gathers,
    candidates written to HBM, column reduction via XLA segment ops (the
    seed dataflow, kept for cross-validation).
  * Zero-copy fixed point: every jitted driver donates the (lb, ub) buffers
    (``donate_argnums``) so XLA updates bounds in place round over round.
    Donation is requested only on backends that implement it (TPU/GPU); the
    drivers hand the loop *private copies* of the cached initial bounds so
    donation can never invalidate the prepare() cache.

Per-round HBM-traffic model (8-byte fp, 4-byte ints, nnz_pad = T*R*K):

  segment (seed): gather writes+reads 2x lb/ub + is_int (~40 B/nnz), tile
    reads val+col (~12 B/nnz), candidate writes (~16 B/nnz), segment-op
    candidate+col reads (~24 B/nnz)   => ~92 B/nnz + O(m + n)
  fused:          tile reads val+col+is_int (~16 B/nnz) + O(m + n_pad)
    for the resident bound/accumulator vectors and row aggregates

``round_cost_analysis`` measures this at the HBM boundary of the actual
lowered round instead of asserting it.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bounds as bnd
from ..core.propagator import (
    batched_fixed_point,
    donate_kwargs,
    donate_supported,
    owned_copy,
    two_tier_bounds_dtypes,
)
from ..core.sparse import (
    BlockEll,
    Problem,
    ProblemBatch,
    chunk_stream,
    csr_to_block_ell,
    pack_problems,
)
from ..core.types import (
    DEFAULT_CONFIG,
    INF,
    PropagationResult,
    PropagatorConfig,
    TierPolicy,
    _is_low_precision,
)
from ..obs import telemetry as obs
from . import prop_round as kern
from . import ref as kref


# Compact index streams: a low-precision tier whose padded column space fits
# int16 narrows its per-nonzero index streams (col -> int16, the is_int
# gather -> int8), shrinking the round's dominant HBM traffic beyond the
# value-dtype halving alone (the fp32 fused round streams 7 B per padded
# nonzero instead of 12).  Kernels compare/gather with the narrow ids
# directly -- widening happens in registers, never at the HBM boundary.
_COMPACT_COL_MAX_NPAD = 1 << 15


class DeviceBlockEll(NamedTuple):
    """Device-resident block-ELL instance (pytree)."""

    val: jnp.ndarray        # (T, R, K)
    col: jnp.ndarray        # (T, R, K) int32 (int16 on compact low-precision tiers)
    chunk_row: jnp.ndarray  # (T, R) int32 in [0, m]; m == padding
    lhs1: jnp.ndarray       # (m+1,) sides padded with one dummy slot at index m
    rhs1: jnp.ndarray       # (m+1,)
    is_int: jnp.ndarray     # (n,) bool
    lb0: jnp.ndarray        # (n,)
    ub0: jnp.ndarray        # (n,)


def device_block_ell(p: Problem, tile_rows: int = 8, tile_width: int = 128, dtype=None) -> DeviceBlockEll:
    """Convert + upload one instance: block-ELL tiles of shape
    ``(tile_rows, tile_width)``, sides padded with a dummy slot for the
    padding row, bounds and integrality marks as ``(n,)`` device arrays.
    Prefer :func:`prepare_block_ell`, which caches this and hoists the
    round-constant gathers."""
    dtype = dtype or p.csr.val.dtype
    b = csr_to_block_ell(p.csr, tile_rows=tile_rows, tile_width=tile_width)
    pad1 = lambda x: np.concatenate([x, np.zeros(1, dtype=x.dtype)])
    return DeviceBlockEll(
        val=jnp.asarray(b.val, dtype=dtype),
        col=jnp.asarray(b.col),
        chunk_row=jnp.asarray(b.chunk_row),
        lhs1=jnp.asarray(pad1(p.lhs), dtype=dtype),
        rhs1=jnp.asarray(pad1(p.rhs), dtype=dtype),
        is_int=jnp.asarray(p.is_int),
        lb0=jnp.asarray(p.lb, dtype=dtype),
        ub0=jnp.asarray(p.ub, dtype=dtype),
    )


def rows_fit_one_chunk(p: Problem, tile_width: int) -> bool:
    """True iff every row's nonzeros fit one ``tile_width``-wide chunk --
    the condition for the single-kernel fused round (no cross-chunk
    activity combine needed)."""
    return int(np.diff(p.csr.row_ptr).max(initial=0)) <= tile_width


# ---------------------------------------------------------------------------
# Column-slab partitioning: the tile stream re-bucketed per VMEM-sized slab
# ---------------------------------------------------------------------------


class SlabPartition(NamedTuple):
    """A block-ELL stream re-bucketed by column slabs at CHUNK granularity,
    carrying everything the slab-parallel fused round consumes.

    The padded column space is split into ``n_slabs`` windows of ``slab``
    columns.  The source tiles are flattened to chunks (one matrix-row
    slice each, see ``core.sparse.chunk_stream``); each chunk becomes one
    COPY per slab its nonzeros touch, keeping only the in-slab nonzeros
    (``val == 0`` elsewhere, the block-ELL padding convention) with
    slab-LOCAL columns.  Chunk granularity is what keeps the duplication
    near 1: a whole-tile copy would inherit the unrelated rows sharing the
    tile, duplicating nearly every tile once per slab on column-scattered
    instances.

    The MAIN stream packs every copy into ``(T'', R, K)`` tiles grouped by
    ``(instance, slab)`` window, each group padded to whole tiles with
    dummy-row chunks.  ``run_*`` describe the groups: run ``r`` covers
    copies ``run_start[r] : run_start[r] + run_len[r]`` of window
    ``(run_inst[r], run_slab[r])`` -- the scalar-prefetch map that routes
    the 2D ``(run, tile)`` grid of the slab-parallel round kernel.  Every
    window has exactly one run (empty windows get one all-padding tile),
    so per-window outputs are always written.

    A row whose nonzeros are split across copies (several slabs and/or
    several chunks) is a STRADDLE row; its activity aggregate cannot
    complete inside any one copy.  The sub-stream ``a_*`` repacks exactly
    those rows' copies; the engine computes per-copy partials over it,
    segment-sums them into a table of ``n_straddle`` completed aggregates
    (slot 0 is a dummy), and the round kernel selects per main-stream row
    between its local aggregate (``row_done == 1``) and the table value
    gathered at ``agg_slot``.  Complete rows -- the vast majority --
    never leave the kernel.

    ``col_slots`` is the build-time rectangle-gather schedule of the jnp
    oracle's column reduction: row ``c`` lists the flat main-stream
    candidate slots of column ``c`` (sentinel ``T''*R*K`` elsewhere), so
    the best-bound reduction is one gather + row-wise max/min instead of a
    segment op over the copy stream.  ``None`` when the rectangle would be
    too large (see ``RECT_SLOTS_MAX_RATIO``).

    Built once per prepared instance/bucket and slab width by
    :func:`build_slab_partition` and cached (see
    ``PreparedBlockEll.slab_partition``)."""

    # Main stream: every chunk copy, (instance, slab)-grouped and padded.
    val: jnp.ndarray        # (T'', R, K) slab-masked copies; 0 == padding
    col_s: jnp.ndarray      # (T'', R, K) int32 slab-LOCAL columns
    chunk_row: jnp.ndarray  # (T'', R) int32 rows (global ids in batched use)
    tile_inst: jnp.ndarray  # (T'',) int32 instance of each copy tile
    tile_slab: jnp.ndarray  # (T'',) int32 slab of each copy tile
    ii_g: jnp.ndarray       # (T'', R, K) int32 is_int at each kept nonzero
    lhs_g: jnp.ndarray      # (T'', R) sides gathered per chunk row
    rhs_g: jnp.ndarray      # (T'', R)
    row_done: jnp.ndarray   # (T'', R) int32: 1 iff copy holds its whole row
    agg_slot: jnp.ndarray   # (T'', R) int32 straddle-table slot (0 = dummy)
    run_start: jnp.ndarray  # (B*n_slabs,) int32 first copy tile of each run
    run_len: jnp.ndarray    # (B*n_slabs,) int32 copy tiles per run (>= 1)
    run_inst: jnp.ndarray   # (B*n_slabs,) int32 window instance per run
    run_slab: jnp.ndarray   # (B*n_slabs,) int32 window slab per run
    # Straddle sub-stream: the copies of split rows, packed the same way
    # (phase-A partials only; empty when nothing straddles).
    a_val: jnp.ndarray        # (Ta, R, K)
    a_col_s: jnp.ndarray      # (Ta, R, K) int32 slab-local
    a_slot: jnp.ndarray       # (Ta, R) int32 straddle-table slot (0 = dummy)
    a_tile_inst: jnp.ndarray  # (Ta,) int32
    a_tile_slab: jnp.ndarray  # (Ta,) int32
    a_run_start: jnp.ndarray  # (n_aruns,) int32
    a_run_len: jnp.ndarray    # (n_aruns,) int32
    a_run_inst: jnp.ndarray   # (n_aruns,) int32
    a_run_slab: jnp.ndarray   # (n_aruns,) int32
    # Rectangle-gather schedule of the oracle reduction (or None).
    col_slots: jnp.ndarray | None  # (B*n_pad_part, C) int32
    # Static layout facts.
    slab: int               # S: columns per slab (multiple of LANE)
    n_slabs: int            # windows per instance
    n_pad_part: int         # n_slabs * slab >= n_pad
    batch: int              # B: instances sharing the stream (1 if single)
    n_straddle: int         # straddle rows (table has n_straddle + 1 slots)
    max_run_len: int        # max(run_len) -- the round grid's minor extent
    a_max_run_len: int      # max(a_run_len), 0 when no straddle copies
    source_tiles: int       # T of the unpartitioned stream
    source_chunks: int      # nonzero-carrying chunks of the source stream
    num_chunk_copies: int   # chunk copies before window padding

    @property
    def num_copies(self) -> int:
        """Main-stream copy tiles (T'')."""
        return int(self.val.shape[0])

    @property
    def has_straddle(self) -> bool:
        """True iff any row's nonzeros are split across copies."""
        return int(self.a_val.shape[0]) > 0

    @property
    def duplication(self) -> float:
        """Chunk-copy blowup vs the source chunks (1.0 == no straddling)."""
        return self.num_chunk_copies / max(1, self.source_chunks)


# Size guard for the oracle's rectangle-gather reduction schedule: the
# (B*n_pad_part, C) slot matrix may use at most this many int32 entries per
# candidate-stream element before the builder falls back to segment ops.
RECT_SLOTS_MAX_RATIO = 8


def _pack_copy_windows(
    sel, cp_inst, cp_slab, cp_val, cp_col, cp_ii, cp_row, cp_done, cp_slot,
    bsz, n_slabs, r, k, dummy_rows, cover,
):
    """Pack the selected chunk copies into per-``(instance, slab)`` window
    groups of whole ``(R, K)`` tiles, plus the run maps describing each
    group.  ``cover=True`` materializes one all-padding tile for windows
    with no copies (the main stream: every window's outputs must be
    written); ``cover=False`` keeps only populated windows (the straddle
    sub-stream).  Window-padding rows are dummy-row chunks: ``val == 0``
    everywhere, ``done = 1``, ``slot = 0``."""
    idx = np.flatnonzero(sel)
    inst_g = cp_inst[idx]
    slab_g = cp_slab[idx]
    order = np.lexsort((idx, slab_g, inst_g))  # stable: stream order in-window
    idx, inst_g, slab_g = idx[order], inst_g[order], slab_g[order]
    win = inst_g * n_slabs + slab_g

    if cover:
        win_ids = np.arange(bsz * n_slabs, dtype=np.int64)
        counts = np.bincount(win, minlength=bsz * n_slabs)
        rows_per_win = np.maximum(-(-counts // r), 1) * r
    else:
        win_ids, counts = np.unique(win, return_counts=True)
        rows_per_win = -(-counts // r) * r
    n_runs = int(win_ids.size)
    offs = np.zeros(n_runs + 1, dtype=np.int64)
    np.cumsum(rows_per_win, out=offs[1:])
    total_rows = int(offs[-1])
    n_tiles = total_rows // r

    if idx.size:
        uw, uc = np.unique(win, return_counts=True)
        starts = np.concatenate([[0], np.cumsum(uc)[:-1]])
        rank = np.arange(win.size) - np.repeat(starts, uc)
        pos = win if cover else np.searchsorted(win_ids, win)
        dst = offs[pos] + rank
    else:
        dst = np.zeros(0, dtype=np.int64)

    row_win = np.repeat(win_ids, rows_per_win)
    w_inst = (row_win // n_slabs).astype(np.int64)
    p_val = np.zeros((total_rows, k), cp_val.dtype)
    p_col = np.zeros((total_rows, k), np.int32)
    p_ii = np.zeros((total_rows, k), bool)
    p_row = dummy_rows[w_inst].astype(np.int32)
    p_done = np.ones(total_rows, dtype=np.int32)
    p_slot = np.zeros(total_rows, dtype=np.int64)
    p_val[dst] = cp_val[idx]
    p_col[dst] = cp_col[idx]
    p_ii[dst] = cp_ii[idx]
    p_row[dst] = cp_row[idx]
    p_done[dst] = cp_done[idx]
    p_slot[dst] = cp_slot[idx]

    run_len = (rows_per_win // r).astype(np.int32)
    run_start = (offs[:-1] // r).astype(np.int32)
    run_inst = (win_ids // n_slabs).astype(np.int32)
    run_slab = (win_ids % n_slabs).astype(np.int32)
    tiles = {
        "val": p_val.reshape(n_tiles, r, k),
        "col": p_col.reshape(n_tiles, r, k),
        "ii": p_ii.reshape(n_tiles, r, k),
        "row": p_row.reshape(n_tiles, r),
        "done": p_done.reshape(n_tiles, r),
        "slot": p_slot.reshape(n_tiles, r).astype(np.int32),
        "tile_inst": np.repeat(run_inst, run_len),
        "tile_slab": np.repeat(run_slab, run_len),
    }
    return tiles, run_start, run_len, run_inst, run_slab


def _rect_gather_schedule(m_val, m_col, tile_inst, tile_slab, slab, bsz, n_pad_part):
    """Build-time per-column slot matrix for the oracle's best-bound
    reduction: row ``c`` holds the flat candidate-stream indices of column
    ``c``'s nonzeros, padded with the sentinel index ``stream_len`` (the
    oracle appends one sentinel candidate there).  Returns ``None`` when
    the rectangle would exceed ``RECT_SLOTS_MAX_RATIO`` int32 entries per
    stream element -- pathological column skew -- and the oracle falls
    back to segment ops."""
    n_tiles, r, k = m_val.shape
    stream_len = n_tiles * r * k
    gbase = tile_inst.astype(np.int64) * n_pad_part + tile_slab.astype(np.int64) * slab
    gcol = gbase[:, None, None] + m_col
    flat_nz = (m_val != 0).reshape(-1)
    cols_nz = gcol.reshape(-1)[flat_nz]
    slots_nz = np.flatnonzero(flat_nz)
    counts = np.bincount(cols_nz, minlength=bsz * n_pad_part)
    width = max(1, int(counts.max(initial=0)))
    if bsz * n_pad_part * width > RECT_SLOTS_MAX_RATIO * max(1, stream_len):
        return None
    rect = np.full((bsz * n_pad_part, width), stream_len, dtype=np.int64)
    if cols_nz.size:
        order = np.argsort(cols_nz, kind="stable")
        cs, ss = cols_nz[order], slots_nz[order]
        uc, cnt = np.unique(cs, return_counts=True)
        starts = np.concatenate([[0], np.cumsum(cnt)[:-1]])
        rank = np.arange(cs.size) - np.repeat(starts, cnt)
        rect[cs, rank] = ss
    return rect.astype(np.int32)


def build_slab_partition(
    val: np.ndarray,
    col: np.ndarray,
    chunk_row: np.ndarray,
    tile_inst: np.ndarray,
    lhs1: np.ndarray,
    rhs1: np.ndarray,
    is_int_rows: np.ndarray,
    n_pad: int,
    slab: int,
    dummy_rows: np.ndarray,
) -> SlabPartition:
    """Host-side slab bucketing of a (possibly batched) block-ELL stream
    at chunk granularity (see :class:`SlabPartition` for the layout).

    ``val``/``col`` are ``(T, R, K)`` tiles with instance-local columns;
    ``chunk_row`` carries the row ids (global across instances in batched
    use); ``lhs1``/``rhs1`` are the side vectors those ids index;
    ``is_int_rows`` is the ``(B, n_pad)`` integrality plane and
    ``dummy_rows`` each instance's padding row.

    Each nonzero-carrying chunk becomes one copy per slab its columns
    touch, so every matrix nonzero lands in exactly one copy.  Rows whose
    nonzeros split across copies are diverted to the straddle sub-stream
    for the out-of-kernel aggregate combine; everything else completes
    in-kernel.  ``SlabPartition.duplication`` reports the chunk-copy
    blowup (near 1 unless single rows genuinely span many slabs)."""
    val = np.asarray(val)
    # Compact (int16) tier streams widen here: slab arithmetic below mixes
    # columns with slab offsets that overflow narrow index types.
    col = np.asarray(col, dtype=np.int32)
    chunk_row = np.asarray(chunk_row)
    tile_inst = np.asarray(tile_inst, dtype=np.int64)
    is_int_rows = np.asarray(is_int_rows)
    dummy_rows = np.asarray(dummy_rows, dtype=np.int64)
    t, r, k = val.shape
    dt = val.dtype
    if slab % kern.LANE:
        raise ValueError(f"slab={slab} must be a multiple of LANE={kern.LANE}")
    n_slabs = -(-n_pad // slab)
    n_pad_part = n_slabs * slab
    bsz = int(dummy_rows.shape[0])

    cval, ccol, crow, cinst, src = chunk_stream(val, col, chunk_row, tile_inst)
    nc = t * r
    nz = cval != 0

    # Copy list: one (chunk, slab) pair per touched slab, chunk-major.
    slab_of = np.where(nz, ccol // slab, 0)
    touched = np.zeros((nc, n_slabs), dtype=bool)
    c_idx = np.broadcast_to(np.arange(nc)[:, None], (nc, k))
    touched[c_idx[nz], slab_of[nz]] = True
    ch_ids, s_ids = np.nonzero(touched)
    cp_inst = cinst[ch_ids]

    keep = nz[ch_ids] & (slab_of[ch_ids] == s_ids[:, None])
    cp_nnz = keep.sum(axis=1)

    # Straddle detection: a copy is complete iff it holds ALL of its row's
    # nonzeros; rows with any incomplete copy get a table slot (>= 1).
    n_rows_all = int(np.asarray(lhs1).shape[0])
    row_nnz = np.zeros(n_rows_all, dtype=np.int64)
    np.add.at(row_nnz, crow, nz.sum(axis=1))
    cp_row = crow[ch_ids].astype(np.int64)
    complete = cp_nnz == row_nnz[cp_row]
    srows = np.unique(cp_row[~complete])
    n_straddle = int(srows.size)
    slot_of_row = np.zeros(n_rows_all, dtype=np.int64)
    slot_of_row[srows] = 1 + np.arange(n_straddle)

    cp_val = np.where(keep, cval[ch_ids], 0).astype(dt)
    cp_col = np.where(keep, ccol[ch_ids] - s_ids[:, None] * slab, 0).astype(np.int32)
    cp_ii = np.where(keep, is_int_rows[cp_inst[:, None], ccol[ch_ids]], False)
    cp_slot = slot_of_row[cp_row]

    main, run_start, run_len, run_inst, run_slab = _pack_copy_windows(
        np.ones(ch_ids.size, dtype=bool), cp_inst, s_ids,
        cp_val, cp_col, cp_ii, cp_row, complete, cp_slot,
        bsz, n_slabs, r, k, dummy_rows, cover=True,
    )
    sub, a_run_start, a_run_len, a_run_inst, a_run_slab = _pack_copy_windows(
        ~complete, cp_inst, s_ids,
        cp_val, cp_col, cp_ii, cp_row, complete, cp_slot,
        bsz, n_slabs, r, k, dummy_rows, cover=False,
    )

    col_slots = _rect_gather_schedule(
        main["val"], main["col"], main["tile_inst"], main["tile_slab"],
        slab, bsz, n_pad_part,
    )

    lhs1 = np.asarray(lhs1, dtype=dt)
    rhs1 = np.asarray(rhs1, dtype=dt)
    # The partition may be built lazily inside a jit trace (the first round
    # closure that needs it); materialize concrete device constants there
    # instead of leaking trace-scoped tracers into the prep cache.
    with jax.ensure_compile_time_eval():
        return SlabPartition(
            val=jnp.asarray(main["val"]),
            col_s=jnp.asarray(main["col"]),
            chunk_row=jnp.asarray(main["row"]),
            tile_inst=jnp.asarray(main["tile_inst"].astype(np.int32)),
            tile_slab=jnp.asarray(main["tile_slab"].astype(np.int32)),
            ii_g=jnp.asarray(main["ii"].astype(np.int32)),
            lhs_g=jnp.asarray(lhs1[main["row"]]),
            rhs_g=jnp.asarray(rhs1[main["row"]]),
            row_done=jnp.asarray(main["done"]),
            agg_slot=jnp.asarray(main["slot"]),
            run_start=jnp.asarray(run_start),
            run_len=jnp.asarray(run_len),
            run_inst=jnp.asarray(run_inst),
            run_slab=jnp.asarray(run_slab),
            a_val=jnp.asarray(sub["val"]),
            a_col_s=jnp.asarray(sub["col"]),
            a_slot=jnp.asarray(sub["slot"]),
            a_tile_inst=jnp.asarray(sub["tile_inst"].astype(np.int32)),
            a_tile_slab=jnp.asarray(sub["tile_slab"].astype(np.int32)),
            a_run_start=jnp.asarray(a_run_start),
            a_run_len=jnp.asarray(a_run_len),
            a_run_inst=jnp.asarray(a_run_inst),
            a_run_slab=jnp.asarray(a_run_slab),
            col_slots=None if col_slots is None else jnp.asarray(col_slots),
            slab=int(slab),
            n_slabs=int(n_slabs),
            n_pad_part=int(n_pad_part),
            batch=bsz,
            n_straddle=n_straddle,
            max_run_len=int(run_len.max(initial=1)),
            a_max_run_len=int(a_run_len.max(initial=0)),
            source_tiles=t,
            source_chunks=int(src.sum()),
            num_chunk_copies=int(ch_ids.size),
        )


# ---------------------------------------------------------------------------
# Prepared instances: one-time setup, hoisted round constants, LRU-cached
# ---------------------------------------------------------------------------

# Largest column-padded width the fused scatter keeps resident in VMEM
# (2 accumulators x n_pad x 8 B = 1 MiB at the cap; ~6% of a v5e core's VMEM).
SCATTER_MAX_NPAD = 1 << 16

# Cap on the partitioned engine's column-slab width: one slab's resident
# state is at most what the fused engine keeps at its cap, so any instance
# the fused engine could hold is one slab of the partitioned one.  The
# default width is BALANCED below the cap (``default_slab_width``) so the
# slab grid overhangs the padded domain by less than one lane row per slab
# instead of up to a whole slab.
SLAB_NPAD = SCATTER_MAX_NPAD


def default_slab_width(n_pad: int, cap: int | None = None) -> int:
    """Balanced column-slab width for a padded domain: the fewest slabs
    whose width stays within the VMEM cap (:data:`SLAB_NPAD`), each width a
    LANE multiple, so ``n_pad_part - n_pad < LANE * n_slabs`` -- the
    per-round pad/slice of the partitioned dataflow stays negligible."""
    cap = SLAB_NPAD if cap is None else int(cap)
    n_slabs = max(1, -(-n_pad // cap))
    return -(-n_pad // (n_slabs * kern.LANE)) * kern.LANE


class LRU:
    """Bounded LRU keyed by tuples that embed ``id()`` of host objects.

    Every entry pins its ``anchors`` (the objects whose ids appear in the
    key) so an id cannot be recycled while the entry is live, and a hit is
    honoured only if every anchor is still the identical object.  Counts
    hits/misses for ``cache_info()``; ``on_evict`` lets dependent caches
    (compiled runners pinning a prep's device tiles) be purged with it.

    THREAD-SAFE: every operation (including the hit/miss counters and the
    eviction walk) holds one re-entrant lock, so the serving loop's
    background admission worker and the device-loop thread can hit the
    runner/pack caches concurrently (``core.service``).  ``on_evict`` hooks
    run under the lock -- they only touch other LRUs, whose own re-entrant
    locks keep the nesting safe.
    """

    def __init__(self, maxsize: int, on_evict=None):
        self.maxsize = maxsize
        self._d: "OrderedDict[tuple, tuple[tuple, object]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self._on_evict = on_evict
        self._lock = threading.RLock()

    def get(self, key, anchors: tuple):
        with self._lock:
            hit = self._d.get(key)
            if hit is not None and all(a is b for a, b in zip(hit[0], anchors)):
                self._d.move_to_end(key)
                self.hits += 1
                return hit[1]
            self.misses += 1
            return None

    def put(self, key, anchors: tuple, value) -> None:
        with self._lock:
            self._d[key] = (anchors, value)
            while len(self._d) > self.maxsize:
                _, (anchors_e, value_e) = self._d.popitem(last=False)
                if self._on_evict is not None:
                    self._on_evict(anchors_e, value_e)

    def drop_where(self, pred) -> None:
        """Remove every entry whose ``(anchors, value)`` satisfies ``pred``."""
        with self._lock:
            for key in [k for k, v in self._d.items() if pred(*v)]:
                del self._d[key]

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def info(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "size": len(self._d),
                "maxsize": self.maxsize,
            }


@dataclasses.dataclass(frozen=True)
class PreparedBlockEll:
    """Device tiles + everything about a round that does not change across
    rounds: the constant gathers the seed engine recomputed per round, the
    column-padded initial bounds, and static layout facts.

    Not a pytree on purpose -- drivers close over it, so its arrays become
    jit constants and its ints/bools stay static.  The round closures read
    only MATRIX STRUCTURE from it (``d``, the hoisted gathers, the layout
    ints); ``lb0``/``ub0`` are per-problem defaults that every driver
    accepts as runtime overrides, so one prepared engine serves any bounds
    (the warm-start / tree-search contract).
    """

    d: DeviceBlockEll
    ii_g: jnp.ndarray    # (T, R, K) int32: is_int[col], hoisted
    lhs_g: jnp.ndarray   # (T, R): lhs1[chunk_row], hoisted
    rhs_g: jnp.ndarray   # (T, R): rhs1[chunk_row], hoisted
    lb0: jnp.ndarray     # (n_pad,) default initial bounds (column-padded)
    ub0: jnp.ndarray     # (n_pad,)
    m: int
    n: int
    n_pad: int
    fits_one_chunk: bool
    # Slab partitions derived from the (immutable) tiles, built lazily and
    # keyed by slab width; shared by bounds-swapped views of this prep.
    _slabs: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False
    )

    def slab_partition(self, slab: int | None = None) -> SlabPartition:
        """This instance's tile stream re-bucketed into ``slab``-wide column
        windows (default: :func:`default_slab_width`, balanced below the
        :data:`SLAB_NPAD` cap), for the ``partitioned`` engine.

        Built once per slab width from the resident tiles (a host-side
        pass over the block-ELL arrays) and cached on the prep, so rounds
        and recompilations never pay it again."""
        s = default_slab_width(self.n_pad) if slab is None else int(slab)
        part = self._slabs.get(s)
        if part is None:
            d = self.d
            is_int_rows = np.zeros((1, self.n_pad), dtype=bool)
            is_int_rows[0, : self.n] = np.asarray(d.is_int)
            part = build_slab_partition(
                np.asarray(d.val),
                np.asarray(d.col),
                np.asarray(d.chunk_row),
                np.zeros(d.val.shape[0], dtype=np.int32),
                np.asarray(d.lhs1),
                np.asarray(d.rhs1),
                is_int_rows,
                self.n_pad,
                s,
                np.array([self.m], dtype=np.int32),
            )
            self._slabs[s] = part
        return part

    def pad_bound(self, arr):
        """One caller bound vector -> the column-padded ``(n_pad,)`` domain
        (padded columns sit at 0, the same trivially-converged fill prepare
        uses)."""
        dt = self.d.val.dtype
        a = jnp.asarray(arr, dt)
        if a.shape != (self.n,):
            raise ValueError(f"bounds have shape {a.shape}, expected {(self.n,)}")
        if self.n_pad > self.n:
            a = jnp.concatenate([a, jnp.zeros((self.n_pad - self.n,), dt)])
        return a

    def pad_bounds(self, lb, ub):
        return self.pad_bound(lb), self.pad_bound(ub)


# Structure anchors: a prepared engine depends on the matrix, the sides and
# the integrality marks -- NOT on the bounds.  Keying prepare on these means
# a B&B node built as ``root._replace(lb=..., ub=...)`` (same csr/lhs/rhs/
# is_int objects) hits the cache and reuses the resident tiles.
def _structure_anchors(p: Problem) -> tuple:
    return (p.csr, p.lhs, p.rhs, p.is_int)


def _drop_runners_for(anchors, value) -> None:
    """Prep-cache eviction hook: compiled runners close over the evicted
    prep's device tiles, so dropping them alongside keeps device memory
    bounded by the prepare LRU, not by the (larger) runner LRUs."""
    _, prep = value
    tiles = prep.d.val
    dead = lambda runner_anchors, _runner: runner_anchors[0] is tiles
    _runner_cache.drop_where(dead)
    _node_runner_cache.drop_where(dead)


_prep_cache = LRU(maxsize=32, on_evict=_drop_runners_for)


def prepare_block_ell(
    p: Problem, tile_rows: int = 8, tile_width: int = 128, dtype=None
) -> PreparedBlockEll:
    """One-time setup for kernel-backed propagation, LRU-cached per matrix
    STRUCTURE (``csr``/``lhs``/``rhs``/``is_int`` identity -- maxsize 32,
    see ``cache_info()``).

    Repeated propagations of the same ``Problem`` -- or of a bounds-only
    variant like a tree-search node (``p._replace(lb=..., ub=...)``) --
    reuse the block-ELL tiles, device buffers and hoisted gathers instead
    of rebuilding and re-transferring them.  The cache pins the keyed
    structure arrays so ``id()`` keys cannot be recycled while an entry is
    live; a hit from a problem whose bounds differ from the cached defaults
    returns a cheap bounds-swapped view sharing every device tile.
    """
    dt = np.dtype(dtype) if dtype is not None else np.dtype(p.csr.val.dtype)
    anchors = _structure_anchors(p)
    key = tuple(id(a) for a in anchors) + (tile_rows, tile_width, dt.str)
    hit = _prep_cache.get(key, anchors)
    if hit is not None:
        creator, prep = hit
        if creator.lb is p.lb and creator.ub is p.ub:
            return prep
        # Bounds-swapped view: every heavy array (tiles, hoisted gathers) is
        # shared with the cached prep, and BOTH bound carriers -- the padded
        # prep.lb0/ub0 and the unpadded d.lb0/ub0 -- reflect p's bounds, so
        # legacy readers of d.lb0 cannot silently see the creator's domain.
        # Runner caches key on id(d.val) (stable across _replace), so the
        # view reuses the creator's compiled fixed points.
        lb0, ub0 = prep.pad_bounds(p.lb, p.ub)
        d = prep.d._replace(
            lb0=jnp.asarray(p.lb, dt), ub0=jnp.asarray(p.ub, dt)
        )
        return dataclasses.replace(prep, d=d, lb0=lb0, ub0=ub0)

    d = device_block_ell(p, tile_rows, tile_width, dt)
    n_pad = kern.col_pad(p.n)
    compact = _is_low_precision(dt) and n_pad <= _COMPACT_COL_MAX_NPAD
    ii_g = d.is_int[d.col].astype(jnp.int8 if compact else jnp.int32)
    if compact:
        d = d._replace(col=d.col.astype(jnp.int16))
    padn = lambda x: jnp.concatenate([x, jnp.zeros((n_pad - p.n,), x.dtype)])
    prep = PreparedBlockEll(
        d=d,
        ii_g=ii_g,
        lhs_g=d.lhs1[d.chunk_row],
        rhs_g=d.rhs1[d.chunk_row],
        lb0=padn(d.lb0) if n_pad > p.n else d.lb0,
        ub0=padn(d.ub0) if n_pad > p.n else d.ub0,
        m=p.m,
        n=p.n,
        n_pad=n_pad,
        fits_one_chunk=rows_fit_one_chunk(p, tile_width),
    )
    _prep_cache.put(key, anchors, (p, prep))
    return prep


def clear_prepare_cache() -> None:
    """Drop all cached prepared instances and their compiled single-instance
    / node-batch runners (frees device buffers)."""
    _prep_cache.clear()
    _runner_cache.clear()
    _node_runner_cache.clear()


# ---------------------------------------------------------------------------
# One block-ELL round
# ---------------------------------------------------------------------------


def block_ell_round(
    d: DeviceBlockEll,
    lb,
    ub,
    m: int,
    n: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
    use_pallas: bool = True,
    fused: bool = False,
    interpret: bool | None = None,
    outward: float = 0.0,
):
    """One propagation round over block-ELL tiles (seed dataflow, kept as the
    legacy baseline: per-round constant gathers, candidates materialized in
    HBM, XLA segment reduction).  Returns (lb, ub, changed)."""
    lb_g = lb[d.col]
    ub_g = ub[d.col]
    ii_g = d.is_int[d.col]
    lhs_g = d.lhs1[d.chunk_row]
    rhs_g = d.rhs1[d.chunk_row]

    if fused:
        # Alg.-3-faithful: activities live in VMEM, reused for candidates.
        if use_pallas:
            lcand, ucand = kern.fused_round_tiles(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf, interpret
            )
        else:
            lcand, ucand = kref.fused_round_tiles_ref(
                d.val, lb_g, ub_g, ii_g, lhs_g, rhs_g, int_eps, inf
            )
    else:
        if use_pallas:
            mf, mc, xf, xc = kern.activities_tiles(d.val, lb_g, ub_g, inf, interpret)
        else:
            mf, mc, xf, xc = kref.activities_tiles_ref(d.val, lb_g, ub_g, inf)
        # Combine chunk partials into completed row aggregates (long rows).
        crow = d.chunk_row.reshape(-1)
        seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=m + 1)
        row_mf, row_mc = seg(mf), seg(mc)
        row_xf, row_xc = seg(xf), seg(xc)
        # Gather completed aggregates back per chunk.
        g = lambda x: x[d.chunk_row]
        if use_pallas:
            lcand, ucand = kern.candidates_tiles(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.candidates_tiles_ref(
                d.val, lb_g, ub_g, ii_g,
                g(row_mf), g(row_mc), g(row_xf), g(row_xc),
                lhs_g, rhs_g, int_eps, inf,
            )

    flat_col = d.col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=n)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=n)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf, outward)


def _combine_chunk_partials(prep: PreparedBlockEll, mf, mc, xf, xc):
    """Chunk partials -> completed per-chunk row aggregates (long rows)."""
    d = prep.d
    crow = d.chunk_row.reshape(-1)
    seg = lambda x: jax.ops.segment_sum(x.reshape(-1), crow, num_segments=prep.m + 1)
    g = lambda x: seg(x)[d.chunk_row]
    return g(mf), g(mc), g(xf), g(xc)


def _straddle_aggregates(part: SlabPartition, lb, ub, active, *, node, inf, interpret):
    """Completed activity aggregates of the straddle rows, as a
    ``(n_straddle + 1,)`` table per aggregate kind (slot 0 is the dummy the
    main stream's complete rows point at) -- ``(B, n_straddle + 1)`` under
    ``node=True``.

    Phase A of a partitioned round: the straddle sub-stream's copies
    produce per-copy partials in a slab-parallel kernel, and a tiny XLA
    segment sum over ``a_slot`` completes them.  Everything row-sized here
    is ``O(straddle copies)``, not ``O(nnz)``; with no straddle rows the
    engine skips this entirely."""
    nseg = part.n_straddle + 1
    if node:
        mf, mc, xf, xc = kern.node_slab_partials_tiles(
            part.a_val, part.a_col_s, part.a_run_start, part.a_run_len,
            part.a_run_slab, active, lb, ub, part.slab, part.a_max_run_len,
            inf, interpret,
        )
        slot = part.a_slot.reshape(-1)
        seg1 = lambda x: jax.ops.segment_sum(x, slot, num_segments=nseg)
        g = lambda x: jax.vmap(seg1)(x.reshape(x.shape[0], -1))
    else:
        mf, mc, xf, xc = kern.batched_slab_partials_tiles(
            part.a_val, part.a_col_s, part.a_run_start, part.a_run_len,
            part.a_run_inst, part.a_run_slab, active, lb, ub, part.slab,
            part.a_max_run_len, inf, interpret,
        )
        slot = part.a_slot.reshape(-1)
        g = lambda x: jax.ops.segment_sum(x.reshape(-1), slot, num_segments=nseg)
    return g(mf), g(mc), g(xf), g(xc)


def _partitioned_pallas_round(
    part: SlabPartition, lb, ub, active,
    *, node: bool, eps: float, int_eps: float, inf: float,
    interpret: bool | None, outward: float = 0.0,
):
    """The one slab-round dataflow every partitioned engine shares, over
    ``(B, n_pad)`` bound planes: pad to the slab grid -> straddle-row
    aggregate tables (phase A, skipped when nothing straddles) -> ONE fused
    slab-parallel kernel per plane set (activities, candidates, per-slab
    scatter into VMEM accumulators, AND the bound merge, on the 2D
    ``(run, tile)`` grid) -> slice back.

    ``node=True`` runs every node's plane against the shared copies on a
    ``(B, run, tile)`` grid (per-node straddle tables, per-node windows);
    otherwise copies route to their own instance's plane rows via the run
    maps (single-instance callers pass ``B == 1``).  Returns the updated
    ``(B, n_pad)`` planes and the ``(B,)`` changed flags."""
    bsz, n_pad = lb.shape
    extra = part.n_pad_part - n_pad
    if extra:
        z = jnp.zeros((bsz, extra), lb.dtype)
        lbp = jnp.concatenate([lb, z], axis=1)
        ubp = jnp.concatenate([ub, z], axis=1)
    else:
        lbp, ubp = lb, ub
    if part.has_straddle:
        smf, smc, sxf, sxc = _straddle_aggregates(
            part, lbp, ubp, active, node=node, inf=inf, interpret=interpret
        )
        tab = lambda t: t[..., part.agg_slot]
        smf, smc, sxf, sxc = tab(smf), tab(smc), tab(sxf), tab(sxc)
    else:
        shape = ((bsz,) if node else ()) + tuple(part.chunk_row.shape)
        smf = jnp.zeros(shape, lbp.dtype)
        smc = jnp.zeros(shape, jnp.int32)
        sxf, sxc = smf, smc
    if node:
        new_lb, new_ub, ch = kern.node_slab_round_tiles(
            part.val, part.col_s, part.ii_g, part.row_done, smf, smc, sxf, sxc,
            part.lhs_g, part.rhs_g, part.run_start, part.run_len,
            part.run_slab, active, lbp, ubp, part.slab, part.max_run_len,
            eps, int_eps, inf, interpret, outward=outward,
        )
        changed = jnp.any(ch != 0, axis=1)
    else:
        new_lb, new_ub, ch = kern.batched_slab_round_tiles(
            part.val, part.col_s, part.ii_g, part.row_done, smf, smc, sxf, sxc,
            part.lhs_g, part.rhs_g, part.run_start, part.run_len,
            part.run_inst, part.run_slab, active, lbp, ubp, part.slab,
            part.max_run_len, eps, int_eps, inf, interpret, outward=outward,
        )
        changed = jax.ops.segment_max(ch, part.run_inst, num_segments=bsz) != 0
    if extra:
        new_lb, new_ub = new_lb[:, :n_pad], new_ub[:, :n_pad]
    return new_lb, new_ub, changed


def _prepared_round(
    prep: PreparedBlockEll,
    lb,
    ub,
    *,
    eps: float,
    int_eps: float,
    inf: float,
    use_pallas: bool,
    fused: bool,
    scatter: str,
    interpret: bool | None,
    slab: int | None = None,
    outward: float = 0.0,
):
    """One round over hoisted constants.  (lb, ub) live in the column-padded
    ``(n_pad,)`` domain end to end; only the bound gathers run in XLA."""
    d = prep.d

    if scatter == "partitioned":
        # Column-slab partitioned round (VMEM-exceeding n_pad): chunk-copy
        # slab partition, straddle aggregates out of band, then ONE fused
        # slab-parallel kernel (candidates + scatter + merge) on the 2D
        # (run, tile) grid.  Only (1, S) windows are ever VMEM-resident;
        # no nnz-shaped tensor touches HBM.
        part = prep.slab_partition(slab)
        if use_pallas:
            new_lb, new_ub, ch = _partitioned_pallas_round(
                part, lb[None, :], ub[None, :], jnp.ones((1,), jnp.int32),
                node=False, eps=eps, int_eps=int_eps, inf=inf,
                interpret=interpret, outward=outward,
            )
            return new_lb[0], new_ub[0], ch[0]
        best_l, best_u = kref.partitioned_round_ref(
            part, lb[None, :], ub[None, :], int_eps, inf
        )
        return bnd.apply_updates(
            lb, ub, best_l[0, : prep.n_pad], best_u[0, : prep.n_pad], eps, inf,
            outward,
        )

    if scatter == "fused":
        if fused:
            # Fully fused: even the bound gather happens in the kernel, so
            # no nnz-shaped tensor is produced in HBM at all this round.
            if use_pallas:
                best_l, best_u = kern.fused_scatter_round_tiles(
                    d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g,
                    lb, ub, prep.n_pad, int_eps, inf, interpret,
                )
            else:
                best_l, best_u = kref.fused_scatter_round_tiles_ref(
                    d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g,
                    lb, ub, prep.n_pad, int_eps, inf,
                )
        else:
            # Long rows: chunk partials (in-kernel gather) -> XLA segment
            # combine of the tiny (T, R) aggregates -> fused scatter round.
            if use_pallas:
                mf, mc, xf, xc = kern.activities_gather_tiles(
                    d.val, d.col, lb, ub, prep.n_pad, inf, interpret
                )
            else:
                mf, mc, xf, xc = kref.activities_gather_tiles_ref(
                    d.val, d.col, lb, ub, prep.n_pad, inf
                )
            rmf, rmc, rxf, rxc = _combine_chunk_partials(prep, mf, mc, xf, xc)
            if use_pallas:
                best_l, best_u = kern.candidates_scatter_tiles(
                    d.val, d.col, prep.ii_g, rmf, rmc, rxf, rxc,
                    prep.lhs_g, prep.rhs_g, lb, ub, prep.n_pad, int_eps, inf,
                    interpret,
                )
            else:
                best_l, best_u = kref.candidates_scatter_tiles_ref(
                    d.val, d.col, prep.ii_g, rmf, rmc, rxf, rxc,
                    prep.lhs_g, prep.rhs_g, lb, ub, prep.n_pad, int_eps, inf,
                )
        if use_pallas:
            return kern.apply_updates_tiles(
                lb, ub, best_l, best_u, eps, inf, interpret, outward
            )
        return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf, outward)

    # scatter == "segment": the materializing oracle path (hoisted gathers).
    lb_g = lb[d.col]
    ub_g = ub[d.col]
    if fused:
        if use_pallas:
            lcand, ucand = kern.fused_round_tiles(
                d.val, lb_g, ub_g, prep.ii_g, prep.lhs_g, prep.rhs_g,
                int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.fused_round_tiles_ref(
                d.val, lb_g, ub_g, prep.ii_g, prep.lhs_g, prep.rhs_g, int_eps, inf
            )
    else:
        if use_pallas:
            mf, mc, xf, xc = kern.activities_tiles(d.val, lb_g, ub_g, inf, interpret)
        else:
            mf, mc, xf, xc = kref.activities_tiles_ref(d.val, lb_g, ub_g, inf)
        rmf, rmc, rxf, rxc = _combine_chunk_partials(prep, mf, mc, xf, xc)
        if use_pallas:
            lcand, ucand = kern.candidates_tiles(
                d.val, lb_g, ub_g, prep.ii_g, rmf, rmc, rxf, rxc,
                prep.lhs_g, prep.rhs_g, int_eps, inf, interpret,
            )
        else:
            lcand, ucand = kref.candidates_tiles_ref(
                d.val, lb_g, ub_g, prep.ii_g, rmf, rmc, rxf, rxc,
                prep.lhs_g, prep.rhs_g, int_eps, inf,
            )
    flat_col = d.col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=prep.n_pad)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=prep.n_pad)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf, outward)


def legacy_round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """The seed round (``block_ell_round``) as a jit-able ``(lb, ub) ->
    (lb, ub, changed)`` closure over a prepared instance -- bounds in the
    unpadded ``(n,)`` domain.  Kept as the measured baseline."""
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        block_ell_round,
        prep.d,
        m=prep.m,
        n=prep.n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=prep.fits_one_chunk,
        interpret=interpret,
        outward=cfg.outward_for(prep.d.val.dtype),
    )


def round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    scatter: str = "fused",
    fused: bool | None = None,
    interpret: bool | None = None,
    slab: int | None = None,
):
    """A jit-able ``(lb, ub) -> (lb, ub, changed)`` round closure over a
    prepared instance (bounds in the ``(n_pad,)`` domain).  ``slab``
    overrides the partitioned engine's column-slab width (default
    :data:`SLAB_NPAD`; ignored by the other scatter modes)."""
    scatter = _resolve_scatter(scatter, prep)
    do_fuse = prep.fits_one_chunk if fused is None else bool(fused)
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        _prepared_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        fused=do_fuse,
        scatter=scatter,
        interpret=interpret,
        slab=slab,
        outward=cfg.outward_for(prep.d.val.dtype),
    )


# ---------------------------------------------------------------------------
# Full propagation drivers over block-ELL
# ---------------------------------------------------------------------------


# Escape hatch for the large-instance leg of ``scatter="auto"``: set
# REPRO_AUTO_LARGE_SCATTER=segment to route VMEM-exceeding instances to the
# materializing segment engine instead of the partitioned one (e.g. while
# re-validating a slab-width regression on new hardware).  The default is
# the slab-parallel partitioned engine, which wins on both bytes and wall
# clock on the benchmarked large-instance families (see BENCH_prop.json).
AUTO_LARGE_SCATTER_ENV = "REPRO_AUTO_LARGE_SCATTER"


def _auto_large_scatter() -> str:
    mode = os.environ.get(AUTO_LARGE_SCATTER_ENV, "partitioned")
    if mode not in ("partitioned", "segment"):
        raise ValueError(
            f"{AUTO_LARGE_SCATTER_ENV}={mode!r}: expected 'partitioned' or 'segment'"
        )
    return mode


def _resolve_scatter(scatter: str, prep: PreparedBlockEll) -> str:
    """The engine decision (see docs/ARCHITECTURE.md): ``auto`` keeps the
    fully fused round while the ``(2, n_pad)`` accumulators fit the VMEM
    budget and moves to the column-slab partitioned round beyond it
    (overridable via :data:`AUTO_LARGE_SCATTER_ENV`), so the fused
    ~16 B/nnz dataflow holds at every instance size; ``segment`` (the
    materializing oracle) is otherwise only ever explicit."""
    if scatter == "auto":
        return "fused" if prep.n_pad <= SCATTER_MAX_NPAD else _auto_large_scatter()
    if scatter not in ("fused", "segment", "partitioned"):
        raise ValueError(f"unknown scatter mode: {scatter!r}")
    return scatter


# Jitted single-instance fixed points, cached per matrix structure + config:
# the tree-search pattern re-propagates the same prepared engine with fresh
# bounds thousands of times, and rebuilding the jit closure per call would
# recompile every time.  Keyed on id(prep.d.val) -- the tile array shared by
# every bounds-swapped prepare() view of one structure -- so ONE compiled
# engine serves any bounds (the round closures read only structure from the
# prep they were built over, never its bound defaults).
_runner_cache = LRU(maxsize=64)


def _initial_padded_bounds(prep: PreparedBlockEll, lb0, ub0):
    """Per-call bound overrides -> private, donated-safe (n_pad,) buffers."""
    lb = owned_copy(prep.lb0 if lb0 is None else prep.pad_bound(lb0))
    ub = owned_copy(prep.ub0 if ub0 is None else prep.pad_bound(ub0))
    return lb, ub


def propagate_block_ell(
    p: Problem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    fused: str = "auto",
    driver: str = "device_loop",
    interpret: bool | None = None,
    scatter: str = "auto",
    donate: bool | None = None,
    lb0=None,
    ub0=None,
    slab: int | None = None,
    stop_progress: float | None = None,
    patience: int = 1,
    policy: TierPolicy | None = None,
    telemetry: int | None = None,
) -> PropagationResult:
    """Kernel-backed propagation.

    ``fused='auto'`` picks the Alg.-3 fusion whenever every row fits in one
    chunk (the paper's common case).  ``scatter='auto'`` picks the fully
    fused in-VMEM column reduction while the padded column count fits the
    accumulator budget and the column-slab ``partitioned`` engine beyond it
    (``slab`` overrides its window width); ``scatter='segment'`` forces the
    materializing oracle.  ``donate=None`` donates the bound buffers
    wherever the backend implements donation (zero-copy fixed point).

    ``lb0``/``ub0`` warm-start the fixed point from caller-supplied bounds:
    the prepared tiles, hoisted gathers AND the compiled fixed point are all
    cached per matrix structure, so propagating a B&B node costs one
    dispatch with two (n,) uploads -- no repacking, no recompilation.

    ``stop_progress``/``patience`` arm the progress-based early stop (see
    ``bounds.progress_measure``); ``policy`` (a :class:`TierPolicy`) runs
    the two-tier precision scheme -- an fp32 tier with outward-rounded
    merges until per-round progress drops below ``policy.switch_progress``,
    then an exact-cast promotion into the requested dtype for the endgame.
    Both tiers reuse their own dtype-keyed prepared engines and compiled
    runners, so tiered tree search stays recompile-free.

    ``telemetry`` (a ring capacity) carries an ``obs.TelemetryPlane``
    through the while_loop and attaches its snapshot to the result --
    per-round progress ring, early-stop and first-infeasible rounds, read
    back only at exit.  Recording reuses the progress scalar the carry
    already computes, so bounds stay bitwise identical and the fixed point
    remains one dispatch; the telemetry capacity is part of the runner
    cache key (on/off are distinct compiled runners, each cached once)."""
    if driver not in ("host_loop", "device_loop"):
        raise ValueError(f"unknown driver: {driver!r}")
    tel_cap = int(telemetry or 0)
    pair = two_tier_bounds_dtypes(policy, dtype) if policy is not None else None
    if pair is not None:
        dt32, final = pair
        kw = dict(
            tile_rows=tile_rows, tile_width=tile_width, use_pallas=use_pallas,
            fused=fused, driver=driver, interpret=interpret, scatter=scatter,
            donate=donate, slab=slab, patience=policy.patience,
            telemetry=telemetry,
        )
        cap32 = max(1, int(cfg.max_rounds * policy.fp32_round_frac))
        r32 = propagate_block_ell(
            p, dataclasses.replace(cfg, max_rounds=cap32), dtype=dt32,
            lb0=lb0, ub0=ub0, stop_progress=policy.switch_progress, **kw,
        )
        if bool(r32.infeasible):
            # fp32 infeasibility is never trusted (see core.propagator):
            # re-derive the verdict in the final dtype from scratch.
            r = propagate_block_ell(
                p, cfg, dtype=final, lb0=lb0, ub0=ub0,
                stop_progress=policy.stop_progress, **kw,
            )
            if r.telemetry is not None:
                r = r._replace(
                    telemetry=dataclasses.replace(r.telemetry, fp32=r32.telemetry)
                )
            return r._replace(tier_rounds=r32.rounds)
        tier_rounds = int(r32.rounds)
        rem = dataclasses.replace(
            cfg, max_rounds=max(1, cfg.max_rounds - tier_rounds)
        )
        warm_lb, warm_ub = bnd.canonical_infinite(
            jnp.asarray(r32.lb, final), jnp.asarray(r32.ub, final)
        )
        r = propagate_block_ell(
            p, rem, dtype=final, lb0=warm_lb, ub0=warm_ub,
            stop_progress=policy.stop_progress, **kw,
        )
        if r.telemetry is not None:
            r = r._replace(
                telemetry=dataclasses.replace(
                    r.telemetry, tier_switch_round=tier_rounds,
                    fp32=r32.telemetry,
                )
            )
        return r._replace(rounds=r.rounds + r32.rounds, tier_rounds=r32.rounds)
    if policy is not None:
        stop_progress = policy.stop_progress
        patience = policy.patience
    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    do_fuse = (
        prep.fits_one_chunk if fused == "auto" else bool(fused == "yes" or fused is True)
    )
    scatter = _resolve_scatter(scatter, prep)
    do_donate = donate_supported() if donate is None else bool(donate)
    n = prep.n

    key = (
        id(prep.d.val), cfg, use_pallas, do_fuse, scatter, interpret, do_donate,
        driver, slab, stop_progress, patience, tel_cap,
    )
    anchors = (prep.d.val,)

    def build():
        donate_kw = {"donate_argnums": (0, 1)} if do_donate else {}
        round_fn = functools.partial(
            _prepared_round,
            prep,
            eps=cfg.eps_for(prep.d.val.dtype),
            int_eps=cfg.int_eps,
            inf=cfg.inf,
            use_pallas=use_pallas,
            fused=do_fuse,
            scatter=scatter,
            interpret=interpret,
            slab=slab,
        )
        if driver == "host_loop":
            # Progress is computed INSIDE the jit, where the pre-round
            # bounds are still live (they are donated away by the call).
            def step(lb, ub):
                nlb, nub, ch = round_fn(lb, ub)
                out = nlb, nub, ch, bnd.progress_measure(lb, ub, nlb, nub)
                if tel_cap:
                    out = out + (jnp.any(nlb > nub + cfg.feas_eps),)
                return out

            return jax.jit(step, **donate_kw)

        @functools.partial(jax.jit, **donate_kw)
        def run(lb0, ub0):
            def body(state):
                lb, ub, _, r, _, flat = state[:6]
                nlb, nub, ch = round_fn(lb, ub)
                prog = bnd.progress_measure(lb, ub, nlb, nub)
                if stop_progress is not None:
                    flat = jnp.where(prog < stop_progress, flat + 1, jnp.int32(0))
                out = (nlb, nub, ch, r + 1, prog, flat)
                if tel_cap:
                    stopped = (
                        (flat >= patience) if stop_progress is not None else None
                    )
                    out = out + (obs.record_round(
                        state[6], prog, r + 1,
                        jnp.any(nlb > nub + cfg.feas_eps), stopped,
                    ),)
                return out

            def cond(state):
                ch, r, flat = state[2], state[3], state[5]
                go = ch & (r < cfg.max_rounds)
                if stop_progress is not None:
                    go = go & (flat < patience)
                return go

            init = (
                lb0, ub0, jnp.asarray(True), jnp.int32(0),
                jnp.asarray(jnp.nan, lb0.dtype), jnp.int32(0),
            )
            if tel_cap:
                init = init + (obs.device_plane(tel_cap, dtype=lb0.dtype),)
            final = jax.lax.while_loop(cond, body, init)
            lb, ub, ch, r, prog = final[:5]
            lb, ub = lb[:n], ub[:n]
            res = (lb, ub, r, ~ch, jnp.any(lb > ub + cfg.feas_eps), prog)
            return res + ((final[6],) if tel_cap else ())

        return run

    runner = _runner_cache.get(key, anchors)
    if runner is None:
        runner = build()
        _runner_cache.put(key, anchors, runner)

    lb, ub = _initial_padded_bounds(prep, lb0, ub0)

    if driver == "host_loop":
        rounds, changed, flat = 0, True, 0
        prog = jnp.asarray(jnp.nan, lb.dtype)
        history: list[float] = []
        stop_round = infeas_round = -1
        while changed and rounds < cfg.max_rounds:
            # Donated in, fresh buffers out: the loop owns its bounds, so XLA
            # reuses the same two (n_pad,) buffers round over round.
            lb, ub, cdev, prog, *infeas_dev = runner(lb, ub)
            changed = bool(cdev)
            rounds += 1
            if tel_cap:
                history.append(float(prog))
                if infeas_round < 0 and bool(infeas_dev[0]):
                    infeas_round = rounds
            if stop_progress is not None:
                flat = flat + 1 if float(prog) < stop_progress else 0
                if flat >= patience:
                    stop_round = rounds
                    break
        infeas = bool(jnp.any(lb[:n] > ub[:n] + cfg.feas_eps))
        snap = obs.host_snapshot(
            history, tel_cap, stop_round=stop_round, infeas_round=infeas_round
        ) if tel_cap else None
        return PropagationResult(
            lb[:n], ub[:n], jnp.int32(rounds), jnp.asarray(not changed),
            jnp.asarray(infeas), progress=prog, telemetry=snap,
        )

    out = runner(lb, ub)
    lb, ub, rounds, converged, infeasible, prog = out[:6]
    snap = obs.TelemetrySnapshot(plane=out[6]) if tel_cap else None
    return PropagationResult(
        lb, ub, rounds, converged, infeasible, progress=prog, telemetry=snap
    )


# ---------------------------------------------------------------------------
# Batched engine: a whole ProblemBatch per dispatch
# ---------------------------------------------------------------------------


class DeviceProblemBatch(NamedTuple):
    """Device-resident packed batch (pytree): the flat tile stream, hoisted
    round-constant gathers/offsets, initial bounds and the real-column
    mask.  ``col`` keeps instance-local columns (the kernel routes blocks
    by ``tile_inst``); ``col_g`` carries the precomputed global ids
    ``col + tile_inst * n_pad`` for the flat XLA dataflow."""

    val: jnp.ndarray        # (T, R, K)
    col: jnp.ndarray        # (T, R, K) int32 instance-local
    col_g: jnp.ndarray      # (T, R, K) int32 global (bound-plane) columns
    chunk_row: jnp.ndarray  # (T, R) int32 global row ids
    tile_inst: jnp.ndarray  # (T,) int32 instance of each tile
    ii_g: jnp.ndarray       # (T, R, K) int32: is_int[col], hoisted
    lhs_g: jnp.ndarray      # (T, R): lhs1[chunk_row], hoisted
    rhs_g: jnp.ndarray      # (T, R)
    lb0: jnp.ndarray        # (B, n_pad)
    ub0: jnp.ndarray        # (B, n_pad)
    col_valid: jnp.ndarray  # (B, n_pad) bool: j < n_i (real columns)


@dataclasses.dataclass(frozen=True)
class PreparedBatch:
    """One bucket, device-ready.  Like :class:`PreparedBlockEll`, not a
    pytree: drivers close over it so arrays become jit constants."""

    batch: ProblemBatch
    d: DeviceProblemBatch
    size: int
    m_total: int
    n_pad: int
    fits_one_chunk: bool
    # Lazy slab partitions of the packed stream, keyed by slab width.
    _slabs: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False
    )

    def slab_partition(self, slab: int | None = None) -> SlabPartition:
        """The bucket's flat super-tile stream re-bucketed into per-instance
        ``slab``-wide column windows (default :func:`default_slab_width`), copies
        sorted ``(instance, slab, tile)``; built once per slab width from
        the host-side packed arrays and cached on the prep."""
        s = default_slab_width(self.n_pad) if slab is None else int(slab)
        part = self._slabs.get(s)
        if part is None:
            ell = self.batch.ell
            dt = np.dtype(self.d.val.dtype)
            # Instance i's padding chunks target its dummy row, the last of
            # its row range.
            dummy_rows = (ell.row_offset[1:] - 1).astype(np.int32)
            part = build_slab_partition(
                np.asarray(ell.val, dtype=dt),
                ell.col,
                ell.chunk_row,
                ell.tile_inst,
                self.batch.lhs1,
                self.batch.rhs1,
                self.batch.is_int,
                self.n_pad,
                s,
                dummy_rows,
            )
            self._slabs[s] = part
        return part


_batch_prep_cache = LRU(maxsize=16)


def prepare_problem_batch(batch: ProblemBatch, dtype=None) -> PreparedBatch:
    """Device transfer + hoisted constant gathers for one packed bucket,
    LRU-cached per ``ProblemBatch`` (maxsize 16, see ``cache_info()``; the
    serving pattern re-propagates the same packed batch with fresh
    bounds -- ``propagate_batch_prepared`` takes them as per-call
    arguments)."""
    ell = batch.ell
    dt = np.dtype(dtype) if dtype is not None else np.dtype(ell.val.dtype)
    key = (id(batch), dt.str)
    hit = _batch_prep_cache.get(key, (batch,))
    if hit is not None:
        return hit

    n_pad = batch.n_pad
    col_g = ell.col + ell.tile_inst[:, None, None] * np.int32(n_pad)
    ii_g = batch.is_int.reshape(-1)[col_g]
    lhs_g = batch.lhs1[ell.chunk_row]
    rhs_g = batch.rhs1[ell.chunk_row]
    col_valid = np.arange(n_pad)[None, :] < ell.n[:, None]
    d = DeviceProblemBatch(
        val=jnp.asarray(ell.val, dtype=dt),
        col=jnp.asarray(ell.col),
        col_g=jnp.asarray(col_g),
        chunk_row=jnp.asarray(ell.chunk_row),
        tile_inst=jnp.asarray(ell.tile_inst),
        ii_g=jnp.asarray(ii_g.astype(np.int32)),
        lhs_g=jnp.asarray(lhs_g.astype(dt)),
        rhs_g=jnp.asarray(rhs_g.astype(dt)),
        lb0=jnp.asarray(batch.lb, dtype=dt),
        ub0=jnp.asarray(batch.ub, dtype=dt),
        col_valid=jnp.asarray(col_valid),
    )
    prep = PreparedBatch(
        batch=batch,
        d=d,
        size=batch.size,
        m_total=batch.m_total,
        n_pad=n_pad,
        fits_one_chunk=all(
            rows_fit_one_chunk(p, ell.tile_width) for p in batch.problems
        ),
    )
    _batch_prep_cache.put(key, (batch,), prep)
    return prep


def batched_reference_round(
    val, col_g, ii_g, chunk_row, lhs_g, rhs_g, lb, ub, active,
    *, m_total: int, n_pad: int, fits_one_chunk: bool,
    eps: float, int_eps: float, inf: float, outward: float = 0.0,
):
    """One batched round at the data level (jnp oracle arithmetic), usable
    under ``shard_map``/``jit`` with the batch axis as a plain leading dim
    of the bound plane.  The whole batch is ONE flat dataflow -- one
    gather, one candidate sweep, one column segment reduction -- so the
    per-op dispatch overhead is paid once per round, not once per instance.
    Inactive instances' candidates are forced to the reduction identity, so
    their bounds pass through unchanged and report no change."""
    if fits_one_chunk:
        best_l, best_u = kref.batched_fused_scatter_round_ref(
            val, col_g, ii_g, lhs_g, rhs_g, lb, ub, n_pad, int_eps, inf
        )
    else:
        best_l, best_u = kref.batched_candidates_scatter_round_ref(
            val, col_g, ii_g, chunk_row, lhs_g, rhs_g, lb, ub,
            m_total, n_pad, int_eps, inf,
        )
    best_l = jnp.where(active[:, None], best_l, -inf)
    best_u = jnp.where(active[:, None], best_u, inf)
    return bnd.apply_updates_batch(lb, ub, best_l, best_u, eps, inf, outward)


def _batched_prepared_round(
    prep: PreparedBatch, lb, ub, active,
    *, eps: float, int_eps: float, inf: float,
    use_pallas: bool, interpret: bool | None, slab: int | None = None,
    outward: float = 0.0,
):
    """One round over a prepared bucket: ``(B, n_pad)`` bounds + ``(B,)``
    active mask -> updated bounds + per-instance changed flags.

    The Pallas path (chunk-complete rows, the paper's common case) runs the
    batched kernel D -- the grid walks the flat tile stream, the
    scalar-prefetched instance map routes each tile to its bound-plane and
    accumulator rows, converged instances are gated off in-kernel -- then
    the batched merge kernel.  Buckets whose ``n_pad`` exceeds the VMEM
    accumulator budget run the slab-partitioned kernels instead (copies
    routed by ``(instance, slab)``, same gating); only buckets with rows
    spanning chunks at small ``n_pad`` use the batched jnp dataflow."""
    d = prep.d
    if use_pallas and prep.fits_one_chunk and prep.n_pad <= SCATTER_MAX_NPAD:
        best_l, best_u = kern.batched_fused_scatter_round_tiles(
            d.val, d.col, d.ii_g, d.lhs_g, d.rhs_g, lb, ub,
            d.tile_inst, active, prep.n_pad, int_eps, inf, interpret,
        )
        return kern.apply_updates_batch_tiles(
            lb, ub, best_l, best_u, active, eps, inf, interpret, outward
        )
    if use_pallas and prep.n_pad > SCATTER_MAX_NPAD:
        return _partitioned_pallas_round(
            prep.slab_partition(slab), lb, ub, active,
            node=False, eps=eps, int_eps=int_eps, inf=inf, interpret=interpret,
            outward=outward,
        )
    return batched_reference_round(
        d.val, d.col_g, d.ii_g, d.chunk_row, d.lhs_g, d.rhs_g, lb, ub, active,
        m_total=prep.m_total, n_pad=prep.n_pad,
        fits_one_chunk=prep.fits_one_chunk,
        eps=eps, int_eps=int_eps, inf=inf, outward=outward,
    )


def batched_round_fn_for(
    prep: PreparedBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
    slab: int | None = None,
):
    """A jit-able ``(lb, ub, active) -> (lb, ub, changed)`` batched round
    closure over a prepared bucket.  ``slab`` overrides the partitioned
    engine's column-slab width for VMEM-exceeding buckets (ignored
    otherwise)."""
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        _batched_prepared_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        interpret=interpret,
        slab=slab,
        outward=cfg.outward_for(prep.d.val.dtype),
    )


def _unpack_batch_results(
    prep, lb, ub, rounds, converged, infeasible, progress=None, plane=None
):
    out = []
    for i, p in enumerate(prep.batch.problems):
        # Per-instance snapshots share ONE underlying batched plane (row
        # selected lazily by index) -- attaching them costs no readback.
        snap = (
            obs.TelemetrySnapshot(plane=plane, index=i)
            if plane is not None else None
        )
        out.append(
            PropagationResult(
                lb[i, : p.n], ub[i, : p.n], rounds[i], converged[i], infeasible[i],
                progress=jnp.nan if progress is None else progress[i],
                telemetry=snap,
            )
        )
    return out


# Jitted fixed-point runners, cached per prepared bucket + config (maxsize
# 64, see ``cache_info()``): the serving loop re-propagates the same packed
# batches, and rebuilding the jit closure per request would recompile every
# time.  Bounds are runtime arguments of every runner, so one compiled
# fixed point serves any warm-start bound plane.
_batch_runner_cache = LRU(maxsize=64)


def _cached_batch_runner(prep, key, build):
    runner = _batch_runner_cache.get(key, (prep,))
    if runner is None:
        runner = build()
        _batch_runner_cache.put(key, (prep,), runner)
    return runner


def batched_device_runner(
    prep: PreparedBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
    donate: bool | None = None,
    slab: int | None = None,
    stop_progress: float | None = None,
    patience: int = 1,
    telemetry: int | None = None,
):
    """The bucket's whole fixed point as ONE jitted dispatch, cached:
    ``run(lb0, ub0) -> (lb, ub, rounds, converged, infeasible, progress)``
    (all per-instance; ``lb0``/``ub0`` donated where supported).
    ``stop_progress``/``patience`` arm the per-instance progress-based
    early stop inside the dispatch; ``telemetry`` (a ring capacity)
    appends the batched ``obs.TelemetryPlane`` to the return."""
    tel_cap = int(telemetry or 0)
    key = (
        id(prep), cfg, use_pallas, interpret, donate, slab,
        stop_progress, patience, tel_cap, "device",
    )

    def build():
        round_fn = batched_round_fn_for(prep, cfg, use_pallas, interpret, slab)
        if donate is None:
            donate_kw = donate_kwargs(argnums=(0, 1))
        else:
            donate_kw = {"donate_argnums": (0, 1)} if donate else {}
        col_valid = prep.d.col_valid

        @functools.partial(jax.jit, **donate_kw)
        def run(lb0, ub0):
            plane = (
                obs.device_plane(tel_cap, batch=lb0.shape[0], dtype=lb0.dtype)
                if tel_cap else None
            )
            out = batched_fixed_point(
                round_fn, lb0, ub0, cfg.max_rounds,
                stop_progress=stop_progress, patience=patience,
                with_progress=True, plane=plane, feas_eps=cfg.feas_eps,
            )
            lb, ub, rounds, converged, progress = out[:5]
            infeasible = jnp.any((lb > ub + cfg.feas_eps) & col_valid, axis=-1)
            res = (lb, ub, rounds, converged, infeasible, progress)
            return res + ((out[5],) if tel_cap else ())

        return run

    return _cached_batch_runner(prep, key, build)


def _batch_initial_bounds(prep: PreparedBatch, lb0, ub0):
    """Per-call bound planes -> private, donated-safe (B, n_pad) buffers."""
    d = prep.d
    out = []
    for override, default in ((lb0, d.lb0), (ub0, d.ub0)):
        if override is None:
            out.append(owned_copy(default))
            continue
        arr = jnp.asarray(override, d.val.dtype)
        if arr.shape != default.shape:
            raise ValueError(
                f"bound plane has shape {arr.shape}, expected {default.shape}"
            )
        out.append(owned_copy(arr))
    return tuple(out)


def propagate_batch_prepared(
    prep: PreparedBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    driver: str = "device_loop",
    interpret: bool | None = None,
    donate: bool | None = None,
    lb0=None,
    ub0=None,
    slab: int | None = None,
    stop_progress: float | None = None,
    patience: int = 1,
    telemetry: int | None = None,
):
    """Run one prepared bucket to its per-instance fixed points.

    ``device_loop``: the entire batched fixed point is ONE dispatch
    (``batched_fixed_point`` under jit, bounds donated).  ``host_loop``:
    host syncs the per-instance changed flags each round and retires
    converged instances from the active mask.  ``lb0``/``ub0`` warm-start
    the bucket from a caller-supplied ``(B, n_pad)`` bound plane (default:
    the packed instances' root bounds) -- the prepared tiles and the cached
    runner serve any plane.  Returns one ``PropagationResult`` per
    instance, bucket order.  ``telemetry`` (a ring capacity) attaches
    per-instance ``obs.TelemetrySnapshot``s -- device-accumulated on the
    device loop, host-accumulated (this driver syncs every round anyway)
    on the host loop."""
    d = prep.d
    bsz = prep.size
    tel_cap = int(telemetry or 0)

    if driver == "host_loop":
        key = (id(prep), cfg, use_pallas, interpret, donate, slab, tel_cap, "host")

        def build():
            round_fn = batched_round_fn_for(prep, cfg, use_pallas, interpret, slab)
            if donate is None:
                donate_kw = donate_kwargs(argnums=(0, 1))
            else:
                donate_kw = {"donate_argnums": (0, 1)} if donate else {}
            col_valid = prep.d.col_valid

            # Progress is computed INSIDE the jit, where the pre-round
            # bounds are still live (they are donated away by the call).
            def step(lb, ub, active):
                nlb, nub, ch = round_fn(lb, ub, active)
                out = nlb, nub, ch, bnd.progress_measure(lb, ub, nlb, nub)
                if tel_cap:
                    out = out + (
                        jnp.any((nlb > nub + cfg.feas_eps) & col_valid, axis=-1),
                    )
                return out

            return jax.jit(step, **donate_kw)

        jit_round = _cached_batch_runner(prep, key, build)
        lb, ub = _batch_initial_bounds(prep, lb0, ub0)
        active = np.ones(bsz, dtype=bool)
        last_changed = np.ones(bsz, dtype=bool)
        rounds = np.zeros(bsz, dtype=np.int32)
        flat = np.zeros(bsz, dtype=np.int32)
        progress = np.full(bsz, np.nan)
        histories: list[list[float]] = [[] for _ in range(bsz)]
        stop_round = np.full(bsz, -1, np.int32)
        infeas_round = np.full(bsz, -1, np.int32)
        while active.any():
            ran = active
            lb, ub, ch, prog, *inf_dev = jit_round(lb, ub, jnp.asarray(active))
            ch = np.asarray(ch)  # the per-round host<->device sync point
            prog = np.asarray(prog)
            rounds += active
            last_changed = np.where(active, ch, last_changed)
            progress = np.where(active, prog, progress)
            active = active & ch & (rounds < cfg.max_rounds)
            if stop_progress is not None:
                flat = np.where(ran & (prog < stop_progress), flat + 1, 0)
                stopped = ran & (flat >= patience)
                stop_round = np.where(
                    stopped & (stop_round < 0), rounds, stop_round
                )
                active = active & (flat < patience)
            if tel_cap:
                inf_now = np.asarray(inf_dev[0])
                infeas_round = np.where(
                    ran & inf_now & (infeas_round < 0), rounds, infeas_round
                )
                for i in np.flatnonzero(ran):
                    histories[i].append(float(prog[i]))
        infeasible = np.asarray(
            jnp.any((lb > ub + cfg.feas_eps) & d.col_valid, axis=-1)
        )
        results = _unpack_batch_results(
            prep, lb, ub, rounds, ~last_changed, infeasible, progress
        )
        if tel_cap:
            results = [
                r._replace(telemetry=obs.host_snapshot(
                    histories[i], tel_cap,
                    stop_round=int(stop_round[i]),
                    infeas_round=int(infeas_round[i]),
                ))
                for i, r in enumerate(results)
            ]
        return results

    if driver != "device_loop":
        raise ValueError(f"unknown driver: {driver!r}")

    run = batched_device_runner(
        prep, cfg, use_pallas, interpret, donate, slab, stop_progress, patience,
        telemetry=tel_cap,
    )
    lb_init, ub_init = _batch_initial_bounds(prep, lb0, ub0)
    out = run(lb_init, ub_init)
    lb, ub, rounds, converged, infeasible, progress = out[:6]
    plane = out[6] if tel_cap else None
    return _unpack_batch_results(
        prep, lb, ub, rounds, converged, infeasible, progress, plane=plane
    )


# Packed-batch cache (maxsize 8, see ``cache_info()``): serving
# re-propagates the same request list, and repacking would defeat both the
# prepare() and the runner caches (both key on object identity).
_pack_cache = LRU(maxsize=8)


def packed_problems(problems, tile_rows: int = 8, tile_width: int = 128):
    """LRU-cached ``pack_problems``: the same problem list (by identity)
    packs once and reuses its ``ProblemBatch`` objects across calls."""
    problems = list(problems)
    anchors = tuple(problems)
    key = (tuple(id(p) for p in problems), tile_rows, tile_width)
    hit = _pack_cache.get(key, anchors)
    if hit is not None:
        return hit
    batches = pack_problems(problems, tile_rows=tile_rows, tile_width=tile_width)
    _pack_cache.put(key, anchors, batches)
    return batches


def clear_batch_caches() -> None:
    """Drop packed batches, prepared buckets and jitted runners."""
    _pack_cache.clear()
    _batch_prep_cache.clear()
    _batch_runner_cache.clear()


def cache_info() -> dict:
    """Hit/miss/size/maxsize counters of every engine-level LRU cache
    (prepared instances, compiled single-instance runners, packed batches,
    prepared buckets, batched runners, node-batch runners).  Complements
    the ``clear_*`` helpers; sizes are entry counts, not bytes."""
    return {
        "prepare_block_ell": _prep_cache.info(),
        "block_ell_runner": _runner_cache.info(),
        "packed_problems": _pack_cache.info(),
        "prepare_problem_batch": _batch_prep_cache.info(),
        "batch_runner": _batch_runner_cache.info(),
        "node_runner": _node_runner_cache.info(),
    }


def _bound_planes_for_batch(batch: ProblemBatch, bounds):
    """Per-problem ``(lb, ub)`` overrides -> this bucket's (B, n_pad) planes.

    ``bounds[i]`` (input order) is either ``None`` (use problem ``i``'s own
    bounds) or a ``(lb, ub)`` pair of ``(n_i,)`` arrays."""
    lb_plane = np.array(batch.lb, copy=True)
    ub_plane = np.array(batch.ub, copy=True)
    touched = False
    for row, (idx, p) in enumerate(zip(batch.indices, batch.problems)):
        pair = bounds[idx]
        if pair is None:
            continue
        lb_i, ub_i = pair
        lb_i = np.asarray(lb_i, lb_plane.dtype)
        ub_i = np.asarray(ub_i, ub_plane.dtype)
        if lb_i.shape != (p.n,) or ub_i.shape != (p.n,):
            raise ValueError(
                f"bounds for instance {idx} have shapes {lb_i.shape}/{ub_i.shape}, "
                f"expected {(p.n,)}"
            )
        lb_plane[row, : p.n] = lb_i
        ub_plane[row, : p.n] = ub_i
        touched = True
    if not touched:
        return None, None
    return lb_plane, ub_plane


def propagate_batch_block_ell(
    problems,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    driver: str = "device_loop",
    interpret: bool | None = None,
    donate: bool | None = None,
    bounds=None,
    slab: int | None = None,
    stop_progress: float | None = None,
    patience: int = 1,
    policy: TierPolicy | None = None,
    telemetry: int | None = None,
):
    """Batched kernel-backed propagation: pack -> per-bucket dispatch ->
    per-instance results in input order.  Packing, device transfer and the
    jitted fixed-point runners are all LRU-cached, so a serving loop that
    re-propagates the same instances pays them once.  ``bounds`` (one
    ``(lb, ub)`` pair or ``None`` per problem, input order) warm-starts
    instances from caller bounds through the SAME packed tiles and compiled
    runners -- nothing is repacked or recompiled.  The public front end is
    ``repro.core.propagate_batch``.

    ``stop_progress``/``patience`` arm the per-instance progress-based
    early stop; ``policy`` (a :class:`TierPolicy`) runs the whole batch
    through the two-tier precision scheme -- an fp32 pass (outward-rounded
    merges) until each instance's progress drops below
    ``policy.switch_progress``, then an exact-cast warm start of the
    requested-dtype engine through the same packed batches.  ``telemetry``
    (a ring capacity) attaches per-instance device telemetry snapshots;
    each bucket's instances share one batched plane (zero extra
    readbacks), and under ``policy`` the fp32 tier's snapshot hangs off
    the endgame snapshot's ``.fp32``."""
    problems = list(problems)
    pair = two_tier_bounds_dtypes(policy, dtype) if policy is not None else None
    if pair is not None:
        dt32, final = pair
        kw = dict(
            tile_rows=tile_rows, tile_width=tile_width, use_pallas=use_pallas,
            driver=driver, interpret=interpret, donate=donate, slab=slab,
            patience=policy.patience, telemetry=telemetry,
        )
        cap32 = max(1, int(cfg.max_rounds * policy.fp32_round_frac))
        r32 = propagate_batch_block_ell(
            problems, dataclasses.replace(cfg, max_rounds=cap32),
            dtype=dt32, bounds=bounds,
            stop_progress=policy.switch_progress, **kw,
        )
        # Per-instance promotion, except that an instance whose fp32 tier
        # declared infeasibility restarts from its ORIGINAL bounds (fp32
        # verdicts are never trusted -- see core.propagator).
        orig = bounds if bounds is not None else [None] * len(problems)
        warm = [
            None if bool(t.infeasible) else bnd.canonical_infinite(
                jnp.asarray(t.lb, final), jnp.asarray(t.ub, final)
            )
            for t in r32
        ]
        warm = [w if w is not None else o for w, o in zip(warm, orig)]
        rem = dataclasses.replace(cfg, max_rounds=max(1, cfg.max_rounds - cap32))
        res = propagate_batch_block_ell(
            problems, rem, dtype=final, bounds=warm,
            stop_progress=policy.stop_progress, **kw,
        )
        def _combine_tel(r, t):
            if r.telemetry is None:
                return None
            return dataclasses.replace(
                r.telemetry,
                tier_switch_round=(
                    -1 if bool(t.infeasible) else int(t.rounds)
                ),
                fp32=t.telemetry,
            )
        return [
            r._replace(
                rounds=r.rounds + (0 if bool(t.infeasible) else t.rounds),
                tier_rounds=t.rounds,
                telemetry=_combine_tel(r, t),
            )
            for r, t in zip(res, r32)
        ]
    if policy is not None:
        stop_progress = policy.stop_progress
        patience = policy.patience
    if bounds is not None:
        bounds = list(bounds)
        if len(bounds) != len(problems):
            raise ValueError(
                f"bounds has {len(bounds)} entries for {len(problems)} problems"
            )
    batches = packed_problems(problems, tile_rows=tile_rows, tile_width=tile_width)
    out = [None] * len(problems)
    for batch in batches:
        prep = prepare_problem_batch(batch, dtype)
        lb0 = ub0 = None
        if bounds is not None:
            lb0, ub0 = _bound_planes_for_batch(batch, bounds)
        results = propagate_batch_prepared(
            prep, cfg, use_pallas=use_pallas, driver=driver,
            interpret=interpret, donate=donate, lb0=lb0, ub0=ub0, slab=slab,
            stop_progress=stop_progress, patience=patience,
            telemetry=telemetry,
        )
        for idx, res in zip(batch.indices, results):
            out[idx] = res
    return out


# ---------------------------------------------------------------------------
# Node-batch engine: one shared matrix, many bound planes (tree search)
# ---------------------------------------------------------------------------


def _node_round(
    prep: PreparedBlockEll, lb, ub, active,
    *, eps: float, int_eps: float, inf: float,
    use_pallas: bool, interpret: bool | None, slab: int | None = None,
    outward: float = 0.0,
):
    """One round over a node batch: ``(B, n_pad)`` per-node bounds +
    ``(B,)`` active mask -> updated bounds + per-node changed flags, with
    the instance's matrix tiles shared by every node.

    The Pallas path (chunk-complete rows, accumulator budget respected)
    runs the node kernel -- the grid walks ``(B, T)`` with the tile axis
    minor, converged nodes gated off in-kernel -- then the batched merge
    kernel.  Nodes of a VMEM-exceeding instance (``n_pad`` beyond the
    accumulator budget) run the slab-partitioned node kernels on a
    ``(B, T')`` grid over the per-slab copies, same gating.  Otherwise the
    single-instance jnp round is vmapped over the node axis (multichunk
    rows at small ``n_pad``, or ``use_pallas=False``), with inactive
    nodes' bounds frozen outside."""
    if use_pallas and prep.fits_one_chunk and prep.n_pad <= SCATTER_MAX_NPAD:
        d = prep.d
        best_l, best_u = kern.node_fused_scatter_round_tiles(
            d.val, d.col, prep.ii_g, prep.lhs_g, prep.rhs_g, lb, ub,
            active, prep.n_pad, int_eps, inf, interpret,
        )
        return kern.apply_updates_batch_tiles(
            lb, ub, best_l, best_u, active, eps, inf, interpret, outward
        )
    if use_pallas and prep.n_pad > SCATTER_MAX_NPAD:
        return _partitioned_pallas_round(
            prep.slab_partition(slab), lb, ub, active,
            node=True, eps=eps, int_eps=int_eps, inf=inf, interpret=interpret,
            outward=outward,
        )
    single = functools.partial(
        _prepared_round,
        prep,
        eps=eps,
        int_eps=int_eps,
        inf=inf,
        use_pallas=False,
        fused=prep.fits_one_chunk,
        scatter=_resolve_scatter("auto", prep),
        interpret=interpret,
        outward=outward,
    )
    new_lb, new_ub, changed = jax.vmap(single)(lb, ub)
    lb = jnp.where(active[:, None], new_lb, lb)
    ub = jnp.where(active[:, None], new_ub, ub)
    return lb, ub, changed & active


def node_round_fn_for(
    prep: PreparedBlockEll,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
    slab: int | None = None,
):
    """A jit-able ``(lb, ub, active) -> (lb, ub, changed)`` node-batch
    round closure over a prepared instance (bounds ``(B, n_pad)``).
    ``slab`` overrides the partitioned engine's column-slab width for
    VMEM-exceeding instances (ignored otherwise)."""
    eps = cfg.eps_for(prep.d.val.dtype)
    return functools.partial(
        _node_round,
        prep,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        use_pallas=use_pallas,
        interpret=interpret,
        slab=slab,
        outward=cfg.outward_for(prep.d.val.dtype),
    )


# Node-batch fixed-point runners, cached per matrix structure + node count +
# config (maxsize 32, see ``cache_info()``): a tree search re-propagates the
# same instance with fresh node bounds every dive, and the bounds are
# runtime arguments, so each (structure, B) pair compiles exactly once.
_node_runner_cache = LRU(maxsize=32)


def node_batch_runner(
    prep: PreparedBlockEll,
    batch_size: int,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
    donate: bool | None = None,
    slab: int | None = None,
    stop_progress: float | None = None,
    patience: int = 1,
    telemetry: int | None = None,
):
    """The node batch's whole fixed point as ONE jitted dispatch, cached:
    ``run(lb0, ub0) -> (lb, ub, rounds, converged, infeasible, progress)``
    with the node axis leading everywhere (``lb0``/``ub0`` donated where
    supported).  ``stop_progress``/``patience`` arm the per-node
    progress-based early stop inside the dispatch; ``telemetry`` (a ring
    capacity) appends the per-node ``obs.TelemetryPlane`` to the return."""
    do_donate = donate_supported() if donate is None else bool(donate)
    tel_cap = int(telemetry or 0)
    key = (
        id(prep.d.val), batch_size, cfg, use_pallas, interpret, do_donate, slab,
        stop_progress, patience, tel_cap,
    )
    anchors = (prep.d.val,)
    runner = _node_runner_cache.get(key, anchors)
    if runner is not None:
        return runner

    round_fn = node_round_fn_for(prep, cfg, use_pallas, interpret, slab)
    donate_kw = {"donate_argnums": (0, 1)} if do_donate else {}
    col_valid = jnp.arange(prep.n_pad) < prep.n

    @functools.partial(jax.jit, **donate_kw)
    def run(lb0, ub0):
        plane = (
            obs.device_plane(tel_cap, batch=lb0.shape[0], dtype=lb0.dtype)
            if tel_cap else None
        )
        out = batched_fixed_point(
            round_fn, lb0, ub0, cfg.max_rounds,
            stop_progress=stop_progress, patience=patience, with_progress=True,
            plane=plane, feas_eps=cfg.feas_eps,
        )
        lb, ub, rounds, converged, progress = out[:5]
        infeasible = jnp.any((lb > ub + cfg.feas_eps) & col_valid[None, :], axis=-1)
        res = (lb, ub, rounds, converged, infeasible, progress)
        return res + ((out[5],) if tel_cap else ())

    _node_runner_cache.put(key, anchors, run)
    return run


def propagate_nodes_prepared(
    prep: PreparedBlockEll,
    lb_nodes,
    ub_nodes,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_pallas: bool = True,
    interpret: bool | None = None,
    donate: bool | None = None,
    slab: int | None = None,
    stop_progress: float | None = None,
    patience: int = 1,
    with_progress: bool = False,
    telemetry: int | None = None,
):
    """Run B warm-started nodes of one prepared instance to their fixed
    points in ONE dispatch.

    ``lb_nodes``/``ub_nodes`` are ``(B, n)`` per-node bound planes (the
    only per-node state -- the matrix tiles are resident once).  Returns
    ``(lb, ub, rounds, converged, infeasible)`` with the node axis leading
    (``with_progress=True`` appends the ``(B,)`` last-round progress
    measure); ``infeasible`` marks nodes whose domain emptied (prune
    them).  ``stop_progress``/``patience`` arm the per-node progress-based
    early stop.  Each node's result is exactly what its own
    single-instance warm-started ``propagate_block_ell`` run would
    produce, including round counts.  ``telemetry`` (a ring capacity)
    appends the per-node batched ``obs.TelemetryPlane`` to either return
    shape -- wrap rows in ``obs.TelemetrySnapshot(plane, index=i)`` to
    read one node's trajectory."""
    lb_nodes = np.asarray(lb_nodes)
    ub_nodes = np.asarray(ub_nodes)
    if lb_nodes.ndim != 2 or lb_nodes.shape != ub_nodes.shape:
        raise ValueError(
            f"node bound planes must share a (B, n) shape, got "
            f"{lb_nodes.shape} / {ub_nodes.shape}"
        )
    bsz, n = lb_nodes.shape
    if n != prep.n:
        raise ValueError(f"node bounds have n={n}, instance has n={prep.n}")
    dt = prep.d.val.dtype
    pad = prep.n_pad - prep.n
    planes = []
    for plane in (lb_nodes, ub_nodes):
        plane = np.asarray(plane, dt)
        if pad:
            plane = np.concatenate([plane, np.zeros((bsz, pad), dt)], axis=1)
        planes.append(jnp.asarray(plane))
    tel_cap = int(telemetry or 0)
    run = node_batch_runner(
        prep, bsz, cfg, use_pallas, interpret, donate, slab,
        stop_progress, patience, telemetry=tel_cap,
    )
    res = run(*planes)
    lb, ub, rounds, converged, infeasible, progress = res[:6]
    out = (lb[:, : prep.n], ub[:, : prep.n], rounds, converged, infeasible)
    if with_progress:
        out = out + (progress,)
    if tel_cap:
        out = out + (res[6],)
    return out


# ---------------------------------------------------------------------------
# Measured bytes-per-round (XLA cost analysis, not assertions)
# ---------------------------------------------------------------------------


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    size = 1
    for s in shape:
        size *= int(s)
    return size * np.dtype(aval.dtype).itemsize


# Structural primitives whose own operands are pass-through loop/call state:
# recurse into their bodies (counted once, as HloCostAnalysis does for while
# bodies) instead of counting the carried tuple.
_RECURSE_PRIMS = frozenset(
    {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call", "while", "cond", "scan"}
)
_INNER_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr", "branches")


def _inner_jaxprs(eqn):
    out = []
    for name in _INNER_JAXPR_PARAMS:
        v = eqn.params.get(name)
        if v is None:
            continue
        for j in v if isinstance(v, (list, tuple)) else [v]:
            out.append(j.jaxpr if hasattr(j, "jaxpr") else j)
    return out


def hbm_bytes_of(fn, *args) -> float:
    """HBM-boundary bytes-accessed of ``fn``, measured from its traced jaxpr.

    Every XLA op counts operand + result bytes -- the same per-instruction
    definition XLA's ``HloCostAnalysis`` uses.  A ``pallas_call`` counts its
    operands + results only: that is exactly the traffic the kernel DMAs
    between HBM and VMEM, while kernel-internal values are VMEM/register
    resident by construction (the interpret-mode emulation would otherwise
    misattribute them as memory traffic).
    """
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr) -> float:
        total = 0.0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in _RECURSE_PRIMS:
                for inner in _inner_jaxprs(eqn):
                    total += walk(inner)
                continue
            total += sum(
                _aval_bytes(v.aval)
                for v in list(eqn.invars) + list(eqn.outvars)
                if hasattr(v, "aval")
            )
        return total

    return walk(closed.jaxpr)


def round_cost_analysis(
    p: Problem,
    scatter: str = "fused",
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    interpret: bool | None = None,
    include_compiled: bool = False,
) -> dict:
    """Measure ONE propagation round's memory traffic.

    ``scatter`` selects the dataflow being measured:
      * ``"fused"``       -- the fully fused in-VMEM gather+round+reduction;
      * ``"partitioned"`` -- the column-slab engine (per-slab tile copies,
        two-phase, slab-windowed scatter) that replaces ``fused`` beyond
        the VMEM accumulator budget;
      * ``"segment"``     -- candidates materialized + XLA segment
        reduction, with hoisted constant gathers;
      * ``"legacy"``      -- the seed round verbatim (``block_ell_round``):
        per-round constant gathers + materialized candidates.

    Returns a dict with
      * ``bytes_accessed``: HBM-boundary bytes (see ``hbm_bytes_of``) -- the
        number the fused engine is designed to shrink;
      * with ``include_compiled=True``, also ``bytes_accessed_compiled`` /
        ``flops``: the raw aggregate from ``Compiled.cost_analysis()`` on
        this backend's lowering, reported for transparency (on CPU it
        includes interpret-mode emulation buffers that a TPU kernel keeps in
        VMEM; computing it pays a full XLA compile, hence opt-in).
    """
    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    val_dtype = prep.d.val.dtype
    if scatter == "legacy":
        fn = legacy_round_fn_for(prep, cfg, use_pallas=True, interpret=interpret)
        shape = (prep.n,)
    else:
        fn = round_fn_for(prep, cfg, use_pallas=True, scatter=scatter, interpret=interpret)
        shape = (prep.n_pad,)
    sds = jax.ShapeDtypeStruct(shape, val_dtype)
    out = {"bytes_accessed": hbm_bytes_of(fn, sds, sds)}
    if include_compiled:
        compiled = jax.jit(fn).lower(sds, sds).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["bytes_accessed_compiled"] = float(ca.get("bytes accessed", 0.0))
        out["flops"] = float(ca.get("flops", 0.0))
    return out
