"""Pallas TPU kernels for the fused propagation round (paper Alg. 3).

TPU adaptation of CSR-adaptive (DESIGN.md §2): the matrix is stored as
length-bucketed block-ELL tiles of shape (R, K) = (tile_rows, tile_width).
On the target (TPU v5e) K=128 matches the lane width and R=8 the sublane
count, so a tile is exactly one VREG-aligned VMEM block; grid steps pipeline
HBM->VMEM DMAs of consecutive tiles.

Three kernels:

  * ``_activities_kernel``  -- per-chunk activity partials + inf counters
                               (CSR-stream/CSR-vector unified: long rows span
                               chunks, partials are segment-combined outside).
  * ``_candidates_kernel``  -- residual activities (§3.4 single-infinity
                               rule) + bound candidates (Eqs. 4/5) +
                               integrality rounding, given completed row
                               aggregates gathered per chunk.
  * ``_fused_round_kernel`` -- Alg.-3-faithful fusion of both phases for the
                               common case where every row fits in one chunk
                               (activities stay in VMEM and are reused
                               immediately -- the shared-memory trick).

All kernels are elementwise/reduction over dense tiles: the irregular
gather (bounds at column ids) and scatter (column-wise min/max merge) live
outside in XLA, which on TPU lowers them to dynamic-gather / segment ops.
Kernels are validated on CPU via ``interpret=True`` against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.types import INF


def _on_cpu() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Kernel A: activity partials
# ---------------------------------------------------------------------------


def _activities_kernel(val_ref, lb_ref, ub_ref, mf_ref, mc_ref, xf_ref, xc_ref, *, inf):
    val = val_ref[...]          # (1, R, K) VMEM block
    lb_g = lb_ref[...]
    ub_g = ub_ref[...]
    pos = val > 0
    pad = val == 0
    b_min = jnp.where(pos, lb_g, ub_g)
    b_max = jnp.where(pos, ub_g, lb_g)
    min_is_inf = (jnp.abs(b_min) >= inf) & ~pad
    max_is_inf = (jnp.abs(b_max) >= inf) & ~pad
    mf_ref[...] = jnp.where(min_is_inf | pad, 0.0, val * b_min).sum(axis=-1)
    xf_ref[...] = jnp.where(max_is_inf | pad, 0.0, val * b_max).sum(axis=-1)
    mc_ref[...] = min_is_inf.astype(jnp.int32).sum(axis=-1)
    xc_ref[...] = max_is_inf.astype(jnp.int32).sum(axis=-1)


def activities_tiles(val, lb_g, ub_g, inf: float = INF, interpret: bool | None = None):
    """Pallas-backed per-chunk activity partials. Shapes: (T, R, K) -> (T, R)."""
    if interpret is None:
        interpret = _on_cpu()
    t, r, k = val.shape
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i: (i, 0, 0))
    out_tile = pl.BlockSpec((1, r), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((t, r), dtype),
        jax.ShapeDtypeStruct((t, r), jnp.int32),
        jax.ShapeDtypeStruct((t, r), dtype),
        jax.ShapeDtypeStruct((t, r), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(_activities_kernel, inf=inf),
        grid=(t,),
        in_specs=[tile, tile, tile],
        out_specs=[out_tile, out_tile, out_tile, out_tile],
        out_shape=out_shape,
        interpret=interpret,
    )
    mf, mc, xf, xc = fn(val, lb_g, ub_g)
    return mf, mc, xf, xc


# ---------------------------------------------------------------------------
# Kernel B: candidates from completed row aggregates
# ---------------------------------------------------------------------------


def _candidates_kernel(
    val_ref,
    lb_ref,
    ub_ref,
    ii_ref,
    rmf_ref,
    rmc_ref,
    rxf_ref,
    rxc_ref,
    lhs_ref,
    rhs_ref,
    lc_ref,
    uc_ref,
    *,
    int_eps,
    inf,
):
    val = val_ref[...]            # (1, R, K)
    lb_g = lb_ref[...]
    ub_g = ub_ref[...]
    is_int_g = ii_ref[...] != 0
    rmf = rmf_ref[...][..., None]  # (1, R, 1)
    rmc = rmc_ref[...][..., None]
    rxf = rxf_ref[...][..., None]
    rxc = rxc_ref[...][..., None]
    lhs_b = lhs_ref[...][..., None]
    rhs_b = rhs_ref[...][..., None]

    pos = val > 0
    pad = val == 0
    b_min = jnp.where(pos, lb_g, ub_g)
    b_max = jnp.where(pos, ub_g, lb_g)
    min_is_inf = (jnp.abs(b_min) >= inf) & ~pad
    max_is_inf = (jnp.abs(b_max) >= inf) & ~pad
    c_min = jnp.where(min_is_inf | pad, 0.0, val * b_min)
    c_max = jnp.where(max_is_inf | pad, 0.0, val * b_max)

    min_res = jnp.where(
        min_is_inf,
        jnp.where(rmc == 1, rmf, -inf),
        jnp.where(rmc == 0, rmf - c_min, -inf),
    )
    max_res = jnp.where(
        max_is_inf,
        jnp.where(rxc == 1, rxf, inf),
        jnp.where(rxc == 0, rxf - c_max, inf),
    )

    safe_a = jnp.where(pad, 1.0, val)
    num_l = jnp.where(pos, lhs_b - max_res, rhs_b - min_res)
    num_u = jnp.where(pos, rhs_b - min_res, lhs_b - max_res)
    lcand = num_l / safe_a
    ucand = num_u / safe_a

    valid_l = (
        jnp.where(pos, (lhs_b > -inf) & (max_res < inf), (rhs_b < inf) & (min_res > -inf))
        & ~pad
    )
    valid_u = (
        jnp.where(pos, (rhs_b < inf) & (min_res > -inf), (lhs_b > -inf) & (max_res < inf))
        & ~pad
    )
    lcand = jnp.where(valid_l, jnp.clip(lcand, -inf, inf), -inf)
    ucand = jnp.where(valid_u, jnp.clip(ucand, -inf, inf), inf)

    do_l = is_int_g & (jnp.abs(lcand) < inf)
    do_u = is_int_g & (jnp.abs(ucand) < inf)
    lc_ref[...] = jnp.where(do_l, jnp.ceil(lcand - int_eps), lcand)
    uc_ref[...] = jnp.where(do_u, jnp.floor(ucand + int_eps), ucand)


def candidates_tiles(
    val,
    lb_g,
    ub_g,
    is_int_g,
    row_min_fin,
    row_min_cnt,
    row_max_fin,
    row_max_cnt,
    lhs_g,
    rhs_g,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
):
    """Pallas-backed candidates. (T,R,K) tiles + (T,R) row data -> (T,R,K) x2."""
    if interpret is None:
        interpret = _on_cpu()
    t, r, k = val.shape
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i: (i, 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((t, r, k), dtype),
        jax.ShapeDtypeStruct((t, r, k), dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(_candidates_kernel, int_eps=int_eps, inf=inf),
        grid=(t,),
        in_specs=[tile, tile, tile, tile, row_tile, row_tile, row_tile, row_tile, row_tile, row_tile],
        out_specs=[tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(
        val,
        lb_g,
        ub_g,
        is_int_g.astype(jnp.int32),
        row_min_fin,
        row_min_cnt,
        row_max_fin,
        row_max_cnt,
        lhs_g,
        rhs_g,
    )


# ---------------------------------------------------------------------------
# Kernel C: fused round (rows complete within one chunk)
# ---------------------------------------------------------------------------


def _fused_round_kernel(
    val_ref, lb_ref, ub_ref, ii_ref, lhs_ref, rhs_ref, lc_ref, uc_ref, *, int_eps, inf
):
    val = val_ref[...]
    lb_g = lb_ref[...]
    ub_g = ub_ref[...]
    pos = val > 0
    pad = val == 0
    b_min = jnp.where(pos, lb_g, ub_g)
    b_max = jnp.where(pos, ub_g, lb_g)
    min_is_inf = (jnp.abs(b_min) >= inf) & ~pad
    max_is_inf = (jnp.abs(b_max) >= inf) & ~pad
    c_min = jnp.where(min_is_inf | pad, 0.0, val * b_min)
    c_max = jnp.where(max_is_inf | pad, 0.0, val * b_max)

    # Row aggregates entirely in VMEM (the paper's shared-memory reuse).
    rmf = c_min.sum(axis=-1, keepdims=True)
    rxf = c_max.sum(axis=-1, keepdims=True)
    rmc = min_is_inf.astype(jnp.int32).sum(axis=-1, keepdims=True)
    rxc = max_is_inf.astype(jnp.int32).sum(axis=-1, keepdims=True)

    min_res = jnp.where(
        min_is_inf,
        jnp.where(rmc == 1, rmf, -inf),
        jnp.where(rmc == 0, rmf - c_min, -inf),
    )
    max_res = jnp.where(
        max_is_inf,
        jnp.where(rxc == 1, rxf, inf),
        jnp.where(rxc == 0, rxf - c_max, inf),
    )

    lhs_b = lhs_ref[...][..., None]
    rhs_b = rhs_ref[...][..., None]
    safe_a = jnp.where(pad, 1.0, val)
    num_l = jnp.where(pos, lhs_b - max_res, rhs_b - min_res)
    num_u = jnp.where(pos, rhs_b - min_res, lhs_b - max_res)
    lcand = num_l / safe_a
    ucand = num_u / safe_a
    valid_l = (
        jnp.where(pos, (lhs_b > -inf) & (max_res < inf), (rhs_b < inf) & (min_res > -inf))
        & ~pad
    )
    valid_u = (
        jnp.where(pos, (rhs_b < inf) & (min_res > -inf), (lhs_b > -inf) & (max_res < inf))
        & ~pad
    )
    lcand = jnp.where(valid_l, jnp.clip(lcand, -inf, inf), -inf)
    ucand = jnp.where(valid_u, jnp.clip(ucand, -inf, inf), inf)

    is_int_g = ii_ref[...] != 0
    do_l = is_int_g & (jnp.abs(lcand) < inf)
    do_u = is_int_g & (jnp.abs(ucand) < inf)
    lc_ref[...] = jnp.where(do_l, jnp.ceil(lcand - int_eps), lcand)
    uc_ref[...] = jnp.where(do_u, jnp.floor(ucand + int_eps), ucand)


def fused_round_tiles(
    val,
    lb_g,
    ub_g,
    is_int_g,
    lhs_g,
    rhs_g,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
):
    """Alg.-3-faithful fused tile round. Requires max row length <= K."""
    if interpret is None:
        interpret = _on_cpu()
    t, r, k = val.shape
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i: (i, 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((t, r, k), dtype),
        jax.ShapeDtypeStruct((t, r, k), dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(_fused_round_kernel, int_eps=int_eps, inf=inf),
        grid=(t,),
        in_specs=[tile, tile, tile, tile, row_tile, row_tile],
        out_specs=[tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(val, lb_g, ub_g, is_int_g.astype(jnp.int32), lhs_g, rhs_g)
