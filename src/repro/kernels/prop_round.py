"""Pallas TPU kernels for the fused propagation round (paper Alg. 3).

TPU adaptation of CSR-adaptive (DESIGN.md §2): the matrix is stored as
length-bucketed block-ELL tiles of shape (R, K) = (tile_rows, tile_width).
On the target (TPU v5e) K=128 matches the lane width and R=8 the sublane
count, so a tile is exactly one VREG-aligned VMEM block; grid steps pipeline
HBM->VMEM DMAs of consecutive tiles.

Kernel inventory
----------------

Split-phase kernels (general case, long rows span chunks):

  * ``_activities_kernel``  -- per-chunk activity partials + inf counters
                               (CSR-stream/CSR-vector unified: long rows span
                               chunks, partials are segment-combined outside).
  * ``_candidates_kernel``  -- residual activities (§3.4 single-infinity
                               rule) + bound candidates (Eqs. 4/5) +
                               integrality rounding, given completed row
                               aggregates gathered per chunk.
  * ``_fused_round_kernel`` -- Alg.-3-faithful fusion of both phases for the
                               common case where every row fits in one chunk
                               (activities stay in VMEM and are reused
                               immediately -- the shared-memory trick).

Fully fused scatter kernels (the zero-HBM-tensor round engine):

  * ``_fused_scatter_kernel``      -- bound gather + activities + candidates
        + column-wise best-bound reduction in ONE kernel.  The bound vectors
        and the ``(2, n_pad)`` best-bound accumulators live in VMEM and are
        revisited by every grid step (the TPU grid is sequential, so a block
        whose index map is constant acts as an on-chip reduction buffer);
        neither the gathered bounds nor the candidates EVER touch HBM.  The
        column scatter is the atomic-free replacement for the paper's
        atomicMax/atomicMin: a lane-blocked one-hot compare-and-reduce
        against each 128-wide column block (see ``_scatter_tile``); the
        gather is its exact dual (see ``_gather_bounds_tile``).
  * ``_activities_gather_kernel``  -- activity partials with the in-kernel
        bound gather, for rows spanning several chunks (partials are
        segment-combined outside, they are only (T, R)-sized).
  * ``_candidates_scatter_kernel`` -- same fused gather+scatter, but
        candidates are computed from completed row aggregates gathered per
        chunk (rows that span several chunks; the CSR-vector analogue).
  * ``_apply_updates_kernel``      -- the small merge kernel: folds the
        accumulated best bounds into (lb, ub) with the shared
        ``bounds.apply_updates`` semantics.  ``input_output_aliases`` donates
        the bound buffers so the fixed-point loop updates bounds in place.

Slab-parallel partitioned kernels (``n_pad > SCATTER_MAX_NPAD``):

  * ``_batched_slab_round_kernel`` / ``_node_slab_round_kernel`` -- the
        fused round over a column-slab partition (``ops.build_slab_partition``)
        on a 2D ``(run, tile)`` grid: one run per ``(instance, slab)``
        window, best-bound accumulators in per-run VMEM scratch, and the
        bound merge folded into the run's last step so no partial plane
        round-trips through HBM.  The run axis is declared ``parallel``.
  * ``_batched_slab_partials_kernel`` / ``_node_slab_partials_kernel`` --
        activity partials for the few STRADDLE rows whose nonzeros are
        split across slab copies (completed by a tiny segment sum outside).
  * ``_apply_updates_slab_kernel``  -- standalone slab-windowed merge
        (kept for callers composing their own partitioned pipelines; the
        round kernels above merge in place themselves).

In the fused engine the irregular gather itself moves into the kernels
(``_gather_bounds_tile``): the bound vectors ride along as VMEM-resident
``(1, n_pad)`` blocks, so no nnz-proportional tensor exists in HBM at all
during a round -- per grid step HBM only streams the tile's static matrix
data.  Kernels are validated on CPU via ``interpret=True`` against
``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import bounds as bnd
# col_pad moved to core.sparse with the batch packing; re-exported here (the
# redundant alias marks the intentional re-export) for kernel-level callers.
from ..core.sparse import LANE as LANE, col_pad as col_pad
from ..core.types import INF, int_round_slack


def _on_cpu() -> bool:
    return jax.default_backend() != "tpu"


def _int_operand(x):
    """Integer pallas_call operand: bools widen to int32, integer dtypes
    pass through unchanged -- compact low-precision index streams (int16
    cols / int8 integrality marks) must reach the kernel narrow, since an
    entry-point widening would materialize an int32 copy at the HBM
    boundary and forfeit the tier's byte savings."""
    x = jnp.asarray(x)
    return x.astype(jnp.int32) if x.dtype == jnp.bool_ else x


# ---------------------------------------------------------------------------
# Shared tile math (used by every kernel AND by the jnp oracles in ref.py)
# ---------------------------------------------------------------------------


def tile_contributions(val, lb_g, ub_g, inf):
    """Per-nonzero activity contributions of one (or many) (.., R, K) tiles.

    Returns (pos, pad, min_is_inf, max_is_inf, c_min, c_max)."""
    pos = val > 0
    pad = val == 0
    b_min = jnp.where(pos, lb_g, ub_g)
    b_max = jnp.where(pos, ub_g, lb_g)
    min_is_inf = (jnp.abs(b_min) >= inf) & ~pad
    max_is_inf = (jnp.abs(b_max) >= inf) & ~pad
    c_min = jnp.where(min_is_inf | pad, 0.0, val * b_min)
    c_max = jnp.where(max_is_inf | pad, 0.0, val * b_max)
    return pos, pad, min_is_inf, max_is_inf, c_min, c_max


def tile_candidates(
    val,
    lb_g,
    ub_g,
    is_int_g,
    row_min_fin,
    row_min_cnt,
    row_max_fin,
    row_max_cnt,
    lhs,
    rhs,
    int_eps,
    inf,
):
    """Residual activities (§3.4 single-infinity rule) + bound candidates
    (Eqs. 4/5) + integrality rounding.  Row aggregates / sides are (.., R)
    and broadcast over the K axis.  Pure jnp: callable inside kernels.

    Candidates use the division-first form ``(side - row_sum) / a + bound``
    rather than dividing the residual ``row_sum - a * bound``: the two are
    algebraically equal, but the residual form multiplies into a
    subtraction, which CPU/LLVM backends contract into an FMA in some
    compilation contexts (inside a fused Pallas kernel) and not others
    (the op-by-op oracle), breaking bitwise kernel-vs-oracle equality in
    the last mantissa bit.  The division-first chain (sub, div, add) has
    no contractible pattern, so every context rounds identically."""
    pos, pad, min_is_inf, max_is_inf, _, _ = tile_contributions(
        val, lb_g, ub_g, inf
    )
    rmf = row_min_fin[..., None]
    rmc = row_min_cnt[..., None]
    rxf = row_max_fin[..., None]
    rxc = row_max_cnt[..., None]
    lhs_b = lhs[..., None]
    rhs_b = rhs[..., None]

    # Residual usable at this entry (§3.4): all contributions finite and
    # the row sum complete (cnt == 0), or exactly this entry's bound
    # infinite so the sum over the others IS the residual (cnt == 1).
    ok_min = jnp.where(min_is_inf, rmc == 1, rmc == 0)
    ok_max = jnp.where(max_is_inf, rxc == 1, rxc == 0)
    # This entry's own bound, folded back in candidate space (0 when the
    # entry's contribution was never part of the finite sum).
    b_min = jnp.where(pos, lb_g, ub_g)
    b_max = jnp.where(pos, ub_g, lb_g)
    inc_min = jnp.where(min_is_inf | pad, 0.0, b_min)
    inc_max = jnp.where(max_is_inf | pad, 0.0, b_max)

    safe_a = jnp.where(pad, 1.0, val)
    q_min = (rhs_b - rmf) / safe_a + inc_min
    q_max = (lhs_b - rxf) / safe_a + inc_max
    lcand = jnp.where(pos, q_max, q_min)
    ucand = jnp.where(pos, q_min, q_max)

    valid_l = (
        jnp.where(pos, (lhs_b > -inf) & ok_max, (rhs_b < inf) & ok_min)
        & ~pad
    )
    valid_u = (
        jnp.where(pos, (rhs_b < inf) & ok_min, (lhs_b > -inf) & ok_max)
        & ~pad
    )
    lcand = jnp.where(valid_l, jnp.clip(lcand, -inf, inf), -inf)
    ucand = jnp.where(valid_u, jnp.clip(ucand, -inf, inf), inf)

    do_l = is_int_g & (jnp.abs(lcand) < inf)
    do_u = is_int_g & (jnp.abs(ucand) < inf)
    # Low-precision tiers widen the integrality rounding by the dtype's
    # scale-aware slack (see core.types.int_round_slack): ceil/floor are
    # discontinuous, so tier arithmetic error must not cross an integer.
    slack = int_round_slack(jnp.result_type(lcand))
    sl = su = int_eps
    if slack:  # static per dtype: fp64 keeps the exact scalar subtraction
        sl = int_eps + slack * jnp.maximum(1.0, jnp.abs(lcand))
        su = int_eps + slack * jnp.maximum(1.0, jnp.abs(ucand))
    lcand = jnp.where(do_l, jnp.ceil(lcand - sl), lcand)
    ucand = jnp.where(do_u, jnp.floor(ucand + su), ucand)
    return lcand, ucand


def tile_row_aggregates(val, lb_g, ub_g, inf):
    """In-register row aggregates of a chunk-complete tile (.., R)."""
    _, _, min_is_inf, max_is_inf, c_min, c_max = tile_contributions(
        val, lb_g, ub_g, inf
    )
    rmf = c_min.sum(axis=-1)
    rxf = c_max.sum(axis=-1)
    rmc = min_is_inf.sum(axis=-1, dtype=jnp.int32)
    rxc = max_is_inf.sum(axis=-1, dtype=jnp.int32)
    return rmf, rmc, rxf, rxc


# ---------------------------------------------------------------------------
# Kernel A: activity partials
# ---------------------------------------------------------------------------


def _activities_kernel(val_ref, lb_ref, ub_ref, mf_ref, mc_ref, xf_ref, xc_ref, *, inf):
    # (1, R, K) VMEM blocks -> (1, R) per-chunk partials.
    rmf, rmc, rxf, rxc = tile_row_aggregates(val_ref[...], lb_ref[...], ub_ref[...], inf)
    mf_ref[...] = rmf
    mc_ref[...] = rmc
    xf_ref[...] = rxf
    xc_ref[...] = rxc


def activities_tiles(val, lb_g, ub_g, inf: float = INF, interpret: bool | None = None):
    """Pallas-backed per-chunk activity partials. Shapes: (T, R, K) -> (T, R)."""
    if interpret is None:
        interpret = _on_cpu()
    t, r, k = val.shape
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i: (i, 0, 0))
    out_tile = pl.BlockSpec((1, r), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((t, r), dtype),
        jax.ShapeDtypeStruct((t, r), jnp.int32),
        jax.ShapeDtypeStruct((t, r), dtype),
        jax.ShapeDtypeStruct((t, r), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(_activities_kernel, inf=inf),
        grid=(t,),
        in_specs=[tile, tile, tile],
        out_specs=[out_tile, out_tile, out_tile, out_tile],
        out_shape=out_shape,
        interpret=interpret,
    )
    mf, mc, xf, xc = fn(val, lb_g, ub_g)
    return mf, mc, xf, xc


def _activities_gather_kernel(
    val_ref, col_ref, lb_ref, ub_ref, mf_ref, mc_ref, xf_ref, xc_ref, *, inf, block
):
    """Kernel A': activity partials with the bound gather done in-kernel
    from the VMEM-resident (1, n_pad) bound vectors (no HBM-side gather)."""
    val = val_ref[...]
    r, k = val.shape[-2:]
    val = val.reshape(r, k)
    col = col_ref[...].reshape(r, k)
    lb_g, ub_g = _gather_bounds_tile(col, lb_ref, ub_ref, block=block)
    rmf, rmc, rxf, rxc = tile_row_aggregates(val, lb_g, ub_g, inf)
    mf_ref[...] = rmf.reshape(1, r)
    mc_ref[...] = rmc.reshape(1, r)
    xf_ref[...] = rxf.reshape(1, r)
    xc_ref[...] = rxc.reshape(1, r)


def activities_gather_tiles(
    val,
    col,
    lb,
    ub,
    n_pad: int,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
):
    """Per-chunk activity partials with in-kernel bound gather.

    (T, R, K) tiles + (n_pad,) bounds -> 4 x (T, R); the gathered-bound
    tensors never exist in HBM."""
    if interpret is None:
        interpret = _on_cpu()
    if n_pad % block:
        raise ValueError(f"n_pad={n_pad} must be a multiple of block={block}")
    t, r, k = val.shape
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i: (i, 0, 0))
    vec = pl.BlockSpec((1, n_pad), lambda i: (0, 0))
    out_tile = pl.BlockSpec((1, r), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((t, r), dtype),
        jax.ShapeDtypeStruct((t, r), jnp.int32),
        jax.ShapeDtypeStruct((t, r), dtype),
        jax.ShapeDtypeStruct((t, r), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(_activities_gather_kernel, inf=inf, block=block),
        grid=(t,),
        in_specs=[tile, tile, vec, vec],
        out_specs=[out_tile, out_tile, out_tile, out_tile],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(val, col, lb.reshape(1, n_pad), ub.reshape(1, n_pad))


# ---------------------------------------------------------------------------
# Kernel B: candidates from completed row aggregates
# ---------------------------------------------------------------------------


def _candidates_kernel(
    val_ref,
    lb_ref,
    ub_ref,
    ii_ref,
    rmf_ref,
    rmc_ref,
    rxf_ref,
    rxc_ref,
    lhs_ref,
    rhs_ref,
    lc_ref,
    uc_ref,
    *,
    int_eps,
    inf,
):
    lc_ref[...], uc_ref[...] = tile_candidates(
        val_ref[...],
        lb_ref[...],
        ub_ref[...],
        ii_ref[...] != 0,
        rmf_ref[...],
        rmc_ref[...],
        rxf_ref[...],
        rxc_ref[...],
        lhs_ref[...],
        rhs_ref[...],
        int_eps,
        inf,
    )


def candidates_tiles(
    val,
    lb_g,
    ub_g,
    is_int_g,
    row_min_fin,
    row_min_cnt,
    row_max_fin,
    row_max_cnt,
    lhs_g,
    rhs_g,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
):
    """Pallas-backed candidates. (T,R,K) tiles + (T,R) row data -> (T,R,K) x2."""
    if interpret is None:
        interpret = _on_cpu()
    t, r, k = val.shape
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i: (i, 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((t, r, k), dtype),
        jax.ShapeDtypeStruct((t, r, k), dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(_candidates_kernel, int_eps=int_eps, inf=inf),
        grid=(t,),
        in_specs=[tile, tile, tile, tile, row_tile, row_tile, row_tile, row_tile, row_tile, row_tile],
        out_specs=[tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(
        val,
        lb_g,
        ub_g,
        _int_operand(is_int_g),
        row_min_fin,
        row_min_cnt,
        row_max_fin,
        row_max_cnt,
        lhs_g,
        rhs_g,
    )


# ---------------------------------------------------------------------------
# Kernel C: fused round (rows complete within one chunk)
# ---------------------------------------------------------------------------


def _fused_round_kernel(
    val_ref, lb_ref, ub_ref, ii_ref, lhs_ref, rhs_ref, lc_ref, uc_ref, *, int_eps, inf
):
    val = val_ref[...]
    lb_g = lb_ref[...]
    ub_g = ub_ref[...]
    # Row aggregates entirely in VMEM (the paper's shared-memory reuse).
    rmf, rmc, rxf, rxc = tile_row_aggregates(val, lb_g, ub_g, inf)
    lc_ref[...], uc_ref[...] = tile_candidates(
        val, lb_g, ub_g, ii_ref[...] != 0,
        rmf, rmc, rxf, rxc, lhs_ref[...], rhs_ref[...], int_eps, inf,
    )


def fused_round_tiles(
    val,
    lb_g,
    ub_g,
    is_int_g,
    lhs_g,
    rhs_g,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
):
    """Alg.-3-faithful fused tile round. Requires max row length <= K."""
    if interpret is None:
        interpret = _on_cpu()
    t, r, k = val.shape
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i: (i, 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((t, r, k), dtype),
        jax.ShapeDtypeStruct((t, r, k), dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(_fused_round_kernel, int_eps=int_eps, inf=inf),
        grid=(t,),
        in_specs=[tile, tile, tile, tile, row_tile, row_tile],
        out_specs=[tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(val, lb_g, ub_g, _int_operand(is_int_g), lhs_g, rhs_g)


# ---------------------------------------------------------------------------
# Kernels D/E: fused column scatter -- candidates never leave VMEM
# ---------------------------------------------------------------------------


def _scatter_tile(lcand, ucand, col, bl_ref, bu_ref, *, inf, block):
    """Column-wise max/min merge of one (1, R, K) candidate tile into the
    (1, n_pad) best-bound accumulators resident in VMEM.

    The scatter is expressed as a lane-blocked one-hot reduction: for each
    aligned ``block``-wide column window, compare column ids against the
    window's lanes, reduce hits, and combine into the accumulator window.
    The slot axis is walked one sublane row at a time (inner loop over R) so
    the one-hot working set is a single (K, block) VREG-sized mask instead
    of an (R*K, block) buffer.  max/min are associative and commutative, so
    the result is bit-identical to a global segment reduction regardless of
    tile or visit order.  Padding slots carry sentinel candidates
    (-inf/+inf) and are absorbed as reduction identity.
    """
    r, k = lcand.shape[-2], lcand.shape[-1]
    lc = lcand.reshape(r, k)
    uc = ucand.reshape(r, k)
    cc = col.reshape(r, k)
    n_pad = bl_ref.shape[-1]
    dtype = lc.dtype

    def col_block(j, carry):
        base = j * block
        lanes = base + jax.lax.broadcasted_iota(jnp.int32, (k, block), 1)

        def row_step(i, best):
            best_l, best_u = best
            ci = jax.lax.dynamic_slice_in_dim(cc, i, 1, 0).reshape(k)
            li = jax.lax.dynamic_slice_in_dim(lc, i, 1, 0).reshape(k)
            ui = jax.lax.dynamic_slice_in_dim(uc, i, 1, 0).reshape(k)
            hit = ci[:, None] == lanes
            best_l = jnp.maximum(best_l, jnp.where(hit, li[:, None], -inf).max(axis=0))
            best_u = jnp.minimum(best_u, jnp.where(hit, ui[:, None], inf).min(axis=0))
            return best_l, best_u

        best_l, best_u = jax.lax.fori_loop(
            0,
            r,
            row_step,
            (jnp.full((block,), -inf, dtype), jnp.full((block,), inf, dtype)),
        )
        bl_ref[0, pl.ds(base, block)] = jnp.maximum(
            bl_ref[0, pl.ds(base, block)], best_l
        )
        bu_ref[0, pl.ds(base, block)] = jnp.minimum(
            bu_ref[0, pl.ds(base, block)], best_u
        )
        return carry

    jax.lax.fori_loop(0, n_pad // block, col_block, 0)


def _init_accumulators(bl_ref, bu_ref, inf):
    @pl.when(pl.program_id(0) == 0)
    def _():
        bl_ref[...] = jnp.full_like(bl_ref[...], -inf)
        bu_ref[...] = jnp.full_like(bu_ref[...], inf)


def _gather_bounds_tile(col, lb_ref, ub_ref, *, block):
    """In-kernel bound gather: reconstruct (lb, ub) at each tile slot from
    the (1, n_pad) bound vectors resident in VMEM.

    Dual of ``_scatter_tile``: for each aligned ``block``-wide column
    window, one-hot-select the window's bound lanes into the matching slots
    and accumulate by sum -- every slot's column id matches exactly one lane
    of exactly one window, so the sum has a single nonzero term and the
    gather is exact.  This removes the per-round XLA gather entirely: the
    (T, R, K) gathered-bound tensors never exist in HBM.
    """
    r, k = col.shape
    n_pad = lb_ref.shape[-1]
    dtype = lb_ref.dtype

    def row(i, acc):
        lbg, ubg = acc
        ci = jax.lax.dynamic_slice_in_dim(col, i, 1, 0).reshape(k)

        def win(j, rowacc):
            gl, gu = rowacc
            base = j * block
            lanes = base + jax.lax.broadcasted_iota(jnp.int32, (k, block), 1)
            hit = ci[:, None] == lanes
            lb_w = lb_ref[0, pl.ds(base, block)]
            ub_w = ub_ref[0, pl.ds(base, block)]
            gl = gl + jnp.where(hit, lb_w[None, :], 0.0).sum(axis=1)[None]
            gu = gu + jnp.where(hit, ub_w[None, :], 0.0).sum(axis=1)[None]
            return gl, gu

        gl, gu = jax.lax.fori_loop(
            0,
            n_pad // block,
            win,
            (jnp.zeros((1, k), dtype), jnp.zeros((1, k), dtype)),
        )
        lbg = jax.lax.dynamic_update_slice_in_dim(lbg, gl, i, 0)
        ubg = jax.lax.dynamic_update_slice_in_dim(ubg, gu, i, 0)
        return lbg, ubg

    return jax.lax.fori_loop(
        0, r, row, (jnp.zeros((r, k), dtype), jnp.zeros((r, k), dtype))
    )


def _fused_scatter_kernel(
    val_ref, col_ref, ii_ref, lhs_ref, rhs_ref, lb_ref, ub_ref,
    bl_ref, bu_ref, *, int_eps, inf, block,
):
    """Kernel D: the whole round for chunk-complete rows.  Bound gather,
    activities, residuals, candidates AND the column-wise best-bound
    reduction happen in VMEM; per grid step HBM only streams the tile's
    matrix data (val, col, is_int) -- the bound vectors and the (2, n_pad)
    accumulators stay resident across all steps."""
    _init_accumulators(bl_ref, bu_ref, inf)
    val = val_ref[...]
    r, k = val.shape[-2:]
    val = val.reshape(r, k)
    col = col_ref[...].reshape(r, k)
    lb_g, ub_g = _gather_bounds_tile(col, lb_ref, ub_ref, block=block)
    rmf, rmc, rxf, rxc = tile_row_aggregates(val, lb_g, ub_g, inf)
    lcand, ucand = tile_candidates(
        val, lb_g, ub_g, ii_ref[...].reshape(r, k) != 0,
        rmf, rmc, rxf, rxc,
        lhs_ref[...].reshape(r), rhs_ref[...].reshape(r), int_eps, inf,
    )
    _scatter_tile(lcand, ucand, col, bl_ref, bu_ref, inf=inf, block=block)


def fused_scatter_round_tiles(
    val,
    col,
    is_int_g,
    lhs_g,
    rhs_g,
    lb,
    ub,
    n_pad: int,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
):
    """Fully fused round: (T, R, K) tiles + (n_pad,) bounds -> (n_pad,)
    best_l / best_u.

    Neither the gathered-bound nor the candidate tensors ever materialize
    in HBM.  Requires max row length <= K (rows complete within their
    chunk) and n_pad % block == 0."""
    if interpret is None:
        interpret = _on_cpu()
    if n_pad % block:
        raise ValueError(f"n_pad={n_pad} must be a multiple of block={block}")
    t, r, k = val.shape
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i: (i, 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda i: (i, 0))
    vec = pl.BlockSpec((1, n_pad), lambda i: (0, 0))  # resident every step
    out_shape = [
        jax.ShapeDtypeStruct((1, n_pad), dtype),
        jax.ShapeDtypeStruct((1, n_pad), dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(_fused_scatter_kernel, int_eps=int_eps, inf=inf, block=block),
        grid=(t,),
        in_specs=[tile, tile, tile, row_tile, row_tile, vec, vec],
        out_specs=[vec, vec],
        out_shape=out_shape,
        interpret=interpret,
    )
    best_l, best_u = fn(
        val, col, _int_operand(is_int_g), lhs_g, rhs_g,
        lb.reshape(1, n_pad), ub.reshape(1, n_pad),
    )
    return best_l.reshape(n_pad), best_u.reshape(n_pad)


def _candidates_scatter_kernel(
    val_ref, col_ref, ii_ref,
    rmf_ref, rmc_ref, rxf_ref, rxc_ref, lhs_ref, rhs_ref,
    lb_ref, ub_ref, bl_ref, bu_ref, *, int_eps, inf, block,
):
    """Kernel E: in-kernel bound gather + candidates from completed row
    aggregates + in-VMEM column scatter (rows spanning several chunks;
    aggregates combined outside)."""
    _init_accumulators(bl_ref, bu_ref, inf)
    val = val_ref[...]
    r, k = val.shape[-2:]
    val = val.reshape(r, k)
    col = col_ref[...].reshape(r, k)
    lb_g, ub_g = _gather_bounds_tile(col, lb_ref, ub_ref, block=block)
    lcand, ucand = tile_candidates(
        val, lb_g, ub_g, ii_ref[...].reshape(r, k) != 0,
        rmf_ref[...].reshape(r), rmc_ref[...].reshape(r),
        rxf_ref[...].reshape(r), rxc_ref[...].reshape(r),
        lhs_ref[...].reshape(r), rhs_ref[...].reshape(r), int_eps, inf,
    )
    _scatter_tile(lcand, ucand, col, bl_ref, bu_ref, inf=inf, block=block)


def candidates_scatter_tiles(
    val,
    col,
    is_int_g,
    row_min_fin,
    row_min_cnt,
    row_max_fin,
    row_max_cnt,
    lhs_g,
    rhs_g,
    lb,
    ub,
    n_pad: int,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
):
    """Candidates + fused column reduction: (T, R, K) tiles + (T, R) row
    aggregates + (n_pad,) bounds -> (n_pad,) x2.  Neither the gathered
    bounds nor the candidates ever materialize in HBM."""
    if interpret is None:
        interpret = _on_cpu()
    if n_pad % block:
        raise ValueError(f"n_pad={n_pad} must be a multiple of block={block}")
    t, r, k = val.shape
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i: (i, 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda i: (i, 0))
    vec = pl.BlockSpec((1, n_pad), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((1, n_pad), dtype),
        jax.ShapeDtypeStruct((1, n_pad), dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(
            _candidates_scatter_kernel, int_eps=int_eps, inf=inf, block=block
        ),
        grid=(t,),
        in_specs=[tile, tile, tile,
                  row_tile, row_tile, row_tile, row_tile, row_tile, row_tile,
                  vec, vec],
        out_specs=[vec, vec],
        out_shape=out_shape,
        interpret=interpret,
    )
    best_l, best_u = fn(
        val, col, _int_operand(is_int_g),
        row_min_fin, row_min_cnt, row_max_fin, row_max_cnt, lhs_g, rhs_g,
        lb.reshape(1, n_pad), ub.reshape(1, n_pad),
    )
    return best_l.reshape(n_pad), best_u.reshape(n_pad)


# ---------------------------------------------------------------------------
# Kernel F: merge -- fold best bounds into (lb, ub) in place
# ---------------------------------------------------------------------------


def _apply_updates_kernel(
    lb_ref, ub_ref, bl_ref, bu_ref, nlb_ref, nub_ref, ch_ref, *, eps, inf, outward
):
    new_lb, new_ub, changed = bnd.apply_updates(
        lb_ref[...], ub_ref[...], bl_ref[...], bu_ref[...], eps, inf, outward
    )
    nlb_ref[...] = new_lb
    nub_ref[...] = new_ub
    ch_ref[...] = changed.astype(jnp.int32).reshape(1, 1)


def apply_updates_tiles(
    lb,
    ub,
    best_l,
    best_u,
    eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    outward: float = 0.0,
):
    """Pallas merge kernel: (n_pad,) bounds x best candidates -> updated
    bounds + changed flag.  The bound buffers are donated
    (``input_output_aliases``) so the update is in place on device.

    Shares ``bounds.apply_updates`` with every other engine, so all paths
    converge to identical fixed points by construction; ``outward`` is the
    fp32-tier safety widening (0.0 = exact fp64 merge)."""
    if interpret is None:
        interpret = _on_cpu()
    (n_pad,) = lb.shape
    dtype = lb.dtype
    vec = pl.BlockSpec((1, n_pad), lambda: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((1, n_pad), dtype),
        jax.ShapeDtypeStruct((1, n_pad), dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(_apply_updates_kernel, eps=eps, inf=inf, outward=outward),
        in_specs=[vec, vec, vec, vec],
        out_specs=[vec, vec, pl.BlockSpec((1, 1), lambda: (0, 0))],
        out_shape=out_shape,
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )
    r2 = lambda x: x.reshape(1, n_pad)
    new_lb, new_ub, changed = fn(r2(lb), r2(ub), r2(best_l), r2(best_u))
    return new_lb.reshape(n_pad), new_ub.reshape(n_pad), changed.reshape(()) != 0


# ---------------------------------------------------------------------------
# Batched kernels: flat super-tile grid + per-instance convergence mask
# ---------------------------------------------------------------------------


def _batched_fused_scatter_kernel(
    inst_ref, act_ref,
    val_ref, col_ref, ii_ref, lhs_ref, rhs_ref, lb_ref, ub_ref,
    bl_ref, bu_ref, *, int_eps, inf, block,
):
    """Kernel D over a packed batch: the grid walks the flat tile stream
    and the scalar-prefetched ``tile_inst`` map routes every block.

    The batch lives in the leading dimension of the ``(B, n_pad)`` bound
    plane and accumulators; each tile's blocks are selected by its
    instance id (``inst_ref``), so instance boundaries are where the
    resident accumulator block is flushed/reloaded -- tiles of one
    instance are contiguous by construction, giving each instance exactly
    one flush, like the single-instance kernel.  ``act_ref`` is the
    per-instance convergence mask: a converged instance's tiles skip
    gather/compute/scatter entirely (their accumulators stay at the
    reduction identity, so the merge kernel reports them unchanged) --
    finished instances become no-ops instead of blocking the batch.

    The continuous-batching service (``repro.core.service``) reuses this
    same mask as its SLOT-OCCUPANCY mask: an empty or retired slot is
    simply an inactive instance, so its tiles skip all compute and its
    stale accumulator rows stay at the identity.  No separate "empty
    slot" machinery exists in the kernel.
    """
    i = pl.program_id(0)
    inst = inst_ref[i]
    first = jnp.where(i == 0, True, inst_ref[jnp.maximum(i - 1, 0)] != inst)

    @pl.when(first)
    def _():
        bl_ref[...] = jnp.full_like(bl_ref[...], -inf)
        bu_ref[...] = jnp.full_like(bu_ref[...], inf)

    @pl.when(act_ref[inst] != 0)
    def _():
        val = val_ref[...]
        r, k = val.shape[-2:]
        val = val.reshape(r, k)
        col = col_ref[...].reshape(r, k)
        lb_g, ub_g = _gather_bounds_tile(col, lb_ref, ub_ref, block=block)
        rmf, rmc, rxf, rxc = tile_row_aggregates(val, lb_g, ub_g, inf)
        lcand, ucand = tile_candidates(
            val, lb_g, ub_g, ii_ref[...].reshape(r, k) != 0,
            rmf, rmc, rxf, rxc,
            lhs_ref[...].reshape(r), rhs_ref[...].reshape(r), int_eps, inf,
        )
        _scatter_tile(lcand, ucand, col, bl_ref, bu_ref, inf=inf, block=block)


def batched_fused_scatter_round_tiles(
    val,
    col,
    is_int_g,
    lhs_g,
    rhs_g,
    lb,
    ub,
    tile_inst,
    active,
    n_pad: int,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
):
    """Fully fused round over a packed batch: ``(T, R, K)`` flat tile
    stream (instance-local columns) + ``(B, n_pad)`` bound plane + ``(T,)``
    tile->instance map + ``(B,)`` active mask -> ``(B, n_pad)`` best_l /
    best_u.

    Same per-instance semantics as :func:`fused_scatter_round_tiles`
    (requires every row of every instance to fit one chunk); inactive
    instances produce identity accumulator rows.  ``active`` doubles as
    the propagation service's slot-occupancy mask -- see
    :func:`batched_occupancy_round_tiles`."""
    if interpret is None:
        interpret = _on_cpu()
    if n_pad % block:
        raise ValueError(f"n_pad={n_pad} must be a multiple of block={block}")
    from jax.experimental.pallas import tpu as pltpu

    t, r, k = val.shape
    bsz = lb.shape[0]
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda i, inst, act: (i, 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda i, inst, act: (i, 0))
    vec = pl.BlockSpec((1, n_pad), lambda i, inst, act: (inst[i], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t,),
        in_specs=[tile, tile, tile, row_tile, row_tile, vec, vec],
        out_specs=[vec, vec],
    )
    out_shape = [
        jax.ShapeDtypeStruct((bsz, n_pad), dtype),
        jax.ShapeDtypeStruct((bsz, n_pad), dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(
            _batched_fused_scatter_kernel, int_eps=int_eps, inf=inf, block=block
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(
        tile_inst.astype(jnp.int32), active.astype(jnp.int32),
        val, col, _int_operand(is_int_g), lhs_g, rhs_g, lb, ub,
    )


def batched_occupancy_round_tiles(
    val,
    col,
    is_int_g,
    lhs_g,
    rhs_g,
    lb,
    ub,
    tile_inst,
    occupied,
    n_pad: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
    outward: float = 0.0,
):
    """One full occupancy-masked round (candidates + scatter + merge) over a
    slot-resident super-tile: ``(S*T, R, K)`` tile stream, ``(S, n_pad)``
    bound plane, ``(S,)`` ``occupied`` mask -> updated bounds + per-slot
    ``changed`` flags.

    This is the round the continuous-batching service runs on its kernel
    path.  ``occupied`` is the per-slot occupancy mask (an alias of the
    batched kernels' ``active`` mask): free or retired slots cost no
    gather/compute/scatter in the round kernel and pass through the merge
    untouched, so admission and retirement never have to compact or
    re-shape the resident state.  Requires the fused-path contract (every
    row fits one chunk of width ``block``); multichunk buckets use the jnp
    reference round instead."""
    best_l, best_u = batched_fused_scatter_round_tiles(
        val, col, is_int_g, lhs_g, rhs_g, lb, ub, tile_inst, occupied,
        n_pad, int_eps, inf, interpret, block,
    )
    return apply_updates_batch_tiles(
        lb, ub, best_l, best_u, occupied, eps, inf, interpret, outward
    )


# ---------------------------------------------------------------------------
# Node-batch kernel: one matrix, many bound planes (tree-search shape)
# ---------------------------------------------------------------------------


def _node_fused_scatter_kernel(
    act_ref,
    val_ref, col_ref, ii_ref, lhs_ref, rhs_ref, lb_ref, ub_ref,
    bl_ref, bu_ref, *, int_eps, inf, block,
):
    """Kernel D over a node batch: B bound planes of ONE instance share the
    matrix tiles.

    The grid is ``(B, T)`` with the tile axis minor, so for each node the
    matrix tiles stream once while that node's ``(1, n_pad)`` bound block
    and accumulator rows stay VMEM-resident across its whole tile sweep --
    the matrix is revisited per node from on-device HBM, never re-packed or
    re-uploaded from the host.  ``act_ref`` is the per-node convergence
    mask: a converged (or pruned-infeasible) node's grid steps skip
    gather/compute/scatter entirely, leaving its accumulators at the
    reduction identity so the batched merge reports it unchanged.
    """
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _():
        bl_ref[...] = jnp.full_like(bl_ref[...], -inf)
        bu_ref[...] = jnp.full_like(bu_ref[...], inf)

    @pl.when(act_ref[b] != 0)
    def _():
        val = val_ref[...]
        r, k = val.shape[-2:]
        val = val.reshape(r, k)
        col = col_ref[...].reshape(r, k)
        lb_g, ub_g = _gather_bounds_tile(col, lb_ref, ub_ref, block=block)
        rmf, rmc, rxf, rxc = tile_row_aggregates(val, lb_g, ub_g, inf)
        lcand, ucand = tile_candidates(
            val, lb_g, ub_g, ii_ref[...].reshape(r, k) != 0,
            rmf, rmc, rxf, rxc,
            lhs_ref[...].reshape(r), rhs_ref[...].reshape(r), int_eps, inf,
        )
        _scatter_tile(lcand, ucand, col, bl_ref, bu_ref, inf=inf, block=block)


def node_fused_scatter_round_tiles(
    val,
    col,
    is_int_g,
    lhs_g,
    rhs_g,
    lb,
    ub,
    active,
    n_pad: int,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
):
    """Fully fused round over a node batch: ``(T, R, K)`` tiles of ONE
    instance, broadcast across the node axis, + ``(B, n_pad)`` per-node
    bound planes + ``(B,)`` active mask -> ``(B, n_pad)`` best_l / best_u.

    Per node the arithmetic is exactly :func:`fused_scatter_round_tiles`
    (requires every row to fit one chunk and ``n_pad % block == 0``);
    inactive nodes produce identity accumulator rows."""
    if interpret is None:
        interpret = _on_cpu()
    if n_pad % block:
        raise ValueError(f"n_pad={n_pad} must be a multiple of block={block}")
    from jax.experimental.pallas import tpu as pltpu

    t, r, k = val.shape
    bsz = lb.shape[0]
    dtype = val.dtype
    tile = pl.BlockSpec((1, r, k), lambda b, i, act: (i, 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda b, i, act: (i, 0))
    vec = pl.BlockSpec((1, n_pad), lambda b, i, act: (b, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, t),
        in_specs=[tile, tile, tile, row_tile, row_tile, vec, vec],
        out_specs=[vec, vec],
    )
    out_shape = [
        jax.ShapeDtypeStruct((bsz, n_pad), dtype),
        jax.ShapeDtypeStruct((bsz, n_pad), dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(
            _node_fused_scatter_kernel, int_eps=int_eps, inf=inf, block=block
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(
        active.astype(jnp.int32),
        val, col, _int_operand(is_int_g), lhs_g, rhs_g, lb, ub,
    )


# ---------------------------------------------------------------------------
# Column-slab partitioned kernels: VMEM-exceeding column spaces
# ---------------------------------------------------------------------------
#
# When ``n_pad`` outgrows the VMEM accumulator budget (``SCATTER_MAX_NPAD``)
# the resident ``(1, n_pad)`` bound/accumulator blocks of the fused kernels
# no longer fit on chip.  The partitioned engine keeps the fused dataflow by
# splitting the padded column space into ``slab``-wide windows and the CHUNK
# stream into per-slab copies grouped by ``(instance, slab)`` window
# (``ops.build_slab_partition``): a copy keeps only the nonzeros whose
# columns fall in its slab, so its in-kernel gather and scatter touch
# exactly one ``(1, S)`` bound window -- VMEM-resident across the window's
# whole tile run.
#
# The round kernels walk a 2D ``(run, tile)`` grid: the major axis is one
# step per ``(instance, slab)`` window (``run_*`` scalar-prefetch maps from
# the partition), the minor axis sweeps the window's copy tiles, padded to
# the longest run with idempotent revisits of the run's last tile.  The run
# axis carries no cross-step state -- the best-bound accumulators live in
# VMEM *scratch* re-initialized at each run's first step -- so it is
# declared ``parallel``: independent windows' reductions may run
# concurrently (on multiple cores) while each window's sweep stays ordered.
# Because every copy tile (including the duplicated straddling-tile copies)
# enters through BlockSpec index maps, Mosaic's grid pipeline
# double-buffers the HBM->VMEM copy stream automatically: step ``j+1``'s
# tile DMAs while step ``j`` computes, so duplication overlaps the
# reduction instead of preceding it.
#
# Rows whose nonzeros are split across copies cannot finish their activity
# aggregate inside any one copy.  Those STRADDLE rows ride a small
# sub-stream (``a_*``): ``*_slab_partials_tiles`` emits their per-copy
# partials, a tiny XLA segment sum completes them into a table, and the
# round kernel selects per row between its local in-register aggregate
# (``row_done == 1``, the vast majority) and the table value.  The round
# kernel then computes candidates, scatters them into the scratch
# accumulators, AND merges the window's bounds in place at the run's last
# step -- no partial best-bound plane ever round-trips through HBM.  The
# jnp oracle is ``ref.partitioned_round_ref`` over the SAME partition
# arrays, which the kernels match bitwise.


def _slab_compiler_params(interpret: bool, semantics: tuple):
    """``compiler_params`` declaring the grid's dimension semantics (the
    run/window axis ``parallel``, sweep axes ``arbitrary``) when compiling
    for a real TPU backend; empty under interpret mode or when this JAX
    build spells the params class differently."""
    if interpret:
        return {}
    from jax.experimental.pallas import tpu as pltpu

    cp = getattr(pltpu, "TPUCompilerParams", None) or getattr(
        pltpu, "CompilerParams", None
    )
    if cp is None:
        return {}
    try:
        return {"compiler_params": cp(dimension_semantics=semantics)}
    except TypeError:
        return {}


def _run_tile_index(j, st, ln, rr):
    """Copy-tile index of run ``rr`` at sweep step ``j``, clamped to the
    run's last tile: steps padding a short run to ``max_run_len`` revisit
    that tile (idempotent recompute) instead of reading out of range."""
    return st[rr] + jnp.minimum(j, ln[rr] - 1)


def _batched_slab_partials_kernel(
    st_ref, ln_ref, ri_ref, rs_ref, act_ref,
    val_ref, col_ref, lb_ref, ub_ref,
    mf_ref, mc_ref, xf_ref, xc_ref, *, inf, block,
):
    """Straddle-partials kernel over a slab-partitioned (optionally
    batched) sub-stream on the 2D ``(run, tile)`` grid.

    Each grid step computes ONE copy tile's per-row activity partials with
    the in-kernel gather from its window's resident ``(1, S)`` bound block
    (routed by the prefetched run maps).  Padded steps of short runs
    recompute the run's last tile -- same inputs, same outputs, harmless.
    Copies of converged instances write zero partials and skip the gather.
    """
    rr = pl.program_id(0)
    j = pl.program_id(1)
    act = act_ref[ri_ref[rr]] != 0

    @pl.when(act)
    def _():
        val = val_ref[...]
        r, k = val.shape[-2:]
        val = val.reshape(r, k)
        col = col_ref[...].reshape(r, k)
        lb_g, ub_g = _gather_bounds_tile(col, lb_ref, ub_ref, block=block)
        rmf, rmc, rxf, rxc = tile_row_aggregates(val, lb_g, ub_g, inf)
        mf_ref[...] = rmf.reshape(1, r)
        mc_ref[...] = rmc.reshape(1, r)
        xf_ref[...] = rxf.reshape(1, r)
        xc_ref[...] = rxc.reshape(1, r)

    @pl.when(~act)
    def _():
        mf_ref[...] = jnp.zeros_like(mf_ref[...])
        mc_ref[...] = jnp.zeros_like(mc_ref[...])
        xf_ref[...] = jnp.zeros_like(xf_ref[...])
        xc_ref[...] = jnp.zeros_like(xc_ref[...])


def batched_slab_partials_tiles(
    val,
    col_s,
    run_start,
    run_len,
    run_inst,
    run_slab,
    active,
    lb,
    ub,
    slab: int,
    max_run_len: int,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
):
    """Per-copy activity partials of a slab-partitioned sub-stream on the
    slab-parallel 2D grid.

    ``(Ta, R, K)`` slab-masked copies (slab-local columns) + the run maps
    (one entry per populated ``(instance, slab)`` window) + ``(B,
    n_pad_part)`` bound planes + ``(B,)`` active mask -> 4 x ``(Ta, R)``
    partials.  Single-instance callers pass ``B == 1`` planes with
    ``run_inst == 0``.  The gathered bounds never exist in HBM; each window
    reads only its resident ``(1, S)`` block, and independent windows are
    declared parallel."""
    if interpret is None:
        interpret = _on_cpu()
    if slab % block:
        raise ValueError(f"slab={slab} must be a multiple of block={block}")
    from jax.experimental.pallas import tpu as pltpu

    t, r, k = val.shape
    n_runs = run_start.shape[0]
    dtype = val.dtype
    copy = lambda rr, j, st, ln, ri, rs, act: _run_tile_index(j, st, ln, rr)
    tile = pl.BlockSpec((1, r, k), lambda rr, j, st, ln, ri, rs, act: (copy(rr, j, st, ln, ri, rs, act), 0, 0))
    out_tile = pl.BlockSpec((1, r), lambda rr, j, st, ln, ri, rs, act: (copy(rr, j, st, ln, ri, rs, act), 0))
    vec = pl.BlockSpec((1, slab), lambda rr, j, st, ln, ri, rs, act: (ri[rr], rs[rr]))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(n_runs, max_run_len),
        in_specs=[tile, tile, vec, vec],
        out_specs=[out_tile, out_tile, out_tile, out_tile],
    )
    out_shape = [
        jax.ShapeDtypeStruct((t, r), dtype),
        jax.ShapeDtypeStruct((t, r), jnp.int32),
        jax.ShapeDtypeStruct((t, r), dtype),
        jax.ShapeDtypeStruct((t, r), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(_batched_slab_partials_kernel, inf=inf, block=block),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        **_slab_compiler_params(interpret, ("parallel", "arbitrary")),
    )
    return fn(
        run_start.astype(jnp.int32), run_len.astype(jnp.int32),
        run_inst.astype(jnp.int32), run_slab.astype(jnp.int32),
        active.astype(jnp.int32),
        val, col_s, lb, ub,
    )


def _batched_slab_round_kernel(
    st_ref, ln_ref, ri_ref, rs_ref, act_ref,
    val_ref, col_ref, ii_ref, done_ref,
    smf_ref, smc_ref, sxf_ref, sxc_ref,
    lhs_ref, rhs_ref, lb_ref, ub_ref,
    nlb_ref, nub_ref, ch_ref,
    acc_l, acc_u, *, eps, int_eps, inf, outward, block,
):
    """The fused slab-parallel round kernel over a partitioned (optionally
    batched) stream on the 2D ``(run, tile)`` grid.

    One run == one ``(instance, slab)`` window.  Its sweep: (1) first step
    initializes the window's ``(1, S)`` best-bound accumulators, held in
    VMEM *scratch* so no partial plane exists in HBM; (2) every real step
    gathers bounds from the resident window, computes local row aggregates,
    swaps in the prefetched straddle aggregates where ``row_done == 0``,
    computes candidates and scatters them into the scratch; (3) the run's
    LAST real step merges the accumulators into the window's bounds in
    place (``bounds.apply_updates`` semantics) and emits the run's changed
    flag.  Padded steps recompute the last tile (idempotent) and re-merge
    the same result.  Converged instances skip compute and pass bounds
    through unchanged."""
    rr = pl.program_id(0)
    j = pl.program_id(1)
    ln = ln_ref[rr]
    act = act_ref[ri_ref[rr]] != 0

    @pl.when(j == 0)
    def _():
        acc_l[...] = jnp.full_like(acc_l[...], -inf)
        acc_u[...] = jnp.full_like(acc_u[...], inf)

    @pl.when((j < ln) & act)
    def _():
        val = val_ref[...]
        r, k = val.shape[-2:]
        val = val.reshape(r, k)
        col = col_ref[...].reshape(r, k)
        lb_g, ub_g = _gather_bounds_tile(col, lb_ref, ub_ref, block=block)
        lmf, lmc, lxf, lxc = tile_row_aggregates(val, lb_g, ub_g, inf)
        done = done_ref[...].reshape(r) != 0
        rmf = jnp.where(done, lmf, smf_ref[...].reshape(r))
        rmc = jnp.where(done, lmc, smc_ref[...].reshape(r))
        rxf = jnp.where(done, lxf, sxf_ref[...].reshape(r))
        rxc = jnp.where(done, lxc, sxc_ref[...].reshape(r))
        lcand, ucand = tile_candidates(
            val, lb_g, ub_g, ii_ref[...].reshape(r, k) != 0,
            rmf, rmc, rxf, rxc,
            lhs_ref[...].reshape(r), rhs_ref[...].reshape(r), int_eps, inf,
        )
        _scatter_tile(lcand, ucand, col, acc_l, acc_u, inf=inf, block=block)

    @pl.when(j == ln - 1)
    def _():
        lb, ub = lb_ref[...], ub_ref[...]
        new_lb, new_ub, changed = bnd.apply_updates(
            lb, ub, acc_l[...], acc_u[...], eps, inf, outward
        )
        nlb_ref[...] = jnp.where(act, new_lb, lb)
        nub_ref[...] = jnp.where(act, new_ub, ub)
        ch_ref[...] = (changed & act).astype(jnp.int32).reshape(1, 1)


def batched_slab_round_tiles(
    val,
    col_s,
    is_int_g,
    row_done,
    str_min_fin,
    str_min_cnt,
    str_max_fin,
    str_max_cnt,
    lhs_g,
    rhs_g,
    run_start,
    run_len,
    run_inst,
    run_slab,
    active,
    lb,
    ub,
    slab: int,
    max_run_len: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
    outward: float = 0.0,
):
    """The fused slab-parallel round over a partitioned stream: candidates,
    per-slab scatter AND the bound merge in ONE kernel on the 2D ``(run,
    tile)`` grid.

    ``(T'', R, K)`` slab-masked copies + ``(T'', R)`` ``row_done`` select
    mask and gathered straddle aggregates (``str_*``; any values where
    ``row_done == 1``) + the run maps (exactly one run per ``(instance,
    slab)`` window) + ``(B, n_pad_part)`` bound planes + ``(B,)`` active
    mask -> updated ``(B, n_pad_part)`` bounds and ``(n_runs,)`` per-run
    changed flags (OR-combine per instance outside).  Best-bound
    accumulators live in VMEM scratch re-initialized per run, so the run
    axis is parallel and no partial bound plane round-trips through HBM.
    The bound buffers are NOT aliased in place (the window merge writes a
    fresh plane); single-instance callers pass ``B == 1`` with
    ``run_inst == 0``.  Shares ``bounds.apply_updates`` semantics with
    every other engine."""
    if interpret is None:
        interpret = _on_cpu()
    if slab % block:
        raise ValueError(f"slab={slab} must be a multiple of block={block}")
    from jax.experimental.pallas import tpu as pltpu

    t, r, k = val.shape
    bsz, n_pad_part = lb.shape
    n_runs = run_start.shape[0]
    dtype = val.dtype
    copy = lambda rr, j, st, ln, ri, rs, act: _run_tile_index(j, st, ln, rr)
    tile = pl.BlockSpec((1, r, k), lambda rr, j, st, ln, ri, rs, act: (copy(rr, j, st, ln, ri, rs, act), 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda rr, j, st, ln, ri, rs, act: (copy(rr, j, st, ln, ri, rs, act), 0))
    vec = pl.BlockSpec((1, slab), lambda rr, j, st, ln, ri, rs, act: (ri[rr], rs[rr]))
    flag = pl.BlockSpec((1, 1), lambda rr, j, st, ln, ri, rs, act: (rr, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(n_runs, max_run_len),
        in_specs=[tile, tile, tile, row_tile,
                  row_tile, row_tile, row_tile, row_tile,
                  row_tile, row_tile, vec, vec],
        out_specs=[vec, vec, flag],
        scratch_shapes=[pltpu.VMEM((1, slab), dtype), pltpu.VMEM((1, slab), dtype)],
    )
    out_shape = [
        jax.ShapeDtypeStruct((bsz, n_pad_part), dtype),
        jax.ShapeDtypeStruct((bsz, n_pad_part), dtype),
        jax.ShapeDtypeStruct((n_runs, 1), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(
            _batched_slab_round_kernel, eps=eps, int_eps=int_eps, inf=inf,
            outward=outward, block=block,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        **_slab_compiler_params(interpret, ("parallel", "arbitrary")),
    )
    new_lb, new_ub, ch = fn(
        run_start.astype(jnp.int32), run_len.astype(jnp.int32),
        run_inst.astype(jnp.int32), run_slab.astype(jnp.int32),
        active.astype(jnp.int32),
        val, col_s, _int_operand(is_int_g), row_done,
        str_min_fin, str_min_cnt, str_max_fin, str_max_cnt,
        lhs_g, rhs_g, lb, ub,
    )
    return new_lb, new_ub, ch.reshape(n_runs)


def _node_slab_partials_kernel(
    st_ref, ln_ref, rs_ref, act_ref,
    val_ref, col_ref, lb_ref, ub_ref,
    mf_ref, mc_ref, xf_ref, xc_ref, *, inf, block,
):
    """Straddle-partials kernel over a node batch: ONE instance's
    sub-stream swept per node on a ``(B, run, tile)`` grid with per-node
    ``(1, S)`` bound windows.  Inactive nodes write zero partials."""
    b = pl.program_id(0)
    act = act_ref[b] != 0

    @pl.when(act)
    def _():
        val = val_ref[...]
        r, k = val.shape[-2:]
        val = val.reshape(r, k)
        col = col_ref[...].reshape(r, k)
        lb_g, ub_g = _gather_bounds_tile(col, lb_ref, ub_ref, block=block)
        rmf, rmc, rxf, rxc = tile_row_aggregates(val, lb_g, ub_g, inf)
        mf_ref[...] = rmf.reshape(1, 1, r)
        mc_ref[...] = rmc.reshape(1, 1, r)
        xf_ref[...] = rxf.reshape(1, 1, r)
        xc_ref[...] = rxc.reshape(1, 1, r)

    @pl.when(~act)
    def _():
        mf_ref[...] = jnp.zeros_like(mf_ref[...])
        mc_ref[...] = jnp.zeros_like(mc_ref[...])
        xf_ref[...] = jnp.zeros_like(xf_ref[...])
        xc_ref[...] = jnp.zeros_like(xc_ref[...])


def node_slab_partials_tiles(
    val,
    col_s,
    run_start,
    run_len,
    run_slab,
    active,
    lb,
    ub,
    slab: int,
    max_run_len: int,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
):
    """Per-copy, per-node activity partials of ONE instance's straddle
    sub-stream: ``(Ta, R, K)`` slab-masked copies broadcast across the node
    axis + ``(B, n_pad_part)`` per-node bound planes -> 4 x ``(B, Ta, R)``
    partials (completed outside by a per-node segment sum over
    ``a_slot``)."""
    if interpret is None:
        interpret = _on_cpu()
    if slab % block:
        raise ValueError(f"slab={slab} must be a multiple of block={block}")
    from jax.experimental.pallas import tpu as pltpu

    t, r, k = val.shape
    bsz = lb.shape[0]
    n_runs = run_start.shape[0]
    dtype = val.dtype
    copy = lambda rr, j, st, ln: _run_tile_index(j, st, ln, rr)
    tile = pl.BlockSpec((1, r, k), lambda b, rr, j, st, ln, rs, act: (copy(rr, j, st, ln), 0, 0))
    out_tile = pl.BlockSpec((1, 1, r), lambda b, rr, j, st, ln, rs, act: (b, copy(rr, j, st, ln), 0))
    vec = pl.BlockSpec((1, slab), lambda b, rr, j, st, ln, rs, act: (b, rs[rr]))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bsz, n_runs, max_run_len),
        in_specs=[tile, tile, vec, vec],
        out_specs=[out_tile, out_tile, out_tile, out_tile],
    )
    out_shape = [
        jax.ShapeDtypeStruct((bsz, t, r), dtype),
        jax.ShapeDtypeStruct((bsz, t, r), jnp.int32),
        jax.ShapeDtypeStruct((bsz, t, r), dtype),
        jax.ShapeDtypeStruct((bsz, t, r), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(_node_slab_partials_kernel, inf=inf, block=block),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        **_slab_compiler_params(interpret, ("parallel", "parallel", "arbitrary")),
    )
    return fn(
        run_start.astype(jnp.int32), run_len.astype(jnp.int32),
        run_slab.astype(jnp.int32), active.astype(jnp.int32),
        val, col_s, lb, ub,
    )


def _node_slab_round_kernel(
    st_ref, ln_ref, rs_ref, act_ref,
    val_ref, col_ref, ii_ref, done_ref,
    smf_ref, smc_ref, sxf_ref, sxc_ref,
    lhs_ref, rhs_ref, lb_ref, ub_ref,
    nlb_ref, nub_ref, ch_ref,
    acc_l, acc_u, *, eps, int_eps, inf, outward, block,
):
    """The fused slab-parallel round kernel over a node batch: ONE
    instance's copies against B bound planes on a ``(B, run, tile)`` grid.
    Same sweep protocol as the batched variant (scratch init -> compute +
    scatter -> last-step in-window merge), with per-node bound windows,
    per-node straddle aggregates and per-node changed flags."""
    b = pl.program_id(0)
    rr = pl.program_id(1)
    j = pl.program_id(2)
    ln = ln_ref[rr]
    act = act_ref[b] != 0

    @pl.when(j == 0)
    def _():
        acc_l[...] = jnp.full_like(acc_l[...], -inf)
        acc_u[...] = jnp.full_like(acc_u[...], inf)

    @pl.when((j < ln) & act)
    def _():
        val = val_ref[...]
        r, k = val.shape[-2:]
        val = val.reshape(r, k)
        col = col_ref[...].reshape(r, k)
        lb_g, ub_g = _gather_bounds_tile(col, lb_ref, ub_ref, block=block)
        lmf, lmc, lxf, lxc = tile_row_aggregates(val, lb_g, ub_g, inf)
        done = done_ref[...].reshape(r) != 0
        rmf = jnp.where(done, lmf, smf_ref[...].reshape(r))
        rmc = jnp.where(done, lmc, smc_ref[...].reshape(r))
        rxf = jnp.where(done, lxf, sxf_ref[...].reshape(r))
        rxc = jnp.where(done, lxc, sxc_ref[...].reshape(r))
        lcand, ucand = tile_candidates(
            val, lb_g, ub_g, ii_ref[...].reshape(r, k) != 0,
            rmf, rmc, rxf, rxc,
            lhs_ref[...].reshape(r), rhs_ref[...].reshape(r), int_eps, inf,
        )
        _scatter_tile(lcand, ucand, col, acc_l, acc_u, inf=inf, block=block)

    @pl.when(j == ln - 1)
    def _():
        lb, ub = lb_ref[...], ub_ref[...]
        new_lb, new_ub, changed = bnd.apply_updates(
            lb, ub, acc_l[...], acc_u[...], eps, inf, outward
        )
        nlb_ref[...] = jnp.where(act, new_lb, lb)
        nub_ref[...] = jnp.where(act, new_ub, ub)
        ch_ref[...] = (changed & act).astype(jnp.int32).reshape(1, 1)


def node_slab_round_tiles(
    val,
    col_s,
    is_int_g,
    row_done,
    str_min_fin,
    str_min_cnt,
    str_max_fin,
    str_max_cnt,
    lhs_g,
    rhs_g,
    run_start,
    run_len,
    run_slab,
    active,
    lb,
    ub,
    slab: int,
    max_run_len: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    block: int = LANE,
    outward: float = 0.0,
):
    """The fused slab-parallel round over a node batch: ``(T'', R, K)``
    slab-masked copies of ONE instance + ``(B, T'', R)`` per-node gathered
    straddle aggregates (``str_*``) + shared ``(T'', R)`` ``row_done`` /
    sides + ``(B, n_pad_part)`` per-node bound planes + ``(B,)`` active
    mask -> updated ``(B, n_pad_part)`` bounds and ``(B, n_runs)`` changed
    flags (OR-combine per node outside).  Per node the arithmetic is
    exactly the batched variant at ``B == 1``; inactive nodes pass their
    bounds through unchanged."""
    if interpret is None:
        interpret = _on_cpu()
    if slab % block:
        raise ValueError(f"slab={slab} must be a multiple of block={block}")
    from jax.experimental.pallas import tpu as pltpu

    t, r, k = val.shape
    bsz, n_pad_part = lb.shape
    n_runs = run_start.shape[0]
    dtype = val.dtype
    copy = lambda rr, j, st, ln: _run_tile_index(j, st, ln, rr)
    tile = pl.BlockSpec((1, r, k), lambda b, rr, j, st, ln, rs, act: (copy(rr, j, st, ln), 0, 0))
    row_tile = pl.BlockSpec((1, r), lambda b, rr, j, st, ln, rs, act: (copy(rr, j, st, ln), 0))
    node_tile = pl.BlockSpec((1, 1, r), lambda b, rr, j, st, ln, rs, act: (b, copy(rr, j, st, ln), 0))
    vec = pl.BlockSpec((1, slab), lambda b, rr, j, st, ln, rs, act: (b, rs[rr]))
    flag = pl.BlockSpec((1, 1), lambda b, rr, j, st, ln, rs, act: (b, rr))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bsz, n_runs, max_run_len),
        in_specs=[tile, tile, tile, row_tile,
                  node_tile, node_tile, node_tile, node_tile,
                  row_tile, row_tile, vec, vec],
        out_specs=[vec, vec, flag],
        scratch_shapes=[pltpu.VMEM((1, slab), dtype), pltpu.VMEM((1, slab), dtype)],
    )
    out_shape = [
        jax.ShapeDtypeStruct((bsz, n_pad_part), dtype),
        jax.ShapeDtypeStruct((bsz, n_pad_part), dtype),
        jax.ShapeDtypeStruct((bsz, n_runs), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(
            _node_slab_round_kernel, eps=eps, int_eps=int_eps, inf=inf,
            outward=outward, block=block,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        **_slab_compiler_params(interpret, ("parallel", "parallel", "arbitrary")),
    )
    return fn(
        run_start.astype(jnp.int32), run_len.astype(jnp.int32),
        run_slab.astype(jnp.int32), active.astype(jnp.int32),
        val, col_s, _int_operand(is_int_g), row_done,
        str_min_fin, str_min_cnt, str_max_fin, str_max_cnt,
        lhs_g, rhs_g, lb, ub,
    )


def _apply_updates_slab_kernel(
    lb_ref, ub_ref, bl_ref, bu_ref, act_ref, nlb_ref, nub_ref, ch_ref,
    *, eps, inf, outward
):
    lb, ub = lb_ref[...], ub_ref[...]
    new_lb, new_ub, changed = bnd.apply_updates(
        lb, ub, bl_ref[...], bu_ref[...], eps, inf, outward
    )
    act = act_ref[0, 0] != 0
    nlb_ref[...] = jnp.where(act, new_lb, lb)
    nub_ref[...] = jnp.where(act, new_ub, ub)
    ch_ref[...] = (changed & act).astype(jnp.int32).reshape(1, 1)


def apply_updates_slab_tiles(
    lb,
    ub,
    best_l,
    best_u,
    active,
    slab: int,
    eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    outward: float = 0.0,
):
    """Slab-gridded merge kernel for VMEM-exceeding column spaces:
    ``(B, n_pad_part)`` bounds x best candidates -> updated bounds +
    ``(B,)`` per-instance changed flags.

    The grid walks ``(instance, slab)`` so only ``(1, S)`` windows are ever
    VMEM-resident; per-window changed flags are OR-combined outside (the
    cheap cross-slab combine).  Every grid step touches a DISJOINT window
    of the planes (no carried accumulator), so both axes are declared
    ``parallel`` like the slab round kernel -- Mosaic may run the window
    merges in any order or concurrently.  The bound buffers are donated
    (``input_output_aliases``); inactive instances pass through untouched.
    Shares ``bounds.apply_updates`` semantics with every other engine."""
    if interpret is None:
        interpret = _on_cpu()
    bsz, n_pad_part = lb.shape
    if n_pad_part % slab:
        raise ValueError(f"n_pad_part={n_pad_part} must be a multiple of slab={slab}")
    n_slabs = n_pad_part // slab
    dtype = lb.dtype
    vec = pl.BlockSpec((1, slab), lambda b, s: (b, s))
    flag_in = pl.BlockSpec((1, 1), lambda b, s: (b, 0))
    flag_out = pl.BlockSpec((1, 1), lambda b, s: (b, s))
    out_shape = [
        jax.ShapeDtypeStruct((bsz, n_pad_part), dtype),
        jax.ShapeDtypeStruct((bsz, n_pad_part), dtype),
        jax.ShapeDtypeStruct((bsz, n_slabs), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(
            _apply_updates_slab_kernel, eps=eps, inf=inf, outward=outward
        ),
        grid=(bsz, n_slabs),
        in_specs=[vec, vec, vec, vec, flag_in],
        out_specs=[vec, vec, flag_out],
        out_shape=out_shape,
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
        **_slab_compiler_params(interpret, ("parallel", "parallel")),
    )
    new_lb, new_ub, changed = fn(
        lb, ub, best_l, best_u, active.astype(jnp.int32).reshape(bsz, 1)
    )
    return new_lb, new_ub, jnp.any(changed != 0, axis=1)


def _apply_updates_batch_kernel(
    lb_ref, ub_ref, bl_ref, bu_ref, act_ref, nlb_ref, nub_ref, ch_ref,
    *, eps, inf, outward
):
    lb, ub = lb_ref[...], ub_ref[...]
    new_lb, new_ub, changed = bnd.apply_updates(
        lb, ub, bl_ref[...], bu_ref[...], eps, inf, outward
    )
    act = act_ref[0, 0] != 0
    nlb_ref[...] = jnp.where(act, new_lb, lb)
    nub_ref[...] = jnp.where(act, new_ub, ub)
    ch_ref[...] = (changed & act).astype(jnp.int32).reshape(1, 1)


def apply_updates_batch_tiles(
    lb,
    ub,
    best_l,
    best_u,
    active,
    eps: float,
    inf: float = INF,
    interpret: bool | None = None,
    outward: float = 0.0,
):
    """Batched merge kernel: ``(B, n_pad)`` bounds x best candidates ->
    updated bounds + ``(B,)`` per-instance changed flags.  The bound buffers
    are donated (``input_output_aliases``); inactive instances pass through
    untouched and report unchanged.  Like the round kernel, the ``active``
    gate doubles as the service's slot-occupancy mask: retired/empty slots
    keep their last bounds bit-for-bit and never flag a change."""
    if interpret is None:
        interpret = _on_cpu()
    bsz, n_pad = lb.shape
    dtype = lb.dtype
    vec = pl.BlockSpec((1, n_pad), lambda b: (b, 0))
    flag = pl.BlockSpec((1, 1), lambda b: (b, 0))
    out_shape = [
        jax.ShapeDtypeStruct((bsz, n_pad), dtype),
        jax.ShapeDtypeStruct((bsz, n_pad), dtype),
        jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(
            _apply_updates_batch_kernel, eps=eps, inf=inf, outward=outward
        ),
        grid=(bsz,),
        in_specs=[vec, vec, vec, vec, flag],
        out_specs=[vec, vec, flag],
        out_shape=out_shape,
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )
    new_lb, new_ub, changed = fn(
        lb, ub, best_l, best_u, active.astype(jnp.int32).reshape(bsz, 1)
    )
    return new_lb, new_ub, changed.reshape(bsz) != 0


def _node_objective_kernel(
    lb_ref, ub_ref, c_ref, ii_ref, valid_ref, obj_ref, fix_ref, cr_ref,
    *, feas_eps, inf
):
    lb, ub = lb_ref[...], ub_ref[...]
    c = c_ref[...]
    ii = ii_ref[...] != 0
    valid = valid_ref[...] != 0
    contrib = jnp.where(c > 0, c * lb, c * ub)
    contrib = jnp.where(valid & (c != 0), contrib, 0.0)
    unbounded = valid & (((c > 0) & (lb <= -inf)) | ((c < 0) & (ub >= inf)))
    obj = jnp.where(jnp.any(unbounded), -inf, jnp.sum(contrib))
    fixed = jnp.all(~(valid & ii) | (ub - lb <= 0.5))
    crossed = jnp.any((lb > ub + feas_eps) & valid)
    obj_ref[...] = obj.reshape(1, 1)
    fix_ref[...] = fixed.astype(jnp.int32).reshape(1, 1)
    cr_ref[...] = crossed.astype(jnp.int32).reshape(1, 1)


def node_objective_tiles(
    lb,
    ub,
    c,
    is_int,
    valid,
    feas_eps: float,
    inf: float = INF,
    interpret: bool | None = None,
):
    """Per-node objective bound + leaf/prune predicates, one kernel pass.

    The solver's post-propagation scan: grid ``(B,)``, each step reads one
    node's ``(1, n_pad)`` bound rows plus the shared objective /
    integrality / validity vectors (their blocks pinned to row 0, so the
    ``(n_pad,)`` constants stay VMEM-resident across the sweep) and writes
    three ``(1, 1)`` scalars -- the domain-relaxation objective bound, the
    all-integers-fixed flag and the crossed-domain flag.  Exact semantics
    (sentinel handling, tie behaviour) are defined by
    ``ref.node_objective_ref``; returns ``(obj, fixed, crossed)`` as
    ``(B,)`` arrays with the flags as bools."""
    if interpret is None:
        interpret = _on_cpu()
    bsz, n_pad = lb.shape
    dtype = lb.dtype
    vec = pl.BlockSpec((1, n_pad), lambda b: (b, 0))
    shared = pl.BlockSpec((1, n_pad), lambda b: (0, 0))
    flag = pl.BlockSpec((1, 1), lambda b: (b, 0))
    out_shape = [
        jax.ShapeDtypeStruct((bsz, 1), dtype),
        jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
        jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
    ]
    fn = pl.pallas_call(
        functools.partial(_node_objective_kernel, feas_eps=feas_eps, inf=inf),
        grid=(bsz,),
        in_specs=[vec, vec, shared, shared, shared],
        out_specs=[flag, flag, flag],
        out_shape=out_shape,
        interpret=interpret,
    )
    obj, fixed, crossed = fn(
        lb,
        ub,
        jnp.asarray(c, dtype).reshape(1, n_pad),
        _int_operand(is_int).reshape(1, n_pad),
        _int_operand(valid).reshape(1, n_pad),
    )
    return obj.reshape(bsz), fixed.reshape(bsz) != 0, crossed.reshape(bsz) != 0
