"""Pure-jnp oracles for every Pallas kernel in this package.

The oracles define the *exact* semantics (sentinel-infinity handling, padding
masks, reduction order at tile granularity) the kernels must reproduce; the
test suite sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.types import INF, int_round_slack


# ---------------------------------------------------------------------------
# Tile-level activity partials (kernel A oracle)
# ---------------------------------------------------------------------------


def activities_tiles_ref(val, lb_g, ub_g, inf: float = INF):
    """Per-chunk activity partials over block-ELL tiles.

    Args:
      val:  (T, R, K) coefficients, 0 == padding.
      lb_g: (T, R, K) lower bounds gathered at each nonzero's column.
      ub_g: (T, R, K) upper bounds gathered at each nonzero's column.

    Returns:
      (min_fin, min_cnt, max_fin, max_cnt): each (T, R); finite partial sums
      and int32 infinity-contribution counts per chunk.
    """
    pos = val > 0
    pad = val == 0
    b_min = jnp.where(pos, lb_g, ub_g)
    b_max = jnp.where(pos, ub_g, lb_g)
    min_is_inf = (jnp.abs(b_min) >= inf) & ~pad
    max_is_inf = (jnp.abs(b_max) >= inf) & ~pad
    min_fin = jnp.where(min_is_inf | pad, 0.0, val * b_min).sum(axis=-1)
    max_fin = jnp.where(max_is_inf | pad, 0.0, val * b_max).sum(axis=-1)
    min_cnt = min_is_inf.astype(jnp.int32).sum(axis=-1)
    max_cnt = max_is_inf.astype(jnp.int32).sum(axis=-1)
    return min_fin, min_cnt, max_fin, max_cnt


# ---------------------------------------------------------------------------
# Tile-level candidate computation (kernel B oracle)
# ---------------------------------------------------------------------------


def candidates_tiles_ref(
    val,
    lb_g,
    ub_g,
    is_int_g,
    row_min_fin,
    row_min_cnt,
    row_max_fin,
    row_max_cnt,
    lhs_g,
    rhs_g,
    int_eps: float,
    inf: float = INF,
):
    """Per-nonzero bound candidates over block-ELL tiles.

    Args:
      val, lb_g, ub_g: (T, R, K) as above.
      is_int_g: (T, R, K) bool, integrality of each nonzero's column.
      row_*: (T, R) *completed* row aggregates gathered per chunk.
      lhs_g, rhs_g: (T, R) constraint sides gathered per chunk.

    Returns:
      (lcand, ucand): (T, R, K); invalid entries at -inf/+inf sentinels.

    Candidates use the same division-first form as the kernels --
    ``(side - row_sum) / a + bound`` instead of dividing the explicit
    residual -- so that no backend can contract a step into an FMA and
    kernel-vs-oracle comparisons stay bitwise in every compilation
    context (see ``prop_round.tile_candidates``).
    """
    pos = val > 0
    pad = val == 0
    b_min = jnp.where(pos, lb_g, ub_g)
    b_max = jnp.where(pos, ub_g, lb_g)
    min_is_inf = (jnp.abs(b_min) >= inf) & ~pad
    max_is_inf = (jnp.abs(b_max) >= inf) & ~pad

    rmf = row_min_fin[..., None]
    rmc = row_min_cnt[..., None]
    rxf = row_max_fin[..., None]
    rxc = row_max_cnt[..., None]

    # Residual usable at this entry (§3.4 single-infinity rule): all
    # contributions finite and the row sum complete, or exactly this
    # entry's bound infinite so the sum over the others IS the residual.
    ok_min = jnp.where(min_is_inf, rmc == 1, rmc == 0)
    ok_max = jnp.where(max_is_inf, rxc == 1, rxc == 0)
    inc_min = jnp.where(min_is_inf | pad, 0.0, b_min)
    inc_max = jnp.where(max_is_inf | pad, 0.0, b_max)

    lhs_b = lhs_g[..., None]
    rhs_b = rhs_g[..., None]
    safe_a = jnp.where(pad, 1.0, val)
    q_min = (rhs_b - rmf) / safe_a + inc_min
    q_max = (lhs_b - rxf) / safe_a + inc_max
    lcand = jnp.where(pos, q_max, q_min)
    ucand = jnp.where(pos, q_min, q_max)

    valid_l = (
        jnp.where(pos, (lhs_b > -inf) & ok_max, (rhs_b < inf) & ok_min)
        & ~pad
    )
    valid_u = (
        jnp.where(pos, (rhs_b < inf) & ok_min, (lhs_b > -inf) & ok_max)
        & ~pad
    )
    lcand = jnp.where(valid_l, jnp.clip(lcand, -inf, inf), -inf)
    ucand = jnp.where(valid_u, jnp.clip(ucand, -inf, inf), inf)

    # Integrality strengthening (same dtype-keyed low-precision slack as
    # the kernel, so kernel-vs-oracle comparisons stay bitwise per tier).
    do_l = is_int_g & (jnp.abs(lcand) < inf)
    do_u = is_int_g & (jnp.abs(ucand) < inf)
    slack = int_round_slack(jnp.result_type(lcand))
    sl = su = int_eps
    if slack:
        sl = int_eps + slack * jnp.maximum(1.0, jnp.abs(lcand))
        su = int_eps + slack * jnp.maximum(1.0, jnp.abs(ucand))
    lcand = jnp.where(do_l, jnp.ceil(lcand - sl), lcand)
    ucand = jnp.where(do_u, jnp.floor(ucand + su), ucand)
    return lcand, ucand


# ---------------------------------------------------------------------------
# Fused one-tile round (kernel C oracle): rows complete within their chunk
# ---------------------------------------------------------------------------


def fused_round_tiles_ref(
    val, lb_g, ub_g, is_int_g, lhs_g, rhs_g, int_eps: float, inf: float = INF
):
    """Activities + candidates in one pass; valid iff every row fits one chunk.

    This is the Alg.-3-faithful fusion: the chunk's activity lives in
    registers/VMEM and is immediately reused for the candidates -- the TPU
    analogue of the paper's shared-memory reuse (§3.5).
    """
    min_fin, min_cnt, max_fin, max_cnt = activities_tiles_ref(val, lb_g, ub_g, inf)
    return candidates_tiles_ref(
        val,
        lb_g,
        ub_g,
        is_int_g,
        min_fin,
        min_cnt,
        max_fin,
        max_cnt,
        lhs_g,
        rhs_g,
        int_eps,
        inf,
    )


# ---------------------------------------------------------------------------
# Fused-scatter oracles (kernels D/E): column-wise best-bound reduction
# ---------------------------------------------------------------------------


def scatter_round_ref(lcand, ucand, col, n_pad: int, inf: float = INF):
    """Column reduction oracle for the in-kernel scatter.

    Matches the kernels' sentinel semantics exactly: accumulators start at
    the -inf/+inf *sentinels*, so columns with no nonzeros come out at
    -inf/+inf sentinel (segment-op identities are clamped accordingly).
    """
    flat_col = col.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=n_pad)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=n_pad)
    return jnp.maximum(best_l, -inf), jnp.minimum(best_u, inf)


def fused_scatter_round_tiles_ref(
    val, col, is_int_g, lhs_g, rhs_g, lb, ub, n_pad: int,
    int_eps: float, inf: float = INF,
):
    """Oracle for kernel D: in-kernel bound gather + fused round + column
    reduction.  (T,R,K) tiles + (n_pad,) bounds -> (n_pad,) x2."""
    lb_g = lb[col]
    ub_g = ub[col]
    lcand, ucand = fused_round_tiles_ref(
        val, lb_g, ub_g, is_int_g, lhs_g, rhs_g, int_eps, inf
    )
    return scatter_round_ref(lcand, ucand, col, n_pad, inf)


def activities_gather_tiles_ref(val, col, lb, ub, n_pad: int, inf: float = INF):
    """Oracle for kernel A': in-kernel bound gather + activity partials."""
    del n_pad  # shape bookkeeping only; the gather is by column id
    return activities_tiles_ref(val, lb[col], ub[col], inf)


def candidates_scatter_tiles_ref(
    val, col, is_int_g,
    row_min_fin, row_min_cnt, row_max_fin, row_max_cnt,
    lhs_g, rhs_g, lb, ub, n_pad: int, int_eps: float, inf: float = INF,
):
    """Oracle for kernel E: in-kernel bound gather + candidates from row
    aggregates + column scatter."""
    lcand, ucand = candidates_tiles_ref(
        val, lb[col], ub[col], is_int_g,
        row_min_fin, row_min_cnt, row_max_fin, row_max_cnt,
        lhs_g, rhs_g, int_eps, inf,
    )
    return scatter_round_ref(lcand, ucand, col, n_pad, inf)


# ---------------------------------------------------------------------------
# Batched oracles: flat super-tile stream, per-instance column windows
# ---------------------------------------------------------------------------


def batched_scatter_round_ref(lcand, ucand, col_g, batch: int, n_pad: int, inf: float = INF):
    """Column reduction over the whole batch in ONE flat segment op.

    ``col_g`` carries global column ids (``col + tile_inst * n_pad``), so
    instance windows never alias; within each window the element order is
    the instance's own tile order, which keeps the per-instance reduction
    bit-identical to :func:`scatter_round_ref`."""
    flat_col = col_g.reshape(-1)
    best_l = jax.ops.segment_max(lcand.reshape(-1), flat_col, num_segments=batch * n_pad)
    best_u = jax.ops.segment_min(ucand.reshape(-1), flat_col, num_segments=batch * n_pad)
    best_l = jnp.maximum(best_l, -inf).reshape(batch, n_pad)
    best_u = jnp.minimum(best_u, inf).reshape(batch, n_pad)
    return best_l, best_u


def batched_fused_scatter_round_ref(
    val, col_g, is_int_g, lhs_g, rhs_g, lb, ub, n_pad: int,
    int_eps: float, inf: float = INF,
):
    """Oracle for the batched fused-scatter kernel: ``(T, R, K)`` flat tile
    stream + ``(B, n_pad)`` bound plane -> ``(B, n_pad)`` x2.  The bound
    gather indexes the flattened plane with global column ids; per instance
    the arithmetic is exactly the single-instance fused round."""
    batch = lb.shape[0]
    lbf, ubf = lb.reshape(-1), ub.reshape(-1)
    lcand, ucand = fused_round_tiles_ref(
        val, lbf[col_g], ubf[col_g], is_int_g, lhs_g, rhs_g, int_eps, inf
    )
    return batched_scatter_round_ref(lcand, ucand, col_g, batch, n_pad, inf)


def node_fused_scatter_round_ref(
    val, col, is_int_g, lhs_g, rhs_g, lb, ub, n_pad: int,
    int_eps: float, inf: float = INF,
):
    """Oracle for the node-batch fused-scatter kernel: ONE instance's
    ``(T, R, K)`` tiles broadcast over a ``(B, n_pad)`` bound plane.  Per
    node this is exactly :func:`fused_scatter_round_tiles_ref`, vmapped
    over the node axis -- the matrix operands are closed over, so only the
    bound planes carry the batch dimension."""
    fn = lambda l, u: fused_scatter_round_tiles_ref(
        val, col, is_int_g, lhs_g, rhs_g, l, u, n_pad, int_eps, inf
    )
    return jax.vmap(fn)(lb, ub)


def _partitioned_gathered_bounds(part, lbf, ubf, val, col_s, tile_inst, tile_slab):
    """Bounds of a slab-partitioned copy stream gathered from the flattened
    ``(B * n_pad_part,)`` plane via each copy's global window base."""
    base = tile_inst.astype(jnp.int32) * jnp.int32(part.n_pad_part) + (
        tile_slab.astype(jnp.int32) * jnp.int32(part.slab)
    )
    col_g = col_s + base[:, None, None]
    return lbf[col_g], ubf[col_g], col_g


def partitioned_round_ref(part, lb_p, ub_p, int_eps: float, inf: float = INF):
    """Slab oracle: one round over a chunk-granularity slab partition.

    Defines the exact semantics of the slab-parallel fused kernels
    (``*_slab_partials_tiles`` / ``*_slab_round_tiles`` in
    ``prop_round.py``) at the data level.  ``part`` is a
    ``SlabPartition``-shaped record (duck-typed); ``lb_p``/``ub_p`` are
    ``(B, n_pad)`` planes for any ``n_pad <= n_pad_part`` (padded to the
    slab grid here).  Per copy: local activity partials; straddle rows
    (``row_done == 0``) replace their local partial with the completed
    aggregate segment-summed over the sub-stream's ``a_slot`` table --
    exactly the summation grouping the engine's out-of-band combine
    commits to, so complete rows' aggregates are the untouched local sums
    and bitwise comparisons hold.  Candidates come from the selected
    aggregates; the column reduction runs over global padded ids, via the
    build-time rectangle-gather schedule (``col_slots``) when present.
    Returns ``(B, n_pad_part)`` best_l / best_u with sentinel identities."""
    bsz, n_pad = lb_p.shape
    dt = lb_p.dtype
    extra = part.n_pad_part - n_pad
    if extra:
        z = jnp.zeros((bsz, extra), dt)
        lb_p = jnp.concatenate([lb_p, z], axis=1)
        ub_p = jnp.concatenate([ub_p, z], axis=1)
    lbf, ubf = lb_p.reshape(-1), ub_p.reshape(-1)

    lb_g, ub_g, col_g = _partitioned_gathered_bounds(
        part, lbf, ubf, part.val, part.col_s, part.tile_inst, part.tile_slab
    )
    mf, mc, xf, xc = activities_tiles_ref(part.val, lb_g, ub_g, inf)

    if int(part.a_val.shape[0]):
        a_lb, a_ub, _ = _partitioned_gathered_bounds(
            part, lbf, ubf, part.a_val, part.a_col_s,
            part.a_tile_inst, part.a_tile_slab,
        )
        amf, amc, axf, axc = activities_tiles_ref(part.a_val, a_lb, a_ub, inf)
        slot = part.a_slot.reshape(-1)
        nseg = part.n_straddle + 1
        tab = lambda x: jax.ops.segment_sum(x.reshape(-1), slot, num_segments=nseg)
        done = part.row_done != 0
        sel = lambda local, t: jnp.where(done, local, tab(t)[part.agg_slot])
        rmf, rmc = sel(mf, amf), sel(mc, amc)
        rxf, rxc = sel(xf, axf), sel(xc, axc)
    else:
        rmf, rmc, rxf, rxc = mf, mc, xf, xc

    lcand, ucand = candidates_tiles_ref(
        part.val, lb_g, ub_g, part.ii_g != 0, rmf, rmc, rxf, rxc,
        part.lhs_g, part.rhs_g, int_eps, inf,
    )
    if part.col_slots is not None:
        # Rectangle-gather reduction: one gather + row-wise max/min over the
        # build-time per-column slot lists (sentinel slot -> the appended
        # -inf/+inf identity element).  Bitwise-equal to the segment ops --
        # min/max are grouping-independent.
        fl = jnp.concatenate([lcand.reshape(-1), jnp.full((1,), -inf, dt)])
        fu = jnp.concatenate([ucand.reshape(-1), jnp.full((1,), inf, dt)])
        best_l = fl[part.col_slots].max(axis=1)
        best_u = fu[part.col_slots].min(axis=1)
        best_l = jnp.maximum(best_l, -inf).reshape(bsz, part.n_pad_part)
        best_u = jnp.minimum(best_u, inf).reshape(bsz, part.n_pad_part)
        return best_l, best_u
    return batched_scatter_round_ref(
        lcand, ucand, col_g, bsz, part.n_pad_part, inf
    )


def node_partitioned_round_ref(part, lb_p, ub_p, int_eps: float, inf: float = INF):
    """Node-batch slab oracle: ONE instance's slab partition broadcast over
    ``(B, n_pad)`` per-node bound planes.  Per node this is exactly
    :func:`partitioned_round_ref` at ``B == 1``, vmapped over the node
    axis; returns ``(B, n_pad_part)`` best_l / best_u."""
    fn = lambda l, u: partitioned_round_ref(part, l[None], u[None], int_eps, inf)
    bl, bu = jax.vmap(fn)(lb_p, ub_p)
    return bl[:, 0], bu[:, 0]


# ---------------------------------------------------------------------------
# Solver oracles: node objective bound, branch selection, incumbent update
# ---------------------------------------------------------------------------


def node_objective_ref(lb, ub, c, is_int, valid, feas_eps: float, inf: float = INF):
    """Per-node objective lower bound + leaf/prune predicates (solver oracle).

    Args:
      lb, ub: (B, n_pad) propagated per-node bound planes (sentinel-infinite).
      c:      (n_pad,) minimization objective (0 on padded columns).
      is_int: (n_pad,) bool integrality marks.
      valid:  (n_pad,) bool, True on real (non-padded) columns.

    Returns ``(obj, fixed, crossed)``, each ``(B,)``:

      * ``obj`` -- the domain-relaxation bound ``sum_j min(c_j lb_j, c_j
        ub_j)`` (i.e. ``c_j lb_j`` for ``c_j > 0``, ``c_j ub_j`` for
        ``c_j < 0``), a valid lower bound on every feasible point in the
        node's box; ``-inf`` sentinel if any contributing bound is
        infinite.  For a node whose variables are all fixed this IS the
        point's objective, and over integral data the f64 sum is exact --
        the bitwise anchor of the differential tests.
      * ``fixed`` -- every valid integer column has ``ub - lb <= 0.5``
        (an integral domain of width 0: the node is a candidate leaf).
      * ``crossed`` -- some valid column's domain emptied
        (``lb > ub + feas_eps``): prune the node as infeasible.
    """
    v = valid[None, :]
    cb = c[None, :]
    contrib = jnp.where(cb > 0, cb * lb, cb * ub)
    contrib = jnp.where(v & (cb != 0), contrib, 0.0)
    unbounded = v & (((cb > 0) & (lb <= -inf)) | ((cb < 0) & (ub >= inf)))
    obj = jnp.where(
        jnp.any(unbounded, axis=-1), -inf, jnp.sum(contrib, axis=-1)
    )
    fixed = jnp.all(~(v & is_int[None, :]) | (ub - lb <= 0.5), axis=-1)
    crossed = jnp.any((lb > ub + feas_eps) & v, axis=-1)
    return obj, fixed, crossed


def most_fractional_ref(lb, ub, is_int, valid):
    """Most-fractional branching selection over ``(B, n_pad)`` bound planes.

    Candidate columns are valid unfixed integers (``ub - lb > 0.5``); the
    score is the domain midpoint's distance-to-integrality
    ``0.5 - |frac(mid) - 0.5|`` and ties resolve to the LOWEST column index
    (``argmax`` first-hit), so selection is deterministic.  Returns
    ``(var, has)``: per-node selected column and whether any candidate
    existed (``var`` is 0 and meaningless when ``has`` is False)."""
    cand = valid[None, :] & is_int[None, :] & (ub - lb > 0.5)
    mid = 0.5 * (lb + ub)
    frac = mid - jnp.floor(mid)
    score = jnp.where(cand, 0.5 - jnp.abs(frac - 0.5), -1.0)
    return jnp.argmax(score, axis=-1), jnp.any(cand, axis=-1)


def pseudo_cost_select_ref(
    lb, ub, is_int, valid, pc_sum, pc_cnt, prior: float = 1e-4
):
    """Pseudo-cost branching selection over ``(B, n_pad)`` bound planes.

    ``pc_sum``/``pc_cnt`` are the search's ``(2, n_pad)`` accumulated
    bound-gain statistics (direction 0 = down child, 1 = up child): each
    propagated child adds ``max(child_bound - parent_bound, 0)`` for its
    branching column and direction.  The score is the product of the two
    directions' average gains (plus a small ``prior`` so unseen columns
    stay comparable), the standard product rule; candidates and
    tie-breaking are exactly :func:`most_fractional_ref`'s.  Returns
    ``(var, has)``."""
    cand = valid[None, :] & is_int[None, :] & (ub - lb > 0.5)
    avg_d = pc_sum[0] / jnp.maximum(pc_cnt[0], 1.0)
    avg_u = pc_sum[1] / jnp.maximum(pc_cnt[1], 1.0)
    score = (avg_d + prior) * (avg_u + prior)
    score = jnp.where(cand, score[None, :], -1.0)
    return jnp.argmax(score, axis=-1), jnp.any(cand, axis=-1)


def incumbent_update_ref(leaf, obj, inc, inc_x, lb, inf: float = INF):
    """Device-resident incumbent update (solver oracle).

    ``leaf`` masks the ``(B,)`` nodes whose propagated domains are feasible
    candidate solutions this level, ``obj`` their objectives, ``inc`` /
    ``inc_x`` the running incumbent scalar and ``(n_pad,)`` solution plane,
    ``lb`` the ``(B, n_pad)`` bound planes (a leaf's solution is its
    ``lb`` row -- all variables fixed).  The best leaf is selected with
    ``min`` + first-index ``argmin``, so reduction order is deterministic;
    the incumbent moves only on STRICT improvement.  Returns
    ``(inc, inc_x, improved)``."""
    leaf_obj = jnp.where(leaf, obj, inf)
    best = jnp.min(leaf_obj)
    improved = best < inc
    inc_new = jnp.where(improved, best, inc)
    x_new = jnp.where(improved, lb[jnp.argmin(leaf_obj)], inc_x)
    return inc_new, x_new, improved


def batched_candidates_scatter_round_ref(
    val, col_g, is_int_g, chunk_row, lhs_g, rhs_g, lb, ub,
    m_total: int, n_pad: int, int_eps: float, inf: float = INF,
):
    """Batched round for rows spanning several chunks: one flat activity
    segment-combine over GLOBAL row ids (instance ``i``'s padding chunks
    target its own dummy row, so segments never alias across instances),
    then candidates + the flat column reduction."""
    batch = lb.shape[0]
    lbf, ubf = lb.reshape(-1), ub.reshape(-1)
    lb_t, ub_t = lbf[col_g], ubf[col_g]
    mf, mc, xf, xc = activities_tiles_ref(val, lb_t, ub_t, inf)
    flat = chunk_row.reshape(-1)
    seg = lambda x: jax.ops.segment_sum(x.reshape(-1), flat, num_segments=m_total + 1)
    g = lambda x: seg(x)[chunk_row]
    lcand, ucand = candidates_tiles_ref(
        val, lb_t, ub_t, is_int_g, g(mf), g(mc), g(xf), g(xc),
        lhs_g, rhs_g, int_eps, inf,
    )
    return batched_scatter_round_ref(lcand, ucand, col_g, batch, n_pad, inf)
