"""Serving: prefill + decode step builders and cache-layout conversion.

``serve_step`` is what the decode_* dry-run shapes lower: ONE new token
against a KV cache of size seq_len (the task-spec definition).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..launch.sharding import Sharder
from ..models.config import ModelConfig
from ..models.transformer import decode_step, prefill


def make_prefill_fn(cfg: ModelConfig, mesh=None):
    shd = Sharder(mesh, seq_shard=cfg.seq_shard)

    def prefill_fn(params, tokens, frontend_embeds=None):
        return prefill(params, cfg, tokens, frontend_embeds=frontend_embeds, shd=shd)

    return prefill_fn


def make_serve_step(cfg: ModelConfig, mesh=None):
    shd = Sharder(mesh, seq_shard=cfg.seq_shard)

    def serve_step(params, tokens, cache, pos):
        """tokens: (B, 1) new token ids; pos: scalar write position."""
        logits, cache = decode_step(params, cfg, tokens, cache, pos, shd=shd)
        return logits, cache

    return serve_step


# ---------------------------------------------------------------------------
# Prefill-cache -> decode-cache layout conversion
# ---------------------------------------------------------------------------


def _pad_seq(x, s_max: int, axis: int):
    pad = s_max - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _roll_window(k, window: int, s: int):
    """(..., S, D) prefill keys -> rolling (..., W, D) decode buffer where
    slot p % W holds position p, for p in [max(0, S-W), S)."""
    w = min(window, k.shape[-2]) if k.shape[-2] < window else window
    start = max(0, s - window)
    positions = jnp.arange(start, s)
    slots = positions % window
    buf = jnp.zeros(k.shape[:-2] + (window, k.shape[-1]), k.dtype)
    return buf.at[..., slots, :].set(k[..., start:s, :])


def prefill_to_decode_cache(cfg: ModelConfig, caches, s_prefill: int, s_max: int):
    """Convert prefill-emitted caches to the decode layout used by
    init_cache/_cache_specs (pad full-attn KV to s_max; roll local windows)."""
    out = []
    for idx, (kind, count) in enumerate(cfg.segments()):
        c = caches[idx]
        if kind == "mamba2":
            out.append(c)
            continue
        if kind.startswith("pattern"):
            sub_out = {}
            for name, sub in c.items():
                if "k" in sub:  # local attention: roll into window buffer
                    sub_out[name] = {
                        "k": _roll_window(sub["k"], cfg.local_window, s_prefill),
                        "v": _roll_window(sub["v"], cfg.local_window, s_prefill),
                    }
                else:
                    sub_out[name] = sub
            out.append(sub_out)
            continue
        if cfg.attn_type == "mla":
            out.append(
                {
                    "c": _pad_seq(c["c"], s_max, axis=2),
                    "kr": _pad_seq(c["kr"], s_max, axis=2),
                }
            )
            continue
        if cfg.local_window is not None:
            out.append(
                {
                    "k": _roll_window(c["k"], cfg.local_window, s_prefill),
                    "v": _roll_window(c["v"], cfg.local_window, s_prefill),
                }
            )
        else:
            out.append(
                {
                    "k": _pad_seq(c["k"], s_max, axis=3),
                    "v": _pad_seq(c["v"], s_max, axis=3),
                }
            )
    return out


def generate(params, cfg: ModelConfig, tokens, steps: int, s_max: int,
             frontend_embeds=None, mesh=None, greedy: bool = True, key=None):
    """Reference generation loop: prefill then ``steps`` decode steps."""
    prefill_fn = make_prefill_fn(cfg, mesh)
    serve_fn = jax.jit(make_serve_step(cfg, mesh))
    logits, caches = prefill_fn(params, tokens, frontend_embeds)
    s0 = tokens.shape[1] + (
        frontend_embeds.shape[1] if frontend_embeds is not None else 0
    )
    cache = prefill_to_decode_cache(cfg, caches, s0, s_max)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(steps - 1):
        logits, cache = serve_fn(params, tok, cache, jnp.int32(s0 + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
