"""Training step: CE loss (+MoE aux), grad accumulation, AdamW -- one jit.

``make_train_step(cfg, mesh, opt_cfg)`` returns a function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` with all
sharding constraints applied; pass the returned fn straight to ``jax.jit``
with the shardings from ``launch.sharding``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..launch.sharding import Sharder
from ..models.config import ModelConfig
from ..models.transformer import forward_train
from .optimizer import OptimizerConfig, adamw_update


def cross_entropy(logits, labels):
    """Mean CE over all positions; logits fp32-softmaxed (vocab may be
    TP-sharded -- XLA inserts the partial-reduction collectives)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def make_loss_fn(cfg: ModelConfig, shd: Optional[Sharder] = None):
    def loss_fn(params, batch):
        logits, aux = forward_train(
            params,
            cfg,
            batch["tokens"],
            frontend_embeds=batch.get("frontend_embeds"),
            shd=shd,
        )
        labels = batch["labels"]
        # Modality stubs prepend frontend tokens; loss is on text positions.
        logits = logits[:, -labels.shape[1] :, :]
        loss = cross_entropy(logits, labels)
        total = loss + cfg.moe_aux_coef * aux
        return total, {"ce": loss, "moe_aux": aux}

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    mesh=None,
    microbatches: int = 1,
):
    shd = Sharder(mesh, seq_shard=cfg.seq_shard)
    loss_fn = make_loss_fn(cfg, shd)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    compute_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def cast_params(params):
        # True mixed precision: differentiate w.r.t. the bf16 copy so grads
        # are bf16 (halves grad residency); fp32 masters live in the
        # optimizer update only.
        return jax.tree.map(
            lambda p: p.astype(compute_dt) if p.dtype == jnp.float32 else p,
            params,
        )

    def train_step(params, opt_state, batch):
        cparams = cast_params(params)
        if microbatches == 1:
            (loss, parts), grads = grad_fn(cparams, batch)
        else:
            # Gradient accumulation: scan over microbatch slices.  The carry
            # dtype follows opt_cfg.state_dtype (bf16 halves residency for
            # 236B-scale cells).
            acc_dt = jnp.dtype(opt_cfg.state_dtype)

            def mb(i, batch=batch):
                return jax.tree.map(
                    lambda x: x.reshape(microbatches, -1, *x.shape[1:])[i], batch
                )

            def body(carry, i):
                acc, loss_acc = carry
                (l, _), g = grad_fn(cparams, mb(i))
                acc = jax.tree.map(
                    lambda a, gg: (a.astype(jnp.float32)
                                   + gg.astype(jnp.float32)).astype(acc_dt),
                    acc, g,
                )
                return (acc, loss_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0.0)), jnp.arange(microbatches)
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            parts = {"ce": loss, "moe_aux": jnp.float32(0.0)}

        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return params, opt_state, metrics

    return train_step
