"""AdamW + cosine schedule + global-norm clipping, from scratch.

Optimizer state is a pytree of fp32 (m, v) mirroring the (fp32 master)
params, so it inherits the parameters' 2D (data x model) sharding --
ZeRO-equivalent optimizer sharding for free under XLA SPMD.

An optional int8 gradient-compression hook (error feedback) is provided for
DCI-bound multi-pod data parallelism (DESIGN.md §8); it quantizes gradients
before the (XLA-inserted) all-reduce equivalent and keeps the residual
locally.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compress: bool = False  # int8 + error feedback
    # "bfloat16" halves m/v + grad-accum residency (needed to fit 236B-scale
    # training on a single 16GB-HBM pod; precision note in EXPERIMENTS.md).
    state_dtype: str = "float32"


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray
    ef: Any  # error-feedback residuals (zeros unless grad_compress)


def cosine_lr(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptimizerConfig) -> OptState:
    sdt = jnp.dtype(cfg.state_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params)
    ef = (
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if cfg.grad_compress
        else jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    )
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros), step=jnp.int32(0), ef=ef)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    # Keep each leaf's dtype (a f32 scalar would promote bf16 trees to f32).
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _quantize_int8(g):
    absmax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    q = jnp.round(g / absmax * 127.0).astype(jnp.int8)
    return q.astype(jnp.float32) * (absmax / 127.0)


def compress_grads(grads, ef):
    """int8 quantization with error feedback: g' = Q(g + ef); ef' = g+ef-g'."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        gq = _quantize_int8(gf)
        return gq, gf - gq

    flat = jax.tree.map(one, grads, ef)
    gq = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    ef2 = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return gq, ef2


def adamw_update(params, grads, state: OptState, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_state, metrics).

    Grads keep their incoming dtype; fp32 casts happen per-leaf inside the
    (fused) update -- materializing an fp32 copy of the whole grad tree
    would cost an extra params-sized buffer per device.
    """
    if cfg.grad_compress:
        grads, ef = compress_grads(grads, state.ef)
    else:
        ef = state.ef
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m2.astype(sdt), v2.astype(sdt)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        OptState(m=new_m, v=new_v, step=step, ef=ef),
        {"grad_norm": gnorm, "lr": lr},
    )
