"""Sharded checkpointing with atomic publish, keep-N retention, async save,
and elastic restore (reshard on a different mesh / device count).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, published by writing to
``step_<N>.tmp`` and ``os.rename``-ing (atomic on POSIX).  ``LATEST`` is a
one-line pointer file rewritten after publish, so a crashed writer can never
corrupt the last good checkpoint -- the restart path (fault tolerance) reads
LATEST, falls back to the newest complete step dir, and resumes.

At real multi-host scale each host writes only its local shards (the
``shard_filter`` hook); in this container there is one host, so the filter
is the identity.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    state: Any,
    keep_n: int = 3,
    extra_meta: Optional[dict] = None,
):
    """Synchronous atomic save of a pytree ``state``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "treedef": str(_treedef_of(state)),
        **(extra_meta or {}),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    with open(os.path.join(ckpt_dir, "LATEST"), "w") as f:
        f.write(os.path.basename(final))
    _retain(ckpt_dir, keep_n)
    return final


def _retain(ckpt_dir: str, keep_n: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_n]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step_dir(ckpt_dir: str) -> Optional[str]:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            cand = os.path.join(ckpt_dir, f.read().strip())
        if os.path.isdir(cand):
            return cand
    except FileNotFoundError:
        pass
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    ) if os.path.isdir(ckpt_dir) else []
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    like: Any,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore into the structure of ``like``; optionally device_put with new
    ``shardings`` (elastic restart onto a different mesh = resharding here).

    Returns (state, step); (like, 0) if no checkpoint exists.
    """
    d = latest_step_dir(ckpt_dir)
    if d is None:
        return like, 0
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    new_leaves = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = arrays[key]
        new_leaves.append(np.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    state = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, int(manifest["step"])


class AsyncCheckpointer:
    """Background-thread saver: snapshot on host, write off the critical path."""

    def __init__(self, ckpt_dir: str, keep_n: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state: Any, extra_meta: Optional[dict] = None):
        self.wait()  # one in flight at a time
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.ckpt_dir, step, host_state, self.keep_n, extra_meta),
            daemon=True,
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
