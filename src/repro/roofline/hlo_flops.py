"""Per-instruction FLOPs attribution from optimized HLO text.

Builds a name->shape symbol table, then computes dot/convolution FLOPs
(2 * prod(result_dims) * contraction_size) and attributes them to
metadata op_name prefixes -- the profiler we get without hardware.
"""
from __future__ import annotations

import collections
import re
from typing import Dict

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z]\w*\[[0-9,]*\](?:\{[^}]*\})?))\s*([\w\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_RCDIMS_RE = re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}")
_META_RE = re.compile(r'metadata=\{[^}]*op_name="([^"]*)"')


def _dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def dot_flops_by_op(hlo_text: str, top: int = 30):
    """Returns (total_dot_flops, Counter op_name_prefix -> flops)."""
    shapes: Dict[str, str] = {}
    dot_lines = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        shapes[name] = shape_str
        if op == "dot":
            dot_lines.append((name, shape_str, line))

    by_op = collections.Counter()
    total = 0.0
    for name, shape_str, line in dot_lines:
        rdims = _dims(shape_str) or []
        # Contraction size from lhs operand shape + contracting dims.
        args = line.split("dot(", 1)[1]
        ops = _OPERAND_RE.findall(args)
        cm = _CDIMS_RE.search(line)
        csize = 1
        if ops and cm and ops[0] in shapes:
            ldims = _dims(shapes[ops[0]]) or []
            for ci in (int(x) for x in cm.group(1).split(",") if x):
                if ci < len(ldims):
                    csize *= ldims[ci]
        n = 1
        for d in rdims:
            n *= d
        flops = 2.0 * n * csize
        total += flops
        meta = _META_RE.search(line)
        label = meta.group(1) if meta else name
        # Collapse to a readable prefix.
        label = "/".join(label.split("/")[:4])[:90]
        by_op[label] += flops
    return total, by_op


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
}


def _shape_bytes_all(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in (dims.split(",") if dims else []):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_TRAFFIC_OPS = ("dot", "gather", "scatter", "dynamic-slice", "dynamic-update-slice")


def hbm_traffic_estimate(hlo_text: str) -> float:
    """Fusion-aware lower-bound HBM traffic (per device): operand + result
    bytes of dots, gathers, scatters and dynamic (update) slices.  Elementwise
    chains are assumed fused (register-resident) -- the TPU-compiler-optimal
    assumption; XLA's raw ``bytes accessed`` is the unfused upper bound.
    """
    shapes: Dict[str, str] = {}
    total = 0.0
    pending = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        shapes[name] = shape_str
        if op in _TRAFFIC_OPS:
            pending.append((name, shape_str, op, line))
    for name, shape_str, op, line in pending:
        total += _shape_bytes_all(shape_str)  # result
        args = line.split(f"{op}(", 1)[1] if f"{op}(" in line else ""
        for oname in _OPERAND_RE.findall(args)[:4]:
            if oname in shapes:
                total += _shape_bytes_all(shapes[oname])
    return total


def print_flops_report(hlo_text: str, top: int = 25):
    total, by_op = dot_flops_by_op(hlo_text)
    print(f"total dot FLOPs (per device): {total:.3e}")
    for label, fl in by_op.most_common(top):
        print(f"  {fl:12.3e} ({100*fl/total:5.1f}%)  {label}")
    return total, by_op
