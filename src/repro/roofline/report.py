"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun.json.

  PYTHONPATH=src python -m repro.roofline.report results/dryrun.json
"""
from __future__ import annotations

import json
import sys


def gib(b):
    return f"{b/2**30:.2f}"


def fmt_sci(x):
    return f"{x:.2e}" if x else "-"


def dryrun_table(results) -> str:
    lines = [
        "| arch | shape | mesh | compile s | mb | arg GiB | temp GiB | fits 16GiB | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("error"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - | "
                f"ERROR | {r['error'][:60]} |"
            )
            continue
        if not r.get("supported", True):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - | "
                f"skip | {r.get('skip_reason','')[:70]} |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('compile_s','-')} |"
            f" {r.get('microbatches','-')} | {gib(r.get('arg_bytes',0))} |"
            f" {gib(r.get('temp_bytes',0))} | {'yes' if r.get('fits_hbm') else 'NO'} | |"
        )
    return "\n".join(lines)


def roofline_table(results, mesh="single") -> str:
    lines = [
        "| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
        "MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != mesh or r.get("error") or not r.get("supported", True):
            continue
        if "t_compute_s" not in r:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} |"
            f" {r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} |"
            f" {r['bottleneck']} | {r.get('useful_compute_ratio',0):.2f} |"
            f" {r.get('roofline_fraction',0):.3f} |"
        )
    return "\n".join(lines)


def summarize(results):
    ok = [r for r in results if r.get("supported", True) and not r.get("error")]
    skip = [r for r in results if not r.get("supported", True)]
    err = [r for r in results if r.get("error")]
    fits = [r for r in ok if r.get("fits_hbm")]
    return (
        f"cells compiled: {len(ok)}; documented skips: {len(skip)}; "
        f"errors: {len(err)}; fit 16GiB/chip: {len(fits)}/{len(ok)}"
    )


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    with open(path) as f:
        results = json.load(f)
    print("## Summary\n")
    print(summarize(results))
    print("\n## Dry-run table\n")
    print(dryrun_table(results))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(results, "single"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(results, "multi"))


if __name__ == "__main__":
    main()
