"""Roofline analysis tooling (three-term model on TPU v5e constants)."""
from .analysis import (
    RooflineTerms,
    collective_bytes,
    extrapolate,
    model_flops,
    PEAK_FLOPS_BF16,
    HBM_BW,
    ICI_BW,
)
