"""Roofline analysis from compiled dry-run artifacts (task §Roofline).

Three terms per (arch x shape x mesh), on TPU v5e constants:

  compute term    = HLO_FLOPs_per_device  / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_device  / HBM_bw_per_chip
  collective term = collective_bytes_per_device / ICI_link_bw

``compiled.cost_analysis()`` numbers are PER-DEVICE for an SPMD module, so
the task's "/ chips" is already applied.  Collective bytes are parsed from
the optimized HLO text: operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (derived from result shapes
and replica-group sizes, since operands are SSA refs in HLO text).

Caveat (DESIGN.md): collectives and FLOPs inside ``while``/``scan`` bodies
appear ONCE in both HLO text and cost_analysis.  The dry-run therefore lowers
*probe* configs with unrolled layers / inner loops (cfg.scan_layers=False,
cfg.unroll_inner=True) at two depths and extrapolates linearly -- exact for
homogeneous segments (see launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# --- TPU v5e hardware constants (task-specified) ---
PEAK_FLOPS_BF16 = 197e12       # 197 TFLOP/s per chip
HBM_BW = 819e9                 # 819 GB/s per chip
ICI_BW = 50e9                  # ~50 GB/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\("
)
_GROUPS_COMPACT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(result_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_COMPACT_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        return max(1, len(ids))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum of collective *operand* bytes by op type (per device)."""
    out: Dict[str, float] = {
        "all-gather": 0.0,
        "all-reduce": 0.0,
        "reduce-scatter": 0.0,
        "all-to-all": 0.0,
        "collective-permute": 0.0,
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_str, op, start = m.group(1), m.group(2), m.group(3)
        if "-done" in line.split("=")[1][:60]:
            continue
        rbytes = _shape_bytes(result_str)
        g = _group_size(line)
        if op == "all-gather":
            operand = rbytes / g
        elif op == "reduce-scatter":
            operand = rbytes * g
        else:
            operand = rbytes
        out[op] += operand
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float               # per device
    bytes_hbm: float           # per device
    bytes_coll: float          # per device
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    def finalize(self) -> "RooflineTerms":
        self.t_compute = self.flops / PEAK_FLOPS_BF16
        self.t_memory = self.bytes_hbm / HBM_BW
        self.t_collective = self.bytes_coll / ICI_BW
        return self

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline lower-bound step time (max of the three terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.bytes_hbm,
            "collective_bytes_per_device": self.bytes_coll,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "t_bound_s": self.t_bound,
        }


def extrapolate(f1: float, f2: float, n1: int, n2: int, n_full: int) -> float:
    """Linear per-segment-unit extrapolation: cost(n) = f1 + (n-n1)*delta."""
    delta = (f2 - f1) / max(1, (n2 - n1))
    return f1 + (n_full - n1) * delta


def model_flops(n_active_params: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS convention: 6*N*D train (fwd+bwd), 2*N*D inference."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens
