"""Bound-candidate computation and update (paper Eqs. 4a/4b via 5a/5b).

Candidate formulas, written with residual activities (derivation in
DESIGN.md §1):

  a_ij > 0:  lcand = (lhs_i - maxres_ij) / a_ij    ucand = (rhs_i - minres_ij) / a_ij
  a_ij < 0:  lcand = (rhs_i - minres_ij) / a_ij    ucand = (lhs_i - maxres_ij) / a_ij

A candidate is *valid* only if the side it uses is finite (lhs > -INF resp.
rhs < +INF) and the residual activity it uses is finite.  Invalid candidates
are emitted as -INF (lower) / +INF (upper) so that the column-wise max/min
reduction ignores them -- this is the mask-before-reduce that replaces the
paper's "check before atomic" trick (§3.5) on TPU.
"""
from __future__ import annotations

import jax.numpy as jnp

from .types import INF, int_round_slack


def bound_candidates(a, lhs_row, rhs_row, min_res, max_res, inf: float = INF):
    """Per-nonzero lower/upper bound candidates.

    Args:
      a: (nnz,) coefficients (0 == padding).
      lhs_row, rhs_row: (nnz,) constraint sides of each nonzero's row.
      min_res, max_res: (nnz,) residual activities (sentinel-infinite).

    Returns:
      (lcand, ucand): candidates with invalid entries at -inf/+inf.
    """
    pos = a > 0
    pad = a == 0
    safe_a = jnp.where(pad, 1.0, a)

    # Numerators per Eqs. 4a/4b in residual form.
    num_l = jnp.where(pos, lhs_row - max_res, rhs_row - min_res)
    num_u = jnp.where(pos, rhs_row - min_res, lhs_row - max_res)

    lcand = num_l / safe_a
    ucand = num_u / safe_a

    valid_l = jnp.where(
        pos,
        (lhs_row > -inf) & (max_res < inf),
        (rhs_row < inf) & (min_res > -inf),
    ) & ~pad
    valid_u = jnp.where(
        pos,
        (rhs_row < inf) & (min_res > -inf),
        (lhs_row > -inf) & (max_res < inf),
    ) & ~pad

    lcand = jnp.where(valid_l, jnp.clip(lcand, -inf, inf), -inf)
    ucand = jnp.where(valid_u, jnp.clip(ucand, -inf, inf), inf)
    return lcand, ucand


def round_candidates(lcand, ucand, is_int_col, int_eps: float, inf: float = INF):
    """Integrality strengthening: ceil lower / floor upper (paper Step 3).

    Low-precision candidates get the dtype's scale-aware rounding slack
    (:func:`core.types.int_round_slack`) so tier-arithmetic error can
    never push a ceil/floor across an integer the exact candidate would
    not cross; fp64 rounds exactly."""
    do_round_l = is_int_col & (jnp.abs(lcand) < inf)
    do_round_u = is_int_col & (jnp.abs(ucand) < inf)
    slack = int_round_slack(jnp.result_type(lcand))
    sl = su = int_eps
    if slack:  # static per dtype: fp64 keeps the exact scalar subtraction
        sl = int_eps + slack * jnp.maximum(1.0, jnp.abs(lcand))
        su = int_eps + slack * jnp.maximum(1.0, jnp.abs(ucand))
    lcand = jnp.where(do_round_l, jnp.ceil(lcand - sl), lcand)
    ucand = jnp.where(do_round_u, jnp.floor(ucand + su), ucand)
    return lcand, ucand


def improved_lb(new_lb, old_lb, eps: float):
    """Scale-aware strict improvement test (tolerance-based termination)."""
    return new_lb > old_lb + eps * jnp.maximum(1.0, jnp.abs(old_lb))


def improved_ub(new_ub, old_ub, eps: float):
    return new_ub < old_ub - eps * jnp.maximum(1.0, jnp.abs(old_ub))


def widen_outward(lcand, ucand, outward: float):
    """Round accepted tightenings *outward* (fp32-tier safety widening).

    Nextafter-style: the accepted lower candidate is pushed DOWN and the
    upper candidate UP by ``outward * max(1, |candidate|)`` -- a scale-aware
    multiple of the fp32 ulp (``outward`` defaults to ``2**-17``, ~64 ulps,
    see ``PropagatorConfig.outward_eps_f32``) that dominates the rounding
    error the fp32 activity/candidate arithmetic can accumulate within a
    round.  Widened bounds are therefore never TIGHTER than the exact-
    arithmetic round would produce from the same state; by induction the
    whole fp32 trajectory stays outside the fp64 fixed point, so promotion
    is an exact cast and infeasibility is never falsely declared.
    ``outward == 0.0`` is the exact fp64 merge (identity)."""
    lcand = lcand - outward * jnp.maximum(1.0, jnp.abs(lcand))
    ucand = ucand + outward * jnp.maximum(1.0, jnp.abs(ucand))
    return lcand, ucand


def canonical_infinite(lb, ub, inf: float = INF):
    """Restore exact ``+-inf`` sentinels after a cross-dtype cast.

    fp32 rounds the sentinel ``1e20`` up to ``1.00000002e20``, so bounds
    promoted from an fp32 tier carry a non-canonical (though still
    semantically infinite -- every engine tests ``|v| >= inf``) sentinel.
    Called on the CAST bounds at every two-tier promotion so untouched
    infinite bounds come out of a tiered run bitwise identical to the
    single-dtype run's.  Clamping in fp32 would be a no-op (the canonical
    value is not representable); always canonicalize in the final dtype."""
    lb = jnp.where(lb <= -inf, -inf, lb)
    ub = jnp.where(ub >= inf, inf, ub)
    return lb, ub


def apply_updates(
    lb, ub, best_lcand, best_ucand, eps: float, inf: float = INF,
    outward: float = 0.0,
):
    """Merge column-reduced candidates into the bounds.

    Returns (new_lb, new_ub, changed) where ``changed`` is a scalar bool.
    Non-improving candidates leave the bound untouched (so no epsilon drift
    accumulates across rounds).  ``outward > 0`` (the fp32 tier) widens
    every accepted tightening back toward the old bound by
    :func:`widen_outward`; the improvement test runs on the UNwidened
    candidate, so ``outward < eps`` keeps accepted updates strictly
    improving and the fixed point terminating.
    """
    take_l = improved_lb(best_lcand, lb, eps)
    take_u = improved_ub(best_ucand, ub, eps)
    if outward:
        best_lcand, best_ucand = widen_outward(best_lcand, best_ucand, outward)
    new_lb = jnp.where(take_l, jnp.clip(best_lcand, -inf, inf), lb)
    new_ub = jnp.where(take_u, jnp.clip(best_ucand, inf * -1, inf), ub)
    changed = jnp.any(take_l) | jnp.any(take_u)
    return new_lb, new_ub, changed


def apply_updates_batch(
    lb, ub, best_lcand, best_ucand, eps: float, inf: float = INF,
    outward: float = 0.0,
):
    """Batched merge: ``(B, n_pad)`` bounds/candidates -> per-instance change.

    Identical elementwise semantics to :func:`apply_updates` (including the
    fp32-tier ``outward`` widening); only the ``changed`` reduction stays
    per instance (axis -1), which is what lets a batched fixed point
    converge each instance independently.
    """
    take_l = improved_lb(best_lcand, lb, eps)
    take_u = improved_ub(best_ucand, ub, eps)
    if outward:
        best_lcand, best_ucand = widen_outward(best_lcand, best_ucand, outward)
    new_lb = jnp.where(take_l, jnp.clip(best_lcand, -inf, inf), lb)
    new_ub = jnp.where(take_u, jnp.clip(best_ucand, -inf, inf), ub)
    changed = jnp.any(take_l, axis=-1) | jnp.any(take_u, axis=-1)
    return new_lb, new_ub, changed


def progress_measure(lb_old, ub_old, lb_new, ub_new):
    """Per-round *measure of progress* (Sofranac et al., arXiv:2106.07573,
    adapted to sentinel-infinite bounds).

    Scale-normalized total bound movement of one round, reduced over the
    trailing (variable) axis of the two bound planes:

        sum_j  (lb' - lb) / (1 + max(|lb|, |lb'|))
             + (ub - ub') / (1 + max(|ub|, |ub'|))

    Each term is ~1 for an infinite->finite jump (the sentinel dominates
    the denominator), ~|delta|/|bound| for a finite tighten, and exactly 0
    for an untouched variable -- so the scalar is comparable across rounds
    and instances regardless of scaling, and monotone tightening keeps it
    >= 0.  Cheap: elementwise + one reduction over ``(2, n_pad)`` planes,
    computed inside the device fixed-point loops (no host sync).  Batched
    ``(B, n_pad)`` inputs reduce per instance."""
    dl = lb_new - lb_old
    du = ub_old - ub_new
    sl = 1.0 + jnp.maximum(jnp.abs(lb_old), jnp.abs(lb_new))
    su = 1.0 + jnp.maximum(jnp.abs(ub_old), jnp.abs(ub_new))
    return jnp.sum(dl / sl + du / su, axis=-1)
