"""Bound-candidate computation and update (paper Eqs. 4a/4b via 5a/5b).

Candidate formulas, written with residual activities (derivation in
DESIGN.md §1):

  a_ij > 0:  lcand = (lhs_i - maxres_ij) / a_ij    ucand = (rhs_i - minres_ij) / a_ij
  a_ij < 0:  lcand = (rhs_i - minres_ij) / a_ij    ucand = (lhs_i - maxres_ij) / a_ij

A candidate is *valid* only if the side it uses is finite (lhs > -INF resp.
rhs < +INF) and the residual activity it uses is finite.  Invalid candidates
are emitted as -INF (lower) / +INF (upper) so that the column-wise max/min
reduction ignores them -- this is the mask-before-reduce that replaces the
paper's "check before atomic" trick (§3.5) on TPU.
"""
from __future__ import annotations

import jax.numpy as jnp

from .types import INF


def bound_candidates(a, lhs_row, rhs_row, min_res, max_res, inf: float = INF):
    """Per-nonzero lower/upper bound candidates.

    Args:
      a: (nnz,) coefficients (0 == padding).
      lhs_row, rhs_row: (nnz,) constraint sides of each nonzero's row.
      min_res, max_res: (nnz,) residual activities (sentinel-infinite).

    Returns:
      (lcand, ucand): candidates with invalid entries at -inf/+inf.
    """
    pos = a > 0
    pad = a == 0
    safe_a = jnp.where(pad, 1.0, a)

    # Numerators per Eqs. 4a/4b in residual form.
    num_l = jnp.where(pos, lhs_row - max_res, rhs_row - min_res)
    num_u = jnp.where(pos, rhs_row - min_res, lhs_row - max_res)

    lcand = num_l / safe_a
    ucand = num_u / safe_a

    valid_l = jnp.where(
        pos,
        (lhs_row > -inf) & (max_res < inf),
        (rhs_row < inf) & (min_res > -inf),
    ) & ~pad
    valid_u = jnp.where(
        pos,
        (rhs_row < inf) & (min_res > -inf),
        (lhs_row > -inf) & (max_res < inf),
    ) & ~pad

    lcand = jnp.where(valid_l, jnp.clip(lcand, -inf, inf), -inf)
    ucand = jnp.where(valid_u, jnp.clip(ucand, -inf, inf), inf)
    return lcand, ucand


def round_candidates(lcand, ucand, is_int_col, int_eps: float, inf: float = INF):
    """Integrality strengthening: ceil lower / floor upper (paper Step 3)."""
    do_round_l = is_int_col & (jnp.abs(lcand) < inf)
    do_round_u = is_int_col & (jnp.abs(ucand) < inf)
    lcand = jnp.where(do_round_l, jnp.ceil(lcand - int_eps), lcand)
    ucand = jnp.where(do_round_u, jnp.floor(ucand + int_eps), ucand)
    return lcand, ucand


def improved_lb(new_lb, old_lb, eps: float):
    """Scale-aware strict improvement test (tolerance-based termination)."""
    return new_lb > old_lb + eps * jnp.maximum(1.0, jnp.abs(old_lb))


def improved_ub(new_ub, old_ub, eps: float):
    return new_ub < old_ub - eps * jnp.maximum(1.0, jnp.abs(old_ub))


def apply_updates(lb, ub, best_lcand, best_ucand, eps: float, inf: float = INF):
    """Merge column-reduced candidates into the bounds.

    Returns (new_lb, new_ub, changed) where ``changed`` is a scalar bool.
    Non-improving candidates leave the bound untouched (so no epsilon drift
    accumulates across rounds).
    """
    take_l = improved_lb(best_lcand, lb, eps)
    take_u = improved_ub(best_ucand, ub, eps)
    new_lb = jnp.where(take_l, jnp.clip(best_lcand, -inf, inf), lb)
    new_ub = jnp.where(take_u, jnp.clip(best_ucand, inf * -1, inf), ub)
    changed = jnp.any(take_l) | jnp.any(take_u)
    return new_lb, new_ub, changed


def apply_updates_batch(lb, ub, best_lcand, best_ucand, eps: float, inf: float = INF):
    """Batched merge: ``(B, n_pad)`` bounds/candidates -> per-instance change.

    Identical elementwise semantics to :func:`apply_updates`; only the
    ``changed`` reduction stays per instance (axis -1), which is what lets a
    batched fixed point converge each instance independently.
    """
    take_l = improved_lb(best_lcand, lb, eps)
    take_u = improved_ub(best_ucand, ub, eps)
    new_lb = jnp.where(take_l, jnp.clip(best_lcand, -inf, inf), lb)
    new_ub = jnp.where(take_u, jnp.clip(best_ucand, -inf, inf), ub)
    changed = jnp.any(take_l, axis=-1) | jnp.any(take_u, axis=-1)
    return new_lb, new_ub, changed
