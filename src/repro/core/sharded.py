"""Distributed domain propagation via shard_map (DESIGN.md §3).

Scaling story: nonzeros are partitioned *equally* across devices (static
equal-nnz balancing == the CSR-adaptive load-balancing idea applied at
cluster scope; doubles as straggler mitigation).  Bound vectors (O(n)) are
replicated -- they are tiny next to O(nnz).  One round becomes:

  1. local activity partials  -> psum     (all-reduce ADD of 4 x (m,) arrays)
  2. local candidates + local segment-max/min over columns
  3. pmax(lb') / pmin(ub')                (all-reduce MAX/MIN of 2 x (n,) arrays)

Step 3 is the TPU-native replacement for the paper's atomicMax/atomicMin: the
column-wise reduction over candidates *is* an all-reduce with max/min
combiner.  The fixed point runs inside ``lax.while_loop`` *under* shard_map,
so a whole multi-pod propagation is a single XLA dispatch with zero host
involvement -- the multi-pod generalization of the paper's "runs entirely on
the GPU" (§3.7).
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.6 exports shard_map at the top level
    from jax import shard_map as _shard_map
except ImportError:  # older jax: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import Mesh, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-compatible ``shard_map``: newer jax spells the "skip the
    varying-manual-axes check" flag ``check_vma``, older jax ``check_rep``."""
    try:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    except TypeError:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
        )

from . import activities as act
from . import bounds as bnd
from .propagator import donate_kwargs, initial_bounds
from .sparse import Problem
from .types import DEFAULT_CONFIG, INF, PropagationResult, PropagatorConfig


def partition_nnz(p: Problem, num_shards: int):
    """Equal-nnz padding + partition. Returns flat (padded) nnz arrays."""
    csr = p.csr
    nnz = csr.nnz
    per = -(-nnz // num_shards)
    padded = per * num_shards
    pad = padded - nnz

    def padf(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])

    row_id = padf(csr.row_ids(), 0)
    col = padf(csr.col, 0)
    val = padf(csr.val, 0)  # val == 0 marks padding everywhere downstream
    return row_id, col, val


def _sharded_round(
    row_id, col, val, lhs, rhs, is_int, lb, ub, *, m, n, eps, int_eps, inf, axes
):
    """One round on the local nnz shard + collectives. Runs under shard_map."""
    lb_col = lb[col]
    ub_col = ub[col]
    min_fin, min_inf, max_fin, max_inf = act.nnz_contributions(val, lb_col, ub_col, inf)

    seg = lambda x: jax.ops.segment_sum(x, row_id, num_segments=m)
    # Local partial row aggregates -> global via all-reduce(add).
    row_min_fin = jax.lax.psum(seg(min_fin), axes)
    row_min_inf = jax.lax.psum(seg(min_inf), axes)
    row_max_fin = jax.lax.psum(seg(max_fin), axes)
    row_max_inf = jax.lax.psum(seg(max_inf), axes)

    min_res = act.residual_activities(
        val, min_fin, min_inf, row_min_fin[row_id], row_min_inf[row_id], "min", inf
    )
    max_res = act.residual_activities(
        val, max_fin, max_inf, row_max_fin[row_id], row_max_inf[row_id], "max", inf
    )
    lcand, ucand = bnd.bound_candidates(
        val, lhs[row_id], rhs[row_id], min_res, max_res, inf
    )
    lcand, ucand = bnd.round_candidates(lcand, ucand, is_int[col], int_eps, inf)

    # Local column reduction, then the atomic-free global min/max combine.
    best_l = jax.lax.pmax(jax.ops.segment_max(lcand, col, num_segments=n), axes)
    best_u = jax.lax.pmin(jax.ops.segment_min(ucand, col, num_segments=n), axes)

    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


def propagate_sharded(
    p: Problem,
    mesh: Mesh,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    dtype=None,
    lb0=None,
    ub0=None,
) -> PropagationResult:
    """Distributed fixed-point propagation over every axis of ``mesh``.

    ``lb0``/``ub0`` warm-start the fixed point from caller-supplied bounds
    (default: the problem's root bounds); the replicated bound vectors are
    the only per-call state, so one partitioned matrix serves any node."""
    axes = tuple(mesh.axis_names)
    num_shards = int(np.prod(mesh.devices.shape))
    dtype = dtype or p.csr.val.dtype
    eps = cfg.eps_for(dtype)

    row_id, col, val = partition_nnz(p, num_shards)
    row_id = jnp.asarray(row_id)
    col = jnp.asarray(col)
    val = jnp.asarray(val, dtype=dtype)
    lhs = jnp.asarray(p.lhs, dtype=dtype)
    rhs = jnp.asarray(p.rhs, dtype=dtype)
    lb0, ub0 = initial_bounds(
        (jnp.asarray(p.lb, dtype=dtype), jnp.asarray(p.ub, dtype=dtype)),
        lb0, ub0, dtype, p.n,
    )
    is_int = jnp.asarray(p.is_int)
    m, n = p.m, p.n

    round_fn = functools.partial(
        _sharded_round,
        m=m,
        n=n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        axes=axes,
    )

    def shard_body(row_id, col, val, lhs, rhs, is_int, lb0, ub0):
        def body(state):
            lb, ub, _, rounds = state
            lb, ub, changed = round_fn(row_id, col, val, lhs, rhs, is_int, lb, ub)
            return lb, ub, changed, rounds + 1

        def cond(state):
            _, _, changed, rounds = state
            return changed & (rounds < cfg.max_rounds)

        lb, ub, changed, rounds = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        infeasible = jnp.any(lb > ub + cfg.feas_eps)
        return lb, ub, rounds, ~changed, infeasible

    nnz_spec = P(axes)  # flat nnz dim sharded over ALL mesh axes jointly
    rep = P()
    fn = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(nnz_spec, nnz_spec, nnz_spec, rep, rep, rep, rep, rep),
        out_specs=(rep, rep, rep, rep, rep),
        check_vma=False,
    )
    # Zero-copy fixed point: the freshly built bound buffers are donated into
    # the on-device while_loop where the backend implements donation.
    lb, ub, rounds, converged, infeasible = jax.jit(fn, **donate_kwargs(argnums=(6, 7)))(
        row_id, col, val, lhs, rhs, is_int, lb0, ub0
    )
    return PropagationResult(lb, ub, rounds, converged, infeasible)


# ---------------------------------------------------------------------------
# Beyond-paper variant (§Perf): ROW-partitioned distribution
# ---------------------------------------------------------------------------


def partition_rows(p: Problem, num_shards: int):
    """Greedy nnz-balanced ROW partition (CSR-adaptive's row-block balancing
    at cluster scope).  Every row lives entirely on one shard, so activities
    complete locally and the per-round psum of 4 x (m,) row aggregates
    disappears -- only the (n,)-sized bound combine remains.

    Returns per-shard dense arrays, all padded to common sizes:
      val, col, lrow (shards, NNZ) ; lhs, rhs (shards, R)
    where ``lrow`` is the shard-local row index (R == padding row).
    """
    csr = p.csr
    lengths = np.diff(csr.row_ptr).astype(np.int64)
    order = np.argsort(-lengths)  # longest rows first
    loads = np.zeros(num_shards, dtype=np.int64)
    assign = [[] for _ in range(num_shards)]
    for r in order:
        s = int(np.argmin(loads))
        assign[s].append(int(r))
        loads[s] += max(1, lengths[r])

    max_rows = max(len(a) for a in assign)
    max_nnz = int(
        max(sum(int(lengths[r]) for r in a) for a in assign) or 1
    )
    val = np.zeros((num_shards, max_nnz), dtype=csr.val.dtype)
    col = np.zeros((num_shards, max_nnz), dtype=np.int32)
    lrow = np.full((num_shards, max_nnz), max_rows, dtype=np.int32)
    lhs = np.full((num_shards, max_rows), -INF, dtype=csr.val.dtype)
    rhs = np.full((num_shards, max_rows), INF, dtype=csr.val.dtype)
    for s, rows in enumerate(assign):
        k = 0
        for li, r in enumerate(rows):
            a, b = int(csr.row_ptr[r]), int(csr.row_ptr[r + 1])
            w = b - a
            val[s, k : k + w] = csr.val[a:b]
            col[s, k : k + w] = csr.col[a:b]
            lrow[s, k : k + w] = li
            lhs[s, li] = p.lhs[r]
            rhs[s, li] = p.rhs[r]
            k += w
    return val, col, lrow, lhs, rhs, max_rows


def _row_sharded_round(
    lrow, col, val, lhs, rhs, is_int, lb, ub, *, rows, n, eps, int_eps, inf, axes
):
    """One round with rows complete on-shard: NO activity collective."""
    lb_col = lb[col]
    ub_col = ub[col]
    min_fin, min_inf, max_fin, max_inf = act.nnz_contributions(val, lb_col, ub_col, inf)
    seg = lambda x: jax.ops.segment_sum(x, lrow, num_segments=rows + 1)
    row_min_fin = seg(min_fin)
    row_min_inf = seg(min_inf)
    row_max_fin = seg(max_fin)
    row_max_inf = seg(max_inf)

    min_res = act.residual_activities(
        val, min_fin, min_inf, row_min_fin[lrow], row_min_inf[lrow], "min", inf
    )
    max_res = act.residual_activities(
        val, max_fin, max_inf, row_max_fin[lrow], row_max_inf[lrow], "max", inf
    )
    lhs1 = jnp.concatenate([lhs, jnp.full((1,), -inf, lhs.dtype)])
    rhs1 = jnp.concatenate([rhs, jnp.full((1,), inf, rhs.dtype)])
    lcand, ucand = bnd.bound_candidates(
        val, lhs1[lrow], rhs1[lrow], min_res, max_res, inf
    )
    lcand, ucand = bnd.round_candidates(lcand, ucand, is_int[col], int_eps, inf)

    # The only collective of the round: the atomic-free bound combine.
    best_l = jax.lax.pmax(jax.ops.segment_max(lcand, col, num_segments=n), axes)
    best_u = jax.lax.pmin(jax.ops.segment_min(ucand, col, num_segments=n), axes)
    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


def propagate_sharded_rows(
    p: Problem,
    mesh: Mesh,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    dtype=None,
    lb0=None,
    ub0=None,
) -> PropagationResult:
    """Row-partitioned distributed propagation (beyond-paper §Perf variant).

    ``lb0``/``ub0`` warm-start the fixed point from caller-supplied bounds."""
    axes = tuple(mesh.axis_names)
    num_shards = int(np.prod(mesh.devices.shape))
    dtype = dtype or p.csr.val.dtype
    eps = cfg.eps_for(dtype)

    val, col, lrow, lhs, rhs, rows = partition_rows(p, num_shards)
    n = p.n
    round_fn = functools.partial(
        _row_sharded_round,
        rows=rows,
        n=n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        axes=axes,
    )

    def shard_body(lrow, col, val, lhs, rhs, is_int, lb0, ub0):
        lrow, col, val = lrow[0], col[0], val[0]
        lhs, rhs = lhs[0], rhs[0]

        def body(state):
            lb, ub, _, r = state
            lb, ub, ch = round_fn(lrow, col, val, lhs, rhs, is_int, lb, ub)
            return lb, ub, ch, r + 1

        def cond(state):
            return state[2] & (state[3] < cfg.max_rounds)

        lb, ub, ch, r = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        return lb, ub, r, ~ch, jnp.any(lb > ub + cfg.feas_eps)

    shard_spec = P(axes, None)
    rep = P()
    fn = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(shard_spec,) * 5 + (rep, rep, rep),
        out_specs=(rep, rep, rep, rep, rep),
        check_vma=False,
    )
    lb0, ub0 = initial_bounds(
        (jnp.asarray(p.lb, dtype=dtype), jnp.asarray(p.ub, dtype=dtype)),
        lb0, ub0, dtype, p.n,
    )
    lb, ub, r, converged, infeasible = jax.jit(fn, **donate_kwargs(argnums=(6, 7)))(
        jnp.asarray(lrow), jnp.asarray(col), jnp.asarray(val, dtype=dtype),
        jnp.asarray(lhs, dtype=dtype), jnp.asarray(rhs, dtype=dtype),
        jnp.asarray(p.is_int), lb0, ub0,
    )
    return PropagationResult(lb, ub, r, converged, infeasible)


# ---------------------------------------------------------------------------
# Batch-axis sharding: many instances, devices split the batch
# ---------------------------------------------------------------------------


# Built shard runners, LRU-cached per (problem identities, mesh, config):
# the serving loop re-propagates the same request list, and rebuilding the
# shard_map closure per call would recompile the whole multi-device fixed
# point every time (mirrors the runner caches in kernels.ops).
_batch_shard_cache: "dict" = {}
_BATCH_SHARD_CACHE_CAPACITY = 4


def _build_batch_shard_runner(problems, mesh, cfg, tile_rows, tile_width, dtype):
    from ..kernels.ops import (  # lazy: kernels imports core at module scope
        batched_reference_round,
        prepare_problem_batch,
    )
    from .propagator import batched_fixed_point
    from .sparse import col_pad, pack_problems

    axes = tuple(mesh.axis_names)
    num_shards = int(np.prod(mesh.devices.shape))
    n_pad = max(col_pad(p.n) for p in problems)

    # Greedy nnz-balanced instance partition (the CSR-adaptive balancing
    # idea at batch scope -- mirrors partition_rows, one level up).
    order = sorted(range(len(problems)), key=lambda i: -problems[i].nnz)
    loads = np.zeros(num_shards, dtype=np.int64)
    assign = [[] for _ in range(num_shards)]
    for i in order:
        s = int(np.argmin(loads))
        assign[s].append(i)
        loads[s] += max(1, problems[i].nnz)

    # One flat bucket per shard (forced common n_pad), then pad every
    # per-shard array to the common maxima so the shard axis stacks.  Idle
    # shards carry the SMALLEST instance as an all-inactive dummy: it never
    # iterates (active0 False), so it costs only the dispatch.
    preps = []
    for members in assign:
        sub = [problems[i] for i in members] or [problems[order[-1]]]
        (bucket,) = pack_problems(
            sub, tile_rows=tile_rows, tile_width=tile_width, n_pad=n_pad
        )
        preps.append((members, bucket, prepare_problem_batch(bucket, dtype)))

    t_max = max(prep.d.val.shape[0] for _, _, prep in preps)
    b_max = max(prep.size for _, _, prep in preps)
    m_max = max(prep.m_total for _, _, prep in preps)
    fits = all(prep.fits_one_chunk for _, _, prep in preps)
    eps = cfg.eps_for(preps[0][2].d.val.dtype)

    def pad_to(x, size, axis=0, fill=0):
        pad = size - x.shape[axis]
        if pad == 0:
            return np.asarray(x)
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return np.pad(np.asarray(x), widths, constant_values=fill)

    stacked = []
    for members, bucket, prep in preps:
        d = prep.d
        nb = len(bucket.problems) if members else 0  # idle dummy: inactive
        stacked.append(dict(
            # Padding tiles: val == 0 everywhere -> all candidates are
            # sentinels; their rows/cols point at the extra dummy row m_max
            # / instance 0's column 0, both reduction-identity targets.
            val=pad_to(d.val, t_max),
            col_g=pad_to(d.col_g, t_max),
            ii_g=pad_to(d.ii_g, t_max),
            chunk_row=pad_to(d.chunk_row, t_max, fill=m_max),
            lhs_g=pad_to(d.lhs_g, t_max),
            rhs_g=pad_to(d.rhs_g, t_max),
            lb0=pad_to(d.lb0, b_max),
            ub0=pad_to(d.ub0, b_max),
            active0=(np.arange(b_max) < nb),
            col_valid=pad_to(d.col_valid, b_max),
        ))
    j = lambda name: jnp.asarray(np.stack([s[name] for s in stacked]))

    round_kw = dict(
        m_total=m_max, n_pad=n_pad, fits_one_chunk=fits,
        eps=eps, int_eps=cfg.int_eps, inf=cfg.inf,
    )

    def shard_body(val, col_g, crow, ii_g, lhs_g, rhs_g, lb0, ub0, active0, col_valid):
        # Each shard sees a leading axis of length 1: its own super-tile.
        val, col_g, crow, ii_g = val[0], col_g[0], crow[0], ii_g[0]
        lhs_g, rhs_g = lhs_g[0], rhs_g[0]
        lb0, ub0, active0, col_valid = lb0[0], ub0[0], active0[0], col_valid[0]

        def round_fn(lb, ub, active):
            return batched_reference_round(
                val, col_g, ii_g, crow, lhs_g, rhs_g, lb, ub, active, **round_kw
            )

        lb, ub, rounds, converged = batched_fixed_point(
            round_fn, lb0, ub0, cfg.max_rounds, active0
        )
        infeasible = jnp.any((lb > ub + cfg.feas_eps) & col_valid, axis=-1)
        add = lambda x: x[None]
        return add(lb), add(ub), add(rounds), add(converged), add(infeasible)

    def spec_for(rank):  # shard axis split over ALL mesh axes jointly
        return P(axes, *([None] * (rank - 1)))

    args = (
        j("val"), j("col_g"), j("chunk_row"), j("ii_g"),
        j("lhs_g"), j("rhs_g"), j("lb0"), j("ub0"), j("active0"), j("col_valid"),
    )
    fn = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=tuple(spec_for(a.ndim) for a in args),
        out_specs=(spec_for(3), spec_for(3), spec_for(2), spec_for(2), spec_for(2)),
        check_vma=False,
    )
    return {
        "preps": preps,
        "args": args,
        "run": jax.jit(fn, **donate_kwargs(argnums=(6, 7))),
    }


def propagate_batch_sharded(
    problems,
    mesh: Mesh,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
):
    """Shard the *batch* axis of packed instances across every mesh device.

    The serving-scale complement of :func:`propagate_sharded`: instead of
    splitting one instance's nonzeros, instances are greedily partitioned
    across devices (balanced by nonzero count), each device's share is
    packed into its own flat super-tile, and every device runs its batched
    fixed point to local convergence -- instances are independent, so one
    multi-device propagation of thousands of subproblems is a single XLA
    dispatch with ZERO collectives and zero host involvement.  Per-shard
    layouts are padded to common shapes (zero tiles / inactive dummy
    instances), which cost their shard nothing but the dispatch.  The
    packed layout and the jitted shard runner are LRU-cached per problem
    list, so a serving loop re-propagating the same instances pays
    partitioning and compilation once.

    Returns one ``PropagationResult`` per instance, input order.
    """
    from .propagator import owned_copy

    problems = list(problems)
    if not problems:
        return []
    dt = np.dtype(dtype).str if dtype is not None else None
    key = (tuple(id(p) for p in problems), mesh, cfg, tile_rows, tile_width, dt)
    hit = _batch_shard_cache.get(key)
    if hit is not None and all(a is b for a, b in zip(hit[0], problems)):
        built = hit[1]
    else:
        built = _build_batch_shard_runner(
            problems, mesh, cfg, tile_rows, tile_width, dtype
        )
        _batch_shard_cache[key] = (tuple(problems), built)
        while len(_batch_shard_cache) > _BATCH_SHARD_CACHE_CAPACITY:
            _batch_shard_cache.pop(next(iter(_batch_shard_cache)))

    args = list(built["args"])
    # Private copies of the cached initial bounds: they are donated into the
    # on-device loop and must not invalidate the cached runner's buffers.
    args[6], args[7] = owned_copy(args[6]), owned_copy(args[7])
    lb, ub, rounds, converged, infeasible = built["run"](*args)

    out = [None] * len(problems)
    for s, (members, bucket, prep) in enumerate(built["preps"]):
        if not members:
            continue  # idle shard carries an inactive dummy instance
        for i, (sub_idx, p) in enumerate(zip(bucket.indices, bucket.problems)):
            out[members[sub_idx]] = PropagationResult(
                lb[s, i, : p.n], ub[s, i, : p.n],
                rounds[s, i], converged[s, i], infeasible[s, i],
            )
    return out


def lower_sharded(
    p: Problem,
    mesh: Mesh,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    dtype=jnp.float32,
):
    """AOT lowering entry point for the dry-run (no execution)."""
    axes = tuple(mesh.axis_names)
    num_shards = int(np.prod(mesh.devices.shape))
    eps = cfg.eps_for(dtype)
    m, n = p.m, p.n
    nnz = p.csr.nnz
    per = -(-nnz // num_shards)
    padded = per * num_shards

    round_fn = functools.partial(
        _sharded_round,
        m=m,
        n=n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        axes=axes,
    )

    def shard_body(row_id, col, val, lhs, rhs, is_int, lb0, ub0):
        def body(state):
            lb, ub, _, rounds = state
            lb, ub, changed = round_fn(row_id, col, val, lhs, rhs, is_int, lb, ub)
            return lb, ub, changed, rounds + 1

        def cond(state):
            _, _, changed, rounds = state
            return changed & (rounds < cfg.max_rounds)

        lb, ub, changed, rounds = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        return lb, ub, rounds, ~changed

    nnz_spec = P(axes)
    rep = P()
    fn = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(nnz_spec, nnz_spec, nnz_spec, rep, rep, rep, rep, rep),
        out_specs=(rep, rep, rep, rep),
        check_vma=False,
    )
    sds = jax.ShapeDtypeStruct
    args = (
        sds((padded,), jnp.int32),
        sds((padded,), jnp.int32),
        sds((padded,), dtype),
        sds((m,), dtype),
        sds((m,), dtype),
        sds((n,), jnp.bool_),
        sds((n,), dtype),
        sds((n,), dtype),
    )
    return jax.jit(fn).lower(*args)
