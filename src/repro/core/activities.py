"""Activity computation (paper Def. 1 + §3.4 infinity counting), pure JAX.

The nonzero-level computation is shared by the pure-JAX propagator, the
shard_map-distributed propagator and the Pallas kernel oracle: given the
per-nonzero coefficient ``a`` and the bounds of its column, emit

  * the finite minimum/maximum activity contributions, and
  * 0/1 infinity counters

which are then segment-summed per row.  Keeping this in one place guarantees
that every implementation agrees bit-for-bit on the sentinel-infinity
semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import INF, Activities


def nnz_contributions(a, lb_col, ub_col, inf: float = INF):
    """Per-nonzero activity contributions.

    Args:
      a: (nnz,) coefficients (0 == padding; contributes nothing).
      lb_col, ub_col: (nnz,) bounds of each nonzero's column, pre-gathered.

    Returns:
      (min_fin, min_inf, max_fin, max_inf): finite contributions (0 where the
      chosen bound is infinite or at padding) and int32 0/1 infinity counters.
    """
    pos = a > 0
    pad = a == 0
    # Minimum activity picks lb where a>0 else ub (Def. 1 / Eq. 3a).
    b_min = jnp.where(pos, lb_col, ub_col)
    # Maximum activity picks ub where a>0 else lb (Eq. 3b).
    b_max = jnp.where(pos, ub_col, lb_col)
    min_is_inf = (jnp.abs(b_min) >= inf) & ~pad
    max_is_inf = (jnp.abs(b_max) >= inf) & ~pad
    min_fin = jnp.where(min_is_inf | pad, 0.0, a * b_min)
    max_fin = jnp.where(max_is_inf | pad, 0.0, a * b_max)
    return (
        min_fin,
        min_is_inf.astype(jnp.int32),
        max_fin,
        max_is_inf.astype(jnp.int32),
    )


def compute_activities(
    row_id, a, col, lb, ub, m: int, inf: float = INF
) -> Activities:
    """Row activities by segment reduction over nonzeros.

    Args:
      row_id: (nnz,) int32 row of each nonzero (precomputed, static).
      a: (nnz,) coefficients.
      col: (nnz,) int32 column ids.
      lb, ub: (n,) bounds.
      m: static row count.
    """
    lb_col = lb[col]
    ub_col = ub[col]
    min_fin, min_inf, max_fin, max_inf = nnz_contributions(a, lb_col, ub_col, inf)
    seg = lambda x: jax.ops.segment_sum(x, row_id, num_segments=m)
    return Activities(
        min_finite=seg(min_fin),
        min_inf_count=seg(min_inf),
        max_finite=seg(max_fin),
        max_inf_count=seg(max_inf),
    )


def activity_values(acts: Activities, inf: float = INF):
    """Materialized (sentinel) activity values: -inf / +inf where counted."""
    amin = jnp.where(acts.min_inf_count > 0, -inf, acts.min_finite)
    amax = jnp.where(acts.max_inf_count > 0, inf, acts.max_finite)
    return amin, amax


def residual_activities(
    a, contrib_fin, contrib_is_inf, row_fin, row_inf_count, side: str, inf: float = INF
):
    """Residual activities per nonzero (paper Eqs. 5a/5b + §3.4 special case).

    ``side='min'``: residual of the minimum activity; infinite residuals are
    ``-inf``.  ``side='max'``: symmetric with ``+inf``.

    The single-infinity rule: if this nonzero's own contribution is the only
    infinite one, the residual is the (fully finite) row sum; if any *other*
    contribution is infinite the residual is infinite.
    """
    sent = -inf if side == "min" else inf
    others_inf = row_inf_count - contrib_is_inf  # infinite contributions besides ours
    res_if_own_inf = jnp.where(row_inf_count == 1, row_fin, sent)
    res_if_own_fin = jnp.where(row_inf_count == 0, row_fin - contrib_fin, sent)
    del others_inf  # folded into the two cases above
    res = jnp.where(contrib_is_inf == 1, res_if_own_inf, res_if_own_fin)
    return jnp.where(a == 0, sent, res)  # padding: force invalid
