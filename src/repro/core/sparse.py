"""Sparse constraint-matrix containers.

Three layouts, mirroring the paper's storage pipeline (§3):

  * :class:`CSR` -- the canonical input format (paper §3: "ubiquitously used").
  * :class:`CSC` -- column-major view, needed by the *sequential* algorithm's
    marking mechanism (Alg. 1 line 20) and built once up-front, exactly like
    the paper's init phase (§4.3: excluded from timing).
  * :class:`BlockEll` -- the TPU-native analogue of CSR-adaptive (§3.2).
    Rows are split into chunks of at most ``K`` nonzeros; chunks are stacked
    into dense ``(num_tiles, R, K)`` tiles.  Short rows occupy one chunk
    (CSR-stream analogue: many rows per tile); long rows span several chunks
    whose partial sums are combined by a per-row segment reduction
    (CSR-vector/multi-warp analogue).  Padding entries carry ``val == 0`` and
    ``col == 0`` and are masked out by ``val != 0``.

Batching (the serving shape): :class:`ProblemBatch` packs many instances
into one flat block-ELL layout so a whole batch propagates in a single
device dispatch.  Instances are *bucketed* by lane-padded column width
(``col_pad(n)``) only; within a bucket their tile streams concatenate into
ONE ``(T_total, R, K)`` super-tile with per-instance row/col offsets, so
ragged batches pay at most one partial tail tile per instance -- never a
pad-to-the-largest blowup.

All containers are pytrees of plain arrays so they can cross ``jit`` /
``shard_map`` boundaries.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

# TPU lane width: column-padded domains are multiples of this so in-kernel
# scatter/gather walk aligned 128-wide windows (see kernels/prop_round.py).
LANE = 128


def col_pad(n: int, lane: int = LANE) -> int:
    """Columns padded up to a lane-width multiple (scatter accumulator size)."""
    return max(lane, -(-n // lane) * lane)


class Problem(NamedTuple):
    """A full propagation instance: ``lhs <= A x <= rhs``, ``lb <= x <= ub``."""

    csr: "CSR"
    lhs: np.ndarray       # (m,) constraint left-hand sides  (-INF if absent)
    rhs: np.ndarray       # (m,) constraint right-hand sides (+INF if absent)
    lb: np.ndarray        # (n,)
    ub: np.ndarray        # (n,)
    is_int: np.ndarray    # (n,) bool: integrality marks

    @property
    def m(self) -> int:
        return self.csr.m

    @property
    def n(self) -> int:
        return self.csr.n

    @property
    def nnz(self) -> int:
        return self.csr.nnz

    def astype(self, dtype) -> "Problem":
        return Problem(
            csr=self.csr.astype(dtype),
            lhs=self.lhs.astype(dtype),
            rhs=self.rhs.astype(dtype),
            lb=self.lb.astype(dtype),
            ub=self.ub.astype(dtype),
            is_int=self.is_int,
        )


class CSR(NamedTuple):
    """Compressed sparse rows, the canonical input format (paper §3).

    ``row_ptr`` is the usual ``(m+1,)`` offset array; ``col``/``val`` hold
    the ``nnz`` column ids and coefficients row-major with columns sorted
    within each row.  ``n_cols`` rides along as a 0-d array so the tuple
    stays a valid pytree."""

    row_ptr: np.ndarray   # (m+1,) int32
    col: np.ndarray       # (nnz,) int32
    val: np.ndarray       # (nnz,) float
    n_cols: np.ndarray    # () int32 -- carried as array for pytree friendliness

    @property
    def m(self) -> int:
        return int(self.row_ptr.shape[0]) - 1

    @property
    def n(self) -> int:
        return int(self.n_cols)

    @property
    def nnz(self) -> int:
        return int(self.col.shape[0])

    def astype(self, dtype) -> "CSR":
        return self._replace(val=self.val.astype(dtype))

    def row_ids(self) -> np.ndarray:
        """Expand row_ptr to a per-nonzero row index (static, precomputed)."""
        out = np.zeros(self.nnz, dtype=np.int32)
        counts = np.diff(self.row_ptr).astype(np.int64)
        out = np.repeat(np.arange(self.m, dtype=np.int32), counts)
        return out

    def to_dense(self) -> np.ndarray:
        a = np.zeros((self.m, self.n), dtype=self.val.dtype)
        rid = self.row_ids()
        a[rid, self.col] = self.val
        return a


class CSC(NamedTuple):
    """Compressed sparse columns: the column-major view the *sequential*
    algorithm's marking mechanism walks (Alg. 1 line 20), built once
    up-front by :func:`csr_to_csc` (paper §4.3 init phase)."""

    col_ptr: np.ndarray   # (n+1,) int32
    row: np.ndarray       # (nnz,) int32
    val: np.ndarray       # (nnz,) float
    n_rows: np.ndarray    # () int32


class BlockEll(NamedTuple):
    """Length-bucketed block-ELL (see module docstring)."""

    val: np.ndarray        # (T, R, K) float; 0 == padding
    col: np.ndarray        # (T, R, K) int32; 0 at padding slots
    chunk_row: np.ndarray  # (T, R) int32; row id of each chunk (m at padding chunks)
    m: np.ndarray          # () int32 original row count
    n: np.ndarray          # () int32 original column count

    @property
    def num_tiles(self) -> int:
        return int(self.val.shape[0])

    @property
    def tile_rows(self) -> int:
        return int(self.val.shape[1])

    @property
    def tile_width(self) -> int:
        return int(self.val.shape[2])

    def astype(self, dtype) -> "BlockEll":
        return self._replace(val=self.val.astype(dtype))

    def padding_fraction(self) -> float:
        return 1.0 - float((self.val != 0).sum()) / float(self.val.size)


def csr_from_dense(a: np.ndarray, dtype=np.float64) -> CSR:
    """Dense ``(m, n)`` matrix -> :class:`CSR` (zeros become structural
    zeros; columns come out sorted within each row)."""
    a = np.asarray(a, dtype=dtype)
    m, n = a.shape
    mask = a != 0
    counts = mask.sum(axis=1).astype(np.int32)
    row_ptr = np.zeros(m + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    col = np.nonzero(mask)[1].astype(np.int32)
    val = a[mask].astype(dtype)
    return CSR(row_ptr=row_ptr, col=col, val=val, n_cols=np.int32(n))


def csr_from_coo(
    rows: np.ndarray, cols: np.ndarray, vals: np.ndarray, m: int, n: int
) -> CSR:
    """Coordinate triplets (any order, no duplicate handling) -> sorted
    :class:`CSR` with ``m`` rows and ``n`` columns."""
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    counts = np.bincount(rows, minlength=m).astype(np.int32)
    row_ptr = np.zeros(m + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    return CSR(
        row_ptr=row_ptr,
        col=cols.astype(np.int32),
        val=np.asarray(vals),
        n_cols=np.int32(n),
    )


def csr_to_csc(csr: CSR) -> CSC:
    """Transpose the storage order: :class:`CSR` -> :class:`CSC` with rows
    sorted within each column (the sequential propagator's init step)."""
    rid = csr.row_ids()
    order = np.lexsort((rid, csr.col))
    col_sorted = csr.col[order]
    counts = np.bincount(col_sorted, minlength=csr.n).astype(np.int32)
    col_ptr = np.zeros(csr.n + 1, dtype=np.int32)
    np.cumsum(counts, out=col_ptr[1:])
    return CSC(
        col_ptr=col_ptr,
        row=rid[order].astype(np.int32),
        val=csr.val[order],
        n_rows=np.int32(csr.m),
    )


def permute_problem(p: Problem, row_perm: np.ndarray, col_perm: np.ndarray) -> Problem:
    """Apply row/column permutations (paper App. B ordering experiment)."""
    dense_free = True  # permute in sparse form to stay cheap for big instances
    del dense_free
    csr = p.csr
    rid = csr.row_ids()
    inv_col = np.empty_like(col_perm)
    inv_col[col_perm] = np.arange(col_perm.shape[0])
    new_rows = np.empty_like(rid)
    inv_row = np.empty_like(row_perm)
    inv_row[row_perm] = np.arange(row_perm.shape[0])
    new_rows = inv_row[rid]
    new_cols = inv_col[csr.col]
    new_csr = csr_from_coo(new_rows, new_cols, csr.val.copy(), csr.m, csr.n)
    return Problem(
        csr=new_csr,
        lhs=p.lhs[row_perm],
        rhs=p.rhs[row_perm],
        lb=p.lb[col_perm],
        ub=p.ub[col_perm],
        is_int=p.is_int[col_perm],
    )


def csr_to_block_ell(csr: CSR, tile_rows: int = 8, tile_width: int = 128) -> BlockEll:
    """Convert CSR to length-bucketed block-ELL.

    Every row is split into ``ceil(len/K)`` chunks of width ``K=tile_width``;
    chunks are packed ``R=tile_rows`` per tile in row order.  The resulting
    padding fraction is bounded by ``K-1`` slots per row plus at most ``R-1``
    empty chunks in the final tile.
    """
    m = csr.m
    lengths = np.diff(csr.row_ptr).astype(np.int64)
    chunks_per_row = np.maximum(1, -(-lengths // tile_width))  # ceil, min 1
    total_chunks = int(chunks_per_row.sum())
    num_tiles = max(1, -(-total_chunks // tile_rows))
    padded_chunks = num_tiles * tile_rows

    val = np.zeros((padded_chunks, tile_width), dtype=csr.val.dtype)
    col = np.zeros((padded_chunks, tile_width), dtype=np.int32)
    chunk_row = np.full((padded_chunks,), m, dtype=np.int32)  # m == padding row

    chunk = 0
    for r in range(m):
        start, end = int(csr.row_ptr[r]), int(csr.row_ptr[r + 1])
        if start == end:
            chunk_row[chunk] = r  # empty row keeps one (all-padding) chunk
            chunk += 1
            continue
        for cstart in range(start, end, tile_width):
            cend = min(cstart + tile_width, end)
            w = cend - cstart
            val[chunk, :w] = csr.val[cstart:cend]
            col[chunk, :w] = csr.col[cstart:cend]
            chunk_row[chunk] = r
            chunk += 1
    assert chunk == total_chunks

    return BlockEll(
        val=val.reshape(num_tiles, tile_rows, tile_width),
        col=col.reshape(num_tiles, tile_rows, tile_width),
        chunk_row=chunk_row.reshape(num_tiles, tile_rows),
        m=np.int32(m),
        n=np.int32(csr.n),
    )


def chunk_stream(val, col, chunk_row, tile_inst=None):
    """Flatten a ``(T, R, K)`` block-ELL tile stream into its chunk stream.

    A chunk is one sublane row of a tile: a ``K``-wide slice of exactly one
    matrix row's nonzeros (``csr_to_block_ell`` packs each row into
    ``ceil(len / K)`` chunks).  Returns ``(cval, ccol, crow, cinst, src)``
    where the first four are ``(T*R, K)`` / ``(T*R,)`` views of the stream
    (``cinst`` zeros when ``tile_inst`` is ``None``) and ``src`` flags the
    chunks carrying at least one nonzero -- all-padding chunks, the dummy
    fill of partially used tiles, are droppable without losing any matrix
    entry.  The column-slab partition builder works at this granularity:
    re-bucketing row slices instead of whole tiles keeps slab copies from
    inheriting the unrelated rows that happen to share their tile."""
    val = np.asarray(val)
    t, r, k = val.shape
    cval = val.reshape(t * r, k)
    ccol = np.asarray(col).reshape(t * r, k)
    crow = np.asarray(chunk_row).reshape(t * r)
    if tile_inst is None:
        cinst = np.zeros(t * r, dtype=np.int64)
    else:
        cinst = np.repeat(np.asarray(tile_inst, dtype=np.int64), r)
    src = (cval != 0).any(axis=1)
    return cval, ccol, crow, cinst, src


# ---------------------------------------------------------------------------
# Batched multi-instance packing (the serving shape)
# ---------------------------------------------------------------------------


class BatchedBlockEll(NamedTuple):
    """A bucket of instances packed as ONE flat tile stream (super-tile).

    Instances' tile streams are concatenated along the tile axis -- no
    per-instance tile padding at all, so ragged batches cost at most
    ``R - 1`` empty chunks per instance (the per-instance tail tile), never
    a stack-to-the-maximum blowup.  Per-instance offsets knit the shared
    domains together:

      * ``tile_inst[t]`` -- which instance tile ``t`` belongs to (tiles of
        one instance are contiguous);
      * ``chunk_row`` -- GLOBAL row ids: instance ``i``'s rows live at
        ``row_offset[i] + local_row``, its padding chunks at its own dummy
        row ``row_offset[i] + m_i``, so one flat segment reduction covers
        the whole batch;
      * columns stay instance-local (each instance owns one ``n_pad``-wide
        window of the ``(B, n_pad)`` bound plane; the global column id is
        ``col + tile_inst * n_pad``).

    ``val == 0`` marks padding slots, exactly as in :class:`BlockEll`.
    """

    val: np.ndarray         # (T, R, K) float; 0 == padding
    col: np.ndarray         # (T, R, K) int32 instance-local columns
    chunk_row: np.ndarray   # (T, R) int32 global row ids
    tile_inst: np.ndarray   # (T,) int32 instance of each tile
    row_offset: np.ndarray  # (B + 1,) int32; instance i owns rows
                            # [row_offset[i], row_offset[i] + m_i], the last
                            # being its dummy padding row
    m: np.ndarray           # (B,) int32 original row counts
    n: np.ndarray           # (B,) int32 original column counts

    @property
    def size(self) -> int:
        return int(self.m.shape[0])

    @property
    def num_tiles(self) -> int:
        return int(self.val.shape[0])

    @property
    def tile_rows(self) -> int:
        return int(self.val.shape[1])

    @property
    def tile_width(self) -> int:
        return int(self.val.shape[2])


class ProblemBatch(NamedTuple):
    """A bucket of propagation instances packed for one device dispatch.

    Built by :func:`pack_problems`.  Constraint sides are stacked into one
    flat ``(m_total,)`` row domain (each instance contributes its ``m_i``
    rows plus one zero dummy row addressed by its padding chunks); bounds
    live on the ``(B, n_pad)`` plane, zero-padded -- padded columns are
    never referenced by any nonzero, so they stay at their (trivially
    converged) initial values.
    """

    problems: tuple          # the original Problem objects, batch order
    indices: tuple           # position of each instance in the packed input
    ell: BatchedBlockEll     # flat tile stream
    lhs1: np.ndarray         # (m_total,) stacked sides incl. dummy rows
    rhs1: np.ndarray         # (m_total,)
    lb: np.ndarray           # (B, n_pad) initial bounds, zero-padded
    ub: np.ndarray           # (B, n_pad)
    is_int: np.ndarray       # (B, n_pad) bool, False-padded

    @property
    def size(self) -> int:
        return len(self.problems)

    @property
    def m_total(self) -> int:
        return int(self.lhs1.shape[0])

    @property
    def n_pad(self) -> int:
        return int(self.lb.shape[1])


def pack_problems(
    problems: Sequence[Problem],
    tile_rows: int = 8,
    tile_width: int = 128,
    lane: int = LANE,
    n_pad: "int | None" = None,
) -> "list[ProblemBatch]":
    """Bucket + pack instances into flat batched block-ELL super-tiles.

    Instances are bucketed by ``col_pad(n)`` only -- the lane-padded column
    width must be uniform within a bucket because every instance owns one
    ``n_pad``-wide window of the bound plane.  Within a bucket the tile
    streams concatenate exactly (no tile quantization), so one bucket is
    one dispatch shape regardless of how ragged the instance sizes are.
    Pass ``n_pad`` to force a single shared column width (used by the
    batch-sharded driver to give every device slice the same shape).
    """
    buckets: "dict[int, list[tuple[int, Problem, BlockEll]]]" = {}
    for idx, p in enumerate(problems):
        b = csr_to_block_ell(p.csr, tile_rows=tile_rows, tile_width=tile_width)
        width = col_pad(p.n, lane) if n_pad is None else int(n_pad)
        if width < p.n:
            raise ValueError(f"forced n_pad={width} < n={p.n}")
        buckets.setdefault(width, []).append((idx, p, b))

    out = []
    for width, members in sorted(buckets.items()):
        bsz = len(members)
        # Mixed-precision buckets promote to the widest member dtype so no
        # instance's coefficients are silently truncated by the stacking.
        dtype = np.result_type(*[b.val.dtype for _, _, b in members])
        tiles = [b for _, _, b in members]
        t_total = sum(b.num_tiles for b in tiles)
        m_total = sum(p.m + 1 for _, p, _ in members)
        val = np.zeros((t_total, tile_rows, tile_width), dtype=dtype)
        col = np.zeros((t_total, tile_rows, tile_width), dtype=np.int32)
        chunk_row = np.zeros((t_total, tile_rows), dtype=np.int32)
        tile_inst = np.zeros((t_total,), dtype=np.int32)
        row_offset = np.zeros((bsz + 1,), dtype=np.int32)
        lhs1 = np.zeros((m_total,), dtype=np.float64)
        rhs1 = np.zeros((m_total,), dtype=np.float64)
        lb = np.zeros((bsz, width), dtype=np.float64)
        ub = np.zeros((bsz, width), dtype=np.float64)
        is_int = np.zeros((bsz, width), dtype=bool)
        t0, r0 = 0, 0
        for i, (_, p, b) in enumerate(members):
            t = b.num_tiles
            val[t0 : t0 + t] = b.val
            col[t0 : t0 + t] = b.col
            # Local chunk rows -> global; padding chunks (local id m_i) land
            # on this instance's dummy row r0 + m_i.
            chunk_row[t0 : t0 + t] = b.chunk_row + r0
            tile_inst[t0 : t0 + t] = i
            row_offset[i] = r0
            lhs1[r0 : r0 + p.m] = p.lhs
            rhs1[r0 : r0 + p.m] = p.rhs
            lb[i, : p.n] = p.lb
            ub[i, : p.n] = p.ub
            is_int[i, : p.n] = p.is_int
            t0 += t
            r0 += p.m + 1
        row_offset[bsz] = r0
        out.append(
            ProblemBatch(
                problems=tuple(p for _, p, _ in members),
                indices=tuple(idx for idx, _, _ in members),
                ell=BatchedBlockEll(
                    val=val,
                    col=col,
                    chunk_row=chunk_row,
                    tile_inst=tile_inst,
                    row_offset=row_offset,
                    m=np.array([p.m for _, p, _ in members], dtype=np.int32),
                    n=np.array([p.n for _, p, _ in members], dtype=np.int32),
                ),
                lhs1=lhs1,
                rhs1=rhs1,
                lb=lb,
                ub=ub,
                is_int=is_int,
            )
        )
    return out


def batch_stats(batches: Sequence[ProblemBatch]) -> dict:
    """Packing diagnostics: bucket shapes, fill, padding overhead.

    ``per_bucket`` is the occupancy/padding histogram of each bucket's
    super-tile (one entry per bucket, same order as ``batches``): instance
    count, tile count, value slots used vs padded, and the fill fraction
    ``nnz / padded_slots`` (so "at least half full" is ``fill >= 0.5``).
    The service's stats endpoint surfaces the same histogram shape for its
    resident slot buckets (``core.service.PropagationService.stats``)."""
    total = sum(b.size for b in batches)
    slots = sum(b.ell.val.size for b in batches)
    nnz = sum(int((b.ell.val != 0).sum()) for b in batches)
    per_bucket = []
    for b in batches:
        b_slots = int(b.ell.val.size)
        b_nnz = int((b.ell.val != 0).sum())
        fill = b_nnz / b_slots if b_slots else 0.0
        per_bucket.append(
            {
                "n_pad": b.n_pad,
                "instances": b.size,
                "tiles": b.ell.num_tiles,
                "tile_rows": b.ell.tile_rows,
                "tile_width": b.ell.tile_width,
                "nnz": b_nnz,
                "padded_slots": b_slots,
                "fill": fill,
                "padding_fraction": 1.0 - fill,
            }
        )
    return {
        "instances": total,
        "buckets": len(batches),
        "bucket_shapes": [tuple(b.ell.val.shape) for b in batches],
        "bucket_sizes": [b.size for b in batches],
        "padded_slots": slots,
        "nnz": nnz,
        "padding_fraction": 1.0 - (nnz / slots if slots else 0.0),
        "per_bucket": per_bucket,
    }


# ---------------------------------------------------------------------------
# Slot-granular packing (the continuous-batching serving shape)
# ---------------------------------------------------------------------------


class SlotPayload(NamedTuple):
    """One instance packed to a FIXED slot shape, ready for device scatter.

    The continuous-batching service (``core.service``) keeps per-bucket
    super-tiles resident on device and admits instances one slot at a time:
    instead of repacking the whole batch (``pack_problems``), an arriving
    instance is converted host-side into this fixed-shape payload and
    scattered into a free slot's tile/bound windows in ONE device op.  All
    row/column ids stay SLOT-LOCAL -- the admission scatter adds the slot's
    global offsets (``slot * n_pad`` columns, ``slot * (slot_rows + 1)``
    rows) on device, so one payload can be admitted into any slot of any
    bucket with matching shape.

    Conventions match :class:`BatchedBlockEll`: ``val == 0`` marks padding,
    padding chunks address the instance's own dummy row (local id ``m``),
    sides/bounds of unused rows/columns are zero-filled (trivially
    converged).  ``lhs_c``/``rhs_c`` are the per-chunk side gathers
    (``lhs1[chunk_row]``) hoisted at pack time, like ``prepare_*`` does for
    whole batches; ``ii`` is the per-nonzero integrality gather.
    """

    val: np.ndarray        # (slot_tiles, R, K) float; 0 == padding
    col: np.ndarray        # (slot_tiles, R, K) int32 slot-local columns
    chunk_row: np.ndarray  # (slot_tiles, R) int32 slot-local rows; m == dummy
    ii: np.ndarray         # (slot_tiles, R, K) int32: is_int[col], 0 at padding
    lhs_c: np.ndarray      # (slot_tiles, R) per-chunk lhs (0 at dummy rows)
    rhs_c: np.ndarray      # (slot_tiles, R) per-chunk rhs
    lb: np.ndarray         # (n_pad,) zero-padded initial bounds
    ub: np.ndarray         # (n_pad,)
    m: int                 # original row count (dummy row == m)
    n: int                 # original column count
    nnz: int               # nonzeros packed
    tiles_used: int        # leading tiles actually carrying the instance
    max_row_nnz: int       # longest row (chunk-splitting diagnostic)

    @property
    def slot_tiles(self) -> int:
        return int(self.val.shape[0])

    @property
    def n_pad(self) -> int:
        return int(self.lb.shape[0])

    def fill(self) -> float:
        """Fraction of the slot's value slots carrying real nonzeros."""
        return self.nnz / float(self.val.size) if self.val.size else 0.0


def pack_into_slot(
    p: Problem,
    slot_tiles: int,
    slot_rows: int,
    n_pad: int,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
) -> SlotPayload:
    """Pack ONE instance to a fixed slot shape (see :class:`SlotPayload`).

    The instance's block-ELL stream is laid into the leading tiles of a
    ``(slot_tiles, tile_rows, tile_width)`` window; trailing tiles stay
    all-padding (their chunks address the dummy row, so they contribute
    nothing to any round).  Raises if the instance exceeds the slot
    capacity (``tiles``, ``rows`` or ``n_pad``) -- routing instances to a
    bucket whose slots fit them is the caller's job
    (``core.service.BucketSpec.admits``)."""
    b = csr_to_block_ell(p.csr, tile_rows=tile_rows, tile_width=tile_width)
    dt = np.dtype(dtype) if dtype is not None else b.val.dtype
    if b.num_tiles > slot_tiles:
        raise ValueError(
            f"instance needs {b.num_tiles} tiles > slot capacity {slot_tiles}"
        )
    if p.m > slot_rows:
        raise ValueError(f"instance has {p.m} rows > slot capacity {slot_rows}")
    if p.n > n_pad:
        raise ValueError(f"instance has {p.n} columns > slot width {n_pad}")

    val = np.zeros((slot_tiles, tile_rows, tile_width), dtype=dt)
    col = np.zeros((slot_tiles, tile_rows, tile_width), dtype=np.int32)
    # All-padding chunks (both the packed stream's and the unused slot
    # tail's) address the instance's own dummy row m, exactly like
    # ``pack_problems`` -- so they never touch another slot's rows.
    chunk_row = np.full((slot_tiles, tile_rows), p.m, dtype=np.int32)
    t = b.num_tiles
    val[:t] = b.val
    col[:t] = b.col
    chunk_row[:t] = b.chunk_row  # local rows; padding chunks already at m

    ii = np.zeros((slot_tiles, tile_rows, tile_width), dtype=np.int32)
    ii[:t] = p.is_int[b.col].astype(np.int32)
    ii[val == 0] = 0

    # Per-chunk side gathers with the dummy row's sides pinned to 0.0 (the
    # ``pack_problems`` convention: dummy rows are trivially redundant).
    lhs1 = np.concatenate([np.asarray(p.lhs, np.float64), [0.0]])
    rhs1 = np.concatenate([np.asarray(p.rhs, np.float64), [0.0]])
    lhs_c = lhs1[chunk_row].astype(dt)
    rhs_c = rhs1[chunk_row].astype(dt)

    lb = np.zeros((n_pad,), dtype=dt)
    ub = np.zeros((n_pad,), dtype=dt)
    lb[: p.n] = p.lb
    ub[: p.n] = p.ub

    lengths = np.diff(p.csr.row_ptr)
    return SlotPayload(
        val=val,
        col=col,
        chunk_row=chunk_row,
        ii=ii,
        lhs_c=lhs_c,
        rhs_c=rhs_c,
        lb=lb,
        ub=ub,
        m=p.m,
        n=p.n,
        nnz=p.nnz,
        tiles_used=t,
        max_row_nnz=int(lengths.max()) if lengths.size else 0,
    )


def evict_slot(
    slot_tiles: int,
    slot_rows: int,
    n_pad: int,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=np.float64,
) -> SlotPayload:
    """The all-padding payload that CLEARS a slot.

    Scattering it through the same admission op zeroes the slot's tiles and
    bounds and parks every chunk on the slot's dummy row (local id
    ``slot_rows``), leaving the slot exactly as an empty bucket initializes
    it.  Retirement itself doesn't need this -- a retired slot's stale
    tiles are gated off by the occupancy mask and simply overwritten by the
    next admission -- but explicit eviction keeps device state minimal when
    a bucket idles, and gives tests a known-empty fixture."""
    dt = np.dtype(dtype)
    shape = (slot_tiles, tile_rows, tile_width)
    return SlotPayload(
        val=np.zeros(shape, dtype=dt),
        col=np.zeros(shape, dtype=np.int32),
        chunk_row=np.full((slot_tiles, tile_rows), slot_rows, dtype=np.int32),
        ii=np.zeros(shape, dtype=np.int32),
        lhs_c=np.zeros((slot_tiles, tile_rows), dtype=dt),
        rhs_c=np.zeros((slot_tiles, tile_rows), dtype=dt),
        lb=np.zeros((n_pad,), dtype=dt),
        ub=np.zeros((n_pad,), dtype=dt),
        m=slot_rows,
        n=0,
        nnz=0,
        tiles_used=0,
        max_row_nnz=0,
    )


def block_ell_stats(b: BlockEll) -> dict:
    """Layout diagnostics of one block-ELL conversion: tile counts, tile
    shape, nnz, padded slots and the padding fraction."""
    nnz = int((b.val != 0).sum())
    return {
        "tiles": b.num_tiles,
        "tile_rows": b.tile_rows,
        "tile_width": b.tile_width,
        "nnz": nnz,
        "padded_slots": int(b.val.size),
        "padding_fraction": b.padding_fraction(),
    }
