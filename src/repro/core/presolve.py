"""Constraint-level presolve observations (paper §1.1 Steps 1 and 2).

These are *diagnostics* layered on top of the activity computation: Step 3
(the propagator) is correct without them (paper §1.1 remark), but a MIP
presolve service wants the redundancy / infeasibility verdicts as outputs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .activities import activity_values, compute_activities
from .types import INF


class PresolveVerdict(NamedTuple):
    """Per-constraint presolve verdicts from one activity computation
    (paper §1.1 Steps 1-2): rows provably redundant, rows provably
    unsatisfiable, and their any-reduction."""

    redundant: jnp.ndarray    # (m,) bool: Step 1 -- constraint can be removed
    infeasible: jnp.ndarray   # (m,) bool: Step 2 -- constraint cannot be satisfied
    any_infeasible: jnp.ndarray  # () bool


def analyze_constraints(
    row_id, val, col, lhs, rhs, lb, ub, m: int, feas_eps: float = 1e-8, inf: float = INF
) -> PresolveVerdict:
    """Classify every constraint as redundant / infeasible / neither from
    its activity bounds (jit-able; ``(nnz,)`` COO-style inputs plus ``(m,)``
    sides and ``(n,)`` bounds)."""
    acts = compute_activities(row_id, val, col, lb, ub, m, inf)
    amin, amax = activity_values(acts, inf)
    # Step 1: lhs <= amin and amax <= rhs  -> redundant.
    redundant = (lhs <= amin) & (amax <= rhs)
    # Step 2: amin > rhs or lhs > amax     -> infeasible.
    infeasible = (amin > rhs + feas_eps) | (lhs > amax + feas_eps)
    return PresolveVerdict(redundant, infeasible, jnp.any(infeasible))
