"""GPU-parallel domain propagation (paper Algorithm 2), in pure JAX.

One *round* (Alg. 3 at nonzero granularity, re-expressed for TPU):

  1. activities + infinity counters per row        (segment-sum over nonzeros)
  2. residual activities + bound candidates        (elementwise over nonzeros)
  3. column-wise best candidate                    (segment-max/min over nonzeros)
  4. integrality rounding + monotone update        (elementwise over columns)

Loop drivers (paper §3.7 / App. C):

  * ``host_loop``   -- Python loop, one jitted round per iteration, host reads
                       a 1-byte converged flag each round (paper: cpu_loop).
  * ``device_loop`` -- ``jax.lax.while_loop``: the entire fixed point is ONE
                       XLA dispatch with zero host synchronization
                       (paper: gpu_loop; on TPU this is the natural form).
  * ``unrolled``    -- while_loop whose body fuses ``unroll`` rounds before
                       re-checking convergence (megakernel-flavored trade-off:
                       fewer sync points, possibly wasted rounds).

All drivers share the exact same round function so they converge to the same
fixed point by construction.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
import numpy as np

from . import activities as act
from . import bounds as bnd
from .sparse import Problem
from .types import DEFAULT_CONFIG, INF, PropagationResult, PropagatorConfig


# ---------------------------------------------------------------------------
# Device-side problem representation (static shapes, jit-friendly)
# ---------------------------------------------------------------------------


class DeviceProblem:
    """Static-shape device arrays + metadata for the parallel propagator."""

    def __init__(self, p: Problem, dtype=None):
        csr = p.csr
        dtype = dtype or csr.val.dtype
        self.m = csr.m
        self.n = csr.n
        self.nnz = csr.nnz
        self.row_id = jnp.asarray(csr.row_ids())
        self.col = jnp.asarray(csr.col)
        self.val = jnp.asarray(csr.val, dtype=dtype)
        self.lhs = jnp.asarray(p.lhs, dtype=dtype)
        self.rhs = jnp.asarray(p.rhs, dtype=dtype)
        self.lb0 = jnp.asarray(p.lb, dtype=dtype)
        self.ub0 = jnp.asarray(p.ub, dtype=dtype)
        self.is_int = jnp.asarray(p.is_int)
        self.dtype = dtype


# ---------------------------------------------------------------------------
# One propagation round
# ---------------------------------------------------------------------------


def propagation_round(
    row_id,
    col,
    val,
    lhs,
    rhs,
    is_int,
    lb,
    ub,
    m: int,
    n: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
):
    """Pure function: one parallel propagation round.  Returns (lb, ub, changed)."""
    lb_col = lb[col]
    ub_col = ub[col]
    min_fin, min_inf, max_fin, max_inf = act.nnz_contributions(val, lb_col, ub_col, inf)

    seg = lambda x: jax.ops.segment_sum(x, row_id, num_segments=m)
    row_min_fin = seg(min_fin)
    row_min_inf = seg(min_inf)
    row_max_fin = seg(max_fin)
    row_max_inf = seg(max_inf)

    min_res = act.residual_activities(
        val, min_fin, min_inf, row_min_fin[row_id], row_min_inf[row_id], "min", inf
    )
    max_res = act.residual_activities(
        val, max_fin, max_inf, row_max_fin[row_id], row_max_inf[row_id], "max", inf
    )

    lcand, ucand = bnd.bound_candidates(
        val, lhs[row_id], rhs[row_id], min_res, max_res, inf
    )
    lcand, ucand = bnd.round_candidates(lcand, ucand, is_int[col], int_eps, inf)

    best_l = jax.ops.segment_max(lcand, col, num_segments=n)
    best_u = jax.ops.segment_min(ucand, col, num_segments=n)
    # Columns with no nonzeros get segment identity (-inf/+inf fill is fine).

    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf)


def _round_fn(dp: DeviceProblem, cfg: PropagatorConfig):
    eps = cfg.eps_for(dp.dtype)
    return functools.partial(
        propagation_round,
        dp.row_id,
        dp.col,
        dp.val,
        dp.lhs,
        dp.rhs,
        dp.is_int,
        m=dp.m,
        n=dp.n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
    )


def check_infeasible(lb, ub, feas_eps: float):
    return jnp.any(lb > ub + feas_eps)


# ---------------------------------------------------------------------------
# Loop drivers
# ---------------------------------------------------------------------------


def donate_supported() -> bool:
    """XLA implements buffer donation on accelerators only; on CPU it is a
    no-op that warns, so zero-copy drivers request it where it works."""
    return jax.default_backend() in ("tpu", "gpu", "cuda", "rocm")


def donate_kwargs(argnums=None, argnames=None) -> dict:
    """``jax.jit`` donation kwargs for the zero-copy drivers, empty on
    backends without donation support (single place for the gating policy)."""
    if not donate_supported():
        return {}
    out = {}
    if argnums is not None:
        out["donate_argnums"] = tuple(argnums)
    if argnames is not None:
        out["donate_argnames"] = tuple(argnames)
    return out


def owned_copy(x):
    """Private copy of a cached device array.  The zero-copy drivers donate
    their bound buffers; handing them copies keeps the DeviceProblem /
    prepare() caches' initial bounds valid across repeated propagations."""
    return jnp.array(x, copy=True)


def initial_bounds(dp_or_arrays, lb0=None, ub0=None, dtype=None, n: int | None = None):
    """Resolve the warm-start bound overrides of a driver call.

    ``(lb0, ub0)`` are RUNTIME arguments, not prepare-time constants: a
    branch-and-bound node that differs from its parent by one branching
    bound propagates through the same prepared engine by passing its bounds
    here.  ``None`` falls back to the prepared root bounds.  The returned
    arrays are private copies, so donation into a zero-copy fixed point can
    never invalidate caller-held buffers or the prepare() caches.
    """
    default_lb, default_ub = dp_or_arrays
    dtype = dtype or default_lb.dtype
    n = int(default_lb.shape[-1]) if n is None else n

    def pick(override, default):
        if override is None:
            return owned_copy(default)
        arr = jnp.asarray(override, dtype)
        if arr.shape != (n,):
            raise ValueError(f"bounds override has shape {arr.shape}, expected {(n,)}")
        return owned_copy(arr)

    return pick(lb0, default_lb), pick(ub0, default_ub)


def propagate_host_loop(
    dp: DeviceProblem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    lb0=None,
    ub0=None,
) -> PropagationResult:
    """cpu_loop analogue: host iterates rounds, syncing one flag per round.

    Zero-copy: (lb, ub) are donated each call, so XLA reuses the same two
    bound buffers round over round instead of allocating fresh ones.
    ``lb0``/``ub0`` warm-start the fixed point from caller-supplied bounds
    (default: the problem's root bounds)."""
    round_fn = jax.jit(_round_fn(dp, cfg), **donate_kwargs(argnames=("lb", "ub")))
    lb, ub = initial_bounds((dp.lb0, dp.ub0), lb0, ub0, dp.dtype, dp.n)
    rounds = 0
    changed = True
    while changed and rounds < cfg.max_rounds:
        lb, ub, changed_dev = round_fn(lb=lb, ub=ub)
        changed = bool(changed_dev)  # the per-round host<->device sync point
        rounds += 1
    infeasible = bool(check_infeasible(lb, ub, cfg.feas_eps))
    return PropagationResult(
        lb=lb,
        ub=ub,
        rounds=jnp.int32(rounds),
        converged=jnp.asarray(not changed),
        infeasible=jnp.asarray(infeasible),
    )


def _device_fixed_point(round_fn, lb0, ub0, max_rounds: int, unroll: int = 1):
    """while_loop fixed point; ``unroll`` rounds per convergence check."""

    def body(state):
        lb, ub, _, rounds = state
        changed_any = jnp.asarray(False)
        for _ in range(unroll):
            lb, ub, changed = round_fn(lb=lb, ub=ub)
            changed_any = changed_any | changed
            rounds = rounds + 1
        return lb, ub, changed_any, rounds

    def cond(state):
        _, _, changed, rounds = state
        return changed & (rounds < max_rounds)

    init = (lb0, ub0, jnp.asarray(True), jnp.int32(0))
    # First iteration must run: seed changed=True, but do not count it.
    lb, ub, changed, rounds = jax.lax.while_loop(cond, body, init)
    return lb, ub, changed, rounds


def batched_step_rounds(
    round_fn, lb, ub, active, last_changed, rounds, max_rounds: int,
    budget: int | None = None,
):
    """Run up to ``budget`` rounds of a batched fixed point and return the
    carried state -- the RESUMABLE core of :func:`batched_fixed_point`.

    ``round_fn(lb, ub, active) -> (lb, ub, changed)`` as there; the state
    quintuple ``(lb, ub, active, last_changed, rounds)`` is exactly the
    fixed point's loop carry, so feeding one call's output to the next
    continues the per-instance round trajectories bit-for-bit -- where the
    step boundary falls cannot change any instance's arithmetic, because a
    round only reads the instance's own tiles and bounds.  The loop exits
    early when every instance converged, so a step over an all-converged
    batch costs one predicate evaluation, not ``budget`` rounds.

    This is the continuous-batching service's device step
    (``core.service``): each pump runs a *bounded* number of rounds per
    bucket -- the per-slot round budget -- then returns control to the host
    so converged slots retire and free slots admit, without any one slow
    instance holding the bucket hostage.  ``budget=None`` (run to
    convergence) makes :func:`batched_fixed_point` a single call of this.
    """

    def body(state):
        lb, ub, active, last_changed, rounds, k = state
        lb, ub, changed = round_fn(lb, ub, active)
        rounds = rounds + active.astype(jnp.int32)
        last_changed = jnp.where(active, changed, last_changed)
        active = active & changed & (rounds < max_rounds)
        return lb, ub, active, last_changed, rounds, k + 1

    def cond(state):
        go = jnp.any(state[2])
        if budget is not None:
            go = go & (state[5] < budget)
        return go

    init = (lb, ub, active, last_changed, rounds, jnp.int32(0))
    lb, ub, active, last_changed, rounds, _ = jax.lax.while_loop(
        cond, body, init
    )
    return lb, ub, active, last_changed, rounds


def batched_fixed_point(round_fn, lb0, ub0, max_rounds: int, active0=None):
    """Batched while_loop fixed point with a per-instance convergence mask.

    ``round_fn(lb, ub, active) -> (lb, ub, changed)`` operates on
    ``(B, n_pad)`` bounds and per-instance ``(B,)`` flags.  The loop runs
    until *every* instance has converged (or hit ``max_rounds``); an
    instance whose round produced no change drops out of ``active`` and its
    bounds are frozen -- finished instances are no-ops, not stragglers'
    hostages.  Per-instance round counts match what each instance would
    have seen in its own single-instance ``device_loop``.

    Returns ``(lb, ub, rounds, converged)`` with ``rounds``/``converged``
    per instance.
    """
    bsz = lb0.shape[0]
    if active0 is None:
        active0 = jnp.ones((bsz,), dtype=bool)

    lb, ub, _, last_changed, rounds = batched_step_rounds(
        round_fn, lb0, ub0, active0, active0,
        jnp.zeros((bsz,), jnp.int32), max_rounds, budget=None,
    )
    return lb, ub, rounds, ~last_changed


def propagate_batch(
    problems,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    driver: str = "device_loop",
    interpret: bool | None = None,
    donate: bool | None = None,
    bounds=None,
    slab: int | None = None,
):
    """Propagate a batch of instances, thousands per device dispatch.

    Front end over the batched block-ELL engine: instances are bucketed by
    padded column width (``core.sparse.pack_problems``), each bucket runs
    its fixed point in ONE dispatch with a per-instance convergence mask,
    and results come back as one ``PropagationResult`` per instance, input
    order (``(n_i,)`` bounds each).  Buckets whose ``n_pad`` exceeds the
    VMEM accumulator budget ride the column-slab partitioned kernels
    automatically.  ``bounds`` (one ``(lb_i, ub_i)`` pair of ``(n_i,)``
    arrays or ``None`` per problem) warm-starts instances from caller
    bounds without repacking.  Packing, device transfer and the compiled
    runners are LRU-cached on the identity of the problem list / packed
    batch (see ``kernels.cache_info()``), so a serving loop pays them
    once.  See ``kernels.ops.propagate_batch_block_ell`` for the engine
    knobs."""
    from ..kernels.ops import propagate_batch_block_ell  # lazy: kernels imports core

    return propagate_batch_block_ell(
        problems,
        cfg=cfg,
        tile_rows=tile_rows,
        tile_width=tile_width,
        dtype=dtype,
        use_pallas=use_pallas,
        driver=driver,
        interpret=interpret,
        donate=donate,
        bounds=bounds,
        slab=slab,
    )


def propagate_device_loop(
    dp: DeviceProblem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    unroll: int = 1,
    lb0=None,
    ub0=None,
) -> PropagationResult:
    """gpu_loop analogue: the whole fixed point is one XLA dispatch.

    Zero-copy: the initial bounds are donated into the while_loop carry, so
    the fixed point runs in place on two device buffers.  ``lb0``/``ub0``
    warm-start the fixed point from caller-supplied bounds."""
    round_fn = _round_fn(dp, cfg)

    @functools.partial(jax.jit, **donate_kwargs(argnums=(0, 1)))
    def run(lb0, ub0):
        lb, ub, changed, rounds = _device_fixed_point(
            round_fn, lb0, ub0, cfg.max_rounds, unroll=unroll
        )
        infeasible = check_infeasible(lb, ub, cfg.feas_eps)
        return lb, ub, rounds, ~changed, infeasible

    lb_init, ub_init = initial_bounds((dp.lb0, dp.ub0), lb0, ub0, dp.dtype, dp.n)
    lb, ub, rounds, converged, infeasible = run(lb_init, ub_init)
    return PropagationResult(lb, ub, rounds, converged, infeasible)


def propagate_unrolled(
    dp: DeviceProblem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    unroll: int = 4,
    lb0=None,
    ub0=None,
) -> PropagationResult:
    """megakernel-flavored driver: k fused rounds per convergence check."""
    return propagate_device_loop(dp, cfg, unroll=unroll, lb0=lb0, ub0=ub0)


def propagate(
    p: Problem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    driver: str = "device_loop",
    dtype=None,
    lb0=None,
    ub0=None,
) -> PropagationResult:
    """Convenience front end: Problem -> PropagationResult (pure-jnp round,
    no Pallas -- the kernel-backed sibling is ``kernels.propagate_block_ell``).

    ``driver`` picks the loop (``host_loop`` syncs one flag per round,
    ``device_loop`` runs the whole fixed point as one dispatch,
    ``unrolled`` checks convergence every k rounds); ``dtype`` overrides
    the value dtype (default: the CSR's, f64 under x64).  ``lb0``/``ub0``
    are ``(n,)`` warm-start overrides for this call only (the tree-search
    path: propagate a B&B node's domain through the root problem's device
    arrays without rebuilding anything); the returned bounds are ``(n,)``
    device arrays in that dtype."""
    dp = DeviceProblem(p, dtype=dtype)
    if driver == "host_loop":
        return propagate_host_loop(dp, cfg, lb0=lb0, ub0=ub0)
    if driver == "device_loop":
        return propagate_device_loop(dp, cfg, lb0=lb0, ub0=ub0)
    if driver == "unrolled":
        return propagate_unrolled(dp, cfg, lb0=lb0, ub0=ub0)
    raise ValueError(f"unknown driver: {driver}")


def fresh_instance_runner(p: Problem, cfg: PropagatorConfig = DEFAULT_CONFIG):
    """One jitted fixed point whose matrix arrays are RUNTIME arguments.

    Returns ``propagate_fresh(lb, ub) -> (lb, ub, rounds)``.  Each call
    re-expands the CSR row structure on the host and re-uploads the whole
    matrix before its single dispatch -- i.e. it treats the node as a
    brand-new instance.  Shapes are stable across calls, so XLA compiles
    once; this is the honest "repack each node" baseline the warm-start
    engines are benchmarked against (``benchmarks/bench_prop.py``,
    ``examples/bnb_dive.py``), and doubles as a one-off runner for streams
    of same-shape instances."""
    eps = cfg.eps_for(p.csr.val.dtype)
    round_fn = functools.partial(
        propagation_round, m=p.m, n=p.n, eps=eps, int_eps=cfg.int_eps, inf=cfg.inf
    )

    @jax.jit
    def run(row_id, col, val, lhs, rhs, is_int, lb0, ub0):
        def body(s):
            lb, ub, _, r = s
            lb, ub, ch = round_fn(row_id, col, val, lhs, rhs, is_int, lb, ub)
            return lb, ub, ch, r + 1

        def cond(s):
            return s[2] & (s[3] < cfg.max_rounds)

        lb, ub, ch, r = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        return lb, ub, r

    def propagate_fresh(lb, ub):
        # The per-node repack: row expansion on the host + full re-upload.
        return run(
            jnp.asarray(p.csr.row_ids()), jnp.asarray(p.csr.col),
            jnp.asarray(p.csr.val), jnp.asarray(p.lhs), jnp.asarray(p.rhs),
            jnp.asarray(p.is_int), jnp.asarray(lb), jnp.asarray(ub),
        )

    return propagate_fresh


# ---------------------------------------------------------------------------
# Result comparison (paper §4.3)
# ---------------------------------------------------------------------------


def bounds_equal(
    a_lb, a_ub, b_lb, b_ub, t_abs: float = 1e-8, t_rel: float = 1e-5, inf: float = INF
) -> bool:
    """Paper §4.3: |a-b| <= t_abs + t_rel*|b|, with both-infinite counted equal."""
    a_lb, a_ub = np.asarray(a_lb, np.float64), np.asarray(a_ub, np.float64)
    b_lb, b_ub = np.asarray(b_lb, np.float64), np.asarray(b_ub, np.float64)

    def eq(a, b):
        both_pinf = (a >= inf) & (b >= inf)
        both_ninf = (a <= -inf) & (b <= -inf)
        close = np.abs(a - b) <= (t_abs + t_rel * np.abs(b))
        return both_pinf | both_ninf | close

    return bool(np.all(eq(a_lb, b_lb)) and np.all(eq(a_ub, b_ub)))
