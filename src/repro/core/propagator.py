"""GPU-parallel domain propagation (paper Algorithm 2), in pure JAX.

One *round* (Alg. 3 at nonzero granularity, re-expressed for TPU):

  1. activities + infinity counters per row        (segment-sum over nonzeros)
  2. residual activities + bound candidates        (elementwise over nonzeros)
  3. column-wise best candidate                    (segment-max/min over nonzeros)
  4. integrality rounding + monotone update        (elementwise over columns)

Loop drivers (paper §3.7 / App. C):

  * ``host_loop``   -- Python loop, one jitted round per iteration, host reads
                       a 1-byte converged flag each round (paper: cpu_loop).
  * ``device_loop`` -- ``jax.lax.while_loop``: the entire fixed point is ONE
                       XLA dispatch with zero host synchronization
                       (paper: gpu_loop; on TPU this is the natural form).
  * ``unrolled``    -- while_loop whose body fuses ``unroll`` rounds before
                       re-checking convergence (megakernel-flavored trade-off:
                       fewer sync points, possibly wasted rounds).

All drivers share the exact same round function so they converge to the same
fixed point by construction.
"""
from __future__ import annotations

import dataclasses
import functools
import jax
import jax.numpy as jnp
import numpy as np

from . import activities as act
from . import bounds as bnd
from ..obs import telemetry as obs
from .sparse import Problem
from .types import (
    DEFAULT_CONFIG,
    INF,
    PropagationResult,
    PropagatorConfig,
    TierPolicy,
)


# ---------------------------------------------------------------------------
# Device-side problem representation (static shapes, jit-friendly)
# ---------------------------------------------------------------------------


class DeviceProblem:
    """Static-shape device arrays + metadata for the parallel propagator."""

    def __init__(self, p: Problem, dtype=None):
        csr = p.csr
        dtype = dtype or csr.val.dtype
        self.m = csr.m
        self.n = csr.n
        self.nnz = csr.nnz
        self.row_id = jnp.asarray(csr.row_ids())
        self.col = jnp.asarray(csr.col)
        self.val = jnp.asarray(csr.val, dtype=dtype)
        self.lhs = jnp.asarray(p.lhs, dtype=dtype)
        self.rhs = jnp.asarray(p.rhs, dtype=dtype)
        self.lb0 = jnp.asarray(p.lb, dtype=dtype)
        self.ub0 = jnp.asarray(p.ub, dtype=dtype)
        self.is_int = jnp.asarray(p.is_int)
        self.dtype = dtype


# ---------------------------------------------------------------------------
# One propagation round
# ---------------------------------------------------------------------------


def propagation_round(
    row_id,
    col,
    val,
    lhs,
    rhs,
    is_int,
    lb,
    ub,
    m: int,
    n: int,
    eps: float,
    int_eps: float,
    inf: float = INF,
    outward: float = 0.0,
):
    """Pure function: one parallel propagation round.  Returns (lb, ub, changed)."""
    lb_col = lb[col]
    ub_col = ub[col]
    min_fin, min_inf, max_fin, max_inf = act.nnz_contributions(val, lb_col, ub_col, inf)

    seg = lambda x: jax.ops.segment_sum(x, row_id, num_segments=m)
    row_min_fin = seg(min_fin)
    row_min_inf = seg(min_inf)
    row_max_fin = seg(max_fin)
    row_max_inf = seg(max_inf)

    min_res = act.residual_activities(
        val, min_fin, min_inf, row_min_fin[row_id], row_min_inf[row_id], "min", inf
    )
    max_res = act.residual_activities(
        val, max_fin, max_inf, row_max_fin[row_id], row_max_inf[row_id], "max", inf
    )

    lcand, ucand = bnd.bound_candidates(
        val, lhs[row_id], rhs[row_id], min_res, max_res, inf
    )
    lcand, ucand = bnd.round_candidates(lcand, ucand, is_int[col], int_eps, inf)

    best_l = jax.ops.segment_max(lcand, col, num_segments=n)
    best_u = jax.ops.segment_min(ucand, col, num_segments=n)
    # Columns with no nonzeros get segment identity (-inf/+inf fill is fine).

    return bnd.apply_updates(lb, ub, best_l, best_u, eps, inf, outward)


def _round_fn(dp: DeviceProblem, cfg: PropagatorConfig):
    eps = cfg.eps_for(dp.dtype)
    return functools.partial(
        propagation_round,
        dp.row_id,
        dp.col,
        dp.val,
        dp.lhs,
        dp.rhs,
        dp.is_int,
        m=dp.m,
        n=dp.n,
        eps=eps,
        int_eps=cfg.int_eps,
        inf=cfg.inf,
        outward=cfg.outward_for(dp.dtype),
    )


def check_infeasible(lb, ub, feas_eps: float):
    return jnp.any(lb > ub + feas_eps)


# ---------------------------------------------------------------------------
# Loop drivers
# ---------------------------------------------------------------------------


def donate_supported() -> bool:
    """XLA implements buffer donation on accelerators only; on CPU it is a
    no-op that warns, so zero-copy drivers request it where it works."""
    return jax.default_backend() in ("tpu", "gpu", "cuda", "rocm")


def donate_kwargs(argnums=None, argnames=None) -> dict:
    """``jax.jit`` donation kwargs for the zero-copy drivers, empty on
    backends without donation support (single place for the gating policy)."""
    if not donate_supported():
        return {}
    out = {}
    if argnums is not None:
        out["donate_argnums"] = tuple(argnums)
    if argnames is not None:
        out["donate_argnames"] = tuple(argnames)
    return out


def owned_copy(x):
    """Private copy of a cached device array.  The zero-copy drivers donate
    their bound buffers; handing them copies keeps the DeviceProblem /
    prepare() caches' initial bounds valid across repeated propagations."""
    return jnp.array(x, copy=True)


def initial_bounds(dp_or_arrays, lb0=None, ub0=None, dtype=None, n: int | None = None):
    """Resolve the warm-start bound overrides of a driver call.

    ``(lb0, ub0)`` are RUNTIME arguments, not prepare-time constants: a
    branch-and-bound node that differs from its parent by one branching
    bound propagates through the same prepared engine by passing its bounds
    here.  ``None`` falls back to the prepared root bounds.  The returned
    arrays are private copies, so donation into a zero-copy fixed point can
    never invalidate caller-held buffers or the prepare() caches.
    """
    default_lb, default_ub = dp_or_arrays
    dtype = dtype or default_lb.dtype
    n = int(default_lb.shape[-1]) if n is None else n

    def pick(override, default):
        if override is None:
            return owned_copy(default)
        arr = jnp.asarray(override, dtype)
        if arr.shape != (n,):
            raise ValueError(f"bounds override has shape {arr.shape}, expected {(n,)}")
        return owned_copy(arr)

    return pick(lb0, default_lb), pick(ub0, default_ub)


def propagate_host_loop(
    dp: DeviceProblem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    lb0=None,
    ub0=None,
    stop_progress: float | None = None,
    patience: int = 1,
    telemetry: int | None = None,
) -> PropagationResult:
    """cpu_loop analogue: host iterates rounds, syncing one flag per round.

    Zero-copy: (lb, ub) are donated each call, so XLA reuses the same two
    bound buffers round over round instead of allocating fresh ones.
    ``lb0``/``ub0`` warm-start the fixed point from caller-supplied bounds
    (default: the problem's root bounds).  ``stop_progress`` arms the
    progress-based early stop (see :func:`_device_fixed_point`); on this
    driver the measure is read back per round like the changed flag.
    ``telemetry`` (a ring capacity) records the per-round trajectory
    host-side -- this driver syncs every round anyway -- into the same
    snapshot shape the device drivers produce."""
    base = _round_fn(dp, cfg)
    tel_on = bool(telemetry)

    def step(lb, ub):
        # Progress is computed INSIDE the jit, while the pre-round bounds
        # are still live -- the donated input buffers are gone afterwards.
        nlb, nub, ch = base(lb=lb, ub=ub)
        out = nlb, nub, ch, bnd.progress_measure(lb, ub, nlb, nub)
        if tel_on:
            out = out + (check_infeasible(nlb, nub, cfg.feas_eps),)
        return out

    round_fn = jax.jit(step, **donate_kwargs(argnums=(0, 1)))
    lb, ub = initial_bounds((dp.lb0, dp.ub0), lb0, ub0, dp.dtype, dp.n)
    rounds = 0
    changed = True
    prog = float("nan")
    flat = 0
    history: list[float] = []
    stop_round = -1
    infeas_round = -1
    while changed and rounds < cfg.max_rounds:
        lb, ub, changed_dev, prog_dev, *infeas_dev = round_fn(lb, ub)
        changed = bool(changed_dev)  # the per-round host<->device sync point
        rounds += 1
        if tel_on:
            history.append(float(prog_dev))
            if infeas_round < 0 and bool(infeas_dev[0]):
                infeas_round = rounds
        if stop_progress is not None:
            prog = float(prog_dev)
            flat = flat + 1 if prog < stop_progress else 0
            if flat >= patience:
                stop_round = rounds
                break
    infeasible = bool(check_infeasible(lb, ub, cfg.feas_eps))
    snap = None
    if tel_on:
        snap = obs.host_snapshot(
            history, telemetry, stop_round=stop_round, infeas_round=infeas_round
        )
    return PropagationResult(
        lb=lb,
        ub=ub,
        rounds=jnp.int32(rounds),
        converged=jnp.asarray(not changed),
        infeasible=jnp.asarray(infeasible),
        progress=jnp.asarray(prog),
        telemetry=snap,
    )


def _device_fixed_point(
    round_fn, lb0, ub0, max_rounds: int, unroll: int = 1,
    stop_progress: float | None = None, patience: int = 1,
    plane=None, feas_eps: float | None = None,
):
    """while_loop fixed point; ``unroll`` rounds per convergence check.

    Carries the per-check progress measure (``bounds.progress_measure`` over
    the bound planes -- a device scalar, no host sync).  ``stop_progress``
    arms the early stop: once progress stays below it for ``patience``
    consecutive checks the loop exits even though epsilon-level changes
    continue (a flatlined instance).  Returns ``(lb, ub, changed, rounds,
    progress)`` -- ``progress`` is the last check's measure (NaN before the
    first round).

    ``plane`` (an ``obs.telemetry.TelemetryPlane``, scalar layout) arms the
    device-resident telemetry: the plane joins the loop carry, each check
    appends its progress sample and latches early-stop / infeasibility
    rounds (the probe needs ``feas_eps``), and the final plane is appended
    to the return tuple.  Recording reads the same progress scalar the
    carry already computes and never feeds back into the bounds, so the
    fixed point's arithmetic is unchanged -- still zero host syncs."""

    def body(state):
        lb, ub, _, rounds, _, flat = state[:6]
        lb_in, ub_in = lb, ub
        changed_any = jnp.asarray(False)
        for _ in range(unroll):
            lb, ub, changed = round_fn(lb=lb, ub=ub)
            changed_any = changed_any | changed
            rounds = rounds + 1
        prog = bnd.progress_measure(lb_in, ub_in, lb, ub)
        if stop_progress is not None:
            flat = jnp.where(prog < stop_progress, flat + 1, 0)
        out = (lb, ub, changed_any, rounds, prog, flat)
        if plane is not None:
            stopped = (flat >= patience) if stop_progress is not None else None
            tel = obs.record_round(
                state[6], prog, rounds,
                check_infeasible(lb, ub, feas_eps), stopped,
            )
            out = out + (tel,)
        return out

    def cond(state):
        changed, rounds, flat = state[2], state[3], state[5]
        go = changed & (rounds < max_rounds)
        if stop_progress is not None:
            go = go & (flat < patience)
        return go

    nan = jnp.asarray(jnp.nan, lb0.dtype)
    init = (lb0, ub0, jnp.asarray(True), jnp.int32(0), nan, jnp.int32(0))
    if plane is not None:
        init = init + (plane,)
    # First iteration must run: seed changed=True, but do not count it.
    final = jax.lax.while_loop(cond, body, init)
    lb, ub, changed, rounds, prog = final[:5]
    if plane is not None:
        return lb, ub, changed, rounds, prog, final[6]
    return lb, ub, changed, rounds, prog


def batched_step_rounds(
    round_fn, lb, ub, active, last_changed, rounds, max_rounds: int,
    budget: int | None = None, *,
    stop_progress: float | None = None, patience: int = 1,
    progress=None, flat=None, with_progress: bool = False,
    plane=None, feas_eps: float | None = None,
):
    """Run up to ``budget`` rounds of a batched fixed point and return the
    carried state -- the RESUMABLE core of :func:`batched_fixed_point`.

    ``round_fn(lb, ub, active) -> (lb, ub, changed)`` as there; the state
    quintuple ``(lb, ub, active, last_changed, rounds)`` is exactly the
    fixed point's loop carry, so feeding one call's output to the next
    continues the per-instance round trajectories bit-for-bit -- where the
    step boundary falls cannot change any instance's arithmetic, because a
    round only reads the instance's own tiles and bounds.  The loop exits
    early when every instance converged, so a step over an all-converged
    batch costs one predicate evaluation, not ``budget`` rounds.

    This is the continuous-batching service's device step
    (``core.service``): each pump runs a *bounded* number of rounds per
    bucket -- the per-slot round budget -- then returns control to the host
    so converged slots retire and free slots admit, without any one slow
    instance holding the bucket hostage.  ``budget=None`` (run to
    convergence) makes :func:`batched_fixed_point` a single call of this.

    Progress control (all keyword-only, default off so the 5-tuple
    contract below is unchanged): ``stop_progress`` arms the per-instance
    flatline stop -- an instance whose per-round ``progress_measure``
    stays below it for ``patience`` consecutive rounds drops out of
    ``active`` with ``last_changed`` still True (stopped, not converged).
    ``progress``/``flat`` are the carried ``(B,)`` measure and low-progress
    streak (pass a previous call's values to resume bit-for-bit across
    step boundaries); ``with_progress=True`` appends them to the return,
    making it ``(lb, ub, active, last_changed, rounds, progress, flat)``.

    ``plane`` (an ``obs.telemetry.TelemetryPlane``, batched layout) arms
    device-resident telemetry: the plane rides the carry, every round
    records per-instance progress / early-stop / infeasibility (probe
    needs ``feas_eps``) for the instances that actually ran, and the final
    plane is appended to the return -- the 8-tuple ``(..., progress, flat,
    plane)``.  Passing a previous step's plane back resumes its rings
    bit-for-bit, exactly like the rest of the carry.  Recording never
    touches the bound dataflow (bitwise-identical bounds, zero host
    syncs); its masks reuse the round's own ``active``/``flat`` values.
    """
    track = with_progress or stop_progress is not None or plane is not None
    bsz = lb.shape[0]
    if progress is None:
        progress = jnp.full((bsz,), jnp.nan, lb.dtype)
    if flat is None:
        flat = jnp.zeros((bsz,), jnp.int32)

    def body(state):
        lb, ub, active, last_changed, rounds, progress, flat, k = state[:8]
        lb_in, ub_in = lb, ub
        ran = active
        lb, ub, changed = round_fn(lb, ub, active)
        rounds = rounds + active.astype(jnp.int32)
        last_changed = jnp.where(active, changed, last_changed)
        if track:
            prog = bnd.progress_measure(lb_in, ub_in, lb, ub)
            progress = jnp.where(active, prog, progress)
            if stop_progress is not None:
                flat = jnp.where(
                    active, jnp.where(prog < stop_progress, flat + 1, 0), flat
                )
        active = active & changed & (rounds < max_rounds)
        if stop_progress is not None:
            active = active & (flat < patience)
        out = (lb, ub, active, last_changed, rounds, progress, flat, k + 1)
        if plane is not None:
            stopped = (flat >= patience) if stop_progress is not None else None
            tel = obs.record_round(
                state[8], prog,
                rounds, jnp.any(lb > ub + feas_eps, axis=-1), stopped,
                active=ran,
            )
            out = out + (tel,)
        return out

    def cond(state):
        go = jnp.any(state[2])
        if budget is not None:
            go = go & (state[7] < budget)
        return go

    init = (lb, ub, active, last_changed, rounds, progress, flat, jnp.int32(0))
    if plane is not None:
        init = init + (plane,)
    final = jax.lax.while_loop(cond, body, init)
    lb, ub, active, last_changed, rounds, progress, flat = final[:7]
    if plane is not None:
        return lb, ub, active, last_changed, rounds, progress, flat, final[8]
    if with_progress:
        return lb, ub, active, last_changed, rounds, progress, flat
    return lb, ub, active, last_changed, rounds


def batched_fixed_point(
    round_fn, lb0, ub0, max_rounds: int, active0=None, *,
    stop_progress: float | None = None, patience: int = 1,
    with_progress: bool = False, plane=None, feas_eps: float | None = None,
):
    """Batched while_loop fixed point with a per-instance convergence mask.

    ``round_fn(lb, ub, active) -> (lb, ub, changed)`` operates on
    ``(B, n_pad)`` bounds and per-instance ``(B,)`` flags.  The loop runs
    until *every* instance has converged (or hit ``max_rounds``); an
    instance whose round produced no change drops out of ``active`` and its
    bounds are frozen -- finished instances are no-ops, not stragglers'
    hostages.  Per-instance round counts match what each instance would
    have seen in its own single-instance ``device_loop``.

    Returns ``(lb, ub, rounds, converged)`` with ``rounds``/``converged``
    per instance; ``with_progress=True`` appends the per-instance last
    progress measure (``(lb, ub, rounds, converged, progress)``).
    ``stop_progress``/``patience`` arm the per-instance flatline stop (see
    :func:`batched_step_rounds`): a stopped instance reports
    ``converged=False`` at ``rounds < max_rounds``.

    ``plane``/``feas_eps`` arm per-instance device telemetry (see
    :func:`batched_step_rounds`); the final plane is appended to either
    return shape.
    """
    bsz = lb0.shape[0]
    if active0 is None:
        active0 = jnp.ones((bsz,), dtype=bool)

    out = batched_step_rounds(
        round_fn, lb0, ub0, active0, active0,
        jnp.zeros((bsz,), jnp.int32), max_rounds, budget=None,
        stop_progress=stop_progress, patience=patience, with_progress=True,
        plane=plane, feas_eps=feas_eps,
    )
    lb, ub, _, last_changed, rounds, progress, _ = out[:7]
    tail = (out[7],) if plane is not None else ()
    if with_progress:
        return (lb, ub, rounds, ~last_changed, progress) + tail
    return (lb, ub, rounds, ~last_changed) + tail


def propagate_batch(
    problems,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    driver: str = "device_loop",
    interpret: bool | None = None,
    donate: bool | None = None,
    bounds=None,
    slab: int | None = None,
    stop_progress: float | None = None,
    patience: int = 1,
    policy: TierPolicy | None = None,
    telemetry: int | None = None,
):
    """Propagate a batch of instances, thousands per device dispatch.

    Front end over the batched block-ELL engine: instances are bucketed by
    padded column width (``core.sparse.pack_problems``), each bucket runs
    its fixed point in ONE dispatch with a per-instance convergence mask,
    and results come back as one ``PropagationResult`` per instance, input
    order (``(n_i,)`` bounds each).  Buckets whose ``n_pad`` exceeds the
    VMEM accumulator budget ride the column-slab partitioned kernels
    automatically.  ``bounds`` (one ``(lb_i, ub_i)`` pair of ``(n_i,)``
    arrays or ``None`` per problem) warm-starts instances from caller
    bounds without repacking.  Packing, device transfer and the compiled
    runners are LRU-cached on the identity of the problem list / packed
    batch (see ``kernels.cache_info()``), so a serving loop pays them
    once.  See ``kernels.ops.propagate_batch_block_ell`` for the engine
    knobs; ``stop_progress``/``patience`` arm the per-instance
    progress-based early stop, ``policy`` the two-tier precision
    scheme, and ``telemetry`` per-instance device telemetry snapshots
    (all documented there)."""
    from ..kernels.ops import propagate_batch_block_ell  # lazy: kernels imports core

    return propagate_batch_block_ell(
        problems,
        cfg=cfg,
        tile_rows=tile_rows,
        tile_width=tile_width,
        dtype=dtype,
        use_pallas=use_pallas,
        driver=driver,
        interpret=interpret,
        donate=donate,
        bounds=bounds,
        slab=slab,
        stop_progress=stop_progress,
        patience=patience,
        policy=policy,
        telemetry=telemetry,
    )


def propagate_device_loop(
    dp: DeviceProblem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    unroll: int = 1,
    lb0=None,
    ub0=None,
    stop_progress: float | None = None,
    patience: int = 1,
    telemetry: int | None = None,
) -> PropagationResult:
    """gpu_loop analogue: the whole fixed point is one XLA dispatch.

    Zero-copy: the initial bounds are donated into the while_loop carry, so
    the fixed point runs in place on two device buffers.  ``lb0``/``ub0``
    warm-start the fixed point from caller-supplied bounds;
    ``stop_progress``/``patience`` arm the in-dispatch progress-based early
    stop (see :func:`_device_fixed_point`).  ``telemetry`` (a ring
    capacity) carries a device telemetry plane through the loop and
    attaches its snapshot to the result -- still one dispatch, zero added
    host syncs."""
    round_fn = _round_fn(dp, cfg)
    tel_cap = int(telemetry or 0)

    @functools.partial(jax.jit, **donate_kwargs(argnums=(0, 1)))
    def run(lb0, ub0):
        plane = obs.device_plane(tel_cap, dtype=lb0.dtype) if tel_cap else None
        out = _device_fixed_point(
            round_fn, lb0, ub0, cfg.max_rounds, unroll=unroll,
            stop_progress=stop_progress, patience=patience,
            plane=plane, feas_eps=cfg.feas_eps,
        )
        lb, ub, changed, rounds, prog = out[:5]
        infeasible = check_infeasible(lb, ub, cfg.feas_eps)
        res = (lb, ub, rounds, ~changed, infeasible, prog)
        return res + ((out[5],) if tel_cap else ())

    lb_init, ub_init = initial_bounds((dp.lb0, dp.ub0), lb0, ub0, dp.dtype, dp.n)
    out = run(lb_init, ub_init)
    lb, ub, rounds, converged, infeasible, prog = out[:6]
    snap = obs.TelemetrySnapshot(plane=out[6]) if tel_cap else None
    return PropagationResult(
        lb, ub, rounds, converged, infeasible, prog, telemetry=snap
    )


def propagate_unrolled(
    dp: DeviceProblem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    unroll: int = 4,
    lb0=None,
    ub0=None,
    stop_progress: float | None = None,
    patience: int = 1,
    telemetry: int | None = None,
) -> PropagationResult:
    """megakernel-flavored driver: k fused rounds per convergence check."""
    return propagate_device_loop(
        dp, cfg, unroll=unroll, lb0=lb0, ub0=ub0,
        stop_progress=stop_progress, patience=patience, telemetry=telemetry,
    )


def two_tier_bounds_dtypes(policy: TierPolicy, dtype):
    """Resolve the (fp32 tier, endgame) dtype pair of a tiered run, or
    ``None`` when the policy degenerates to single-tier (disabled, or the
    requested dtype is already low-precision)."""
    import numpy as np

    final = jnp.dtype(dtype) if dtype is not None else (
        jnp.dtype(jnp.float64) if jax.config.jax_enable_x64
        else jnp.dtype(jnp.float32)
    )
    if not policy.two_tier or final in (
        jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)
    ):
        return None
    return np.float32, final


def propagate(
    p: Problem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    driver: str = "device_loop",
    dtype=None,
    lb0=None,
    ub0=None,
    policy: TierPolicy | None = None,
    telemetry: int | None = None,
) -> PropagationResult:
    """Convenience front end: Problem -> PropagationResult (pure-jnp round,
    no Pallas -- the kernel-backed sibling is ``kernels.propagate_block_ell``).

    ``driver`` picks the loop (``host_loop`` syncs one flag per round,
    ``device_loop`` runs the whole fixed point as one dispatch,
    ``unrolled`` checks convergence every k rounds); ``dtype`` overrides
    the value dtype (default: the CSR's, f64 under x64).  ``lb0``/``ub0``
    are ``(n,)`` warm-start overrides for this call only (the tree-search
    path: propagate a B&B node's domain through the root problem's device
    arrays without rebuilding anything); the returned bounds are ``(n,)``
    device arrays in that dtype.

    ``policy`` (a :class:`TierPolicy`) turns on runtime progress control:
    with ``two_tier`` the fixed point runs an fp32 tier (outward-rounded
    merges, so its bounds are never inside the fp64 fixed point) until
    per-round progress drops below ``switch_progress``, promotes the
    bounds by exact cast, and finishes in the requested dtype -- landing
    on the same fixed point the untied run reaches; ``stop_progress``
    additionally early-stops flatlined runs.  ``result.tier_rounds``
    counts the fp32-tier rounds.

    ``telemetry`` (a ring capacity, e.g. ``obs.DEFAULT_CAPACITY``) attaches
    an ``obs.TelemetrySnapshot`` to the result: per-round progress ring,
    early-stop / infeasibility rounds, accumulated on device and read back
    only at exit.  Under a two-tier policy the snapshot is the endgame's,
    with ``tier_switch_round`` stamped (at the host decision point that
    already reads the fp32 round count) and the fp32 tier's own snapshot
    under ``.fp32``."""
    pair = two_tier_bounds_dtypes(policy, dtype) if policy is not None else None
    if pair is not None:
        dt32, final = pair
        cap32 = max(1, int(cfg.max_rounds * policy.fp32_round_frac))
        r32 = _propagate_single(
            p, dataclasses.replace(cfg, max_rounds=cap32), driver, dt32,
            lb0, ub0, stop_progress=policy.switch_progress,
            patience=policy.patience, telemetry=telemetry,
        )
        if bool(r32.infeasible):
            # Never trust an fp32 infeasibility verdict: outward rounding
            # makes it overwhelmingly a true positive, but a cancellation-
            # heavy row can overtighten past the widening, so the endgame
            # re-derives the verdict in the final dtype from scratch.
            r = _propagate_single(
                p, cfg, driver, final, lb0, ub0,
                stop_progress=policy.stop_progress, patience=policy.patience,
                telemetry=telemetry,
            )
            if r.telemetry is not None:
                r = r._replace(
                    telemetry=dataclasses.replace(r.telemetry, fp32=r32.telemetry)
                )
            return r._replace(tier_rounds=r32.rounds)
        tier_rounds = int(r32.rounds)
        rem = dataclasses.replace(
            cfg, max_rounds=max(1, cfg.max_rounds - tier_rounds)
        )
        warm_lb, warm_ub = bnd.canonical_infinite(
            jnp.asarray(r32.lb, final), jnp.asarray(r32.ub, final)
        )
        r = _propagate_single(
            p, rem, driver, final, warm_lb, warm_ub,
            stop_progress=policy.stop_progress, patience=policy.patience,
            telemetry=telemetry,
        )
        if r.telemetry is not None:
            r = r._replace(
                telemetry=dataclasses.replace(
                    r.telemetry,
                    tier_switch_round=tier_rounds,
                    fp32=r32.telemetry,
                )
            )
        return r._replace(
            rounds=r.rounds + r32.rounds, tier_rounds=r32.rounds
        )
    stop = policy.stop_progress if policy is not None else None
    patience = policy.patience if policy is not None else 1
    return _propagate_single(
        p, cfg, driver, dtype, lb0, ub0, stop_progress=stop, patience=patience,
        telemetry=telemetry,
    )


def _propagate_single(
    p: Problem, cfg, driver, dtype, lb0, ub0,
    stop_progress=None, patience: int = 1, telemetry: int | None = None,
) -> PropagationResult:
    """One single-dtype fixed point (the tiered front end calls this twice)."""
    dp = DeviceProblem(p, dtype=dtype)
    kw = dict(
        lb0=lb0, ub0=ub0, stop_progress=stop_progress, patience=patience,
        telemetry=telemetry,
    )
    if driver == "host_loop":
        return propagate_host_loop(dp, cfg, **kw)
    if driver == "device_loop":
        return propagate_device_loop(dp, cfg, **kw)
    if driver == "unrolled":
        return propagate_unrolled(dp, cfg, **kw)
    raise ValueError(f"unknown driver: {driver}")


def fresh_instance_runner(p: Problem, cfg: PropagatorConfig = DEFAULT_CONFIG):
    """One jitted fixed point whose matrix arrays are RUNTIME arguments.

    Returns ``propagate_fresh(lb, ub) -> (lb, ub, rounds)``.  Each call
    re-expands the CSR row structure on the host and re-uploads the whole
    matrix before its single dispatch -- i.e. it treats the node as a
    brand-new instance.  Shapes are stable across calls, so XLA compiles
    once; this is the honest "repack each node" baseline the warm-start
    engines are benchmarked against (``benchmarks/bench_prop.py``,
    ``examples/bnb_dive.py``), and doubles as a one-off runner for streams
    of same-shape instances."""
    eps = cfg.eps_for(p.csr.val.dtype)
    round_fn = functools.partial(
        propagation_round, m=p.m, n=p.n, eps=eps, int_eps=cfg.int_eps, inf=cfg.inf
    )

    @jax.jit
    def run(row_id, col, val, lhs, rhs, is_int, lb0, ub0):
        def body(s):
            lb, ub, _, r = s
            lb, ub, ch = round_fn(row_id, col, val, lhs, rhs, is_int, lb, ub)
            return lb, ub, ch, r + 1

        def cond(s):
            return s[2] & (s[3] < cfg.max_rounds)

        lb, ub, ch, r = jax.lax.while_loop(
            cond, body, (lb0, ub0, jnp.asarray(True), jnp.int32(0))
        )
        return lb, ub, r

    def propagate_fresh(lb, ub):
        # The per-node repack: row expansion on the host + full re-upload.
        return run(
            jnp.asarray(p.csr.row_ids()), jnp.asarray(p.csr.col),
            jnp.asarray(p.csr.val), jnp.asarray(p.lhs), jnp.asarray(p.rhs),
            jnp.asarray(p.is_int), jnp.asarray(lb), jnp.asarray(ub),
        )

    return propagate_fresh


# ---------------------------------------------------------------------------
# Result comparison (paper §4.3)
# ---------------------------------------------------------------------------


def bounds_equal(
    a_lb, a_ub, b_lb, b_ub, t_abs: float = 1e-8, t_rel: float = 1e-5, inf: float = INF
) -> bool:
    """Paper §4.3: |a-b| <= t_abs + t_rel*|b|, with both-infinite counted equal."""
    a_lb, a_ub = np.asarray(a_lb, np.float64), np.asarray(a_ub, np.float64)
    b_lb, b_ub = np.asarray(b_lb, np.float64), np.asarray(b_ub, np.float64)

    def eq(a, b):
        both_pinf = (a >= inf) & (b >= inf)
        both_ninf = (a <= -inf) & (b <= -inf)
        close = np.abs(a - b) <= (t_abs + t_rel * np.abs(b))
        return both_pinf | both_ninf | close

    return bool(np.all(eq(a_lb, b_lb)) and np.all(eq(a_ub, b_ub)))
