"""Device-resident branch-and-bound: the search loop joins the bounds on
device.

Every engine before this one kept the paper's central property -- rounds run
on the accelerator with no host synchronization -- INSIDE one node's fixed
point, while the tree search around it still round-tripped to Python every
level: frontier bookkeeping, branching-variable selection and incumbent
tracking all lived on the host (``examples/bnb_dive.py``'s original shape).
:func:`solve` moves the search itself into device arrays, the
propagate-and-search architecture of Talbot et al.'s GPU constraint solving
(arXiv:2207.12116) on top of this repo's node-batch propagation engine:

  * a fixed-capacity **node pool**: ``(cap, n_pad)`` lower/upper bound
    planes plus per-node ``status`` / ``depth`` / branching / objective
    lanes, with freed slots recycled in place (the service's
    converged-mask-as-occupancy trick from ``core.service``, applied to
    tree nodes instead of serving slots);
  * one **level step** = one traced function: ``batched_fixed_point`` over
    the OPEN rows (frozen rows are in-kernel no-ops), the node-objective
    kernel (``kernels.prop_round.node_objective_tiles`` /
    ``kernels.ref.node_objective_ref``), incumbent update, bound +
    infeasibility pruning, on-device branching-variable selection
    (:class:`BranchRule`) and child expansion -- all inside the same
    dispatch;
  * a ``lax.while_loop`` **outer search loop** whose carry is the pool,
    the incumbent scalar/solution plane, the pseudo-cost statistics, the
    counters and a scalar ``obs.TelemetryPlane``; the host syncs only
    every ``sync_every`` levels, for logging and termination checks, so a
    depth-``d`` search costs at most ``ceil(d / sync_every)`` host syncs.

Exactness contract: :func:`solve` targets PURE-INTEGER instances with
integral matrix data (coefficients, sides, bounds and objective), the
regime of the pseudo-boolean / random-MIP differential-test families.
There, every activity, candidate and objective sum is an exact f64
integer, so (1) a propagation fixed point whose variables are all fixed
and whose domains never crossed is a FEASIBLE point (a violated row would
violate by >= 1 and force a crossing tightening), and (2) ``solve()``'s
optimal objective matches the brute-force oracle
(``core.seq_ref.brute_force_solve``) bitwise -- the property
``tests/test_solver.py`` pins across >= 20 seeded instances.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .sparse import Problem
from .types import DEFAULT_CONFIG, INF, PropagatorConfig

# Node-pool slot states.  FREE slots are recyclable; OPEN nodes propagate
# next level; READY nodes are propagated survivors awaiting expansion.
FREE, OPEN, READY = 0, 1, 2


class BranchRule(enum.Enum):
    """On-device branching-variable selection rule (see ``kernels.ref``).

    ``MOST_FRACTIONAL`` scores each unfixed integer column by its domain
    midpoint's distance to integrality (``most_fractional_ref``);
    ``PSEUDO_COST`` by the product of the average propagated bound gains
    its two child directions achieved so far (``pseudo_cost_select_ref``),
    accumulated on device as ``(2, n_pad)`` sum/count planes.  Both
    resolve ties to the lowest column index, so searches are deterministic.
    """

    MOST_FRACTIONAL = "most_fractional"
    PSEUDO_COST = "pseudo_cost"


class SearchCarry(NamedTuple):
    """The device-resident search state: the ``lax.while_loop`` carry.

    Pool planes are ``(cap, n_pad)``; per-node lanes ``(cap,)``; the
    pseudo-cost statistics ``(2, n_pad)`` (direction 0 = down child);
    everything else is scalar.  ``nbound`` is each node's objective lower
    bound (its pruning key), ``pbound`` its parent's -- their difference
    is the pseudo-cost gain.  The telemetry ``plane`` records one sample
    per LEVEL (see ``obs.telemetry``)."""

    lb: jnp.ndarray        # (cap, n_pad) per-node lower bounds
    ub: jnp.ndarray        # (cap, n_pad) per-node upper bounds
    status: jnp.ndarray    # (cap,) int32: FREE / OPEN / READY
    depth: jnp.ndarray     # (cap,) int32 node depth (root = 0)
    bvar: jnp.ndarray      # (cap,) int32 branching column (-1 at root)
    bdir: jnp.ndarray      # (cap,) int32 branch direction (0 down, 1 up)
    pbound: jnp.ndarray    # (cap,) parent objective bound
    nbound: jnp.ndarray    # (cap,) node objective bound
    pc_sum: jnp.ndarray    # (2, n_pad) pseudo-cost gain sums
    pc_cnt: jnp.ndarray    # (2, n_pad) pseudo-cost observation counts
    inc: jnp.ndarray       # () incumbent objective (INF = none yet)
    inc_x: jnp.ndarray     # (n_pad,) incumbent solution plane
    expanded: jnp.ndarray  # () int32 nodes branched
    created: jnp.ndarray   # () int32 nodes created (root + children)
    leaves: jnp.ndarray    # () int32 feasible all-fixed nodes reached
    pruned_bound: jnp.ndarray   # () int32 nodes pruned on bound
    pruned_infeas: jnp.ndarray  # () int32 nodes pruned infeasible
    levels: jnp.ndarray    # () int32 search levels executed
    done: jnp.ndarray      # () bool: nothing left to expand
    stuck: jnp.ndarray     # () bool: READY nodes but no FREE slots
    plane: object          # scalar obs.TelemetryPlane (per-level samples)


@dataclasses.dataclass
class SolveResult:
    """Outcome of one :func:`solve` search (host-side, built at the final
    sync).  ``status`` is ``'optimal'`` (search completed with an
    incumbent), ``'infeasible'`` (completed without one),
    ``'pool_exhausted'`` (READY nodes remained but no FREE slots -- raise
    ``node_cap``) or ``'level_limit'`` (hit ``max_levels``).  The node
    accounting satisfies ``created == 1 + 2 * expanded`` and, on a
    completed search, ``created == leaves + pruned_infeasible +
    pruned_bound + expanded``.  ``incumbent_trajectory`` holds the
    incumbent objective observed at each host sync (``host_syncs``
    entries, one per device dispatch); ``telemetry`` is an
    ``obs.TelemetrySnapshot`` of the per-level plane when requested."""

    status: str
    objective: float
    x: "np.ndarray | None"
    feasible: bool
    nodes_expanded: int
    nodes_created: int
    leaves: int
    pruned_bound: int
    pruned_infeasible: int
    levels: int
    host_syncs: int
    incumbent_trajectory: "list[float]"
    telemetry: object = None


def _plan_expansion(status, depth, nbound, width=None):
    """Pure slot planning for one expansion wave (unit-testable).

    Ranks READY nodes deepest-first (DFS keeps the pool small), then
    best-bound, then slot id -- three chained STABLE argsorts, least
    significant key first, so the order is deterministic.  FREE slots rank
    by slot id.  ``k = min(#READY, #FREE)`` pairs expand (further clamped
    to ``width`` when given -- the DFS beam: un-expanded READY nodes just
    wait, so a bounded wave never loses completeness, only defers): rank
    ``r``'s parent slot is ``parent[r]``, its up-child's slot ``child[r]``
    (the down child reuses the parent slot in place).  Ranks ``>= k``
    carry the out-of-range sentinel ``cap``, so ``mode='drop'`` scatters
    ignore them.  Returns ``(parent, child, k, n_ready, n_free)``."""
    cap = status.shape[0]
    ready = status == READY
    free = status == FREE
    order = jnp.argsort(nbound, stable=True)
    order = order[jnp.argsort(-depth[order], stable=True)]
    order = order[jnp.argsort((~ready[order]).astype(jnp.int32), stable=True)]
    slots = jnp.argsort((~free).astype(jnp.int32), stable=True)
    n_ready = jnp.sum(ready, dtype=jnp.int32)
    n_free = jnp.sum(free, dtype=jnp.int32)
    k = jnp.minimum(n_ready, n_free)
    if width is not None:
        k = jnp.minimum(k, jnp.int32(width))
    r = jnp.arange(cap)
    parent = jnp.where(r < k, order, cap)
    child = jnp.where(r < k, slots, cap)
    return parent, child, k, n_ready, n_free


def _make_level_step(prep, cfg, rule, use_pallas, interpret, prune_gap,
                     expand_width):
    """Build the traced level step ``(carry, c_pad) -> carry`` over one
    prepared instance: propagate OPEN rows to their fixed points, score
    them, update the incumbent, prune, select branching variables and
    expand -- one function, inlined into the search ``while_loop`` body."""
    from ..kernels import ref as kref  # lazy: kernels imports core
    from ..kernels.prop_round import node_objective_tiles
    from .propagator import batched_fixed_point
    from ..obs import telemetry as obs

    n_pad, n = prep.n_pad, prep.n
    col_valid = np.zeros(n_pad, dtype=bool)
    col_valid[:n] = True
    valid = jnp.asarray(col_valid)
    is_int = np.zeros(n_pad, dtype=bool)
    is_int[:n] = np.asarray(prep.d.is_int, bool)[:n]
    ii = jnp.asarray(is_int)
    from ..kernels.ops import node_round_fn_for

    round_fn = node_round_fn_for(prep, cfg, use_pallas, interpret)
    pallas_objective = bool(use_pallas) and n_pad <= 2**16

    def step(c: SearchCarry, c_pad) -> SearchCarry:
        cap = c.status.shape[0]
        open_m = c.status == OPEN

        # (1) All OPEN nodes to their propagation fixed points, one inner
        # loop; FREE/READY rows are frozen (active0 mask).
        lb, ub, _, _ = batched_fixed_point(
            round_fn, c.lb, c.ub, cfg.max_rounds, active0=open_m
        )

        # (2) Objective bound + leaf / infeasibility predicates.
        if pallas_objective:
            obj, fixed, crossed = node_objective_tiles(
                lb, ub, c_pad, ii, valid, cfg.feas_eps, cfg.inf, interpret
            )
        else:
            obj, fixed, crossed = kref.node_objective_ref(
                lb, ub, c_pad, ii, valid, cfg.feas_eps, cfg.inf
            )
        infeas = crossed & open_m
        # Monotone: a child's bound can only improve on its parent's.
        nb = jnp.where(open_m, jnp.maximum(obj, c.pbound), c.nbound)

        # (3) Pseudo-cost statistics: each propagated child credits its
        # branching (column, direction) with its bound gain.  Sentinel
        # column n_pad + mode='drop' masks non-contributors.
        contrib = open_m & (c.bvar >= 0) & ~infeas
        gain = jnp.where(contrib, jnp.maximum(nb - c.pbound, 0.0), 0.0)
        vidx = jnp.where(contrib, c.bvar, n_pad)
        didx = jnp.clip(c.bdir, 0, 1)
        pc_sum = c.pc_sum.at[didx, vidx].add(gain, mode="drop")
        pc_cnt = c.pc_cnt.at[didx, vidx].add(
            contrib.astype(c.pc_cnt.dtype), mode="drop"
        )

        # (4) Incumbent: best feasible all-fixed node this level (min +
        # first-index argmin -- deterministic reduction order).
        leaf = open_m & ~infeas & fixed
        inc, inc_x, improved = kref.incumbent_update_ref(
            leaf, obj, c.inc, c.inc_x, lb, cfg.inf
        )

        # (5) Pruning + status transitions.  OPEN survivors whose bound
        # cannot beat the incumbent are fathomed; existing READY nodes are
        # re-fathomed against the improved incumbent.
        survivor = open_m & ~infeas & ~leaf
        pruned_o = survivor & (nb >= inc - prune_gap)
        to_ready = survivor & ~pruned_o
        pruned_r = (c.status == READY) & (c.nbound >= inc - prune_gap)
        status = jnp.where(
            open_m,
            jnp.where(to_ready, READY, FREE).astype(jnp.int32),
            c.status,
        )
        status = jnp.where(pruned_r, FREE, status)

        # (6) Expansion: slot plan + on-device branching selection.
        parent, child, k, n_ready, n_free = _plan_expansion(
            status, c.depth, nb, expand_width
        )
        if rule is BranchRule.PSEUDO_COST:
            var_all, _ = kref.pseudo_cost_select_ref(
                lb, ub, ii, valid, pc_sum, pc_cnt
            )
        else:
            var_all, _ = kref.most_fractional_ref(lb, ub, ii, valid)
        pg = jnp.minimum(parent, cap - 1)  # clamped gather twin of parent
        r = jnp.arange(cap)
        pv = var_all[pg]
        plbv = lb[pg, pv]
        pubv = ub[pg, pv]
        bv = jnp.clip(jnp.floor(0.5 * (plbv + pubv)), plbv, pubv - 1.0)
        pdep = c.depth[pg]
        pnb = nb[pg]
        # Parent planes gathered BEFORE the in-place down-child scatter.
        plb_rows = lb[pg]
        pub_rows = ub[pg]
        up_lb = plb_rows.at[r, pv].set(bv + 1.0)
        # Down child reuses the parent slot: only ub[bvar] moves.
        ub = ub.at[parent, pv].set(bv, mode="drop")
        # Up child fills a FREE slot with the parent's planes + lb[bvar].
        lb = lb.at[child].set(up_lb, mode="drop")
        ub = ub.at[child].set(pub_rows, mode="drop")

        def stamp(lane, down_val, up_val):
            return lane.at[parent].set(down_val, mode="drop").at[child].set(
                up_val, mode="drop"
            )

        status = stamp(status, jnp.int32(OPEN), jnp.int32(OPEN))
        depth = stamp(c.depth, pdep + 1, pdep + 1)
        bvar = stamp(c.bvar, pv.astype(jnp.int32), pv.astype(jnp.int32))
        bdir = stamp(c.bdir, jnp.int32(0), jnp.int32(1))
        pbound = stamp(c.pbound, pnb, pnb)
        nbound = stamp(nb, pnb, pnb)

        # (7) Counters, termination, telemetry (one sample per level: the
        # next frontier's width, first-incumbent / first-fathom latches).
        levels = c.levels + 1
        done = n_ready == 0
        stuck = (n_ready > 0) & (k == 0)
        plane = obs.record_round(
            c.plane,
            progress=(2 * k).astype(c.lb.dtype),
            rounds=levels,
            infeasible=jnp.any(infeas),
            stopped=improved,
        )
        return SearchCarry(
            lb=lb, ub=ub, status=status, depth=depth, bvar=bvar, bdir=bdir,
            pbound=pbound, nbound=nbound, pc_sum=pc_sum, pc_cnt=pc_cnt,
            inc=inc, inc_x=inc_x,
            expanded=(c.expanded + k).astype(jnp.int32),
            created=(c.created + 2 * k).astype(jnp.int32),
            leaves=(c.leaves + jnp.sum(leaf, dtype=jnp.int32)).astype(jnp.int32),
            pruned_bound=(
                c.pruned_bound
                + jnp.sum(pruned_o, dtype=jnp.int32)
                + jnp.sum(pruned_r, dtype=jnp.int32)
            ).astype(jnp.int32),
            pruned_infeas=(
                c.pruned_infeas + jnp.sum(infeas, dtype=jnp.int32)
            ).astype(jnp.int32),
            levels=levels, done=done, stuck=stuck, plane=plane,
        )

    return step


@functools.lru_cache(maxsize=32)
def _init_carry(cap, n_pad, dt, tel_cap):
    """Jitted fresh-pool builder: ONE dispatch instead of ~20 small ones.

    Building the carry eagerly costs a host round-trip per array on CPU --
    milliseconds of fixed overhead that dominates short searches.  The
    shape key is tiny, so the compiled builders are cached for the life of
    the process."""
    from ..obs import telemetry as obs

    @jax.jit
    def init(lb0, ub0):
        return SearchCarry(
            lb=jnp.zeros((cap, n_pad), dt).at[0].set(lb0),
            ub=jnp.zeros((cap, n_pad), dt).at[0].set(ub0),
            status=jnp.zeros(cap, jnp.int32).at[0].set(OPEN),
            depth=jnp.zeros(cap, jnp.int32),
            bvar=jnp.full(cap, -1, jnp.int32),
            bdir=jnp.zeros(cap, jnp.int32),
            pbound=jnp.full(cap, -INF, dt),
            nbound=jnp.full(cap, -INF, dt),
            pc_sum=jnp.zeros((2, n_pad), dt),
            pc_cnt=jnp.zeros((2, n_pad), dt),
            inc=jnp.asarray(INF, dt),
            inc_x=jnp.zeros(n_pad, dt),
            expanded=jnp.int32(0),
            created=jnp.int32(1),
            leaves=jnp.int32(0),
            pruned_bound=jnp.int32(0),
            pruned_infeas=jnp.int32(0),
            levels=jnp.int32(0),
            done=jnp.asarray(False),
            stuck=jnp.asarray(False),
            plane=obs.device_plane(tel_cap, dtype=dt),
        )

    return init


# Compiled search runners, cached per matrix structure + pool capacity +
# search knobs (bounds and the objective are runtime arguments, so one
# resident runner serves every solve() of the same instance).  Lazily
# constructed so importing core never drags the kernels package in.
_solver_runner_cache = None


def _solver_runner(prep, cap, cfg, rule, use_pallas, interpret, prune_gap,
                   expand_width, tel_cap):
    from ..kernels.ops import LRU
    from .propagator import donate_kwargs, donate_supported

    global _solver_runner_cache
    if _solver_runner_cache is None:
        _solver_runner_cache = LRU(maxsize=16)
    do_donate = donate_supported()
    key = (
        id(prep.d.val), cap, cfg, rule, use_pallas, interpret, prune_gap,
        expand_width, tel_cap, do_donate,
    )
    anchors = (prep.d.val,)
    runner = _solver_runner_cache.get(key, anchors)
    if runner is not None:
        return runner

    step = _make_level_step(
        prep, cfg, rule, use_pallas, interpret, prune_gap, expand_width
    )

    @functools.partial(jax.jit, **donate_kwargs(argnums=(0,)))
    def run(carry: SearchCarry, c_pad, level_target) -> SearchCarry:
        def cond(c):
            return (~c.done) & (~c.stuck) & (c.levels < level_target)

        return jax.lax.while_loop(cond, lambda c: step(c, c_pad), carry)

    _solver_runner_cache.put(key, anchors, run)
    return run


def solve(
    p: Problem,
    c,
    *,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    rule: BranchRule = BranchRule.MOST_FRACTIONAL,
    node_cap: int = 256,
    max_levels: int = 64,
    sync_every: int = 8,
    prune_gap: float = 0.0,
    expand_width: int | None = None,
    tile_rows: int = 8,
    tile_width: int = 8,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    telemetry: int | None = None,
    on_sync: "Callable[[dict], None] | None" = None,
) -> SolveResult:
    """Branch-and-bound minimization of ``c @ x`` with device-resident
    search state.

    ``p`` must be pure-integer (``p.is_int`` all True); ``c`` is the
    ``(n,)`` minimization objective.  The search lives in a fixed
    ``node_cap``-slot device pool and advances one LEVEL at a time -- each
    level propagates every OPEN node to its fixed point, updates the
    incumbent from feasible fully-fixed nodes, prunes on bound and
    infeasibility, and expands the survivors depth-first (down child in
    the parent's slot, up child in a recycled FREE slot).  The host is
    consulted only every ``sync_every`` levels: one small ``device_get``
    per dispatch, so a depth-``d`` search syncs at most
    ``ceil(d / sync_every)`` times (``on_sync``, when given, is called
    with a progress dict at exactly those points -- the test hook for the
    sync-count contract).

    ``rule`` picks the on-device branching-variable selection
    (:class:`BranchRule`); ``prune_gap`` widens the fathoming test to
    ``bound >= incumbent - prune_gap`` (0.0 = exact; ``-INF`` disables
    bound pruning, the property-test lever).  ``expand_width`` clamps each
    expansion wave (default: every READY node with a FREE slot expands) --
    with the deepest-first priority a small width acts as a DFS beam, so
    searches whose early levels would otherwise exhaust the pool before
    any leaf seeds the incumbent dig deep first instead; un-expanded READY
    nodes simply wait, so completeness is preserved.  ``use_pallas``
    defaults to
    Pallas kernels on TPU and the jnp dataflow elsewhere (same policy as
    the benches); ``telemetry`` (a ring capacity) records one sample per
    level into a scalar ``obs.TelemetryPlane`` riding the search carry.
    See the module docstring for the integral-data exactness contract.
    """
    from ..kernels.ops import prepare_block_ell

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not bool(np.all(np.asarray(p.is_int, bool))):
        raise ValueError("solve() requires a pure-integer problem (is_int all True)")
    c = np.asarray(c, np.float64)
    if c.shape != (p.n,):
        raise ValueError(f"objective has shape {c.shape}, expected {(p.n,)}")
    cap = int(node_cap)
    if cap < 2:
        raise ValueError("node_cap must be >= 2")
    sync_every = max(1, int(sync_every))
    if expand_width is not None:
        expand_width = int(expand_width)
        if expand_width < 1:
            raise ValueError("expand_width must be >= 1 (or None)")
    tel_cap = int(telemetry or 0)

    prep = prepare_block_ell(p, tile_rows, tile_width, None)
    dt = prep.d.val.dtype
    n_pad = prep.n_pad
    from ..obs import telemetry as obs

    c_pad = jnp.asarray(np.pad(c, (0, n_pad - p.n)), dt)
    carry = _init_carry(cap, n_pad, dt, max(tel_cap, 1))(prep.lb0, prep.ub0)
    run = _solver_runner(
        prep, cap, cfg, rule, use_pallas, interpret, float(prune_gap),
        expand_width, tel_cap,
    )

    syncs = 0
    traj: "list[float]" = []
    target = 0
    while True:
        target = min(target + sync_every, max_levels)
        carry = run(carry, c_pad, jnp.int32(target))
        # THE host sync: one device_get of the scalars + status lane.
        host = jax.device_get((
            carry.done, carry.stuck, carry.levels, carry.inc,
            carry.expanded, carry.created, carry.leaves,
            carry.pruned_bound, carry.pruned_infeas, carry.status,
        ))
        done, stuck, levels, inc = (
            bool(host[0]), bool(host[1]), int(host[2]), float(host[3])
        )
        syncs += 1
        traj.append(inc)
        if on_sync is not None:
            st = np.asarray(host[9])
            on_sync({
                "sync": syncs,
                "levels": levels,
                "incumbent": inc,
                "done": done,
                "stuck": stuck,
                "expanded": int(host[4]),
                "created": int(host[5]),
                "open": int((st == OPEN).sum()),
                "ready": int((st == READY).sum()),
                "free": int((st == FREE).sum()),
            })
        if done or stuck or levels >= max_levels:
            break

    feasible = inc < INF
    if stuck:
        status = "pool_exhausted"
    elif not done:
        status = "level_limit"
    elif feasible:
        status = "optimal"
    else:
        status = "infeasible"
    x = np.asarray(carry.inc_x)[: p.n].copy() if feasible else None
    snap = obs.TelemetrySnapshot(plane=carry.plane) if tel_cap else None
    assert syncs <= max(1, math.ceil(levels / sync_every))
    return SolveResult(
        status=status,
        objective=inc if feasible else INF,
        x=x,
        feasible=feasible,
        nodes_expanded=int(host[4]),
        nodes_created=int(host[5]),
        leaves=int(host[6]),
        pruned_bound=int(host[7]),
        pruned_infeasible=int(host[8]),
        levels=levels,
        host_syncs=syncs,
        incumbent_trajectory=traj,
        telemetry=snap,
    )


__all__ = [
    "BranchRule",
    "SearchCarry",
    "SolveResult",
    "solve",
]
