"""Sequential domain propagation (paper Algorithm 1) -- the cpu_seq baseline.

Faithful numpy implementation of the state-of-the-art sequential algorithm,
including:

  * the constraint *marking* mechanism (lines 1, 6, 7, 20) driven by a CSC
    view built once up-front (init excluded from timing, paper §4.3);
  * early-termination checks (redundant / cannot-propagate constraints are
    skipped);
  * immediate bound updates: a tightening found while processing constraint c
    is visible to every constraint processed after c in the same round --
    the sequential advantage quantified in §2.2.

A variant without marking (``propagate_sequential(..., use_marking=False)``)
serves as the independent second baseline for the Fig.-3-style validation
benchmark.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .sparse import Problem, csr_to_csc
from .types import DEFAULT_CONFIG, INF, PropagatorConfig


@dataclasses.dataclass
class SeqResult:
    """Outcome of the sequential reference propagation (host numpy):
    tightened ``(n,)`` bounds, rounds to the fixed point, convergence /
    infeasibility verdicts, and the total number of bound changes applied
    (the marking mechanism's work measure)."""

    lb: np.ndarray
    ub: np.ndarray
    rounds: int
    converged: bool
    infeasible: bool
    n_bound_changes: int


def _row_activities(a, lb_v, ub_v, inf):
    """Finite parts + infinity counts of min/max activity for one row."""
    pos = a > 0
    b_min = np.where(pos, lb_v, ub_v)
    b_max = np.where(pos, ub_v, lb_v)
    min_inf = np.abs(b_min) >= inf
    max_inf = np.abs(b_max) >= inf
    min_fin = float(np.sum(np.where(min_inf, 0.0, a * b_min)))
    max_fin = float(np.sum(np.where(max_inf, 0.0, a * b_max)))
    return min_fin, int(min_inf.sum()), max_fin, int(max_inf.sum()), min_inf, max_inf


def propagate_sequential(
    p: Problem,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    use_marking: bool = True,
    dtype=np.float64,
) -> SeqResult:
    """The paper's sequential Algorithm 1 on the host: constraint-at-a-time
    propagation with the CSC-based marking mechanism (``use_marking=False``
    sweeps every row each round instead).  The limit-point reference every
    parallel engine is validated against (paper §4.3 tolerance)."""
    csr = p.csr.astype(dtype)
    m, n = csr.m, csr.n
    inf = cfg.inf
    eps = cfg.tighten_eps if dtype == np.float64 else cfg.tighten_eps_f32
    int_eps = cfg.int_eps

    lb = p.lb.astype(dtype).copy()
    ub = p.ub.astype(dtype).copy()
    lhs = p.lhs.astype(dtype)
    rhs = p.rhs.astype(dtype)
    is_int = p.is_int

    # Init phase (excluded from timed region by callers): CSC for marking.
    csc = csr_to_csc(p.csr)

    marked = np.ones(m, dtype=bool)
    rounds = 0
    infeasible = False
    n_changes = 0
    bound_change_found = True

    while bound_change_found and rounds < cfg.max_rounds and not infeasible:
        bound_change_found = False
        rounds += 1
        for c in range(m):
            if use_marking and not marked[c]:
                continue
            marked[c] = False
            s, e = int(csr.row_ptr[c]), int(csr.row_ptr[c + 1])
            if s == e:
                continue
            a = csr.val[s:e]
            cols = csr.col[s:e]
            lb_v = lb[cols]
            ub_v = ub[cols]
            min_fin, min_cnt, max_fin, max_cnt, min_inf, max_inf = _row_activities(
                a, lb_v, ub_v, inf
            )
            amin = -inf if min_cnt > 0 else min_fin
            amax = inf if max_cnt > 0 else max_fin

            # Early termination (paper Alg. 1 line 9): redundant constraints
            # cannot tighten anything.
            if lhs[c] <= amin + 1e-12 * max(1.0, abs(amin)) and amax <= rhs[c] + 1e-12 * max(1.0, abs(amax)):
                continue
            # No finite residual on either side -> nothing to propagate.
            if min_cnt >= 2 and max_cnt >= 2:
                continue

            pos = a > 0
            contrib_min = np.where(min_inf, 0.0, a * np.where(pos, lb_v, ub_v))
            contrib_max = np.where(max_inf, 0.0, a * np.where(pos, ub_v, lb_v))

            for k in range(e - s):
                j = int(cols[k])
                ak = float(a[k])
                # Residual activities (Eqs. 5a/5b, §3.4 single-infinity rule).
                if min_inf[k]:
                    min_res = min_fin if min_cnt == 1 else -inf
                else:
                    min_res = min_fin - contrib_min[k] if min_cnt == 0 else -inf
                if max_inf[k]:
                    max_res = max_fin if max_cnt == 1 else inf
                else:
                    max_res = max_fin - contrib_max[k] if max_cnt == 0 else inf

                if ak > 0:
                    lcand_ok = lhs[c] > -inf and max_res < inf
                    ucand_ok = rhs[c] < inf and min_res > -inf
                    lcand = (lhs[c] - max_res) / ak if lcand_ok else -inf
                    ucand = (rhs[c] - min_res) / ak if ucand_ok else inf
                else:
                    lcand_ok = rhs[c] < inf and min_res > -inf
                    ucand_ok = lhs[c] > -inf and max_res < inf
                    lcand = (rhs[c] - min_res) / ak if lcand_ok else -inf
                    ucand = (lhs[c] - max_res) / ak if ucand_ok else inf

                if is_int[j]:
                    if abs(lcand) < inf:
                        lcand = np.ceil(lcand - int_eps)
                    if abs(ucand) < inf:
                        ucand = np.floor(ucand + int_eps)

                changed_j = False
                if lcand > lb[j] + eps * max(1.0, abs(lb[j])):
                    lb[j] = min(max(lcand, -inf), inf)
                    changed_j = True
                if ucand < ub[j] - eps * max(1.0, abs(ub[j])):
                    ub[j] = min(max(ucand, -inf), inf)
                    changed_j = True
                if changed_j:
                    n_changes += 1
                    bound_change_found = True
                    if lb[j] > ub[j] + cfg.feas_eps:
                        infeasible = True
                    # Mark every constraint containing variable j (line 20).
                    cs, ce = int(csc.col_ptr[j]), int(csc.col_ptr[j + 1])
                    marked[csc.row[cs:ce]] = True
                    # Bound of j changed -> our own activities are stale.
                    lb_v = lb[cols]
                    ub_v = ub[cols]
                    (
                        min_fin,
                        min_cnt,
                        max_fin,
                        max_cnt,
                        min_inf,
                        max_inf,
                    ) = _row_activities(a, lb_v, ub_v, inf)
                    contrib_min = np.where(
                        min_inf, 0.0, a * np.where(pos, lb_v, ub_v)
                    )
                    contrib_max = np.where(
                        max_inf, 0.0, a * np.where(pos, ub_v, lb_v)
                    )
                if infeasible:
                    break
            if infeasible:
                break

    converged = not bound_change_found and not infeasible
    return SeqResult(
        lb=lb,
        ub=ub,
        rounds=rounds,
        converged=converged,
        infeasible=infeasible,
        n_bound_changes=n_changes,
    )


@dataclasses.dataclass
class BruteForceResult:
    """Outcome of :func:`brute_force_solve`: the exact optimal objective
    and one optimal assignment (``None`` when infeasible), the feasibility
    verdict, and the number of assignments enumerated."""

    objective: float
    x: "np.ndarray | None"
    feasible: bool
    n_enumerated: int


def brute_force_solve(
    p: Problem,
    c,
    feas_eps: float = 1e-8,
    limit: int = 2_000_000,
    chunk: int = 65536,
) -> BruteForceResult:
    """Exhaustive minimization of ``c @ x`` over the integer box -- the
    exact oracle the device solver's differential tests compare against.

    Enumerates EVERY integer assignment in ``prod_j (ub_j - lb_j + 1)``
    (mixed-radix, variable 0 most significant; ``limit`` guards against
    accidental blowups -- binary instances are fine up to n = 20), checks
    each against the dense constraint rows with the same ``feas_eps``
    tolerance the propagator uses (infinite sides are no constraints), and
    returns the minimum objective over the feasible set with a
    first-in-enumeration-order tie-break.  All host numpy in f64: over
    integral data the objective sums are exact, so the comparison to
    ``solver.solve()`` is bitwise.  Enumeration runs in ``chunk``-sized
    blocks to bound memory."""
    lb = np.asarray(p.lb, np.float64)
    ub = np.asarray(p.ub, np.float64)
    c = np.asarray(c, np.float64)
    if not bool(np.all(np.asarray(p.is_int, bool))):
        raise ValueError("brute_force_solve requires a pure-integer problem")
    if np.any(np.abs(lb) >= INF) or np.any(np.abs(ub) >= INF):
        raise ValueError("brute_force_solve requires finite variable bounds")
    widths = (ub - lb + 1.0).astype(np.int64)
    if np.any(widths < 1):
        return BruteForceResult(INF, None, False, 0)
    total = int(np.prod(widths))
    if total > limit:
        raise ValueError(f"{total} assignments exceed the {limit} cap")

    n = p.n
    dense = np.zeros((p.m, n))
    csr = p.csr
    dense[csr.row_ids(), csr.col] = csr.val
    lhs = np.asarray(p.lhs, np.float64)
    rhs = np.asarray(p.rhs, np.float64)
    has_lhs = lhs > -INF
    has_rhs = rhs < INF

    # Mixed-radix place values, variable 0 most significant.
    place = np.ones(n, np.int64)
    for j in range(n - 2, -1, -1):
        place[j] = place[j + 1] * widths[j + 1]

    best_obj = INF
    best_x = None
    for start in range(0, total, chunk):
        idx = np.arange(start, min(start + chunk, total), dtype=np.int64)
        digits = (idx[:, None] // place[None, :]) % widths[None, :]
        X = lb[None, :] + digits.astype(np.float64)
        act = X @ dense.T
        ok = np.ones(idx.shape[0], dtype=bool)
        if has_lhs.any():
            ok &= np.all(act[:, has_lhs] >= lhs[has_lhs][None, :] - feas_eps, axis=1)
        if has_rhs.any():
            ok &= np.all(act[:, has_rhs] <= rhs[has_rhs][None, :] + feas_eps, axis=1)
        if not ok.any():
            continue
        obj = X[ok] @ c
        k = int(np.argmin(obj))
        if obj[k] < best_obj:
            best_obj = float(obj[k])
            best_x = X[ok][k].copy()
    return BruteForceResult(
        objective=best_obj if best_x is not None else INF,
        x=best_x,
        feasible=best_x is not None,
        n_enumerated=total,
    )
