"""Continuous-batching propagation service: slot-recycled resident
super-tiles with AOT-warmed engines.

The paper's headline is throughput -- propagation rounds run entirely on the
accelerator with no host synchronization -- but a fixed-batch driver
(:func:`repro.core.propagator.propagate_batch`) still stops the world at
batch boundaries: every new batch repacks, re-uploads and (first time)
recompiles, and the whole batch waits for its slowest instance.  This module
is the serving loop that removes those stalls, in the spirit of the
progress-measure serving loop of Sofranac et al. (arXiv:2106.07573) and the
fully device-resident search loop of Talbot et al. (arXiv:2207.12116):

* Each :class:`BucketSpec` keeps ONE device-resident super-tile of
  ``slots`` fixed-shape slots.  An arriving instance is packed host-side to
  the slot shape (:func:`repro.core.sparse.pack_into_slot`) and admitted by
  scattering its tiles/bounds into a free slot in one device op -- the
  resident batch is never repacked or reshaped.
* The per-instance ``converged``/``active`` mask of the batched kernels IS
  the slot-occupancy mask: a free (or just-retired) slot is an inactive
  instance, so its tiles skip gather/compute/scatter in-kernel and an empty
  slot costs ~nothing.  Retirement is pure host bookkeeping plus an async
  readback of the bound plane; the device loop never stops for it.
* Every compiled engine (the budgeted step and the power-of-two admission
  scatters) is built and warmed when the service is constructed, and cached
  process-wide by bucket shape -- admission and backfill NEVER compile.
* Each pump runs a bounded number of rounds per bucket
  (:func:`repro.core.propagator.batched_step_rounds` with a ``budget``), so
  one slow instance cannot hold a bucket hostage: converged co-residents
  retire and their slots backfill at the next step boundary while the slow
  instance keeps iterating.

Bitwise contract: a slot-resident instance follows the exact round
trajectory of a one-shot ``propagate_batch`` of the same instance (same
tile parameters) -- a round only reads the instance's own tiles, bounds and
rows, co-residents and step boundaries cannot perturb its arithmetic, and
retirement reads back the converged plane unchanged.  ``tests/test_service.py``
asserts this bit-for-bit through admit -> converge -> retire -> backfill.
One caveat, by construction: the service's matrix buffers are RUNTIME
arguments of its compiled step (that is what makes admission compile-free),
while the one-shot engines close over them as jit constants -- XLA may
compile the two dataflow graphs with differently-associated reductions, so
equality of every float op is only guaranteed up to reassociation ulps.
Whenever the per-row dot products are exactly representable (integral
coefficient/bound families like set covers or knapsacks -- and any engine
whose round runs as a Pallas kernel, whose in-kernel order is fixed), the
trajectories are identical bit-for-bit, and the tests pin exactly that.

Observability rides the same zero-sync discipline (``repro.obs``): an
optional per-slot telemetry plane lives in the resident state (entries
13-16) and is read back only at the retirement sync; a host-side tracer
emits pump/admit/step/readback spans plus one ``ticket`` span per
request; and ``stats()`` carries a unified metrics-registry snapshot.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.metrics import default_registry
from ..obs.telemetry import TelemetryPlane, TelemetrySnapshot, reset_rows
from ..obs.trace import NULL_TRACER
from .propagator import batched_step_rounds, donate_kwargs
from .sparse import LANE, Problem, SlotPayload, col_pad, evict_slot, pack_into_slot
from .types import DEFAULT_CONFIG, PropagationResult, PropagatorConfig

# Resident bucket state layout (flat tuple, so the jitted step/admit engines
# can donate individual buffers):
#   0 val    (slots*slot_tiles, R, K)  tile values; 0 == padding
#   1 col    (slots*slot_tiles, R, K)  int32 SLOT-LOCAL columns
#   2 ii     (slots*slot_tiles, R, K)  int32 integrality gather
#   3 crow   (slots*slot_tiles, R)    int32 GLOBAL rows (slot-offset applied)
#   4 lhs_c  (slots*slot_tiles, R)    per-chunk lhs (0 at dummy rows)
#   5 rhs_c  (slots*slot_tiles, R)    per-chunk rhs
#   6 lb     (slots, n_pad)           bound plane
#   7 ub     (slots, n_pad)
#   8 active (slots,) bool            occupancy mask == still-running mask
#   9 last_changed (slots,) bool      convergence evidence (as in fixed point)
#  10 rounds (slots,) int32           per-slot rounds executed
#  11 progress (slots,)               last round's progress measure (NaN fresh)
#  12 flat   (slots,) int32           consecutive low-progress rounds
#  13 ring   (slots, tel_cap)         telemetry progress rings (tel_cap may be 0)
#  14 ticks  (slots,) int32           telemetry rounds recorded per slot
#  15 stop_round (slots,) int32       early-stop round latch (-1 = never)
#  16 infeas_round (slots,) int32     first crossed-bounds round (-1 = never)
# The telemetry entries exist in EVERY state (zero-width ring when the
# service runs without telemetry), so there is exactly one state layout and
# one donation signature per bucket shape regardless of the telemetry knob.
_LB, _UB, _ACTIVE, _LAST_CHANGED, _ROUNDS = 6, 7, 8, 9, 10
_PROGRESS, _FLAT = 11, 12
_RING, _TICKS, _STOPR, _INFSR = 13, 14, 15, 16
_MATRIX_ARGS = 6          # state[:6] is the scattered matrix payload
_STATE_ARGS = 17

_TW_CANDIDATES = (8, 16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Fixed slot geometry of one resident bucket.

    Slot ``i`` of a bucket owns tiles ``[i*slot_tiles, (i+1)*slot_tiles)``
    of the flat tile stream, the column window ``[i*n_pad, (i+1)*n_pad)``
    of the bound plane and the row range ``[i*(slot_rows+1),
    (i+1)*(slot_rows+1))`` (one dummy row per slot, at the resident
    instance's local ``m``).  Payloads are slot-local
    (:class:`repro.core.sparse.SlotPayload`); the admission scatter adds
    the slot offsets on device, so any payload fits any free slot.

    ``fits_one_chunk`` is the engine-path policy bit: when True the bucket
    runs the fused round (every row of every admitted instance must fit one
    ``tile_width`` chunk -- :meth:`admits` enforces it); otherwise the
    multichunk dataflow round handles split rows.
    """

    n_pad: int
    slots: int
    slot_tiles: int
    slot_rows: int
    tile_rows: int = 8
    tile_width: int = 128
    fits_one_chunk: bool = False

    @property
    def m_total(self) -> int:
        """Total rows of the resident bucket (one dummy row per slot)."""
        return self.slots * (self.slot_rows + 1)

    def chunks_needed(self, row_lengths: np.ndarray) -> int:
        """Chunks an instance with these row lengths occupies at this tile
        width (the :func:`repro.core.sparse.csr_to_block_ell` count: every
        row gets ``max(1, ceil(len/K))`` chunks, empty rows included)."""
        lengths = np.asarray(row_lengths, dtype=np.int64)
        return int(np.maximum(1, -(-lengths // self.tile_width)).sum())

    def tiles_needed(self, row_lengths: np.ndarray) -> int:
        """Tiles an instance with these row lengths occupies in a slot."""
        return max(1, -(-self.chunks_needed(row_lengths) // self.tile_rows))

    def fits_problem(self, p: Problem) -> bool:
        """Whether one instance fits a slot of this bucket (dimension,
        tile-count and -- on fused buckets -- row-width checks)."""
        if p.m > self.slot_rows or p.n > self.n_pad:
            return False
        lengths = np.diff(p.csr.row_ptr)
        max_row = int(lengths.max()) if lengths.size else 0
        if self.fits_one_chunk and max_row > self.tile_width:
            return False
        return self.tiles_needed(lengths) <= self.slot_tiles

    def admits(self, payload: SlotPayload) -> bool:
        """Whether an already-packed payload can occupy a slot: exact slot
        shape match plus the fused-path row-width contract."""
        if payload.val.shape != (self.slot_tiles, self.tile_rows, self.tile_width):
            return False
        if payload.n_pad != self.n_pad or payload.m > self.slot_rows:
            return False
        return not (self.fits_one_chunk and payload.max_row_nnz > self.tile_width)

    def pack(self, p: Problem, dtype=None) -> SlotPayload:
        """Pack one instance to this bucket's slot shape."""
        return pack_into_slot(
            p, self.slot_tiles, self.slot_rows, self.n_pad,
            tile_rows=self.tile_rows, tile_width=self.tile_width, dtype=dtype,
        )

    @classmethod
    def for_problems(
        cls,
        problems: Sequence[Problem],
        slots: int = 8,
        tile_rows: int = 8,
        tile_width: int | None = None,
        size_classes: int = 1,
    ) -> "list[BucketSpec]":
        """Derive bucket specs from a sample population: one spec per
        ``col_pad(n)`` class, slot capacity = the max over the class, tile
        width chosen (when not pinned) to maximize estimated slot fill --
        the same padding model as ``csr_to_block_ell`` -- so resident
        super-tiles stay dense instead of inheriting the default layout's
        worst-case padding.

        ``size_classes > 1`` additionally splits each ``col_pad`` class
        into that many tile-count quantiles with their own slot shapes.
        Slot capacity is the max over a bucket's population, so one
        outsized instance otherwise pads EVERY slot to its size; with
        quantile sub-buckets a small instance routes to a small slot
        (``fits_problem`` picks the first -- tightest -- fitting spec) and
        the resident super-tiles stay near the population's density."""
        groups: dict[int, list[Problem]] = {}
        for p in problems:
            groups.setdefault(col_pad(p.n), []).append(p)
        specs = []
        for n_pad in sorted(groups):
            ps = groups[n_pad]
            all_lens = [np.diff(p.csr.row_ptr) for p in ps]
            nnz = float(sum(p.nnz for p in ps))
            if tile_width is not None:
                tw = tile_width
            else:
                def padded(tw_):
                    tot = 0
                    for ls in all_lens:
                        chunks = int(np.maximum(1, -(-ls.astype(np.int64) // tw_)).sum())
                        tot += max(1, -(-chunks // tile_rows)) * tile_rows * tw_
                    return tot
                tw = max(_TW_CANDIDATES, key=lambda t: (nnz / padded(t), t))
            probe = cls(
                n_pad=n_pad, slots=slots, slot_tiles=1, slot_rows=1,
                tile_rows=tile_rows, tile_width=tw,
            )
            by_tiles = sorted(ps, key=lambda p: probe.tiles_needed(
                np.diff(p.csr.row_ptr)
            ))
            q = max(1, -(-len(by_tiles) // max(1, size_classes)))
            subs = [by_tiles[i:i + q] for i in range(0, len(by_tiles), q)]
            # Suffix-max slot_rows: classes are split by TILE count, so a
            # small-tiles instance may still carry more rows than its own
            # class max; widening every class to the row max of itself and
            # all larger classes guarantees each sampled instance fits the
            # first spec whose tile capacity admits it.
            row_caps = [max(p.m for p in sub) for sub in subs]
            for i in range(len(row_caps) - 2, -1, -1):
                row_caps[i] = max(row_caps[i], row_caps[i + 1])
            for sub, slot_rows in zip(subs, row_caps):
                lens = [np.diff(p.csr.row_ptr) for p in sub]
                slot_tiles = max(probe.tiles_needed(ls) for ls in lens)
                max_row = max((int(ls.max()) if ls.size else 0) for ls in lens)
                specs.append(cls(
                    n_pad=n_pad, slots=slots, slot_tiles=slot_tiles,
                    slot_rows=slot_rows, tile_rows=tile_rows, tile_width=tw,
                    fits_one_chunk=max_row <= tw,
                ))
        # Tightest spec first, so routing admits each instance to the
        # smallest slot shape that fits it.
        specs.sort(key=lambda s: (s.n_pad, s.slot_tiles, s.slot_rows))
        return specs


def _pow2_decomposition(n: int) -> list[int]:
    """``n`` as descending powers of two (the admission group sizes)."""
    return [1 << b for b in range(n.bit_length() - 1, -1, -1) if (n >> b) & 1]


class ServiceTicket:
    """Future for one submitted instance.

    Carries the packed payload until admission and the
    :class:`repro.core.types.PropagationResult` (host numpy arrays) after
    retirement; ``submit_t``/``admit_t``/``done_t`` are ``perf_counter``
    stamps for the latency percentiles in the bench's ``service`` row.
    """

    __slots__ = (
        "problem", "payload", "submit_t", "admit_t", "done_t",
        "slot", "_result", "_event",
    )

    def __init__(self, problem: Problem | None, payload: SlotPayload):
        self.problem = problem
        self.payload = payload
        self.submit_t = time.perf_counter()
        self.admit_t: float | None = None
        self.done_t: float | None = None
        self.slot: int | None = None
        self._result: PropagationResult | None = None
        self._event = threading.Event()

    def done(self) -> bool:
        """Whether the instance has retired (result available)."""
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> PropagationResult:
        """Block until the instance retires and return its result."""
        if not self._event.wait(timeout):
            raise TimeoutError("instance has not retired yet")
        assert self._result is not None
        return self._result

    def latency(self) -> float | None:
        """Submit-to-retire wall seconds (``None`` until retirement)."""
        if self.done_t is None:
            return None
        return self.done_t - self.submit_t

    def queue_latency(self) -> float | None:
        """Submit-to-admit wall seconds (``None`` until admission) -- how
        long the instance waited for a free slot."""
        if self.admit_t is None:
            return None
        return self.admit_t - self.submit_t

    def service_latency(self) -> float | None:
        """Admit-to-retire wall seconds (``None`` until retirement) -- the
        resident time actually spent propagating."""
        if self.done_t is None or self.admit_t is None:
            return None
        return self.done_t - self.admit_t


class _BucketEngine:
    """The AOT-warmed compiled engines of one bucket shape.

    ``step`` runs up to ``rounds_per_step`` occupancy-masked rounds over the
    resident state (matrix buffers are RUNTIME arguments, so scattering a
    new instance never retraces); ``admits[k]`` scatters ``k`` payloads
    into ``k`` slots in one dispatch (one compiled function per power of
    two bounds compiles at ~log2(slots) per bucket, all warmed up front).

    The step also carries the per-slot *measure of progress* and its
    low-progress streak (state planes 11/12); with ``stop_progress`` set,
    a slot whose progress flatlines for ``patience`` consecutive rounds
    drops out of ``active`` inside the device loop, so the pump's normal
    retire path frees its slot early (``last_changed`` still True marks it
    stopped-not-converged).

    ``telemetry`` (a ring capacity) arms the per-slot device telemetry
    plane (state entries 13-16): every round of every step records the
    slot's progress / early-stop / infeasibility on device, admission
    resets the recycled slot's rows in the same scatter, and the pump
    reads the rows back only at retirement -- where it already syncs for
    the bound plane.  ``telemetry=0`` carries zero-width buffers through
    the identical state layout, so the two modes share one step/admit
    signature and compile count.
    """

    def __init__(
        self,
        spec: BucketSpec,
        dtype,
        cfg: PropagatorConfig,
        rounds_per_step: int,
        use_pallas: bool,
        interpret: bool | None,
        stop_progress: float | None = None,
        patience: int = 1,
        telemetry: int = 0,
    ):
        from ..kernels import ops as kops  # lazy: kernels imports core at module scope
        from ..kernels import prop_round as kern

        self.spec = spec
        self.cfg = cfg
        self.rounds_per_step = rounds_per_step
        self.telemetry = tel_cap = int(telemetry or 0)
        self.np_dtype = np.dtype(dtype)
        self.dev_dtype = jnp.asarray(np.zeros(0, self.np_dtype)).dtype
        self.eps = cfg.eps_for(self.dev_dtype)
        self._lock = threading.RLock()
        self.warmed = False

        s, t, r, k = spec.slots, spec.slot_tiles, spec.tile_rows, spec.tile_width
        n_pad, m_total = spec.n_pad, spec.m_total
        tile_inst = np.repeat(np.arange(s, dtype=np.int32), t)
        pallas_ok = (
            use_pallas and spec.fits_one_chunk
            and n_pad <= kops.SCATTER_MAX_NPAD and n_pad % LANE == 0
        )
        eps, int_eps, inf = self.eps, cfg.int_eps, cfg.inf
        outward = cfg.outward_for(self.dev_dtype)
        max_rounds, budget = cfg.max_rounds, rounds_per_step
        feas_eps = cfg.feas_eps

        def step(val, col, ii, crow, lhs_c, rhs_c,
                 lb, ub, active, last_changed, rounds, progress, flat,
                 ring, ticks, stopr, infsr):
            ti = jnp.asarray(tile_inst)
            if pallas_ok:
                def round_fn(lb_, ub_, act):
                    return kern.batched_occupancy_round_tiles(
                        val, col, ii, lhs_c, rhs_c, lb_, ub_, ti, act,
                        n_pad, eps, int_eps, inf, interpret, outward=outward,
                    )
            else:
                col_g = col + ti[:, None, None] * n_pad
                def round_fn(lb_, ub_, act):
                    return kops.batched_reference_round(
                        val, col_g, ii, crow, lhs_c, rhs_c, lb_, ub_, act,
                        m_total=m_total, n_pad=n_pad,
                        fits_one_chunk=spec.fits_one_chunk,
                        eps=eps, int_eps=int_eps, inf=inf, outward=outward,
                    )
            if tel_cap:
                out = batched_step_rounds(
                    round_fn, lb, ub, active, last_changed, rounds,
                    max_rounds, budget=budget,
                    stop_progress=stop_progress, patience=patience,
                    progress=progress, flat=flat, with_progress=True,
                    plane=TelemetryPlane(ring, ticks, stopr, infsr),
                    feas_eps=feas_eps,
                )
                return out[:7] + tuple(out[7])
            out = batched_step_rounds(
                round_fn, lb, ub, active, last_changed, rounds,
                max_rounds, budget=budget,
                stop_progress=stop_progress, patience=patience,
                progress=progress, flat=flat, with_progress=True,
            )
            # Telemetry off: the zero-width plane rides through unchanged
            # so the state layout (and donation signature) never varies.
            return out + (ring, ticks, stopr, infsr)

        self.step = jax.jit(
            step, **donate_kwargs(argnums=range(_MATRIX_ARGS, _STATE_ARGS))
        )

        srows1 = spec.slot_rows + 1

        def make_admit(kk: int):
            def admit(val, col, ii, crow, lhs_c, rhs_c,
                      lb, ub, active, last_changed, rounds, progress, flat,
                      ring, ticks, stopr, infsr,
                      p_val, p_col, p_ii, p_crow, p_lhs, p_rhs, p_lb, p_ub,
                      slot_ids, on):
                tix = (slot_ids[:, None] * t + jnp.arange(t)[None, :]).reshape(-1)
                val = val.at[tix].set(p_val.reshape(kk * t, r, k))
                col = col.at[tix].set(p_col.reshape(kk * t, r, k))
                ii = ii.at[tix].set(p_ii.reshape(kk * t, r, k))
                crow_g = p_crow + (slot_ids * srows1)[:, None, None]
                crow = crow.at[tix].set(crow_g.reshape(kk * t, r))
                lhs_c = lhs_c.at[tix].set(p_lhs.reshape(kk * t, r))
                rhs_c = rhs_c.at[tix].set(p_rhs.reshape(kk * t, r))
                lb = lb.at[slot_ids].set(p_lb)
                ub = ub.at[slot_ids].set(p_ub)
                active = active.at[slot_ids].set(on)
                last_changed = last_changed.at[slot_ids].set(on)
                rounds = rounds.at[slot_ids].set(0)
                progress = progress.at[slot_ids].set(jnp.nan)
                flat = flat.at[slot_ids].set(0)
                # Slot recycling: the admitted slots' telemetry rows return
                # to the fresh-plane state inside the same fused dispatch.
                plane = reset_rows(
                    TelemetryPlane(ring, ticks, stopr, infsr), slot_ids
                )
                return (val, col, ii, crow, lhs_c, rhs_c,
                        lb, ub, active, last_changed, rounds, progress, flat,
                        *plane)
            return jax.jit(admit, **donate_kwargs(argnums=range(_STATE_ARGS)))

        self.admits = {
            kk: make_admit(kk)
            for kk in (1 << b for b in range(s.bit_length()))
            if kk <= s
        }

    def init_state(self) -> tuple:
        """Fresh all-empty resident state: zero tiles, every chunk parked on
        its slot's dummy row, every slot inactive (== unoccupied)."""
        spec = self.spec
        s, t, r, k = spec.slots, spec.slot_tiles, spec.tile_rows, spec.tile_width
        dt = self.np_dtype
        crow = np.repeat(
            np.arange(s, dtype=np.int32) * (spec.slot_rows + 1) + spec.slot_rows,
            t * r,
        ).reshape(s * t, r)
        return (
            jnp.asarray(np.zeros((s * t, r, k), dt)),
            jnp.asarray(np.zeros((s * t, r, k), np.int32)),
            jnp.asarray(np.zeros((s * t, r, k), np.int32)),
            jnp.asarray(crow),
            jnp.asarray(np.zeros((s * t, r), dt)),
            jnp.asarray(np.zeros((s * t, r), dt)),
            jnp.asarray(np.zeros((s, spec.n_pad), dt)),
            jnp.asarray(np.zeros((s, spec.n_pad), dt)),
            jnp.asarray(np.zeros((s,), bool)),
            jnp.asarray(np.zeros((s,), bool)),
            jnp.asarray(np.zeros((s,), np.int32)),
            jnp.asarray(np.full((s,), np.nan, dt)),
            jnp.asarray(np.zeros((s,), np.int32)),
            jnp.asarray(np.full((s, self.telemetry), np.nan, dt)),
            jnp.asarray(np.zeros((s,), np.int32)),
            jnp.asarray(np.full((s,), -1, np.int32)),
            jnp.asarray(np.full((s,), -1, np.int32)),
        )

    def admit_args(self, payloads: Sequence[SlotPayload], slot_ids, on: bool):
        """Host-side stacking of ``k`` payloads into the admit operands."""
        stacks = tuple(
            np.stack([np.asarray(getattr(p, f), dtype=None) for p in payloads])
            for f in ("val", "col", "ii", "chunk_row", "lhs_c", "rhs_c", "lb", "ub")
        )
        k = len(payloads)
        return stacks + (
            np.asarray(slot_ids, np.int32),
            np.full((k,), on, dtype=bool),
        )

    def warm(self) -> None:
        """Compile every engine up front (idempotent): one step and one
        admission per group size, each against a throwaway empty state --
        after this, admission/backfill/step never hit compile."""
        with self._lock:
            if self.warmed:
                return
            state = self.init_state()
            out = self.step(*state)
            jax.block_until_ready(out)
            for kk, fn in self.admits.items():
                state = self.init_state()
                pay = [evict_slot(
                    self.spec.slot_tiles, self.spec.slot_rows, self.spec.n_pad,
                    self.spec.tile_rows, self.spec.tile_width, self.np_dtype,
                )] * kk
                res = fn(*state, *self.admit_args(pay, list(range(kk)), False))
                jax.block_until_ready(res)
            self.warmed = True

    def compile_counts(self) -> dict:
        """Compiled-trace counts of the step and admit engines (for the
        no-recompile-on-backfill assertion in the tests)."""
        def count(fn):
            get = getattr(fn, "_cache_size", None)
            return int(get()) if callable(get) else None
        return {
            "step": count(self.step),
            "admit": {kk: count(fn) for kk, fn in self.admits.items()},
        }


_engine_cache = None
_engine_cache_lock = threading.Lock()


def _engine_lru():
    """Process-wide engine cache (thread-safe LRU from ``kernels.ops``)."""
    global _engine_cache
    with _engine_cache_lock:
        if _engine_cache is None:
            from ..kernels.ops import LRU  # lazy: kernels imports core
            _engine_cache = LRU(16)
        return _engine_cache


def _get_engine(spec, dtype, cfg, rounds_per_step, use_pallas, interpret,
                stop_progress=None, patience=1, telemetry=0):
    """Fetch-or-build the warmed engine of one bucket shape."""
    key = (
        spec, np.dtype(dtype).str, dataclasses.astuple(cfg),
        rounds_per_step, use_pallas, interpret, stop_progress, patience,
        int(telemetry or 0),
    )
    lru = _engine_lru()
    eng = lru.get(key, ())
    if eng is None:
        eng = _BucketEngine(
            spec, dtype, cfg, rounds_per_step, use_pallas, interpret,
            stop_progress=stop_progress, patience=patience,
            telemetry=int(telemetry or 0),
        )
        lru.put(key, (), eng)
    eng.warm()
    return eng


class _Bucket:
    """Runtime state of one resident bucket: device state tuple, the
    slot->ticket table (the host half of the occupancy mask) and the
    admission queue."""

    def __init__(self, spec: BucketSpec, engine: _BucketEngine):
        self.spec = spec
        self.engine = engine
        self.state = engine.init_state()
        self.slot_tickets: list[ServiceTicket | None] = [None] * spec.slots
        self.queue: deque[ServiceTicket] = deque()
        self.retired = 0
        self.early_stopped = 0
        self.occupancy_sum = 0.0
        self.pumps = 0

    def occupied(self) -> int:
        return sum(t is not None for t in self.slot_tickets)


class PropagationService:
    """Continuous-batching domain-propagation service.

    Construct with bucket specs (or :meth:`from_problems`), then either
    drive it synchronously (``submit`` + ``pump``/``drain``/``serve``) or
    start the background device-loop thread (``start``/``stop``) and treat
    ``submit`` as a fully asynchronous request API.  All compiled engines
    are built and warmed at construction; steady-state operation never
    compiles, repacks a batch, or stops the device loop to retire/admit.

    ``stop_progress``/``patience`` arm the progress-based early retire
    (see :class:`repro.core.types.TierPolicy`): a resident slot whose
    per-round *measure of progress* flatlines below ``stop_progress`` for
    ``patience`` consecutive rounds is deactivated inside the device step
    and retired at the next step boundary with ``converged=False`` and the
    last measure in ``PropagationResult.progress`` -- freeing the slot for
    the backlog instead of grinding out epsilon-level tail rounds.  A
    whole-service fp32 tier is ``dtype=np.float32`` (the engines apply the
    outward-rounded merge automatically); per-slot tier promotion is not a
    service feature -- resubmit promoted instances to an fp64 service.

    Observability: ``telemetry`` (a ring capacity) arms per-slot device
    telemetry -- retired tickets' results carry an
    ``obs.telemetry.TelemetrySnapshot`` read back at the retirement sync
    the pump already performs.  ``tracer`` (an ``obs.trace.Tracer``) emits
    structured spans for every pump/admit/step/readback plus one
    ``ticket`` span per retired instance; the default ``NULL_TRACER``
    no-ops.  ``metrics`` is a :class:`~repro.obs.metrics.MetricsRegistry`
    preloaded with the kernel/engine caches, compile counts and service
    counters; its pinned-schema snapshot rides ``stats()['metrics']``.
    """

    def __init__(
        self,
        specs: Sequence[BucketSpec],
        cfg: PropagatorConfig = DEFAULT_CONFIG,
        dtype=np.float64,
        rounds_per_step: int = 8,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
        stop_progress: float | None = None,
        patience: int = 1,
        telemetry: int | None = None,
        tracer=None,
    ):
        if not specs:
            raise ValueError("PropagationService needs at least one BucketSpec")
        from ..kernels import prop_round as kern  # lazy: kernels imports core
        if use_pallas is None:
            use_pallas = not kern._on_cpu()
        self._cfg = cfg
        self._dtype = np.dtype(dtype)
        self._stop_progress = stop_progress
        self._telemetry = int(telemetry or 0)
        self._tracer = NULL_TRACER if tracer is None else tracer
        self._lock = threading.RLock()
        self._wake = threading.Event()
        self._stop_evt = threading.Event()
        self._thread: threading.Thread | None = None
        self._submitted = 0
        self._buckets = [
            _Bucket(spec, _get_engine(
                spec, dtype, cfg, rounds_per_step, use_pallas, interpret,
                stop_progress=stop_progress, patience=patience,
                telemetry=self._telemetry,
            ))
            for spec in specs
        ]
        self.metrics = default_registry()
        self.metrics.register("engine_cache", lambda: _engine_lru().info())
        self.metrics.register("compile_counts", self.compile_counts)
        self.metrics.register("service", self._counters)

    @classmethod
    def from_problems(
        cls,
        problems: Sequence[Problem],
        slots: int = 8,
        tile_rows: int = 8,
        tile_width: int | None = None,
        size_classes: int = 1,
        **kwargs,
    ) -> "PropagationService":
        """Build a service sized for a sample population (one bucket per
        ``col_pad`` class -- or per tile-count quantile within it when
        ``size_classes > 1`` -- with fill-tuned tile width; see
        :meth:`BucketSpec.for_problems`)."""
        specs = BucketSpec.for_problems(
            problems, slots=slots, tile_rows=tile_rows,
            tile_width=tile_width, size_classes=size_classes,
        )
        return cls(specs, **kwargs)

    # -- request path ------------------------------------------------------

    def submit(
        self, problem: Problem | None = None, payload: SlotPayload | None = None
    ) -> ServiceTicket:
        """Enqueue one instance and return its ticket.

        Routing picks the first bucket that fits; packing to the slot shape
        happens here (host-side, outside the service lock) unless a
        pre-packed ``payload`` is supplied -- the bench pre-packs to keep
        the measured loop device-bound."""
        if payload is None:
            if problem is None:
                raise ValueError("submit() needs a problem or a payload")
            for bk in self._buckets:
                if bk.spec.fits_problem(problem):
                    payload = bk.spec.pack(problem, dtype=self._dtype)
                    break
            else:
                raise ValueError(
                    f"no bucket fits instance m={problem.m} n={problem.n}"
                )
        ticket = ServiceTicket(problem, payload)
        with self._lock:
            for bk in self._buckets:
                if bk.spec.admits(payload):
                    bk.queue.append(ticket)
                    self._submitted += 1
                    break
            else:
                raise ValueError("no bucket admits the given payload")
        self._wake.set()
        return ticket

    # -- device loop -------------------------------------------------------

    def pump(self) -> dict:
        """One service cycle over every bucket: admit into free slots
        (power-of-two grouped scatters), run one budgeted step where any
        slot is occupied, retire newly converged slots (async readback +
        host bookkeeping only -- their tiles are already gated off by the
        occupancy mask).  Returns the cycle's counters.

        With a tracer attached the cycle emits one ``pump`` span with
        nested ``admit``/``step``/``readback`` spans per bucket, plus one
        ``ticket`` span per retirement built from the timestamps the
        ticket already carries (zero tracing work on the submit path)."""
        admitted = retired = stepped = 0
        tr = self._tracer
        with tr.span("pump"), self._lock:
            for bk in self._buckets:
                label = f"n_pad={bk.spec.n_pad}/tw={bk.spec.tile_width}"
                free = [i for i, tk in enumerate(bk.slot_tickets) if tk is None]
                take = min(len(free), len(bk.queue))
                if take:
                    with tr.span("admit", bucket=label, count=take):
                        tickets = [bk.queue.popleft() for _ in range(take)]
                        pos = 0
                        for k in _pow2_decomposition(take):
                            group = tickets[pos:pos + k]
                            slot_ids = free[pos:pos + k]
                            pos += k
                            bk.state = bk.engine.admits[k](
                                *bk.state,
                                *bk.engine.admit_args(
                                    [tk.payload for tk in group], slot_ids, True
                                ),
                            )
                            now = time.perf_counter()
                            for s, tk in zip(slot_ids, group):
                                bk.slot_tickets[s] = tk
                                tk.admit_t = now
                                tk.slot = s
                    admitted += take
                occ = bk.occupied()
                bk.occupancy_sum += occ / bk.spec.slots
                bk.pumps += 1
                if not occ:
                    continue
                with tr.span("step", bucket=label, occupied=occ):
                    bk.state = bk.state[:_MATRIX_ARGS] + tuple(
                        bk.engine.step(*bk.state)
                    )
                stepped += 1
                active_h = np.asarray(bk.state[_ACTIVE])
                done_slots = [
                    i for i, tk in enumerate(bk.slot_tickets)
                    if tk is not None and not active_h[i]
                ]
                if not done_slots:
                    continue
                tel_on = bool(self._telemetry)
                planes = (_LB, _UB, _LAST_CHANGED, _ROUNDS, _PROGRESS)
                if tel_on:
                    planes += (_RING, _TICKS, _STOPR, _INFSR)
                with tr.span("readback", bucket=label, retired=len(done_slots)):
                    for idx in planes:
                        hint = getattr(bk.state[idx], "copy_to_host_async", None)
                        if callable(hint):
                            hint()
                    lb_h = np.asarray(bk.state[_LB])
                    ub_h = np.asarray(bk.state[_UB])
                    lc_h = np.asarray(bk.state[_LAST_CHANGED])
                    rd_h = np.asarray(bk.state[_ROUNDS])
                    pg_h = np.asarray(bk.state[_PROGRESS])
                    if tel_on:
                        ring_h = np.asarray(bk.state[_RING])
                        ticks_h = np.asarray(bk.state[_TICKS])
                        stopr_h = np.asarray(bk.state[_STOPR])
                        infsr_h = np.asarray(bk.state[_INFSR])
                now = time.perf_counter()
                for i in done_slots:
                    tk = bk.slot_tickets[i]
                    n = tk.payload.n
                    lb_i = lb_h[i, :n].copy()
                    ub_i = ub_h[i, :n].copy()
                    # An early-retired (flatlined) slot leaves last_changed
                    # True with rounds below the cap: stopped, not converged.
                    conv = not bool(lc_h[i])
                    if (self._stop_progress is not None and not conv
                            and int(rd_h[i]) < self._cfg.max_rounds):
                        bk.early_stopped += 1
                    tel = None
                    if tel_on:
                        # The slot will be recycled, so copy its rows out of
                        # the shared plane into a scalar-layout host plane.
                        tel = TelemetrySnapshot(plane=TelemetryPlane(
                            ring=ring_h[i].copy(),
                            ticks=ticks_h[i],
                            stop_round=stopr_h[i],
                            infeas_round=infsr_h[i],
                        ))
                    tk._result = PropagationResult(
                        lb=lb_i,
                        ub=ub_i,
                        rounds=int(rd_h[i]),
                        converged=conv,
                        infeasible=bool(
                            np.any(lb_i > ub_i + self._cfg.feas_eps)
                        ),
                        progress=float(pg_h[i]),
                        telemetry=tel,
                    )
                    tk.done_t = now
                    tr.record(
                        "ticket", tk.submit_t, now,
                        bucket=label,
                        slot=i,
                        queue_ms=(tk.admit_t - tk.submit_t) * 1e3,
                        service_ms=(now - tk.admit_t) * 1e3,
                        rounds=int(rd_h[i]),
                        converged=conv,
                    )
                    bk.slot_tickets[i] = None
                    bk.retired += 1
                    tk._event.set()
                retired += len(done_slots)
            pending = sum(len(bk.queue) for bk in self._buckets)
            occupied = sum(bk.occupied() for bk in self._buckets)
        return {
            "admitted": admitted,
            "retired": retired,
            "stepped": stepped,
            "pending": pending,
            "occupied": occupied,
        }

    def drain(self, max_pumps: int | None = None) -> None:
        """Pump until every submitted instance has retired."""
        pumps = 0
        while True:
            res = self.pump()
            pumps += 1
            if res["pending"] == 0 and res["occupied"] == 0:
                return
            if max_pumps is not None and pumps >= max_pumps:
                raise RuntimeError(f"drain did not finish in {max_pumps} pumps")

    def serve(self, problems: Sequence[Problem]) -> list[PropagationResult]:
        """Submit a population and return results in submit order (pumps
        inline unless the background thread is running)."""
        tickets = [self.submit(p) for p in problems]
        if self._thread is not None and self._thread.is_alive():
            return [tk.result() for tk in tickets]
        while not all(tk.done() for tk in tickets):
            self.pump()
        return [tk.result() for tk in tickets]

    # -- background thread -------------------------------------------------

    def start(self) -> None:
        """Start the background device-loop thread (idempotent): pumps
        continuously while work exists, parks on an event when idle."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return
            self._stop_evt.clear()
            self._thread = threading.Thread(
                target=self._loop, name="propagation-service", daemon=True
            )
            self._thread.start()

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            res = self.pump()
            if not (res["admitted"] or res["stepped"]):
                self._wake.wait(timeout=0.002)
                self._wake.clear()

    def stop(self, timeout: float = 10.0) -> None:
        """Stop the background thread (idempotent; queued work stays)."""
        self._stop_evt.set()
        self._wake.set()
        th = self._thread
        if th is not None:
            th.join(timeout)
        self._thread = None

    def __enter__(self) -> "PropagationService":
        """Context manager: run the background loop for the block."""
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- observability -----------------------------------------------------

    @property
    def tracer(self):
        """The attached span tracer (``NULL_TRACER`` when tracing is off)."""
        return self._tracer

    def _counters(self) -> dict:
        """The registry's ``service`` source: the live global counters."""
        with self._lock:
            return {
                "submitted": self._submitted,
                "retired": sum(bk.retired for bk in self._buckets),
                "early_stopped": sum(bk.early_stopped for bk in self._buckets),
                "pending": sum(len(bk.queue) for bk in self._buckets),
                "occupied": sum(bk.occupied() for bk in self._buckets),
                "telemetry_capacity": self._telemetry,
            }

    def stats(self) -> dict:
        """Service stats endpoint: per-bucket occupancy/padding histogram in
        the same shape as ``batch_stats()['per_bucket']`` (computed over the
        RESIDENT instances), queue depths, retire counters, mean occupancy,
        the engine-cache and kernel-cache counters, and the unified
        ``metrics`` registry snapshot (pinned schema -- see
        ``repro.obs.metrics``)."""
        from ..kernels.ops import cache_info  # lazy: kernels imports core
        with self._lock:
            buckets = []
            for bk in self._buckets:
                spec = bk.spec
                resident = [tk for tk in bk.slot_tickets if tk is not None]
                nnz = int(sum(tk.payload.nnz for tk in resident))
                padded = (
                    len(resident) * spec.slot_tiles
                    * spec.tile_rows * spec.tile_width
                )
                fill = nnz / padded if padded else 0.0
                buckets.append({
                    "n_pad": spec.n_pad,
                    "slots": spec.slots,
                    "slot_tiles": spec.slot_tiles,
                    "slot_rows": spec.slot_rows,
                    "tile_rows": spec.tile_rows,
                    "tile_width": spec.tile_width,
                    "occupied": bk.occupied(),
                    "pending": len(bk.queue),
                    "retired": bk.retired,
                    "early_stopped": bk.early_stopped,
                    "mean_occupancy": (
                        bk.occupancy_sum / bk.pumps if bk.pumps else 0.0
                    ),
                    "histogram": {
                        "n_pad": spec.n_pad,
                        "instances": len(resident),
                        "tiles": len(resident) * spec.slot_tiles,
                        "tile_rows": spec.tile_rows,
                        "tile_width": spec.tile_width,
                        "nnz": nnz,
                        "padded_slots": padded,
                        "fill": fill,
                        "padding_fraction": 1.0 - fill if padded else 0.0,
                    },
                })
            return {
                "submitted": self._submitted,
                "retired": sum(bk.retired for bk in self._buckets),
                "early_stopped": sum(bk.early_stopped for bk in self._buckets),
                "pending": sum(len(bk.queue) for bk in self._buckets),
                "occupied": sum(bk.occupied() for bk in self._buckets),
                "buckets": buckets,
                "engine_cache": _engine_lru().info(),
                "kernel_caches": cache_info(),
                "metrics": self.metrics.snapshot(),
            }

    def compile_counts(self) -> dict:
        """Per-bucket compiled-trace counts (steady state: unchanged across
        any number of admissions/backfills -- the AOT warmup covers every
        engine the service can ever dispatch)."""
        return {
            f"n_pad={bk.spec.n_pad}/tw={bk.spec.tile_width}":
                bk.engine.compile_counts()
            for bk in self._buckets
        }
