"""Warm-start node-batch propagation: the tree-search serving shape.

Domain propagation is called at every node of a branch-and-bound search --
millions of times per solve -- and a node differs from its parent by ONE
branching bound.  Repacking the instance per node (the one-shot presolver
dataflow) pays block-ELL conversion, device transfer and compilation for a
two-number change.  This module serves the tree instead:

  * the MATRIX is prepared once per instance (``prepare_block_ell``, keyed
    on structure) and stays device-resident;
  * a :class:`NodeBatch` carries B sibling/frontier nodes as ``(B, n)``
    bound planes -- the only per-node state;
  * :func:`propagate_nodes` runs all B fixed points in ONE dispatch over
    the shared tiles, with the per-instance convergence mask of the batched
    engine reused as a per-node mask (converged nodes become in-kernel
    no-ops) and per-node infeasibility reported for pruning.

``examples/bnb_dive.py`` drives this as a batched diving search;
``benchmarks/bench_prop.py`` reports nodes/sec against per-node repacking.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np

from .sparse import Problem
from .types import (
    DEFAULT_CONFIG,
    INF,
    PropagationResult,
    PropagatorConfig,
    TierPolicy,
)


class NodeBatchResult(NamedTuple):
    """Per-node results of one node-batch propagation (node axis leading)."""

    lb: object          # (B, n) propagated lower bounds
    ub: object          # (B, n) propagated upper bounds
    rounds: object      # (B,) int32 rounds to each node's fixed point
    converged: object   # (B,) bool
    infeasible: object  # (B,) bool: domain emptied -> prune this node
    progress: object = None     # (B,) last-round progress measure (or None)
    tier_rounds: object = 0     # (B,) int32 fp32-tier rounds (two-tier runs)
    telemetry: object = None    # batched obs.TelemetryPlane (or None)
    fp32_telemetry: object = None  # fp32 tier's plane under a TierPolicy

    @property
    def size(self) -> int:
        return int(self.lb.shape[0])

    def node_telemetry(self, i: int):
        """Node ``i``'s ``obs.TelemetrySnapshot`` (None when telemetry off).

        Rows view the shared batched plane lazily -- no readback until a
        snapshot accessor is called.  Under a two-tier run the fp32 tier's
        snapshot hangs off ``.fp32`` and ``tier_switch_round`` is the
        node's fp32 round count (``-1`` if its fp32 tier was distrusted).
        """
        if self.telemetry is None:
            return None
        from ..obs.telemetry import TelemetrySnapshot  # lazy: keep import light

        snap = TelemetrySnapshot(plane=self.telemetry, index=i)
        if self.fp32_telemetry is not None:
            snap.fp32 = TelemetrySnapshot(plane=self.fp32_telemetry, index=i)
            # tier_rounds was zeroed for nodes whose fp32 verdict was
            # distrusted (no promotion happened for them).
            tr = int(np.asarray(self.tier_rounds)[i])
            snap.tier_switch_round = tr if tr > 0 else -1
        return snap

    def result(self, i: int) -> PropagationResult:
        """Node ``i``'s result in single-instance form."""
        return PropagationResult(
            self.lb[i], self.ub[i], self.rounds[i], self.converged[i],
            self.infeasible[i], telemetry=self.node_telemetry(i),
        )

    def results(self) -> "list[PropagationResult]":
        return [self.result(i) for i in range(self.size)]


class NodeBatch(NamedTuple):
    """B nodes of ONE instance: the shared problem + per-node bound planes.

    ``lb``/``ub`` are host ``(B, n)`` arrays (numpy -- node bookkeeping is
    host-side search logic; only propagation runs on device)."""

    problem: Problem
    lb: np.ndarray  # (B, n)
    ub: np.ndarray  # (B, n)

    @property
    def size(self) -> int:
        return int(self.lb.shape[0])

    @classmethod
    def from_root(cls, p: Problem, copies: int = 1) -> "NodeBatch":
        """``copies`` identical nodes at the problem's root bounds."""
        lb = np.repeat(np.asarray(p.lb, np.float64)[None, :], copies, axis=0)
        ub = np.repeat(np.asarray(p.ub, np.float64)[None, :], copies, axis=0)
        return cls(problem=p, lb=lb, ub=ub)

    @classmethod
    def from_nodes(cls, p: Problem, nodes: Sequence[tuple]) -> "NodeBatch":
        """Stack ``(lb_i, ub_i)`` pairs into one batch."""
        lb = np.stack([np.asarray(l, np.float64) for l, _ in nodes])
        ub = np.stack([np.asarray(u, np.float64) for _, u in nodes])
        return cls(problem=p, lb=lb, ub=ub)

    def select(self, mask) -> "NodeBatch":
        """Keep the nodes where ``mask`` is True (pruning survivors)."""
        mask = np.asarray(mask)
        return NodeBatch(self.problem, self.lb[mask], self.ub[mask])


def branch_children(lb, ub, var: int, value: float) -> "tuple[tuple, tuple]":
    """The two children of branching ``x[var]`` at ``value``: the *down*
    child gets ``ub[var] = floor(value)``, the *up* child ``lb[var] =
    floor(value) + 1`` (the standard integer dichotomy; for a binary
    variable at value 0 this is the x=0 / x=1 split).  Returns
    ``((lb_down, ub_down), (lb_up, ub_up))`` as fresh host arrays."""
    lb = np.asarray(lb, np.float64)
    ub = np.asarray(ub, np.float64)
    f = float(np.floor(value))
    down_lb, down_ub = lb.copy(), ub.copy()
    down_ub[var] = min(down_ub[var], f)
    up_lb, up_ub = lb.copy(), ub.copy()
    up_lb[var] = max(up_lb[var], f + 1.0)
    return (down_lb, down_ub), (up_lb, up_ub)


def pick_most_fractional(lb, ub, is_int) -> "int | None":
    """Deterministic host-side branching rule: the unfixed integer variable
    whose domain midpoint is most fractional, ties to the lowest index --
    the host-numpy twin of the solver's on-device
    ``kernels.ref.most_fractional_ref``.  Replaces the RNG-per-level pick
    the diving example used, so level-by-level Python drivers (the bench
    ``solver`` row's baseline) are reproducible run-to-run.  Returns the
    column index, or ``None`` when every integer variable is fixed."""
    lb = np.asarray(lb, np.float64)
    ub = np.asarray(ub, np.float64)
    cand = np.asarray(is_int, bool) & (ub - lb > 0.5)
    if not cand.any():
        return None
    mid = 0.5 * (lb + ub)
    frac = mid - np.floor(mid)
    score = np.where(cand, 0.5 - np.abs(frac - 0.5), -1.0)
    return int(np.argmax(score))


def propagate_nodes(
    p: Problem,
    lb_nodes,
    ub_nodes,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    interpret: bool | None = None,
    donate: bool | None = None,
    slab: int | None = None,
    stop_progress: float | None = None,
    patience: int = 1,
    policy: TierPolicy | None = None,
    telemetry: int | None = None,
) -> NodeBatchResult:
    """Propagate B warm-started nodes of ONE instance in one dispatch.

    ``lb_nodes``/``ub_nodes`` are ``(B, n)`` per-node bound planes (or a
    :class:`NodeBatch`'s fields).  The instance's block-ELL tiles, hoisted
    gathers and the compiled fixed point are cached per matrix structure
    (``kernels.cache_info()`` reports hits), so successive frontiers of
    the same search pay only the two ``(B, n)`` uploads and one dispatch.
    VMEM-exceeding instances (``n_pad > SCATTER_MAX_NPAD``) ride the
    column-slab partitioned node kernels automatically.  Per-node
    ``rounds``/``converged`` match what each node would see in its own
    single-instance run; ``infeasible`` nodes are reported for pruning,
    and their bucket mates are unaffected.

    ``stop_progress``/``patience`` arm the per-node progress-based early
    stop (see ``bounds.progress_measure``); ``policy`` (a
    :class:`TierPolicy`) runs the frontier through the two-tier precision
    scheme: an fp32 dispatch (outward-rounded merges, own cached prep +
    runner) until per-node progress drops below ``policy.switch_progress``,
    then an exact-cast warm start of the requested-dtype engine.

    ``telemetry`` (a ring capacity) carries a per-node device telemetry
    plane through the dispatch; read node trajectories via
    ``result.node_telemetry(i)`` / ``result.result(i).telemetry``."""
    from ..kernels.ops import (  # lazy: kernels imports core at module scope
        prepare_block_ell,
        propagate_nodes_prepared,
    )
    from .propagator import two_tier_bounds_dtypes

    tel_cap = int(telemetry or 0)
    pair = two_tier_bounds_dtypes(policy, dtype) if policy is not None else None
    if pair is not None:
        dt32, final = pair
        cap32 = max(1, int(cfg.max_rounds * policy.fp32_round_frac))
        prep32 = prepare_block_ell(p, tile_rows, tile_width, dt32)
        out32 = propagate_nodes_prepared(
            prep32, lb_nodes, ub_nodes,
            dataclasses.replace(cfg, max_rounds=cap32),
            use_pallas=use_pallas, interpret=interpret, donate=donate,
            slab=slab, stop_progress=policy.switch_progress,
            patience=policy.patience, telemetry=tel_cap,
        )
        lb32, ub32, r32, _, inf32 = out32[:5]
        plane32 = out32[5] if tel_cap else None
        # Per-node promotion; a node whose fp32 tier declared infeasibility
        # restarts from its ORIGINAL bounds (fp32 verdicts are never
        # trusted -- see core.propagator's two-tier front end).
        bad = np.asarray(inf32)[:, None]
        warm_lb = np.where(bad, np.asarray(lb_nodes), np.asarray(lb32, np.float64))
        warm_ub = np.where(bad, np.asarray(ub_nodes), np.asarray(ub32, np.float64))
        # Canonicalize the cast sentinels (fp32's 1e20 rounds up; see
        # bounds.canonical_infinite) so untouched infinite bounds promote
        # bitwise.
        warm_lb = np.where(warm_lb <= -INF, -INF, warm_lb)
        warm_ub = np.where(warm_ub >= INF, INF, warm_ub)
        r32 = np.where(np.asarray(inf32), 0, np.asarray(r32)).astype(np.int32)
        rem = dataclasses.replace(cfg, max_rounds=max(1, cfg.max_rounds - cap32))
        prep = prepare_block_ell(p, tile_rows, tile_width, final)
        out = propagate_nodes_prepared(
            prep, warm_lb, warm_ub, rem,
            use_pallas=use_pallas, interpret=interpret, donate=donate,
            slab=slab, stop_progress=policy.stop_progress,
            patience=policy.patience, with_progress=True, telemetry=tel_cap,
        )
        lb, ub, rounds, converged, infeasible, progress = out[:6]
        return NodeBatchResult(
            lb, ub, rounds + r32, converged, infeasible,
            progress=progress, tier_rounds=r32,
            telemetry=out[6] if tel_cap else None, fp32_telemetry=plane32,
        )
    if policy is not None:
        stop_progress = policy.stop_progress
        patience = policy.patience
    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    out = propagate_nodes_prepared(
        prep, lb_nodes, ub_nodes, cfg,
        use_pallas=use_pallas, interpret=interpret, donate=donate, slab=slab,
        stop_progress=stop_progress, patience=patience, with_progress=True,
        telemetry=tel_cap,
    )
    lb, ub, rounds, converged, infeasible, progress = out[:6]
    return NodeBatchResult(
        lb, ub, rounds, converged, infeasible, progress=progress,
        telemetry=out[6] if tel_cap else None,
    )


def propagate_node_batch(
    batch: NodeBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    **kwargs,
) -> NodeBatchResult:
    """:func:`propagate_nodes` over a :class:`NodeBatch`."""
    return propagate_nodes(batch.problem, batch.lb, batch.ub, cfg, **kwargs)
