"""Warm-start node-batch propagation: the tree-search serving shape.

Domain propagation is called at every node of a branch-and-bound search --
millions of times per solve -- and a node differs from its parent by ONE
branching bound.  Repacking the instance per node (the one-shot presolver
dataflow) pays block-ELL conversion, device transfer and compilation for a
two-number change.  This module serves the tree instead:

  * the MATRIX is prepared once per instance (``prepare_block_ell``, keyed
    on structure) and stays device-resident;
  * a :class:`NodeBatch` carries B sibling/frontier nodes as ``(B, n)``
    bound planes -- the only per-node state;
  * :func:`propagate_nodes` runs all B fixed points in ONE dispatch over
    the shared tiles, with the per-instance convergence mask of the batched
    engine reused as a per-node mask (converged nodes become in-kernel
    no-ops) and per-node infeasibility reported for pruning.

``examples/bnb_dive.py`` drives this as a batched diving search;
``benchmarks/bench_prop.py`` reports nodes/sec against per-node repacking.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

from .sparse import Problem
from .types import DEFAULT_CONFIG, PropagationResult, PropagatorConfig


class NodeBatchResult(NamedTuple):
    """Per-node results of one node-batch propagation (node axis leading)."""

    lb: object          # (B, n) propagated lower bounds
    ub: object          # (B, n) propagated upper bounds
    rounds: object      # (B,) int32 rounds to each node's fixed point
    converged: object   # (B,) bool
    infeasible: object  # (B,) bool: domain emptied -> prune this node

    @property
    def size(self) -> int:
        return int(self.lb.shape[0])

    def result(self, i: int) -> PropagationResult:
        """Node ``i``'s result in single-instance form."""
        return PropagationResult(
            self.lb[i], self.ub[i], self.rounds[i], self.converged[i],
            self.infeasible[i],
        )

    def results(self) -> "list[PropagationResult]":
        return [self.result(i) for i in range(self.size)]


class NodeBatch(NamedTuple):
    """B nodes of ONE instance: the shared problem + per-node bound planes.

    ``lb``/``ub`` are host ``(B, n)`` arrays (numpy -- node bookkeeping is
    host-side search logic; only propagation runs on device)."""

    problem: Problem
    lb: np.ndarray  # (B, n)
    ub: np.ndarray  # (B, n)

    @property
    def size(self) -> int:
        return int(self.lb.shape[0])

    @classmethod
    def from_root(cls, p: Problem, copies: int = 1) -> "NodeBatch":
        """``copies`` identical nodes at the problem's root bounds."""
        lb = np.repeat(np.asarray(p.lb, np.float64)[None, :], copies, axis=0)
        ub = np.repeat(np.asarray(p.ub, np.float64)[None, :], copies, axis=0)
        return cls(problem=p, lb=lb, ub=ub)

    @classmethod
    def from_nodes(cls, p: Problem, nodes: Sequence[tuple]) -> "NodeBatch":
        """Stack ``(lb_i, ub_i)`` pairs into one batch."""
        lb = np.stack([np.asarray(l, np.float64) for l, _ in nodes])
        ub = np.stack([np.asarray(u, np.float64) for _, u in nodes])
        return cls(problem=p, lb=lb, ub=ub)

    def select(self, mask) -> "NodeBatch":
        """Keep the nodes where ``mask`` is True (pruning survivors)."""
        mask = np.asarray(mask)
        return NodeBatch(self.problem, self.lb[mask], self.ub[mask])


def branch_children(lb, ub, var: int, value: float) -> "tuple[tuple, tuple]":
    """The two children of branching ``x[var]`` at ``value``: the *down*
    child gets ``ub[var] = floor(value)``, the *up* child ``lb[var] =
    floor(value) + 1`` (the standard integer dichotomy; for a binary
    variable at value 0 this is the x=0 / x=1 split).  Returns
    ``((lb_down, ub_down), (lb_up, ub_up))`` as fresh host arrays."""
    lb = np.asarray(lb, np.float64)
    ub = np.asarray(ub, np.float64)
    f = float(np.floor(value))
    down_lb, down_ub = lb.copy(), ub.copy()
    down_ub[var] = min(down_ub[var], f)
    up_lb, up_ub = lb.copy(), ub.copy()
    up_lb[var] = max(up_lb[var], f + 1.0)
    return (down_lb, down_ub), (up_lb, up_ub)


def propagate_nodes(
    p: Problem,
    lb_nodes,
    ub_nodes,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    tile_rows: int = 8,
    tile_width: int = 128,
    dtype=None,
    use_pallas: bool = True,
    interpret: bool | None = None,
    donate: bool | None = None,
    slab: int | None = None,
) -> NodeBatchResult:
    """Propagate B warm-started nodes of ONE instance in one dispatch.

    ``lb_nodes``/``ub_nodes`` are ``(B, n)`` per-node bound planes (or a
    :class:`NodeBatch`'s fields).  The instance's block-ELL tiles, hoisted
    gathers and the compiled fixed point are cached per matrix structure
    (``kernels.cache_info()`` reports hits), so successive frontiers of
    the same search pay only the two ``(B, n)`` uploads and one dispatch.
    VMEM-exceeding instances (``n_pad > SCATTER_MAX_NPAD``) ride the
    column-slab partitioned node kernels automatically.  Per-node
    ``rounds``/``converged`` match what each node would see in its own
    single-instance run; ``infeasible`` nodes are reported for pruning,
    and their bucket mates are unaffected."""
    from ..kernels.ops import (  # lazy: kernels imports core at module scope
        prepare_block_ell,
        propagate_nodes_prepared,
    )

    prep = prepare_block_ell(p, tile_rows, tile_width, dtype)
    lb, ub, rounds, converged, infeasible = propagate_nodes_prepared(
        prep, lb_nodes, ub_nodes, cfg,
        use_pallas=use_pallas, interpret=interpret, donate=donate, slab=slab,
    )
    return NodeBatchResult(lb, ub, rounds, converged, infeasible)


def propagate_node_batch(
    batch: NodeBatch,
    cfg: PropagatorConfig = DEFAULT_CONFIG,
    **kwargs,
) -> NodeBatchResult:
    """:func:`propagate_nodes` over a :class:`NodeBatch`."""
    return propagate_nodes(batch.problem, batch.lb, batch.ub, cfg, **kwargs)
