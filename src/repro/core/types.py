"""Shared value types and numeric conventions for domain propagation.

Conventions (SCIP / PaPILO style, see paper §3.4):
  * Infinite bounds are encoded with the finite sentinel ``INF = 1e20``.
    Any value ``|v| >= INF`` is treated as infinite.  All arithmetic therefore
    stays finite (no NaNs from ``0 * inf``), and "counting infinite
    contributions" is a plain comparison against the sentinel.
  * A *bound change* only counts if it improves the bound by more than a
    scale-aware epsilon -- this is the tolerance-based termination the paper
    uses to guarantee finite convergence (§1.1).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# SCIP-style infinity sentinel.  Values beyond this magnitude are "infinite".
INF = 1e20


def _is_low_precision(dtype) -> bool:
    return jnp.dtype(dtype) in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16))


def int_round_slack(dtype) -> float:
    """Scale-aware integrality-rounding slack of a tier dtype.

    ``ceil``/``floor`` amplify arithmetic error discontinuously: an fp32
    candidate a few ulps above ``k - int_eps`` rounds to ``k`` where the
    exact candidate rounds to ``k - 1`` -- an O(1) overtightening no merge-
    side widening can undo.  Low-precision rounding therefore subtracts
    (adds) ``slack * max(1, |candidate|)`` before the ceil (floor), treating
    anything within the tier's accumulated-error margin of an integer as
    that integer.  Same magnitude as the merge widening
    (``PropagatorConfig.outward_eps_f32``); 0.0 for fp64 (exact rounding,
    bitwise-identical to the pre-tier engines)."""
    dt = jnp.dtype(dtype)
    if dt == jnp.dtype(jnp.float32):
        return 2.0**-17
    if dt == jnp.dtype(jnp.bfloat16):
        return 2.0**-6
    return 0.0


@dataclasses.dataclass(frozen=True)
class PropagatorConfig:
    """Numeric + termination knobs shared by all propagator implementations."""

    max_rounds: int = 100          # paper §4.1: round cap
    tighten_eps: float = 1e-9      # scale-aware minimum improvement (fp64)
    tighten_eps_f32: float = 1e-5  # minimum improvement when running in fp32
    int_eps: float = 1e-6          # integrality rounding tolerance
    feas_eps: float = 1e-8         # empty-domain detection: l > u + feas_eps
    inf: float = INF
    # fp32-tier outward rounding: every accepted tightening is widened back
    # toward the old bound by ``outward_eps_f32 * max(1, |bound|)`` in the
    # merge, so accumulated fp32 arithmetic error can never push a bound
    # INSIDE the fp64 fixed point (no false infeasibility, promotion-safe).
    # Must stay < tighten_eps_f32 so accepted updates still make strict
    # progress and the fp32 fixed point terminates.
    outward_eps_f32: float = 2.0**-17

    def eps_for(self, dtype) -> float:
        if _is_low_precision(dtype):
            return self.tighten_eps_f32
        return self.tighten_eps

    def outward_for(self, dtype) -> float:
        """Outward-rounding width for a tier dtype (0.0 = exact merge).

        fp64 merges stay exact (bitwise-compatible with every pre-tier
        engine and oracle); low-precision tiers widen accepted tightenings
        by this relative amount."""
        return self.outward_eps_f32 if _is_low_precision(dtype) else 0.0


DEFAULT_CONFIG = PropagatorConfig()


@dataclasses.dataclass(frozen=True)
class TierPolicy:
    """Runtime policy for two-tier adaptive precision + progress control.

    The *measure of progress* (Sofranac et al., arXiv:2106.07573, adapted
    to sentinel-infinite bounds -- see ``bounds.progress_measure``) is a
    per-round device scalar: the scale-normalized total bound movement of
    the round.  Two decisions hang off it:

      * **tier switch** (``two_tier``): rounds run in fp32 (half the
        bytes/round of the fused dataflow, double the effective slab
        width) while per-round progress stays >= ``switch_progress``;
        once it drops below for ``patience`` consecutive rounds the
        bounds are promoted (exact fp32->fp64 cast -- they are outward-
        rounded, so never inside the fp64 fixed point) and the fp64
        engine finishes the endgame.
      * **early stop** (``stop_progress``): a fixed point whose progress
        stays below this for ``patience`` rounds is declared flatlined
        and stopped even though epsilon-level changes continue; the
        service pump retires such slots early to keep occupancy high.
        ``None`` disables the early stop (iterate to exact convergence).
    """

    two_tier: bool = True          # run an fp32 tier before the fp64 endgame
    switch_progress: float = 1e-3  # fp32 tier: promote below this progress
    stop_progress: float | None = None  # early stop threshold (None = off)
    patience: int = 2              # consecutive low-progress rounds to act
    fp32_round_frac: float = 0.5   # fp32 tier's share of the round cap


DEFAULT_TIER_POLICY = TierPolicy()


class Bounds(NamedTuple):
    """Variable domains ``lb <= x <= ub`` (sentinel-infinite)."""

    lb: jnp.ndarray  # (n,)
    ub: jnp.ndarray  # (n,)


class Activities(NamedTuple):
    """Per-row activity aggregates with infinity counters (paper §3.4).

    ``min_act = -inf`` iff ``min_inf_count > 0`` else ``min_finite``;
    symmetric for the maximum activity (whose infinite contributions are
    all ``+inf``).
    """

    min_finite: jnp.ndarray     # (m,) finite part of the minimum activity
    min_inf_count: jnp.ndarray  # (m,) int32 number of -inf contributions
    max_finite: jnp.ndarray     # (m,) finite part of the maximum activity
    max_inf_count: jnp.ndarray  # (m,) int32 number of +inf contributions


class PropagationResult(NamedTuple):
    """Outcome of one propagation fixed point (any engine, any driver).

    ``lb``/``ub`` are the tightened ``(n,)`` bound vectors (device arrays,
    sentinel-infinite); the scalars are device arrays too so batched
    drivers can return them without host syncs.  ``infeasible`` means some
    variable's domain emptied (``lb > ub + feas_eps``) -- in tree search,
    prune the node."""

    lb: jnp.ndarray            # (n,) tightened lower bounds
    ub: jnp.ndarray            # (n,) tightened upper bounds
    rounds: jnp.ndarray        # () int32: propagation rounds executed
    converged: jnp.ndarray     # () bool: fixed point reached within cap
    infeasible: jnp.ndarray    # () bool: some variable domain became empty
    progress: jnp.ndarray = jnp.nan    # () last round's progress measure
    tier_rounds: jnp.ndarray = 0       # () int32: rounds run in the fp32 tier
    # obs.telemetry.TelemetrySnapshot when the driver was called with a
    # telemetry capacity (lazy: holds device arrays, no sync on attach).
    telemetry: object | None = None


def is_pos_inf(v, inf: float = INF):
    return v >= inf


def is_neg_inf(v, inf: float = INF):
    return v <= -inf


def is_inf(v, inf: float = INF):
    return jnp.abs(v) >= inf if isinstance(v, jnp.ndarray) else abs(v) >= inf


def clamp_to_sentinel(v, inf: float = INF):
    """Clamp values into the representable range [-INF, INF]."""
    return jnp.clip(v, -inf, inf)


def np_is_inf(v: np.ndarray, inf: float = INF) -> np.ndarray:
    return np.abs(v) >= inf
