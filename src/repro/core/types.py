"""Shared value types and numeric conventions for domain propagation.

Conventions (SCIP / PaPILO style, see paper §3.4):
  * Infinite bounds are encoded with the finite sentinel ``INF = 1e20``.
    Any value ``|v| >= INF`` is treated as infinite.  All arithmetic therefore
    stays finite (no NaNs from ``0 * inf``), and "counting infinite
    contributions" is a plain comparison against the sentinel.
  * A *bound change* only counts if it improves the bound by more than a
    scale-aware epsilon -- this is the tolerance-based termination the paper
    uses to guarantee finite convergence (§1.1).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# SCIP-style infinity sentinel.  Values beyond this magnitude are "infinite".
INF = 1e20


@dataclasses.dataclass(frozen=True)
class PropagatorConfig:
    """Numeric + termination knobs shared by all propagator implementations."""

    max_rounds: int = 100          # paper §4.1: round cap
    tighten_eps: float = 1e-9      # scale-aware minimum improvement (fp64)
    tighten_eps_f32: float = 1e-5  # minimum improvement when running in fp32
    int_eps: float = 1e-6          # integrality rounding tolerance
    feas_eps: float = 1e-8         # empty-domain detection: l > u + feas_eps
    inf: float = INF

    def eps_for(self, dtype) -> float:
        if jnp.dtype(dtype) in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)):
            return self.tighten_eps_f32
        return self.tighten_eps


DEFAULT_CONFIG = PropagatorConfig()


class Bounds(NamedTuple):
    """Variable domains ``lb <= x <= ub`` (sentinel-infinite)."""

    lb: jnp.ndarray  # (n,)
    ub: jnp.ndarray  # (n,)


class Activities(NamedTuple):
    """Per-row activity aggregates with infinity counters (paper §3.4).

    ``min_act = -inf`` iff ``min_inf_count > 0`` else ``min_finite``;
    symmetric for the maximum activity (whose infinite contributions are
    all ``+inf``).
    """

    min_finite: jnp.ndarray     # (m,) finite part of the minimum activity
    min_inf_count: jnp.ndarray  # (m,) int32 number of -inf contributions
    max_finite: jnp.ndarray     # (m,) finite part of the maximum activity
    max_inf_count: jnp.ndarray  # (m,) int32 number of +inf contributions


class PropagationResult(NamedTuple):
    """Outcome of one propagation fixed point (any engine, any driver).

    ``lb``/``ub`` are the tightened ``(n,)`` bound vectors (device arrays,
    sentinel-infinite); the scalars are device arrays too so batched
    drivers can return them without host syncs.  ``infeasible`` means some
    variable's domain emptied (``lb > ub + feas_eps``) -- in tree search,
    prune the node."""

    lb: jnp.ndarray            # (n,) tightened lower bounds
    ub: jnp.ndarray            # (n,) tightened upper bounds
    rounds: jnp.ndarray        # () int32: propagation rounds executed
    converged: jnp.ndarray     # () bool: fixed point reached within cap
    infeasible: jnp.ndarray    # () bool: some variable domain became empty


def is_pos_inf(v, inf: float = INF):
    return v >= inf


def is_neg_inf(v, inf: float = INF):
    return v <= -inf


def is_inf(v, inf: float = INF):
    return jnp.abs(v) >= inf if isinstance(v, jnp.ndarray) else abs(v) >= inf


def clamp_to_sentinel(v, inf: float = INF):
    """Clamp values into the representable range [-INF, INF]."""
    return jnp.clip(v, -inf, inf)


def np_is_inf(v: np.ndarray, inf: float = INF) -> np.ndarray:
    return np.abs(v) >= inf
