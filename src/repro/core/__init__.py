"""Core library: the paper's contribution (GPU-parallel domain propagation)
as a composable JAX module, plus the sequential baseline and the distributed
(shard_map) variant.  See DESIGN.md for the TPU adaptation of the CUDA
mechanisms (CSR-adaptive -> block-ELL, atomics -> segment/all-reduce min-max).
"""
from .types import (
    INF,
    Activities,
    Bounds,
    PropagationResult,
    PropagatorConfig,
    DEFAULT_CONFIG,
)
from .sparse import (
    CSR,
    CSC,
    LANE,
    BlockEll,
    BatchedBlockEll,
    Problem,
    ProblemBatch,
    col_pad,
    pack_problems,
    batch_stats,
    csr_from_dense,
    csr_from_coo,
    csr_to_csc,
    csr_to_block_ell,
    block_ell_stats,
    permute_problem,
)
from .activities import compute_activities, activity_values
from .propagator import (
    DeviceProblem,
    propagate,
    propagate_batch,
    batched_fixed_point,
    propagate_host_loop,
    propagate_device_loop,
    propagate_unrolled,
    propagation_round,
    bounds_equal,
)
from .seq_ref import propagate_sequential, SeqResult
from .presolve import analyze_constraints, PresolveVerdict
from .sharded import (
    propagate_sharded,
    propagate_sharded_rows,
    propagate_batch_sharded,
    lower_sharded,
    partition_nnz,
    partition_rows,
)

__all__ = [
    "INF",
    "Activities",
    "Bounds",
    "PropagationResult",
    "PropagatorConfig",
    "DEFAULT_CONFIG",
    "CSR",
    "CSC",
    "LANE",
    "BlockEll",
    "BatchedBlockEll",
    "Problem",
    "ProblemBatch",
    "col_pad",
    "pack_problems",
    "batch_stats",
    "csr_from_dense",
    "csr_from_coo",
    "csr_to_csc",
    "csr_to_block_ell",
    "block_ell_stats",
    "permute_problem",
    "compute_activities",
    "activity_values",
    "DeviceProblem",
    "propagate",
    "propagate_batch",
    "batched_fixed_point",
    "propagate_host_loop",
    "propagate_device_loop",
    "propagate_unrolled",
    "propagation_round",
    "bounds_equal",
    "propagate_sequential",
    "SeqResult",
    "analyze_constraints",
    "PresolveVerdict",
    "propagate_sharded",
    "propagate_sharded_rows",
    "propagate_batch_sharded",
    "partition_rows",
    "lower_sharded",
    "partition_nnz",
]
