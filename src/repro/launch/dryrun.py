import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run (task §MULTI-POD DRY-RUN).

For every (architecture x input shape) cell and both production meshes
(single-pod (16,16), multi-pod (2,16,16)):

  1. FULL lowering (scan-over-layers) -> .lower().compile() must succeed;
     ``memory_analysis()`` proves the per-device footprint fits 16 GB HBM.
  2. PROBE lowerings (unrolled layers + inner loops, two depths) ->
     ``cost_analysis()`` + HLO collective parsing, linearly extrapolated to
     full depth -> the three roofline terms (roofline/analysis.py).

Results are appended to a JSON file consumed by EXPERIMENTS.md tooling.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k \
      --mesh single --out results/dryrun.json [--probes/--no-probes]
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from ..configs import ARCH_IDS, get_config
from ..models.config import SHAPES, cell_supported, input_specs
from ..models.transformer import (
    active_param_count,
    init_params,
    param_count,
)
from ..roofline.analysis import (
    RooflineTerms,
    collective_bytes,
    extrapolate,
    model_flops,
)
from ..train.optimizer import OptimizerConfig, init_opt_state
from ..train.serve_step import make_serve_step
from ..train.train_step import make_train_step
from .mesh import make_production_mesh
from .sharding import batch_shardings, opt_state_shardings, param_shardings

HBM_PER_CHIP = 16 * 2**30  # v5e


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


_MB_OVERRIDE = {
    # Heavy cells need full grad accumulation (per-mb local batch == 1).
    "deepseek-v2-236b": 1_000_000,
}


def _opt_cfg_for(cfg) -> OptimizerConfig:
    """236B-scale training cannot hold fp32 Adam state in 16GB-HBM chips at
    256-chip scale; use bf16 m/v + bf16 grad accumulation there."""
    if cfg.name == "deepseek-v2-236b":
        return OptimizerConfig(state_dtype="bfloat16")
    return OptimizerConfig()


def _train_microbatches(cfg, shape, mesh) -> int:
    """Default grad-accum factor so per-device activations fit HBM."""
    dp = 1
    for a, size in zip(mesh.axis_names, mesh.devices.shape):
        if a != "model":
            dp *= size
    local_batch = max(1, shape.global_batch // dp)
    return min(_MB_OVERRIDE.get(cfg.name, 8), local_batch)


def lower_cell(cfg, shape, mesh, microbatches: int = 1):
    """Build + lower + compile one cell. Returns (lowered, compiled, specs)."""
    specs = input_specs(cfg, shape)
    p_sh = param_shardings(cfg, mesh)
    params_sds = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))

    if shape.kind == "train":
        opt_cfg = _opt_cfg_for(cfg)
        step = make_train_step(cfg, opt_cfg, mesh, microbatches=microbatches)
        opt_sds = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_sds)
        o_sh = opt_state_shardings(cfg, mesh, opt_cfg)
        b_sh = batch_shardings(cfg, mesh, specs)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),  # params/opt alias -> no double residency
        )
        lowered = jitted.lower(params_sds, opt_sds, specs)
    elif shape.kind == "prefill":
        from ..train.serve_step import make_prefill_fn

        fn = make_prefill_fn(cfg, mesh)
        b_sh = batch_shardings(cfg, mesh, specs)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh["tokens"],
                                           b_sh.get("frontend_embeds")))
        args = [params_sds, specs["tokens"]]
        if "frontend_embeds" in specs:
            args.append(specs["frontend_embeds"])
        else:
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh["tokens"]))
        lowered = jitted.lower(*args)
    else:  # decode
        fn = make_serve_step(cfg, mesh)
        b_sh = batch_shardings(cfg, mesh, specs)
        jitted = jax.jit(
            fn,
            in_shardings=(p_sh, b_sh["tokens"], b_sh["cache"], b_sh["pos"]),
            donate_argnums=(2,),  # KV cache updated in place
        )
        lowered = jitted.lower(
            params_sds, specs["tokens"], specs["cache"], specs["pos"]
        )
    compiled = lowered.compile()
    return lowered, compiled


# ---------------------------------------------------------------------------
# Probe configs (unrolled lowerings at two depths)
# ---------------------------------------------------------------------------


def probe_depths(cfg):
    """(n1, n2, n_full, unit) for linear extrapolation over segment units."""
    if cfg.layer_pattern:  # recurrentgemma: unit = one period, same tail
        period = len(cfg.layer_pattern)
        n_full, tail = divmod(cfg.num_layers, period)
        return (1 * period + tail, 2 * period + tail, None, n_full)
    if cfg.first_k_dense:  # deepseek: unit = one MoE layer
        return (cfg.first_k_dense + 1, cfg.first_k_dense + 2, None,
                cfg.num_layers - cfg.first_k_dense)
    return (1, 2, None, cfg.num_layers)


def probe_cfg(cfg, n_layers: int, shape):
    return dataclasses.replace(
        cfg,
        num_layers=n_layers,
        scan_layers=False,
        unroll_inner=True,
        attn_chunk=max(512, shape.seq_len // 2),
        remat=False,
    )


def probe_cell(cfg, shape, mesh):
    """Unrolled probe lowerings -> extrapolated per-device costs.

    FLOPs come from the HLO dot parser (XLA:CPU cost_analysis inflates flops
    ~16x by modeling elementwise ops on attention score tensors); HBM bytes
    from the fusion-aware traffic estimator.  Raw cost_analysis numbers are
    recorded alongside for reference.
    """
    from ..roofline.hlo_flops import dot_flops_by_op, hbm_traffic_estimate

    n1, n2, _, n_units = probe_depths(cfg)
    res = []
    for n in (n1, n2):
        pcfg = probe_cfg(cfg, n, shape)
        lowered, compiled = lower_cell(pcfg, shape, mesh, microbatches=1)
        txt = compiled.as_text()
        ca = compiled.cost_analysis()
        coll = collective_bytes(txt)
        dot_flops, _ = dot_flops_by_op(txt)
        res.append(
            {
                "flops": dot_flops,
                "bytes": hbm_traffic_estimate(txt),
                "raw_ca_flops": float(ca.get("flops", 0.0)),
                "raw_ca_bytes": float(ca.get("bytes accessed", 0.0)),
                "coll": coll["total"],
                "coll_by_op": coll,
            }
        )
    # probe1 covers 1 unit (plus fixed base); probe2 covers 2 units.
    flops = extrapolate(res[0]["flops"], res[1]["flops"], 1, 2, n_units)
    bytes_hbm = extrapolate(res[0]["bytes"], res[1]["bytes"], 1, 2, n_units)
    coll = extrapolate(res[0]["coll"], res[1]["coll"], 1, 2, n_units)
    by_op = {
        k: extrapolate(res[0]["coll_by_op"][k], res[1]["coll_by_op"][k], 1, 2, n_units)
        for k in res[0]["coll_by_op"]
    }
    raw = {
        "raw_ca_flops": extrapolate(
            res[0]["raw_ca_flops"], res[1]["raw_ca_flops"], 1, 2, n_units
        ),
        "raw_ca_bytes": extrapolate(
            res[0]["raw_ca_bytes"], res[1]["raw_ca_bytes"], 1, 2, n_units
        ),
    }
    terms = RooflineTerms(flops=flops, bytes_hbm=bytes_hbm, bytes_coll=coll).finalize()
    return terms, by_op, raw


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, probes: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": shape.kind,
        "supported": ok,
    }
    if not ok:
        rec["skip_reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    mb = _train_microbatches(cfg, shape, mesh) if shape.kind == "train" else 1
    t0 = time.time()
    lowered, compiled = lower_cell(cfg, shape, mesh, microbatches=mb)
    ma = compiled.memory_analysis()
    rec.update(
        {
            "microbatches": mb,
            "compile_s": round(time.time() - t0, 1),
            "arg_bytes": int(ma.argument_size_in_bytes),
            "out_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0)),
            "fits_hbm": bool(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes < HBM_PER_CHIP
            ),
        }
    )
    # Scanned-module cost numbers (loop bodies counted once -- recorded for
    # comparison against the probe-extrapolated numbers).
    ca = compiled.cost_analysis()
    rec["scanned_flops_per_device"] = float(ca.get("flops", 0.0))

    if probes:
        t1 = time.time()
        terms, by_op, raw = probe_cell(cfg, shape, mesh)
        rec["probe_s"] = round(time.time() - t1, 1)
        rec.update(terms.as_dict())
        rec.update(raw)
        rec["collective_by_op"] = {k: float(v) for k, v in by_op.items()}
        # Useful-compute ratio.
        n_active = active_param_count(cfg)
        tokens = shape.global_batch * (
            shape.seq_len if shape.kind != "decode" else 1
        )
        chips = 1
        for s in mesh.devices.shape:
            chips *= s
        mf = model_flops(n_active, tokens, shape.kind)
        rec["model_flops_total"] = mf
        rec["hlo_flops_total"] = terms.flops * chips
        rec["useful_compute_ratio"] = (
            mf / rec["hlo_flops_total"] if rec["hlo_flops_total"] else 0.0
        )
        rec["roofline_fraction"] = (
            rec["t_compute_s"] / rec["t_bound_s"] if rec["t_bound_s"] else 0.0
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--no-probes", dest="probes", action="store_false")
    ap.add_argument("--include-prop", action="store_true",
                    help="also dry-run the paper's sharded propagation workload")
    args = ap.parse_args()

    cells = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                for mk in meshes:
                    cells.append((arch, shape_name, mk))
    else:
        assert args.arch and args.shape
        for mk in meshes:
            cells.append((args.arch, args.shape, mk))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch, shape_name, mk in cells:
        if (arch, shape_name, mk) in done:
            print(f"[skip done] {arch} x {shape_name} x {mk}")
            continue
        print(f"[dryrun] {arch} x {shape_name} x {mk}", flush=True)
        try:
            rec = run_cell(arch, shape_name, mk, probes=args.probes)
        except Exception as e:  # a failing cell is a bug -- record loudly
            rec = {
                "arch": arch,
                "shape": shape_name,
                "mesh": mk,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
            print(f"  FAILED: {e}")
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if "error" not in rec and rec.get("supported", True):
            print(
                f"  ok compile={rec.get('compile_s')}s mb={rec.get('microbatches')}"
                f" peak={rec.get('temp_bytes', 0)/2**30:.2f}GiB"
                f" bottleneck={rec.get('bottleneck', '-')}"
            )

    if args.include_prop:
        run_propagation_dryrun(results, args.out, meshes)


def run_propagation_dryrun(results, out, meshes):
    """Dry-run the paper's distributed propagation on the production meshes."""
    from ..core.sharded import lower_sharded
    from ..core.sparse import Problem, csr_from_coo
    import numpy as np

    # Synthetic production-scale instance: 16M nnz, 1M rows, 500k cols.
    m, n, nnz = 1_000_000, 500_000, 16_000_000
    rng = np.random.default_rng(0)
    rows = np.sort(rng.integers(0, m, nnz)).astype(np.int32)
    cols = rng.integers(0, n, nnz).astype(np.int32)
    vals = rng.standard_normal(nnz)
    csr = csr_from_coo(rows, cols, vals, m, n)
    p = Problem(
        csr=csr,
        lhs=np.full(m, -1e20),
        rhs=rng.uniform(1, 10, m),
        lb=np.zeros(n),
        ub=np.full(n, 10.0),
        is_int=np.zeros(n, dtype=bool),
    )
    for mk in meshes:
        key = ("propagation-16Mnnz", "fixed_point", mk)
        if any((r["arch"], r["shape"], r["mesh"]) == key for r in results):
            continue
        mesh = make_production_mesh(multi_pod=(mk == "multi"))
        t0 = time.time()
        lowered = lower_sharded(p, mesh)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        coll = collective_bytes(compiled.as_text())
        rec = {
            "arch": "propagation-16Mnnz",
            "shape": "fixed_point",
            "mesh": mk,
            "kind": "propagation",
            "supported": True,
            "compile_s": round(time.time() - t0, 1),
            "arg_bytes": int(ma.argument_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "fits_hbm": bool(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes < HBM_PER_CHIP
            ),
            "collective_by_op_per_round": {k: float(v) for k, v in coll.items()},
            "note": "collectives are per ROUND (fixed point is a while loop)",
        }
        results.append(rec)
        with open(out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] propagation x {mk}: compile={rec['compile_s']}s")


if __name__ == "__main__":
    main()
