"""Production mesh definitions (DESIGN.md §4).

Single-pod: (16, 16)  = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") -- "pod"
is an outer data axis; gradient all-reduce is hierarchical (ICI within a
pod, DCI across pods).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for subprocess-based multi-device tests."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """All non-"model" axes -- the batch / pure-data-parallel dimensions."""
    return tuple(a for a in mesh.axis_names if a != "model")
