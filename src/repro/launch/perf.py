import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """§Perf hillclimb driver: re-lower selected cells with optimization
levers toggled and report the three roofline terms per variant, plus the
full-lowering memory footprint.  Appends to results/perf.json.

  python -m repro.launch.perf --cell granite-3-2b:train_4k --mesh single
  python -m repro.launch.perf --prop             # propagation variants
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.config import SHAPES
from ..roofline.analysis import collective_bytes
from .dryrun import HBM_PER_CHIP, lower_cell, probe_cell, _train_microbatches
from .mesh import make_production_mesh

LEVERS = {
    "baseline": {},
    "+causal_skip": {"causal_skip": True},
    "+seq_shard": {"seq_shard": True},
    "+both": {"causal_skip": True, "seq_shard": True},
}


def run_lm_cell(arch: str, shape_name: str, mesh_kind: str, levers=None):
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    records = []
    for name, overrides in (levers or LEVERS).items():
        cfg = dataclasses.replace(get_config(arch), **overrides)
        mb = _train_microbatches(cfg, shape, mesh) if shape.kind == "train" else 1
        t0 = time.time()
        rec = {"cell": f"{arch}:{shape_name}:{mesh_kind}", "variant": name}
        try:
            lowered, compiled = lower_cell(cfg, shape, mesh, microbatches=mb)
            ma = compiled.memory_analysis()
            rec.update(
                arg_gib=round(ma.argument_size_in_bytes / 2**30, 2),
                temp_gib=round(ma.temp_size_in_bytes / 2**30, 2),
                fits_hbm=bool(
                    ma.argument_size_in_bytes + ma.temp_size_in_bytes < HBM_PER_CHIP
                ),
            )
            terms, by_op, raw = probe_cell(cfg, shape, mesh)
            rec.update(terms.as_dict())
            rec["roofline_fraction"] = (
                terms.t_compute / terms.t_bound if terms.t_bound else 0.0
            )
            rec["wall_s"] = round(time.time() - t0, 1)
        except Exception as e:  # noqa: BLE001
            rec["error"] = f"{type(e).__name__}: {e}"
        records.append(rec)
        print(json.dumps(rec), flush=True)
    return records


def run_propagation_variants(mesh_kind: str = "single", nnz=4_000_000,
                             m=250_000, n=125_000):
    """Static per-round collective bytes: nnz-partition (paper-faithful
    distribution) vs row-partition (beyond-paper)."""
    import numpy as np

    from ..core.sharded import (
        _row_sharded_round,
        _sharded_round,
        partition_nnz,
        partition_rows,
    )
    from ..core.sparse import Problem, csr_from_coo
    from ..core.types import DEFAULT_CONFIG as cfg
    from jax import shard_map
    from jax.sharding import PartitionSpec as P
    import functools

    rng = np.random.default_rng(0)
    rows_idx = np.sort(rng.integers(0, m, nnz)).astype(np.int32)
    cols = rng.integers(0, n, nnz).astype(np.int32)
    vals = rng.standard_normal(nnz).astype(np.float32)
    csr = csr_from_coo(rows_idx, cols, vals, m, n)
    p = Problem(
        csr=csr, lhs=np.full(m, -1e20, np.float32),
        rhs=rng.uniform(1, 10, m).astype(np.float32),
        lb=np.zeros(n, np.float32), ub=np.full(n, 10.0, np.float32),
        is_int=np.zeros(n, dtype=bool),
    )
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    axes = tuple(mesh.axis_names)
    shards = 1
    for s in mesh.devices.shape:
        shards *= s
    eps = cfg.eps_for(jnp.float32)
    out = []

    # Variant A: nnz partition (baseline).
    row_id, col, val = partition_nnz(p, shards)
    rfn = functools.partial(
        _sharded_round, m=m, n=n, eps=eps, int_eps=cfg.int_eps, inf=cfg.inf,
        axes=axes,
    )

    def bodyA(row_id, col, val, lhs, rhs, is_int, lb, ub):
        lb, ub, ch = rfn(row_id, col, val, lhs, rhs, is_int, lb, ub)
        return lb, ub, ch

    nnz_spec = P(axes)
    rep = P()
    fnA = shard_map(
        bodyA, mesh=mesh,
        in_specs=(nnz_spec,) * 3 + (rep,) * 5,
        out_specs=(rep, rep, rep), check_vma=False,
    )
    lowA = jax.jit(fnA).lower(
        jnp.asarray(row_id), jnp.asarray(col), jnp.asarray(val),
        jnp.asarray(p.lhs), jnp.asarray(p.rhs), jnp.asarray(p.is_int),
        jnp.asarray(p.lb), jnp.asarray(p.ub),
    )
    collA = collective_bytes(lowA.compile().as_text())
    out.append({"variant": "nnz-partition (baseline)", "mesh": mesh_kind,
                "per_round_collective_bytes": collA})
    print(json.dumps(out[-1]), flush=True)

    # Variant B: row partition (beyond-paper).
    val2, col2, lrow2, lhs2, rhs2, rows = partition_rows(p, shards)
    rfnB = functools.partial(
        _row_sharded_round, rows=rows, n=n, eps=eps, int_eps=cfg.int_eps,
        inf=cfg.inf, axes=axes,
    )

    def bodyB(lrow, col, val, lhs, rhs, is_int, lb, ub):
        lb, ub, ch = rfnB(lrow[0], col[0], val[0], lhs[0], rhs[0], is_int, lb, ub)
        return lb, ub, ch

    shard_spec = P(axes, None)
    fnB = shard_map(
        bodyB, mesh=mesh,
        in_specs=(shard_spec,) * 5 + (rep,) * 3,
        out_specs=(rep, rep, rep), check_vma=False,
    )
    lowB = jax.jit(fnB).lower(
        jnp.asarray(lrow2), jnp.asarray(col2), jnp.asarray(val2),
        jnp.asarray(lhs2), jnp.asarray(rhs2), jnp.asarray(p.is_int),
        jnp.asarray(p.lb), jnp.asarray(p.ub),
    )
    collB = collective_bytes(lowB.compile().as_text())
    out.append({"variant": "row-partition (beyond-paper)", "mesh": mesh_kind,
                "per_round_collective_bytes": collB})
    print(json.dumps(out[-1]), flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, help="arch:shape")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default=None, help="run only this lever")
    ap.add_argument("--prop", action="store_true")
    ap.add_argument("--out", default="results/perf.json")
    args = ap.parse_args()

    records = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            records = json.load(f)
    if args.prop:
        records += run_propagation_variants(args.mesh)
    if args.cell:
        arch, shape = args.cell.split(":")
        levers = {args.variant: LEVERS[args.variant]} if args.variant else None
        records += run_lm_cell(arch, shape, args.mesh, levers)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)


if __name__ == "__main__":
    main()
