"""End-to-end training driver with checkpoint/restart (fault tolerance).

Example (CPU container, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

The driver auto-resumes from the newest checkpoint: kill it at any step and
rerun the same command -- it continues where it left off (the data pipeline
is stateless-deterministic, so the token stream realigns exactly).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data.tokens import DataConfig, make_batch
from ..models.transformer import init_params
from ..train.checkpoint import AsyncCheckpointer, restore_checkpoint
from ..train.optimizer import OptimizerConfig, init_opt_state
from ..train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = OptimizerConfig(
        lr_peak=args.lr, warmup_steps=max(10, args.steps // 10),
        total_steps=args.steps,
    )
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
    )

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(params, opt_cfg)
    start_step = 0

    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        (params, opt_state), start_step = restore_checkpoint(
            args.ckpt_dir, (params, opt_state)
        )
        if start_step:
            print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, mesh=None,
                                      microbatches=args.microbatches))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(data_cfg, step).items()}
        if cfg.frontend != "none":
            nf = cfg.n_frontend_tokens
            key = jax.random.fold_in(jax.random.PRNGKey(args.seed), step)
            batch["frontend_embeds"] = (
                jax.random.normal(key, (args.batch, nf, cfg.d_model)) * 0.02
            )
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            rate = (step + 1 - start_step) * args.batch * args.seq / (
                time.time() - t0
            )
            print(
                f"step {step+1:5d} loss {losses[-1]:.4f} "
                f"ce {float(metrics['ce']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} tok/s {rate:,.0f}",
                flush=True,
            )
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state))
    if ckpt:
        ckpt.save(args.steps, (params, opt_state))
        ckpt.wait()
    first, last = losses[0], np.mean(losses[-5:])
    print(f"[done] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    return losses


if __name__ == "__main__":
    main()
