"""Sharding rules: parameter PartitionSpecs (FSDP over "data" x TP over
"model"), activation constraints, and cache specs (DESIGN.md §4).

Parameters are 2D-sharded: the contraction-side dimension over "data"
(ZeRO-3-style -- XLA SPMD all-gathers on use) and the parallel dimension
over "model" (megatron-style TP).  Optimizer state inherits parameter
sharding, so the full optimizer is sharded over all 256/512 chips.
"""
from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..models.transformer import init_params


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# Parameter names whose trailing dims follow (d_in -> "data", d_out -> "model")
_IN_OUT = ("wq/w", "wk/w", "wv/w", "w_gate", "w_up", "in_proj", "w_in",
           "w_gate_branch")
# (d_in -> "model", d_out -> "data"): output projections
_OUT_IN = ("wo/w", "w_down", "out_proj", "w_out")


def spec_for_param(pathstr: str, ndim: int) -> P:
    """PartitionSpec for one parameter leaf (trailing dims; stacked layer
    dims are padded with None on the left)."""

    def pad(*trailing):
        lead = (None,) * (ndim - len(trailing))
        return P(*(lead + trailing))

    # Replicated small params: norms, gates, biases, scalars.
    for frag in ("ln1", "ln2", "final_norm", "q_norm", "kv_norm", "gate_norm",
                 "a_log", "dt_bias", "d_skip", "lam", "b_x", "b_a", "conv_b"):
        if frag in pathstr:
            return P(*((None,) * ndim))

    if pathstr.endswith("embed/table"):
        # NOTE: vocab-only sharding; 2D-sharding the table trips XLA SPMD
        # "involuntary full rematerialization" on the gather (pod mesh).
        return P("model", None)
    if pathstr.endswith("lm_head/w"):
        return P("data", "model")
    if pathstr.endswith("/wo"):  # MLA out projection (bare array)
        return pad("model", "data")
    if "router" in pathstr:
        return pad("data", None)
    if "conv_w" in pathstr:
        return pad(None, "model")
    if pathstr.endswith("w_x") or pathstr.endswith("w_a"):
        return pad("model", None)
    if "wq_a" in pathstr or "wkv_a" in pathstr:
        # Lora-rank outputs replicated over "model": each TP rank redundantly
        # computes the tiny latent (0.3% of layer FLOPs) instead of
        # all-gathering (B,S,rank) activations every layer (§Perf iter 2).
        return pad("data", None)
    if "wq_b" in pathstr or "wkv_b" in pathstr:
        return pad("data", "model")

    for frag in _OUT_IN:
        if frag in pathstr:
            if ndim >= 3 and ("moe" in pathstr and "shared" not in pathstr):
                return pad("model", None, "data")   # (E, F, D) experts
            return pad("model", "data")
    for frag in _IN_OUT:
        if frag in pathstr:
            if ndim >= 3 and ("moe" in pathstr and "shared" not in pathstr):
                return pad("model", "data", None)   # (E, D, F) experts
            return pad("data", "model")
    if pathstr.endswith("/b"):  # qkv biases: follow the output dim
        return pad("model")
    # Fallback: replicate.
    return P(*((None,) * ndim))


def _widen_data_axis(spec: P, mesh: Mesh) -> P:
    """On the multi-pod mesh, FSDP-shard params over ("pod","data") jointly
    (ZeRO across pods: halves state residency, pays DCI all-gathers)."""
    if "pod" not in mesh.axis_names:
        return spec
    return P(*(("pod", "data") if ax == "data" else ax for ax in spec))


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    """NamedSharding pytree matching init_params(cfg, key)."""
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))

    def spec(path, leaf):
        ps = spec_for_param(_path_str(path), len(leaf.shape))
        return NamedSharding(mesh, _widen_data_axis(ps, mesh))

    return jax.tree_util.tree_map_with_path(spec, shapes)


def param_spec_tree(cfg: ModelConfig):
    """PartitionSpec pytree (mesh-independent; for shard_map / tests)."""
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    return jax.tree_util.tree_map_with_path(
        lambda p, l: spec_for_param(_path_str(p), len(l.shape)), shapes
    )


def opt_state_shardings(cfg: ModelConfig, mesh: Mesh, opt_cfg=None):
    """OptState shardings: m/v/ef mirror the params; step is replicated."""
    from ..train.optimizer import OptState, OptimizerConfig, init_opt_state

    opt_cfg = opt_cfg or OptimizerConfig()
    p_sh = param_shardings(cfg, mesh)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), shapes)
    rep = NamedSharding(mesh, P())
    if opt_cfg.grad_compress:
        ef_sh = p_sh
    else:
        ef_sh = jax.tree.map(lambda s: rep, opt_sds.ef)
    return OptState(m=p_sh, v=p_sh, step=rep, ef=ef_sh)


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------


class Sharder:
    """Activation sharding-constraint helper; no-ops without a mesh.

    ``seq_shard=True`` = Megatron sequence parallelism: the residual stream
    between blocks is sharded (B over data, S over "model"), turning the
    per-layer TP all-reduces into reduce-scatter/all-gather pairs (half the
    bytes) and dividing the per-layer saved activations by |model| (§Perf).
    """

    def __init__(self, mesh: Mesh | None = None, seq_shard: bool = False):
        self.mesh = mesh
        self.seq_shard = seq_shard
        if mesh is None:
            self.dp: tuple = ()
            self.model_size = 1
        else:
            self.dp = tuple(a for a in mesh.axis_names if a != "model")
            self.model_size = dict(
                zip(mesh.axis_names, mesh.devices.shape)
            ).get("model", 1)

    def _ws(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def hidden(self, x):  # (B, S, D)
        if self.seq_shard and x.shape[1] % self.model_size == 0 and x.shape[1] > 1:
            return self._ws(x, P(self.dp, "model", None))
        return self._ws(x, P(self.dp, None, None))

    def kv(self, x):  # (B, Hkv, S, D): hoist the SP KV all-gather out of the
        # attention chunk loop (one gather per layer, not per tile pair).
        if self.seq_shard:
            return self._ws(x, P(self.dp, None, None, None))
        return x

    def logits(self, x):  # (B, S, V): vocab TP-sharded
        return self._ws(x, P(self.dp, None, "model"))

    def batch_spec(self, ndim: int) -> P:
        return P(*((self.dp,) + (None,) * (ndim - 1)))


def batch_shardings(cfg: ModelConfig, mesh: Mesh, specs: dict):
    """NamedShardings for an input_specs() dict (batch over data axes)."""
    dp = tuple(a for a in mesh.axis_names if a != "model")

    def shard_leaf(path, leaf):
        ps = _path_str(path)
        nd = len(leaf.shape)
        if "cache" in ps:
            return NamedSharding(mesh, cache_spec(cfg, ps, leaf.shape, dp))
        if nd == 0:
            return NamedSharding(mesh, P())
        if leaf.shape[0] == 1:  # batch==1 (long_500k): nothing to shard
            return NamedSharding(mesh, P(*((None,) * nd)))
        return NamedSharding(mesh, P(*((dp,) + (None,) * (nd - 1))))

    return jax.tree_util.tree_map_with_path(shard_leaf, specs)


def cache_spec(cfg: ModelConfig, pathstr: str, shape, dp) -> P:
    """Decode-cache sharding.

    GQA K/V (L,B,H,S,D): batch over data; when kv-heads >= |model| shard
    heads over model, otherwise shard the *sequence* dim over model (long
    caches; keeps per-chip KV bounded).  MLA latent (L,B,S,r): sequence over
    model.  SSM/RG-LRU states: channels/heads over model.
    """
    nd = len(shape)
    b = shape[1] if nd >= 2 else 1
    bspec = dp if b > 1 else None
    last = pathstr.rsplit("/", 1)[-1]
    if last in ("k", "v") and nd == 5:
        n_kv, seq = shape[2], shape[3]
        if n_kv % 16 == 0:
            return P(None, bspec, "model", None, None)
        if seq % 16 == 0:  # few/odd KV heads: shard the sequence dim
            return P(None, bspec, None, "model", None)
        return P(None, bspec, None, None, None)
    if last == "c" and nd == 4:   # MLA latent
        return P(None, bspec, "model", None)
    if last == "kr" and nd == 4:
        return P(None, bspec, "model", None)
    if last == "h" and nd == 5:   # mamba2 state (L,B,H,P,N)
        return P(None, bspec, "model", None, None)
    if last == "h" and nd == 3:   # rg-lru state (L,B,dr)
        return P(None, bspec, "model")
    if last == "conv" and nd == 4:
        return P(None, bspec, None, "model")
    return P(*((None,) * nd))
