"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

  i_t = sigmoid(W_x x_t + b_x)                 (input gate)
  r_t = sigmoid(W_a x_t + b_a)                 (recurrence gate)
  log a_t = -c * softplus(Lambda) * r_t        (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t x_t)

Prefill/train uses ``jax.lax.associative_scan`` over (a, b) pairs (O(log S)
depth); decode is the single recurrence step.  The surrounding block is the
Griffin recurrent block: linear -> temporal conv (k=4) -> RG-LRU, gated by a
GeLU branch, then an output projection.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import normal_init


class RGLRUConfig(NamedTuple):
    d_model: int
    d_rnn: int
    d_conv: int = 4
    c: float = 8.0


def rglru_block_init(key, cfg: RGLRUConfig, dtype=jnp.float32):
    keys = jax.random.split(key, 6)
    d, dr = cfg.d_model, cfg.d_rnn
    return {
        "w_in": normal_init(keys[0], (d, dr), d**-0.5, dtype),
        "w_gate_branch": normal_init(keys[1], (d, dr), d**-0.5, dtype),
        "conv_w": normal_init(keys[2], (cfg.d_conv, dr), 0.5, dtype),
        "conv_b": jnp.zeros((dr,), dtype=dtype),
        "w_x": normal_init(keys[3], (dr, dr), dr**-0.5, dtype),
        "b_x": jnp.zeros((dr,), dtype=jnp.float32),
        "w_a": normal_init(keys[4], (dr, dr), dr**-0.5, dtype),
        "b_a": jnp.zeros((dr,), dtype=jnp.float32),
        "lam": jnp.full((dr,), 0.65, dtype=jnp.float32),  # softplus^-1-ish init
        "w_out": normal_init(keys[5], (dr, d), dr**-0.5, dtype),
    }


def _gates(params, u, cfg: RGLRUConfig):
    """Per-step recurrence coefficients (a_t, b_t) in fp32. u: (..., d_rnn)."""
    uf = u.astype(jnp.float32)
    i_t = jax.nn.sigmoid(uf @ params["w_x"].astype(jnp.float32) + params["b_x"])
    r_t = jax.nn.sigmoid(uf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    log_a = -cfg.c * jax.nn.softplus(params["lam"]) * r_t
    a_t = jnp.exp(log_a)
    b_t = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 1e-12)) * (i_t * uf)
    return a_t, b_t


def _conv(params, u, conv_state=None):
    k = params["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros(u.shape[:1] + (k - 1,) + u.shape[2:], u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(
        up[:, i : i + u.shape[1], :] * params["conv_w"][i].astype(u.dtype)
        for i in range(k)
    )
    return out + params["conv_b"].astype(u.dtype), up[:, -(k - 1) :, :]


def rglru_block_forward(params, x, cfg: RGLRUConfig, state=None):
    """Full-sequence forward. state=(h0 (B, d_rnn) fp32, conv_state)."""
    h0, conv_state = state if state is not None else (None, None)
    u = x @ params["w_in"].astype(x.dtype)
    u, conv_state_new = _conv(params, u, conv_state)
    a_t, b_t = _gates(params, u, cfg)  # (B, S, dr) fp32

    if h0 is not None:
        # Fold the incoming state into the first step: b_0 += a_0 * h0.
        b_t = b_t.at[:, 0, :].add(a_t[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a_t, b_t), axis=1)
    h_last = h[:, -1, :]

    gate = jax.nn.gelu(x @ params["w_gate_branch"].astype(x.dtype))
    y = (h.astype(x.dtype) * gate) @ params["w_out"].astype(x.dtype)
    return y, (h_last, conv_state_new)


def rglru_block_decode(params, x, cfg: RGLRUConfig, state):
    """Single-token step. state = (h (B, dr) fp32, conv_state (B, K-1, dr))."""
    h, conv_state = state
    u = x @ params["w_in"].astype(x.dtype)
    u, conv_state = _conv(params, u, conv_state)
    a_t, b_t = _gates(params, u, cfg)           # (B, 1, dr)
    h = a_t[:, 0] * h + b_t[:, 0]
    gate = jax.nn.gelu(x @ params["w_gate_branch"].astype(x.dtype))
    y = (h[:, None, :].astype(x.dtype) * gate) @ params["w_out"].astype(x.dtype)
    return y, (h, conv_state)
