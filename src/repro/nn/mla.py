"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Train/prefill expand the KV latent to per-head keys/values and run the
blockwise attention; decode uses the *absorbed* formulation so the cache is
only the latent ``c_kv`` (kv_lora_rank) plus the shared rotary key
(qk_rope_dim) per position -- the MLA memory win:

  score = q_nope^T k_nope + q_rope^T k_rope
        = (q_nope W_uk^T)^T c   + q_rope^T k_rope            (absorb W_uk)
  out_h = (sum_t p_t c_t) W_uv[h]                            (absorb W_uv)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .attention import blockwise_attention
from .layers import normal_init, rmsnorm, rmsnorm_init
from .rope import apply_rope


class MLAConfig(NamedTuple):
    d_model: int
    n_heads: int
    q_lora_rank: int       # 0 => full-rank q projection
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_dim: int


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32):
    keys = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {}
    if cfg.q_lora_rank > 0:
        p["wq_a"] = normal_init(keys[0], (d, cfg.q_lora_rank), d**-0.5, dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
        p["wq_b"] = normal_init(
            keys[1], (cfg.q_lora_rank, h * qk), cfg.q_lora_rank**-0.5, dtype
        )
    else:
        p["wq"] = normal_init(keys[0], (d, h * qk), d**-0.5, dtype)
    p["wkv_a"] = normal_init(
        keys[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), d**-0.5, dtype
    )
    p["kv_norm"] = rmsnorm_init(cfg.kv_lora_rank, dtype)
    p["wkv_b"] = normal_init(
        keys[3],
        (cfg.kv_lora_rank, h * (cfg.qk_nope_dim + cfg.v_dim)),
        cfg.kv_lora_rank**-0.5,
        dtype,
    )
    p["wo"] = normal_init(keys[4], (h * cfg.v_dim, d), (h * cfg.v_dim) ** -0.5, dtype)
    return p


def _queries(params, x, cfg: MLAConfig, cos, sin):
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank > 0:
        qa = x @ params["wq_a"].astype(x.dtype)
        qa = rmsnorm(params["q_norm"], qa)
        q = qa @ params["wq_b"].astype(x.dtype)
    else:
        q = x @ params["wq"].astype(x.dtype)
    q = q.reshape(b, s, h, qk).transpose(0, 2, 1, 3)  # (B, H, S, qk)
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim :], cos, sin)
    return q_nope, q_rope


def _latent(params, x, cfg: MLAConfig, cos, sin):
    """Compressed KV: (c_latent (B,S,r), k_rope (B,1,S,rope)) -- rope applied."""
    kv = x @ params["wkv_a"].astype(x.dtype)
    c = rmsnorm(params["kv_norm"], kv[..., : cfg.kv_lora_rank])
    k_rope = kv[..., cfg.kv_lora_rank :][:, None]  # (B, 1, S, rope), shared head
    k_rope = apply_rope(k_rope, cos, sin)
    return c, k_rope


def mla_attention(params, x, cfg: MLAConfig, cos, sin, chunk: int = 512,
                  unroll: bool = False, causal_skip: bool = False):
    """Full-sequence MLA (train / prefill). Returns (y, cache=(c, k_rope))."""
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _queries(params, x, cfg, cos, sin)
    c, k_rope = _latent(params, x, cfg, cos, sin)

    kvb = (c @ params["wkv_b"].astype(x.dtype)).reshape(
        b, s, h, cfg.qk_nope_dim + cfg.v_dim
    )
    k_nope = kvb[..., : cfg.qk_nope_dim].transpose(0, 2, 1, 3)   # (B,H,S,nope)
    v = kvb[..., cfg.qk_nope_dim :].transpose(0, 2, 1, 3)        # (B,H,S,v)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, h, s, cfg.qk_rope_dim))], axis=-1
    )
    y = blockwise_attention(
        q, k, v, causal=True, chunk_q=chunk, chunk_k=chunk, unroll=unroll,
        causal_skip=causal_skip,
    )
    y = y.transpose(0, 2, 1, 3).reshape(b, s, h * cfg.v_dim)
    return y @ params["wo"].astype(x.dtype), (c, k_rope[:, 0])


def mla_decode(params, x, cfg: MLAConfig, cos, sin, cache, pos):
    """Absorbed single-token decode.

    cache: (c_cache (B, S_max, r), kr_cache (B, S_max, rope)) with entries
    valid for positions <= pos-1; this step writes position ``pos``.
    """
    b, one, d = x.shape
    assert one == 1
    h = cfg.n_heads
    r = cfg.kv_lora_rank
    neg = -1e30

    q_nope, q_rope = _queries(params, x, cfg, cos, sin)  # (B,H,1,*)
    c_new, kr_new = _latent(params, x, cfg, cos, sin)    # (B,1,r), (B,1,1,rope)

    c_cache, kr_cache = cache
    c_cache = jax.lax.dynamic_update_slice_in_dim(c_cache, c_new, pos, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(kr_cache, kr_new[:, 0], pos, axis=1)

    wkv_b = params["wkv_b"].astype(x.dtype).reshape(r, h, cfg.qk_nope_dim + cfg.v_dim)
    w_uk = wkv_b[..., : cfg.qk_nope_dim]   # (r, H, nope)
    w_uv = wkv_b[..., cfg.qk_nope_dim :]   # (r, H, v)

    # Absorb W_uk into the query: q_abs (B, H, r).
    q_abs = jnp.einsum("bhon,rhn->bhor", q_nope, w_uk)[:, :, 0]
    s_nope = jnp.einsum("bhr,bsr->bhs", q_abs, c_cache)
    s_rope = jnp.einsum("bhoe,bse->bhs", q_rope, kr_cache)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    s = (s_nope + s_rope).astype(jnp.float32) * scale
    valid = jnp.arange(c_cache.shape[1]) <= pos
    s = s + jnp.where(valid, 0.0, neg)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)

    ctx = jnp.einsum("bhs,bsr->bhr", p, c_cache)           # latent context
    y = jnp.einsum("bhr,rhv->bhv", ctx, w_uv).reshape(b, 1, h * cfg.v_dim)
    return y @ params["wo"].astype(x.dtype), (c_cache, kr_cache)
