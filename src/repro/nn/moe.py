"""Mixture-of-Experts: top-k softmax router + capacity-based dispatch with
SwiGLU experts and optional shared experts (DeepSeekMoE / Qwen3-MoE style).

Dispatch is scatter-based (no dense one-hot matmuls), so compiled FLOPs match
the *active* expert FLOPs -- important for honest roofline numbers.  Expert
weights carry a leading E dim that is expert-parallel (sharded over "model").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import normal_init


class MoEConfig(NamedTuple):
    n_experts: int
    top_k: int
    d_model: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0          # total shared-expert hidden width
    capacity_factor: float = 1.25
    router_scale: bool = False    # normalize top-k weights to sum 1


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    p = {
        "router": normal_init(k1, (d, e), scale=d**-0.5, dtype=jnp.float32),
        "w_gate": normal_init(k2, (e, d, f), scale=d**-0.5, dtype=dtype),
        "w_up": normal_init(k3, (e, d, f), scale=d**-0.5, dtype=dtype),
        "w_down": normal_init(k4, (e, f, d), scale=f**-0.5, dtype=dtype),
    }
    if cfg.n_shared > 0:
        fs = cfg.d_ff_shared or cfg.d_ff_expert * cfg.n_shared
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "w_gate": normal_init(ks[0], (d, fs), scale=d**-0.5, dtype=dtype),
            "w_up": normal_init(ks[1], (d, fs), scale=d**-0.5, dtype=dtype),
            "w_down": normal_init(ks[2], (fs, d), scale=fs**-0.5, dtype=dtype),
        }
    return p


def moe_apply(params, x, cfg: MoEConfig):
    """x: (B, S, D) -> (B, S, D). Returns (y, aux) with load-balance loss."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    # Router in fp32 for numerics.
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)           # (T, K)
    if cfg.router_scale:
        weights = weights / jnp.maximum(
            weights.sum(axis=-1, keepdims=True), 1e-9
        )

    e = cfg.n_experts
    if t <= 4096:
        # Dropless small-T path (decode / small prefill): worst case every
        # token routes one of its k choices to the same expert -> cap = t.
        cap = t
    else:
        cap = max(1, int(t * cfg.top_k * cfg.capacity_factor / e))

    # Position of each (token, k) routing within its expert's capacity.
    flat_e = experts.reshape(-1)                                  # (T*K,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (T*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1                     # running index
    my_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = my_pos < cap                                           # capacity drop
    slot = jnp.where(keep, flat_e * cap + my_pos, e * cap)        # overflow bin

    # Scatter tokens to (E*cap+1, D) expert buffers.
    src = jnp.repeat(xt, cfg.top_k, axis=0)                       # (T*K, D)
    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype).at[slot].add(src)
    xe = buf[: e * cap].reshape(e, cap, d)

    # Expert SwiGLU (grouped einsum over the expert dim).
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(x.dtype))
    ye = jnp.einsum(
        "ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"].astype(x.dtype)
    )

    # Gather back + weighted combine.
    yflat = ye.reshape(e * cap, d)
    y_tok = jnp.where(
        keep[:, None], yflat[jnp.clip(slot, 0, e * cap - 1)], 0.0
    )                                                             # (T*K, D)
    y = (
        (y_tok.reshape(t, cfg.top_k, d) * weights[..., None].astype(x.dtype))
        .sum(axis=1)
        .reshape(b, s, d)
    )

    if "shared" in params:
        sh = params["shared"]
        gg = x @ sh["w_gate"].astype(x.dtype)
        uu = x @ sh["w_up"].astype(x.dtype)
        y = y + (jax.nn.silu(gg) * uu) @ sh["w_down"].astype(x.dtype)

    # Switch-style load-balance aux loss.
    me = probs.mean(axis=0)                                       # (E,)
    ce = (onehot.reshape(t, cfg.top_k, e).sum(axis=1) > 0).astype(
        jnp.float32
    ).mean(axis=0)
    aux = e * jnp.sum(me * ce)
    return y, aux
