"""Neural-net substrate shared by the assigned architectures."""
from . import attention, ffn, layers, mla, moe, rglru, rope, ssm
