"""Feed-forward blocks: SwiGLU (LLaMA-style) and plain GELU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import normal_init


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": normal_init(k1, (d_model, d_ff), scale=d_model**-0.5, dtype=dtype),
        "w_up": normal_init(k2, (d_model, d_ff), scale=d_model**-0.5, dtype=dtype),
        "w_down": normal_init(k3, (d_ff, d_model), scale=d_ff**-0.5, dtype=dtype),
    }


def swiglu(params, x):
    g = x @ params["w_gate"].astype(x.dtype)
    u = x @ params["w_up"].astype(x.dtype)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(x.dtype)


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": normal_init(k1, (d_model, d_ff), scale=d_model**-0.5, dtype=dtype),
        "w_out": normal_init(k2, (d_ff, d_model), scale=d_ff**-0.5, dtype=dtype),
    }


def gelu_mlp(params, x):
    return jax.nn.gelu(x @ params["w_in"].astype(x.dtype)) @ params["w_out"].astype(
        x.dtype
    )
