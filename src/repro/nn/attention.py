"""Attention: GQA/MQA/MHA with memory-efficient blockwise softmax.

Design notes (DESIGN.md §4):
  * train/prefill use *blockwise* attention -- an online-softmax scan over
    (q-chunk, k-chunk) tiles so the S x S score matrix never materializes
    (mandatory for prefill_32k; also keeps train_4k activation memory flat).
    The causal mask is applied additively per tile; off-diagonal masked tiles
    are still computed (XLA SPMD-friendly static schedule).  Skipping them is
    a recorded §Perf hillclimb lever.
  * GQA never materializes repeated KV heads: q is grouped to
    (B, H_kv, G, S, D) and contracted against (B, H_kv, S, D).
  * decode attends a (possibly rolling) cache with position masking.

All softmax accumulation in fp32 regardless of activation dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def _group_q(q, n_kv: int):
    b, hq, s, d = q.shape
    g = hq // n_kv
    return q.reshape(b, n_kv, g, s, d)


def blockwise_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    chunk_q: int = 512,
    chunk_k: int = 512,
    q_offset=0,
    unroll: bool = False,
    causal_skip: bool = False,
):
    """See module docstring.  ``causal_skip=True`` switches to the
    triangular pair schedule (flash-style): fully-masked (i, j) tiles are
    never computed, halving attention FLOPs/traffic for causal masks and
    cutting banded (window) masks to the live diagonal band -- §Perf lever.
    """
    if causal_skip and q.shape[2] > 1:
        return _blockwise_attention_pairs(
            q, k, v, causal=causal, window=window, chunk_q=chunk_q,
            chunk_k=chunk_k, q_offset=q_offset, unroll=unroll,
        )
    return _blockwise_attention_full(
        q, k, v, causal=causal, window=window, chunk_q=chunk_q,
        chunk_k=chunk_k, q_offset=q_offset, unroll=unroll,
    )


def _blockwise_attention_full(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    chunk_q: int = 512,
    chunk_k: int = 512,
    q_offset=0,
    unroll: bool = False,
):
    """Memory-efficient attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0.
    ``q_offset``: absolute position of q[..., 0, :] (chunked prefill).
    Returns (B, Hq, Sq, D).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]  # value dim may differ from qk dim (MLA)
    g = hq // hkv
    scale = d ** -0.5

    def _pick(s, c):
        c = min(c, s)
        while s % c:  # largest divisor <= requested chunk
            c -= 1
        return c

    cq = _pick(sq, chunk_q)
    ck = _pick(sk, chunk_k)
    nq = sq // cq
    nk = sk // ck

    qg = _group_q(q, hkv).reshape(b, hkv, g, nq, cq, d)
    qg = jnp.moveaxis(qg, 3, 0)  # (nq, b, hkv, g, cq, d)
    ks = jnp.moveaxis(k.reshape(b, hkv, nk, ck, d), 2, 0)  # (nk, b, hkv, ck, d)
    vs = jnp.moveaxis(v.reshape(b, hkv, nk, ck, dv), 2, 0)

    kpos = jnp.arange(nk * ck).reshape(nk, ck)

    def q_chunk_body(iq, q_chunk):
        qpos = q_offset + iq * cq + jnp.arange(cq)

        def kv_body(carry, xs):
            m, l, acc = carry
            k_c, v_c, kp = xs
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", q_chunk, k_c, preferred_element_type=jnp.float32
            ) * scale
            mask = jnp.ones((cq, ck), dtype=bool)
            if causal:
                mask &= qpos[:, None] >= kp[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kp[None, :]) < window
            s = jnp.where(mask, s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v_c.dtype), v_c,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, cq), NEG, dtype=jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), dtype=jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, dv), dtype=jnp.float32)
        if unroll:  # dry-run probe mode: explicit HLO for every tile
            carry = (m0, l0, a0)
            for j in range(nk):
                carry, _ = kv_body(carry, (ks[j], vs[j], kpos[j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (ks, vs, kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    if unroll:
        outs = jnp.stack([q_chunk_body(i, qg[i]) for i in range(nq)])
    else:
        # Remat per q-chunk: backward recomputes a chunk's online-softmax scan
        # instead of saving per-kv-step (m, l, acc) stacks (flash-bwd style).
        body = jax.checkpoint(
            q_chunk_body, policy=jax.checkpoint_policies.nothing_saveable
        )
        outs = jax.lax.map(
            lambda args: body(*args), (jnp.arange(nq), qg)
        )  # (nq, b, hkv, g, cq, dv)
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, sq, dv)
    return out.reshape(b, hq, sq, dv)


def _blockwise_attention_pairs(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    chunk_q: int = 512,
    chunk_k: int = 512,
    q_offset=0,
    unroll: bool = False,
):
    """Triangular (i, j) tile schedule: only tiles with at least one live
    (q, k) position are computed.  State for every q chunk is carried and
    updated at index i (online softmax), so FLOPs = live tiles only."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale = d ** -0.5

    def _pick(s, c):
        c = min(c, s)
        while s % c:
            c -= 1
        return c

    cq = _pick(sq, chunk_q)
    ck = _pick(sk, chunk_k)
    nq = sq // cq
    nk = sk // ck

    # Static live-tile list.
    pairs = []
    for i in range(nq):
        q_lo = q_offset + i * cq
        q_hi = q_offset + (i + 1) * cq - 1
        for j in range(nk):
            k_lo = j * ck
            k_hi = (j + 1) * ck - 1
            if causal and k_lo > q_hi:
                continue  # fully in the future
            if window is not None and (q_lo - k_hi) >= window:
                continue  # fully out of the band
            pairs.append((i, j))
    pair_i = jnp.array([p[0] for p in pairs], jnp.int32)
    pair_j = jnp.array([p[1] for p in pairs], jnp.int32)

    qg = _group_q(q, hkv).reshape(b, hkv, g, nq, cq, d)
    qg = jnp.moveaxis(qg, 3, 0)                       # (nq, b, hkv, g, cq, d)
    ks = jnp.moveaxis(k.reshape(b, hkv, nk, ck, d), 2, 0)
    vs = jnp.moveaxis(v.reshape(b, hkv, nk, ck, dv), 2, 0)

    m0 = jnp.full((nq, b, hkv, g, cq), NEG, dtype=jnp.float32)
    l0 = jnp.zeros((nq, b, hkv, g, cq), dtype=jnp.float32)
    a0 = jnp.zeros((nq, b, hkv, g, cq, dv), dtype=jnp.float32)

    def pair_body(carry, ij):
        m, l, acc = carry
        i, j = ij
        q_c = jax.lax.dynamic_index_in_dim(qg, i, 0, keepdims=False)
        k_c = jax.lax.dynamic_index_in_dim(ks, j, 0, keepdims=False)
        v_c = jax.lax.dynamic_index_in_dim(vs, j, 0, keepdims=False)
        qpos = q_offset + i * cq + jnp.arange(cq)
        kpos = j * ck + jnp.arange(ck)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", q_c, k_c, preferred_element_type=jnp.float32
        ) * scale
        mask = jnp.ones((cq, ck), dtype=bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask, s, NEG)
        m_i = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + p.sum(axis=-1)
        pv = jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32,
        )
        a_new = a_i * corr[..., None] + pv
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        return (m, l, acc), None

    if unroll:
        carry = (m0, l0, a0)
        for idx in range(len(pairs)):
            carry, _ = pair_body(carry, (pair_i[idx], pair_j[idx]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(pair_body, (m0, l0, a0), (pair_i, pair_j))
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # (nq, b, hkv, g, cq, dv)
    out = jnp.moveaxis(out.astype(q.dtype), 0, 3).reshape(b, hkv, g, sq, dv)
    return out.reshape(b, hq, sq, dv)


def decode_attention(q, k_cache, v_cache, pos, *, window: int | None = None):
    """Single-token attention over a (max_len) cache.

    q: (B, Hq, 1, D); caches: (B, Hkv, S_max, D); pos: scalar int32 --
    index of the *current* token (cache already updated at ``pos``).
    For rolling caches (window), ``k_cache`` holds the last ``window``
    positions at slots ``p % window``.
    """
    b, hq, _, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    dv = v_cache.shape[-1]
    scale = d ** -0.5
    qg = _group_q(q, hkv)  # (B, Hkv, G, 1, D)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    slots = jnp.arange(smax)
    if window is None:
        valid = slots <= pos  # (smax,)
    else:
        # slot s holds absolute position p = pos - ((pos - s) mod window)
        p_abs = pos - jnp.mod(pos - slots, window)
        valid = (p_abs >= 0) & (p_abs <= pos)
    s = s + jnp.where(valid, 0.0, NEG)  # broadcast over trailing smax dim
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, hq, 1, dv).astype(q.dtype)


def cache_update(k_cache, v_cache, k_new, v_new, pos, window: int | None = None):
    """Insert one step's K/V at position ``pos`` (mod window for rolling)."""
    slot = pos if window is None else jnp.mod(pos, window)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot, axis=2)
    return k_cache, v_cache
