"""Mamba-2 (SSD -- state-space duality, arXiv:2405.21060).

Chunked SSD for train/prefill (quadratic within chunks, linear recurrence
across chunks) and a constant-memory recurrent step for decode.  Follows the
minimal-SSD reference formulation:

  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t        (per head, state (P, N))
  y_t = C_t . h_t + D x_t
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import normal_init, rmsnorm, rmsnorm_init


class Mamba2Config(NamedTuple):
    d_model: int
    d_inner: int       # = expand * d_model
    n_heads: int       # d_inner = n_heads * head_p
    head_p: int
    n_groups: int      # B/C groups (G)
    d_state: int       # N
    d_conv: int = 4
    chunk: int = 128


def mamba2_init(key, cfg: Mamba2Config, dtype=jnp.float32):
    keys = jax.random.split(key, 8)
    d, di = cfg.d_model, cfg.d_inner
    gn = cfg.n_groups * cfg.d_state
    conv_ch = di + 2 * gn
    return {
        "in_proj": normal_init(
            keys[0], (d, 2 * di + 2 * gn + cfg.n_heads), d**-0.5, dtype
        ),
        "conv_w": normal_init(keys[1], (cfg.d_conv, conv_ch), 0.5, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, cfg.n_heads, dtype=jnp.float32)
        ),
        "dt_bias": jnp.zeros((cfg.n_heads,), dtype=jnp.float32),
        "d_skip": jnp.ones((cfg.n_heads,), dtype=jnp.float32),
        "gate_norm": rmsnorm_init(di, dtype),
        "out_proj": normal_init(keys[2], (di, d), di**-0.5, dtype),
    }


def _split_in(proj, cfg: Mamba2Config):
    di, gn, h = cfg.d_inner, cfg.n_groups * cfg.d_state, cfg.n_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * gn]
    dt = proj[..., di + di + 2 * gn :]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, kernel K. xbc: (B, S, C).

    If ``conv_state`` (B, K-1, C) is given (decode), it is prepended and the
    new state returned."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, S+K-1, C)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * conv_w[i].astype(xbc.dtype)
        for i in range(k)
    )
    out = out + conv_b.astype(xbc.dtype)
    new_state = xp[:, -(k - 1) :, :]
    return jax.nn.silu(out), new_state


def _segsum(x):
    """Cumulative segment-sum matrix: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(xh, dt, a, bmat, cmat, cfg: Mamba2Config, h0=None, unroll: bool = False):
    """Chunked SSD.

    xh: (B, S, H, P); dt: (B, S, H) (post-softplus); a: (H,) negative;
    bmat/cmat: (B, S, G, N).  Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    b, s, h, p = xh.shape
    g, n = bmat.shape[2], bmat.shape[3]
    q = min(cfg.chunk, s)
    s_orig = s
    if s % q:  # pad with dt=0 steps: decay exp(0)=1, zero contribution
        pad = q - s % q
        padf = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        xh, dt, bmat, cmat = padf(xh), padf(dt), padf(bmat), padf(cmat)
        s = s + pad
    nc = s // q
    rep = h // g

    f32 = jnp.float32
    xc = xh.reshape(b, nc, q, h, p).astype(f32)
    dtc = dt.reshape(b, nc, q, h).astype(f32)
    bc = bmat.reshape(b, nc, q, g, n).astype(f32)
    cc = cmat.reshape(b, nc, q, g, n).astype(f32)

    da = dtc * a  # (b, nc, q, h)
    da_cs = jnp.cumsum(da, axis=2)

    # Intra-chunk (diagonal blocks): y_i += C_i . (sum_{j<=i} decay * dt_j B_j x_j)
    l = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))          # (b,nc,h,q,q)
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", cc, bc)           # (b,nc,g,q,q)
    cb = jnp.repeat(cb, rep, axis=2)                        # (b,nc,h,q,q)
    m = cb * l * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # weight on x_k
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", m, xc)

    # Chunk-final states: S_c = sum_j decay_to_end * dt_j B_j x_j
    decay_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)        # (b,nc,q,h)
    b_h = jnp.repeat(bc, rep, axis=3)                       # per-head B (G small)
    sb = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", decay_end * dtc, b_h, xc)

    # Inter-chunk recurrence over chunk index.
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))              # (b,nc,h)

    def scan_fn(hprev, xs):
        s_c, dec = xs
        hnew = hprev * dec[..., None, None] + s_c
        return hnew, hprev  # emit state *entering* the chunk

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), dtype=f32)
    sb_t = sb.transpose(1, 0, 2, 3, 4)
    dec_t = chunk_decay.transpose(1, 0, 2)
    if unroll:  # dry-run probe mode
        carry, emitted = h0, []
        for c in range(nc):
            carry, out = scan_fn(carry, (sb_t[c], dec_t[c]))
            emitted.append(out)
        hfin, h_in = carry, jnp.stack(emitted)
    else:
        hfin, h_in = jax.lax.scan(scan_fn, h0, (sb_t, dec_t))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                    # (b,nc,h,p,n)

    # Off-diagonal contribution: y_i += (C_i . h_in) * exp(da_cs_i)
    c_h = jnp.repeat(cc, rep, axis=3)                       # (b,nc,q,h,n)
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", c_h, h_in) * jnp.exp(da_cs)[..., None]

    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(xh.dtype), hfin


def mamba2_forward(params, x, cfg: Mamba2Config, h0=None, conv_state=None,
                   unroll: bool = False):
    """Full-sequence forward. Returns (y, (conv_state, ssm_state))."""
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_in(proj, cfg)
    xbc, conv_state_new = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    xs = xbc[..., :di]
    bmat = xbc[..., di : di + gn].reshape(*x.shape[:2], cfg.n_groups, cfg.d_state)
    cmat = xbc[..., di + gn :].reshape(*x.shape[:2], cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    xh = xs.reshape(*x.shape[:2], cfg.n_heads, cfg.head_p)
    y, hfin = ssd_scan(xh, dt, a, bmat, cmat, cfg, h0, unroll=unroll)
    y = y + xh.astype(y.dtype) * params["d_skip"].astype(y.dtype)[:, None]
    y = y.reshape(*x.shape[:2], di)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"].astype(x.dtype), (conv_state_new, hfin)


def mamba2_decode(params, x, cfg: Mamba2Config, state):
    """Single-token recurrent step. state = (conv_state (B,K-1,C), h (B,H,P,N))."""
    conv_state, h = state
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_in(proj, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    xs = xbc[..., :di]
    bmat = xbc[..., di : di + gn].reshape(x.shape[0], 1, cfg.n_groups, cfg.d_state)
    cmat = xbc[..., di + gn :].reshape(x.shape[0], 1, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"])
    xh = xs.reshape(x.shape[0], cfg.n_heads, cfg.head_p).astype(jnp.float32)

    rep = cfg.n_heads // cfg.n_groups
    bh = jnp.repeat(bmat[:, 0], rep, axis=1).astype(jnp.float32)  # (B,H,N)
    ch = jnp.repeat(cmat[:, 0], rep, axis=1).astype(jnp.float32)

    decay = jnp.exp(dt * a)                                        # (B,H)
    h = h * decay[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, bh, xh
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, ch)
    y = y + xh * params["d_skip"][:, None]
    y = y.reshape(x.shape[0], 1, di).astype(x.dtype)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"].astype(x.dtype), (conv_state, h)
